"""Fused-stage megakernel tests (DESIGN.md §10).

Pins the four contracts of the fused execution pipeline:

* ``cluster()`` is lossless (expanding clusters recovers the program)
  and, per the transaction model, saves >= 2x HBM round trips on the
  acceptance workloads (2^12 sort and FFT).
* Fused-cluster outputs are BIT-IDENTICAL to per-stage ref execution
  for permutation/compare/map clusters across dtypes x trailing dims x
  batch sizes (a compare-exchange moves values without arithmetic).
  Butterfly clusters are linear algebra in float — identical operation
  DAG, but XLA may fuse differently — so they pin to a few-ulp bound.
* ``jax.grad`` through a fused sort still matches the per-stage ref
  grad and the argsort oracle (fused_apply's save-x + per-stage-replay
  VJP).
* ``CompiledExpr.inverse`` round-trips through the PALLAS engine, not
  just the vjp oracle.
"""
import random

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.combinators import (FusedStage, cache_stats, clear_caches, cluster,
                               compile_expr, expand_clusters,
                               program_cost, run_program, vocab as V)
from repro.combinators.fft import compiled_fft, fft_expr, to_planar
from repro.combinators.optimize import optimize
from repro.combinators.sort import compiled_sort, sort_expr
from repro.core.bmmc import Bmmc
from repro.kernels.ops import choose_tile


@pytest.fixture(autouse=True, scope="module")
def _bounded_caches():
    """This module sweeps many (n, dtype, tail, batch) geometries; drop
    the pinned jitted executables when done (ISSUE 4 satellite)."""
    yield
    clear_caches()


def _payload(shape, dtype, seed):
    vals = np.random.default_rng(seed).integers(0, 1 << 16, shape)
    return jnp.asarray(vals).astype(dtype)


def _assert_bitwise(got, want, ctx):
    assert got.dtype == want.dtype, ctx
    assert np.array_equal(np.asarray(got).view(np.uint8),
                          np.asarray(want).view(np.uint8)), ctx


# ---------------------------------------------------------------------------
# cluster(): structure + transaction model
# ---------------------------------------------------------------------------

@pytest.mark.tier1
@pytest.mark.parametrize("name,mk", [("sort", sort_expr), ("fft", fft_expr)])
def test_cluster_lossless_and_2x_round_trips(name, mk):
    """ISSUE 4 acceptance: clustering is a pure regrouping, and the fused
    2^12 sort/FFT cost >= 2x fewer HBM round trips in the model."""
    n = 12
    prog = optimize(mk(n), n)
    t = choose_tile(n, 4, 2 if name == "fft" else 1)
    clustered = cluster(prog, n, t)
    assert expand_clusters(clustered) == prog
    assert any(isinstance(s, FusedStage) for s in clustered)
    c0 = program_cost(prog, t)
    c1 = program_cost(clustered, t)
    assert c1["round_trips"] * 2 <= c0["round_trips"], (name, c0, c1)
    assert c1["round_trips_unfused"] == c0["round_trips"]
    assert c1["round_trips_saved"] == c0["round_trips"] - c1["round_trips"]
    assert c1["bytes_moved"] < c0["bytes_moved"]


@pytest.mark.tier1
def test_cluster_composition_consistency():
    """Each FusedStage composes exactly the perms it swallowed, and each
    compute's prefix is the composition of the perms before it."""
    n = 8
    prog = optimize(sort_expr(n), n)
    for s in cluster(prog, n, choose_tile(n, 4, 1)):
        if not isinstance(s, FusedStage):
            continue
        acc = Bmmc.identity(n)
        ci = 0
        for stage in s.stages:
            if hasattr(stage, "bmmc"):
                acc = stage.bmmc @ acc
            else:
                assert s.computes[ci][0] is stage
                assert s.computes[ci][1] == acc
                ci += 1
        assert acc == s.bmmc
        assert ci == len(s.computes)


@pytest.mark.tier1
def test_cluster_none_tile_is_identity():
    n = 6
    prog = optimize(sort_expr(n), n)
    assert cluster(prog, n, None) == prog


# ---------------------------------------------------------------------------
# Parity fuzz: fused pallas vs per-stage ref, bit-identical
# ---------------------------------------------------------------------------

@pytest.mark.tier1
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.int32, jnp.bfloat16])
@pytest.mark.parametrize("tail", [(), (3,)])
def test_fused_sort_parity_dtypes_tails(dtype, tail):
    n = 6
    f_pal = compiled_sort(n, engine="pallas")
    f_ref = compiled_sort(n, engine="ref")
    t = choose_tile(n, jnp.dtype(dtype).itemsize, tail[0] if tail else 1)
    assert any(isinstance(s, FusedStage)
               for s in f_pal.clustered_program(n, t)), "megakernel unused"
    x = _payload((1 << n,) + tail, dtype, seed=hash((str(dtype), tail)) % 997)
    got, want = f_pal(x), f_ref(x)
    _assert_bitwise(got, want, (dtype, tail))
    if not tail and dtype != jnp.bfloat16:
        assert np.array_equal(np.sort(np.asarray(x)), np.asarray(got))


@pytest.mark.tier1
@pytest.mark.parametrize("bsz", [1, 3])
def test_fused_sort_parity_batched(bsz):
    n = 7
    f_pal = compiled_sort(n, engine="pallas")
    f_ref = compiled_sort(n, engine="ref")
    x = _payload((bsz, 1 << n), jnp.float32, seed=bsz)
    _assert_bitwise(f_pal(x, batched=True), f_ref(x, batched=True), bsz)


@pytest.mark.tier1
@pytest.mark.parametrize("seed", range(4))
def test_fused_mixed_program_fuzz(seed):
    """Random perm/compare/map programs: clustered pallas == per-stage
    ref, bitwise, across dtype x tail x batch drawn per seed."""
    rng = random.Random(seed)
    n = rng.choice([6, 7])
    parts = [V.perm(Bmmc.random_bpc(n, rng))]
    for _ in range(rng.choice([2, 3])):
        parts.append(V.cmp_halves())
        parts.append(V.perm(Bmmc.random_bpc(n, rng)
                            if rng.random() < 0.7
                            else Bmmc.random(n, rng)))
    if rng.random() < 0.5:
        parts.insert(2, V.emap("x2", lambda v: v * 2))
    e = V.seq(*parts)
    dtype = [jnp.float32, jnp.int32, jnp.bfloat16][seed % 3]
    tail = [(), (2,)][seed % 2]
    batched = seed % 2 == 1
    shape = ((2,) if batched else ()) + (1 << n,) + tail
    x = _payload(shape, dtype, seed)
    f_pal = compile_expr(e, engine="pallas")
    f_ref = compile_expr(e, engine="ref")
    got = f_pal(x, batched=batched)
    want = f_ref(x, batched=batched)
    _assert_bitwise(got, want, (seed, n, dtype, tail, batched))


@pytest.mark.tier1
def test_fused_fft_parity_ulp():
    """Butterfly clusters: same value DAG, so pallas matches ref to a few
    float32 ulp (XLA fusion may differ; bit-identity is not guaranteed
    for float multiply-adds)."""
    n = 7
    rng = np.random.default_rng(3)
    z = (rng.normal(size=1 << n) + 1j * rng.normal(size=1 << n))
    x = to_planar(z.astype(np.complex64))
    f_pal = compiled_fft(n, engine="pallas")
    t = choose_tile(n, 4, 2)
    assert any(isinstance(s, FusedStage)
               for s in f_pal.clustered_program(n, t)), "megakernel unused"
    got = np.asarray(f_pal(x))
    want = np.asarray(compiled_fft(n, engine="ref")(x))
    assert np.allclose(got, want, rtol=1e-4, atol=1e-4)
    # and the fused pipeline is still a correct FFT
    full = got[..., 0] + 1j * got[..., 1]
    assert np.allclose(full, np.fft.fft(z), rtol=1e-3, atol=1e-3)


@pytest.mark.tier1
def test_fused_complex_dtype_falls_back_per_stage():
    """Complex arrays can't enter the megakernel (pallas TPU has no
    complex dtype); the cluster transparently replays per-stage."""
    n = 6
    z = jnp.asarray(np.random.default_rng(4).normal(size=1 << n)
                    + 1j * np.random.default_rng(5).normal(size=1 << n),
                    jnp.complex64)
    got = np.asarray(compiled_fft(n, engine="pallas")(z))
    want = np.asarray(compiled_fft(n, engine="ref")(z))
    assert np.allclose(got, want, rtol=1e-4, atol=1e-4)


# ---------------------------------------------------------------------------
# Inverse round-trip through the pallas engine (ISSUE 4 satellite)
# ---------------------------------------------------------------------------

@pytest.mark.tier1
def test_inverse_roundtrip_through_pallas_engine():
    n = 8
    rng = random.Random(9)
    e = (V.bit_reverse(n) >> V.perm(Bmmc.random(n, rng)) >> V.riffle(n)
         >> V.perm(Bmmc.random_bpc(n, rng)))
    f = compile_expr(e, engine="pallas")
    finv = f.inverse(n)
    assert finv.engine == "pallas"
    x = _payload((1 << n,), jnp.float32, 9)
    _assert_bitwise(finv(f(x)), x, "unbatched roundtrip")
    xb = _payload((3, 1 << n), jnp.int32, 10)
    _assert_bitwise(finv(f(xb, batched=True), batched=True), xb,
                    "batched roundtrip")


# ---------------------------------------------------------------------------
# Autodiff through fused clusters
# ---------------------------------------------------------------------------

@pytest.mark.tier1
def test_grad_through_fused_sort_matches_oracle():
    """ISSUE 4 acceptance: jax.grad through a fused (megakernel) sort ==
    per-stage ref grad == the argsort oracle."""
    n = 6
    x = jnp.asarray(np.random.default_rng(11).normal(
        size=1 << n).astype(np.float32))
    w = jnp.asarray(np.random.default_rng(12).normal(
        size=1 << n).astype(np.float32))
    grads = {}
    for engine in ("ref", "pallas"):
        f = compiled_sort(n, engine=engine)
        grads[engine] = np.asarray(
            jax.grad(lambda v: jnp.sum(w * f(v)))(x))
    assert np.allclose(grads["pallas"], grads["ref"], atol=1e-6)
    order = np.argsort(np.asarray(x), kind="stable")
    want = np.empty_like(np.asarray(w))
    want[order] = np.asarray(w)
    assert np.allclose(grads["ref"], want, atol=1e-6)


@pytest.mark.tier1
def test_batched_grad_through_fused_sort():
    n = 6
    xb = _payload((3, 1 << n), jnp.float32, 21).astype(jnp.float32)
    w = _payload((3, 1 << n), jnp.float32, 22).astype(jnp.float32)
    grads = {}
    for engine in ("ref", "pallas"):
        f = compiled_sort(n, engine=engine)
        grads[engine] = np.asarray(jax.grad(
            lambda v: jnp.sum(w * f(v, batched=True)))(xb))
    assert np.allclose(grads["pallas"], grads["ref"], atol=1e-6)


@pytest.mark.tier1
def test_grad_through_fused_fft_matches_ref():
    n = 6
    rng = np.random.default_rng(13)
    x = to_planar((rng.normal(size=1 << n)
                   + 1j * rng.normal(size=1 << n)).astype(np.complex64))
    w = jnp.asarray(rng.normal(size=(1 << n, 2)).astype(np.float32))
    grads = {}
    for engine in ("ref", "pallas"):
        f = compiled_fft(n, engine=engine)
        grads[engine] = np.asarray(
            jax.grad(lambda v: jnp.sum(w * f(v)))(x))
    assert np.allclose(grads["pallas"], grads["ref"], rtol=1e-4, atol=1e-4)


# ---------------------------------------------------------------------------
# Cache hygiene
# ---------------------------------------------------------------------------

@pytest.mark.tier1
def test_clear_caches_drops_executables():
    n = 6
    f = compile_expr(V.riffle(n) >> V.bit_reverse(n), engine="pallas")
    f(_payload((1 << n,), jnp.float32, 0))
    assert cache_stats()["geom"].currsize > 0
    clear_caches()
    assert cache_stats()["geom"].currsize == 0
