"""TilePlan invariants (paper §4.1): coverage, coalescing, conflict-freedom."""
import random

import numpy as np
import pytest
from _hyp_compat import given, settings, strategies as st

from repro.core.bmmc import Bmmc
from repro.core.tiling import naive_write_runs, plan_bmmc, plan_tiled


@given(st.integers(6, 12), st.integers(0, 10**6), st.integers(2, 4))
@settings(max_examples=40, deadline=None)
def test_tile_row_coverage(n, seed, t):
    """Every input row is read exactly once; every output row written once."""
    if 2 * t > n:
        return
    b = Bmmc.random_bpc(n, random.Random(seed))
    p = plan_tiled(b, t)
    assert p is not None
    nrows = 1 << (n - t)
    assert sorted(p.in_rows.reshape(-1).tolist()) == list(range(nrows))
    assert sorted(p.out_rows.reshape(-1).tolist()) == list(range(nrows))


@given(st.integers(6, 12), st.integers(0, 10**6), st.integers(2, 4))
@settings(max_examples=40, deadline=None)
def test_src0_is_tile_permutation(n, seed, t):
    if 2 * t > n:
        return
    b = Bmmc.random_bpc(n, random.Random(seed))
    p = plan_tiled(b, t)
    flat = p.src0.reshape(-1)
    assert sorted(flat.tolist()) == list(range(flat.size))


@given(st.integers(6, 12), st.integers(0, 10**6))
@settings(max_examples=30, deadline=None)
def test_bpc_has_zero_xor(n, seed):
    """For BPCs the per-tile lane XOR vanishes (block bits map high)."""
    b = Bmmc.random_bpc(n, random.Random(seed))
    p = plan_tiled(b, min(3, n // 2))
    assert p is not None
    assert (p.xor_low == 0).all()


def test_simulated_kernel_matches_reference():
    """Full numpy simulation of the tiled pipeline == direct permutation."""
    rng = random.Random(9)
    for n, t in [(10, 3), (12, 4)]:
        for b in (Bmmc.bit_reverse(n), Bmmc.random(n, rng)):
            plans = plan_bmmc(b, t)
            x = np.arange(1 << n)
            cur = x
            for p in plans:
                rl = p.row_len
                xv = cur.reshape(-1, rl)
                out = np.empty_like(xv)
                for g in range(p.n_tiles):
                    tile = xv[p.in_rows[g]].reshape(-1)
                    j = np.arange(tile.size)
                    src = p.src0.reshape(-1)[(j & ~(rl - 1)) | ((j ^ p.xor_low[g]) & (rl - 1))]
                    out[p.out_rows[g]] = tile[src].reshape(-1, rl)
                cur = out.reshape(-1)
            want = np.empty_like(x)
            for i in range(1 << n):
                want[b.apply(i)] = x[i]
            assert np.array_equal(cur, want)


def test_transaction_model_tiled_vs_naive():
    """The tiled pipeline is fully coalesced; the naive kernel is not.

    This is the offline counterpart of the paper's Fig. 9: bit-reversal's
    naive kernel touches ~seg_elems segments per warp (worst case), the
    tiled kernel exactly 1 contiguous run per row.
    """
    n, t = 16, 4
    b = Bmmc.bit_reverse(n)
    runs = naive_write_runs(b, seg_elems=1 << t)
    assert runs == float(1 << t)          # worst case: fully uncoalesced
    p = plan_tiled(b, t)
    in_bytes, out_bytes = p.bytes_per_descriptor(4)
    assert in_bytes >= (1 << t) * 4 and out_bytes >= (1 << t) * 4
    # identity: naive already coalesced
    assert naive_write_runs(Bmmc.identity(n), seg_elems=1 << t) == 1.0
