"""HLO analyzer: collective bytes + trip-weighted flops vs hand counts.

Runs in a subprocess with 8 fake devices (jax device count is locked at
first import in the main test process).
"""
import json
import subprocess
import sys

import pytest

SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import json
import jax, jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.launch.hlo_analysis import analyze_hlo

kw = ({"axis_types": (jax.sharding.AxisType.Auto,) * 2}
      if hasattr(jax.sharding, "AxisType") else {})
mesh = jax.make_mesh((4, 2), ("data", "model"), **kw)
M, N, K, T = 256, 128, 64, 5

def f(x, w):
    def body(c, _):
        c = c @ w
        c = c @ w.T
        return c, ()
    y, _ = jax.lax.scan(body, x, None, length=T)
    return y.sum()

jf = jax.jit(f, in_shardings=(NamedSharding(mesh, P("data", None)),
                              NamedSharding(mesh, P(None, "model"))))
comp = jf.lower(jax.ShapeDtypeStruct((M, K), jnp.float32),
                jax.ShapeDtypeStruct((K, N), jnp.float32)).compile()
r = analyze_hlo(comp.as_text())
print(json.dumps(r))
"""


@pytest.mark.slow
def test_analyzer_hand_count():
    out = subprocess.run([sys.executable, "-c", SCRIPT], capture_output=True,
                         text=True, env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin",
                                          "HOME": "/root"})
    assert out.returncode == 0, out.stderr[-2000:]
    r = json.loads(out.stdout.strip().splitlines()[-1])
    # per device, 5 loop iterations:
    #  - all-reduce of the (M/4, N/2->K) intermediate: 5 * 64*64*4 B
    #    (+ one scalar f32 all-reduce for the final sum: 4 B)
    assert r["all-reduce"] == 5 * 64 * 64 * 4 + 4
    #  - dots: c@w (out 64x64, contract 64) + c@w.T (out 64x64, contract 64)
    assert r["dot_flops"] == 5 * 2 * (2 * 64 * 64 * 64)
    assert r["collective_total"] == r["all-reduce"]


def test_analyzer_plain_text():
    """Parser handles a minimal synthetic module (no jax involved)."""
    from repro.launch.hlo_analysis import analyze_hlo
    hlo = """
HloModule test

%body (p: (s32[], f32[8,8])) -> (s32[], f32[8,8]) {
  %p = (s32[], f32[8,8]) parameter(0)
  %g = f32[8,8] get-tuple-element(%p), index=1
  %ar = f32[8,8] all-reduce(%g), replica_groups={}
  %i = s32[] get-tuple-element(%p), index=0
  ROOT %t = (s32[], f32[8,8]) tuple(%i, %ar)
}

%cond (p: (s32[], f32[8,8])) -> pred[] {
  %p = (s32[], f32[8,8]) parameter(0)
  %i = s32[] get-tuple-element(%p), index=0
  %c = s32[] constant(3)
  ROOT %lt = pred[] compare(%i, %c), direction=LT
}

ENTRY %main (a: f32[8,8]) -> f32[8,8] {
  %a = f32[8,8] parameter(0)
  %z = s32[] constant(0)
  %tup = (s32[], f32[8,8]) tuple(%z, %a)
  %w = (s32[], f32[8,8]) while(%tup), condition=%cond, body=%body
  ROOT %o = f32[8,8] get-tuple-element(%w), index=1
}
"""
    r = analyze_hlo(hlo)
    assert r["all-reduce"] == 3 * 8 * 8 * 4  # trip count 3 from the cond


def test_analyzer_tuple_result_collective():
    """Tuple-typed collectives (XLA-combined ops): operand parens follow the
    opcode, not the result type — regression for the all-to-all undercount."""
    from repro.launch.hlo_analysis import analyze_hlo
    hlo = """
HloModule t

ENTRY %main (a: f32[4,8], b: f32[4,8]) -> f32[4,8] {
  %a = f32[4,8] parameter(0)
  %b = f32[4,8] parameter(1)
  %aa = (f32[4,8], f32[4,8]) all-to-all(%a, %b), replica_groups={}
  %g0 = f32[4,8] get-tuple-element(%aa), index=0
  %ar = (f32[4,8], f32[4,8]) all-reduce(%g0, %b), replica_groups={}, to_apply=%main
  ROOT %o = f32[4,8] get-tuple-element(%ar), index=0
}
"""
    r = analyze_hlo(hlo)
    assert r["all-to-all"] == 2 * 4 * 8 * 4
    assert r["all-reduce"] == 2 * 4 * 8 * 4
