"""Property-based F2/BMMC algebra tests (via the _hyp_compat shim).

Random compose/inverse round-trips, ``f2.ulp`` factorization validity and
BP/BPC/tiled classification invariants across sizes n = 2..16 — the
offline algebra every kernel plan and every autodiff inverse relies on.
"""
import random

import pytest
from _hyp_compat import given, settings, strategies as st

from repro.core import f2
from repro.core.bmmc import Bmmc


def _rand_bmmc(n, rng, bpc=False):
    return Bmmc.random_bpc(n, rng) if bpc else Bmmc.random(n, rng)


# ---------------------------------------------------------------------------
# compose / inverse round-trips
# ---------------------------------------------------------------------------

@pytest.mark.tier1
@given(st.integers(2, 16), st.integers(0, 10**6), st.booleans())
@settings(max_examples=40, deadline=None)
def test_inverse_roundtrip(n, seed, bpc):
    """b.inverse() is a two-sided inverse, elementwise and as a matrix."""
    rng = random.Random(seed)
    b = _rand_bmmc(n, rng, bpc)
    binv = b.inverse()
    assert binv.compose(b).is_identity_perm()
    assert b.compose(binv).is_identity_perm()
    for _ in range(8):
        x = rng.randrange(1 << n)
        assert binv.apply(b.apply(x)) == x
        assert b.apply(binv.apply(x)) == x
    assert binv.inverse() == b


@pytest.mark.tier1
@given(st.integers(2, 16), st.integers(0, 10**6))
@settings(max_examples=40, deadline=None)
def test_compose_is_function_composition(n, seed):
    """(a @ b).apply == a.apply ∘ b.apply on random indices."""
    rng = random.Random(seed)
    a, b = _rand_bmmc(n, rng), _rand_bmmc(n, rng, bpc=True)
    ab = a @ b
    for _ in range(8):
        x = rng.randrange(1 << n)
        assert ab.apply(x) == a.apply(b.apply(x))


@pytest.mark.tier1
@given(st.integers(2, 12), st.integers(0, 10**6))
@settings(max_examples=25, deadline=None)
def test_compose_associative_and_identity(n, seed):
    rng = random.Random(seed)
    a, b, c = (_rand_bmmc(n, rng) for _ in range(3))
    assert (a @ b) @ c == a @ (b @ c)
    i = Bmmc.identity(n)
    assert a @ i == a and i @ a == a


@pytest.mark.tier1
@given(st.integers(2, 16), st.integers(0, 10**6))
@settings(max_examples=25, deadline=None)
def test_inverse_antihomomorphism(n, seed):
    """(a @ b)^-1 == b^-1 @ a^-1."""
    rng = random.Random(seed)
    a, b = _rand_bmmc(n, rng), _rand_bmmc(n, rng)
    assert (a @ b).inverse() == b.inverse() @ a.inverse()


# ---------------------------------------------------------------------------
# f2.ulp factorization validity
# ---------------------------------------------------------------------------

@pytest.mark.tier1
@given(st.integers(2, 16), st.integers(0, 10**6))
@settings(max_examples=40, deadline=None)
def test_ulp_factorization_valid(n, seed):
    """M = U L P with U upper, L lower, both unit-diagonal, P a perm."""
    rng = random.Random(seed)
    m = f2.random_invertible(n, rng)
    u, l, p = f2.ulp(m)
    assert f2.matmul(u, f2.matmul(l, p)) == m
    assert f2.is_upper(u) and f2.is_unit_diag(u)
    assert f2.is_lower(l) and f2.is_unit_diag(l)
    assert f2.to_perm(p) is not None


@pytest.mark.tier1
@given(st.integers(2, 16), st.integers(0, 10**6))
@settings(max_examples=25, deadline=None)
def test_lup_factorization_valid(n, seed):
    """The underlying column-pivoted LUP: M = L U P."""
    rng = random.Random(seed)
    m = f2.random_invertible(n, rng)
    l, u, p = f2.lup(m)
    assert f2.matmul(l, f2.matmul(u, p)) == m
    assert f2.is_lower(l) and f2.is_unit_diag(l)
    assert f2.is_upper(u)
    assert f2.to_perm(p) is not None


@pytest.mark.tier1
@given(st.integers(3, 14), st.integers(0, 10**6), st.integers(1, 6))
@settings(max_examples=30, deadline=None)
def test_factor_tiled_composes_back(n, seed, t):
    """factor_tiled yields 1-2 factors, each tiled, composing to self."""
    t = min(t, max(1, n // 2))
    rng = random.Random(seed)
    b = _rand_bmmc(n, rng)
    factors = b.factor_tiled(t)
    assert 1 <= len(factors) <= 2
    acc = factors[0]
    for f in factors[1:]:
        acc = f @ acc
    assert acc == b
    if t < n:
        for f in factors:
            assert f.is_tiled(t)


# ---------------------------------------------------------------------------
# classification invariants
# ---------------------------------------------------------------------------

@pytest.mark.tier1
@given(st.integers(2, 16), st.integers(0, 10**6))
@settings(max_examples=40, deadline=None)
def test_classification_invariants(n, seed):
    """BP => BPC; BPC closed under compose/inverse; perm() faithful."""
    rng = random.Random(seed)
    bp = Bmmc(f2.random_perm_matrix(n, rng))
    bpc = _rand_bmmc(n, rng, bpc=True)
    assert bp.is_bp() and bp.is_bpc()
    assert bpc.is_bpc()
    assert bpc.is_bp() == (bpc.c == 0)
    assert (bpc @ bp).is_bpc()
    assert bpc.inverse().is_bpc()
    p = bp.perm()
    assert sorted(p) == list(range(n))
    for _ in range(4):
        x = rng.randrange(1 << n)
        y = bp.apply(x)
        for j in range(n):
            assert ((y >> p[j]) & 1) == ((x >> j) & 1)


@pytest.mark.tier1
@given(st.integers(2, 16), st.integers(0, 10**6), st.integers(1, 8))
@settings(max_examples=40, deadline=None)
def test_bpc_always_tiled(n, seed, t):
    """Every BPC is tiled for every t <= n (paper §5.1); witness valid."""
    t = min(t, n)
    rng = random.Random(seed)
    b = _rand_bmmc(n, rng, bpc=True)
    cols = b.tiled_columns(t)
    assert cols is not None
    low_mask = (1 << t) - 1
    sub = [f2.column(b.rows, j) for j in cols]
    assert all((c >> t) == 0 for c in sub)      # zero block below
    assert f2.rank(tuple(c & low_mask for c in sub)) == t  # invertible top


@pytest.mark.tier1
@given(st.integers(4, 14), st.integers(0, 10**6))
@settings(max_examples=25, deadline=None)
def test_tiled_closed_under_inverse_of_factors(n, seed):
    """Inverses of tiled factors stay invertible and compose to b^-1."""
    t = max(2, n // 3)
    rng = random.Random(seed)
    b = _rand_bmmc(n, rng)
    factors = b.factor_tiled(t)
    inv = Bmmc.identity(n)
    for f in factors:  # (f2 f1)^-1 = f1^-1 f2^-1
        inv = inv @ f.inverse()
    assert inv == b.inverse()
