"""Resilience layer (DESIGN.md §16): breaker state machine properties,
retry/backoff/deadline policy, admission control, the guard-runtime
breaker wiring (zero per-call trap cost while open, counter-verified),
the chaos soak SLOs, and the serve.py SIGTERM graceful-drain contract.
"""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np
import pytest

from _hyp_compat import given, settings, strategies as st
from repro import guard, resilience
from repro.resilience import breaker, chaos, policy


@pytest.fixture(autouse=True)
def _fresh_resilience():
    """Every test starts and ends with a clean board + zeroed counters
    (and fresh guard stats: breaker tests trip guard counters too)."""
    resilience.reset()
    guard.reset_stats()
    yield
    resilience.reset()
    guard.reset_stats()


def _opened(threshold: int, cooldown: int) -> breaker.Breaker:
    b = breaker.Breaker(threshold, cooldown)
    for _ in range(threshold):
        b.on_failure(False)
    assert b.state == breaker.OPEN
    return b


# ---------------------------------------------------------------------------
# breaker state machine properties
# ---------------------------------------------------------------------------

@pytest.mark.tier1
@settings(max_examples=40, deadline=None)
@given(st.integers(1, 5), st.integers(1, 8), st.integers(0, 255))
def test_no_exit_from_open_before_cooldown(threshold, cooldown, noise):
    """OPEN holds for the full cool-down no matter what outcome
    notifications arrive (shunted calls report against the fallback —
    they must never advance the protected circuit)."""
    b = _opened(threshold, cooldown)
    for i in range(cooldown - 1):
        assert b.decide() == "shunt"
        if noise & (1 << (i % 8)):
            b.on_success(False)
            b.on_failure(False)
        assert b.state == breaker.OPEN
    assert b.decide() == "shunt"   # the cool-down-completing call still
    assert b.state == breaker.HALF_OPEN   # routes away; the NEXT probes


@pytest.mark.tier1
@settings(max_examples=40, deadline=None)
@given(st.integers(1, 4), st.integers(1, 6), st.integers(2, 9))
def test_half_open_admits_exactly_one_probe(threshold, cooldown, calls):
    b = _opened(threshold, cooldown)
    for _ in range(cooldown):
        b.decide()
    decisions = [b.decide() for _ in range(calls)]
    assert decisions[0] == "probe"
    assert all(d == "shunt" for d in decisions[1:])
    assert b.probes == 1


@pytest.mark.tier1
@settings(max_examples=40, deadline=None)
@given(st.integers(1, 4), st.integers(1, 6))
def test_trap_during_probe_reopens(threshold, cooldown):
    b = _opened(threshold, cooldown)
    for _ in range(cooldown):
        b.decide()
    assert b.decide() == "probe"
    b.on_failure(True)
    assert b.state == breaker.OPEN
    assert b.cool_remaining == cooldown    # a FULL fresh cool-down
    assert b.opens == 2
    # ... and the machine still works: cool down again, probe, close
    for _ in range(cooldown + 1):
        b.decide()
    assert b.probe_inflight
    b.on_success(True)
    assert b.state == breaker.CLOSED and b.closes == 1


@pytest.mark.tier1
def test_closed_successes_reset_consecutive_failures():
    b = breaker.Breaker(threshold=3, cooldown=2)
    for _ in range(10):                    # never 3 consecutive
        b.on_failure(False)
        b.on_failure(False)
        b.on_success(False)
    assert b.state == breaker.CLOSED and b.opens == 0


# ---------------------------------------------------------------------------
# breaker board routing
# ---------------------------------------------------------------------------

@pytest.mark.tier1
def test_board_opens_shunts_probes_and_closes():
    board = breaker.BreakerBoard(threshold=2, cooldown=3)
    for _ in range(2):
        r = board.route("pallas")
        assert r.engine == "pallas" and not r.engaged
        board.on_trap(r, ("oob",))
    assert board.engaged("pallas")
    # open: exactly `cooldown` calls shunt to ref with no accounting
    # against the protected circuit
    for _ in range(3):
        r = board.route("pallas")
        assert r.shunted and r.engine == "ref" and r.requested == "pallas"
        board.on_success(r)                # shunted success: no close
    assert board.engaged("pallas")
    r = board.route("pallas")
    assert r.probe and r.engine == "pallas"
    board.on_success(r)
    assert not board.engaged("pallas")
    s = board.stats()
    assert s == {"open": 1, "probe": 1, "close": 1, "shunt": 3}


@pytest.mark.tier1
def test_board_trapped_probe_reopens_all_half_open():
    board = breaker.BreakerBoard(threshold=1, cooldown=2)
    r = board.route("pallas")
    board.on_trap(r, ("oob", "parity"))    # two circuits open at once
    for _ in range(2):
        assert board.route("pallas").shunted
    r = board.route("pallas")
    assert r.probe
    board.on_trap(r, ("oob",))             # probe traps on ONE kind...
    assert board.engaged("pallas")
    snap = board.snapshot()
    assert snap["pallas/oob"]["state"] == breaker.OPEN
    assert snap["pallas/parity"]["state"] == breaker.OPEN  # ...reopens BOTH


@pytest.mark.tier1
def test_board_never_protects_the_engine_of_last_resort():
    board = breaker.BreakerBoard(threshold=1, cooldown=1)
    r = board.route("ref")
    assert r.engine == "ref" and not r.engaged
    board.on_trap(r, ("oob",))             # ref has nowhere to degrade to
    assert not board.engaged("ref")
    assert board.snapshot() == {}
    fn = len                               # injected engine callables too
    r2 = board.route(fn)
    assert r2.engine is fn and not r2.engaged


# ---------------------------------------------------------------------------
# retry/backoff policy
# ---------------------------------------------------------------------------

@pytest.mark.tier1
@settings(max_examples=40, deadline=None)
@given(st.integers(0, 10_000), st.integers(0, 500), st.integers(0, 6))
def test_backoff_jitter_deterministic_and_bounded(seed, rid, attempt):
    p = policy.RetryPolicy(seed=seed)
    d = p.delay_s(attempt, rid)
    assert d == policy.RetryPolicy(seed=seed).delay_s(attempt, rid)
    cap = min(p.max_delay_s, p.base_delay_s * 2 ** attempt)
    assert cap * (1.0 - p.jitter) <= d <= cap


@pytest.mark.tier1
def test_backoff_decorrelates_requests_under_one_seed():
    p = policy.RetryPolicy(seed=0)
    delays = {p.delay_s(2, rid) for rid in range(16)}
    assert len(delays) == 16


@pytest.mark.tier1
def test_classification_table():
    assert policy.classify(guard.CachePoisoned("x")) == policy.RETRYABLE
    assert policy.classify(guard.GuardTrap(("oob",), "pallas")) \
        == policy.RETRYABLE
    # the step-level nonfinite health check recomputes deterministically
    assert policy.classify(guard.GuardTrap(("nonfinite",), "train")) \
        == policy.TERMINAL
    assert policy.classify(guard.BadInput("x")) == policy.TERMINAL
    assert policy.classify(guard.NotInvertible("x")) == policy.TERMINAL
    assert policy.classify(ValueError("x")) == policy.TERMINAL


def _virtual_clock():
    t = [0.0]
    slept = []

    def sleep(d):
        slept.append(d)
        t[0] += d

    return (lambda: t[0]), sleep, slept


@pytest.mark.tier1
def test_policy_retries_transient_then_succeeds():
    calls = {"n": 0}

    def flaky():
        calls["n"] += 1
        if calls["n"] < 3:
            raise guard.CachePoisoned("transient")
        return 42

    clock, sleep, slept = _virtual_clock()
    pol = policy.RetryPolicy(max_retries=2, seed=1)
    res = policy.run_with_policy(flaky, policy=pol, request_id=9,
                                 clock=clock, sleep=sleep)
    assert res.ok and res.value == 42
    assert res.attempts == 3 and res.retries == 2
    assert slept == [pol.delay_s(0, 9), pol.delay_s(1, 9)]
    assert resilience.stats()["retries"] == 2


@pytest.mark.tier1
def test_policy_terminal_errors_never_retry():
    calls = {"n": 0}

    def bad():
        calls["n"] += 1
        raise guard.BadInput("malformed request")

    clock, sleep, slept = _virtual_clock()
    res = policy.run_with_policy(bad, clock=clock, sleep=sleep)
    assert res.outcome == "error" and res.error_class == policy.TERMINAL
    assert calls["n"] == 1 and slept == []


@pytest.mark.tier1
def test_policy_exhausted_retries_return_structured_error():
    def always():
        raise guard.GuardTrap(("parity",), "pallas")

    clock, sleep, _ = _virtual_clock()
    res = policy.run_with_policy(
        always, policy=policy.RetryPolicy(max_retries=2),
        clock=clock, sleep=sleep)
    assert res.outcome == "error" and res.error_class == policy.RETRYABLE
    assert res.attempts == 3
    assert "GuardTrap" in res.describe()


@pytest.mark.tier1
def test_policy_never_sleeps_into_a_guaranteed_timeout():
    def always():
        raise guard.CachePoisoned("transient")

    clock, sleep, slept = _virtual_clock()
    pol = policy.RetryPolicy(max_retries=5, base_delay_s=10.0,
                             max_delay_s=10.0, jitter=0.0)
    res = policy.run_with_policy(always, policy=pol, deadline_s=1.0,
                                 clock=clock, sleep=sleep)
    assert res.outcome == "deadline" and slept == []
    assert isinstance(res.error, resilience.DeadlineExceeded)
    assert resilience.stats()["deadline_exceeded"] == 1


@pytest.mark.tier1
def test_policy_deadline_checked_between_attempts():
    clock, sleep, _ = _virtual_clock()

    def slow():
        sleep(2.0)                          # attempt burns the budget
        raise guard.CachePoisoned("transient")

    res = policy.run_with_policy(
        slow, policy=policy.RetryPolicy(max_retries=3, jitter=0.0),
        deadline_s=1.0, clock=clock, sleep=sleep)
    assert res.outcome == "deadline" and res.attempts == 1


# ---------------------------------------------------------------------------
# admission control
# ---------------------------------------------------------------------------

@pytest.mark.tier1
def test_admission_queue_depth_bound_and_release():
    q = policy.AdmissionQueue(max_depth=2)
    assert q.admit() and q.admit()
    assert not q.admit()                   # full -> shed
    assert q.shed == 1 and resilience.stats()["shed"] == 1
    q.complete(0.1)
    assert q.admit() and q.depth == 2


@pytest.mark.tier1
def test_admission_queue_sheds_doomed_backlog():
    # 0.6s/request observed; a 2nd concurrent request could not drain
    # inside the 1s deadline -> shed at admission, not timed out later
    q = policy.AdmissionQueue(max_depth=10, deadline_s=1.0,
                              est_latency_s=0.6)
    assert q.admit()
    assert not q.admit()
    q.complete(0.2)                        # EWMA drops the estimate
    assert q.est_latency_s < 0.6
    assert q.admit()


# ---------------------------------------------------------------------------
# train-step retry integration
# ---------------------------------------------------------------------------

@pytest.mark.tier1
def test_train_step_retries_transient_trap_then_succeeds():
    from repro.train.step import _guard_step

    calls = {"n": 0}

    def step(p, o, b):
        calls["n"] += 1
        if calls["n"] == 1:
            raise guard.CachePoisoned("poisoned plan cache")
        return p, o, {"loss": jnp.float32(1.0),
                      "grad_norm": jnp.float32(0.5)}

    out = _guard_step(step, trap_retries=1)(1, 2, {})
    assert calls["n"] == 2 and float(out[2]["loss"]) == 1.0
    assert resilience.stats()["retries"] == 1


@pytest.mark.tier1
def test_train_step_nonfinite_is_terminal_not_retried():
    from repro.train.step import _guard_step

    calls = {"n": 0}

    def step(p, o, b):
        calls["n"] += 1
        return p, o, {"loss": jnp.float32(np.nan),
                      "grad_norm": jnp.float32(1.0)}

    with pytest.raises(guard.GuardTrap):
        _guard_step(step, trap_retries=3)(1, 2, {})
    assert calls["n"] == 1                 # health check is outside the
    assert resilience.stats()["retries"] == 0   # retry loop by design
    assert guard.stats()["traps"].get(("nonfinite", "train"), 0) == 1


# ---------------------------------------------------------------------------
# chaos soak (live guarded request loop + scheduled injectors)
# ---------------------------------------------------------------------------

@pytest.mark.tier1
def test_chaos_soak_pallas_memory_fault_holds_slos():
    rep = chaos.soak(engine="pallas", fault="poison_plan", requests=32,
                     window=(8, 16), threshold=2, cooldown=4)
    assert rep.passed, rep.slo_violations
    assert rep.silent_wrong == 0
    assert rep.faults_injected == 8
    assert rep.faults_caught == rep.faults_injected
    # the breaker arc happened: open -> shunted ref service -> probe ->
    # close, and while open the per-call trap cost was verifiably zero
    assert rep.breaker["open"] >= 1 and rep.breaker["close"] >= 1
    assert rep.shunted > 0 and rep.traps_while_open == 0
    assert rep.recovery_requests is not None
    assert rep.recovery_requests <= rep.recovery_k


@pytest.mark.tier1
def test_chaos_soak_disk_fault_quarantines_and_recovers():
    rep = chaos.soak(engine="pallas", fault="disk_bitflip", requests=14,
                     window=(6, 8), threshold=2, cooldown=4)
    assert rep.passed, rep.slo_violations
    assert rep.silent_wrong == 0 and rep.errors == 0
    assert rep.detected >= 1               # quarantine caught the flip
    assert rep.breaker["open"] == 0        # plan-load healing; the
    # breaker never needed to engage


@pytest.mark.slow
def test_chaos_full_matrix_passes():
    reports = chaos.run_matrix()
    assert len(reports) == 4
    bad = [r.summary() for r in reports if not r.passed]
    assert not bad, bad


# ---------------------------------------------------------------------------
# serve.py drain contract
# ---------------------------------------------------------------------------

@pytest.mark.tier1
def test_serve_tokens_1_reports_na_throughput(capsys):
    from repro.launch import serve

    gen = serve.main(["--arch", "mistral-nemo-12b", "--batch", "1",
                      "--prompt-len", "4", "--tokens", "1"])
    out = capsys.readouterr().out
    assert gen.shape == (1, 1)
    assert "n/a tok/s" in out
    assert "resilience: requests=1" in out


@pytest.mark.slow
def test_serve_sigterm_drains_gracefully():
    drill = chaos.sigterm_drill()
    assert drill["started"], drill["output"][-2000:]
    assert drill["ok"], drill["output"][-2000:]
