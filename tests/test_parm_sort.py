"""parm combinator (§7.2) and the sorting network (§7.1)."""
import random

import jax.numpy as jnp
import numpy as np
import pytest
from _hyp_compat import given, settings, strategies as st

from repro.core import f2
from repro.core.bmmc import Bmmc
from repro.core.parm import lsb, parm, parm_matrix, parm_ref
from repro.core.sort import (compile_sort, fuse, num_perm_stages, run_stages,
                             sort_compiled, sort_rec)
from repro.kernels.ops import bmmc_permute


def test_parm_matrix_paper_fig13():
    """mask = 0b110 on 3 bits: the matrix of paper Fig. 13b."""
    a = parm_matrix(3, 0b110)
    assert a.rows == (0b001, 0b100, 0b110)
    # sub-array assignments from Fig. 13a
    want_sub = [0, 0, 1, 1, 1, 1, 0, 0]
    for x in range(8):
        assert (a.apply(x) >> 2) == want_sub[x]


def test_parm_matrix_paper_section3():
    """parm 0b0011 example from §3."""
    a = parm_matrix(4, 0b0011)
    assert a.rows == (0b0010, 0b0100, 0b1000, 0b0011)


@given(st.integers(2, 8), st.integers(0, 10**6))
@settings(max_examples=50, deadline=None)
def test_parm_matrix_invertible_and_semantics(n, seed):
    rng = random.Random(seed)
    mask = rng.randrange(1, 1 << n)
    a = parm_matrix(n, mask)  # constructor asserts invertibility
    half = 1 << (n - 1)
    for x in (0, 1, (1 << n) - 1, rng.randrange(1 << n)):
        y = a.apply(x)
        sub = bin(x & mask).count("1") & 1
        assert (y >= half) == bool(sub)          # sub-array bit on top
        # sub-index: drop the lsb(mask) bit of x
        l = lsb(mask)
        sub_idx = (x & ((1 << l) - 1)) | ((x >> (l + 1)) << l)
        assert (y & (half - 1)) == sub_idx


@given(st.integers(2, 7), st.integers(0, 10**6))
@settings(max_examples=40, deadline=None)
def test_parm_bmmc_equals_direct(n, seed):
    rng = random.Random(seed)
    mask = rng.randrange(1, 1 << n)
    xs = np.random.default_rng(seed).integers(0, 100, size=1 << n).astype(np.int32)
    want = parm_ref(mask, lambda h: h[::-1], xs)
    got = np.asarray(parm(mask, lambda h: h[::-1], jnp.asarray(xs)))
    assert np.array_equal(want, got)


def test_parm_with_pallas_engine():
    """parm compiled through the tiled Pallas kernels end-to-end."""
    n, mask = 8, 0b0110
    xs = jnp.arange(1 << n, dtype=jnp.float32)
    engine = lambda x, b: bmmc_permute(x, b, t=3)
    got = np.asarray(parm(mask, lambda h: h[::-1], xs, engine=engine))
    want = parm_ref(mask, lambda h: h[::-1], np.asarray(xs))
    assert np.array_equal(got, want)


@pytest.mark.parametrize("n", [1, 2, 3, 4, 5, 6])
def test_sort_recursion(n):
    xs = np.random.default_rng(n).integers(0, 1000, size=1 << n).astype(np.int32)
    assert np.array_equal(sort_rec(n, xs.copy()), np.sort(xs))


@pytest.mark.parametrize("n", [1, 3, 5, 7])
def test_sort_compiled(n):
    xs = np.random.default_rng(n + 50).integers(0, 1000, size=1 << n).astype(np.int32)
    got = np.asarray(sort_compiled(jnp.asarray(xs)))
    assert np.array_equal(got, np.sort(xs))


def test_sort_compiled_with_pallas_engine():
    n = 7
    xs = np.random.default_rng(7).integers(0, 1000, size=1 << n).astype(np.int32)
    engine = lambda x, b: bmmc_permute(x, b, t=3)
    got = np.asarray(sort_compiled(jnp.asarray(xs), engine=engine))
    assert np.array_equal(got, np.sort(xs))


def test_fusion_reduces_perm_stages():
    """The §7.2 rewrite algebra: fused program is drastically shorter."""
    raw = compile_sort(6)
    fz = fuse(raw)
    assert num_perm_stages(fz) < num_perm_stages(raw) / 5
    # fused program still sorts
    xs = np.random.default_rng(0).integers(0, 99, size=64).astype(np.int32)
    got = np.asarray(run_stages(fz, jnp.asarray(xs)))
    assert np.array_equal(got, np.sort(xs))


@given(st.lists(st.integers(-1000, 1000), min_size=16, max_size=16))
@settings(max_examples=30, deadline=None)
def test_sort_property(values):
    xs = np.asarray(values, dtype=np.int32)
    assert np.array_equal(sort_rec(4, xs.copy()), np.sort(xs))
    assert np.array_equal(np.asarray(sort_compiled(jnp.asarray(xs))), np.sort(xs))
