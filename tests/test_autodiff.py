"""Gradcheck: jax.grad through compiled combinator programs.

The executor's ``Perm`` stages carry a custom VJP that routes cotangents
through the offline-inverted program (DESIGN.md §9). These tests pin it
three ways: against the inverse-permutation oracle (the VJP of a pure
permutation program *is* the inverse program), against finite
differences, and pallas-engine against ref-engine on sort / FFT / vocab
programs — including inside a full training step (grads + AdamW).
"""
import random

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.combinators import (compile_expr, inverse_program, perm_apply,
                               run_program, vocab as V)
from repro.combinators.fft import compiled_fft, to_planar
from repro.combinators.sort import compiled_sort
from repro.core.bmmc import Bmmc

ENGINES = ("ref", "pallas")


def _x(n, seed, shape=()):
    return jnp.asarray(np.random.default_rng(seed).normal(
        size=shape + (1 << n,)).astype(np.float32))


def _fd_check(loss, x, grad, idx, eps=1e-3, tol=2e-2):
    """Central-difference spot check of ``grad`` at flat positions idx."""
    flat = np.asarray(x).reshape(-1)
    g = np.asarray(grad).reshape(-1)
    for i in idx:
        e = np.zeros_like(flat)
        e[i] = eps
        up = loss(jnp.asarray((flat + e).reshape(x.shape)))
        dn = loss(jnp.asarray((flat - e).reshape(x.shape)))
        fd = (float(up) - float(dn)) / (2 * eps)
        assert abs(fd - g[i]) <= tol * max(1.0, abs(fd)), (i, fd, g[i])


# ---------------------------------------------------------------------------
# perm_apply: the inverse-permutation oracle
# ---------------------------------------------------------------------------

@pytest.mark.tier1
@pytest.mark.parametrize("engine", ENGINES)
def test_perm_grad_is_inverse_permutation(engine):
    """d/dx sum(w * P(x)) == P^-1(w), exactly, on both engines."""
    n = 8
    rng = random.Random(0)
    for trial in range(3):
        b = Bmmc.random(n, rng) if trial % 2 else Bmmc.random_bpc(n, rng)
        x, w = _x(n, trial), _x(n, 100 + trial)
        g = jax.grad(lambda x: jnp.sum(w * perm_apply(x, b, engine)))(x)
        oracle = perm_apply(w, b.inverse(), "ref")
        assert np.array_equal(np.asarray(g), np.asarray(oracle)), (engine, trial)


@pytest.mark.tier1
def test_compiled_program_vjp_is_inverse_program():
    """grad through a fused multi-stage permutation program == the
    offline-inverted program applied to the cotangent."""
    n = 9
    e = (V.bit_reverse(n) >> V.parm(0b1011, V.rev(n - 1))
         >> V.perm(Bmmc.random(n, random.Random(2))) >> V.riffle(n))
    for engine in ENGINES:
        f = compile_expr(e, engine=engine)
        prog = f.program(n)
        w = _x(n, 3)
        g = jax.grad(lambda x: jnp.sum(w * f(x)))(_x(n, 4))
        oracle = run_program(inverse_program(prog), w, "ref")
        assert np.array_equal(np.asarray(g), np.asarray(oracle)), engine
        assert f.vjp_program(n) == inverse_program(prog)


@pytest.mark.tier1
def test_batched_grad_matches_per_row():
    n = 8
    e = V.perm(Bmmc.random(n, random.Random(5))) >> V.rev(n)
    f = compile_expr(e, engine="pallas")
    xb = _x(n, 6, shape=(3,))
    loss_b = lambda x: jnp.sum(jnp.cos(f(x, batched=True)))
    gb = jax.grad(loss_b)(xb)
    for i in range(3):
        gi = jax.grad(lambda x: jnp.sum(jnp.cos(f(x))))(xb[i])
        assert np.allclose(np.asarray(gb[i]), np.asarray(gi), atol=1e-6)


# ---------------------------------------------------------------------------
# Workload programs: sort / FFT / vocab, pallas vs ref + finite differences
# ---------------------------------------------------------------------------

@pytest.mark.tier1
def test_sort_grad_engines_agree_and_fd():
    """Sorting networks are piecewise-linear; grads route to the argsort.
    ISSUE 2 acceptance: pallas grad == ref grad to 1e-5."""
    n = 6
    x = _x(n, 7)
    w = _x(n, 8)
    grads = {}
    for engine in ENGINES:
        f = compiled_sort(n, engine=engine)
        loss = lambda x, f=f: jnp.sum(w * f(x))
        grads[engine] = np.asarray(jax.grad(loss)(x))
        _fd_check(loss, x, grads[engine], idx=[0, 5, 31, 63])
    assert np.allclose(grads["pallas"], grads["ref"], atol=1e-5)
    # oracle: d sum(w*sort(x)) / dx_i = w at x_i's sorted position
    order = np.argsort(np.asarray(x), kind="stable")
    want = np.empty_like(np.asarray(w))
    want[order] = np.asarray(w)
    assert np.allclose(grads["ref"], want, atol=1e-6)


@pytest.mark.tier1
def test_fft_grad_engines_agree_and_fd():
    """Planar (re,im) FFT: linear map, so grads are engine-exact."""
    n = 6
    x = to_planar(np.random.default_rng(9).normal(size=1 << n)
                  + 1j * np.random.default_rng(10).normal(size=1 << n))
    w = jnp.asarray(np.random.default_rng(11).normal(
        size=(1 << n, 2)).astype(np.float32))
    grads = {}
    for engine in ENGINES:
        f = compiled_fft(n, engine=engine)
        loss = lambda x, f=f: jnp.sum(w * f(x))
        grads[engine] = np.asarray(jax.grad(loss)(x))
        _fd_check(loss, x, grads[engine], idx=[0, 17, 64, 127], eps=1e-2)
    assert np.allclose(grads["pallas"], grads["ref"], atol=1e-5)


@pytest.mark.tier1
def test_vocab_program_grads_fd():
    """A mixed vocab program (perm + emap nonlinearity) gradchecks."""
    n = 7
    e = (V.riffle(n) >> V.emap("tanh", jnp.tanh) >> V.bit_reverse(n)
         >> V.emap("sq", lambda v: v * v))
    x = _x(n, 12)
    grads = {}
    for engine in ENGINES:
        f = compile_expr(e, engine=engine)
        loss = lambda x, f=f: jnp.sum(f(x))
        grads[engine] = np.asarray(jax.grad(loss)(x))
        _fd_check(loss, x, grads[engine], idx=[1, 40, 100])
    assert np.allclose(grads["pallas"], grads["ref"], atol=1e-5)


# ---------------------------------------------------------------------------
# Model / train-step integration
# ---------------------------------------------------------------------------

@pytest.mark.tier1
def test_attention_head_shuffle_grads_match():
    """Head shuffle is neutral in value AND in gradients."""
    from repro.models.attention import attention, default_head_perm
    key = jax.random.PRNGKey(0)
    q = jax.random.normal(key, (2, 8, 8, 4), jnp.float32)
    k = jax.random.normal(jax.random.PRNGKey(1), (2, 8, 4, 4), jnp.float32)
    v = jax.random.normal(jax.random.PRNGKey(2), (2, 8, 4, 4), jnp.float32)
    hp = default_head_perm(4)

    def loss(q, hp):
        return jnp.sum(attention(q, k, v, head_perm=hp) ** 2)

    g0 = jax.grad(lambda q: loss(q, None))(q)
    g1 = jax.grad(lambda q: loss(q, hp))(q)
    assert np.allclose(np.asarray(g0), np.asarray(g1), atol=1e-6)


@pytest.mark.tier1
def test_train_step_grad_through_pallas_permute():
    """ISSUE 2 tentpole: jax.grad through a pallas BMMC permute inside a
    real training step (loss -> grads -> AdamW update), matching the
    ref-engine oracle step bit-for-bit in its metrics."""
    from repro.configs import ARCHS, reduce_for_smoke
    from repro.models.permute import PermuteLayer
    from repro.train.step import make_train_step
    from repro.optim.adamw import AdamWConfig, adamw_init

    n = 10
    bmmc = Bmmc.random(n, random.Random(21))
    cfg = reduce_for_smoke(ARCHS["mistral-nemo-12b"])
    params = {"w": _x(n, 22)}
    batch = {"x": _x(n, 23, shape=(4,)), "y": _x(n, 24, shape=(4,))}

    def make_loss(engine):
        layer = PermuteLayer(bmmc, axis=1, engine=engine)

        def loss_fn(params, batch):
            pred = layer(batch["x"] * params["w"])
            l = jnp.mean((pred - batch["y"]) ** 2)
            return l, {"mse": l}
        return loss_fn

    metrics = {}
    new_w = {}
    for engine in ENGINES:
        step_fn, opt_cfg = make_train_step(
            cfg, opt_cfg=AdamWConfig(), loss_fn=make_loss(engine))
        opt_state = adamw_init(params, opt_cfg)
        new_params, _, m = jax.jit(step_fn)(params, opt_state, batch)
        assert np.isfinite(float(m["loss"]))
        assert float(m["grad_norm"]) > 0
        metrics[engine] = m
        new_w[engine] = np.asarray(new_params["w"])
        assert not np.array_equal(new_w[engine], np.asarray(params["w"]))
    assert np.allclose(metrics["pallas"]["grad_norm"],
                       metrics["ref"]["grad_norm"], rtol=1e-6)
    assert np.allclose(new_w["pallas"], new_w["ref"], atol=1e-6)


@pytest.mark.tier1
def test_train_step_loss_override_with_grad_accum():
    """A custom (tokens-free) loss works under gradient accumulation and
    matches the unaccumulated grads."""
    from repro.configs import ARCHS, reduce_for_smoke
    from repro.models.permute import PermuteLayer
    from repro.train.step import make_train_step
    from repro.optim.adamw import AdamWConfig, adamw_init

    n = 8
    layer = PermuteLayer(Bmmc.random(n, random.Random(31)), axis=1,
                         engine="ref")
    cfg = reduce_for_smoke(ARCHS["mistral-nemo-12b"])
    params = {"w": _x(n, 32)}
    batch = {"x": _x(n, 33, shape=(4,)), "y": _x(n, 34, shape=(4,))}

    def loss_fn(params, batch):
        l = jnp.mean((layer(batch["x"] * params["w"]) - batch["y"]) ** 2)
        return l, {"mse": l}

    outs = {}
    for accum in (1, 2):
        step_fn, opt_cfg = make_train_step(cfg, opt_cfg=AdamWConfig(),
                                           grad_accum=accum, loss_fn=loss_fn)
        new_params, _, m = jax.jit(step_fn)(
            params, adamw_init(params, opt_cfg), batch)
        assert np.isfinite(float(m["loss"]))
        outs[accum] = np.asarray(new_params["w"])
    assert np.allclose(outs[1], outs[2], atol=1e-6)


@pytest.mark.tier1
def test_model_train_step_with_head_shuffle_cfg():
    """The cfg knob: a smoke-arch train step with head_shuffle on yields
    the same loss as off, and finite grads (perm VJP inside the stack)."""
    import dataclasses
    from repro.configs import ARCHS, reduce_for_smoke
    from repro.models import model as M
    from repro.train.step import make_train_step, init_opt

    key = jax.random.PRNGKey(3)
    cfg0 = reduce_for_smoke(ARCHS["mistral-nemo-12b"])
    cfg1 = dataclasses.replace(cfg0, head_shuffle="ref")
    params = M.init(cfg1, key)
    batch = {"tokens": jax.random.randint(key, (2, 16), 0, cfg1.vocab_size),
             "labels": jax.random.randint(key, (2, 16), 0, cfg1.vocab_size)}
    l0, _ = M.loss_fn(cfg0, params, batch)
    l1, _ = M.loss_fn(cfg1, params, batch)
    assert np.array_equal(np.asarray(l0), np.asarray(l1))
    step_fn, _ = make_train_step(cfg1)
    _, _, m = jax.jit(step_fn)(params, init_opt(cfg1, params), batch)
    assert np.isfinite(float(m["loss"]))
    assert np.isfinite(float(m["grad_norm"]))
