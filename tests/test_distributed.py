"""Distributed BMMC: offline plan verification + on-device executor.

The executor test runs in a subprocess with 16 fake CPU devices (device
count is locked at first jax import in the main pytest process).
"""
import random
import subprocess
import sys

import pytest
from _hyp_compat import given, settings, strategies as st

from repro.core.bmmc import Bmmc
from repro.core.distributed import make_plan, plan_cost, plan_to_bmmc


@given(st.integers(5, 12), st.integers(1, 4), st.integers(0, 10**6),
       st.booleans())
@settings(max_examples=60, deadline=None)
def test_plan_composes_to_bmmc(n, s, seed, bpc):
    """Offline: rounds compose exactly back to the global BMMC."""
    if s >= n - 1:
        return
    rng = random.Random(seed)
    b = Bmmc.random_bpc(n, rng) if bpc else Bmmc.random(n, rng)
    plan = make_plan(b, s)  # internal assert: plan_to_bmmc(plan) == b
    got = plan_to_bmmc(plan, n, s)
    assert got.rows == b.rows and got.c == b.c


@given(st.integers(5, 12), st.integers(1, 4), st.integers(0, 10**6))
@settings(max_examples=40, deadline=None)
def test_two_exchange_round_bound(n, s, seed):
    """Sharded analogue of paper §5.2: <= 2 exchange (all-to-all) rounds."""
    if s >= n - 1:
        return
    b = Bmmc.random(n, random.Random(seed))
    cost = plan_cost(make_plan(b, s))
    assert cost["exchange"] <= 2
    assert cost["permute"] <= 6


def test_separable_needs_no_exchange():
    """Shard-separable BMMCs (A_sl = 0) need zero all-to-all rounds."""
    # pure local permutation + shard relabel
    n, s = 10, 3
    rng = random.Random(0)
    local = Bmmc.random(n - s, rng)
    rows = tuple(local.rows) + tuple(1 << i for i in range(n - s, n))
    b = Bmmc(rows, 5)
    cost = plan_cost(make_plan(b, s))
    assert cost["exchange"] == 0 and cost["permute"] <= 1


EXEC_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=16"
import random
import numpy as np, jax.numpy as jnp
from repro.core.bmmc import Bmmc
from repro.core.distributed import distributed_bmmc, binary_mesh
from repro.kernels.ref import bmmc_ref

rng = random.Random(1)
for s in (2, 4):
    mesh = binary_mesh(s)
    for n in (s + 2, s + 5):
        for trial in range(3):
            b = Bmmc.random(n, rng) if trial % 2 else Bmmc.random_bpc(n, rng)
            x = jnp.arange(1 << n, dtype=jnp.float32)
            got = np.asarray(distributed_bmmc(x, b, s, mesh))
            want = np.asarray(bmmc_ref(x, b))
            assert np.array_equal(got, want), (n, s, trial)
print("OK")
"""


@pytest.mark.slow
def test_executor_on_fake_devices():
    out = subprocess.run(
        [sys.executable, "-c", EXEC_SCRIPT], capture_output=True, text=True,
        # JAX_PLATFORMS=cpu: the fake devices are host-platform shards;
        # without it a scrubbed env lets jax probe real accelerator
        # backends (a baked-in libtpu stalls ~8 min) and the probe
        # alone blows the timeout
        env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin", "HOME": "/root",
             "JAX_PLATFORMS": "cpu"},
        timeout=500)
    assert out.returncode == 0, out.stderr[-3000:]
    assert "OK" in out.stdout
