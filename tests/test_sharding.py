"""Sharding rules: divisibility guards, spec construction (1-device mesh
semantics only — multi-device behaviour is exercised by the dry-run)."""
import jax
import pytest
from jax.sharding import PartitionSpec as P

from repro.parallel.sharding import batch_spec, dp_axes, spec_for


class FakeMesh:
    """Duck-typed mesh: only .axis_names and .shape are consulted."""
    def __init__(self, shape):
        self.shape = dict(shape)
        self.axis_names = tuple(shape)


MESH = FakeMesh({"data": 16, "model": 16})
MESH3 = FakeMesh({"pod": 2, "data": 16, "model": 16})


def test_vocab_sharded_when_divisible():
    s = spec_for(MESH, ("vocab", "embed"), (49152, 4608))
    assert s == P("model", "data")


def test_divisibility_guard_falls_back():
    # 50280 % 16 != 0 -> vocab replicated; 36 heads % 16 != 0 -> replicated
    s = spec_for(MESH, ("vocab", "embed"), (50280, 768))
    assert s[0] is None
    s2 = spec_for(MESH, ("embed", "heads", "head_dim"), (4608, 36, 128))
    assert s2 == P("data", None, None)


def test_each_axis_used_once():
    # experts takes model; mlp would also want model -> replicated
    s = spec_for(MESH, ("experts", "embed", "mlp"), (384, 7168, 2048))
    assert s == P("model", "data", None)


def test_pod_composes_with_data():
    s = spec_for(MESH3, ("embed", "mlp"), (8192, 28672))
    assert s == P(("pod", "data"), "model")
    assert dp_axes(MESH3) == ("pod", "data")


def test_seq_kv_cache_rule():
    s = spec_for(MESH, ("batch", "seq_kv", "kv_heads", None),
                 (128, 32768, 8, 128))
    # kv=8 cannot take model (16); sequence carries it (SP)
    assert s == P("data", "model", None, None)


def test_batch_spec_guard():
    assert batch_spec(MESH, 256, 2) == P("data", None)
    assert batch_spec(MESH, 1, 2) == P(None, None)  # long_500k batch=1
    assert batch_spec(MESH3, 256, 3) == P(("pod", "data"), None, None)
