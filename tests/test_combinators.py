"""Combinator IR: vocabulary semantics, algebraic laws, optimizer, executor."""
import random

import jax.numpy as jnp
import numpy as np
import pytest
from _hyp_compat import given, settings, strategies as st

from repro.combinators import (compile_expr, fuse, lower, num_perm_stages,
                               run_program, vocab as V)
from repro.combinators.execute import get_engine, register_engine
from repro.combinators.ir import Bfly, CmpHalves, Id, Map, Perm, Seq, seq
from repro.combinators.optimize import program_cost
from repro.combinators.sort import compiled_sort, sort_expr
from repro.core.bmmc import Bmmc
from repro.core.parm import parm_ref


def run_ref(expr, n, xs):
    return np.asarray(run_program(lower(expr, n), jnp.asarray(xs), "ref"))


# ---------------------------------------------------------------------------
# Vocabulary semantics vs numpy oracles
# ---------------------------------------------------------------------------

def test_riffle_is_perfect_shuffle():
    n = 4
    xs = np.arange(1 << n, dtype=np.int32)
    got = run_ref(V.riffle(n), n, xs)
    h = 1 << (n - 1)
    want = np.empty_like(xs)
    want[0::2], want[1::2] = xs[:h], xs[h:]
    assert np.array_equal(got, want)


def test_unriffle_and_evens_odds():
    n = 5
    xs = np.arange(1 << n, dtype=np.int32)
    want = np.concatenate([xs[0::2], xs[1::2]])
    assert np.array_equal(run_ref(V.unriffle(n), n, xs), want)
    assert np.array_equal(run_ref(V.evens_odds(n), n, xs), want)


def test_interleave_alias():
    assert V.interleave(6) == V.riffle(6)


def test_rev_reverses():
    n = 6
    xs = np.arange(1 << n, dtype=np.int32)
    assert np.array_equal(run_ref(V.rev(n), n, xs), xs[::-1])


def test_transpose_matches_numpy():
    rb, cb = 3, 4
    xs = np.arange(1 << (rb + cb), dtype=np.int32)
    got = run_ref(V.transpose(rb, cb), rb + cb, xs)
    want = xs.reshape(1 << rb, 1 << cb).T.reshape(-1)
    assert np.array_equal(got, want)


def test_stride_permute_gathers_with_stride():
    n, k = 6, 2
    xs = np.arange(1 << n, dtype=np.int32)
    got = run_ref(V.stride_permute(n, k), n, xs)
    # out visits x at stride 2^k: out[c * 2^(n-k) + r] = x[r * 2^k + c]
    want = xs.reshape(1 << (n - k), 1 << k).T.reshape(-1)
    assert np.array_equal(got, want)
    assert V.stride_permute(n, 1) == V.unriffle(n)
    assert V.stride_permute(n, n - 1) == V.riffle(n)


@given(st.integers(2, 7), st.integers(0, 10**6))
@settings(max_examples=25, deadline=None)
def test_parm_combinator_matches_parm_ref(n, seed):
    rng = random.Random(seed)
    mask = rng.randrange(1, 1 << n)
    xs = np.random.default_rng(seed).integers(0, 100, 1 << n).astype(np.int32)
    e = V.parm(mask, V.rev(n - 1))
    want = parm_ref(mask, lambda h: h[::-1], xs)
    assert np.array_equal(run_ref(e, n, xs), want)


def test_two_and_ilv_lifts():
    n = 5
    xs = np.arange(1 << n, dtype=np.int32)
    h = 1 << (n - 1)
    got = run_ref(V.two(V.rev(n - 1)), n, xs)
    want = np.concatenate([xs[:h][::-1], xs[h:][::-1]])
    assert np.array_equal(got, want)
    got = run_ref(V.ilv(V.rev(n - 1)), n, xs)
    want = parm_ref(1, lambda s: s[::-1], xs)
    assert np.array_equal(got, want)


def test_emap_applies_elementwise_through_lifts():
    n = 4
    xs = np.arange(1 << n, dtype=np.int32)
    e = V.two(V.ilv(V.emap("double", lambda x: x * 2)))
    assert np.array_equal(run_ref(e, n, xs), xs * 2)


# ---------------------------------------------------------------------------
# Algebraic laws / optimizer properties
# ---------------------------------------------------------------------------

def test_riffle_unriffle_cancels_to_identity():
    n = 8
    assert fuse(lower(V.riffle(n) >> V.unriffle(n), n)) == ()
    assert fuse(lower(V.unriffle(n) >> V.riffle(n), n)) == ()


def test_perm_inverse_cancels():
    b = Bmmc.random(7, random.Random(0))
    e = V.perm(b) >> V.perm(b.inverse())
    assert fuse(lower(e, 7)) == ()


@given(st.integers(3, 8), st.integers(0, 10**6), st.integers(2, 6))
@settings(max_examples=20, deadline=None)
def test_fusion_preserves_semantics(n, seed, depth):
    """Fused program == unfused oracle on a random perm/cmp expression."""
    rng = random.Random(seed)
    parts = []
    for _ in range(depth):
        r = rng.random()
        if r < 0.5:
            parts.append(V.perm(Bmmc.random_bpc(n, rng)))
        elif r < 0.75:
            parts.append(V.perm(Bmmc.random(n, rng)))
        else:
            parts.append(V.cmp_halves())
    e = seq(*parts)
    raw = lower(e, n)
    fz = fuse(raw)
    xs = np.random.default_rng(seed).integers(0, 1000, 1 << n).astype(np.int32)
    got_raw = np.asarray(run_program(raw, jnp.asarray(xs), "ref"))
    got_fz = np.asarray(run_program(fz, jnp.asarray(xs), "ref"))
    assert np.array_equal(got_raw, got_fz)
    assert num_perm_stages(fz) <= num_perm_stages(raw)


@given(st.integers(4, 9), st.integers(0, 10**6))
@settings(max_examples=10, deadline=None)
def test_optimizer_never_increases_pass_count(n, seed):
    """Tiled pass count (the §5.2 cost) of fused <= unfused — always."""
    rng = random.Random(seed)
    e = seq(V.parm(rng.randrange(1, 1 << n), V.rev(n - 1)),
            V.riffle(n), V.perm(Bmmc.random(n, rng)), V.bit_reverse(n))
    t = max(2, n // 3)
    raw_cost = program_cost(lower(e, n), t)
    fz_cost = program_cost(fuse(lower(e, n)), t)
    assert fz_cost["tiled_passes"] <= raw_cost["tiled_passes"]
    assert fz_cost["perm_stages"] <= raw_cost["perm_stages"]


def test_seq_flattens_and_drops_id():
    a, b = V.bit_reverse(4), V.rev(4)
    assert seq(a, Id(), b) == Seq((a, b))
    assert seq(Id(), Id()) == Id()
    assert seq(a) == a
    assert (a >> b) == Seq((a, b))


# ---------------------------------------------------------------------------
# Executor: engines, caching
# ---------------------------------------------------------------------------

def test_engine_registry_and_custom_engine():
    calls = []

    def counting_engine(x, bmmc):
        calls.append(bmmc)
        return get_engine("ref")(x, bmmc)

    n = 6
    xs = jnp.arange(1 << n, dtype=jnp.int32)
    e = V.riffle(n) >> V.bit_reverse(n)
    got = np.asarray(run_program(fuse(lower(e, n)), xs, counting_engine))
    want = np.asarray(run_program(lower(e, n), xs, "ref"))
    assert np.array_equal(got, want)
    assert len(calls) == 1  # fused into a single Perm stage

    register_engine("counting-test", counting_engine)
    assert get_engine("counting-test") is counting_engine


def test_compile_expr_cache_returns_same_object():
    e = V.riffle(8) >> V.unriffle(8)
    f1 = compile_expr(e, engine="ref")
    f2 = compile_expr(e, engine="ref")
    assert f1 is f2
    f3 = compile_expr(e, engine="pallas")
    assert f3 is not f1


def test_compiled_expr_pallas_matches_ref():
    n = 9
    e = V.bit_reverse(n) >> V.parm(0b101, V.rev(n - 1)) >> V.riffle(n)
    xs = jnp.arange(1 << n, dtype=jnp.float32)
    got = np.asarray(compile_expr(e, engine="pallas")(xs))
    want = np.asarray(compile_expr(e, engine="ref")(xs))
    assert np.array_equal(got, want)


def test_compiled_expr_rejects_bad_length():
    f = compile_expr(V.rev(4), engine="ref")
    with pytest.raises(ValueError):
        f(jnp.arange(24.0))


# ---------------------------------------------------------------------------
# End-to-end sort acceptance (ISSUE 1): 2^12 through the pallas engine
# ---------------------------------------------------------------------------

def test_sort_expr_small_all_sizes():
    for n in range(0, 7):
        xs = np.random.default_rng(n).integers(0, 997, 1 << n).astype(np.int32)
        got = np.asarray(compiled_sort(n, engine="ref")(jnp.asarray(xs)))
        assert np.array_equal(got, np.sort(xs)), n


def test_sort_fusion_strictly_reduces_perm_stages():
    n = 12
    raw = lower(sort_expr(n), n)
    fz = fuse(raw)
    assert num_perm_stages(fz) < num_perm_stages(raw)
    # exactly one fused BMMC between consecutive compare-exchange sweeps
    kinds = [type(s).__name__ for s in fz]
    assert "Perm Perm" not in " ".join(kinds)


@pytest.mark.slow
def test_sort_2pow12_through_pallas_engine():
    """ISSUE 1 acceptance: compiled balanced-periodic sort on 2^12 elements
    matches np.sort and executes through the pallas engine."""
    n = 12
    xs = np.random.default_rng(0).integers(0, 1 << 30, 1 << n).astype(np.int32)
    f = compiled_sort(n, engine="pallas")
    got = np.asarray(f(jnp.asarray(xs)))
    assert np.array_equal(got, np.sort(xs))
