"""Fallback shim for ``hypothesis`` so tier-1 collection never breaks.

When the real ``hypothesis`` package is installed it is re-exported
unchanged. Otherwise, minimal seeded-random equivalents of ``given`` /
``settings`` / ``strategies`` are provided: each ``@given`` test runs
``max_examples`` deterministic examples drawn from ``random.Random``
seeded by the test name, so failures are reproducible (no shrinking).

Only the strategy surface used by this repo's tests is implemented:
``integers``, ``booleans``, ``lists``.
"""
from __future__ import annotations

try:  # pragma: no cover - exercised only when hypothesis is installed
    from hypothesis import given, settings, strategies  # noqa: F401

    HAVE_HYPOTHESIS = True
except ImportError:
    import random
    import zlib

    HAVE_HYPOTHESIS = False

    _DEFAULT_MAX_EXAMPLES = 30

    class _Strategy:
        def __init__(self, sample):
            self._sample = sample

        def sample(self, rng: random.Random):
            return self._sample(rng)

    class _Strategies:
        @staticmethod
        def integers(min_value, max_value):
            return _Strategy(lambda rng: rng.randint(min_value, max_value))

        @staticmethod
        def booleans():
            return _Strategy(lambda rng: rng.random() < 0.5)

        @staticmethod
        def lists(elements, min_size=0, max_size=10):
            def draw(rng):
                k = rng.randint(min_size, max_size)
                return [elements.sample(rng) for _ in range(k)]

            return _Strategy(draw)

    strategies = _Strategies()

    def settings(max_examples=_DEFAULT_MAX_EXAMPLES, deadline=None, **_kw):
        """Records options for ``given``; a no-op on already-wrapped tests."""

        def deco(fn):
            fn._hyp_settings = {"max_examples": max_examples}
            return fn

        return deco

    def given(*strats, **kw_strats):
        def deco(fn):
            opts = getattr(fn, "_hyp_settings", {})
            n_examples = opts.get("max_examples", _DEFAULT_MAX_EXAMPLES)
            seed = zlib.crc32(f"{fn.__module__}.{fn.__qualname__}".encode())

            # NB: no functools.wraps — pytest must see a zero-arg signature,
            # not the original one (it would look for fixtures n, seed, ...).
            def runner():
                rng = random.Random(seed)
                for i in range(n_examples):
                    drawn = [s.sample(rng) for s in strats]
                    kw = {k: s.sample(rng) for k, s in kw_strats.items()}
                    try:
                        fn(*drawn, **kw)
                    except Exception as e:  # annotate the failing example
                        raise AssertionError(
                            f"{fn.__qualname__} failed on example {i}: "
                            f"args={drawn!r} kwargs={kw!r}"
                        ) from e

            runner.__name__ = fn.__name__
            runner.__qualname__ = fn.__qualname__
            runner.__doc__ = fn.__doc__
            runner.__module__ = fn.__module__
            return runner

        return deco
