"""MoE: group-local GSPMD dispatch + explicit all-to-all (shard_map) path.

Both implementations are checked against a dense no-drop reference (large
capacity factor => no token drops => exact agreement is required).
"""
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models.moe import moe_ffn, router_topk


def dense_reference(x2d, rw, wg, wu, wd, k):
    t, e = x2d.shape
    p = jax.nn.softmax(x2d.astype(jnp.float32) @ rw.astype(jnp.float32), -1)
    vals, ids = jax.lax.top_k(p, k)
    w = vals / vals.sum(-1, keepdims=True)
    g = jnp.einsum("te,xef->txf", x2d, wg)
    u = jnp.einsum("te,xef->txf", x2d, wu)
    y_all = jnp.einsum("txf,xfe->txe",
                       jax.nn.silu(g.astype(jnp.float32)).astype(x2d.dtype) * u,
                       wd)
    sel = jnp.take_along_axis(y_all, ids[:, :, None], axis=1)
    return (sel * w[:, :, None].astype(x2d.dtype)).sum(1)


@pytest.mark.parametrize("groups", [1, 4])
def test_moe_ffn_matches_dense_reference(groups):
    t, e, f, x_n, k = 64, 8, 12, 8, 2
    key = jax.random.PRNGKey(0)
    x = jax.random.normal(key, (t, e), jnp.float32)
    rw = jax.random.normal(jax.random.PRNGKey(1), (e, x_n), jnp.float32)
    wg = jax.random.normal(jax.random.PRNGKey(2), (x_n, e, f)) * 0.2
    wu = jax.random.normal(jax.random.PRNGKey(3), (x_n, e, f)) * 0.2
    wd = jax.random.normal(jax.random.PRNGKey(4), (x_n, f, e)) * 0.2
    want = dense_reference(x, rw, wg, wu, wd, k)
    got, aux = moe_ffn(x.reshape(groups, t // groups, e), rw, wg, wu, wd,
                       top_k=k, capacity_factor=8.0)  # no drops
    np.testing.assert_allclose(np.asarray(got.reshape(t, e)),
                               np.asarray(want), rtol=2e-5, atol=2e-5)
    assert float(aux) > 0


def test_router_topk_weights_normalized():
    logits = jax.random.normal(jax.random.PRNGKey(0), (32, 16))
    w, ids, aux = router_topk(logits, 4)
    np.testing.assert_allclose(np.asarray(w.sum(-1)), 1.0, rtol=1e-5)
    assert ids.shape == (32, 4)


@pytest.mark.tier1
def test_a2a_slot_shuffle_roundtrips_with_metadata():
    """The dispatch_shuffle building block: payload and int metadata take
    the same slot permutation and the inverse restores packing order."""
    from repro.core.bmmc import Bmmc
    from repro.models.moe_a2a import _slot_shuffle

    peers, cap, e = 4, 32, 8
    bmmc = Bmmc.bit_reverse(cap.bit_length() - 1)
    payload = jax.random.normal(jax.random.PRNGKey(0), (peers, cap, e))
    eid = jax.random.randint(jax.random.PRNGKey(1), (peers, cap), 0, 7)
    ps, es = _slot_shuffle(payload, bmmc), _slot_shuffle(eid, bmmc)
    assert not np.array_equal(np.asarray(ps), np.asarray(payload))
    # metadata rides along: the multiset of (eid, payload-row) pairs is
    # preserved within each peer block
    for p in range(peers):
        src = sorted((int(e_),) + tuple(row) for e_, row in
                     zip(np.asarray(eid[p]), np.asarray(payload[p])))
        got = sorted((int(e_),) + tuple(row) for e_, row in
                     zip(np.asarray(es[p]), np.asarray(ps[p])))
        assert src == got
    assert np.array_equal(
        np.asarray(_slot_shuffle(ps, bmmc, inverse=True)),
        np.asarray(payload))
    assert np.array_equal(
        np.asarray(_slot_shuffle(es, bmmc, inverse=True)), np.asarray(eid))
    # differentiable: grad of a shuffled sum-loss is the inverse shuffle
    w = jax.random.normal(jax.random.PRNGKey(2), (peers, cap, e))
    g = jax.grad(lambda x: jnp.sum(w * _slot_shuffle(x, bmmc)))(payload)
    assert np.allclose(np.asarray(g),
                       np.asarray(_slot_shuffle(w, bmmc, inverse=True)))


def test_capacity_drops_tokens():
    """With a tiny capacity factor, some token outputs must be zero."""
    t, e, f, x_n, k = 256, 8, 8, 2, 1
    key = jax.random.PRNGKey(5)
    x = jax.random.normal(key, (1, t, e), jnp.float32)
    rw = jnp.zeros((e, x_n)).at[:, 0].set(1.0)  # all tokens pick expert 0
    wg = jnp.ones((x_n, e, f)) * 0.1
    wu = jnp.ones((x_n, e, f)) * 0.1
    wd = jnp.ones((x_n, f, e)) * 0.1
    out, _ = moe_ffn(x, rw, wg, wu, wd, top_k=k, capacity_factor=0.25)
    zero_rows = np.sum(np.abs(np.asarray(out[0])).sum(-1) == 0)
    assert zero_rows > 0  # overflow beyond capacity was dropped


A2A_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp, numpy as np
kw = ({"axis_types": (jax.sharding.AxisType.Auto,)*2}
      if hasattr(jax.sharding, "AxisType") else {})
mesh = jax.make_mesh((2, 4), ("data", "model"), **kw)
from repro.models.moe_a2a import moe_ffn_a2a

B, S, E, F, X, K = 2, 16, 8, 12, 8, 2
x = jax.random.normal(jax.random.PRNGKey(0), (B, S, E), jnp.float32)
rw = jax.random.normal(jax.random.PRNGKey(1), (E, X), jnp.float32)
wg = jax.random.normal(jax.random.PRNGKey(2), (X, E, F)) * 0.2
wu = jax.random.normal(jax.random.PRNGKey(3), (X, E, F)) * 0.2
wd = jax.random.normal(jax.random.PRNGKey(4), (X, F, E)) * 0.2

def ref(x, wg_):
    t = x.reshape(-1, E)
    p = jax.nn.softmax(t @ rw, -1)
    vals, ids = jax.lax.top_k(p, K)
    w = vals / vals.sum(-1, keepdims=True)
    g = jnp.einsum("te,xef->txf", t, wg_)
    u = jnp.einsum("te,xef->txf", t, wu)
    y = jnp.einsum("txf,xfe->txe", jax.nn.silu(g) * u, wd)
    sel = jnp.take_along_axis(y, ids[:, :, None], axis=1)
    return (sel * w[:, :, None]).sum(1).reshape(B, S, E)

out, aux = jax.jit(lambda x: moe_ffn_a2a(x, rw, wg, wu, wd, top_k=K,
                                         capacity_factor=8.0, mesh=mesh))(x)
assert np.abs(np.asarray(out) - np.asarray(ref(x, wg))).max() < 1e-4
g1 = jax.jit(jax.grad(lambda w_: jnp.sum(
    moe_ffn_a2a(x, rw, w_, wu, wd, top_k=K, capacity_factor=8.0,
                mesh=mesh)[0] ** 2)))(wg)
g2 = jax.grad(lambda w_: jnp.sum(ref(x, w_) ** 2))(wg)
rel = np.abs(np.asarray(g1) - np.asarray(g2)).max() / np.abs(np.asarray(g2)).max()
assert rel < 1e-3, rel
# dispatch_shuffle neutrality at no-drop capacity: bit-identical output
out_s, _ = jax.jit(lambda x: moe_ffn_a2a(x, rw, wg, wu, wd, top_k=K,
                                         capacity_factor=8.0, mesh=mesh,
                                         dispatch_shuffle=True))(x)
assert np.array_equal(np.asarray(out), np.asarray(out_s))
print("OK")
"""


@pytest.mark.slow
def test_moe_a2a_forward_and_grad():
    out = subprocess.run(
        [sys.executable, "-c", A2A_SCRIPT], capture_output=True, text=True,
        env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin", "HOME": "/root"},
        timeout=500)
    assert out.returncode == 0, out.stderr[-3000:]
    assert "OK" in out.stdout
