"""Telemetry subsystem tests (DESIGN.md §12).

Pins the contracts of the :mod:`repro.obs` layer:

* spans nest (parent/depth recorded) and survive a Chrome-trace export
  round trip as valid ``ph: "X"`` events;
* the dispatch counters the executor records while tracing a sort
  program EXACTLY equal the transaction model's
  ``cost(..., clustered=True)["kernels"]`` counts — the model-honesty
  acceptance bar, here at 2^8;
* disabled telemetry records nothing (counters, histograms, spans all
  empty after an instrumented program runs);
* counter deltas are independent of the batch size (trace-time
  recording: the per-class counts describe the program, not the data),
  and warm same-shape calls add no dispatch counts at all;
* ``cache_stats()`` covers every executor/ops cache and
  ``clear_caches()`` resets the telemetry with them.
"""
import json
import random

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import obs
from repro.combinators import cache_stats, clear_caches, compile_expr
from repro.combinators import vocab as V
from repro.combinators.sort import sort_expr
from repro.core.bmmc import Bmmc
from repro.kernels.ops import choose_tile


@pytest.fixture(autouse=True)
def _clean_telemetry():
    """Every test starts disabled with empty buffers and leaves no
    telemetry state behind for the rest of the suite."""
    obs.disable()
    obs.reset()
    yield
    obs.disable()
    obs.reset()


@pytest.fixture(autouse=True, scope="module")
def _bounded_caches():
    yield
    clear_caches()


def _payload(shape, seed):
    vals = np.random.default_rng(seed).normal(size=shape)
    return jnp.asarray(vals.astype(np.float32))


# ---------------------------------------------------------------------------
# Span nesting + Chrome-trace export round trip
# ---------------------------------------------------------------------------

@pytest.mark.tier1
def test_span_nesting_and_export_roundtrip(tmp_path):
    obs.enable(sync=False)
    with obs.span("outer", cat="test", n=8) as oargs:
        oargs["discovered"] = "late-fact"
        with obs.span("inner", cat="test"):
            pass
    evs = obs.events()
    assert [e["name"] for e in evs] == ["inner", "outer"]  # exit order
    inner, outer = evs
    assert inner["args"]["parent"] == "outer"
    assert inner["args"]["depth"] == 1
    assert "parent" not in outer["args"]
    assert outer["args"]["n"] == 8
    assert outer["args"]["discovered"] == "late-fact"
    for ev in evs:
        assert ev["ph"] == "X" and ev["dur"] >= 0

    path = tmp_path / "roundtrip.trace.json"
    obs.export_trace(str(path))
    with open(path) as f:
        loaded = json.load(f)
    assert loaded["traceEvents"] == evs
    assert loaded["displayTimeUnit"] == "ms"
    assert loaded["otherData"]["dropped"] == 0


@pytest.mark.tier1
def test_span_is_noop_when_disabled():
    with obs.span("ghost") as args:
        assert args is None
    assert obs.events() == []


# ---------------------------------------------------------------------------
# Counter honesty: recorded dispatches == transaction-model counts
# ---------------------------------------------------------------------------

@pytest.mark.tier1
def test_sort_counters_match_program_cost():
    """The acceptance bar: execute the 2^8 sort once with telemetry on;
    the per-kernel dispatch counters must equal the clustered model's
    kernel-class counts exactly — same vocabulary, same values."""
    clear_caches()
    n = 8
    t = choose_tile(n, 4, 1)
    f = compile_expr(sort_expr(n), engine="pallas")
    want = {k: v for k, v in
            f.cost(n, t, clustered=True)["kernels"].items() if v}
    obs.enable(sync=True)
    jax.block_until_ready(f(_payload((1 << n,), 0)))
    got = {k: v for k, v in obs.kernel_counts().items() if v}
    assert got == want, (got, want)
    # the modeled round trips accumulate alongside
    assert obs.counter_total("model.round_trips") > 0
    mm = obs.model_vs_measured()
    assert mm["program_calls"] == 1
    assert mm["modeled_round_trips"] > 0
    assert mm["measured_wall_us"] > 0


@pytest.mark.tier1
def test_report_renders_after_execution():
    clear_caches()
    n = 7
    f = compile_expr(sort_expr(n), engine="pallas")
    obs.enable(sync=True)
    jax.block_until_ready(f(_payload((1 << n,), 1)))
    text = obs.report()
    assert "kernel dispatches" in text
    assert "model vs measured" in text
    assert "caches" in text
    snap = obs.snapshot()
    assert snap["kernel_counts"] == obs.kernel_counts()
    assert snap["trace_events"] == len(obs.events())
    json.dumps(snap)  # must be JSON-serializable (embedded in --json)


# ---------------------------------------------------------------------------
# Disabled mode is a strict no-op
# ---------------------------------------------------------------------------

@pytest.mark.tier1
def test_disabled_mode_records_nothing():
    clear_caches()
    n = 7
    f = compile_expr(sort_expr(n), engine="pallas")
    assert not obs.enabled()
    jax.block_until_ready(f(_payload((1 << n,), 2)))
    assert obs.counters() == {}
    assert obs.histograms() == {}
    assert obs.events() == []
    assert obs.kernel_counts() == {}
    # inc/observe are guarded too, not just the executor sites
    obs.inc("dispatch.kernel", kernel="tiled")
    obs.observe("program.call_us", 1.0)
    assert obs.counters() == {} and obs.histograms() == {}


# ---------------------------------------------------------------------------
# Batch-size independence of trace-time counters
# ---------------------------------------------------------------------------

@pytest.mark.tier1
def test_counter_deltas_independent_of_batch_size():
    """Counters record at trace time, so the dispatch counts describe
    the PROGRAM: re-tracing the same program for a different batch size
    yields the identical delta, and warm same-shape calls add nothing."""
    clear_caches()
    n = 8
    e = V.bit_reverse(n) >> V.perm(Bmmc.random(n, random.Random(3)))
    f = compile_expr(e, engine="pallas")
    obs.enable(sync=True)

    def delta(bsz, seed):
        before = obs.kernel_counts()
        jax.block_until_ready(
            f(_payload((bsz, 1 << n), seed), batched=True))
        after = obs.kernel_counts()
        return {k: v - before.get(k, 0) for k, v in after.items()
                if v - before.get(k, 0)}

    d2 = delta(2, 10)       # cold: executable traced here
    d4 = delta(4, 11)       # new shape: jit re-specializes, re-traces
    assert d2 == d4 and d2, (d2, d4)
    assert delta(4, 12) == {}   # warm same-shape call: no re-trace


# ---------------------------------------------------------------------------
# Cache hygiene: aggregate stats + telemetry reset
# ---------------------------------------------------------------------------

@pytest.mark.tier1
def test_cache_stats_covers_every_executor_cache():
    stats = cache_stats()
    assert {"geom", "block", "lane", "program", "fused_plan", "w_planar",
            "lowered", "clustered", "model_round_trips", "plans",
            "class_plan", "compiled_exprs"} <= set(stats)
    for name, info in stats.items():
        assert info.hits >= 0 and info.misses >= 0, name
        assert info.currsize >= 0, name
    # obs.cache_stats() is the same data as plain dicts
    assert obs.cache_stats()["program"]["currsize"] == \
        stats["program"].currsize


@pytest.mark.tier1
def test_clear_caches_resets_telemetry_too():
    clear_caches()
    n = 7
    f = compile_expr(sort_expr(n), engine="pallas")
    obs.enable(sync=True)
    jax.block_until_ready(f(_payload((1 << n,), 3)))
    assert obs.counters() and obs.events()
    assert cache_stats()["program"].currsize > 0
    clear_caches()
    assert obs.counters() == {} and obs.events() == []
    assert obs.histograms() == {}
    for name in ("geom", "block", "lane", "program", "fused_plan",
                 "clustered", "model_round_trips", "class_plan"):
        assert cache_stats()[name].currsize == 0, name
    assert obs.enabled()    # reset drops data, not the enabled flag
