"""Property tests for GF(2) linear algebra (hypothesis)."""
import random

import pytest
from _hyp_compat import given, settings, strategies as st

from repro.core import f2


def rand_invertible(n, seed):
    return f2.random_invertible(n, random.Random(seed))


@given(st.integers(2, 14), st.integers(0, 10**6))
@settings(max_examples=60, deadline=None)
def test_inverse_roundtrip(n, seed):
    a = rand_invertible(n, seed)
    ai = f2.inverse(a)
    assert f2.matmul(a, ai) == f2.identity(n)
    assert f2.matmul(ai, a) == f2.identity(n)


@given(st.integers(2, 12), st.integers(0, 10**6))
@settings(max_examples=60, deadline=None)
def test_lup(n, seed):
    a = rand_invertible(n, seed)
    l, u, p = f2.lup(a)
    assert f2.matmul(l, f2.matmul(u, p)) == a
    assert f2.is_lower(l) and f2.is_unit_diag(l)
    assert f2.is_upper(u)
    assert f2.to_perm(p) is not None


@given(st.integers(2, 12), st.integers(0, 10**6))
@settings(max_examples=60, deadline=None)
def test_ulp_paper_order(n, seed):
    """Paper §5.2: A = U L P with U upper, L lower, P a permutation."""
    a = rand_invertible(n, seed)
    u, l, p = f2.ulp(a)
    assert f2.matmul(u, f2.matmul(l, p)) == a
    assert f2.is_upper(u)
    assert f2.is_lower(l)
    assert f2.to_perm(p) is not None


@given(st.integers(1, 14), st.integers(0, 10**6), st.integers(0, 10**6))
@settings(max_examples=40, deadline=None)
def test_matvec_linear(n, seed, xseed):
    a = rand_invertible(n, seed)
    r = random.Random(xseed)
    x, y = r.randrange(1 << n), r.randrange(1 << n)
    assert f2.matvec(a, x ^ y) == f2.matvec(a, x) ^ f2.matvec(a, y)


@given(st.integers(2, 10), st.integers(0, 10**6))
@settings(max_examples=40, deadline=None)
def test_matmul_assoc_transpose(n, seed):
    r = random.Random(seed)
    a, b = f2.random_invertible(n, r), f2.random_invertible(n, r)
    assert f2.transpose(f2.matmul(a, b)) == f2.matmul(f2.transpose(b), f2.transpose(a))
    x = r.randrange(1 << n)
    assert f2.matvec(f2.matmul(a, b), x) == f2.matvec(a, f2.matvec(b, x))


def test_perm_matrix_semantics():
    # paper §3: P_{i,j} = 1 iff i = p(j); y_{p(j)} = x_j
    p = [2, 0, 3, 1]
    m = f2.from_perm(p)
    for j in range(4):
        x = 1 << j
        y = f2.matvec(m, x)
        assert y == 1 << p[j]
    assert f2.to_perm(m) == p


def test_reversal_involution():
    for n in (1, 3, 8):
        r = f2.reversal(n)
        assert f2.matmul(r, r) == f2.identity(n)


@given(st.integers(2, 12), st.integers(0, 10**6), st.integers(1, 6))
@settings(max_examples=60, deadline=None)
def test_tiled_columns_witness(n, seed, t):
    """tiled_columns returns a valid witness whenever it returns one."""
    if t > n:
        return
    a = rand_invertible(n, seed)
    cols = f2.tiled_columns(a, t)
    if cols is None:
        return
    assert len(cols) == t
    low = (1 << t) - 1
    sub_rows = []
    for i in range(t):
        bits = 0
        for k, j in enumerate(cols):
            if (a[i] >> j) & 1:
                bits |= 1 << k
        sub_rows.append(bits)
    assert f2.rank(tuple(sub_rows)) == t          # top t x t invertible
    for i in range(t, n):
        for j in cols:
            assert not (a[i] >> j) & 1            # bottom rows zero
