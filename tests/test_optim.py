"""AdamW: f32 vs int8 block-quantized moments."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.optim.adamw import (AdamWConfig, adamw_init, adamw_update,
                               dequantize8, quantize8, state_shapes)
from repro.optim.schedule import warmup_cosine


@pytest.mark.parametrize("shape", [(7,), (3, 300), (2, 3, 515), (128, 256)])
def test_quantize_roundtrip(shape):
    x = jax.random.normal(jax.random.PRNGKey(0), shape)
    q = quantize8(x)
    y = dequantize8(q, shape)
    # per-block max scaling: error <= scale/2 <= max|block|/254
    err = np.abs(np.asarray(y - x))
    bound = np.abs(np.asarray(x)).max() / 100
    assert err.max() <= bound
    # leading dims preserved (sharding-preserving layout)
    assert q["q"].shape[:-2] == shape[:-1]


def _quadratic_losses(bits, steps=60):
    target = jnp.asarray([1.5, -2.0, 0.5, 3.0])
    params = {"w": jnp.zeros((4,), jnp.float32)}
    cfg = AdamWConfig(lr=0.05, weight_decay=0.0, state_bits=bits)
    state = adamw_init(params, cfg)

    losses = []
    for _ in range(steps):
        def loss_fn(p):
            return jnp.sum((p["w"] - target) ** 2)
        loss, g = jax.value_and_grad(loss_fn)(params)
        params, state = adamw_update(params, g, state, cfg)
        losses.append(float(loss))
    return losses


def test_adamw_converges_f32():
    losses = _quadratic_losses(32)
    assert losses[-1] < losses[0] * 0.05


def test_adamw_converges_int8():
    """8-bit moments track the f32 trajectory closely on a quadratic."""
    l32 = _quadratic_losses(32)
    l8 = _quadratic_losses(8)
    assert l8[-1] < l8[0] * 0.10
    assert abs(l8[-1] - l32[-1]) < 0.5


def test_state_shapes_match_init():
    params = {"a": jnp.zeros((3, 300)), "b": {"c": jnp.zeros((7,))}}
    for bits in (32, 8):
        cfg = AdamWConfig(state_bits=bits)
        st = adamw_init(params, cfg)
        sh = state_shapes(jax.tree.map(
            lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), params), cfg)
        real = jax.tree.map(lambda x: (x.shape, x.dtype), st)
        want = jax.tree.map(lambda x: (x.shape, x.dtype), sh)
        assert jax.tree.all(jax.tree.map(lambda a, b: a == b, real, want))


def test_warmup_cosine_shape():
    assert float(warmup_cosine(0, warmup=10, total=100)) == 0.0
    assert abs(float(warmup_cosine(10, warmup=10, total=100)) - 1.0) < 1e-6
    assert float(warmup_cosine(100, warmup=10, total=100)) <= 0.11
