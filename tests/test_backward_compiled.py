"""The compiled backward pass (DESIGN.md §13).

Gradients of compiled programs no longer replay the forward stage by
stage under ``jax.vjp``: a permutation-only program's backward IS the
offline-inverted (clustered) program, and a compute-bearing program's
backward is the COLLAPSED plan — every transposed pairwise compute
conjugated into forward-output coordinates plus at most ONE composed
inverse BMMC pass. These tests pin, in order:

* the inverse-program algebra (clusters invert to clusters; per-class
  closure; cost symmetry);
* the residual policy (permutation-only forwards save NOTHING);
* the collapsed-plan structure (sort's composed sigma is the identity,
  so its backward needs ZERO permutation passes);
* bitwise parity of the collapsed backward against the per-stage
  ``jax.vjp`` replay oracle across dtypes, tail shapes, batching, and
  tied inputs (the 0.5-mask path);
* the backward honesty gate: one COLD backward call's
  ``model.vjp_round_trips`` counter delta equals
  ``CompiledExpr.vjp_round_trips``, and a permutation-only backward's
  kernel-class histogram mirrors the forward's;
* the (gated) gradient megakernel agrees with the collapsed default.
"""
import random

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import obs
from repro.combinators import (Bfly, CmpHalves, FusedStage, Map,
                               clear_caches, compile_expr, inverse_program,
                               is_perm_program, program_cost, run_program,
                               vocab as V)
from repro.combinators import execute as EX
from repro.combinators.fft import compiled_fft, fft_expr, to_planar
from repro.combinators.sort import sort_expr
from repro.core.bmmc import Bmmc
from repro.kernels.ops import choose_tile

N = 8


def _x(n, seed, shape=(), dtype=np.float32):
    return jnp.asarray(np.random.default_rng(seed).normal(
        size=shape + (1 << n,)).astype(dtype))


def _perm_expr(n, seed=0):
    rng = random.Random(seed)
    return (V.bit_reverse(n) >> V.perm(Bmmc.random(n, rng)) >> V.riffle(n))


# ---------------------------------------------------------------------------
# Inverse-program algebra: clusters invert to clusters, per-class closure
# ---------------------------------------------------------------------------

@pytest.mark.tier1
def test_inverse_clustered_mirrors_forward_cost():
    """inverse(clustered perm program) is itself clustered (FusedStage
    of inverted members, reversed) and models the SAME kernel-class
    histogram and round-trip count — the backward re-dispatches the
    classes the forward did."""
    t = choose_tile(N, 4, 1)
    f = compile_expr(_perm_expr(N), engine="pallas")
    prog = f.clustered_program(N, t)
    inv = inverse_program(prog)
    assert is_perm_program(inv)
    assert len(inv) == len(prog)
    for st, ist in zip(reversed(prog), inv):
        assert type(ist) is type(st)
        if isinstance(st, FusedStage):
            assert not ist.computes
    fcost, icost = program_cost(prog, t), program_cost(inv, t)
    assert icost["round_trips"] == fcost["round_trips"]
    assert icost["kernels"] == fcost["kernels"]


@pytest.mark.tier1
def test_inverse_is_involution_on_cost():
    """Inverting twice restores the forward's modeled cost exactly."""
    t = choose_tile(N, 4, 1)
    f = compile_expr(_perm_expr(N, seed=3), engine="pallas")
    prog = f.clustered_program(N, t)
    twice = inverse_program(inverse_program(prog))
    assert program_cost(twice, t) == program_cost(prog, t)


@pytest.mark.tier1
def test_inverse_program_rejects_compute_clusters():
    t = choose_tile(N, 4, 1)
    f = compile_expr(sort_expr(N), engine="pallas")
    prog = f.clustered_program(N, t)
    assert not is_perm_program(prog)
    with pytest.raises(TypeError):
        inverse_program(prog)


# ---------------------------------------------------------------------------
# Residual policy: permutations save nothing
# ---------------------------------------------------------------------------

@pytest.mark.tier1
def test_perm_only_program_saves_no_residual():
    t = choose_tile(N, 4, 1)
    f = compile_expr(_perm_expr(N), engine="pallas")
    prog = f.clustered_program(N, t)
    x = _x(N, 0)
    _, res = EX._program_apply_fwd(x, prog, t, "pallas", False)
    assert res is None


@pytest.mark.tier1
def test_compute_free_cluster_saves_no_residual():
    from repro.combinators.ir import Perm
    from repro.combinators.optimize import _run_fused
    rng = random.Random(4)
    fs = _run_fused((Perm(Bmmc.random(N, rng)), Perm(Bmmc.random(N, rng))), N)
    assert not fs.computes
    x = _x(N, 1)
    _, res = EX._fused_fwd(x, fs, "pallas", False)
    assert res is None


@pytest.mark.tier1
def test_compute_bearing_program_saves_inputs_at_compute_stages():
    """Residuals are the inputs of compute-bearing stages only — NOT a
    copy per stage (the old replay saved the whole forward input even
    for pure permutations)."""
    t = choose_tile(N, 4, 1)
    f = compile_expr(sort_expr(N), engine="pallas")
    prog = f.clustered_program(N, t)
    x = _x(N, 2)
    _, res = EX._program_apply_fwd(x, prog, t, "pallas", False)
    n_compute = sum(
        1 for st in prog
        if isinstance(st, (CmpHalves, Bfly, Map))
        or (isinstance(st, FusedStage) and st.computes))
    assert res is not None and len(res) == 1 + n_compute


# ---------------------------------------------------------------------------
# Collapsed-plan structure
# ---------------------------------------------------------------------------

@pytest.mark.tier1
def test_sort_collapsed_plan_has_identity_final():
    """The balanced-periodic sorter's perms compose to the identity in
    backward time, so the collapsed backward needs ZERO permutation
    passes: all transposed cmp links run in forward-output coordinates
    and ``plan.final`` is None."""
    t = choose_tile(N, 4, 1)
    f = compile_expr(sort_expr(N), engine="pallas")
    plan = EX._program_bwd_plan(f.clustered_program(N, t), False)
    assert plan is not None
    assert plan.final is None
    assert not plan.has_bfly
    assert all(lk[0] == "cmp" for lk in plan.links)
    assert f.vjp_round_trips(N, t) == 0


@pytest.mark.tier1
def test_nonidentity_sigma_collapses_to_one_compute_free_pass():
    """A trailing permutation after the computes must survive as exactly
    ONE composed compute-free pass in the collapsed backward."""
    t = choose_tile(N, 4, 1)
    f = compile_expr(sort_expr(N) >> V.bit_reverse(N), engine="pallas")
    prog = f.clustered_program(N, t)
    plan = EX._program_bwd_plan(prog, False)
    assert plan is not None
    assert isinstance(plan.final, FusedStage) and not plan.final.computes
    modeled = f.vjp_round_trips(N, t)
    assert modeled == program_cost((plan.final,), t)["round_trips"] > 0


@pytest.mark.tier1
def test_map_stage_has_no_collapsed_plan():
    t = choose_tile(N, 4, 1)
    f = compile_expr(V.emap("double", lambda v: v * 2.0) >> V.riffle(N),
                     engine="pallas")
    assert EX._program_bwd_plan(f.clustered_program(N, t), False) is None
    assert f.vjp_round_trips(N, t) is None


# ---------------------------------------------------------------------------
# Bitwise parity: collapsed backward vs per-stage jax.vjp replay oracle
# ---------------------------------------------------------------------------

def _replay_grad(f, n, x, w, batched=False):
    """The pre-§13 backward: jax.vjp per-stage replay of the expanded
    program on the ref engine — the oracle the collapsed plan must
    reproduce bit for bit (its masks are constructed to be bitwise
    identical to the replayed where/select VJPs)."""
    prog = f.program(n)

    def loss(v):
        return jnp.sum(w * run_program(prog, v, "ref", batched=batched))

    return jax.grad(loss)(x)


@pytest.mark.tier1
@pytest.mark.parametrize("dtype", [np.float32, np.float16])
@pytest.mark.parametrize("shape,batched", [
    ((), False),          # flat vector: permuted axis only
    ((8,), True),         # leading batch axis, then the permuted axis
    ((3,), True),         # ragged (non-power-of-2) batch width
])
def test_collapsed_backward_bitwise_vs_replay(dtype, shape, batched):
    f = compile_expr(sort_expr(N), engine="pallas")
    x = _x(N, 7, shape=shape, dtype=dtype)
    w = _x(N, 77, shape=shape, dtype=dtype)
    g = jax.grad(lambda v: jnp.sum(w * f(v, batched=batched)))(x)
    oracle = _replay_grad(f, N, x, w, batched=batched)
    assert g.dtype == x.dtype
    assert np.array_equal(np.asarray(g), np.asarray(oracle)), (dtype, shape)


@pytest.mark.tier1
def test_collapsed_backward_bitwise_on_ties():
    """Tied inputs exercise the balanced 0.5 masks: d(min)/d(max) at a
    tie splits evenly between the pair. The collapsed select-form masks
    must equal the replayed VJP exactly even there."""
    f = compile_expr(sort_expr(N), engine="pallas")
    rng = np.random.default_rng(5)
    x = jnp.asarray(rng.integers(0, 4, size=1 << N).astype(np.float32))
    w = _x(N, 55)
    g = jax.grad(lambda v: jnp.sum(w * f(v)))(x)
    oracle = _replay_grad(f, N, x, w)
    assert np.array_equal(np.asarray(g), np.asarray(oracle))


@pytest.mark.tier1
def test_permchain_backward_is_inverse_program_bitwise():
    """Permutation-only: grad == clustered inverse program applied to
    the cotangent, exactly, on both engines."""
    for engine in ("ref", "pallas"):
        f = compile_expr(_perm_expr(N), engine=engine)
        x, w = _x(N, 9), _x(N, 99)
        g = jax.grad(lambda v: jnp.sum(w * f(v)))(x)
        oracle = run_program(f.vjp_program(N), w, "ref")
        assert np.array_equal(np.asarray(g), np.asarray(oracle)), engine


@pytest.mark.tier1
def test_fft_planar_grad_collapsed_vs_replay():
    """Butterfly (bfly) links in the collapsed sweep: planar complex
    FFT gradients agree with the replay oracle (regression for the
    side-table broadcast bug the fused bfly sweep shipped with)."""
    n = 6
    rng = np.random.default_rng(13)
    x = to_planar((rng.normal(size=1 << n)
                   + 1j * rng.normal(size=1 << n)).astype(np.complex64))
    w = jnp.asarray(rng.normal(size=(1 << n, 2)).astype(np.float32))
    f = compile_expr(fft_expr(n), engine="pallas")
    g = jax.grad(lambda v: jnp.sum(w * f(v)))(x)
    prog = f.program(n)
    oracle = jax.grad(lambda v: jnp.sum(
        w * run_program(prog, v, "ref")))(x)
    assert np.allclose(np.asarray(g), np.asarray(oracle),
                       rtol=1e-5, atol=1e-5)


# ---------------------------------------------------------------------------
# Backward honesty gate: cold counter delta == model; histogram mirror
# ---------------------------------------------------------------------------

def _cold_bwd_counters(f, x):
    """One cold loss-forward and one cold grad call, each from cleared
    executor caches (counters fire at executable trace time)."""
    was = obs.enabled()
    obs.enable(sync=True)
    try:
        clear_caches()
        obs.reset()
        jax.block_until_ready(jax.jit(lambda v: jnp.sum(f(v) ** 2))(x))
        fwd_kernels = obs.kernel_counts()
        clear_caches()
        obs.reset()
        jax.block_until_ready(
            jax.jit(jax.grad(lambda v: jnp.sum(f(v) ** 2)))(x))
        delta = int(obs.counter_total("model.vjp_round_trips"))
        grad_kernels = obs.kernel_counts()
    finally:
        if not was:
            obs.disable()
        obs.reset()
    bwd_kernels = {k: v - fwd_kernels.get(k, 0)
                   for k, v in grad_kernels.items()
                   if v - fwd_kernels.get(k, 0)}
    return delta, fwd_kernels, bwd_kernels


@pytest.mark.tier1
def test_cold_backward_counter_delta_equals_model_permchain():
    t = choose_tile(N, 4, 1)
    f = compile_expr(_perm_expr(N), engine="pallas")
    modeled = f.vjp_round_trips(N, t)
    delta, fwd_kernels, bwd_kernels = _cold_bwd_counters(f, _x(N, 0))
    assert modeled is not None and delta == modeled
    # perm-only: the inverse program re-dispatches the same classes
    assert bwd_kernels == fwd_kernels


@pytest.mark.tier1
def test_cold_backward_counter_delta_equals_model_sort():
    t = choose_tile(N, 4, 1)
    f = compile_expr(sort_expr(N), engine="pallas")
    modeled = f.vjp_round_trips(N, t)
    delta, _, bwd_kernels = _cold_bwd_counters(f, _x(N, 0))
    assert modeled == 0 and delta == 0
    # collapsed with identity sigma: the backward dispatches NOTHING
    assert bwd_kernels == {}


@pytest.mark.tier1
def test_vjp_dispatch_counter_labels_kind():
    was = obs.enabled()
    obs.enable(sync=True)
    try:
        obs.reset()
        clear_caches()
        f = compile_expr(_perm_expr(N), engine="pallas")
        jax.block_until_ready(
            jax.jit(jax.grad(lambda v: jnp.sum(f(v) ** 2)))(_x(N, 0)))
        counts = {labels: v for (name, labels), v in obs.counters().items()
                  if name == "dispatch.vjp"}
        assert sum(counts.values()) >= 1
    finally:
        if not was:
            obs.disable()
        obs.reset()


# ---------------------------------------------------------------------------
# Gradient megakernel (gated): agrees with the collapsed default
# ---------------------------------------------------------------------------

@pytest.mark.tier1
def test_bwd_megakernel_gate_default_off():
    assert EX.BWD_MEGAKERNEL is False


@pytest.mark.tier1
def test_bwd_megakernel_matches_collapsed(monkeypatch):
    f = compile_expr(sort_expr(N), engine="pallas")
    x, w = _x(N, 21), _x(N, 22)

    def grad():
        clear_caches()
        return np.asarray(jax.grad(
            lambda v: jnp.sum(w * f(v)))(x))

    g_default = grad()
    monkeypatch.setattr(EX, "BWD_MEGAKERNEL", True)
    g_mega = grad()
    assert np.allclose(g_mega, g_default, rtol=1e-5, atol=1e-6)
