"""Class-dispatch kernel hierarchy tests (DESIGN.md §11).

Pins the contracts of this PR's dispatch stack:

* the classification predicates partition fuzzed BMMC space (n=2..16)
  and stay consistent with ``is_bp`` / ``is_bpc`` / ``is_tiled``;
* each fast-path kernel (block-permute, lane-permute) is bitwise-equal
  to the ref engine across dtypes x trailing dims x batch sizes for
  BMMCs sampled from its class;
* the generalized witness-direction planner gives EVERY invertible BMMC
  a one-pass plan (2t <= n) whose tables drive the unchanged tiled
  kernel to the exact permutation, and whose analytic stats match the
  enumerated tables;
* the block plan's descriptor count equals the copy-through-VMEM
  baseline's whenever the class grants copy-block granularity;
* free-stage folding (complement / tile-index-only) erases the folded
  stage's HBM round trip and stays lossless;
* the program-executable and class-plan caches are registered with
  ``clear_caches`` and their keys are independent of the batch size.
"""
import random

import jax.numpy as jnp
import numpy as np
import pytest

from repro.combinators import (cache_stats, clear_caches, cluster,
                               compile_expr, expand_clusters, fold_free,
                               program_cost, vocab as V)
from repro.combinators.ir import CmpHalves, Perm
from repro.core.bmmc import Bmmc
from repro.core.tiling import (class_stats, copy_descriptors, dispatch_kernel,
                               plan_block, plan_bmmc, plan_general,
                               plan_stats_general, plan_tiled)
from repro.kernels.bmmc_permute import (block_permute, copy_pad_elems,
                                        lane_permute, tiled_permute)
from repro.kernels.ops import bmmc_permute, choose_tile, class_plan
from repro.kernels.ref import bmmc_ref


@pytest.fixture(autouse=True, scope="module")
def _bounded_caches():
    yield
    clear_caches()


def _payload(shape, dtype, seed):
    vals = np.random.default_rng(seed).integers(0, 1 << 16, shape)
    return jnp.asarray(vals).astype(dtype)


def _assert_bitwise(got, want, ctx):
    assert got.dtype == want.dtype, ctx
    assert np.array_equal(np.asarray(got).view(np.uint8),
                          np.asarray(want).view(np.uint8)), ctx


def _sample_of_class(cls: str, n: int, t: int, rng) -> Bmmc:
    """A random BMMC whose ``bmmc_class(t)`` is exactly ``cls`` (a draw
    from a structural family can collapse into an earlier class — e.g. a
    1-bit "block" sub-BMMC is the identity — so resample until exact)."""
    ident = tuple(1 << i for i in range(n))
    while True:
        if cls == "identity":
            return Bmmc.identity(n)
        elif cls == "complement":
            b = Bmmc(ident, rng.randrange(1, 1 << n))
        elif cls == "block":
            # needs >= 2 permutable high bits or it collapses to
            # identity/complement
            k = rng.randrange(t, n - 1)
            sub = Bmmc.random(n - k, rng)
            b = Bmmc(ident[:k] + tuple(r << k for r in sub.rows),
                     sub.c << k)
        elif cls == "lane":
            k = rng.randrange(2, t + 1)  # closed on the low k <= t bits
            sub = Bmmc.random(k, rng)
            b = Bmmc(tuple(sub.rows) + ident[k:], sub.c)
        elif cls == "tiled":
            b = Bmmc.random_bpc(n, rng)
        else:
            b = Bmmc.random(n, rng)
        if b.bmmc_class(t) == cls:
            return b


# ---------------------------------------------------------------------------
# Classification predicates partition BMMC space
# ---------------------------------------------------------------------------

@pytest.mark.tier1
@pytest.mark.parametrize("n", range(2, 17))
def test_classes_partition_and_agree_with_bp_bpc_tiled(n):
    rng = random.Random(n)
    t = max(1, n // 2)
    samples = [Bmmc.identity(n), Bmmc.reverse_array(n),
               Bmmc.bit_reverse(n)]
    reachable = ["complement"]
    if n >= t + 2:
        reachable.append("block")
    if t >= 2:
        reachable.append("lane")
    for _ in range(12):
        samples.append(Bmmc.random(n, rng))
        samples.append(Bmmc.random_bpc(n, rng))
        samples.append(_sample_of_class(rng.choice(reachable), n, t, rng))
    for b in samples:
        cls = b.bmmc_class(t)
        # the class is the FIRST matching predicate -> partition
        preds = {
            "identity": b.is_identity_perm(),
            "complement": b.is_complement_only(),
            "block": b.is_tile_index_only(t),
            "lane": b.is_lane_local(t),
            "tiled": b.is_tiled(t),
            "general": True,
        }
        order = list(preds)
        assert preds[cls], (cls, b)
        for earlier in order[:order.index(cls)]:
            assert not preds[earlier], (cls, earlier, b)
        # consistency with the PR-2 classification predicates
        if cls in ("identity", "complement"):
            assert b.is_bpc()
            assert b.is_bp() == (b.c == 0)
        if b.is_bpc():
            assert b.is_tiled(t)          # every BPC is tiled
            assert cls != "general"
        if cls == "block":
            assert b.block_bits() >= t
            assert b.is_tiled(t)          # whole-row moves are tiled too
        if cls == "lane":
            assert b.is_tiled(t)
        if cls == "general":
            assert not b.is_tiled(t)


@pytest.mark.tier1
def test_block_and_lane_predicates_are_semantic():
    """Predicates match the permutation's actual behaviour: block never
    splits an aligned 2^t run; lane never moves an element across rows."""
    rng = random.Random(7)
    n, t = 9, 3
    for cls in ("block", "lane"):
        b = _sample_of_class(cls, n, t, rng)
        for x in rng.sample(range(1 << n), 32):
            y = b.apply(x)
            if cls == "block":
                assert (y & ((1 << t) - 1)) == (x & ((1 << t) - 1))
                assert b.apply(x ^ 1) == (y ^ 1)   # lanes ride along
            else:
                assert (y >> t) == (x >> t)        # row is fixed


# ---------------------------------------------------------------------------
# Fast-path kernels: bitwise parity with the ref oracle
# ---------------------------------------------------------------------------

@pytest.mark.tier1
@pytest.mark.parametrize("cls", ["block", "lane"])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.int32, jnp.bfloat16])
@pytest.mark.parametrize("tail,bsz", [((), None), ((3,), None), ((), 2),
                                      ((2,), 3)])
def test_fast_path_kernels_bitwise_vs_ref(cls, dtype, tail, bsz):
    rng = random.Random(hash((cls, str(dtype), tail, bsz)) % 9973)
    n = 10
    t = choose_tile(n, jnp.dtype(dtype).itemsize, tail[0] if tail else 1)
    b = _sample_of_class(cls, n, t, rng)
    kernel, payload = class_plan(b, t)
    assert kernel == cls, (kernel, b)
    batched = bsz is not None
    shape = ((bsz,) if batched else ()) + (1 << n,) + tail
    x = _payload(shape, dtype, seed=rng.randrange(1 << 20))
    if cls == "block":
        got = block_permute(x, payload, batched=batched)
    else:
        got = lane_permute(x, payload, batched=batched)
    want = bmmc_ref(x, b, batched=batched)
    _assert_bitwise(got, want, (cls, dtype, tail, bsz))
    # and the public dispatcher picks the same fast path
    got2 = bmmc_permute(x, b, batched=batched)
    _assert_bitwise(got2, want, ("dispatch", cls, dtype, tail, bsz))


@pytest.mark.tier1
@pytest.mark.parametrize("seed", range(3))
def test_complement_dispatch_all_shapes(seed):
    """Pure complements: high-only -> block kernel, low-only -> lane
    kernel, mixed -> one tiled pass; all bitwise == ref."""
    n = 10
    t = choose_tile(n, 4, 1)
    rng = random.Random(seed)
    cases = {
        "block": rng.randrange(1, 1 << (n - t)) << t,
        "lane": rng.randrange(1, 1 << t),
        "tiled": (rng.randrange(1, 1 << t)
                  | (rng.randrange(1, 1 << (n - t)) << t)),
    }
    x = _payload((1 << n,), jnp.float32, seed)
    for want_kernel, c in cases.items():
        b = Bmmc.xor_shift(n, c)
        assert b.bmmc_class(t) == "complement"
        assert dispatch_kernel(b, t) == want_kernel, (want_kernel, hex(c))
        _assert_bitwise(bmmc_permute(x, b), bmmc_ref(x, b),
                        (want_kernel, hex(c)))


# ---------------------------------------------------------------------------
# Generalized one-pass planner
# ---------------------------------------------------------------------------

@pytest.mark.tier1
@pytest.mark.parametrize("n,t", [(8, 3), (8, 4), (10, 5), (12, 6), (9, 4)])
def test_general_bmmc_plans_one_pass_and_matches_ref(n, t):
    rng = random.Random(n * 31 + t)
    for _ in range(4):
        b = Bmmc.random(n, rng)
        plans = plan_bmmc(b, t)
        assert len(plans) == 1, "2t <= n must always yield ONE pass"
        x = jnp.arange(1 << n, dtype=jnp.int32)
        got = tiled_permute(x, plans[0])
        _assert_bitwise(got, bmmc_ref(x, b), (n, t))


@pytest.mark.tier1
@pytest.mark.parametrize("n,t", [(8, 3), (10, 4), (12, 6)])
def test_general_plan_stats_match_tables(n, t):
    rng = random.Random(n + t)
    for _ in range(6):
        b = Bmmc.random(n, rng)
        if b.is_tiled(t):
            continue
        p = plan_general(b, t)
        s = plan_stats_general(b, t)
        assert p is not None and s is not None
        assert (s.n_tiles, s.rows_per_tile, s.in_run, s.out_run) == \
            (p.n_tiles, p.rows_per_tile, p.in_run, p.out_run)
        assert s.dma_descriptors() == p.dma_descriptors()


@pytest.mark.tier1
def test_classic_witness_still_preferred_for_tiled():
    """BPCs keep the tuned classic planner (contiguity-preferring
    witness search), not the generalized one."""
    n, t = 10, 4
    b = Bmmc.random_bpc(n, random.Random(5))
    plans = plan_bmmc(b, t)
    assert len(plans) == 1
    assert plans[0].row_cols, "classic plan carries witness columns"
    assert plan_tiled(b, t) is not None


# ---------------------------------------------------------------------------
# Block plan == copy roofline
# ---------------------------------------------------------------------------

@pytest.mark.tier1
def test_block_plan_descriptors_equal_copy():
    """ISSUE 5 acceptance: at copy-block granularity the block-permute
    plan issues exactly copy_through_vmem's descriptor count."""
    n = 13
    rng = random.Random(1)
    ident = tuple(1 << i for i in range(n))
    sub = Bmmc.random(n - 11, rng)
    b = Bmmc(ident[:11] + tuple(r << 11 for r in sub.rows), sub.c << 11)
    plan = plan_block(b, choose_tile(n, 4, 1))
    assert copy_pad_elems(1 << n) == 0     # baseline is exact, not padded
    assert plan.dma_descriptors() == copy_descriptors(n)
    cs = class_stats(b, choose_tile(n, 4, 1))
    assert cs["kernel"] == "block"
    assert cs["roofline_ratio"] == 1.0


# ---------------------------------------------------------------------------
# Free-stage folding
# ---------------------------------------------------------------------------

@pytest.mark.tier1
@pytest.mark.parametrize("free_cls", ["complement", "block"])
def test_fold_free_erases_round_trip_and_is_lossless(free_cls):
    n, t = 10, 4
    rng = random.Random(3)
    base = (Perm(Bmmc.random(n, rng)), CmpHalves(),
            Perm(Bmmc.random(n, rng)))
    clustered = cluster(base, n, t)
    free = Perm(_sample_of_class(free_cls, n, t, rng))
    prog = clustered + (free,)
    folded = fold_free(prog, n, t)
    assert expand_clusters(folded) == expand_clusters(prog)
    assert not any(isinstance(s, Perm) and s is free for s in folded)
    assert (program_cost(folded, t)["round_trips"]
            < program_cost(tuple(clustered) + (free,), t)["round_trips"]
            + 1), "free stage must not add a round trip"
    c_folded = program_cost(folded, t)
    c_apart = program_cost(prog, t)
    assert c_folded["round_trips"] == c_apart["round_trips"] - 1
    # execution equivalence through the pallas engine
    e_folded = compile_expr(V.seq(*expand_clusters(folded)), engine="pallas")
    e_ref = compile_expr(V.seq(*expand_clusters(prog)), engine="ref")
    x = _payload((1 << n,), jnp.float32, 11)
    _assert_bitwise(e_folded(x), e_ref(x), free_cls)


@pytest.mark.tier1
def test_clustered_program_round_trips_acceptance():
    """ISSUE 5 acceptance: the 2^12 sort drops below 40 model round
    trips; the 2^12 FFT stays at ONE."""
    from repro.combinators.sort import sort_expr
    from repro.combinators.fft import fft_expr
    n = 12
    f = compile_expr(sort_expr(n), engine="pallas")
    cost = f.cost(n, choose_tile(n, 4, 1), clustered=True)
    assert cost["round_trips"] < 40, cost
    assert "kernels" in cost and cost["kernels"], cost
    g = compile_expr(fft_expr(n), engine="pallas")
    gcost = g.cost(n, choose_tile(n, 4, 2), clustered=True)
    assert gcost["round_trips"] == 1, gcost


# ---------------------------------------------------------------------------
# Cache registration + batch-size independence (ISSUE 5 satellite)
# ---------------------------------------------------------------------------

@pytest.mark.tier1
def test_program_and_class_caches_clear_and_ignore_batch_size():
    from repro.kernels import ops

    clear_caches()
    n = 9
    e = V.bit_reverse(n) >> V.perm(Bmmc.random(n, random.Random(3)))
    f = compile_expr(e, engine="pallas")
    f(_payload((2, 1 << n), jnp.float32, 0), batched=True)   # warm
    before_prog = cache_stats()["program"]
    before_class = cache_stats()["class_plan"]
    assert before_prog.currsize > 0
    for bsz in (3, 4, 8, 16):
        f(_payload((bsz, 1 << n), jnp.float32, bsz), batched=True)
    after_prog = cache_stats()["program"]
    after_class = cache_stats()["class_plan"]
    assert after_prog.misses == before_prog.misses
    assert after_prog.currsize == before_prog.currsize
    assert after_class.currsize == before_class.currsize
    clear_caches()
    assert cache_stats()["program"].currsize == 0
    assert ops._class_plan_cached.cache_info().currsize == 0


@pytest.mark.tier1
def test_executable_matches_per_stage_path():
    """The whole-program executable and the stage-at-a-time dispatcher
    compute the same bits (the executable only removes host overhead)."""
    from repro.combinators.sort import sort_expr
    n = 7
    f = compile_expr(sort_expr(n), engine="pallas")
    x = _payload((1 << n,), jnp.float32, 5)
    _assert_bitwise(f(x), f.call_per_stage(x), "executable parity")
