"""Per-kernel correctness: Pallas tiled kernels vs the pure-jnp oracle.

Sweeps shapes / dtypes / permutation kinds and uses hypothesis for random
invertible matrices; every case asserts exact equality with ref.py
(permutations move data, they never compute, so equality is exact even for
floats).
"""
import random

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hyp_compat import given, settings, strategies as st

from repro.core.bmmc import Bmmc
from repro.core.tiling import plan_bmmc, plan_tiled
from repro.kernels.bmmc_permute import copy_through_vmem, tiled_permute
from repro.kernels.ops import bmmc_permute, choose_tile, num_passes
from repro.kernels.ref import bmmc_ref, bmmc_ref_jnp


def _want(b, x):
    out = np.empty_like(np.asarray(x))
    xs = np.asarray(x)
    for i in range(xs.shape[0]):
        out[b.apply(i)] = xs[i]
    return out


KINDS = ("bitrev", "transpose", "reverse", "bpc", "bmmc")


def _make(kind, n, rng):
    return {"bitrev": lambda: Bmmc.bit_reverse(n),
            "transpose": lambda: Bmmc.matrix_transpose(n // 2, n - n // 2),
            "reverse": lambda: Bmmc.reverse_array(n),
            "bpc": lambda: Bmmc.random_bpc(n, rng),
            "bmmc": lambda: Bmmc.random(n, rng)}[kind]()


@pytest.mark.parametrize("kind", KINDS)
@pytest.mark.parametrize("n,t", [(6, 2), (8, 3), (10, 3), (12, 4), (13, 5)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16, jnp.int32])
def test_pallas_vs_ref(kind, n, t, dtype):
    rng = random.Random(n * 131 + t)
    b = _make(kind, n, rng)
    x = jnp.arange(1 << n).astype(dtype)
    got = np.asarray(bmmc_permute(x, b, t=t))
    assert np.array_equal(got, _want(b, x)), (kind, n, t)
    assert np.array_equal(got, np.asarray(bmmc_ref(x, b)))


@pytest.mark.parametrize("d", [2, 5, 8])
def test_pallas_rows_variant(d):
    """(2^n, d) leading-axis permutation — the tokens x features layout."""
    rng = random.Random(d)
    n = 9
    b = Bmmc.random(n, rng)
    x = jnp.arange((1 << n) * d, dtype=jnp.float32).reshape(1 << n, d)
    got = np.asarray(bmmc_permute(x, b, t=3))
    want = np.asarray(bmmc_ref(x, b))
    assert np.array_equal(got, want)


@given(st.integers(6, 12), st.integers(0, 10**6), st.integers(2, 4))
@settings(max_examples=25, deadline=None)
def test_pallas_random_bmmc_property(n, seed, t):
    if 2 * t > n:
        return
    b = Bmmc.random(n, random.Random(seed))
    x = jnp.arange(1 << n, dtype=jnp.float32)
    got = np.asarray(bmmc_permute(x, b, t=t))
    assert np.array_equal(got, np.asarray(bmmc_ref(x, b)))


def test_ref_jnp_cross_check():
    rng = random.Random(0)
    for n in (5, 9, 12):
        b = Bmmc.random(n, rng)
        x = jnp.arange(1 << n, dtype=jnp.int32)
        assert np.array_equal(np.asarray(bmmc_ref(x, b)),
                              np.asarray(bmmc_ref_jnp(x, b)))


def test_pass_counts():
    """BPC -> 1 pass; general BMMC -> <= 2 passes (paper §5.2/§6)."""
    rng = random.Random(1)
    assert num_passes(Bmmc.bit_reverse(12), 4) == 1
    assert num_passes(Bmmc.random_bpc(12, rng), 4) == 1
    for _ in range(5):
        assert num_passes(Bmmc.random(12, rng), 4) in (1, 2)


def test_small_array_fallback():
    """Tiny arrays use the ref gather (choose_tile None)."""
    assert choose_tile(1, 4) is None
    b = Bmmc.reverse_array(1)
    x = jnp.asarray([3.0, 7.0])
    assert np.array_equal(np.asarray(bmmc_permute(x, b)), [7.0, 3.0])


def test_identity_shortcut():
    b = Bmmc.identity(8)
    x = jnp.arange(256, dtype=jnp.float32)
    assert bmmc_permute(x, b) is x


def test_copy_kernel_identity():
    x = jnp.arange(1 << 12, dtype=jnp.float32)
    got = copy_through_vmem(x, rows_per_block=4, row_len=64)
    assert np.array_equal(np.asarray(got), np.asarray(x))


def test_dma_run_merging():
    """Contiguous tile rows are merged into multi-row DMA descriptors."""
    # transpose with row bits adjacent to the low bits: runs > 1
    b = Bmmc.matrix_transpose(6, 6)
    p = plan_tiled(b, 3)
    assert p is not None
    # in/out runs are powers of two and divide rows_per_tile
    assert p.rows_per_tile % p.in_run == 0
    assert p.rows_per_tile % p.out_run == 0
    # identity-like BPC: fully contiguous rows -> maximal runs
    ident_rows = plan_tiled(Bmmc.identity(10), 3)
    assert ident_rows.in_run == ident_rows.rows_per_tile
    assert ident_rows.out_run == ident_rows.rows_per_tile
