"""Per-arch smoke tests: reduced config, one forward/train/prefill/decode
step on CPU, asserting output shapes and the absence of NaNs."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, reduce_for_smoke
from repro.models import model as M
from repro.models.ssm import rglru, rglru_step, ssd_chunked, ssd_decode_step

B, S = 2, 16


def _batch(cfg, key):
    batch = {"tokens": jax.random.randint(key, (B, S), 0, cfg.vocab_size),
             "labels": jax.random.randint(key, (B, S), 0, cfg.vocab_size)}
    if cfg.is_encdec or cfg.family == "vlm":
        batch["src"] = jax.random.normal(key, (B, cfg.src_len, cfg.d_model),
                                         cfg.dtype)
    return batch


@pytest.mark.parametrize("arch", sorted(ARCHS))
def test_arch_smoke(arch):
    cfg = reduce_for_smoke(ARCHS[arch])
    key = jax.random.PRNGKey(0)
    params = M.init(cfg, key)
    batch = _batch(cfg, key)
    loss, parts = M.loss_fn(cfg, params, batch)
    assert np.isfinite(float(loss)), arch
    logits, caches = M.prefill(cfg, params, batch)
    assert logits.shape == (B, 1, cfg.vocab_size)
    assert np.isfinite(np.asarray(logits, dtype=np.float32)).all()
    tok = jnp.zeros((B, 1), jnp.int32)
    logits2, caches2 = M.decode_step(cfg, params, caches, tok, jnp.int32(S - 1))
    assert logits2.shape == (B, 1, cfg.vocab_size)
    assert np.isfinite(np.asarray(logits2, dtype=np.float32)).all()
    assert jax.tree.structure(caches) == jax.tree.structure(caches2)


@pytest.mark.parametrize("arch", sorted(ARCHS))
def test_arch_train_step(arch):
    from repro.train.step import make_train_step, init_opt
    cfg = reduce_for_smoke(ARCHS[arch])
    key = jax.random.PRNGKey(1)
    params = M.init(cfg, key)
    opt_state = init_opt(cfg, params)
    step_fn, _ = make_train_step(cfg)
    batch = _batch(cfg, key)
    new_params, new_state, metrics = jax.jit(step_fn)(params, opt_state, batch)
    assert np.isfinite(float(metrics["loss"]))
    assert np.isfinite(float(metrics["grad_norm"]))
    assert int(new_state.step) == 1
    # parameters actually moved
    moved = any(
        not np.array_equal(np.asarray(a, dtype=np.float32),
                           np.asarray(b, dtype=np.float32))
        for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(new_params)))
    assert moved, arch


def test_decode_matches_prefill_continuation():
    """Greedy continuation: prefill(x[:t]) + decode(x[t]) == prefill(x[:t+1]).

    Run on a dense arch (exact cache semantics) in f32.
    """
    cfg = reduce_for_smoke(ARCHS["mistral-nemo-12b"])
    key = jax.random.PRNGKey(2)
    params = M.init(cfg, key)
    toks = jax.random.randint(key, (B, S), 0, cfg.vocab_size)
    # full prefill logits at last position
    full_logits, _ = M.prefill(cfg, params, {"tokens": toks})
    # prefill on S-1, then decode token S-1
    short = {"tokens": toks[:, :S - 1]}
    _, caches = M.prefill(cfg, params, short)
    caches = M.grow_caches(caches, S - 1, S)
    dec_logits, _ = M.decode_step(cfg, params, caches, toks[:, S - 1:S],
                                  jnp.int32(S - 1))
    np.testing.assert_allclose(np.asarray(dec_logits), np.asarray(full_logits),
                               rtol=2e-4, atol=2e-4)


@pytest.mark.parametrize("arch", ["mamba2-130m", "recurrentgemma-2b",
                                  "phi3.5-moe-42b-a6.6b"])
def test_decode_continuation_stateful_archs(arch):
    """prefill(x[:t]) + decode(x[t]) == prefill(x[:t+1]) for SSM/hybrid/MoE.

    Exercises the SSD state carry, RG-LRU hidden state, conv-tail states and
    windowed-attention caches — the families with nontrivial decode state.
    """
    cfg = reduce_for_smoke(ARCHS[arch])
    key = jax.random.PRNGKey(11)
    params = M.init(cfg, key)
    toks = jax.random.randint(key, (B, S), 0, cfg.vocab_size)
    full_logits, _ = M.prefill(cfg, params, {"tokens": toks})
    _, caches = M.prefill(cfg, params, {"tokens": toks[:, :S - 1]})
    caches = M.grow_caches(caches, S - 1, S)
    dec_logits, _ = M.decode_step(cfg, params, caches, toks[:, S - 1:S],
                                  jnp.int32(S - 1))
    np.testing.assert_allclose(np.asarray(dec_logits),
                               np.asarray(full_logits),
                               rtol=5e-3, atol=5e-3)


def test_ssd_equals_sequential_recurrence():
    """Chunked SSD == step-by-step recurrence (state-space duality)."""
    key = jax.random.PRNGKey(3)
    b, l, h, p, n, g = 2, 32, 4, 8, 16, 1
    x = jax.random.normal(key, (b, l, h, p))
    dt_a = -jnp.abs(jax.random.normal(jax.random.PRNGKey(4), (b, l, h))) * 0.1
    bb = jax.random.normal(jax.random.PRNGKey(5), (b, l, g, n))
    cc = jax.random.normal(jax.random.PRNGKey(6), (b, l, g, n))
    y_chunk, final = ssd_chunked(x, dt_a, bb, cc, chunk=8,
                                 return_final_state=True)
    state = jnp.zeros((b, h, p, n), jnp.float32)
    ys = []
    for t in range(l):
        state, y = ssd_decode_step(state, x[:, t], dt_a[:, t], bb[:, t], cc[:, t])
        ys.append(y)
    y_seq = jnp.stack(ys, axis=1)
    np.testing.assert_allclose(np.asarray(y_chunk), np.asarray(y_seq),
                               rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(final), np.asarray(state),
                               rtol=1e-4, atol=1e-4)


def test_rglru_scan_equals_step():
    key = jax.random.PRNGKey(7)
    b, l, d = 2, 16, 8
    x = jax.random.normal(key, (b, l, d))
    ga = jax.random.normal(jax.random.PRNGKey(8), (b, l, d))
    gx = jax.random.normal(jax.random.PRNGKey(9), (b, l, d))
    ap = jax.random.normal(jax.random.PRNGKey(10), (d,))
    y_scan, h_last = rglru(x, ga, gx, ap)
    h = jnp.zeros((b, d), jnp.float32)
    ys = []
    for t in range(l):
        h, y = rglru_step(h, x[:, t], ga[:, t], gx[:, t], ap)
        ys.append(y)
    np.testing.assert_allclose(np.asarray(y_scan), np.asarray(jnp.stack(ys, 1)),
                               rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(np.asarray(h_last), np.asarray(h),
                               rtol=1e-5, atol=1e-5)
