"""Engine-parity fuzz: "ref" vs "pallas" (interpret mode) agreement.

Randomized BMMCs × dtypes (int32 / float32 / bfloat16) × trailing dims ×
tile geometries × batch sizes. A permutation moves values without
arithmetic, so agreement must be bit-exact in every dtype. Also pins the
batched-execution contracts: vmap fallback for 2-arg engines, and a
geometry cache that does not grow with the batch size.
"""
import random

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hyp_compat import given, settings, strategies as st

from repro.combinators import cache_stats, clear_caches, compile_expr
from repro.combinators import vocab as V
from repro.core.bmmc import Bmmc
from repro.kernels.ops import bmmc_permute
from repro.kernels.ref import bmmc_ref

DTYPES = (jnp.int32, jnp.float32, jnp.bfloat16)


@pytest.fixture(autouse=True, scope="module")
def _bounded_caches():
    """This module sweeps many tile geometries; drop the pinned jitted
    executables when the sweep is done (ISSUE 4 satellite)."""
    yield
    clear_caches()


def _payload(shape, dtype, seed):
    vals = np.random.default_rng(seed).integers(0, 1 << 16, shape)
    return jnp.asarray(vals).astype(dtype)


def _assert_same(got, want, ctx):
    assert got.dtype == want.dtype, ctx
    assert np.array_equal(np.asarray(got).view(np.uint8),
                          np.asarray(want).view(np.uint8)), ctx


@pytest.mark.tier1
@given(st.integers(4, 8), st.integers(0, 10**6))
@settings(max_examples=8, deadline=None)
def test_engine_parity_unbatched(n, seed):
    rng = random.Random(seed)
    b = Bmmc.random(n, rng) if seed % 2 else Bmmc.random_bpc(n, rng)
    t = rng.choice([None, 2, min(3, n // 2)])
    dtype = DTYPES[seed % len(DTYPES)]
    tail = rng.choice([(), (2,), (3,)])
    x = _payload((1 << n,) + tail, dtype, seed)
    got = bmmc_permute(x, b, t=t, engine="pallas")
    want = bmmc_ref(x, b)
    _assert_same(got, want, (n, seed, t, dtype, tail))


@pytest.mark.tier1
@given(st.integers(4, 8), st.integers(0, 10**6), st.integers(1, 5))
@settings(max_examples=8, deadline=None)
def test_engine_parity_batched(n, seed, bsz):
    """Batched pallas pass == per-row ref gather, any dtype/tail/tile."""
    rng = random.Random(seed)
    b = Bmmc.random(n, rng) if seed % 2 else Bmmc.random_bpc(n, rng)
    t = rng.choice([None, 2, min(3, n // 2)])
    dtype = DTYPES[seed % len(DTYPES)]
    tail = rng.choice([(), (3,)])
    x = _payload((bsz, 1 << n) + tail, dtype, seed)
    got = bmmc_permute(x, b, t=t, engine="pallas", batched=True)
    want = jnp.stack([bmmc_ref(x[i], b) for i in range(bsz)])
    _assert_same(got, want, (n, seed, bsz, t, dtype, tail))


@pytest.mark.tier1
def test_batched_matches_vmap_of_unbatched():
    """The native batched path == jax.vmap of the unbatched ref path."""
    rng = random.Random(7)
    b = Bmmc.random(7, rng)
    x = _payload((6, 128), jnp.float32, 7)
    native = bmmc_ref(x, b, batched=True)
    vmapped = jax.vmap(lambda r: bmmc_ref(r, b))(x)
    _assert_same(native, vmapped, "vmap parity")


@pytest.mark.tier1
def test_injected_engine_vmap_fallback():
    """A legacy (x, bmmc) engine is transparently vmapped when batched."""
    calls = []

    def legacy(x, bmmc):
        calls.append(x.shape)
        assert x.ndim <= 2  # must only ever see unbatched slices
        return bmmc_ref(x, bmmc)

    n = 6
    e = V.riffle(n) >> V.bit_reverse(n)
    f = compile_expr(e, engine=legacy)
    x = _payload((3, 1 << n), jnp.float32, 0)
    got = f(x, batched=True)
    want = compile_expr(e, engine="ref")(x, batched=True)
    _assert_same(got, want, "fallback parity")
    assert calls, "legacy engine was never invoked"


@pytest.mark.tier1
def test_geometry_cache_constant_in_batch():
    """ISSUE 2 acceptance: growing B adds no geometry-cache entries."""
    n = 9
    e = V.bit_reverse(n) >> V.perm(Bmmc.random(n, random.Random(3)))
    f = compile_expr(e, engine="pallas")
    f(_payload((2, 1 << n), jnp.float32, 0), batched=True)  # warm
    before = cache_stats()["geom"]
    for bsz in (3, 4, 8, 16):
        f(_payload((bsz, 1 << n), jnp.float32, bsz), batched=True)
    after = cache_stats()["geom"]
    assert after.misses == before.misses, (before, after)
    assert after.currsize == before.currsize


@pytest.mark.tier1
def test_batched_roundtrip_through_tiled_kernels():
    """(B, 2^n) through a compiled program and its inverse is identity."""
    n = 9
    rng = random.Random(11)
    e = V.perm(Bmmc.random(n, rng)) >> V.riffle(n)
    f = compile_expr(e, engine="pallas")
    finv = f.inverse(n)
    x = _payload((4, 1 << n), jnp.float32, 5)
    _assert_same(finv(f(x, batched=True), batched=True), x, "roundtrip")
