"""Data pipeline (BMMC shuffle) and checkpoint/restore fault tolerance."""
import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import ckpt
from repro.data.pipeline import DataConfig, ShardedLoader, epoch_bmmc


def test_epoch_shuffle_is_permutation():
    cfg = DataConfig(n_samples_log2=10, seq_len=8, vocab_size=64, seed=3)
    b = epoch_bmmc(cfg, epoch=0)
    seen = {b.apply(i) for i in range(1 << 10)}
    assert len(seen) == 1 << 10
    # different epochs -> different shuffles
    b1 = epoch_bmmc(cfg, epoch=1)
    assert b.rows != b1.rows or b.c != b1.c


def test_loader_deterministic_and_resumable():
    cfg = DataConfig(n_samples_log2=8, seq_len=16, vocab_size=64, seed=1)
    l1 = ShardedLoader(cfg, batch_size=4)
    batches = [next(l1) for _ in range(5)]
    # restore from state after 3 batches reproduces batches 4,5 exactly
    l2 = ShardedLoader(cfg, batch_size=4)
    for _ in range(3):
        next(l2)
    state = l2.state()
    l3 = ShardedLoader(cfg, batch_size=4)
    l3.restore(state)
    for want_i in (3, 4):
        got = next(l3)
        assert np.array_equal(got["tokens"], batches[want_i]["tokens"])


def test_loader_shards_disjoint():
    cfg = DataConfig(n_samples_log2=8, seq_len=4, vocab_size=64, seed=2)
    a = ShardedLoader(cfg, batch_size=128, host_id=0, n_hosts=2)
    b = ShardedLoader(cfg, batch_size=128, host_id=1, n_hosts=2)
    ta, tb = next(a)["tokens"], next(b)["tokens"]
    # shards read different samples (overwhelmingly likely to differ)
    assert not np.array_equal(ta, tb)


def test_checkpoint_roundtrip_and_integrity():
    tree = {"w": jnp.arange(12.0).reshape(3, 4),
            "opt": {"m": jnp.ones((5,)), "n": jnp.zeros((2, 2))}}
    with tempfile.TemporaryDirectory() as d:
        ckpt.save(d, 7, tree, extra_state={"loader": {"epoch": 1}})
        assert ckpt.latest_step(d) == 7
        restored, extra = ckpt.restore(d, 7, tree)
        assert extra["loader"]["epoch"] == 1
        for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(restored)):
            assert np.array_equal(np.asarray(a), np.asarray(b))
        # corrupt a leaf -> integrity failure
        import numpy as _np
        path = os.path.join(d, "step_00000007", "arrays.npz")
        data = dict(_np.load(path))
        data["w"] = data["w"] + 1
        _np.savez(path, **data)
        with pytest.raises(IOError):
            ckpt.restore(d, 7, tree)


def test_checkpoint_prunes_old():
    tree = {"w": jnp.zeros((2,))}
    with tempfile.TemporaryDirectory() as d:
        for s in (1, 2, 3, 4, 5):
            ckpt.save(d, s, tree, keep_last=2)
        steps = sorted(x for x in os.listdir(d) if x.startswith("step_"))
        assert len(steps) == 2 and ckpt.latest_step(d) == 5


_KILL_WRITER = """
import sys
import numpy as np
from repro.checkpoint import ckpt

d = sys.argv[1]
tree = {"w": np.arange(1 << 16, dtype=np.float32),
        "opt": {"m": np.ones((1 << 14,), np.float32)}}
print("ready", flush=True)
step = 0
while True:
    step += 1
    ckpt.save(d, step, tree, keep_last=1_000_000)
"""


@pytest.mark.slow
def test_checkpoint_survives_kill_mid_write():
    """SIGKILL a process mid-``ckpt.save`` loop: every *published*
    ``step_*`` directory must restore cleanly (the tmp + fsync +
    os.replace discipline means a torn write can only ever be an
    invisible ``.tmp_ckpt_*`` orphan, never a corrupt step)."""
    import signal
    import subprocess
    import sys
    import time

    tree = {"w": np.arange(1 << 16, dtype=np.float32),
            "opt": {"m": np.ones((1 << 14,), np.float32)}}
    with tempfile.TemporaryDirectory() as d:
        proc = subprocess.Popen(
            [sys.executable, "-c", _KILL_WRITER, d],
            stdout=subprocess.PIPE, stderr=subprocess.DEVNULL, text=True)
        try:
            assert proc.stdout.readline().strip() == "ready"
            # let it race through a few saves, then kill at a random
            # instant (mid-write with high probability)
            time.sleep(1.0)
            proc.send_signal(signal.SIGKILL)
        finally:
            proc.kill()
            proc.wait()
        published = sorted(x for x in os.listdir(d)
                           if x.startswith("step_"))
        assert published, "writer never published a checkpoint"
        for name in published:
            step = int(name.split("_")[1])
            restored, _ = ckpt.restore(d, step, tree)
            for a, b in zip(jax.tree.leaves(tree),
                            jax.tree.leaves(restored)):
                assert np.array_equal(np.asarray(a), np.asarray(b))
