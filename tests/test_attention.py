"""Blockwise attention vs naive reference; decode vs full recompute."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models.attention import attention, decode_attention


def naive_attention(q, k, v, *, causal=True, window=None):
    b, sq, h, d = q.shape
    _, skv, kvh, _ = k.shape
    g = h // kvh
    qg = q.reshape(b, sq, kvh, g, d)
    s = jnp.einsum("bqkgd,bckd->bkgqc", qg, k).astype(jnp.float32) / np.sqrt(d)
    if causal:
        qpos = jnp.arange(sq)[:, None]
        kpos = jnp.arange(skv)[None, :]
        ok = kpos <= qpos
        if window is not None:
            ok &= kpos > qpos - window
        s = jnp.where(ok, s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgqc,bckd->bkgqd", p.astype(q.dtype), v)
    return o.transpose(0, 3, 1, 2, 4).reshape(b, sq, h, d)


@pytest.mark.parametrize("h,kv", [(4, 4), (8, 2), (6, 1)])
@pytest.mark.parametrize("kv_block", [8, 16, 64])
def test_blockwise_matches_naive(h, kv, kv_block):
    key = jax.random.PRNGKey(0)
    b, s, d = 2, 64, 16
    q = jax.random.normal(key, (b, s, h, d), jnp.float32)
    k = jax.random.normal(jax.random.PRNGKey(1), (b, s, kv, d), jnp.float32)
    v = jax.random.normal(jax.random.PRNGKey(2), (b, s, kv, d), jnp.float32)
    got = attention(q, k, v, kind="causal", kv_block=kv_block)
    want = naive_attention(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


def test_windowed_matches_naive():
    key = jax.random.PRNGKey(3)
    b, s, h, d, w = 1, 64, 2, 8, 16
    q = jax.random.normal(key, (b, s, h, d))
    k = jax.random.normal(jax.random.PRNGKey(4), (b, s, h, d))
    v = jax.random.normal(jax.random.PRNGKey(5), (b, s, h, d))
    got = attention(q, k, v, kind="causal", window=w, kv_block=16)
    want = naive_attention(q, k, v, causal=True, window=w)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


def test_full_cross_matches_naive():
    key = jax.random.PRNGKey(6)
    b, sq, skv, h, d = 2, 16, 40, 4, 8  # skv not a multiple of the block
    q = jax.random.normal(key, (b, sq, h, d))
    k = jax.random.normal(jax.random.PRNGKey(7), (b, skv, h, d))
    v = jax.random.normal(jax.random.PRNGKey(8), (b, skv, h, d))
    got = attention(q, k, v, kind="full", kv_block=16)
    want = naive_attention(q, k, v, causal=False)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


def test_decode_matches_full_recompute():
    """decode at position t == row t of full causal attention."""
    key = jax.random.PRNGKey(9)
    b, s, h, kv, d = 2, 32, 4, 2, 8
    q = jax.random.normal(key, (b, s, h, d))
    k = jax.random.normal(jax.random.PRNGKey(10), (b, s, kv, d))
    v = jax.random.normal(jax.random.PRNGKey(11), (b, s, kv, d))
    full = attention(q, k, v, kind="causal", kv_block=8)
    t = s - 1
    dec = decode_attention(q[:, t:t + 1], k, v, length=t + 1)
    np.testing.assert_allclose(np.asarray(dec[:, 0]), np.asarray(full[:, t]),
                               rtol=2e-5, atol=2e-5)


def test_decode_window():
    key = jax.random.PRNGKey(12)
    b, s, h, d, w = 1, 32, 2, 8, 8
    q = jax.random.normal(key, (b, s, h, d))
    k = jax.random.normal(jax.random.PRNGKey(13), (b, s, h, d))
    v = jax.random.normal(jax.random.PRNGKey(14), (b, s, h, d))
    full = attention(q, k, v, kind="causal", window=w, kv_block=8)
    t = s - 1
    dec = decode_attention(q[:, t:t + 1], k, v, length=t + 1, window=w)
    np.testing.assert_allclose(np.asarray(dec[:, 0]), np.asarray(full[:, t]),
                               rtol=2e-5, atol=2e-5)
