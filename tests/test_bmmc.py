"""BMMC semantics, classification, and the §5.2 two-pass factorization."""
import random

import numpy as np
import pytest
from _hyp_compat import given, settings, strategies as st

from repro.core import f2
from repro.core.bmmc import Bmmc


def ref_perm(b: Bmmc, xs):
    out = [None] * len(xs)
    for x, v in enumerate(xs):
        out[b.apply(x)] = v
    return out


@given(st.integers(2, 12), st.integers(0, 10**6))
@settings(max_examples=50, deadline=None)
def test_is_permutation(n, seed):
    b = Bmmc.random(n, random.Random(seed))
    xs = list(range(1 << n))
    ys = ref_perm(b, xs)
    assert sorted(ys) == xs


@given(st.integers(2, 12), st.integers(0, 10**6))
@settings(max_examples=50, deadline=None)
def test_inverse_compose(n, seed):
    rng = random.Random(seed)
    b = Bmmc.random(n, rng)
    xs = list(range(1 << n))
    assert ref_perm(b.inverse(), ref_perm(b, xs)) == xs
    b2 = Bmmc.random(n, rng)
    assert ref_perm(b2 @ b, xs) == ref_perm(b2, ref_perm(b, xs))


@given(st.integers(4, 12), st.integers(0, 10**6), st.integers(2, 5))
@settings(max_examples=60, deadline=None)
def test_factor_tiled_two_passes(n, seed, t):
    """Any BMMC = at most two tiled BMMCs (paper §5.2), each tiled."""
    if 2 * t > n:
        return
    b = Bmmc.random(n, random.Random(seed))
    fs = b.factor_tiled(t)
    assert 1 <= len(fs) <= 2
    for fac in fs:
        assert fac.is_tiled(t)
    xs = list(range(1 << n))
    cur = xs
    for fac in fs:
        cur = ref_perm(fac, cur)
    assert cur == ref_perm(b, xs)


@given(st.integers(4, 12), st.integers(0, 10**6), st.integers(2, 5))
@settings(max_examples=30, deadline=None)
def test_bpc_always_tiled(n, seed, t):
    """BPCs are tiled for every tile size (paper §5.1)."""
    if t > n:
        return
    b = Bmmc.random_bpc(n, random.Random(seed))
    assert b.is_tiled(t)
    cols = b.tiled_columns(t)
    p = b.perm()
    assert sorted(cols) == sorted(j for j in range(n) if p[j] < t)


def test_paper_examples():
    # 4x4 matrix transpose (paper §3): y_i = x_{(i+2) % 4}
    tr = Bmmc.matrix_transpose(2, 2)
    assert tr.perm() == [(i + 2) % 4 for i in range(4)]
    # bit reversal: y_i = x_{n-1-i}
    br = Bmmc.bit_reverse(4)
    assert br.apply(0b0111) == 0b1110
    # array reversal: identity matrix, c = 1...1
    rv = Bmmc.reverse_array(4)
    assert rv.apply(0) == 15 and rv.apply(5) == 10
    assert rv.is_bpc() and not rv.is_bp()


def test_classification():
    assert Bmmc.bit_reverse(5).is_bp()
    assert not Bmmc.reverse_array(5).is_bp()
    assert Bmmc.reverse_array(5).is_bpc()
    rng = random.Random(3)
    # random dense BMMC is almost surely not a BPC
    b = Bmmc.random(10, rng)
    assert b.perm() is None or b.is_bpc()


def test_singular_rejected():
    with pytest.raises(f2.SingularError):
        Bmmc((1, 1), 0)  # duplicate rows: singular
