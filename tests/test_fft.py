"""Radix-2 FFT workload on the combinator IR, vs jnp.fft / np.fft."""
import numpy as np
import jax.numpy as jnp
import pytest

from repro.combinators import fuse, lower, num_perm_stages
from repro.combinators.fft import (compiled_fft, fft, fft_expr, from_planar,
                                   to_planar)


def _rand_complex(n, seed=0):
    rng = np.random.default_rng(seed)
    return (rng.standard_normal(1 << n)
            + 1j * rng.standard_normal(1 << n)).astype(np.complex64)


@pytest.mark.parametrize("n", [0, 1, 2, 3, 5, 8, 10])
def test_fft_matches_jnp_fft(n):
    x = _rand_complex(n, seed=n)
    got = np.asarray(fft(jnp.asarray(x)))
    want = np.asarray(jnp.fft.fft(jnp.asarray(x)))
    scale = max(1.0, np.abs(want).max())
    assert np.abs(got - want).max() / scale < 1e-4


def test_fft_planar_layout_roundtrip():
    n = 6
    x = _rand_complex(n, seed=1)
    xp = to_planar(jnp.asarray(x))
    assert xp.shape == (1 << n, 2) and xp.dtype == jnp.float32
    back = np.asarray(from_planar(xp))
    assert np.allclose(back, x, atol=1e-6)


def test_fft_fusion_strictly_reduces_perm_stages():
    n = 9
    raw = lower(fft_expr(n), n)
    fz = fuse(raw)
    assert num_perm_stages(fz) < num_perm_stages(raw)
    # n butterflies survive; at most one Perm between consecutive ones
    from repro.combinators.ir import Bfly
    assert sum(isinstance(s, Bfly) for s in fz) == n


@pytest.mark.slow
def test_fft_through_pallas_engine():
    """ISSUE 1 acceptance: FFT whose reorderings run as tiled Pallas
    kernels (planar (re, im) layout) matches the reference to 1e-4."""
    n = 10
    x = _rand_complex(n, seed=3)
    f = compiled_fft(n, engine="pallas")
    got = np.asarray(from_planar(f(to_planar(jnp.asarray(x)))))
    want = np.fft.fft(x)
    assert np.abs(got - want).max() / np.abs(want).max() < 1e-4


def test_fft_linearity_and_impulse():
    n = 5
    imp = np.zeros(1 << n, np.complex64)
    imp[0] = 1.0
    got = np.asarray(fft(jnp.asarray(imp)))
    assert np.allclose(got, np.ones(1 << n), atol=1e-5)  # delta -> flat
    x, y = _rand_complex(n, 4), _rand_complex(n, 5)
    fxy = np.asarray(fft(jnp.asarray(x + y)))
    fx = np.asarray(fft(jnp.asarray(x)))
    fy = np.asarray(fft(jnp.asarray(y)))
    assert np.abs(fxy - (fx + fy)).max() < 1e-4 * max(1.0, np.abs(fxy).max())
