"""Durable plan store (DESIGN.md §15).

Covers the crash-safety contract end to end: entry codec round trips
(class + fused payloads, reserved measured-cost slot), the corruption
matrix (truncated / bit-flipped / version-skewed / torn entries →
quarantine-or-skew-miss + replan, bitwise parity with fresh planning,
zero silent wrong outputs), a two-process persistence round trip
(phase B compiles zero plans), concurrent reader/writer fuzz, the
quarantine race resolving exactly once, and the bounded identity
memos' eviction + ``cache_stats`` surfacing.
"""
from __future__ import annotations

import os
import subprocess
import sys
import threading

import jax.numpy as jnp
import numpy as np
import pytest

from repro import guard, store
from repro.combinators.execute import (cache_stats, clear_caches,
                                       compile_expr)
from repro.combinators import vocab as V
from repro.combinators.sort import sort_expr
from repro.core.bmmc import Bmmc
from repro.guard import inject
from repro.guard.validate import IdentityMemo, plan_fingerprint
from repro.kernels import ops, ref
from repro.store import codec


@pytest.fixture()
def tmp_store(tmp_path):
    """A configured throwaway store; restores the prior configuration
    (env-default or none) afterwards so tests are hermetic."""
    prev = store.active()
    st = store.configure(str(tmp_path / "planstore"))
    store.reset_stats()
    clear_caches()
    yield st
    clear_caches()
    store.configure(prev.root if prev is not None else None)


def _plan_key(n: int) -> tuple:
    b = Bmmc.bit_reverse(n)
    t = ops.choose_tile(n, 4)
    return b, t, store.class_key(b.rows, b.c, t)


# ---------------------------------------------------------------------------
# codec round trips
# ---------------------------------------------------------------------------

@pytest.mark.tier1
def test_entry_roundtrip_class_plan(tmp_store):
    n = 8
    b, t, key = _plan_key(n)
    kernel, payload = ops._build_class_plan(b.rows, b.c, t)
    meta, arrays = codec.encode_class_payload(kernel, payload)
    assert tmp_store.put(key, "class", meta, arrays)
    header, loaded = tmp_store.get(key)
    k2, p2 = codec.decode_class_payload(header["meta"], loaded)
    assert k2 == kernel
    assert plan_fingerprint(k2, p2) == plan_fingerprint(kernel, payload)
    # the reserved autotune slot exists, is empty, and survives rewrite
    assert header["measured_cost"] is None
    assert tmp_store.annotate_cost(key, {"us": 12.5, "t": t})
    header2, _ = tmp_store.get(key)
    assert header2["measured_cost"] == {"us": 12.5, "t": t}


@pytest.mark.tier1
def test_loaded_arrays_are_writable_copies(tmp_store):
    n = 8
    b, t, key = _plan_key(n)
    kernel, payload = ops._build_class_plan(b.rows, b.c, t)
    meta, arrays = codec.encode_class_payload(kernel, payload)
    tmp_store.put(key, "class", meta, arrays)
    _, loaded = tmp_store.get(key)
    for arr in loaded.values():
        arr.flat[0] = arr.flat[0]  # would raise on a read-only view


@pytest.mark.tier1
def test_store_backed_plans_bitwise_equal_fresh(tmp_store):
    """A plan decoded from disk is bitwise the plan a fresh planner
    builds — the parity that makes warm-start behavior-preserving."""
    n = 8
    b, t, _ = _plan_key(n)
    fresh = ops._build_class_plan(b.rows, b.c, t)
    ops._class_plan_cached(b.rows, b.c, t)      # build + write
    ops._class_plan_cached.cache_clear()
    loaded = ops._class_plan_cached(b.rows, b.c, t)  # disk hit
    assert store.stats()["hit"] >= 1
    assert loaded[0] == fresh[0]
    assert plan_fingerprint(*loaded) == plan_fingerprint(*fresh)


# ---------------------------------------------------------------------------
# warm boot: zero plans compiled, end-to-end parity
# ---------------------------------------------------------------------------

@pytest.mark.tier1
def test_warm_boot_compiles_zero_plans(tmp_store):
    n = 8
    x = jnp.asarray(np.random.default_rng(0).standard_normal(1 << n),
                    dtype=jnp.float32)
    y0 = np.asarray(compile_expr(sort_expr(n))(x))
    cold = store.stats()
    assert cold["plan_built"] > 0 and cold["write"] == cold["plan_built"]
    clear_caches()  # fresh process modulo the disk
    y1 = np.asarray(compile_expr(sort_expr(n))(x))
    warm = store.stats()
    assert np.array_equal(y0, y1)
    assert warm["plan_built"] == 0, "warm boot replanned"
    assert warm["miss"] == 0 and warm["hit"] == cold["plan_built"]


# ---------------------------------------------------------------------------
# corruption matrix
# ---------------------------------------------------------------------------

@pytest.mark.tier1
@pytest.mark.parametrize("kind,mode", [
    ("disk_truncate", "truncate"), ("disk_bitflip", "bitflip"),
    ("disk_version_skew", "skew"), ("disk_torn_write", "torn")])
def test_corruption_matrix(tmp_store, kind, mode):
    n = 6
    b, t, key = _plan_key(n)
    x = jnp.arange(1 << n, dtype=jnp.float32)
    oracle = np.asarray(ref.bmmc_ref(x, b))
    ce = compile_expr(V.bit_reverse(n), optimize=False)
    ce(x)  # populate
    base = store.stats()
    gbase = guard.stats()
    with inject.corrupt_store_entry(tmp_store, key, mode):
        inject._clear_replan_path()
        y = ce(x)
    assert np.array_equal(np.asarray(y), oracle), "SILENT WRONG OUTPUT"
    now = store.stats()
    if mode == "skew":
        assert now["version_skew"] > base["version_skew"]
        assert now["quarantined"] == base["quarantined"]
    else:
        assert now["corrupt"] > base["corrupt"]
        assert now["quarantined"] == base["quarantined"] + 1
        # quarantine mirrors into the guard report
        gnow = guard.stats()
        assert (sum(gnow["store_quarantined"].values())
                == sum(gbase["store_quarantined"].values()) + 1)
        assert tmp_store.quarantined_count() >= 1
    assert now["plan_built"] > base["plan_built"], "no replan happened"


@pytest.mark.tier1
def test_full_disk_fault_matrix():
    r = inject.run_disk_fault_matrix()
    assert r["caught"] == r["injected"] == len(inject.STORE_FAULT_KINDS), \
        r["cases"]


@pytest.mark.tier1
def test_quarantine_race_resolves_once(tmp_store):
    n = 6
    b, t, key = _plan_key(n)
    ops._class_plan_cached(b.rows, b.c, t)
    fresh = ops._build_class_plan(b.rows, b.c, t)
    base = store.stats()
    with inject.corrupt_store_entry(tmp_store, key, "bitflip"):
        results, errs = [], []

        def reader():
            try:
                results.append(store.class_plan_through(
                    b.rows, b.c, t,
                    lambda: ops._build_class_plan(b.rows, b.c, t)))
            except BaseException as e:  # noqa: BLE001
                errs.append(e)

        threads = [threading.Thread(target=reader) for _ in range(6)]
        for th in threads:
            th.start()
        for th in threads:
            th.join()
    assert not errs
    now = store.stats()
    assert now["quarantined"] - base["quarantined"] == 1
    want = plan_fingerprint(*fresh)
    assert all(plan_fingerprint(*r) == want for r in results)


# ---------------------------------------------------------------------------
# wrong-key / cross-matrix defense
# ---------------------------------------------------------------------------

@pytest.mark.tier1
def test_valid_plan_under_wrong_key_is_refused(tmp_store):
    """A bitwise-intact entry copied under another key (hash collision /
    tampering) must not pass: the header key check + ring-1 audit tie
    the payload to the key's matrix."""
    n = 8
    b, t, key = _plan_key(n)
    ops._class_plan_cached(b.rows, b.c, t)
    other = Bmmc.reverse_array(n)
    other_key = store.class_key(other.rows, other.c, t)
    data = tmp_store.read_bytes(key)
    tmp_store.write_bytes(other_key, data)
    base = store.stats()
    got = store.class_plan_through(
        other.rows, other.c, t,
        lambda: ops._build_class_plan(other.rows, other.c, t))
    now = store.stats()
    assert now["quarantined"] > base["quarantined"]
    assert plan_fingerprint(*got) == plan_fingerprint(
        *ops._build_class_plan(other.rows, other.c, t))


# ---------------------------------------------------------------------------
# concurrency fuzz
# ---------------------------------------------------------------------------

@pytest.mark.tier1
def test_concurrent_reader_writer_fuzz(tmp_store):
    """Readers racing one writer over the same key never see a torn
    entry: every get() is either a miss or a complete, checksummed
    entry (rename atomicity)."""
    n = 8
    b, t, key = _plan_key(n)
    kernel, payload = ops._build_class_plan(b.rows, b.c, t)
    meta, arrays = codec.encode_class_payload(kernel, payload)
    stop = threading.Event()
    bad: list = []

    def writer():
        while not stop.is_set():
            assert tmp_store.put(key, "class", meta, arrays)

    def reader():
        while not stop.is_set():
            try:
                got = tmp_store.get(key)
            except (codec.EntryCorrupt, codec.EntrySkew) as e:
                bad.append(e)
                return
            if got is not None:
                k2, p2 = codec.decode_class_payload(got[0]["meta"], got[1])
                if plan_fingerprint(k2, p2) != plan_fingerprint(
                        kernel, payload):
                    bad.append("fingerprint drift")
                    return

    threads = [threading.Thread(target=writer)] + [
        threading.Thread(target=reader) for _ in range(4)]
    for th in threads:
        th.start()
    import time
    time.sleep(1.0)
    stop.set()
    for th in threads:
        th.join()
    assert not bad, bad


# ---------------------------------------------------------------------------
# two-process persistence round trip
# ---------------------------------------------------------------------------

_PHASE_SCRIPT = r"""
import sys, numpy as np
import jax.numpy as jnp
from repro import store
from repro.combinators.execute import compile_expr
from repro.combinators.sort import sort_expr

store.configure(sys.argv[1])
n = 8
x = jnp.asarray(np.random.default_rng(0).standard_normal(1 << n),
                dtype=jnp.float32)
y = np.asarray(compile_expr(sort_expr(n))(x))
np.save(sys.argv[3], y)
s = store.stats()
if sys.argv[2] == "B":
    assert s["plan_built"] == 0, f"phase B compiled plans: {s}"
    assert s["miss"] == 0 and s["hit"] > 0, f"phase B not 100% disk-hit: {s}"
else:
    assert s["plan_built"] > 0 and s["write"] > 0, s
print("OK", s["hit"], s["plan_built"])
"""


@pytest.mark.slow
def test_two_process_persistence_roundtrip(tmp_path):
    root = str(tmp_path / "planstore")
    env = {"PYTHONPATH": "src", "PATH": "/usr/bin:/bin", "HOME": "/root",
           # JAX_PLATFORMS=cpu: without it a scrubbed env lets jax
           # probe real accelerator backends (PR 8: baked-in libtpu
           # stalls ~8 min) and the probe alone blows the timeout
           "JAX_PLATFORMS": "cpu"}
    outs = []
    for phase in ("A", "B"):
        out_npy = str(tmp_path / f"y_{phase}.npy")
        r = subprocess.run(
            [sys.executable, "-c", _PHASE_SCRIPT, root, phase, out_npy],
            capture_output=True, text=True, env=env, timeout=500,
            cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))))
        assert r.returncode == 0, (phase, r.stderr[-3000:])
        assert "OK" in r.stdout
        outs.append(np.load(out_npy))
    assert np.array_equal(outs[0], outs[1]), \
        "disk-warm process diverged from cold process"


# ---------------------------------------------------------------------------
# bounded identity memos (satellite: no unbounded growth in serving)
# ---------------------------------------------------------------------------

@pytest.mark.tier1
def test_identity_memo_eviction():
    memo = IdentityMemo(maxsize=4)
    owners = [(i,) for i in range(10)]
    for i, o in enumerate(owners):
        memo.store((id(o), i), o, i)
    assert len(memo) == 4
    # the four youngest survive, oldest evicted
    assert memo.lookup((id(owners[9]), 9), owners[9]) == 9
    assert memo.lookup((id(owners[0]), 0), owners[0]) is None
    hits, misses, maxsize, currsize = memo.cache_info()
    assert maxsize == 4 and currsize == 4


@pytest.mark.tier1
def test_memos_surface_in_cache_stats_and_reset():
    n = 6
    x = jnp.arange(1 << n, dtype=jnp.float32)
    with guard.guarded():
        compile_expr(V.bit_reverse(n))(x)
    stats = cache_stats()
    for key in ("guard_validate_fast", "guard_exec_memo", "store"):
        assert key in stats, key
    assert stats["guard_validate_fast"].currsize >= 1
    assert stats["guard_exec_memo"].currsize >= 1
    clear_caches()
    stats = cache_stats()
    assert stats["guard_validate_fast"].currsize == 0
    assert stats["guard_exec_memo"].currsize == 0
    assert store.stats()["plan_built"] == 0  # session counters reset


@pytest.mark.tier1
def test_version_skew_is_miss_then_heals(tmp_store):
    n = 6
    b, t, key = _plan_key(n)
    ops._class_plan_cached(b.rows, b.c, t)
    data = tmp_store.read_bytes(key)
    tmp_store.write_bytes(key, inject._skewed_entry(data))
    base = store.stats()
    store.class_plan_through(
        b.rows, b.c, t, lambda: ops._build_class_plan(b.rows, b.c, t))
    now = store.stats()
    assert now["version_skew"] == base["version_skew"] + 1
    assert now["quarantined"] == base["quarantined"]
    assert now["write"] == base["write"] + 1  # rebuilt + overwrote
    # healed: the rewritten entry is current-version and hits
    store.class_plan_through(
        b.rows, b.c, t, lambda: ops._build_class_plan(b.rows, b.c, t))
    assert store.stats()["hit"] == now["hit"] + 1


@pytest.mark.tier1
def test_fused_negative_entry_cached(tmp_store):
    """Unplannable clusters persist as negative entries: a warm boot
    skips the failing planning attempt too (plan_built stays 0)."""
    from repro.combinators import execute as _ex

    n = 8
    x = jnp.asarray(np.random.default_rng(1).standard_normal(1 << n),
                    dtype=jnp.float32)
    ce = compile_expr(sort_expr(n))
    ce(x)
    prog, t = ce._resolve(x, False)
    fused = [s for s in prog if getattr(s, "computes", ())]
    assert fused
    # an off-nominal tile parameter the megakernel may reject
    _ex._fused_plan_cached.cache_clear()
    got_a = _ex._fused_plan_cached(fused[0], t)
    base = store.stats()
    _ex._fused_plan_cached.cache_clear()
    got_b = _ex._fused_plan_cached(fused[0], t)
    now = store.stats()
    assert now["hit"] == base["hit"] + 1 and now["plan_built"] == \
        base["plan_built"]
    assert (got_a is None) == (got_b is None)
