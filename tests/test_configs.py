"""Guard the assigned architecture configs against drift (exact dims)."""
import pytest

from repro.configs import ARCHS, get_config, list_archs

ASSIGNED = {
    # name: (layers, d_model, heads, kv, d_ff-or-moe_d_ff, vocab)
    "starcoder2-7b": (32, 4608, 36, 4, 18432, 49152),
    "mistral-nemo-12b": (40, 5120, 32, 8, 14336, 131072),
    "qwen1.5-32b": (64, 5120, 40, 40, 27392, 152064),
    "chatglm3-6b": (28, 4096, 32, 2, 13696, 65024),
    "llama-3.2-vision-90b": (100, 8192, 64, 8, 28672, 128256),
    "recurrentgemma-2b": (26, 2560, 10, 1, 7680, 256000),
    "kimi-k2-1t-a32b": (61, 7168, 64, 8, 2048, 163840),
    "phi3.5-moe-42b-a6.6b": (32, 4096, 32, 8, 6400, 32064),
    "mamba2-130m": (24, 768, 12, 12, 0, 50280),
    "seamless-m4t-medium": (12, 1024, 16, 16, 4096, 256206),
}


def test_all_archs_registered():
    assert set(list_archs()) == set(ASSIGNED)


@pytest.mark.parametrize("name", sorted(ASSIGNED))
def test_exact_dims(name):
    layers, d, h, kv, ff, v = ASSIGNED[name]
    cfg = get_config(name)
    assert cfg.d_model == d and cfg.n_heads == h and cfg.n_kv_heads == kv
    assert cfg.vocab_size == v
    if name == "kimi-k2-1t-a32b":
        assert cfg.moe_d_ff == ff and cfg.n_experts == 384 and cfg.top_k == 8
        assert cfg.n_layers == layers
    elif name == "phi3.5-moe-42b-a6.6b":
        assert cfg.moe_d_ff == ff and cfg.n_experts == 16 and cfg.top_k == 2
        assert cfg.n_layers == layers
    elif name == "seamless-m4t-medium":
        assert cfg.d_ff == ff
        assert cfg.n_periods == layers and cfg.n_enc_periods == layers
    elif name == "mamba2-130m":
        assert cfg.ssm_state == 128 and cfg.n_layers == layers
    else:
        assert cfg.d_ff == ff and cfg.n_layers == layers


def test_param_counts_near_published():
    # total params within 15% of the published scale
    published = {"starcoder2-7b": 7.2e9, "mistral-nemo-12b": 12.2e9,
                 "qwen1.5-32b": 32.5e9, "chatglm3-6b": 6.2e9,
                 "llama-3.2-vision-90b": 90e9, "recurrentgemma-2b": 2.7e9,
                 "kimi-k2-1t-a32b": 1.0e12, "phi3.5-moe-42b-a6.6b": 41.9e9,
                 "mamba2-130m": 130e6}
    for name, want in published.items():
        got = get_config(name).n_params()
        assert abs(got - want) / want < 0.15, (name, got, want)


def test_moe_active_params():
    kimi = get_config("kimi-k2-1t-a32b")
    assert 30e9 < kimi.n_active_params() < 45e9          # ~A32B
    phi = get_config("phi3.5-moe-42b-a6.6b")
    assert 5e9 < phi.n_active_params() < 8e9             # ~A6.6B


def test_sub_quadratic_flags():
    assert get_config("mamba2-130m").sub_quadratic
    assert get_config("recurrentgemma-2b").sub_quadratic
    for name in ("starcoder2-7b", "kimi-k2-1t-a32b", "seamless-m4t-medium"):
        assert not get_config(name).sub_quadratic


def test_layer_patterns():
    rg = get_config("recurrentgemma-2b")
    kinds = rg.layer_kinds
    assert len(kinds) == 26 and kinds.count("local") == 8
    lv = get_config("llama-3.2-vision-90b")
    assert len(lv.layer_kinds) == 100
    assert lv.layer_kinds.count("cross") == 20
    kimi = get_config("kimi-k2-1t-a32b")
    assert kimi.layer_kinds[0] == "dense"
    assert kimi.layer_kinds.count("moe") == 60
