"""Validated execution: the three guard rings (DESIGN.md §14).

Covers the typed error taxonomy (and its backward-compatible builtin
bases), ring-1 plan-time validation units, the ring-3 fault-injection
matrix on both engines (every corruption class caught, zero
silent-wrong-output cases), the pallas → ref fallback path returning a
bitwise-correct degraded result, the guards-off no-op contract
(bitwise-identical outputs, zero guard-counter deltas), and guard-cache
hygiene through ``clear_caches``/``cache_stats``.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import guard
from repro.combinators import cache_stats, clear_caches, compile_expr
from repro.combinators import vocab as V
from repro.combinators.sort import sort_expr
from repro.core import f2
from repro.core.bmmc import Bmmc
from repro.guard import inject
from repro.kernels import ops, ref
from repro.kernels.ops import choose_tile


@pytest.fixture(autouse=True)
def _guards_off_between_tests():
    """Every test starts and ends with guards in the environment-default
    state and fresh guard stats, so counter-delta assertions are
    hermetic."""
    prev = guard.enabled()
    guard.reset_stats()
    yield
    guard._enabled = prev
    guard.reset_stats()


@pytest.fixture(autouse=True, scope="module")
def _bounded_caches():
    yield
    clear_caches()


# ---------------------------------------------------------------------------
# error taxonomy
# ---------------------------------------------------------------------------

@pytest.mark.tier1
def test_taxonomy_types_and_backward_compatible_bases():
    # every typed error is a GuardError, and each keeps the builtin base
    # pre-guard call sites raised — existing pytest.raises expectations
    # (ValueError on bad shapes, KeyError on unknown engines, TypeError
    # on non-primitive stages, SingularError on singular matrices) keep
    # passing against guarded code
    assert issubclass(guard.NotInvertible, guard.GuardError)
    assert issubclass(guard.NotInvertible, f2.SingularError)
    assert issubclass(guard.ClassMismatch, ValueError)
    assert issubclass(guard.DescriptorOOB, IndexError)
    assert issubclass(guard.BadInput, ValueError)
    assert issubclass(guard.BadStage, TypeError)
    assert issubclass(guard.UnknownEngine, KeyError)
    assert issubclass(guard.CachePoisoned, ValueError)
    assert issubclass(guard.GuardTrap, RuntimeError)
    for cls in (guard.ClassMismatch, guard.DescriptorOOB, guard.BadInput,
                guard.BadStage, guard.UnknownEngine, guard.CachePoisoned):
        assert issubclass(cls, guard.GuardError)


@pytest.mark.tier1
def test_legacy_raise_sites_keep_builtin_bases():
    from repro.combinators.execute import get_engine

    with pytest.raises(KeyError):
        get_engine("no-such-engine")
    with pytest.raises(guard.UnknownEngine):
        get_engine("no-such-engine")
    ce = compile_expr(V.rev(4), engine="ref")
    with pytest.raises(ValueError):        # legacy expectation
        ce(jnp.arange(24.0))
    with pytest.raises(guard.BadInput):    # typed expectation
        ce(jnp.arange(24.0))


# ---------------------------------------------------------------------------
# ring 1: plan-time validation units
# ---------------------------------------------------------------------------

@pytest.mark.tier1
def test_verify_bmmc_accepts_sound_rejects_corrupt():
    b = Bmmc.bit_reverse(6)
    assert b.verify() is b
    bad = inject.corrupt_bmmc(b)
    with pytest.raises(guard.NotInvertible, match="singular"):
        guard.verify_bmmc(bad)
    # out-of-range row bits are a distinct corruption from singularity
    oob = Bmmc.__new__(Bmmc)
    object.__setattr__(oob, "rows", (1, 2, 4, 1 << 9))
    object.__setattr__(oob, "c", 0)
    with pytest.raises(guard.NotInvertible, match="column range"):
        guard.verify_bmmc(oob)


@pytest.mark.tier1
def test_validate_input_preconditions():
    assert guard.validate_input((64,), np.float32) == 6
    assert guard.validate_input((4, 64, 2), np.float32, batched=True) == 6
    with pytest.raises(guard.BadInput, match="power of 2"):
        guard.validate_input((24,), np.float32)
    with pytest.raises(guard.BadInput, match="axis"):
        guard.validate_input((), np.float32)
    with pytest.raises(guard.BadInput, match="rank"):
        guard.validate_input((2, 64, 2, 2), np.float32, batched=True)
    with pytest.raises(guard.BadInput, match="expects a 2\\^7"):
        guard.validate_input((64,), np.float32, n=7)


@pytest.mark.tier1
@pytest.mark.parametrize("cls", ["block", "lane", "tiled", "general"])
def test_plan_audits_pass_sound_plans(cls):
    import random
    rng = random.Random(3)
    n, t = 10, 4
    ident = tuple(1 << i for i in range(n))
    if cls == "block":
        sub = Bmmc.random(n - t, rng)
        b = Bmmc(ident[:t] + tuple(r << t for r in sub.rows), sub.c << t)
    elif cls == "lane":
        sub = Bmmc.random(t, rng)
        b = Bmmc(tuple(sub.rows) + ident[t:], sub.c)
    elif cls == "tiled":
        b = Bmmc.bit_reverse(n)
    else:
        b = Bmmc.random(n, rng)
    kernel = guard.validate_dispatch(b.rows, b.c, t)
    assert kernel == ops.class_plan(b, t)[0]


@pytest.mark.tier1
def test_audit_catches_swapped_and_oob_descriptors():
    n = 8
    b = Bmmc.bit_reverse(n)
    t = choose_tile(n, 4)
    # swapped-in-bounds entries: only the SEMANTIC audit can see them
    with inject.swap_descriptors(b, t):
        guard.clear_guard_caches()
        with pytest.raises(guard.DescriptorOOB, match="maps"):
            guard.validate_dispatch(b.rows, b.c, t)
    # out-of-bounds entry: the bounds audit sees it first
    guard.clear_guard_caches()
    with inject.poison_plan(b, t):
        guard.clear_guard_caches()
        with pytest.raises(guard.DescriptorOOB):
            guard.validate_dispatch(b.rows, b.c, t)
    guard.clear_guard_caches()


@pytest.mark.tier1
def test_plan_audit_methods_return_self():
    from repro.core.tiling import plan_block, plan_lane, plan_tiled
    import random
    rng = random.Random(0)
    n, t = 10, 4
    ident = tuple(1 << i for i in range(n))
    sub = Bmmc.random(n - t, rng)
    blk = Bmmc(ident[:t] + tuple(r << t for r in sub.rows), sub.c << t)
    subl = Bmmc.random(t, rng)
    lane = Bmmc(tuple(subl.rows) + ident[t:], subl.c)
    tiled = Bmmc.bit_reverse(n)
    bp = plan_block(blk, t)
    lp = plan_lane(lane, t)
    tp = plan_tiled(tiled, t)
    assert bp.audit() is bp
    assert lp.audit() is lp
    assert tp.audit() is tp


@pytest.mark.tier1
def test_ref_gather_table_audit():
    b = Bmmc.bit_reverse(7)
    tab = ref.audit_src_table(b)
    assert tab.shape == (b.size,)
    with inject.poison_ref_table(b):
        with pytest.raises(guard.DescriptorOOB, match="outside"):
            ref.audit_src_table(b)
    ref.audit_src_table(b)  # restored on exit


# ---------------------------------------------------------------------------
# ring 3: the fault-injection matrix — every corruption class caught
# ---------------------------------------------------------------------------

@pytest.mark.tier1
@pytest.mark.parametrize("engine", ["ref", "pallas"])
def test_fault_matrix_catches_every_corruption_class(engine):
    r = inject.run_fault_matrix(engine=engine)
    missed = [c for c in r["cases"] if not c["caught"]]
    assert r["injected"] == len(inject.FAULT_KINDS)
    assert not missed, f"uncaught fault(s) on {engine}: {missed}"
    assert r["caught"] == r["injected"]
    silent = [c for c in r["cases"] if "SILENT" in c["how"]]
    assert not silent, f"silent wrong output on {engine}: {silent}"


# ---------------------------------------------------------------------------
# ring 2: the fallback state machine
# ---------------------------------------------------------------------------

@pytest.mark.tier1
def test_pallas_trap_degrades_to_ref_with_bitwise_parity():
    n = 6
    x = jnp.arange(1 << n, dtype=jnp.float32)
    b = Bmmc.bit_reverse(n)
    t = choose_tile(n, 4)
    want = np.asarray(ref.bmmc_ref(x, b))
    ce = compile_expr(V.bit_reverse(n), engine="pallas", optimize=False)
    with guard.guarded():
        ce(x)  # warm + ring-1-validate the clean plans
        base = guard.stats()
        with inject.poison_plan(b, t):
            inject._clear_runtime_only()  # re-bake the poisoned tables
            got = ce(x)
        now = guard.stats()
    # degraded result is bitwise-equal to the ref oracle
    assert got.dtype == x.dtype
    assert np.array_equal(np.asarray(got).view(np.uint8),
                          want.view(np.uint8))
    # and the machine recorded the trap -> fallback -> recovery arc
    assert sum(now["traps"].values()) > sum(base["traps"].values())
    assert now["fallbacks"].get("ref", 0) > base["fallbacks"].get("ref", 0)
    assert now["recovered"] > base["recovered"]
    inject._fresh_guard_state()


@pytest.mark.tier1
def test_ref_trap_has_no_fallback_and_fails_loudly():
    n = 6
    x = jnp.arange(1 << n, dtype=jnp.float32)
    b = Bmmc.bit_reverse(n)
    ce = compile_expr(V.bit_reverse(n), engine="ref", optimize=False)
    with guard.guarded():
        ce(x)
        with inject.poison_ref_table(b):
            inject._clear_runtime_only()
            with pytest.raises(guard.GuardTrap, match="no fallback"):
                ce(x)
        now = guard.stats()
    assert now["raised"].get("GuardTrap", 0) >= 1
    inject._fresh_guard_state()


@pytest.mark.tier1
def test_guarded_bmmc_permute_matches_ref_and_flags_decode():
    n = 7
    x = jnp.asarray(np.random.default_rng(1).standard_normal(1 << n),
                    dtype=jnp.float32)
    b = Bmmc.from_perm([(i + 3) % n for i in range(n)], c=5)
    want = np.asarray(ref.bmmc_ref(x, b))
    with guard.guarded():
        got = ops.bmmc_permute(x, b)
    assert np.array_equal(np.asarray(got), want)
    assert guard.resolve_flags(0) == ()
    assert guard.resolve_flags(1) == ("oob",)
    assert guard.resolve_flags(7) == ("nonfinite", "oob", "parity")


@pytest.mark.tier1
def test_guarded_train_step_traps_nonfinite_loss():
    from repro.train.step import _guard_step

    def bad_step(params, opt_state, batch):
        return params, opt_state, {"loss": jnp.float32(np.nan),
                                   "grad_norm": jnp.float32(1.0)}

    def good_step(params, opt_state, batch):
        return params, opt_state, {"loss": jnp.float32(0.5),
                                   "grad_norm": jnp.float32(1.0)}

    assert _guard_step(good_step)(0, 0, 0)[2]["loss"] == 0.5
    with pytest.raises(guard.GuardTrap, match="nonfinite"):
        _guard_step(bad_step)(0, 0, 0)
    assert guard.stats()["traps"].get(("nonfinite", "train"), 0) == 1


# ---------------------------------------------------------------------------
# guards-off no-op contract
# ---------------------------------------------------------------------------

@pytest.mark.tier1
def test_guards_off_is_a_bitwise_noop_with_zero_counters():
    n = 8
    x = jnp.asarray(np.random.default_rng(2).standard_normal(1 << n),
                    dtype=jnp.float32)
    f = compile_expr(sort_expr(n), engine="pallas")
    guard.disable()
    guard.reset_stats()
    base = guard.stats()
    y_off = np.asarray(f(x))
    after = guard.stats()
    assert after == base  # no trap/fallback/raise counters moved
    with guard.guarded():
        y_on = np.asarray(f(x))
    assert np.array_equal(y_off.view(np.uint8), y_on.view(np.uint8))


# ---------------------------------------------------------------------------
# cache hygiene (mirrors test_class_dispatch's pattern)
# ---------------------------------------------------------------------------

@pytest.mark.tier1
def test_guard_caches_in_cache_stats_and_cleared():
    clear_caches()
    st = cache_stats()
    for name in ("guard_validate", "guard_dispatch", "guard_program",
                 "guard_permute"):
        assert name in st, f"{name} missing from cache_stats()"
        assert st[name].currsize == 0
    n = 6
    x = jnp.arange(1 << n, dtype=jnp.float32)
    ce = compile_expr(V.bit_reverse(n), engine="pallas", optimize=False)
    with guard.guarded():
        ce(x)
        ops.bmmc_permute(x, Bmmc.bit_reverse(n))
    st = cache_stats()
    assert st["guard_validate"].currsize > 0
    assert st["guard_dispatch"].currsize > 0
    assert st["guard_program"].currsize > 0
    assert st["guard_permute"].currsize > 0
    with guard.guarded():
        ce(x)  # warm call: validation must memo-hit, not re-prove
    # warm calls land on the identity front memo, so the lru sees no
    # new misses (re-proving) and no growth — only the memo answers
    st2 = cache_stats()
    assert st2["guard_validate"].misses == st["guard_validate"].misses
    assert st2["guard_validate"].currsize == st["guard_validate"].currsize
    clear_caches()
    st = cache_stats()
    for name in ("guard_validate", "guard_dispatch", "guard_program",
                 "guard_permute"):
        assert st[name].currsize == 0
