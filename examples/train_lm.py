"""End-to-end driver: train an LM with the BMMC-shuffled pipeline +
checkpoint/restart, demonstrating fault tolerance by killing and resuming
mid-run.

Run:  PYTHONPATH=src python examples/train_lm.py            (~1M, fast)
      PYTHONPATH=src python examples/train_lm.py --profile 100m --steps 300
"""
import argparse
import shutil
import sys
import tempfile

from repro.launch.train import main as train_main


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--profile", default="smoke")
    ap.add_argument("--steps", type=int, default=60)
    args = ap.parse_args()

    ckpt_dir = tempfile.mkdtemp(prefix="bmmc_lm_ckpt_")
    try:
        # phase 1: train to ~60% of steps, checkpointing along the way
        mid = max(args.steps * 6 // 10, 2)
        print(f"=== phase 1: steps 0..{mid} ===")
        train_main(["--profile", args.profile, "--steps", str(mid),
                    "--ckpt-dir", ckpt_dir, "--ckpt-every", "10"])
        # phase 2: a "restarted job" resumes from the latest checkpoint —
        # including the BMMC shuffle state, so it consumes exactly the
        # unconsumed samples.
        print(f"=== phase 2: simulated restart, resume to {args.steps} ===")
        losses = train_main(["--profile", args.profile,
                             "--steps", str(args.steps),
                             "--ckpt-dir", ckpt_dir, "--ckpt-every", "10"])
        print(f"final loss {losses[-1]:.4f}")
    finally:
        shutil.rmtree(ckpt_dir, ignore_errors=True)


if __name__ == "__main__":
    main()
