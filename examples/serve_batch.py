"""Serving example: batched prefill + KV-cache greedy decode.

Run: PYTHONPATH=src python examples/serve_batch.py [--arch <id>]
Uses the reduced config of any assigned architecture (default: GQA dense).
"""
import sys

from repro.launch.serve import main as serve_main

if __name__ == "__main__":
    serve_main(sys.argv[1:] or ["--arch", "mistral-nemo-12b",
                                "--batch", "4", "--tokens", "12"])
