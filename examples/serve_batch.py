"""Serving example: batched prefill + KV-cache greedy decode, with a
durable-store warm-start demo (DESIGN.md §15).

Run::

    PYTHONPATH=src python examples/serve_batch.py [--arch <id>]

Runs the serving driver twice against the same on-disk plan store with
the compiled BMMC kv-head shuffle enabled (``--head-shuffle pallas``),
dropping every in-process cache in between:

* boot 1 (**cold**) — empty store: the first request plans its
  permutations from scratch and writes each plan back to disk.
* boot 2 (**disk-warm**) — same store, fresh caches: the first request
  loads every plan from disk (each one re-audited through guard
  ring 1), compiling zero plans.

Prints first-request (prefill) latency for both boots plus the
per-request ``store.hit/miss/quarantined`` deltas the driver reports
next to its guard resolution lines. Pass ``--store PATH`` to keep the
store (default: a throwaway temp dir), or any other
``repro.launch.serve`` flag to forward it.
"""
import argparse
import sys
import tempfile
import time

from repro import store
from repro.combinators.execute import clear_caches
from repro.launch.serve import main as serve_main


def _boot(label, root, extra):
    """One fresh-process-equivalent serve run: drop the in-process plan
    caches so the only warm state is the on-disk store."""
    clear_caches()
    store.reset_stats()
    print(f"--- boot: {label} ---")
    t0 = time.perf_counter()
    serve_main(["--store", root, "--head-shuffle", "pallas",
                "--kv-heads", "4", "--validate"] + extra)
    dt = time.perf_counter() - t0
    s = store.stats()
    print(f"[{label}] run={dt:.2f}s store: hits={s['hit']} "
          f"misses={s['miss']} plans_built={s['plan_built']} "
          f"quarantined={s['quarantined']}")
    return s


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--store", default=None, metavar="PATH",
                    help="plan store root (default: throwaway temp dir)")
    args, extra = ap.parse_known_args()
    if not extra:
        extra = ["--arch", "mistral-nemo-12b", "--batch", "4",
                 "--tokens", "8"]
    root = args.store or tempfile.mkdtemp(prefix="repro-serve-store-")

    cold = _boot("cold (empty store)", root, extra)
    warm = _boot("disk-warm (fresh process state)", root, extra)

    print("--- warm-start summary ---")
    print(f"cold boot:      {cold['plan_built']} plan(s) compiled, "
          f"{cold['write']} written to {root}")
    print(f"disk-warm boot: {warm['plan_built']} plan(s) compiled, "
          f"{warm['hit']} served from disk "
          f"({store.active().entry_count()} entries)")
    if warm["plan_built"] or warm["miss"]:
        print("WARN: disk-warm boot was not 100% store-served")
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
