"""Radix-2 FFT whose data reorderings are fused BMMC combinators.

The bit-reversal and every butterfly block reordering are expressions in
the combinator IR; the optimizer fuses the conjugation chains so each of
the n butterfly stages is preceded by exactly one BMMC permutation, each
running as tiled Pallas passes on the planar (re, im) layout.

Run: PYTHONPATH=src python examples/fft_pipeline.py
"""
import time

import jax.numpy as jnp
import numpy as np

from repro.combinators import fuse, lower, num_perm_stages
from repro.combinators.fft import (compiled_fft, fft_expr, from_planar,
                                   to_planar)


def main():
    n = 10
    rng = np.random.default_rng(0)
    x = (rng.standard_normal(1 << n)
         + 1j * rng.standard_normal(1 << n)).astype(np.complex64)

    raw = lower(fft_expr(n), n)
    prog = fuse(raw)
    print(f"2^{n}-point FFT: {num_perm_stages(raw)} raw perm stages "
          f"-> {num_perm_stages(prog)} fused ({n} butterfly stages)")

    f = compiled_fft(n, engine="pallas")
    xp = to_planar(jnp.asarray(x))        # (2^n, 2) float32 (re, im)
    t0 = time.perf_counter()
    got = np.asarray(from_planar(f(xp)))
    dt = time.perf_counter() - t0
    want = np.fft.fft(x)
    err = np.abs(got - want).max() / np.abs(want).max()
    print(f"pallas-engine FFT rel err vs np.fft: {err:.2e} ({dt:.2f}s cold)")
    assert err < 1e-4

    got_ref = np.asarray(compiled_fft(n, engine="ref")(jnp.asarray(x)))
    err = np.abs(got_ref - want).max() / np.abs(want).max()
    print(f"ref-engine (complex64) FFT rel err: {err:.2e}")


if __name__ == "__main__":
    main()
