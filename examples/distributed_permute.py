"""Distributed BMMC permutation over a sharded array (beyond-paper).

Runs on 16 fake CPU devices: plans a global BMMC as local rounds + shard
permutes + at most 2 all-to-all exchange rounds (the sharded analogue of
the paper's two-pass theorem), executes it with shard_map, and checks the
result against the single-device oracle.

Run: PYTHONPATH=src python examples/distributed_permute.py
"""
import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=16")

import random

import jax.numpy as jnp
import numpy as np

from repro.core.bmmc import Bmmc
from repro.core.distributed import (binary_mesh, distributed_bmmc, make_plan,
                                    plan_cost)
from repro.kernels.ref import bmmc_ref


def main():
    n, s = 14, 4                      # 16384 elements over 16 shards
    rng = random.Random(0)
    mesh = binary_mesh(s)
    for name, b in [("bit-reverse", Bmmc.bit_reverse(n)),
                    ("matrix transpose", Bmmc.matrix_transpose(7, 7)),
                    ("random BMMC", Bmmc.random(n, rng))]:
        plan = make_plan(b, s)
        cost = plan_cost(plan)
        x = jnp.arange(1 << n, dtype=jnp.float32)
        got = np.asarray(distributed_bmmc(x, b, s, mesh))
        ok = np.array_equal(got, np.asarray(bmmc_ref(x, b)))
        print(f"{name:18s} rounds: {cost['local']} local, "
              f"{cost['permute']} permute, {cost['exchange']} all-to-all "
              f"({cost['exchange_bits']} bits)  correct={ok}")
        assert ok


if __name__ == "__main__":
    main()
