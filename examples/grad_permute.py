"""Gradients and batches through BMMC permute layers (DESIGN.md §9).

A compiled combinator program is a first-class JAX citizen: ``jax.grad``
flows through the tiled pallas kernels via the offline-inverted program
(no gather transpose), and a leading batch dim shares one tile plan.

Run: PYTHONPATH=src python examples/grad_permute.py
"""
import random

import jax
import jax.numpy as jnp
import numpy as np

from repro.combinators import (cache_stats, compile_expr,
                               inverse_program, vocab as V)
from repro.core.bmmc import Bmmc
from repro.models.permute import PermuteLayer


def main():
    n = 10
    rng = random.Random(0)
    e = V.bit_reverse(n) >> V.perm(Bmmc.random(n, rng)) >> V.riffle(n)
    f = compile_expr(e, engine="pallas")

    # 1. The VJP of a permutation program is its offline inverse program.
    print("forward program: ", f.program(n))
    print("vjp program:     ", f.vjp_program(n))

    # 2. jax.grad through the pallas kernels == inverse permutation of the
    #    cotangent — checked against the ref-engine oracle.
    x = jnp.asarray(np.random.default_rng(1).normal(size=1 << n),
                    jnp.float32)
    w = jnp.asarray(np.random.default_rng(2).normal(size=1 << n),
                    jnp.float32)
    g = jax.grad(lambda x: jnp.sum(w * f(x)))(x)
    oracle = compile_expr(e, engine="ref").inverse(n)(w)
    print("grad == P^-1(w):", bool(np.array_equal(np.asarray(g),
                                                  np.asarray(oracle))))

    # 3. A PermuteLayer in a tiny "model": gradient descent recovers a
    #    signal observed through a permuted channel.
    layer = PermuteLayer(Bmmc.random(n, rng), axis=1, engine="pallas")
    target = jnp.asarray(np.random.default_rng(3).normal(size=(4, 1 << n)),
                         jnp.float32)
    y_obs = layer(target)

    def loss(params):
        return jnp.sum((layer(params) - y_obs) ** 2)

    # a permutation is orthogonal, so lr = 1/2 solves this in one step:
    # p - L^-1(L p - y) = L^-1 y
    params = jnp.zeros_like(target)
    params = jax.jit(lambda p: p - 0.5 * jax.grad(loss)(p))(params)
    print(f"recovery loss after 1 step: {float(loss(params)):.2e}  "
          f"(exact: {bool(np.allclose(np.asarray(params), np.asarray(target)))})")

    # 4. Batch scaling is free: the tile-geometry cache has the same
    #    entries no matter the batch size.
    before = cache_stats()["geom"].currsize
    for b in (2, 8, 32):
        f(jnp.tile(x, (b, 1)), batched=True)
    print("geometry cache entries before/after batches:",
          before, "->", cache_stats()["geom"].currsize)


if __name__ == "__main__":
    main()
