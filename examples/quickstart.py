"""Quickstart: BMMC permutations through the public API.

Run: PYTHONPATH=src python examples/quickstart.py
"""
import random

import jax.numpy as jnp
import numpy as np

from repro.combinators import compile_expr, fuse, lower, num_perm_stages
from repro.combinators import vocab as V
from repro.core.bmmc import Bmmc
from repro.core.parm import parm
from repro.kernels.ops import bmmc_permute, modeled_transactions, num_passes
from repro.kernels.ref import bmmc_ref


def main():
    n = 12  # arrays of 2^12 elements
    x = jnp.arange(1 << n, dtype=jnp.float32)

    # 1. BPC permutations: bit-reversal, transpose, reversal — one tiled pass
    for name, b in [("bit-reverse", Bmmc.bit_reverse(n)),
                    ("matrix transpose 64x64", Bmmc.matrix_transpose(6, 6)),
                    ("array reversal", Bmmc.reverse_array(n))]:
        y = bmmc_permute(x, b, t=4)                 # tiled Pallas kernel
        assert np.array_equal(np.asarray(y), np.asarray(bmmc_ref(x, b)))
        print(f"{name:24s} passes={num_passes(b, 4)}  ok")

    # 2. A general BMMC factorizes into two tiled passes (paper §5.2)
    b = Bmmc.random(n, random.Random(0))
    y = bmmc_permute(x, b, t=4)
    assert np.array_equal(np.asarray(y), np.asarray(bmmc_ref(x, b)))
    tx = modeled_transactions(b, t=4)
    print(f"random BMMC              passes={tx['passes']}  "
          f"modeled bw fraction vs copy={tx['bandwidth_fraction']:.2f}")

    # 3. The parm combinator (paper §7): apply f to interleaved sub-arrays
    ys = parm(0b0101, lambda h: jnp.cumsum(h, axis=0), x[:16])
    print("parm 0b0101 cumsum on 16 elements:", np.asarray(ys, np.int32))

    # 4. Permuting (tokens, features) rows — the framework-internal layout
    tok = jnp.arange((1 << 10) * 8, dtype=jnp.bfloat16).reshape(1 << 10, 8)
    shuffled = bmmc_permute(tok, Bmmc.random(10, random.Random(1)), t=3)
    print("row permute (2^10, 8):", shuffled.shape, shuffled.dtype)

    # 5. The combinator IR: compose lazily, fuse, run as one tiled pass
    e = V.riffle(n) >> V.bit_reverse(n) >> V.rev(n)
    print(f"riffle >> bit_reverse >> rev: "
          f"{num_perm_stages(lower(e, n))} perms lowered -> "
          f"{num_perm_stages(fuse(lower(e, n)))} after fusion")
    f = compile_expr(e, engine="pallas")
    g = compile_expr(e, engine="ref")
    assert np.array_equal(np.asarray(f(x)), np.asarray(g(x)))
    print("combinator pipeline agrees across engines  ok")


if __name__ == "__main__":
    main()
