"""Paper §7: merge sort with a balanced periodic merger, as a combinator
expression.

The declarative network (``parm`` recursion in repro.combinators.sort)
lowers to a [BMMC permute | compare-exchange] stage program; BMMC fusion
collapses ~30x of the permutation stages, and each remaining BMMC runs
as <=2 fully-coalesced tiled kernel passes.

Run: PYTHONPATH=src python examples/sorting_network.py
"""
import time

import jax.numpy as jnp
import numpy as np

from repro.combinators import fuse, lower, num_perm_stages
from repro.combinators.sort import compiled_sort, sort_expr
from repro.core.sort import sort_rec


def main():
    n = 10
    xs = np.random.default_rng(0).integers(0, 10**6, size=1 << n).astype(np.int32)

    # reference recursion (paper pseudocode, numpy)
    ref = sort_rec(n, xs.copy())
    assert np.array_equal(ref, np.sort(xs))

    # the lazy expression, lowered and fused offline
    raw = lower(sort_expr(n), n)
    prog = fuse(raw)
    print(f"2^{n} elements: {num_perm_stages(raw)} raw perm stages "
          f"-> {num_perm_stages(prog)} fused BMMC stages "
          f"({len(prog) - num_perm_stages(prog)} compare-exchange sweeps)")

    # run through both engines via the compiled-plan cache
    got_ref = np.asarray(compiled_sort(n, engine="ref")(jnp.asarray(xs)))
    pallas_sort = compiled_sort(n, engine="pallas")
    t0 = time.perf_counter()
    got_pallas = np.asarray(pallas_sort(jnp.asarray(xs)))
    dt = time.perf_counter() - t0
    assert np.array_equal(got_ref, np.sort(xs))
    assert np.array_equal(got_pallas, np.sort(xs))
    print(f"sorted correctly via tiled Pallas kernels "
          f"(interpret mode, {dt:.2f}s cold on CPU)")
    t0 = time.perf_counter()
    np.asarray(pallas_sort(jnp.asarray(xs)))
    print(f"warm re-run {time.perf_counter() - t0:.3f}s "
          f"(geometry-cached kernel executables)")


if __name__ == "__main__":
    main()
