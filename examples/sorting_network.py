"""Paper §7: merge sort with a balanced periodic merger, written with parm.

The declarative network compiles to [fused BMMC permute | compare-exchange]
stages; BMMC fusion collapses ~15x of the permutation stages, and each
remaining BMMC runs as <=2 fully-coalesced tiled kernel passes.

Run: PYTHONPATH=src python examples/sorting_network.py
"""
import time

import jax.numpy as jnp
import numpy as np

from repro.core.sort import (compile_sort, fuse, num_perm_stages,
                             run_stages, sort_rec)
from repro.kernels.ops import bmmc_permute


def main():
    n = 10
    xs = np.random.default_rng(0).integers(0, 10**6, size=1 << n).astype(np.int32)

    # reference recursion (paper pseudocode, numpy)
    ref = sort_rec(n, xs.copy())
    assert np.array_equal(ref, np.sort(xs))

    # compiled network
    raw = compile_sort(n)
    prog = fuse(raw)
    print(f"2^{n} elements: {num_perm_stages(raw)} raw perm stages "
          f"-> {num_perm_stages(prog)} fused BMMC stages "
          f"({len(prog) - num_perm_stages(prog)} compare-exchange sweeps)")

    # run with the pure-jnp engine and with the tiled Pallas engine
    got_ref = np.asarray(run_stages(prog, jnp.asarray(xs)))
    engine = lambda x, b: bmmc_permute(x, b, t=3)
    t0 = time.perf_counter()
    got_pallas = np.asarray(run_stages(prog, jnp.asarray(xs), engine=engine))
    dt = time.perf_counter() - t0
    assert np.array_equal(got_ref, np.sort(xs))
    assert np.array_equal(got_pallas, np.sort(xs))
    print(f"sorted correctly via tiled Pallas kernels "
          f"(interpret mode, {dt:.2f}s on CPU)")


if __name__ == "__main__":
    main()
