"""Request lifecycle policy: deadlines, bounded retry + deterministic
backoff, and admission control with load shedding (DESIGN.md §16).

The guard taxonomy (DESIGN.md §14) splits into two operational classes:

=================  ==========  =========================================
error              class       why
=================  ==========  =========================================
CachePoisoned      retryable   the poisoned entry was quarantined /
                               the fingerprint mismatch named the cache;
                               a retry replans from clean state
GuardTrap          retryable   a runtime trap the fallback machine
                               already demonstrated it can route around
                               (transient poisoning, re-baked tables) —
                               EXCEPT engine="train" traps (a nonfinite
                               loss recomputes deterministically)
BadInput           terminal    the request itself is malformed
NotInvertible      terminal    the program is malformed
ClassMismatch /    terminal    plan-time refusals: retrying re-proves
DescriptorOOB /                the same invariant against the same
BadStage /                     artifact
UnknownEngine
=================  ==========  =========================================

Backoff is exponential with **deterministic seeded jitter**: the delay
for ``(seed, request_id, attempt)`` is a pure function, so a chaos run
replays byte-identically while distinct requests still decorrelate
(no thundering herd of synchronized retries).

:class:`AdmissionQueue` models the serving loop's bounded backlog: a
request is shed (``resilience.shed``) when the queue is at capacity or
when the backlog, at the observed per-request service latency, could
not drain inside the deadline budget anyway — shedding early is
cheaper than admitting work that is already doomed to time out.
"""
from __future__ import annotations

import random
import threading
import time
import zlib
from dataclasses import dataclass, field
from typing import Callable, Optional

from ..guard.errors import CachePoisoned, GuardError, GuardTrap

RETRYABLE = "retryable"
TERMINAL = "terminal"

_STATS_LOCK = threading.Lock()
_STATS = {"retries": 0, "deadline_exceeded": 0, "shed": 0,
          "requests": 0, "errors": 0}


def _record(key: str, n: int = 1, obs_name: Optional[str] = None,
            **labels) -> None:
    from ..obs import metrics as _om

    with _STATS_LOCK:
        _STATS[key] += n
    if obs_name:
        _om.inc(obs_name, n, **labels)


def stats() -> dict:
    with _STATS_LOCK:
        return dict(_STATS)


def reset_stats() -> None:
    with _STATS_LOCK:
        for k in _STATS:
            _STATS[k] = 0


class DeadlineExceeded(TimeoutError):
    """The request's deadline budget ran out before an attempt could
    finish (or before a retry could be worth starting)."""

    def __init__(self, budget_s: float, elapsed_s: float):
        self.budget_s = budget_s
        self.elapsed_s = elapsed_s
        super().__init__(
            f"deadline {budget_s * 1e3:.0f} ms exceeded "
            f"({elapsed_s * 1e3:.0f} ms elapsed)")


def classify(err: BaseException) -> str:
    """``retryable`` or ``terminal`` for one caught error (see the
    module table). Unknown (non-Guard) errors are terminal."""
    if isinstance(err, GuardTrap):
        # a "train"-engine trap is the step-level nonfinite health check
        # — deterministic on the same batch, retrying re-proves it
        if getattr(err, "engine", None) == "train":
            return TERMINAL
        return RETRYABLE
    if isinstance(err, CachePoisoned):
        return RETRYABLE
    return TERMINAL


@dataclass(frozen=True)
class RetryPolicy:
    """Bounded retry with exponential backoff + deterministic jitter.

    ``delay_s(attempt, request_id)`` is a pure function of
    ``(seed, request_id, attempt)``: base * 2^attempt, capped at
    ``max_delay_s``, with the top ``jitter`` fraction randomized by a
    CRC-seeded :class:`random.Random` — reproducible under a fixed
    seed, decorrelated across requests.
    """

    max_retries: int = 2
    base_delay_s: float = 0.005
    max_delay_s: float = 0.25
    jitter: float = 0.5
    seed: int = 0

    def delay_s(self, attempt: int, request_id: int = 0) -> float:
        d = min(self.max_delay_s, self.base_delay_s * (2 ** attempt))
        rng = random.Random(
            zlib.crc32(f"{self.seed}:{request_id}:{attempt}".encode()))
        return d * (1.0 - self.jitter + self.jitter * rng.random())


@dataclass
class RequestResult:
    """Structured outcome of one policied request — what serve.py
    records per request instead of aborting the process."""

    outcome: str                      # ok | error | deadline | shed
    value: object = None
    error: Optional[BaseException] = None
    error_class: Optional[str] = None  # retryable | terminal
    attempts: int = 0
    retries: int = 0
    latency_s: float = 0.0
    labels: dict = field(default_factory=dict)

    @property
    def ok(self) -> bool:
        return self.outcome == "ok"

    def describe(self) -> str:
        if self.ok:
            return f"ok ({self.attempts} attempt(s))"
        if self.outcome == "shed":
            return "shed (admission control)"
        err = type(self.error).__name__ if self.error else "?"
        return (f"{self.outcome}: {err} [{self.error_class or '-'}] "
                f"after {self.attempts} attempt(s)")


def run_with_policy(fn: Callable[[], object], *,
                    policy: Optional[RetryPolicy] = None,
                    deadline_s: Optional[float] = None,
                    request_id: int = 0,
                    classify_fn: Callable = classify,
                    clock: Callable[[], float] = time.monotonic,
                    sleep: Callable[[float], None] = time.sleep,
                    ) -> RequestResult:
    """Run ``fn`` under the request lifecycle: bounded retries of
    retryable :class:`GuardError`\\ s with backoff, a deadline that
    bounds the WHOLE lifecycle (attempts + backoff sleeps), typed
    terminal errors returned — never raised — as a structured
    :class:`RequestResult`. ``clock``/``sleep`` are injectable so tests
    and the chaos harness run on a virtual clock."""
    pol = policy or RetryPolicy()
    _record("requests")
    t0 = clock()
    attempt = 0
    while True:
        if deadline_s is not None:
            elapsed = clock() - t0
            if elapsed >= deadline_s:
                _record("deadline_exceeded",
                        obs_name="resilience.deadline")
                return RequestResult(
                    "deadline", error=DeadlineExceeded(deadline_s, elapsed),
                    attempts=attempt, retries=max(0, attempt - 1),
                    latency_s=clock() - t0)
        try:
            value = fn()
            return RequestResult("ok", value=value, attempts=attempt + 1,
                                 retries=attempt, latency_s=clock() - t0)
        except GuardError as e:
            cls = classify_fn(e)
            if cls != RETRYABLE or attempt >= pol.max_retries:
                _record("errors")
                return RequestResult(
                    "error", error=e, error_class=cls, attempts=attempt + 1,
                    retries=attempt, latency_s=clock() - t0)
            delay = pol.delay_s(attempt, request_id)
            if deadline_s is not None and \
                    clock() - t0 + delay >= deadline_s:
                # the backoff alone would blow the budget: fail now as
                # a deadline, don't sleep into a guaranteed timeout
                _record("deadline_exceeded",
                        obs_name="resilience.deadline")
                return RequestResult(
                    "deadline", error=DeadlineExceeded(
                        deadline_s, clock() - t0),
                    error_class=cls, attempts=attempt + 1, retries=attempt,
                    latency_s=clock() - t0)
            _record("retries", obs_name="resilience.retry")
            sleep(delay)
            attempt += 1


def shed_result() -> RequestResult:
    """The structured result of a request refused at admission."""
    _record("shed", obs_name="resilience.shed")
    _record("requests")
    return RequestResult("shed")


class AdmissionQueue:
    """Bounded admission with deadline-aware load shedding.

    ``admit()`` refuses (returns False, counts ``resilience.shed``)
    when the backlog is at ``max_depth``, or when serving everything
    already queued plus this request — at the EWMA-observed per-request
    latency — would exceed ``deadline_s``. ``complete(latency_s)``
    feeds the latency estimate and frees a slot.
    """

    def __init__(self, max_depth: int = 64,
                 deadline_s: Optional[float] = None,
                 est_latency_s: float = 0.0, alpha: float = 0.2):
        if max_depth < 1:
            raise ValueError("max_depth must be >= 1")
        self.max_depth = max_depth
        self.deadline_s = deadline_s
        self.est_latency_s = est_latency_s
        self.alpha = alpha
        self._depth = 0
        self._lock = threading.Lock()
        self.admitted = 0
        self.shed = 0

    def would_shed(self, depth: Optional[int] = None) -> bool:
        d = self._depth if depth is None else depth
        if d >= self.max_depth:
            return True
        if self.deadline_s is not None and self.est_latency_s > 0:
            return (d + 1) * self.est_latency_s > self.deadline_s
        return False

    def admit(self) -> bool:
        with self._lock:
            if self.would_shed():
                self.shed += 1
                shed = True
            else:
                self._depth += 1
                self.admitted += 1
                shed = False
        if shed:
            _record("shed", obs_name="resilience.shed")
        return not shed

    def complete(self, latency_s: float) -> None:
        with self._lock:
            self._depth = max(0, self._depth - 1)
            if self.est_latency_s <= 0:
                self.est_latency_s = latency_s
            else:
                self.est_latency_s = ((1 - self.alpha) * self.est_latency_s
                                      + self.alpha * latency_s)

    @property
    def depth(self) -> int:
        return self._depth
