"""Resilience layer: circuit breakers, request lifecycle policy, and
the chaos soak harness (DESIGN.md §16).

Sits on top of the guard rings (DESIGN.md §14) and the durable plan
store (§15): the guard *detects* faults per call; this layer decides
what the serving runtime *does about them over time* — route around a
persistently bad engine (:mod:`.breaker`), retry transient faults with
deadlines and bounded backoff (:mod:`.policy`), and prove the whole
stack holds its SLOs under scheduled fault injection (:mod:`.chaos`).

Like ``guard.stats()``/``store.stats()``, :func:`stats` is always on
(plain dict counters); the same events also mirror into the opt-in
``resilience.*`` obs counters when telemetry is enabled.
"""
from __future__ import annotations

from . import breaker, policy
from .breaker import BreakerBoard, Route, board, configure
from .policy import (AdmissionQueue, DeadlineExceeded, RequestResult,
                     RetryPolicy, classify, run_with_policy, shed_result)

__all__ = [
    "AdmissionQueue", "BreakerBoard", "DeadlineExceeded", "RequestResult",
    "RetryPolicy", "Route", "board", "breaker", "classify", "configure",
    "policy", "reset", "run_with_policy", "shed_result", "stats",
]


def stats() -> dict:
    """Always-on resilience counters: the request-policy record plus
    the breaker board's transition counts and live circuit states."""
    out = policy.stats()
    out["breaker"] = board().stats()
    out["circuits"] = board().snapshot()
    return out


def reset() -> None:
    """Reset every resilience counter and circuit (test hermeticity;
    called from ``execute.clear_caches`` / ``inject._fresh_guard_state``)."""
    policy.reset_stats()
    board().reset()
