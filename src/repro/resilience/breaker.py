"""Per-(engine, fault-class) circuit breakers (DESIGN.md §16).

The guard subsystem's fallback machine (DESIGN.md §14) recovers a
trapped pallas call by re-dispatching it through the ref engine — but
it is *stateless*: a persistently poisoned pallas path pays the full
trap + fallback cost (two guarded dispatches plus the flag readback)
on **every** call. The breaker adds the memory: after ``threshold``
consecutive trapped calls on an (engine, fault-kind) pair, the circuit
**opens** and the dispatcher routes straight to the fallback engine at
plan level — one clean ref dispatch per call, zero per-call trap cost —
until a cool-down of ``cooldown`` routed calls has elapsed. The circuit
then goes **half-open**: exactly one probe request is admitted back to
the protected engine to rediscover its health. A clean probe closes
the circuit (full pallas service resumes); a trapped probe reopens it
for another cool-down.

State machine per ``(engine, kind)``::

      CLOSED --[threshold consecutive failures]--> OPEN
      OPEN   --[cooldown routed calls]-----------> HALF_OPEN
      HALF_OPEN --[probe succeeds]---------------> CLOSED
      HALF_OPEN --[probe traps]------------------> OPEN   (fresh cool-down)

Invariants (property-tested in ``tests/test_resilience.py``):

* no transition out of OPEN before the cool-down has fully elapsed;
* HALF_OPEN admits **exactly one** in-flight probe — every other call
  keeps routing to the fallback until the probe resolves;
* a trap during the probe reopens the circuit.

The :class:`BreakerBoard` aggregates the per-kind breakers for one
protected engine and makes the per-call routing decision the guard
runtime consults (:func:`repro.guard.runtime._resolve_or_fallback`).
Only engines with a fallback are protected — today that is ``pallas``
(fallback ``ref``); the ref oracle is the engine of last resort and is
never re-routed. Transitions mirror into ``resilience.breaker.{open,
probe,close,shunt}`` obs counters and into the always-on
:func:`repro.resilience.stats` record.
"""
from __future__ import annotations

import threading
from dataclasses import dataclass
from typing import Dict, Optional, Tuple

CLOSED = "closed"
OPEN = "open"
HALF_OPEN = "half_open"

# breakers guard engines that have somewhere to degrade to
FALLBACK_OF = {"pallas": "ref"}

DEFAULT_THRESHOLD = 3
DEFAULT_COOLDOWN = 8


def _count(event: str, **labels) -> None:
    from ..obs import metrics as _om

    _om.inc(f"resilience.breaker.{event}", **labels)


class Breaker:
    """One (engine, fault-kind) circuit. Not thread-safe on its own —
    the :class:`BreakerBoard` serializes access."""

    __slots__ = ("threshold", "cooldown", "state", "failures",
                 "cool_remaining", "probe_inflight",
                 "opens", "probes", "closes")

    def __init__(self, threshold: int = DEFAULT_THRESHOLD,
                 cooldown: int = DEFAULT_COOLDOWN):
        if threshold < 1 or cooldown < 1:
            raise ValueError("threshold and cooldown must be >= 1")
        self.threshold = threshold
        self.cooldown = cooldown
        self.state = CLOSED
        self.failures = 0          # consecutive, while CLOSED
        self.cool_remaining = 0    # routed-away calls left, while OPEN
        self.probe_inflight = False
        self.opens = 0
        self.probes = 0
        self.closes = 0

    def _open(self) -> None:
        self.state = OPEN
        self.failures = 0
        self.cool_remaining = self.cooldown
        self.probe_inflight = False
        self.opens += 1

    def decide(self) -> str:
        """One routing decision: ``"run"`` (closed), ``"shunt"`` (route
        to the fallback), or ``"probe"`` (half-open, this call is the
        probe). OPEN ticks its cool-down on every decision and flips to
        HALF_OPEN only after the full cool-down elapsed — the next
        decision after the flip is the probe."""
        if self.state == CLOSED:
            return "run"
        if self.state == OPEN:
            self.cool_remaining -= 1
            if self.cool_remaining <= 0:
                self.state = HALF_OPEN
                self.probe_inflight = False
            return "shunt"
        # HALF_OPEN: exactly one probe in flight
        if self.probe_inflight:
            return "shunt"
        self.probe_inflight = True
        self.probes += 1
        return "probe"

    def on_success(self, probe: bool) -> None:
        if self.state == HALF_OPEN and probe:
            self.state = CLOSED
            self.failures = 0
            self.probe_inflight = False
            self.closes += 1
        elif self.state == CLOSED:
            self.failures = 0

    def on_failure(self, probe: bool) -> None:
        if self.state == HALF_OPEN and probe:
            self._open()
        elif self.state == CLOSED:
            self.failures += 1
            if self.failures >= self.threshold:
                self._open()
        # a failure while OPEN can only come from a shunted call that
        # trapped on the *fallback* engine; it never touches this circuit

    def snapshot(self) -> dict:
        return {"state": self.state, "failures": self.failures,
                "cool_remaining": self.cool_remaining,
                "probe_inflight": self.probe_inflight,
                "opens": self.opens, "probes": self.probes,
                "closes": self.closes}


@dataclass(frozen=True)
class Route:
    """One routing decision for one guarded call."""

    engine: object          # the engine to actually dispatch on
    requested: object       # the engine the caller asked for
    probe: bool = False     # this call is the half-open health probe
    shunted: bool = False   # an open circuit routed it to the fallback

    @property
    def engaged(self) -> bool:
        return self.probe or self.shunted


class BreakerBoard:
    """The per-kind breakers of every protected engine, plus the
    aggregate routing decision: if ANY circuit for the engine is open,
    the call shunts to the fallback (each open circuit ticks its
    cool-down); once every open circuit has cooled, the first call
    probes ALL half-open circuits at once (one probe request total —
    the engine is healthy or it is not); otherwise the call runs
    normally. Thread-safe; the no-breakers fast path is one dict
    emptiness check."""

    def __init__(self, threshold: int = DEFAULT_THRESHOLD,
                 cooldown: int = DEFAULT_COOLDOWN):
        self.threshold = threshold
        self.cooldown = cooldown
        self._lock = threading.Lock()
        self._breakers: Dict[Tuple[str, str], Breaker] = {}
        self._stats = {"open": 0, "probe": 0, "close": 0, "shunt": 0}

    def configure(self, threshold: Optional[int] = None,
                  cooldown: Optional[int] = None) -> None:
        """Set thresholds for breakers created from now on and reset
        live circuits (a reconfigured machine starts from CLOSED)."""
        with self._lock:
            if threshold is not None:
                self.threshold = threshold
            if cooldown is not None:
                self.cooldown = cooldown
            self._breakers.clear()

    def reset(self) -> None:
        with self._lock:
            self._breakers.clear()
            for k in self._stats:
                self._stats[k] = 0

    def _engine_breakers(self, engine: str):
        return [b for (e, _), b in self._breakers.items() if e == engine]

    def route(self, engine) -> Route:
        """The per-call routing decision. Engines without a fallback
        (and injected engine callables) are never re-routed."""
        fallback = FALLBACK_OF.get(engine) if isinstance(engine, str) \
            else None
        if fallback is None or not self._breakers:
            return Route(engine, engine)
        with self._lock:
            brs = self._engine_breakers(engine)
            if not brs:
                return Route(engine, engine)
            open_brs = [b for b in brs if b.state == OPEN]
            if open_brs:
                for b in open_brs:
                    b.decide()          # ticks the cool-down
                self._stats["shunt"] += 1
            else:
                half = [b for b in brs if b.state == HALF_OPEN]
                if not half:
                    return Route(engine, engine)
                decisions = [b.decide() for b in half]
                if "probe" in decisions:
                    self._stats["probe"] += 1
                    _count("probe", engine=engine)
                    return Route(engine, engine, probe=True)
                self._stats["shunt"] += 1
        _count("shunt", engine=engine)
        return Route(fallback, engine, shunted=True)

    def on_success(self, route: Route) -> None:
        """The call ran clean ON THE REQUESTED ENGINE (a shunted call's
        success says nothing about the protected engine)."""
        if route.engine != route.requested:
            return
        with self._lock:
            closed_any = False
            for b in self._engine_breakers(route.requested):
                was = b.state
                b.on_success(route.probe)
                closed_any |= (was == HALF_OPEN and b.state == CLOSED)
            if closed_any:
                self._stats["close"] += 1
        if closed_any:
            _count("close", engine=route.requested)

    def on_trap(self, route: Route, kinds) -> None:
        """The call trapped on the requested engine: per-kind failure
        accounting, plus — on a trapped probe — reopening every
        half-open circuit (one bad probe re-condemns the engine)."""
        if route.engine != route.requested:
            return
        engine = route.requested
        if not isinstance(engine, str) or engine not in FALLBACK_OF:
            # the engine of last resort has nowhere to degrade to — a
            # circuit for it could open but never tick (route() never
            # re-routes it), so it gets no circuit at all
            return
        opened = 0
        with self._lock:
            for kind in kinds:
                key = (engine, kind)
                b = self._breakers.get(key)
                if b is None:
                    b = self._breakers[key] = Breaker(
                        self.threshold, self.cooldown)
                was_open = b.opens
                b.on_failure(route.probe)
                opened += b.opens - was_open
            if route.probe:
                for b in self._engine_breakers(engine):
                    if b.state == HALF_OPEN:
                        was_open = b.opens
                        b.on_failure(True)
                        opened += b.opens - was_open
            self._stats["open"] += opened
        for _ in range(opened):
            _count("open", engine=engine)

    def snapshot(self) -> dict:
        with self._lock:
            return {f"{e}/{k}": b.snapshot()
                    for (e, k), b in sorted(self._breakers.items())}

    def engaged(self, engine: str) -> bool:
        """Any circuit for ``engine`` not fully CLOSED (the serving loop
        uses this as the "degraded" signal; recovery = not engaged)."""
        with self._lock:
            return any(b.state != CLOSED
                       for b in self._engine_breakers(engine))

    def stats(self) -> dict:
        with self._lock:
            return dict(self._stats)


_BOARD = BreakerBoard()


def board() -> BreakerBoard:
    return _BOARD


def configure(threshold: Optional[int] = None,
              cooldown: Optional[int] = None) -> None:
    _BOARD.configure(threshold, cooldown)


def reset() -> None:
    _BOARD.reset()
