"""Chaos soak harness: scheduled fault injection against a live
serving loop, with SLO assertions (DESIGN.md §16).

The ring-3 injectors (:mod:`repro.guard.inject`) prove each corruption
class is caught *once*; this harness proves the runtime stays healthy
when faults arrive **over time**: a timeline of fault windows is played
against a serve.py-style request loop (each request one guarded
compiled-permutation dispatch, every result bitwise-compared to the ref
oracle), and the report asserts the serving SLOs:

* **zero silent wrong outputs** — every result served while (or after)
  an injector is active is bitwise-equal to the oracle, or the request
  failed loudly (typed error / deadline / shed);
* **bounded error budget** — loud failures stay within the per-cell
  budget (0 for recoverable faults; the window length where the fault
  hits the engine of last resort);
* **breaker recovery** — the circuit opened by a fault window closes
  within ``recovery_k`` requests of the injector clearing (probe
  rediscovers pallas health), and while it is open the per-call trap
  cost is verifiably gone (``traps_while_open == 0``).

Timeline format: one fault kind + a ``[start, stop)`` request window.
``fault`` names the injector:

* ``poison_plan``      — memory fault: OOB-poison the cached pallas
  descriptor table (ring-2 trap -> ref fallback -> breaker opens);
* ``poison_ref_table`` — memory fault on the engine of last resort
  (loud per-request failure, no fallback left);
* ``disk_bitflip``     — disk fault: flip a payload bit of the durable
  plan-store entry (quarantine + replan on next load; the ref engine
  never consults the store, so its cell must be a no-op);
* ``none``             — control cell.

CLI (the CI chaos-soak smoke job)::

    python -m repro.resilience.chaos --smoke [--sigterm-drill] [--json OUT]

runs the full injector matrix (memory + disk x {ref, pallas}) and exits
nonzero on any SLO violation. ``--sigterm-drill`` additionally boots
``repro.launch.serve`` as a subprocess, SIGTERMs it mid-decode, and
requires a graceful drain (exit 0 + complete summary, no stack trace).
"""
from __future__ import annotations

import contextlib
import tempfile
from dataclasses import dataclass, field
from typing import Optional

import numpy as np

MEMORY_FAULTS = ("poison_plan", "poison_ref_table")
DISK_FAULTS = ("disk_bitflip",)
FAULTS = MEMORY_FAULTS + DISK_FAULTS + ("none",)


@dataclass
class SoakReport:
    """Outcome of one soak cell; ``slo_violations`` empty == passed."""

    engine: str
    fault: str
    requests: int
    window: tuple
    ok: int = 0
    errors: int = 0
    deadline: int = 0
    shed: int = 0
    silent_wrong: int = 0
    faults_injected: int = 0
    faults_caught: int = 0
    shunted: int = 0
    traps_while_open: int = 0
    retries: int = 0
    detected: int = 0            # guard traps + store quarantines seen
    breaker: dict = field(default_factory=dict)
    recovered_at: Optional[int] = None
    recovery_requests: Optional[int] = None
    recovery_k: int = 0
    error_budget: int = 0
    slo_violations: list = field(default_factory=list)

    @property
    def passed(self) -> bool:
        return not self.slo_violations

    def summary(self) -> str:
        return (f"chaos[{self.engine}/{self.fault}]: "
                f"{self.ok}/{self.requests} ok, "
                f"{self.errors} error(s) (budget {self.error_budget}), "
                f"{self.silent_wrong} silent-wrong, "
                f"faults {self.faults_caught}/{self.faults_injected} "
                f"caught, breaker {self.breaker}, "
                f"recovery +{self.recovery_requests} req "
                f"(K={self.recovery_k}), "
                f"traps-while-open {self.traps_while_open}"
                + (" — PASS" if self.passed
                   else f" — FAIL {self.slo_violations}"))


def _trap_total() -> int:
    from .. import guard

    return sum(guard.stats()["traps"].values())


def soak(*, engine: str = "pallas", fault: str = "poison_plan",
         n: int = 6, requests: int = 32, window: tuple = (8, 16),
         threshold: int = 2, cooldown: int = 4,
         recovery_k: Optional[int] = None,
         error_budget: int = 0, max_retries: int = 1,
         deadline_s: Optional[float] = None) -> SoakReport:
    """Play one fault window against a live guarded request loop and
    return the :class:`SoakReport`. Deterministic (seeded input, seeded
    backoff jitter, request-count cool-downs); restores every piece of
    global state it touches (breaker config, store root, caches)."""
    import jax.numpy as jnp

    from .. import guard, store as _store
    from ..combinators import vocab as V
    from ..combinators.execute import compile_expr
    from ..core.bmmc import Bmmc
    from ..guard import inject
    from ..kernels import ops, ref as _ref
    from . import breaker as _breaker
    from .policy import RetryPolicy, run_with_policy

    if fault not in FAULTS:
        raise ValueError(f"unknown fault {fault!r}; one of {FAULTS}")
    start, stop = window
    if recovery_k is None:
        # open at `threshold`, cool down, one (possibly wasted, fault
        # still active) probe, cool down again, clean probe
        recovery_k = 2 * cooldown + 2
    rep = SoakReport(engine=engine, fault=fault, requests=requests,
                     window=(start, stop), recovery_k=recovery_k,
                     error_budget=error_budget)

    x = jnp.arange(1 << n, dtype=jnp.float32)
    bmmc = Bmmc.bit_reverse(n)
    t = ops.choose_tile(n, 4)
    oracle = np.asarray(_ref.bmmc_ref(x, bmmc))
    policy = RetryPolicy(max_retries=max_retries, base_delay_s=1e-4,
                         max_delay_s=2e-3, seed=7)

    board = _breaker.board()
    prev_cfg = (board.threshold, board.cooldown)
    board.configure(threshold=threshold, cooldown=cooldown)

    prev_store = _store.active()
    store_root = None
    stack = contextlib.ExitStack()
    injector_active = False

    def activate():
        nonlocal injector_active
        if fault == "poison_plan":
            stack.enter_context(inject.poison_plan(bmmc, t))
            inject._clear_runtime_only()   # re-bake the poisoned tables
        elif fault == "poison_ref_table":
            stack.enter_context(inject.poison_ref_table(bmmc))
            inject._clear_runtime_only()
        elif fault == "disk_bitflip":
            st = _store.active()
            key = _store.class_key(bmmc.rows, bmmc.c, t)
            if st is not None and st.read_bytes(key) is not None:
                stack.enter_context(
                    inject.corrupt_store_entry(st, key, "bitflip"))
                inject._clear_replan_path()  # next call reaches the disk
        injector_active = True

    def deactivate():
        nonlocal injector_active
        stack.close()                      # restores the clean state
        if fault in MEMORY_FAULTS:
            inject._clear_runtime_only()   # re-bake the clean tables
        elif fault in DISK_FAULTS:
            inject._clear_replan_path()
        injector_active = False

    try:
        if fault in DISK_FAULTS:
            # the disk cells run against their own throwaway store so a
            # CI-level REPRO_STORE is never corrupted
            store_root = tempfile.mkdtemp(prefix="repro-chaos-store-")
            _store.configure(store_root)
            inject._clear_replan_path()
        ce = compile_expr(V.bit_reverse(n), engine=engine, optimize=False)
        with guard.guarded():
            ce(x)                          # warm + populate the store
            base_traps = _trap_total()
            base_quar = _store.stats()["quarantined"]
            for i in range(requests):
                if i == start and fault != "none":
                    activate()
                if i == stop and injector_active:
                    deactivate()
                shunt0 = board.stats()["shunt"]
                traps0 = _trap_total()
                res = run_with_policy(lambda: ce(x), policy=policy,
                                      deadline_s=deadline_s, request_id=i)
                shunted = board.stats()["shunt"] > shunt0
                trap_delta = _trap_total() - traps0
                rep.retries += res.retries
                if shunted:
                    rep.shunted += 1
                    rep.traps_while_open += trap_delta
                if injector_active:
                    rep.faults_injected += 1
                if res.ok:
                    if np.array_equal(
                            np.asarray(res.value).view(np.uint8),
                            oracle.view(np.uint8)):
                        rep.ok += 1
                        if injector_active:
                            rep.faults_caught += 1
                    else:
                        rep.silent_wrong += 1
                elif res.outcome == "deadline":
                    rep.deadline += 1
                    if injector_active:
                        rep.faults_caught += 1  # loud, not silent
                else:
                    rep.errors += 1
                    if injector_active:
                        rep.faults_caught += 1  # loud, not silent
                if (i >= stop and rep.recovered_at is None
                        and not board.engaged(engine)):
                    rep.recovered_at = i
            rep.detected = (_trap_total() - base_traps
                            + _store.stats()["quarantined"] - base_quar)
    finally:
        stack.close()
        rep.breaker = board.stats()
        board.configure(threshold=prev_cfg[0], cooldown=prev_cfg[1])
        if fault in DISK_FAULTS:
            _store.configure(prev_store.root if prev_store else None)
            inject._clear_replan_path()
        elif fault in MEMORY_FAULTS:
            inject._clear_runtime_only()

    if rep.recovered_at is not None:
        rep.recovery_requests = rep.recovered_at - stop
    # ---- SLO assertions ----------------------------------------------
    if rep.silent_wrong:
        rep.slo_violations.append(
            f"silent_wrong_outputs={rep.silent_wrong} (must be 0)")
    if rep.faults_caught != rep.faults_injected:
        rep.slo_violations.append(
            f"faults_caught={rep.faults_caught} != "
            f"faults_injected={rep.faults_injected}")
    if rep.errors + rep.deadline > rep.error_budget:
        rep.slo_violations.append(
            f"errors={rep.errors + rep.deadline} exceed "
            f"budget={rep.error_budget}")
    if rep.recovered_at is None:
        rep.slo_violations.append("no recovery before the soak ended")
    elif rep.recovery_requests > recovery_k:
        rep.slo_violations.append(
            f"recovery took {rep.recovery_requests} requests "
            f"(K={recovery_k})")
    if rep.shunted and rep.traps_while_open:
        rep.slo_violations.append(
            f"open breaker still paid {rep.traps_while_open} trap(s) "
            f"across {rep.shunted} shunted request(s)")
    if fault != "none" and stop > start and rep.detected == 0:
        rep.slo_violations.append(
            "injector active but nothing was detected "
            "(no trap, no quarantine)"
            if engine != "ref" or fault not in DISK_FAULTS else "")
        rep.slo_violations = [v for v in rep.slo_violations if v]
    return rep


def default_matrix() -> list:
    """The full injector matrix: memory + disk faults x {ref, pallas}.

    * pallas x memory: the breaker arc — trap/fallback, open, shunted
      zero-trap service on ref, probe, close;
    * pallas x disk: quarantine + replan recovery (no breaker needed —
      detection happens at plan load, before any dispatch);
    * ref x memory: the engine of last resort failing LOUDLY per
      request (error budget = the window length x (1 + retries));
    * ref x disk: the ref oracle never consults the plan store, so a
      corrupt entry must not perturb it at all.
    """
    return [
        dict(engine="pallas", fault="poison_plan", requests=32,
             window=(8, 16), threshold=2, cooldown=4, error_budget=0),
        dict(engine="pallas", fault="disk_bitflip", requests=16,
             window=(6, 8), threshold=2, cooldown=4, error_budget=0),
        dict(engine="ref", fault="poison_ref_table", requests=18,
             window=(6, 9), threshold=2, cooldown=4, error_budget=3,
             max_retries=1),
        dict(engine="ref", fault="disk_bitflip", requests=14,
             window=(6, 8), threshold=2, cooldown=4, error_budget=0),
    ]


def run_matrix(cells: Optional[list] = None) -> list:
    """Run every cell; returns the list of :class:`SoakReport`."""
    return [soak(**cell) for cell in (cells or default_matrix())]


# ---------------------------------------------------------------------------
# SIGTERM drain drill (drives the real serve.py as a subprocess)
# ---------------------------------------------------------------------------

def sigterm_drill(tokens: int = 6000, timeout_s: float = 240.0) -> dict:
    """Boot ``repro.launch.serve`` with a long decode, SIGTERM it once
    decoding has started, and verify the graceful-drain contract: exit
    code 0, a ``drained:`` marker, the complete summary (decode report
    + guard resolution), and no traceback."""
    import os
    import signal
    import subprocess
    import sys
    import time

    cmd = [sys.executable, "-u", "-m", "repro.launch.serve",
           "--arch", "mistral-nemo-12b", "--batch", "2",
           "--prompt-len", "8", "--tokens", str(tokens),
           "--validate", "--error-budget", "0"]
    env = dict(os.environ)
    env.setdefault("JAX_PLATFORMS", "cpu")
    proc = subprocess.Popen(cmd, stdout=subprocess.PIPE,
                            stderr=subprocess.STDOUT, text=True, env=env)
    out_lines = []
    started = False
    deadline = time.monotonic() + timeout_s
    try:
        for line in proc.stdout:
            out_lines.append(line)
            if "decode starting" in line:
                started = True
                time.sleep(1.0)      # let a few decode steps land
                proc.send_signal(signal.SIGTERM)
                break
            if time.monotonic() > deadline:
                proc.kill()
                break
        remaining = max(5.0, deadline - time.monotonic())
        rest, _ = proc.communicate(timeout=remaining)
        out_lines.append(rest or "")
    except subprocess.TimeoutExpired:
        proc.kill()
        proc.communicate()
    out = "".join(out_lines)
    ok = (started and proc.returncode == 0 and "drained:" in out
          and "decode:" in out and "Traceback" not in out)
    return {"ok": ok, "returncode": proc.returncode, "started": started,
            "drained": "drained:" in out, "traceback": "Traceback" in out,
            "output": out}


def main(argv=None) -> int:
    import argparse
    import json as _json

    ap = argparse.ArgumentParser(description=__doc__.split("\n")[0])
    ap.add_argument("--smoke", action="store_true",
                    help="run the default (short) injector matrix")
    ap.add_argument("--sigterm-drill", action="store_true",
                    help="also SIGTERM a live serve.py mid-decode and "
                         "require a graceful drain")
    ap.add_argument("--json", default=None, metavar="OUT.json")
    args = ap.parse_args(argv)

    reports = run_matrix()
    failures = []
    for rep in reports:
        print(rep.summary())
        if not rep.passed:
            failures.extend(
                f"{rep.engine}/{rep.fault}: {v}"
                for v in rep.slo_violations)
    drill = None
    if args.sigterm_drill:
        drill = sigterm_drill()
        marker = "PASS" if drill["ok"] else "FAIL"
        print(f"chaos[sigterm-drill]: started={drill['started']} "
              f"rc={drill['returncode']} drained={drill['drained']} "
              f"traceback={drill['traceback']} — {marker}")
        if not drill["ok"]:
            failures.append("sigterm-drill: serve.py did not drain "
                            "gracefully")
            print(drill["output"][-4000:])
    if args.json:
        payload = {"cells": [vars(r) for r in reports],
                   "failures": failures}
        if drill is not None:
            payload["sigterm_drill"] = {
                k: v for k, v in drill.items() if k != "output"}
        with open(args.json, "w") as f:
            _json.dump(payload, f, indent=1, default=str)
    if failures:
        print("chaos soak: SLO violations:")
        for msg in failures:
            print(f"  {msg}")
        return 1
    print(f"chaos soak: {len(reports)} cell(s) passed"
          + (" + sigterm drill" if args.sigterm_drill else ""))
    return 0


if __name__ == "__main__":
    import sys

    sys.exit(main())
