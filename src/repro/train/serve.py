"""Serving steps: prefill (cache build) and single-token decode."""
from __future__ import annotations

from ..configs.base import ArchConfig
from ..models import model as M
def make_prefill_step(cfg: ArchConfig, mesh=None):

    def prefill_step(params, batch):
        return M.prefill(cfg, params, batch, mesh=mesh)

    return prefill_step


def make_decode_step(cfg: ArchConfig, mesh=None):

    def decode_step(params, caches, tokens, pos):
        return M.decode_step(cfg, params, caches, tokens, pos, mesh=mesh)

    return decode_step
