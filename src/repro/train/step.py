"""Train step: loss + grads + (optionally 8-bit) AdamW update.

Pure function of (params, opt_state, batch); gradient accumulation folds
microbatches with a ``lax.scan`` so the peak activation footprint is one
microbatch regardless of global batch.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from ..configs.base import ArchConfig
from ..models import model as M
from ..optim.adamw import AdamWConfig, adamw_init, adamw_update, state_shapes
def make_train_step(cfg: ArchConfig, mesh=None,
                    opt_cfg: Optional[AdamWConfig] = None,
                    grad_accum: int = 1):
    opt_cfg = opt_cfg or AdamWConfig(state_bits=cfg.opt_bits)

    def loss_of(params, batch):
        return M.loss_fn(cfg, params, batch, mesh=mesh)

    def train_step(params, opt_state, batch):
        if grad_accum > 1:
            b = batch["tokens"].shape[0]
            mb = b // grad_accum
            micro = jax.tree.map(
                lambda x: x.reshape((grad_accum, mb) + x.shape[1:]), batch)

            def acc(carry, mbatch):
                g_acc, l_acc = carry
                (loss, _), g = jax.value_and_grad(loss_of, has_aux=True)(
                    params, mbatch)
                g_acc = jax.tree.map(jnp.add, g_acc, g)
                return (g_acc, l_acc + loss), None

            g0 = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
            (grads, loss), _ = jax.lax.scan(acc, (g0, jnp.zeros((), jnp.float32)),
                                            micro)
            grads = jax.tree.map(lambda g: g / grad_accum, grads)
            loss = loss / grad_accum
            parts = {"ce": loss, "aux": jnp.zeros((), jnp.float32)}
        else:
            (loss, parts), grads = jax.value_and_grad(loss_of, has_aux=True)(
                params, batch)
        new_params, new_state = adamw_update(params, grads, opt_state, opt_cfg)
        metrics = {"loss": loss, **parts,
                   "grad_norm": jnp.sqrt(sum(
                       jnp.sum(jnp.square(g.astype(jnp.float32)))
                       for g in jax.tree.leaves(grads)))}
        return new_params, new_state, metrics

    return train_step, opt_cfg


def init_opt(cfg: ArchConfig, params, opt_cfg: Optional[AdamWConfig] = None):
    opt_cfg = opt_cfg or AdamWConfig(state_bits=cfg.opt_bits)
    return adamw_init(params, opt_cfg)


def opt_state_shapes(cfg: ArchConfig, param_shapes,
                     opt_cfg: Optional[AdamWConfig] = None):
    opt_cfg = opt_cfg or AdamWConfig(state_bits=cfg.opt_bits)
    return state_shapes(param_shapes, opt_cfg)
