"""Train step: loss + grads + (optionally 8-bit) AdamW update.

Pure function of (params, opt_state, batch); gradient accumulation folds
microbatches with a ``lax.scan`` so the peak activation footprint is one
microbatch regardless of global batch.

``loss_fn`` may override the model loss with any ``(params, batch) ->
(loss, parts_dict)`` — e.g. a loss routed through a
:class:`repro.models.permute.PermuteLayer`, so ``jax.grad`` exercises
the pallas BMMC custom VJP inside a full (grads + AdamW) training step.

Telemetry (:mod:`repro.obs`, when enabled): each *eager* step call
records a ``train.step`` span, a ``train.step_us`` latency histogram
entry, and the permute share of the step — the modeled permutation
round trips dispatched while the step traced plus the fraction of step
wall-clock spent in ``program.call`` permute executions. Callers that
``jax.jit`` the returned function still get the trace-time dispatch
counters (they fire while the jaxpr is built); the wall-clock pieces
are skipped under tracing, never measured wrong.

``make_train_step(..., validate=True)`` returns the guarded variant:
the step body runs under :mod:`repro.guard` and eager calls raise a
typed ``GuardTrap`` on a nonfinite loss/grad norm (DESIGN.md §14).
"""
from __future__ import annotations

import functools
import time
from typing import Callable, Optional

import jax
import jax.numpy as jnp

from .. import obs
from ..configs.base import ArchConfig
from ..models import model as M
from ..optim.adamw import AdamWConfig, adamw_init, adamw_update, state_shapes


def _trace_state_clean() -> bool:
    try:
        return jax.core.trace_state_clean()
    except AttributeError:  # pragma: no cover - older/newer jax
        return True


def _instrument_step(train_step: Callable) -> Callable:
    """Wrap a step fn with per-step telemetry; transparent when obs is
    disabled (one attribute check) or when the wrapper itself is being
    jit-traced (timing a trace is not timing a step)."""

    @functools.wraps(train_step)
    def observed(params, opt_state, batch):
        if not (obs.enabled() and _trace_state_clean()):
            return train_step(params, opt_state, batch)
        rt0 = obs.counter_total("model.round_trips")
        vjp0 = obs.counter_total("model.vjp_round_trips")
        perm0 = sum(s["sum"] for (nm, _), s in obs.histograms().items()
                    if nm == "program.call_us")
        with obs.span("train.step") as sargs:
            t0 = time.perf_counter_ns()
            out = train_step(params, opt_state, batch)
            if obs.sync_enabled():
                jax.block_until_ready(out)
            dur_us = (time.perf_counter_ns() - t0) / 1e3
            sargs["dur_us"] = round(dur_us, 1)
        obs.observe("train.step_us", dur_us)
        rt = obs.counter_total("model.round_trips") - rt0
        if rt:  # permute stages traced/dispatched inside this step
            obs.inc("train.permute_round_trips", rt)
        vjp = obs.counter_total("model.vjp_round_trips") - vjp0
        if vjp:  # backward-rule passes traced/dispatched inside this step
            obs.inc("train.permute_vjp_round_trips", vjp)
        perm_us = sum(s["sum"] for (nm, _), s in obs.histograms().items()
                      if nm == "program.call_us") - perm0
        if perm_us and dur_us > 0:
            # eager CompiledExpr permute calls inside the step: their
            # measured share of the step wall clock
            obs.observe("train.permute_share", perm_us / dur_us)
        return out

    return observed


def _guard_step(train_step: Callable, trap_retries: int = 1) -> Callable:
    """Guarded step variant (DESIGN.md §14): the step body runs with
    :mod:`repro.guard` rings active — plan validation plus guarded
    permute dispatch inside the loss — and each *eager* call resolves a
    step-level health check: a nonfinite loss or gradient norm raises
    the typed :class:`repro.guard.GuardTrap` instead of silently
    poisoning the optimizer state. Under an outer jit trace the
    host-side resolution is skipped (the in-program guards still
    recorded at trace time); the returned metrics are unchanged.

    Transient traps retry (DESIGN.md §16): a *retryable*
    :class:`~repro.guard.GuardError` escaping the step body — e.g. a
    poisoned plan cache that quarantine + replan clears — is retried up
    to ``trap_retries`` times (counted as ``resilience.retry``) before
    it propagates. The step is a pure function of its inputs, so a
    retry is safe; the nonfinite health check is deliberately OUTSIDE
    the retry loop — a nonfinite loss recomputes deterministically on
    the same batch, so retrying it would just re-prove the trap."""
    from .. import guard
    from ..resilience import policy as _rp

    @functools.wraps(train_step)
    def validated(params, opt_state, batch):
        attempt = 0
        while True:
            try:
                with guard.guarded():
                    out = train_step(params, opt_state, batch)
                break
            except guard.GuardError as e:
                if (_rp.classify(e) != _rp.RETRYABLE
                        or attempt >= trap_retries):
                    raise
                attempt += 1
                _rp._record("retries", obs_name="resilience.retry")
        if not _trace_state_clean():
            return out
        metrics = out[2]
        bad = [k for k in ("loss", "grad_norm")
               if k in metrics and not bool(jnp.isfinite(metrics[k]))]
        if bad:
            err = guard.GuardTrap(("nonfinite",), "train")
            err.args = (f"guarded train step: nonfinite {bad} — the "
                        f"update would poison the optimizer state",)
            guard._record_trap("nonfinite", "train")
            guard._record_raised(err)
            raise err
        return out

    return validated


def make_train_step(cfg: ArchConfig, mesh=None,
                    opt_cfg: Optional[AdamWConfig] = None,
                    grad_accum: int = 1,
                    loss_fn: Optional[Callable] = None,
                    validate: bool = False,
                    trap_retries: int = 1):
    opt_cfg = opt_cfg or AdamWConfig(state_bits=cfg.opt_bits)

    def loss_of(params, batch):
        if loss_fn is not None:
            return loss_fn(params, batch)
        return M.loss_fn(cfg, params, batch, mesh=mesh)

    def train_step(params, opt_state, batch):
        if grad_accum > 1:
            b = jax.tree.leaves(batch)[0].shape[0]  # custom losses may
            mb = b // grad_accum                    # not carry "tokens"
            micro = jax.tree.map(
                lambda x: x.reshape((grad_accum, mb) + x.shape[1:]), batch)

            def acc(carry, mbatch):
                g_acc, l_acc, p_acc = carry
                (loss, parts), g = jax.value_and_grad(loss_of, has_aux=True)(
                    params, mbatch)
                g_acc = jax.tree.map(jnp.add, g_acc, g)
                p_acc = jax.tree.map(jnp.add, p_acc, parts)
                return (g_acc, l_acc + loss, p_acc), None

            g0 = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
            p_shape = jax.eval_shape(
                lambda p, mb_: loss_of(p, mb_)[1], params,
                jax.tree.map(lambda x: x[0], micro))
            p0 = jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype), p_shape)
            (grads, loss, parts), _ = jax.lax.scan(
                acc, (g0, jnp.zeros((), jnp.float32), p0), micro)
            grads = jax.tree.map(lambda g: g / grad_accum, grads)
            loss = loss / grad_accum
            # same metric keys as grad_accum=1: parts averaged over
            # microbatches
            parts = jax.tree.map(lambda v: v / grad_accum, parts)
        else:
            (loss, parts), grads = jax.value_and_grad(loss_of, has_aux=True)(
                params, batch)
        new_params, new_state = adamw_update(params, grads, opt_state, opt_cfg)
        metrics = {"loss": loss, **parts,
                   "grad_norm": jnp.sqrt(sum(
                       jnp.sum(jnp.square(g.astype(jnp.float32)))
                       for g in jax.tree.leaves(grads)))}
        return new_params, new_state, metrics

    step = _instrument_step(train_step)
    if validate:
        step = _guard_step(step, trap_retries=trap_retries)
    return step, opt_cfg


def init_opt(cfg: ArchConfig, params, opt_cfg: Optional[AdamWConfig] = None):
    opt_cfg = opt_cfg or AdamWConfig(state_bits=cfg.opt_bits)
    return adamw_init(params, opt_cfg)


def opt_state_shapes(cfg: ArchConfig, param_shapes,
                     opt_cfg: Optional[AdamWConfig] = None):
    opt_cfg = opt_cfg or AdamWConfig(state_bits=cfg.opt_bits)
    return state_shapes(param_shapes, opt_cfg)
