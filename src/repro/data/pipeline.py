"""Data pipeline with deterministic BMMC affine shuffling.

The epoch shuffle is a *random invertible BMMC* over sample indices — an
affine permutation of the dataset (paper §3 applied beyond the paper: a
PRP with O(1) state). Properties the framework relies on:

* **O(1) state**: (A, c, epoch) fully determines the order — a restored or
  replacement host recomputes its shard without coordination (straggler /
  fault-tolerance story, DESIGN.md §5).
* **Exactly invertible**: sample -> position and position -> sample are both
  O(n-bit matvec); auditing which samples a failed step consumed is exact.
* **Shard-local evaluation**: host h evaluates only positions
  [h*per_host, (h+1)*per_host) — no global shuffle buffer.

Token streams are synthesized deterministically per sample id (this
container has no corpus; swap ``sample_tokens`` for a real tokenizer-backed
reader in production).
"""
from __future__ import annotations

import dataclasses
import random
from typing import Dict, Iterator, Optional

import numpy as np

from ..core import f2
from ..core.bmmc import Bmmc


@dataclasses.dataclass(frozen=True)
class DataConfig:
    n_samples_log2: int = 20          # dataset size = 2^n (paper's setting)
    seq_len: int = 128
    vocab_size: int = 256
    seed: int = 0


def epoch_bmmc(cfg: DataConfig, epoch: int) -> Bmmc:
    """The affine shuffle for one epoch (deterministic in (seed, epoch))."""
    rng = random.Random((cfg.seed << 20) ^ epoch)
    return Bmmc.random(cfg.n_samples_log2, rng)


def sample_tokens(cfg: DataConfig, sample_id: int) -> np.ndarray:
    """Synthetic *learnable* token stream for one sample id (deterministic).

    Tokens follow an affine successor rule t_{i+1} = (5 t_i + 17) mod V with
    10% noise — a model that learns the rule reaches ~0.1 * ln(V) loss, so
    training progress is observable (pure-random tokens would pin the loss
    at the ln(V) entropy floor).
    """
    rng = np.random.default_rng(np.uint64((cfg.seed << 32) ^ sample_id))
    v = cfg.vocab_size
    out = np.empty(cfg.seq_len + 1, dtype=np.int32)
    out[0] = rng.integers(0, v)
    noise = rng.random(cfg.seq_len) < 0.1
    rand = rng.integers(0, v, size=cfg.seq_len)
    for i in range(cfg.seq_len):
        out[i + 1] = rand[i] if noise[i] else (5 * out[i] + 17) % v
    return out


@dataclasses.dataclass
class ShardedLoader:
    """Batch iterator for one host shard; resumable from (epoch, step)."""

    cfg: DataConfig
    batch_size: int               # per-host batch
    host_id: int = 0
    n_hosts: int = 1
    epoch: int = 0
    step: int = 0                 # batches already consumed this epoch

    def __post_init__(self):
        total = 1 << self.cfg.n_samples_log2
        assert total % self.n_hosts == 0
        self.per_host = total // self.n_hosts

    def _shuffled_id(self, position: int) -> int:
        """Global position -> sample id through the epoch's BMMC."""
        b = epoch_bmmc(self.cfg, self.epoch)
        # permutation: sample x lands at position A x ^ c; reading order is
        # the inverse map.
        return b.inverse().apply(position)

    def state(self) -> Dict:
        return {"epoch": self.epoch, "step": self.step,
                "host_id": self.host_id, "seed": self.cfg.seed}

    def restore(self, state: Dict):
        assert state["seed"] == self.cfg.seed, "shuffle seed mismatch"
        self.epoch, self.step = state["epoch"], state["step"]

    def __iter__(self) -> Iterator[Dict[str, np.ndarray]]:
        return self

    def __next__(self) -> Dict[str, np.ndarray]:
        start = self.host_id * self.per_host + self.step * self.batch_size
        if self.step * self.batch_size + self.batch_size > self.per_host:
            self.epoch += 1
            self.step = 0
            start = self.host_id * self.per_host
        toks = np.stack([
            sample_tokens(self.cfg, self._shuffled_id(start + i))
            for i in range(self.batch_size)])
        self.step += 1
        return {"tokens": toks[:, :-1], "labels": toks[:, 1:]}
