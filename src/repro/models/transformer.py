"""Block definitions + period-scanned stack executor for all arch families.

Block kinds:
  dense  — self-attn (GQA, RoPE) + MLP
  local  — sliding-window self-attn + MLP
  moe    — self-attn + mixture-of-experts FFN (+ optional shared experts)
  cross  — gated cross-attention to stub patch/frame embeddings + MLP (VLM)
  enc    — bidirectional self-attn + MLP (encoder)
  dec    — causal self-attn + cross-attn + MLP (enc-dec decoder)
  rec    — RG-LRU recurrent block + MLP (RecurrentGemma)
  mamba  — Mamba-2 SSD block

The stack is ``prefix + pattern * n_periods + tail``; the repeated pattern
runs under ``lax.scan`` with stacked parameters so HLO size is depth-
independent (critical for the 100-layer VLM / 61-layer 1T-MoE dry-runs),
optionally rematerialized.
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..configs.base import ArchConfig
from .layers import (ParamDef, apply_rope, layer_norm, rms_norm, stack_defs,
                     tree_map_defs)
from .attention import attention, decode_attention, default_head_perm
from .moe import moe_ffn
from .ssm import (causal_conv1d, rglru, rglru_step, ssd_chunked,
                  ssd_decode_step)

# ---------------------------------------------------------------------------
# Parameter definitions per block kind
# ---------------------------------------------------------------------------


def _norm_defs(cfg, name):
    if cfg.norm == "ln":
        return {f"{name}_scale": ParamDef((cfg.d_model,), ("embed",), cfg.dtype, "ones"),
                f"{name}_bias": ParamDef((cfg.d_model,), ("embed",), cfg.dtype, "zeros")}
    return {f"{name}_scale": ParamDef((cfg.d_model,), ("embed",), cfg.dtype, "zeros")}


def _apply_norm(cfg, p, name, x):
    if cfg.norm == "ln":
        return layer_norm(x, p[f"{name}_scale"], p[f"{name}_bias"])
    return rms_norm(x, p[f"{name}_scale"])


def _attn_defs(cfg: ArchConfig, prefix: str = "") -> Dict[str, ParamDef]:
    e, h, kv, d = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.hd
    dt = cfg.dtype
    defs = {
        f"{prefix}wq": ParamDef((e, h, d), ("embed", "heads", "head_dim"), dt),
        f"{prefix}wk": ParamDef((e, kv, d), ("embed", "kv_heads", "head_dim"), dt),
        f"{prefix}wv": ParamDef((e, kv, d), ("embed", "kv_heads", "head_dim"), dt),
        f"{prefix}wo": ParamDef((h, d, e), ("heads", "head_dim", "embed"), dt, "small"),
    }
    if cfg.qkv_bias:
        defs[f"{prefix}bq"] = ParamDef((h, d), ("heads", "head_dim"), dt, "zeros")
        defs[f"{prefix}bk"] = ParamDef((kv, d), ("kv_heads", "head_dim"), dt, "zeros")
        defs[f"{prefix}bv"] = ParamDef((kv, d), ("kv_heads", "head_dim"), dt, "zeros")
    return defs


def _mlp_defs(cfg: ArchConfig) -> Dict[str, ParamDef]:
    e, f, dt = cfg.d_model, cfg.d_ff, cfg.dtype
    if cfg.mlp == "gelu":
        return {
            "w_up": ParamDef((e, f), ("embed", "mlp"), dt),
            "b_up": ParamDef((f,), ("mlp",), dt, "zeros"),
            "w_down": ParamDef((f, e), ("mlp", "embed"), dt, "small"),
            "b_down": ParamDef((e,), ("embed",), dt, "zeros"),
        }
    return {
        "w_gate": ParamDef((e, f), ("embed", "mlp"), dt),
        "w_up": ParamDef((e, f), ("embed", "mlp"), dt),
        "w_down": ParamDef((f, e), ("mlp", "embed"), dt, "small"),
    }


def _moe_defs(cfg: ArchConfig) -> Dict[str, ParamDef]:
    e, f, x, dt = cfg.d_model, cfg.moe_d_ff, cfg.n_experts, cfg.dtype
    defs = {
        "router": ParamDef((e, x), ("embed", None), jnp.float32, "normal", 0.006),
        "we_gate": ParamDef((x, e, f), ("experts", "embed", None), dt),
        "we_up": ParamDef((x, e, f), ("experts", "embed", None), dt),
        "we_down": ParamDef((x, f, e), ("experts", None, "embed"), dt, "small"),
    }
    if cfg.n_shared_experts:
        fs = f * cfg.n_shared_experts
        defs.update({
            "ws_gate": ParamDef((e, fs), ("embed", "mlp"), dt),
            "ws_up": ParamDef((e, fs), ("embed", "mlp"), dt),
            "ws_down": ParamDef((fs, e), ("mlp", "embed"), dt, "small"),
        })
    return defs


def _mamba_defs(cfg: ArchConfig) -> Dict[str, ParamDef]:
    e, dt = cfg.d_model, cfg.dtype
    di = cfg.ssm_expand * e
    n = cfg.ssm_state
    nh = di // cfg.ssm_headdim
    k = cfg.ssm_conv
    conv_ch = di + 2 * n
    return {
        "w_z": ParamDef((e, di), ("embed", "mlp"), dt),
        "w_x": ParamDef((e, di), ("embed", "mlp"), dt),
        "w_b": ParamDef((e, n), ("embed", "state"), dt),
        "w_c": ParamDef((e, n), ("embed", "state"), dt),
        "w_dt": ParamDef((e, nh), ("embed", None), dt),
        "dt_bias": ParamDef((nh,), (None,), jnp.float32, "zeros"),
        "a_log": ParamDef((nh,), (None,), jnp.float32, "ones"),
        "d_skip": ParamDef((nh,), (None,), jnp.float32, "ones"),
        "conv_w": ParamDef((k, conv_ch), (None, "mlp"), dt, "normal", 0.1),
        "norm_y": ParamDef((di,), ("mlp",), dt, "zeros"),
        "w_out": ParamDef((di, e), ("mlp", "embed"), dt, "small"),
    }


def _rec_defs(cfg: ArchConfig) -> Dict[str, ParamDef]:
    e, dt = cfg.d_model, cfg.dtype
    w = cfg.lru_width or e
    k = cfg.ssm_conv
    return {
        "w_xb": ParamDef((e, w), ("embed", "mlp"), dt),
        "w_gateb": ParamDef((e, w), ("embed", "mlp"), dt),
        "conv_w": ParamDef((k, w), (None, "mlp"), dt, "normal", 0.1),
        "w_gate_a": ParamDef((w, w), ("mlp", None), dt, "small"),
        "w_gate_x": ParamDef((w, w), ("mlp", None), dt, "small"),
        "a_param": ParamDef((w,), ("mlp",), jnp.float32, "ones"),
        "w_out": ParamDef((w, e), ("mlp", "embed"), dt, "small"),
    }


def block_defs(cfg: ArchConfig, kind: str) -> Dict[str, ParamDef]:
    d: Dict[str, ParamDef] = {}
    if kind in ("dense", "local", "moe", "enc", "dec"):
        d.update(_norm_defs(cfg, "ln_attn"))
        d.update(_attn_defs(cfg))
    if kind == "dec":
        d.update(_norm_defs(cfg, "ln_cross"))
        d.update(_attn_defs(cfg, prefix="c_"))
    if kind == "cross":
        d.update(_norm_defs(cfg, "ln_attn"))
        d.update(_attn_defs(cfg))
        d["attn_gate"] = ParamDef((1,), (None,), jnp.float32, "zeros")
        d["mlp_gate"] = ParamDef((1,), (None,), jnp.float32, "zeros")
    if kind in ("dense", "local", "cross", "enc", "dec"):
        d.update(_norm_defs(cfg, "ln_mlp"))
        d.update(_mlp_defs(cfg))
    if kind == "moe":
        d.update(_norm_defs(cfg, "ln_mlp"))
        d.update(_moe_defs(cfg))
    if kind == "mamba":
        d.update(_norm_defs(cfg, "ln_attn"))
        d.update(_mamba_defs(cfg))
    if kind == "rec":
        d.update(_norm_defs(cfg, "ln_attn"))
        d.update(_rec_defs(cfg))
        d.update({k2: v for k2, v in _norm_defs(cfg, "ln_mlp").items()})
        d.update(_mlp_defs(cfg))
    return d


# ---------------------------------------------------------------------------
# Cache definitions (decode/prefill state per block)
# ---------------------------------------------------------------------------

def cache_defs(cfg: ArchConfig, kind: str, batch: int, cache_len: int) -> Dict:
    kv, dd, dt = cfg.n_kv_heads, cfg.hd, cfg.dtype
    kvax = ("batch", "seq_kv", "kv_heads", None)  # seq-sharded cache (SP):
    # kv_heads rarely divide the model axis (2/4/8 heads vs 16 shards), so
    # the cache sequence dim carries the model-axis sharding for decode.
    if kind in ("dense", "local", "moe", "enc"):
        if kind == "enc":
            return {}
        return {"k": ParamDef((batch, cache_len, kv, dd), kvax, dt, "zeros"),
                "v": ParamDef((batch, cache_len, kv, dd), kvax, dt, "zeros")}
    if kind == "dec":
        src = max(cfg.src_len, 1)
        return {"k": ParamDef((batch, cache_len, kv, dd), kvax, dt, "zeros"),
                "v": ParamDef((batch, cache_len, kv, dd), kvax, dt, "zeros"),
                "ck": ParamDef((batch, src, kv, dd), kvax, dt, "zeros"),
                "cv": ParamDef((batch, src, kv, dd), kvax, dt, "zeros")}
    if kind == "cross":
        src = max(cfg.src_len, 1)
        return {"ck": ParamDef((batch, src, kv, dd), kvax, dt, "zeros"),
                "cv": ParamDef((batch, src, kv, dd), kvax, dt, "zeros")}
    if kind == "mamba":
        di = cfg.ssm_expand * cfg.d_model
        nh = di // cfg.ssm_headdim
        conv_ch = di + 2 * cfg.ssm_state
        return {"conv": ParamDef((batch, cfg.ssm_conv - 1, conv_ch), ("batch", None, "mlp"), dt, "zeros"),
                "state": ParamDef((batch, nh, cfg.ssm_headdim, cfg.ssm_state),
                                  ("batch", None, None, "state"), jnp.float32, "zeros")}
    if kind == "rec":
        w = cfg.lru_width or cfg.d_model
        return {"conv": ParamDef((batch, cfg.ssm_conv - 1, w), ("batch", None, "mlp"), dt, "zeros"),
                "h": ParamDef((batch, w), ("batch", "mlp"), jnp.float32, "zeros")}
    raise ValueError(kind)


# ---------------------------------------------------------------------------
# Block application
# ---------------------------------------------------------------------------

def _project_qkv(cfg, p, x, prefix=""):
    q = jnp.einsum("bse,ehd->bshd", x, p[f"{prefix}wq"])
    k = jnp.einsum("bse,ehd->bshd", x, p[f"{prefix}wk"])
    v = jnp.einsum("bse,ehd->bshd", x, p[f"{prefix}wv"])
    if cfg.qkv_bias:
        q = q + p[f"{prefix}bq"]
        k = k + p[f"{prefix}bk"]
        v = v + p[f"{prefix}bv"]
    return q, k, v


def _self_attn(cfg, p, x, ctx, *, window=None, kind_attn="causal", cache=None):
    """Returns (attn_out, new_cache_kv)."""
    mode = ctx["mode"]
    q, k, v = _project_qkv(cfg, p, x)
    rd = int(cfg.hd * cfg.rotary_frac) if cfg.rotary_frac < 1.0 else None
    if kind_attn != "full":  # positional only for causal self-attn
        pos = ctx["pos"] + jnp.arange(x.shape[1])
        q = apply_rope(q, pos, cfg.rope_theta, rd)
        k = apply_rope(k, pos, cfg.rope_theta, rd)
    hp = default_head_perm(cfg.n_kv_heads) if cfg.head_shuffle else None
    if cfg.head_shuffle and hp is None:
        raise ValueError(
            f"head_shuffle={cfg.head_shuffle!r} needs a power-of-two "
            f"kv-head count >= 2, got n_kv_heads={cfg.n_kv_heads}")
    hp_kw = ({"head_perm": hp, "head_perm_engine": cfg.head_shuffle}
             if hp is not None else {})
    if mode == "decode":
        kc = jax.lax.dynamic_update_slice_in_dim(cache["k"], k, ctx["pos"], axis=1)
        vc = jax.lax.dynamic_update_slice_in_dim(cache["v"], v, ctx["pos"], axis=1)
        # the shuffle is output-neutral, so decode skips it: re-permuting
        # the whole KV cache every token would be O(S^2) over a decode
        out = decode_attention(q, kc, vc, ctx["pos"] + 1, window=window)
        new_cache = {"k": kc, "v": vc}
    else:
        out = attention(q, k, v, kind=kind_attn, window=window,
                        kv_block=cfg.kv_block, **hp_kw)
        new_cache = {"k": k, "v": v} if mode == "prefill" else None
    y = jnp.einsum("bshd,hde->bse", out, p["wo"])
    return y, new_cache


def _cross_attn(cfg, p, x, ctx, prefix="", cache=None):
    mode = ctx["mode"]
    q = jnp.einsum("bse,ehd->bshd", x, p[f"{prefix}wq"])
    if cfg.qkv_bias:
        q = q + p[f"{prefix}bq"]
    if mode == "decode":
        k, v = cache["ck"], cache["cv"]
        new_cache = {"ck": k, "cv": v}
    else:
        enc = ctx["enc"]
        k = jnp.einsum("bse,ehd->bshd", enc, p[f"{prefix}wk"])
        v = jnp.einsum("bse,ehd->bshd", enc, p[f"{prefix}wv"])
        if cfg.qkv_bias:
            k = k + p[f"{prefix}bk"]
            v = v + p[f"{prefix}bv"]
        new_cache = {"ck": k, "cv": v} if mode == "prefill" else None
    out = attention(q, k, v, kind="full", kv_block=cfg.kv_block)
    y = jnp.einsum("bshd,hde->bse", out, p[f"{prefix}wo"])
    return y, new_cache


def _mlp(cfg, p, x):
    if cfg.mlp == "gelu":
        h = jnp.einsum("bse,ef->bsf", x, p["w_up"]) + p["b_up"]
        h = jax.nn.gelu(h.astype(jnp.float32), approximate=True).astype(x.dtype)
        return jnp.einsum("bsf,fe->bse", h, p["w_down"]) + p["b_down"]
    g = jnp.einsum("bse,ef->bsf", x, p["w_gate"])
    u = jnp.einsum("bse,ef->bsf", x, p["w_up"])
    act = jax.nn.gelu if cfg.mlp == "geglu" else jax.nn.silu
    h = act(g.astype(jnp.float32)).astype(x.dtype) * u
    return jnp.einsum("bsf,fe->bse", h, p["w_down"])


def _moe_block_ffn(cfg, p, x, ctx):
    b, s, e = x.shape
    mesh = ctx.get("mesh")
    if (cfg.moe_impl == "a2a" and mesh is not None
            and s % mesh.shape["model"] == 0
            and cfg.n_experts % mesh.shape["model"] == 0):
        from .moe_a2a import moe_ffn_a2a
        out, aux = moe_ffn_a2a(x, p["router"], p["we_gate"], p["we_up"],
                               p["we_down"], top_k=cfg.top_k,
                               capacity_factor=cfg.capacity_factor, mesh=mesh)
    else:
        # "naive" = historical baseline: one global group (global token
        # indices -> GSPMD replicates the token activation per layer)
        groups = 1 if cfg.moe_impl == "naive" else ctx.get("dp_groups", 1)
        if (b * s) % max(groups, 1):
            groups = 1
        grouped = x.reshape(groups, (b * s) // groups, e)
        out, aux = moe_ffn(grouped, p["router"], p["we_gate"], p["we_up"],
                           p["we_down"], top_k=cfg.top_k,
                           capacity_factor=cfg.capacity_factor,
                           constrain_buf=ctx.get("constrain_moe"))
        out = out.reshape(b, s, e)
    if cfg.n_shared_experts:
        g = jnp.einsum("bse,ef->bsf", x, p["ws_gate"])
        u = jnp.einsum("bse,ef->bsf", x, p["ws_up"])
        h = jax.nn.silu(g.astype(jnp.float32)).astype(x.dtype) * u
        out = out + jnp.einsum("bsf,fe->bse", h, p["ws_down"])
    return out, aux


def _mamba_block(cfg, p, x, ctx, cache=None):
    b, s, e = x.shape
    di = cfg.ssm_expand * e
    n = cfg.ssm_state
    nh = di // cfg.ssm_headdim
    pdim = cfg.ssm_headdim
    z = jnp.einsum("bse,ei->bsi", x, p["w_z"])
    xi = jnp.einsum("bse,ei->bsi", x, p["w_x"])
    bb = jnp.einsum("bse,en->bsn", x, p["w_b"])
    cc = jnp.einsum("bse,en->bsn", x, p["w_c"])
    dt = jnp.einsum("bse,eh->bsh", x, p["w_dt"])

    conv_in = jnp.concatenate([xi, bb, cc], axis=-1)
    prev = cache["conv"] if ctx["mode"] == "decode" else None
    conv_out, conv_state = causal_conv1d(conv_in, p["conv_w"], prev)
    conv_out = jax.nn.silu(conv_out.astype(jnp.float32)).astype(x.dtype)
    xi, bb, cc = conv_out[..., :di], conv_out[..., di:di + n], conv_out[..., di + n:]

    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])      # (b,s,nh)
    a = -jnp.exp(p["a_log"])                                          # (nh,)
    dt_a = dt * a                                                     # (b,s,nh)
    xh = xi.reshape(b, s, nh, pdim) * dt[..., None].astype(x.dtype)
    bg = bb[:, :, None, :]                                            # (b,s,1,n)
    cg = cc[:, :, None, :]

    if ctx["mode"] == "decode":
        state = cache["state"]
        new_state, y = ssd_decode_step(state, xh[:, 0], dt_a[:, 0].astype(jnp.float32),
                                       bg[:, 0], cg[:, 0])
        y = y[:, None]                                                # (b,1,nh,p)
        new_cache = {"conv": conv_state, "state": new_state}
    else:
        if ctx["mode"] == "prefill":
            y, state = ssd_chunked(xh, dt_a, bg, cg, chunk=cfg.ssm_chunk,
                                   return_final_state=True)
            new_cache = {"conv": conv_state, "state": state}
        else:
            y = ssd_chunked(xh, dt_a, bg, cg, chunk=cfg.ssm_chunk)
            new_cache = None
    y = y + xh * p["d_skip"][:, None].astype(x.dtype)
    y = y.reshape(b, -1, di)
    y = rms_norm(y * jax.nn.silu(z.astype(jnp.float32)).astype(x.dtype), p["norm_y"])
    return jnp.einsum("bsi,ie->bse", y, p["w_out"]), new_cache


def _rec_block(cfg, p, x, ctx, cache=None):
    xb = jnp.einsum("bse,ew->bsw", x, p["w_xb"])
    gate_b = jnp.einsum("bse,ew->bsw", x, p["w_gateb"])
    prev = cache["conv"] if ctx["mode"] == "decode" else None
    xc, conv_state = causal_conv1d(xb, p["conv_w"], prev)
    ga = jnp.einsum("bsw,wv->bsv", xc, p["w_gate_a"])
    gx = jnp.einsum("bsw,wv->bsv", xc, p["w_gate_x"])
    if ctx["mode"] == "decode":
        h_new, y = rglru_step(cache["h"], xc[:, 0], ga[:, 0], gx[:, 0], p["a_param"])
        y = y[:, None]
        new_cache = {"conv": conv_state, "h": h_new}
    else:
        h0 = None
        y, h_last = rglru(xc, ga, gx, p["a_param"], h0)
        new_cache = ({"conv": conv_state, "h": h_last.astype(jnp.float32)}
                     if ctx["mode"] == "prefill" else None)
    y = y * jax.nn.gelu(gate_b.astype(jnp.float32), approximate=True).astype(x.dtype)
    return jnp.einsum("bsw,we->bse", y, p["w_out"]), new_cache


def block_apply(cfg: ArchConfig, kind: str, p: Dict, x, ctx,
                cache: Optional[Dict] = None) -> Tuple[Any, Optional[Dict], Any]:
    """Returns (x_out, new_cache, aux_loss)."""
    aux = jnp.zeros((), jnp.float32)
    constrain = ctx.get("constrain", lambda v, _k="act": v)
    x = constrain(x)
    if kind in ("dense", "local", "moe"):
        h = _apply_norm(cfg, p, "ln_attn", x)
        window = cfg.window if kind == "local" else None
        a, kv_cache = _self_attn(cfg, p, h, ctx, window=window, cache=cache)
        x = x + a
        h = _apply_norm(cfg, p, "ln_mlp", x)
        if kind == "moe":
            m, aux = _moe_block_ffn(cfg, p, h, ctx)
        else:
            m = _mlp(cfg, p, h)
        x = x + m
        return x, kv_cache, aux
    if kind == "enc":
        h = _apply_norm(cfg, p, "ln_attn", x)
        a, _ = _self_attn(cfg, p, h, ctx, kind_attn="full")
        x = x + a
        x = x + _mlp(cfg, p, _apply_norm(cfg, p, "ln_mlp", x))
        return x, None, aux
    if kind == "dec":
        h = _apply_norm(cfg, p, "ln_attn", x)
        a, kv_cache = _self_attn(cfg, p, h, ctx, cache=cache)
        x = x + a
        h = _apply_norm(cfg, p, "ln_cross", x)
        ca, c_cache = _cross_attn(cfg, p, h, ctx, prefix="c_", cache=cache)
        x = x + ca
        x = x + _mlp(cfg, p, _apply_norm(cfg, p, "ln_mlp", x))
        new_cache = None
        if kv_cache is not None or c_cache is not None:
            new_cache = {**(kv_cache or {}), **(c_cache or {})}
        return x, new_cache, aux
    if kind == "cross":
        h = _apply_norm(cfg, p, "ln_attn", x)
        ca, c_cache = _cross_attn(cfg, p, h, ctx, cache=cache)
        x = x + jnp.tanh(p["attn_gate"]).astype(x.dtype) * ca
        m = _mlp(cfg, p, _apply_norm(cfg, p, "ln_mlp", x))
        x = x + jnp.tanh(p["mlp_gate"]).astype(x.dtype) * m
        return x, c_cache, aux
    if kind == "mamba":
        h = _apply_norm(cfg, p, "ln_attn", x)
        y, new_cache = _mamba_block(cfg, p, h, ctx, cache)
        return x + y, new_cache, aux
    if kind == "rec":
        h = _apply_norm(cfg, p, "ln_attn", x)
        y, new_cache = _rec_block(cfg, p, h, ctx, cache)
        x = x + y
        x = x + _mlp(cfg, p, _apply_norm(cfg, p, "ln_mlp", x))
        return x, new_cache, aux
    raise ValueError(kind)


# ---------------------------------------------------------------------------
# Stack: prefix (unrolled) + pattern x n_periods (scanned) + tail (unrolled)
# ---------------------------------------------------------------------------

def stack_defs_tree(cfg: ArchConfig, pattern=None, n_periods=None,
                    prefix=None, tail=None) -> Dict:
    pattern = cfg.pattern if pattern is None else pattern
    n_periods = cfg.n_periods if n_periods is None else n_periods
    prefix = cfg.prefix if prefix is None else prefix
    tail = cfg.tail if tail is None else tail
    period = {f"{j}_{k}": block_defs(cfg, k) for j, k in enumerate(pattern)}
    tree = {"prefix": {f"{j}_{k}": block_defs(cfg, k) for j, k in enumerate(prefix)},
            "tail": {f"{j}_{k}": block_defs(cfg, k) for j, k in enumerate(tail)}}
    if n_periods:
        tree["scan"] = stack_defs(period, n_periods, "layers")
    return tree


def stack_cache_defs(cfg: ArchConfig, batch: int, cache_len: int,
                     pattern=None, n_periods=None, prefix=None, tail=None) -> Dict:
    pattern = cfg.pattern if pattern is None else pattern
    n_periods = cfg.n_periods if n_periods is None else n_periods
    prefix = cfg.prefix if prefix is None else prefix
    tail = cfg.tail if tail is None else tail
    period = {f"{j}_{k}": cache_defs(cfg, k, batch, cache_len)
              for j, k in enumerate(pattern)}
    tree = {"prefix": {f"{j}_{k}": cache_defs(cfg, k, batch, cache_len)
                       for j, k in enumerate(prefix)},
            "tail": {f"{j}_{k}": cache_defs(cfg, k, batch, cache_len)
                     for j, k in enumerate(tail)}}
    if n_periods:
        tree["scan"] = stack_defs(period, n_periods, "layers")
    return tree


def run_stack(cfg: ArchConfig, params: Dict, x, ctx,
              caches: Optional[Dict] = None,
              pattern=None, n_periods=None, prefix=None, tail=None):
    """Returns (x, new_caches (or None), aux)."""
    pattern = cfg.pattern if pattern is None else pattern
    n_periods = cfg.n_periods if n_periods is None else n_periods
    prefix = cfg.prefix if prefix is None else prefix
    tail = cfg.tail if tail is None else tail
    mode = ctx["mode"]
    want_cache = mode in ("prefill", "decode")
    aux = jnp.zeros((), jnp.float32)
    new_caches: Dict[str, Any] = {"prefix": {}, "tail": {}}

    def seq_blocks(x, aux, names, pgroup, cgroup, out_group):
        for name in names:
            kind = name.split("_", 1)[1]
            cache = cgroup.get(name) if cgroup else None
            x, nc, a = block_apply(cfg, kind, pgroup[name], x, ctx, cache)
            if want_cache:
                out_group[name] = nc if nc is not None else {}
            aux = aux + a
        return x, aux

    pre_names = [f"{j}_{k}" for j, k in enumerate(prefix)]
    x, aux = seq_blocks(x, aux, pre_names, params.get("prefix", {}),
                        (caches or {}).get("prefix"), new_caches["prefix"])

    if n_periods:
        period_names = [f"{j}_{k}" for j, k in enumerate(pattern)]

        def body(carry, xs):
            xx, aa = carry
            pparams, pcaches = xs
            outs = {}
            for name in period_names:
                kind = name.split("_", 1)[1]
                cache = pcaches.get(name) if pcaches is not None else None
                xx, nc, a = block_apply(cfg, kind, pparams[name], xx, ctx, cache)
                outs[name] = nc if (nc is not None and want_cache) else {}
                aa = aa + a
            return (xx, aa), (outs if want_cache else {})

        if cfg.remat:
            policy = (jax.checkpoint_policies.dots_with_no_batch_dims_saveable
                      if cfg.remat_policy == "dots"
                      else jax.checkpoint_policies.nothing_saveable)
            body = jax.checkpoint(body, policy=policy)
        scan_caches = (caches or {}).get("scan")
        xs = (params["scan"], scan_caches)
        (x, aux), scan_out = jax.lax.scan(body, (x, aux), xs)
        if want_cache:
            new_caches["scan"] = scan_out

    tail_names = [f"{j}_{k}" for j, k in enumerate(tail)]
    x, aux = seq_blocks(x, aux, tail_names, params.get("tail", {}),
                        (caches or {}).get("tail"), new_caches["tail"])
    return x, (new_caches if want_cache else None), aux
