"""Mixture-of-Experts: top-k routing with sort-based capacity dispatch.

Dispatch is **DP-group-local** (perf iteration #1, EXPERIMENTS.md §Perf):
tokens arrive as ``(groups, T_local, d_model)`` with ``groups`` = the
data-parallel world size, sharded over the dp axes. Routing, sorting and
the capacity scatter are vmapped over the group axis, so they never index
across groups — GSPMD keeps them communication-free. The expert einsum runs
on a ``(group -> dp, expert -> model)`` 2D-sharded buffer against
model-sharded expert weights, i.e. each (dp, ep) device pair processes its
own tokens through its own expert slice (standard EP x DP).

The naive formulation (global token indices into the full (T, E) array)
made GSPMD replicate the whole token activation per MoE layer —
measured at ~84% of all collective bytes for kimi-k2 before this change.

The (token-slot <-> expert-slot) relayout this implements is the
distributed-BP pattern of DESIGN.md §3; the sort handles the data-dependent
part, the BMMC algebra the static part.

Sort-based dispatch scales to 384-expert configs (kimi-k2) where a dense
one-hot dispatch tensor (T x X x C) would be infeasible.
"""
from __future__ import annotations

from typing import Callable, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np


def router_topk(logits, k: int):
    """logits: (T, X) f32. Returns (weights (T,k), ids (T,k), aux_loss)."""
    probs = jax.nn.softmax(logits.astype(jnp.float32), axis=-1)
    vals, ids = jax.lax.top_k(probs, k)
    weights = vals / jnp.maximum(vals.sum(-1, keepdims=True), 1e-9)
    # Switch-style load-balance loss: X * mean_x(frac_tokens_x * mean_prob_x)
    x = logits.shape[-1]
    frac = jnp.zeros((x,), jnp.float32).at[ids.reshape(-1)].add(1.0)
    frac = frac / jnp.maximum(frac.sum(), 1.0)
    aux = x * jnp.sum(frac * probs.mean(0))
    return weights.astype(jnp.float32), ids, aux


def _dispatch_group(x, router_w, *, top_k: int, cap: int, xn: int):
    """Per-group routing + capacity pack. x: (T_local, E).

    Returns (buf (X*C, E), slot, tok_sorted, w_sorted, keep, aux).
    """
    t, e = x.shape
    logits = jnp.einsum("te,ex->tx", x.astype(jnp.float32),
                        router_w.astype(jnp.float32))
    weights, ids, aux = router_topk(logits, top_k)

    flat_ids = ids.reshape(-1)                       # (T*k,)
    order = jnp.argsort(flat_ids)
    eid_sorted = jnp.take(flat_ids, order)
    tok_sorted = order // top_k                      # token per sorted slot
    w_sorted = jnp.take(weights.reshape(-1), order)

    starts = jnp.searchsorted(eid_sorted, jnp.arange(xn), side="left")
    pos = jnp.arange(t * top_k) - jnp.take(starts, eid_sorted)
    keep = pos < cap
    slot = jnp.where(keep, eid_sorted * cap + pos, xn * cap)  # OOB -> dropped

    buf = jnp.zeros((xn * cap, e), x.dtype)
    buf = buf.at[slot].set(jnp.take(x, tok_sorted, axis=0), mode="drop")
    return buf, slot, tok_sorted, w_sorted, keep, aux


def _combine_group(yexp, slot, tok_sorted, w_sorted, keep, t):
    """Per-group un-permute + weighted sum. yexp: (X*C, E)."""
    e = yexp.shape[-1]
    y_sorted = jnp.take(yexp, jnp.minimum(slot, yexp.shape[0] - 1), axis=0)
    y_sorted = jnp.where(keep[:, None], y_sorted, 0)
    y_sorted = y_sorted * w_sorted[:, None].astype(yexp.dtype)
    return jnp.zeros((t, e), yexp.dtype).at[tok_sorted].add(y_sorted)


def moe_ffn(x, router_w, w_gate, w_up, w_down, *, top_k: int,
            capacity_factor: float = 1.25,
            constrain_buf: Optional[Callable] = None):
    """x: (G, T_local, E) grouped tokens. Expert weights: (X, E, F) etc.

    Returns (out (G, T_local, E), aux_loss). Tokens beyond per-group expert
    capacity are dropped (standard capacity-based MoE semantics).
    """
    g, t, e = x.shape
    xn = router_w.shape[1]
    cap = int(np.ceil(top_k * t * capacity_factor / xn))
    cap = max(8, int(np.ceil(cap / 8)) * 8)
    cap = min(cap, t * top_k)

    buf, slot, tok_sorted, w_sorted, keep, aux = jax.vmap(
        lambda xg: _dispatch_group(xg, router_w, top_k=top_k, cap=cap, xn=xn)
    )(x)
    buf = buf.reshape(g, xn, cap, e)
    if constrain_buf is not None:
        buf = constrain_buf(buf)

    gate = jnp.einsum("gxce,xef->gxcf", buf, w_gate)
    up = jnp.einsum("gxce,xef->gxcf", buf, w_up)
    h = jax.nn.silu(gate.astype(jnp.float32)).astype(x.dtype) * up
    yexp = jnp.einsum("gxcf,xfe->gxce", h, w_down)
    if constrain_buf is not None:
        yexp = constrain_buf(yexp)
    yexp = yexp.reshape(g, xn * cap, e)

    out = jax.vmap(_combine_group, in_axes=(0, 0, 0, 0, 0, None))(
        yexp, slot, tok_sorted, w_sorted, keep, t)
    return out, aux.mean()
