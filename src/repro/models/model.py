"""Model facade: ArchConfig -> parameter defs, loss, prefill, decode.

All entry points are pure functions of (cfg, params, inputs) suitable for
``jax.jit`` + AOT ``.lower().compile()`` in the dry-run.
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from ..configs.base import ArchConfig
from ..parallel.sharding import (activation_constrainer, dp_size,
                                 moe_buffer_constrainer)
from .layers import ParamDef, init_params, rms_norm, layer_norm, shape_tree, axes_tree
from .transformer import (run_stack, stack_cache_defs, stack_defs_tree)


def _make_ctx(cfg: "ArchConfig", mode: str, mesh, pos) -> Dict:
    return {"mode": mode, "pos": pos, "mesh": mesh,
            "constrain": activation_constrainer(
                mesh, seq_parallel=getattr(cfg, "seq_parallel", False)),
            "constrain_moe": moe_buffer_constrainer(mesh),
            "dp_groups": dp_size(mesh)}


# ---------------------------------------------------------------------------
# Parameter definitions
# ---------------------------------------------------------------------------

def model_defs(cfg: ArchConfig) -> Dict:
    dt = cfg.dtype
    defs: Dict = {
        "embed": ParamDef((cfg.vocab_size, cfg.d_model), ("vocab", "embed"), dt),
        "stack": stack_defs_tree(cfg),
    }
    if cfg.norm == "ln":
        defs["final_scale"] = ParamDef((cfg.d_model,), ("embed",), dt, "ones")
        defs["final_bias"] = ParamDef((cfg.d_model,), ("embed",), dt, "zeros")
    else:
        defs["final_scale"] = ParamDef((cfg.d_model,), ("embed",), dt, "zeros")
    if not cfg.tie_embeddings:
        defs["lm_head"] = ParamDef((cfg.vocab_size, cfg.d_model), ("vocab", "embed"), dt)
    if cfg.is_encdec:
        defs["enc_stack"] = stack_defs_tree(
            cfg, pattern=cfg.enc_pattern, n_periods=cfg.n_enc_periods,
            prefix=(), tail=())
        defs["enc_final_scale"] = ParamDef((cfg.d_model,), ("embed",), dt, "zeros")
    return defs


def model_cache_defs(cfg: ArchConfig, batch: int, cache_len: int) -> Dict:
    return stack_cache_defs(cfg, batch, cache_len)


def init(cfg: ArchConfig, key) -> Dict:
    return init_params(model_defs(cfg), key)


def param_shapes(cfg: ArchConfig):
    return shape_tree(model_defs(cfg))


def param_axes(cfg: ArchConfig):
    return axes_tree(model_defs(cfg))


# ---------------------------------------------------------------------------
# Forward passes
# ---------------------------------------------------------------------------

def _final_norm(cfg, params, x, prefix=""):
    if cfg.norm == "ln":
        return layer_norm(x, params[f"{prefix}final_scale"], params[f"{prefix}final_bias"])
    return rms_norm(x, params[f"{prefix}final_scale"])


def _head(cfg, params, x):
    table = params["embed"] if cfg.tie_embeddings else params["lm_head"]
    return jnp.einsum("bse,ve->bsv", x, table,
                      preferred_element_type=jnp.float32)


def _encode(cfg, params, src, ctx):
    """Run the encoder stack over stub source embeddings (audio)."""
    x, _, _ = run_stack(cfg, params["enc_stack"], src,
                        {**ctx, "mode": "train", "pos": 0},
                        pattern=cfg.enc_pattern, n_periods=cfg.n_enc_periods,
                        prefix=(), tail=())
    return rms_norm(x, params["enc_final_scale"])


def _enc_states(cfg, params, batch: Dict, ctx):
    """Cross-attention memory: encoder output (audio) or raw patch embeds (vlm)."""
    if cfg.is_encdec:
        return _encode(cfg, params, batch["src"], ctx)
    if cfg.family == "vlm":
        return batch["src"]
    return None


def forward(cfg: ArchConfig, params: Dict, batch: Dict, *, mode: str = "train",
            mesh=None):
    """batch: {"tokens": (B,S) int32, optional "src": (B,Ssrc,E)}.

    Returns (logits (B,S,V) f32, caches-or-None, aux).
    """
    ctx = _make_ctx(cfg, mode, mesh, jnp.zeros((), jnp.int32))
    ctx["enc"] = _enc_states(cfg, params, batch, ctx)
    x = jnp.take(params["embed"], batch["tokens"], axis=0)
    x, caches, aux = run_stack(cfg, params["stack"], x, ctx)
    x = _final_norm(cfg, params, x)
    logits = _head(cfg, params, x)
    return logits, caches, aux


def loss_fn(cfg: ArchConfig, params: Dict, batch: Dict, *, mesh=None):
    """Causal-LM cross entropy (+ MoE aux). batch needs "labels" (B,S)."""
    logits, _, aux = forward(cfg, params, batch, mode="train", mesh=mesh)
    logp = jax.nn.log_softmax(logits, axis=-1)
    ll = jnp.take_along_axis(logp, batch["labels"][..., None], axis=-1)[..., 0]
    loss = -jnp.mean(ll)
    return loss + 0.01 * aux, {"ce": loss, "aux": aux}


def prefill(cfg: ArchConfig, params: Dict, batch: Dict, *, mesh=None):
    """Full-sequence forward emitting decode caches + last-position logits."""
    logits, caches, _ = forward(cfg, params, batch, mode="prefill", mesh=mesh)
    return logits[:, -1:], caches


def grow_caches(caches: Dict, old_len: int, new_len: int) -> Dict:
    """Extend KV caches from ``old_len`` to ``new_len`` positions.

    Scanned caches carry a leading layer axis (layers, B, S, ...): their
    sequence axis is 2; prefix/tail caches use axis 1. Only leaves whose
    sequence axis currently equals ``old_len`` are padded (SSM/RG-LRU
    state and conv leaves are length-independent and pass through).
    """
    pad = new_len - old_len
    if pad <= 0:
        return caches

    def pad_leaf(x, axis):
        if x.ndim > axis and x.shape[axis] == old_len:
            widths = [(0, 0)] * x.ndim
            widths[axis] = (0, pad)
            return jnp.pad(x, widths)
        return x

    out = {}
    for group, sub in caches.items():
        axis = 2 if group == "scan" else 1
        out[group] = jax.tree.map(lambda x: pad_leaf(x, axis), sub)
    return out


def decode_step(cfg: ArchConfig, params: Dict, caches: Dict, tokens, pos,
                *, mesh=None):
    """One-token decode. tokens: (B,1) int32; pos: () int32 = # valid tokens.

    Returns (logits (B,1,V), new_caches).
    """
    ctx = _make_ctx(cfg, "decode", mesh, pos)
    ctx["enc"] = None
    x = jnp.take(params["embed"], tokens, axis=0)
    x, new_caches, _ = run_stack(cfg, params["stack"], x, ctx, caches)
    x = _final_norm(cfg, params, x)
    return _head(cfg, params, x), new_caches
