"""Expert-parallel MoE with explicit all-to-all dispatch (shard_map).

The GSPMD capacity formulation (models/moe.py) ends every MoE layer with an
all-reduce of the full token activation across the model axis (each EP rank
holds partial expert outputs). Here tokens instead *travel*: each device
routes its own token slice, packs per-peer send buffers, `all_to_all`s them
to the experts' owners, computes locally, and `all_to_all`s results back —
wire bytes ~ top_k * capacity_factor * token-slice bytes instead of a full
activation ring reduction (EXPERIMENTS.md §Perf hillclimb 5).

This is the data-dependent instance of the distributed-BP pattern in
core/distributed.py: the (token-slot <-> expert-slot) relayout is the
exchange round; routing metadata rides along with the payload.

``dispatch_shuffle=True`` adds a *static* BMMC permutation of the send
slots inside each peer's capacity block (routing metadata rides along, so
expert compute is unaffected; the return trip is inverse-permuted) — the
differentiable batched BMMC executor as a dispatch layer (DESIGN.md §9).
It de-correlates slot addresses from routing order, and because it is
offline and affine it fuses with any surrounding BMMC relayout instead of
costing a data-dependent gather. The permutation itself is exactly
neutral; enabling the flag also rounds the per-peer capacity up to a
power of two (the shuffle's block size), which can *reduce* token drops
versus the unshuffled run when a peer block overflows — at equal
effective capacity the outputs are bit-identical (tested).

Token layout inside shard_map: batch over the dp axes, **sequence over
``model``** — the sequence-parallel residual layout.
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from ..combinators.execute import perm_apply
from ..core.bmmc import Bmmc
from ..kernels.ref import bmmc_ref


def _slot_shuffle(buf, bmmc, *, inverse: bool = False):
    """Permute the slot axis (axis 1) of a (peers, cap[, e]) buffer by a
    static BMMC; every peer block shares the one offline plan. Integer
    metadata takes the plain gather (no VJP machinery on int dtypes)."""
    b = bmmc.inverse() if inverse else bmmc
    if jnp.issubdtype(buf.dtype, jnp.integer):
        return bmmc_ref(buf, b, batched=True)
    return perm_apply(buf, b, "ref", True)


def _device_moe(x, router_w, w_gate, w_up, w_down, *, top_k: int,
                n_experts: int, capacity_factor: float,
                model_axis: str, dp_axes: Tuple[str, ...],
                dispatch_shuffle: bool = False):
    """Per-device body. x: (T_local, E). Expert weights arrive model-sharded
    on dim 0 and FSDP-sharded over dp on the embed dim; gathered here."""
    t, e = x.shape
    if hasattr(jax.lax, "axis_size"):
        n_peers = jax.lax.axis_size(model_axis)
    else:  # jax < 0.5: psum of a python literal folds to the static size
        n_peers = int(jax.lax.psum(1, model_axis))
    xpp = n_experts // n_peers                     # experts per peer

    def gather_dp(w, axis):
        for ax in dp_axes:
            w = jax.lax.all_gather(w, ax, axis=axis, tiled=True)
        return w
    wg = gather_dp(w_gate, 1)                      # (xpp, E, F)
    wu = gather_dp(w_up, 1)
    wd = gather_dp(w_down, 2)                      # (xpp, F, E)

    # -- route ----------------------------------------------------------------
    logits = jnp.einsum("te,ex->tx", x.astype(jnp.float32),
                        router_w.astype(jnp.float32))
    probs = jax.nn.softmax(logits, axis=-1)
    vals, ids = jax.lax.top_k(probs, top_k)        # (T, k)
    weights = (vals / jnp.maximum(vals.sum(-1, keepdims=True), 1e-9))
    frac = jnp.zeros((n_experts,), jnp.float32).at[ids.reshape(-1)].add(1.0)
    frac = frac / jnp.maximum(frac.sum(), 1.0)
    aux = n_experts * jnp.sum(frac * probs.mean(0))
    aux = jax.lax.pmean(aux, dp_axes + (model_axis,))

    # -- pack per-peer send buffers --------------------------------------------
    cap = int(np.ceil(top_k * t * capacity_factor / n_peers))
    cap = max(8, int(np.ceil(cap / 8)) * 8)
    if dispatch_shuffle:  # slot shuffle needs a power-of-two block
        cap = 1 << (cap - 1).bit_length()
        slot_bmmc = Bmmc.bit_reverse(cap.bit_length() - 1)
    flat_ids = ids.reshape(-1)
    peer = flat_ids // xpp
    order = jnp.argsort(peer)
    peer_s = jnp.take(peer, order)
    eid_s = (jnp.take(flat_ids, order) % xpp).astype(jnp.int32)
    tok_s = order // top_k
    w_s = jnp.take(weights.reshape(-1), order)

    starts = jnp.searchsorted(peer_s, jnp.arange(n_peers), side="left")
    pos = jnp.arange(t * top_k) - jnp.take(starts, peer_s)
    keep = pos < cap
    slot = jnp.where(keep, peer_s * cap + pos, n_peers * cap)  # OOB -> drop

    send = jnp.zeros((n_peers * cap, e), x.dtype)
    send = send.at[slot].set(jnp.take(x, tok_s, axis=0), mode="drop")
    send_eid = jnp.full((n_peers * cap,), xpp, jnp.int32)      # pad sentinel
    send_eid = send_eid.at[slot].set(eid_s, mode="drop")

    # -- exchange: tokens travel to their experts' owners ----------------------
    send3 = send.reshape(n_peers, cap, e)
    send_eid2 = send_eid.reshape(n_peers, cap)
    if dispatch_shuffle:  # static slot relayout; eids ride along
        send3 = _slot_shuffle(send3, slot_bmmc)
        send_eid2 = _slot_shuffle(send_eid2, slot_bmmc)
    recv = jax.lax.all_to_all(send3, model_axis,
                              split_axis=0, concat_axis=0, tiled=True)
    recv_eid = jax.lax.all_to_all(send_eid2, model_axis,
                                  split_axis=0, concat_axis=0, tiled=True)
    rt = recv.reshape(n_peers * cap, e)
    re_ = recv_eid.reshape(n_peers * cap)

    # -- local expert compute: pack by local expert id --------------------------
    order2 = jnp.argsort(re_)
    eid2 = jnp.take(re_, order2)
    # rt.shape[0] = n_peers*cap already carries the capacity_factor slack;
    # dividing by xpp keeps the same per-expert overprovisioning.
    cap2 = max(8, int(np.ceil(rt.shape[0] / xpp / 8)) * 8)
    cap2 = min(cap2, rt.shape[0])
    starts2 = jnp.searchsorted(eid2, jnp.arange(xpp), side="left")
    pos2 = jnp.arange(rt.shape[0]) - jnp.take(starts2, eid2)
    keep2 = (pos2 < cap2) & (eid2 < xpp)           # drop pad sentinels
    slot2 = jnp.where(keep2, eid2 * cap2 + pos2, xpp * cap2)
    buf = jnp.zeros((xpp * cap2, e), x.dtype)
    buf = buf.at[slot2].set(jnp.take(rt, order2, axis=0), mode="drop")
    buf = buf.reshape(xpp, cap2, e)

    g = jnp.einsum("xce,xef->xcf", buf, wg)
    u = jnp.einsum("xce,xef->xcf", buf, wu)
    h = jax.nn.silu(g.astype(jnp.float32)).astype(x.dtype) * u
    yb = jnp.einsum("xcf,xfe->xce", h, wd).reshape(xpp * cap2, e)

    # un-permute local results back to recv-slot order
    y_sorted = jnp.take(yb, jnp.minimum(slot2, xpp * cap2 - 1), axis=0)
    y_sorted = jnp.where(keep2[:, None], y_sorted, 0)
    y_recv = jnp.zeros((rt.shape[0], e), x.dtype).at[order2].add(y_sorted)

    # -- return trip + weighted combine ----------------------------------------
    back = jax.lax.all_to_all(y_recv.reshape(n_peers, cap, e), model_axis,
                              split_axis=0, concat_axis=0, tiled=True)
    if dispatch_shuffle:  # undo the slot relayout: back to packing order
        back = _slot_shuffle(back, slot_bmmc, inverse=True)
    back = back.reshape(n_peers * cap, e)
    y_slot = jnp.take(back, jnp.minimum(slot, n_peers * cap - 1), axis=0)
    y_slot = jnp.where(keep[:, None], y_slot, 0)
    y_slot = y_slot * w_s[:, None].astype(x.dtype)
    out = jnp.zeros((t, e), x.dtype).at[tok_s].add(y_slot)
    return out, aux


def moe_ffn_a2a(x, router_w, w_gate, w_up, w_down, *, top_k: int,
                capacity_factor: float, mesh, dispatch_shuffle: bool = False):
    """x: (B, S, E). Returns (out (B,S,E), aux). shard_map over the mesh:
    batch -> dp axes, sequence -> model axis (sequence-parallel layout).
    ``dispatch_shuffle`` BMMC-permutes send slots within each peer block
    (neutral at equal capacity; rounds capacity to a power of two — see
    module docstring)."""
    from jax.experimental.shard_map import shard_map
    from ..parallel.sharding import dp_axes as _dp
    dp = _dp(mesh)
    dp_entry = dp if len(dp) > 1 else dp[0]
    n_experts = router_w.shape[1]

    body = functools.partial(
        _device_moe, top_k=top_k, n_experts=n_experts,
        capacity_factor=capacity_factor, model_axis="model", dp_axes=dp,
        dispatch_shuffle=dispatch_shuffle)

    def fn(xg, rw, wgt, wupt, wdt):
        b, s, e = xg.shape
        out, aux = body(xg.reshape(b * s, e), rw, wgt, wupt, wdt)
        return out.reshape(b, s, e), aux

    emb_spec = dp_entry  # FSDP axis for the embed dim of expert weights
    mapped = shard_map(
        fn, mesh=mesh,
        in_specs=(P(dp_entry, "model", None),       # x
                  P(None, None),                     # router (replicated)
                  P("model", emb_spec, None),        # w_gate (X, E, F)
                  P("model", emb_spec, None),        # w_up
                  P("model", None, emb_spec)),       # w_down (X, F, E)
        out_specs=(P(dp_entry, "model", None), P()),
        check_rep=False)
    return mapped(x, router_w, w_gate, w_up, w_down)
