"""Expert-parallel MoE with explicit all-to-all dispatch (shard_map).

The GSPMD capacity formulation (models/moe.py) ends every MoE layer with an
all-reduce of the full token activation across the model axis (each EP rank
holds partial expert outputs). Here tokens instead *travel*: each device
routes its own token slice, packs per-peer send buffers, `all_to_all`s them
to the experts' owners, computes locally, and `all_to_all`s results back —
wire bytes ~ top_k * capacity_factor * token-slice bytes instead of a full
activation ring reduction (EXPERIMENTS.md §Perf hillclimb 5).

This is the data-dependent instance of the distributed-BP pattern in
core/distributed.py: the (token-slot <-> expert-slot) relayout is the
exchange round; routing metadata rides along with the payload.

Token layout inside shard_map: batch over the dp axes, **sequence over
``model``** — the sequence-parallel residual layout.
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P


def _device_moe(x, router_w, w_gate, w_up, w_down, *, top_k: int,
                n_experts: int, capacity_factor: float,
                model_axis: str, dp_axes: Tuple[str, ...]):
    """Per-device body. x: (T_local, E). Expert weights arrive model-sharded
    on dim 0 and FSDP-sharded over dp on the embed dim; gathered here."""
    t, e = x.shape
    if hasattr(jax.lax, "axis_size"):
        n_peers = jax.lax.axis_size(model_axis)
    else:  # jax < 0.5: psum of a python literal folds to the static size
        n_peers = int(jax.lax.psum(1, model_axis))
    xpp = n_experts // n_peers                     # experts per peer

    def gather_dp(w, axis):
        for ax in dp_axes:
            w = jax.lax.all_gather(w, ax, axis=axis, tiled=True)
        return w
    wg = gather_dp(w_gate, 1)                      # (xpp, E, F)
    wu = gather_dp(w_up, 1)
    wd = gather_dp(w_down, 2)                      # (xpp, F, E)

    # -- route ----------------------------------------------------------------
    logits = jnp.einsum("te,ex->tx", x.astype(jnp.float32),
                        router_w.astype(jnp.float32))
    probs = jax.nn.softmax(logits, axis=-1)
    vals, ids = jax.lax.top_k(probs, top_k)        # (T, k)
    weights = (vals / jnp.maximum(vals.sum(-1, keepdims=True), 1e-9))
    frac = jnp.zeros((n_experts,), jnp.float32).at[ids.reshape(-1)].add(1.0)
    frac = frac / jnp.maximum(frac.sum(), 1.0)
    aux = n_experts * jnp.sum(frac * probs.mean(0))
    aux = jax.lax.pmean(aux, dp_axes + (model_axis,))

    # -- pack per-peer send buffers --------------------------------------------
    cap = int(np.ceil(top_k * t * capacity_factor / n_peers))
    cap = max(8, int(np.ceil(cap / 8)) * 8)
    flat_ids = ids.reshape(-1)
    peer = flat_ids // xpp
    order = jnp.argsort(peer)
    peer_s = jnp.take(peer, order)
    eid_s = (jnp.take(flat_ids, order) % xpp).astype(jnp.int32)
    tok_s = order // top_k
    w_s = jnp.take(weights.reshape(-1), order)

    starts = jnp.searchsorted(peer_s, jnp.arange(n_peers), side="left")
    pos = jnp.arange(t * top_k) - jnp.take(starts, peer_s)
    keep = pos < cap
    slot = jnp.where(keep, peer_s * cap + pos, n_peers * cap)  # OOB -> drop

    send = jnp.zeros((n_peers * cap, e), x.dtype)
    send = send.at[slot].set(jnp.take(x, tok_s, axis=0), mode="drop")
    send_eid = jnp.full((n_peers * cap,), xpp, jnp.int32)      # pad sentinel
    send_eid = send_eid.at[slot].set(eid_s, mode="drop")

    # -- exchange: tokens travel to their experts' owners ----------------------
    recv = jax.lax.all_to_all(send.reshape(n_peers, cap, e), model_axis,
                              split_axis=0, concat_axis=0, tiled=True)
    recv_eid = jax.lax.all_to_all(send_eid.reshape(n_peers, cap), model_axis,
                                  split_axis=0, concat_axis=0, tiled=True)
    rt = recv.reshape(n_peers * cap, e)
    re_ = recv_eid.reshape(n_peers * cap)

    # -- local expert compute: pack by local expert id --------------------------
    order2 = jnp.argsort(re_)
    eid2 = jnp.take(re_, order2)
    # rt.shape[0] = n_peers*cap already carries the capacity_factor slack;
    # dividing by xpp keeps the same per-expert overprovisioning.
    cap2 = max(8, int(np.ceil(rt.shape[0] / xpp / 8)) * 8)
    cap2 = min(cap2, rt.shape[0])
    starts2 = jnp.searchsorted(eid2, jnp.arange(xpp), side="left")
    pos2 = jnp.arange(rt.shape[0]) - jnp.take(starts2, eid2)
    keep2 = (pos2 < cap2) & (eid2 < xpp)           # drop pad sentinels
    slot2 = jnp.where(keep2, eid2 * cap2 + pos2, xpp * cap2)
    buf = jnp.zeros((xpp * cap2, e), x.dtype)
    buf = buf.at[slot2].set(jnp.take(rt, order2, axis=0), mode="drop")
    buf = buf.reshape(xpp, cap2, e)

    g = jnp.einsum("xce,xef->xcf", buf, wg)
    u = jnp.einsum("xce,xef->xcf", buf, wu)
    h = jax.nn.silu(g.astype(jnp.float32)).astype(x.dtype) * u
    yb = jnp.einsum("xcf,xfe->xce", h, wd).reshape(xpp * cap2, e)

    # un-permute local results back to recv-slot order
    y_sorted = jnp.take(yb, jnp.minimum(slot2, xpp * cap2 - 1), axis=0)
    y_sorted = jnp.where(keep2[:, None], y_sorted, 0)
    y_recv = jnp.zeros((rt.shape[0], e), x.dtype).at[order2].add(y_sorted)

    # -- return trip + weighted combine ----------------------------------------
    back = jax.lax.all_to_all(y_recv.reshape(n_peers, cap, e), model_axis,
                              split_axis=0, concat_axis=0, tiled=True)
    back = back.reshape(n_peers * cap, e)
    y_slot = jnp.take(back, jnp.minimum(slot, n_peers * cap - 1), axis=0)
    y_slot = jnp.where(keep[:, None], y_slot, 0)
    y_slot = y_slot * w_s[:, None].astype(x.dtype)
    out = jnp.zeros((t, e), x.dtype).at[tok_s].add(y_slot)
    return out, aux


def moe_ffn_a2a(x, router_w, w_gate, w_up, w_down, *, top_k: int,
                capacity_factor: float, mesh):
    """x: (B, S, E). Returns (out (B,S,E), aux). shard_map over the mesh:
    batch -> dp axes, sequence -> model axis (sequence-parallel layout)."""
    from jax.experimental.shard_map import shard_map
    from ..parallel.sharding import dp_axes as _dp
    dp = _dp(mesh)
    dp_entry = dp if len(dp) > 1 else dp[0]
    n_experts = router_w.shape[1]

    body = functools.partial(
        _device_moe, top_k=top_k, n_experts=n_experts,
        capacity_factor=capacity_factor, model_axis="model", dp_axes=dp)

    def fn(xg, rw, wgt, wupt, wdt):
        b, s, e = xg.shape
        out, aux = body(xg.reshape(b * s, e), rw, wgt, wupt, wdt)
        return out.reshape(b, s, e), aux

    emb_spec = dp_entry  # FSDP axis for the embed dim of expert weights
    mapped = shard_map(
        fn, mesh=mesh,
        in_specs=(P(dp_entry, "model", None),       # x
                  P(None, None),                     # router (replicated)
                  P("model", emb_spec, None),        # w_gate (X, E, F)
                  P("model", emb_spec, None),        # w_up
                  P("model", None, emb_spec)),       # w_down (X, F, E)
        out_specs=(P(dp_entry, "model", None), P()),
        check_rep=False)
    return mapped(x, router_w, w_gate, w_up, w_down)
