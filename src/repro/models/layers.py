"""Shared layer primitives + parameter-definition infrastructure.

Parameters are plain nested dicts of jax arrays. Shapes/logical axes are
declared via ``ParamDef`` trees so the same definition serves:

* real initialization (CPU smoke tests / the end-to-end driver),
* shape-only ``ShapeDtypeStruct`` trees + ``PartitionSpec`` trees for the
  multi-pod dry-run (no allocation),
* optimizer-state construction (mirrors the param tree).

Logical axis names are mapped to mesh axes by ``repro.parallel.sharding``.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class ParamDef:
    shape: Tuple[int, ...]
    axes: Tuple[Optional[str], ...]          # logical axis names, len == ndim
    dtype: Any = jnp.bfloat16
    init: str = "normal"                      # normal | zeros | ones | small
    scale: float = 0.02

    def __post_init__(self):
        assert len(self.shape) == len(self.axes), (self.shape, self.axes)


def tree_map_defs(fn, tree):
    """Map over ParamDef leaves of a nested dict."""
    if isinstance(tree, ParamDef):
        return fn(tree)
    return {k: tree_map_defs(fn, v) for k, v in tree.items()}


def init_params(defs, key) -> Dict:
    """Materialize a ParamDef tree (for smoke tests / real training)."""
    leaves = []

    def collect(d):
        leaves.append(d)
        return d

    tree_map_defs(collect, defs)
    keys = jax.random.split(key, max(len(leaves), 1))
    it = iter(range(len(leaves)))

    def make(d: ParamDef):
        i = next(it)
        if d.init == "zeros":
            return jnp.zeros(d.shape, d.dtype)
        if d.init == "ones":
            return jnp.ones(d.shape, d.dtype)
        std = d.scale
        if d.init == "small":
            std = d.scale / math.sqrt(max(d.shape[0], 1))
        return (jax.random.normal(keys[i], d.shape, jnp.float32) * std).astype(d.dtype)

    return tree_map_defs(make, defs)


def shape_tree(defs):
    """ShapeDtypeStruct tree — the dry-run stand-in (no allocation)."""
    return tree_map_defs(lambda d: jax.ShapeDtypeStruct(d.shape, d.dtype), defs)


def axes_tree(defs):
    return tree_map_defs(lambda d: d.axes, defs)


def stack_defs(defs, n: int, axis_name: Optional[str] = None):
    """Prepend a stacked (scan) layer axis to every leaf."""
    return tree_map_defs(
        lambda d: ParamDef((n,) + d.shape, (axis_name,) + d.axes, d.dtype,
                           d.init, d.scale),
        defs)


# ---------------------------------------------------------------------------
# Numerics
# ---------------------------------------------------------------------------

def rms_norm(x, scale, eps: float = 1e-6):
    dt = x.dtype
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    return ((xf * jax.lax.rsqrt(var + eps)) * (1.0 + scale.astype(jnp.float32))).astype(dt)


def layer_norm(x, scale, bias, eps: float = 1e-5):
    dt = x.dtype
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    y = (xf - mu) * jax.lax.rsqrt(var + eps)
    return (y * scale.astype(jnp.float32) + bias.astype(jnp.float32)).astype(dt)


def swiglu(x, w_gate, w_up, w_down):
    g = jnp.einsum("...e,ef->...f", x, w_gate)
    u = jnp.einsum("...e,ef->...f", x, w_up)
    h = jax.nn.silu(g.astype(jnp.float32)).astype(x.dtype) * u
    return jnp.einsum("...f,fe->...e", h, w_down)


def gelu_mlp(x, w_up, b_up, w_down, b_down):
    h = jnp.einsum("...e,ef->...f", x, w_up) + b_up
    h = jax.nn.gelu(h.astype(jnp.float32), approximate=True).astype(x.dtype)
    return jnp.einsum("...f,fe->...e", h, w_down) + b_down


# ---------------------------------------------------------------------------
# Rotary position embeddings
# ---------------------------------------------------------------------------

def rope_freqs(head_dim: int, theta: float, rotary_dim: Optional[int] = None):
    rd = rotary_dim or head_dim
    inv = 1.0 / (theta ** (np.arange(0, rd, 2, dtype=np.float64) / rd))
    return jnp.asarray(inv, dtype=jnp.float32)  # (rd//2,)


def apply_rope(x, positions, theta: float = 10000.0,
               rotary_dim: Optional[int] = None):
    """x: (..., S, H, D); positions: broadcastable to (..., S).

    ``rotary_dim < D`` rotates only the first ``rotary_dim`` features
    (ChatGLM-style "2d" partial rotary); the rest pass through.
    """
    d = x.shape[-1]
    rd = rotary_dim or d
    inv = rope_freqs(d, theta, rd)
    ang = positions[..., None].astype(jnp.float32) * inv  # (..., S, rd//2)
    cos = jnp.cos(ang)[..., None, :]
    sin = jnp.sin(ang)[..., None, :]
    xr, xp = x[..., :rd], x[..., rd:]
    x1, x2 = xr[..., : rd // 2], xr[..., rd // 2:]
    o1 = x1 * cos - x2 * sin
    o2 = x2 * cos + x1 * sin
    return jnp.concatenate([o1.astype(x.dtype), o2.astype(x.dtype), xp], axis=-1)


def causal_mask_bias(q_pos, k_pos, window: Optional[int] = None):
    """Additive mask bias (0 / -inf) for causal (+ optional local window)."""
    ok = k_pos[None, :] <= q_pos[:, None]
    if window is not None:
        ok &= k_pos[None, :] > (q_pos[:, None] - window)
    return jnp.where(ok, 0.0, -jnp.inf).astype(jnp.float32)
