"""Blockwise (flash-style) attention in pure JAX + decode-step attention.

Never materializes the full (Sq, Skv) score matrix: scans KV blocks with an
online-softmax carry. Supports GQA (q heads grouped over kv heads), causal,
causal+sliding-window, and full (cross) attention. This is the memory-safe
substrate required for the 32k prefill shapes; kernel-level flash is a
documented perf-iteration candidate (the roofline shows whether it is worth
it on TPU — see EXPERIMENTS.md §Perf).

Head shuffling (``head_perm``): an optional BMMC permutation of the kv-head
axis, applied consistently to k/v, to q at kv-head granularity (each kv
head drags its GQA group along), and inverted on the output heads — so
the result is bit-identical to the unshuffled call while the layout
travelling through the kernel is permuted. This is the model-facing use
of the batched differentiable BMMC executor (DESIGN.md §9): sharded or
interleaved head layouts become one tiled permutation pass instead of a
gather, and gradients flow through the offline-inverted program.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..core.bmmc import Bmmc
from .permute import permute_axis

NEG_INF = -1e30


def default_head_perm(n_kv_heads: int) -> Optional[Bmmc]:
    """The canonical head shuffle: bit-reversal of the kv-head index.

    Returns None when there is nothing to shuffle (fewer than 2 kv heads
    or a non-power-of-two head count).
    """
    if n_kv_heads < 2 or n_kv_heads & (n_kv_heads - 1):
        return None
    return Bmmc.bit_reverse(n_kv_heads.bit_length() - 1)


def _block_bias(q_pos, k_pos, kind: str, window: Optional[int]):
    if kind == "full":
        return None
    ok = k_pos[None, :] <= q_pos[:, None]
    if window is not None:
        ok &= k_pos[None, :] > (q_pos[:, None] - window)
    return jnp.where(ok, 0.0, NEG_INF).astype(jnp.float32)


def attention(q, k, v, *, kind: str = "causal", window: Optional[int] = None,
              q_offset=0, kv_block: int = 1024, softmax_scale: Optional[float] = None,
              head_perm: Optional[Bmmc] = None, head_perm_engine="ref"):
    """q: (B, Sq, H, D); k, v: (B, Skv, KV, D) with H = G * KV.

    Returns (B, Sq, H, D). ``q_offset`` shifts query positions (prefill
    continuation). Scans over KV blocks with an online-softmax carry.
    ``head_perm`` (a BMMC on log2(KV) bits) shuffles the kv-head layout
    through the kernel and un-shuffles the output — semantically neutral.
    """
    b, sq, h, d = q.shape
    _, skv, kvh, _ = k.shape
    g = h // kvh
    assert g * kvh == h, (h, kvh)
    scale = softmax_scale if softmax_scale is not None else 1.0 / np.sqrt(d)

    kv_block = min(kv_block, skv)
    while skv % kv_block:  # largest divisor of skv <= requested block
        kv_block -= 1
    nkv = skv // kv_block

    if head_perm is not None:
        assert head_perm.size == kvh, (head_perm.n, kvh)
        k = permute_axis(k, head_perm, axis=2, engine=head_perm_engine)
        v = permute_axis(v, head_perm, axis=2, engine=head_perm_engine)

    qg = q.reshape(b, sq, kvh, g, d)
    if head_perm is not None:
        qg = permute_axis(qg, head_perm, axis=2, engine=head_perm_engine)
    kb = k.reshape(b, nkv, kv_block, kvh, d).transpose(1, 0, 2, 3, 4)
    vb = v.reshape(b, nkv, kv_block, kvh, d).transpose(1, 0, 2, 3, 4)

    q_pos = q_offset + jnp.arange(sq)

    def step(carry, blk):
        acc, m, l = carry
        kblk, vblk, bi = blk  # (B, kvb, KV, D), (B, kvb, KV, D), ()
        k_pos = bi * kv_block + jnp.arange(kv_block)
        s = jnp.einsum("bqkgd,bckd->bkgqc", qg, kblk,
                       preferred_element_type=jnp.float32) * scale
        bias = _block_bias(q_pos, k_pos, kind, window)
        if bias is not None:
            s = s + bias  # (Sq, kvb) broadcast over (b, kv, g)
        m_new = jnp.maximum(m, s.max(axis=-1))
        p = jnp.exp(s - m_new[..., None])
        corr = jnp.exp(m - m_new)
        l_new = l * corr + p.sum(axis=-1)
        pv = jnp.einsum("bkgqc,bckd->bkgqd", p.astype(q.dtype), vblk,
                        preferred_element_type=jnp.float32)
        acc_new = acc * corr[..., None] + pv
        return (acc_new, m_new, l_new), None

    acc0 = jnp.zeros((b, kvh, g, sq, d), jnp.float32)
    m0 = jnp.full((b, kvh, g, sq), NEG_INF, jnp.float32)
    l0 = jnp.zeros((b, kvh, g, sq), jnp.float32)
    (acc, m, l), _ = jax.lax.scan(step, (acc0, m0, l0),
                                  (kb, vb, jnp.arange(nkv)))
    out = acc / jnp.maximum(l, 1e-30)[..., None]
    out = out.transpose(0, 3, 1, 2, 4)  # (b, sq, kvh, g, d)
    if head_perm is not None:
        out = permute_axis(out, head_perm.inverse(), axis=2,
                           engine=head_perm_engine)
    return out.reshape(b, sq, h, d).astype(q.dtype)


def decode_attention(q, k_cache, v_cache, length, *, window: Optional[int] = None,
                     softmax_scale: Optional[float] = None,
                     head_perm: Optional[Bmmc] = None, head_perm_engine="ref"):
    """Single-token attention over a KV cache.

    q: (B, 1, H, D); k_cache/v_cache: (B, S, KV, D); ``length``: number of
    valid cache entries (the new token's k/v must already be inserted).
    ``head_perm`` shuffles the kv-head layout exactly as in :func:`attention`.
    """
    b, _, h, d = q.shape
    _, s, kvh, _ = k_cache.shape
    g = h // kvh
    scale = softmax_scale if softmax_scale is not None else 1.0 / np.sqrt(d)
    if head_perm is not None:
        assert head_perm.size == kvh, (head_perm.n, kvh)
        k_cache = permute_axis(k_cache, head_perm, axis=2,
                               engine=head_perm_engine)
        v_cache = permute_axis(v_cache, head_perm, axis=2,
                               engine=head_perm_engine)
    qg = q.reshape(b, kvh, g, d)
    if head_perm is not None:
        qg = permute_axis(qg, head_perm, axis=1, engine=head_perm_engine)
    sc = jnp.einsum("bkgd,bckd->bkgc", qg, k_cache,
                    preferred_element_type=jnp.float32) * scale
    pos = jnp.arange(s)
    ok = pos[None, :] < length
    if window is not None:
        ok &= pos[None, :] > (length - 1 - window)
    sc = jnp.where(ok[:, None, None, :], sc, NEG_INF)
    p = jax.nn.softmax(sc, axis=-1).astype(q.dtype)
    out = jnp.einsum("bkgc,bckd->bkgd", p, v_cache,
                     preferred_element_type=jnp.float32)
    if head_perm is not None:
        out = permute_axis(out, head_perm.inverse(), axis=1,
                           engine=head_perm_engine)
    return out.reshape(b, 1, h, d).astype(q.dtype)
