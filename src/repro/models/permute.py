"""PermuteLayer: BMMC permutations as a differentiable model component.

The combinator executor works on ``(2^n,)`` / ``(B, 2^n[, d])`` arrays;
model activations are arbitrary-rank. ``PermuteLayer`` bridges the two:
it applies a compiled BMMC program along *one* axis of any tensor by
collapsing the leading axes into the kernel batch dim and the trailing
axes into the feature dim — so a ``(B, S, H, D)`` head shuffle and a
``(P, C, E)`` MoE slot shuffle both ride the same batched tiled kernels,
sharing one ``TilePlan`` geometry across every surrounding shape.

Layers are parameter-free and differentiable: gradients flow through the
executor's offline-inverted custom VJP (DESIGN.md §9), so a
``PermuteLayer`` inside a training step costs one extra permutation pass
per direction and never materializes a gather transpose.
"""
from __future__ import annotations

import math
from typing import Optional, Union

import jax

from ..combinators.execute import compile_expr, perm_apply
from ..combinators.ir import Expr, Perm, seq
from ..core.bmmc import Bmmc


def _collapse_axis(x: jax.Array, axis: int) -> jax.Array:
    """Reshape so ``axis`` becomes axis 1 of a batched kernel view:
    leading axes collapse into the batch dim, trailing into the feature
    dim — ``(lead, size)`` or ``(lead, size, d)``. The permuted axis
    length must be a power of two."""
    ax = axis % x.ndim
    size = x.shape[ax]
    if size & (size - 1):
        raise ValueError(f"axis {axis} length {size} is not a power of 2")
    lead = math.prod(x.shape[:ax])
    d = math.prod(x.shape[ax + 1:])
    return x.reshape((lead, size) if d == 1 else (lead, size, d))


def permute_axis(x: jax.Array, bmmc: Bmmc, *, axis: int = -1,
                 engine: Union[str, None] = "ref") -> jax.Array:
    """Apply one BMMC permutation along ``axis`` of an arbitrary tensor.

    ``x.shape[axis]`` must equal ``2^bmmc.n``. Differentiable (the VJP is
    the offline-inverse permutation through the same engine).
    """
    ax = axis % x.ndim
    if x.shape[ax] != bmmc.size:
        raise ValueError(f"axis {axis} has length {x.shape[ax]}, "
                         f"BMMC needs {bmmc.size}")
    y = perm_apply(_collapse_axis(x, ax), bmmc, engine, True)
    return y.reshape(x.shape)


class PermuteLayer:
    """Applies a compiled BMMC combinator program along one tensor axis.

    ``perm`` is a :class:`Bmmc` or any combinator :class:`Expr`; ``axis``
    selects the permuted axis (its length must be the program's ``2^n``).
    The layer is stateless — construct it once (module level / closure)
    so the compiled-plan caches stay warm.
    """

    def __init__(self, perm: Union[Bmmc, Expr], *, axis: int = -1,
                 engine="pallas", optimize: bool = True):
        self.expr = Perm(perm) if isinstance(perm, Bmmc) else perm
        self.axis = axis
        self.engine = engine
        self.optimized = optimize
        self.compiled = compile_expr(self.expr, engine=engine,
                                     optimize=optimize)

    def __call__(self, x: jax.Array) -> jax.Array:
        x3 = _collapse_axis(x, self.axis)
        return self.compiled(x3, batched=True).reshape(x.shape)

    def inverse(self, n: Optional[int] = None) -> "PermuteLayer":
        """The inverse layer (permutation-only programs).

        ``n`` may be omitted when the expression pins its own size.
        """
        if n is None:
            n = self.expr.size_bits()
            if n is None:
                raise ValueError("size-polymorphic expression: pass n")
        inv = seq(*self.compiled.vjp_program(n))
        return PermuteLayer(inv, axis=self.axis, engine=self.engine,
                            optimize=self.optimized)
