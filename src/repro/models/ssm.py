"""State-space blocks: Mamba-2 SSD (state-space duality) and RG-LRU.

Mamba-2 (arXiv:2405.21060): chunked SSD — intra-chunk quadratic attention-
like term + inter-chunk linear recurrence over chunk states (lax.scan).
RG-LRU (RecurrentGemma, arXiv:2402.19427): gated linear recurrence computed
with ``lax.associative_scan`` (log-depth, TPU-friendly).

Sequence-to-chunk blocking in SSD is a BP map on sequence index bits
(seq -> (chunks, chunk)); with power-of-two chunks it routes through the
BMMC planner's row view (see DESIGN.md §4 Arch-applicability). The inner
recurrences are not permutations — the paper's technique is inapplicable
there and they are plain JAX.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np


# ---------------------------------------------------------------------------
# Mamba-2 SSD
# ---------------------------------------------------------------------------

def _segsum(a):
    """Stable segment-sum: out[..., i, j] = sum_{j < k <= i} a[..., k]."""
    q = a.shape[-1]
    cs = jnp.cumsum(a, axis=-1)
    d = cs[..., :, None] - cs[..., None, :]
    mask = jnp.tril(jnp.ones((q, q), bool), k=0)
    return jnp.where(mask, d, -jnp.inf)


def ssd_chunked(x, dt_a, b, c, *, chunk: int = 256, return_final_state: bool = False):
    """Chunked state-space duality forward pass.

    x: (B, L, H, P) head inputs (already dt-weighted by the caller)
    dt_a: (B, L, H) per-step log decay (A * dt, <= 0)
    b, c: (B, L, G, N) input/output projections (G groups, heads share)
    Returns y: (B, L, H, P) [and the final SSM state (B, H, P, N) if asked —
    the decode-continuation carry, free from the inter-chunk scan].
    """
    bsz, l, h, p = x.shape
    g, n = b.shape[2], b.shape[3]
    hg = h // g
    chunk = min(chunk, l)
    pad = (-l) % chunk
    if pad:
        # no-op padding: x/b/c = 0 contribute nothing to states, and
        # dt_a = 0 => decay exp(0) = 1 passes state through unchanged.
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt_a = jnp.pad(dt_a, ((0, 0), (0, pad), (0, 0)))
        b = jnp.pad(b, ((0, 0), (0, pad), (0, 0), (0, 0)))
        c = jnp.pad(c, ((0, 0), (0, pad), (0, 0), (0, 0)))
    lp = l + pad
    nc = lp // chunk

    xc = x.reshape(bsz, nc, chunk, h, p)
    ac = dt_a.reshape(bsz, nc, chunk, h)
    bc = b.reshape(bsz, nc, chunk, g, n)
    cc = c.reshape(bsz, nc, chunk, g, n)

    # intra-chunk ("diagonal") term: attention-like with decay kernel L
    lmat = jnp.exp(_segsum(ac.transpose(0, 1, 3, 2)))        # (B,nc,H,q,q)
    scores = jnp.einsum("bcqgn,bckgn->bcgqk", cc, bc,
                        preferred_element_type=jnp.float32)   # (B,nc,G,q,k)
    scores = scores.reshape(bsz, nc, g, 1, chunk, chunk)
    lmat = lmat.reshape(bsz, nc, g, hg, chunk, chunk)
    w = (scores * lmat).reshape(bsz, nc, h, chunk, chunk)
    y_diag = jnp.einsum("bchqk,bckhp->bcqhp", w.astype(x.dtype), xc,
                        preferred_element_type=jnp.float32)

    # chunk-final states: S_c = sum_j exp(cum_last - cum_j) B_j (x) x_j
    cum = jnp.cumsum(ac, axis=2)                              # (B,nc,q,H)
    decay_states = jnp.exp(cum[:, :, -1:, :] - cum)           # (B,nc,q,H)
    bh = jnp.repeat(bc, hg, axis=3) if g != h else bc          # (B,nc,q,H,N)
    states = jnp.einsum("bcqhn,bcqh,bcqhp->bchpn", bh, decay_states.astype(x.dtype), xc,
                        preferred_element_type=jnp.float32)

    # inter-chunk recurrence over chunk states
    chunk_decay = jnp.exp(cum[:, :, -1, :])                   # (B,nc,H)

    def step(s_prev, inp):
        st, dec = inp                                          # (B,H,P,N), (B,H)
        s_new = s_prev * dec[..., None, None] + st
        return s_new, s_prev

    s0 = jnp.zeros((bsz, h, p, n), jnp.float32)
    s_final, s_prevs = jax.lax.scan(
        step, s0,
        (states.transpose(1, 0, 2, 3, 4), chunk_decay.transpose(1, 0, 2)))
    s_prevs = s_prevs.transpose(1, 0, 2, 3, 4)                 # (B,nc,H,P,N)

    # off-diagonal contribution: y_i += C_i . (decay_i * S_prev)
    state_decay = jnp.exp(cum)                                  # (B,nc,q,H)
    ch = jnp.repeat(cc, hg, axis=3) if g != h else cc           # (B,nc,q,H,N)
    y_off = jnp.einsum("bcqhn,bchpn,bcqh->bcqhp", ch, s_prevs.astype(x.dtype),
                       state_decay.astype(x.dtype),
                       preferred_element_type=jnp.float32)
    y = (y_diag + y_off).reshape(bsz, lp, h, p)[:, :l]
    if return_final_state:
        return y.astype(x.dtype), s_final
    return y.astype(x.dtype)


def ssd_decode_step(state, x_t, dt_a_t, b_t, c_t):
    """One-token SSD update. state: (B,H,P,N) f32.

    x_t: (B,H,P); dt_a_t: (B,H); b_t, c_t: (B,G,N).
    Returns (new_state, y_t (B,H,P)).
    """
    bsz, h, p, n = state.shape
    g = b_t.shape[1]
    hg = h // g
    bh = jnp.repeat(b_t, hg, axis=1) if g != h else b_t        # (B,H,N)
    ch = jnp.repeat(c_t, hg, axis=1) if g != h else c_t
    dec = jnp.exp(dt_a_t)[..., None, None]                      # (B,H,1,1)
    new_state = state * dec + jnp.einsum("bhp,bhn->bhpn", x_t, bh).astype(jnp.float32)
    y = jnp.einsum("bhpn,bhn->bhp", new_state.astype(x_t.dtype), ch)
    return new_state, y


def causal_conv1d(x, w, prev: Optional[jax.Array] = None):
    """Depthwise causal conv. x: (B, L, C); w: (K, C); prev: (B, K-1, C)."""
    k = w.shape[0]
    if prev is None:
        prev = jnp.zeros((x.shape[0], k - 1, x.shape[2]), x.dtype)
    xp = jnp.concatenate([prev, x], axis=1)
    out = sum(xp[:, i: i + x.shape[1], :] * w[i] for i in range(k))
    new_prev = xp[:, -(k - 1):, :] if k > 1 else prev
    return out, new_prev


# ---------------------------------------------------------------------------
# RG-LRU (RecurrentGemma)
# ---------------------------------------------------------------------------

_RGLRU_C = 8.0


def rglru(x, gate_a, gate_x, a_param, h0: Optional[jax.Array] = None):
    """Real-gated LRU scan. x, gate_a, gate_x: (B, L, D); a_param: (D,).

    h_t = a_t * h_{t-1} + sqrt(1 - a_t^2) * (i_t * x_t)
    with a_t = exp(-c * softplus(a_param) * sigmoid(gate_a)).
    Computed with an associative scan; ``h0`` carries decode state.
    """
    log_a = -_RGLRU_C * jax.nn.softplus(a_param.astype(jnp.float32)) \
        * jax.nn.sigmoid(gate_a.astype(jnp.float32))
    a = jnp.exp(log_a)
    gated = jax.nn.sigmoid(gate_x.astype(jnp.float32)) * x.astype(jnp.float32)
    b = jnp.sqrt(jnp.maximum(1.0 - a * a, 1e-12)) * gated

    if h0 is not None:
        b = b.at[:, 0, :].add(a[:, 0, :] * h0.astype(jnp.float32))

    def comb(l, r):
        al, bl = l
        ar, br = r
        return al * ar, bl * ar + br

    _, h = jax.lax.associative_scan(comb, (a, b), axis=1)
    return h.astype(x.dtype), h[:, -1, :]


def rglru_step(state, x_t, gate_a_t, gate_x_t, a_param):
    """One-token RG-LRU update. state: (B, D) f32."""
    log_a = -_RGLRU_C * jax.nn.softplus(a_param.astype(jnp.float32)) \
        * jax.nn.sigmoid(gate_a_t.astype(jnp.float32))
    a = jnp.exp(log_a)
    gated = jax.nn.sigmoid(gate_x_t.astype(jnp.float32)) * x_t.astype(jnp.float32)
    h = a * state + jnp.sqrt(jnp.maximum(1.0 - a * a, 1e-12)) * gated
    return h, h.astype(x_t.dtype)
