"""Logical-axis -> mesh-axis sharding rules (DP+FSDP / TP / EP / SP).

Mesh axes: ``("data", "model")`` single-pod, ``("pod", "data", "model")``
multi-pod. Rules:

* ``batch``                    -> (pod,) data       (DP)
* ``vocab, heads, kv_heads,
  mlp, experts``               -> model             (TP / EP)
* ``embed``                    -> (pod,) data       (FSDP parameter sharding;
                                  optimizer states follow parameters)
* everything else              -> replicated

A **divisibility guard** drops a rule when the dimension is not divisible by
the mesh-axis product (e.g. 36 heads or vocab 50280 on a 16-wide model axis
fall back to replicated — recorded per-arch in EXPERIMENTS.md §Dry-run).
Each mesh axis is used at most once per tensor (first dim wins).
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


def dp_axes(mesh: Mesh) -> Tuple[str, ...]:
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def logical_rules(mesh: Mesh, *, fsdp: bool = True):
    dp = dp_axes(mesh)
    rules = {
        "batch": dp,
        "vocab": ("model",),
        "heads": ("model",),
        "kv_heads": ("model",),
        "seq_kv": ("model",),            # decode-cache sequence sharding (SP)
        "mlp": ("model",),
        "experts": ("model",),
        "embed": dp if fsdp else (),
        "state": (),
        "head_dim": (),
        "layers": (),
        # 8-bit optimizer moments: flat blocks sharded over every axis
        "opt_shard": (("pod",) if "pod" in mesh.axis_names else ()) + ("data", "model"),
    }
    return rules


def _axis_size(mesh: Mesh, names: Tuple[str, ...]) -> int:
    return int(np.prod([mesh.shape[n] for n in names], initial=1))


def spec_for(mesh: Mesh, axes: Tuple[Optional[str], ...],
             shape: Tuple[int, ...], *, fsdp: bool = True,
             min_shard: int = 2) -> P:
    """PartitionSpec for a tensor with logical ``axes`` and ``shape``."""
    rules = logical_rules(mesh, fsdp=fsdp)
    used: set = set()
    parts = []
    for ax, dim in zip(axes, shape):
        names = rules.get(ax, ()) if ax else ()
        names = tuple(n for n in names if n not in used)
        sz = _axis_size(mesh, names)
        if names and sz > 1 and dim % sz == 0 and dim // sz >= min_shard:
            parts.append(names if len(names) > 1 else names[0])
            used.update(names)
        else:
            parts.append(None)
    return P(*parts)


def param_shardings(mesh: Mesh, shapes_tree, axes_tree, *, fsdp: bool = True):
    """NamedSharding tree matching a ShapeDtypeStruct tree + axes tree.

    Both trees are nested dicts with leaves at identical positions
    (ShapeDtypeStruct vs logical-axes tuple).
    """
    def rec(s, a):
        if isinstance(s, dict):
            return {k: rec(s[k], a[k]) for k in s}
        return NamedSharding(mesh, spec_for(mesh, a, s.shape, fsdp=fsdp))
    return rec(shapes_tree, axes_tree)


def batch_spec(mesh: Mesh, batch_size: int, ndim: int) -> P:
    dp = dp_axes(mesh)
    sz = _axis_size(mesh, dp)
    if sz > 1 and batch_size % sz == 0:
        first = dp if len(dp) > 1 else dp[0]
        return P(first, *([None] * (ndim - 1)))
    return P(*([None] * ndim))


def dp_size(mesh: Optional[Mesh]) -> int:
    if mesh is None:
        return 1
    return _axis_size(mesh, dp_axes(mesh))


def moe_buffer_constrainer(mesh: Optional[Mesh]):
    """Constrain (G, X, C, E) MoE buffers to (dp, model, None, None)."""
    if mesh is None:
        return None
    dp = dp_axes(mesh)
    first = dp if len(dp) > 1 else dp[0]

    def constrain(buf):
        g, xn = buf.shape[0], buf.shape[1]
        gspec = first if g % _axis_size(mesh, dp) == 0 else None
        xspec = "model" if xn % mesh.shape["model"] == 0 else None
        spec = P(gspec, xspec, *([None] * (buf.ndim - 2)))
        return jax.lax.with_sharding_constraint(buf, NamedSharding(mesh, spec))
    return constrain


def activation_constrainer(mesh: Optional[Mesh], seq_parallel: bool = False):
    """Constrain (B, S, E) activations at block boundaries.

    Default: batch over the DP axes. With ``seq_parallel`` the sequence dim
    is additionally sharded over ``model`` (Megatron-SP style): GSPMD then
    lowers the TP activation all-reduces into reduce-scatter/all-gather
    pairs whose exposed bytes halve (see EXPERIMENTS.md §Perf).
    """
    if mesh is None:
        return lambda x: x

    def constrain(x):
        if x.ndim < 1:
            return x
        spec = batch_spec(mesh, x.shape[0], x.ndim)
        if (seq_parallel and x.ndim == 3 and
                x.shape[1] % mesh.shape["model"] == 0 and
                x.shape[1] // mesh.shape["model"] >= 128):
            spec = P(spec[0], "model", None)
        return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))
    return constrain
