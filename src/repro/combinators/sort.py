"""The balanced-periodic merge sort (paper §7.1) as a combinator expression.

The paper's recursion, transliterated into the IR::

    sort 0      = id
    sort n      = parm 1 (sort (n-1))  >>  merge n

    merge 0     = id
    merge n     = vcolumn n  >>  parm 2^(n-1) (merge (n-1))

    vcolumn 1   = cmp_halves
    vcolumn n   = parm 3 (vcolumn (n-1))

Lowering expands every ``parm`` into its §7.2 BMMC conjugation and the
optimizer fuses the resulting permutation chains, leaving exactly one
BMMC permutation between consecutive compare-exchange sweeps.
"""
from __future__ import annotations

import functools

import numpy as np

from .execute import CompiledExpr, compile_expr
from .ir import Expr
from .vocab import cmp_halves, identity, parm, seq


@functools.lru_cache(maxsize=None)
def vcolumn_expr(n: int) -> Expr:
    if n <= 0:
        return identity()
    if n == 1:
        return cmp_halves()
    return parm(3, vcolumn_expr(n - 1))


@functools.lru_cache(maxsize=None)
def merge_expr(n: int) -> Expr:
    if n <= 0:
        return identity()
    return seq(vcolumn_expr(n), parm(1 << (n - 1), merge_expr(n - 1)))


@functools.lru_cache(maxsize=None)
def sort_expr(n: int) -> Expr:
    if n <= 0:
        return identity()
    return seq(parm(1, sort_expr(n - 1)), merge_expr(n))


def compiled_sort(n: int, *, engine="ref", optimize: bool = True) -> CompiledExpr:
    """The compiled sorting network for arrays of 2^n elements."""
    return compile_expr(sort_expr(n), engine=engine, optimize=optimize)


def sort(xs, *, engine="ref"):
    """Sort a jax array of 2^n elements via the compiled network."""
    n = int(np.log2(np.shape(xs)[0]))
    return compiled_sort(n, engine=engine)(xs)
