"""Multi-engine executor with a compiled-plan cache.

Engines map a ``Perm`` stage to an actual array permutation:

* ``"ref"``    — the pure-jnp gather oracle (:mod:`repro.kernels.ref`).
* ``"pallas"`` — the tiled Pallas pipeline (:mod:`repro.kernels`), with a
  twist: the per-stage kernel executable is cached by *tile geometry*
  (:func:`repro.kernels.bmmc_permute.plan_geometry`), and the per-stage
  index tables are passed as runtime arguments. A fused program with many
  distinct BMMCs but few distinct geometries therefore pays the pallas
  trace/lower cost only once per geometry, not once per stage.

Any callable ``(x, bmmc) -> x`` is also accepted wherever an engine name
is, so tests can inject instrumented engines.

``compile_expr(expr)`` is the user entry point: lowering + fusion happen
once per ``(expr, n)``; kernel plans once per ``(bmmc, t)``; kernel
executables once per geometry. The returned function is jax-traceable
(it can be wrapped in ``jax.jit``), and cheap to call as-is.

Autodiff (DESIGN.md §9, §13): every ``Perm`` stage executes through
:func:`perm_apply`, a ``jax.custom_vjp`` primitive whose backward pass
applies the *offline-inverted* BMMC (``Bmmc.inverse``) through the same
engine. A BMMC permutation is orthogonal — its Jacobian transpose is the
inverse permutation — so no residuals are saved and cotangents ride the
same geometry-cached tiled kernels as the forward pass. Pallas DMA
kernels have no JVP/transpose rules of their own; this rule is what
makes ``jax.grad`` flow through the "pallas" engine at all.

The backward pass is itself a compiled program (DESIGN.md §13). A
permutation-only program — every stage a ``Perm`` or a compute-free
cluster — executes through :func:`program_apply`, a whole-program
``custom_vjp`` primitive whose backward dispatches the offline-inverted
program (:func:`repro.combinators.optimize.inverse_program`, which
inverts *clustered* programs cluster-for-cluster) through its own
``(program, engine, batched)`` executable-cache entry, warmed alongside
the forward. No residuals are saved anywhere on this path. Compute-
bearing clusters save only the cluster input and run a *pulled-back*
backward: the cluster forward factors as ``B ∘ C̃m ∘ … ∘ C̃1`` (each
``C̃j = Mj⁻¹ ∘ Cj ∘ Mj`` an input-space XOR-partner pairwise compute
with offline side/twiddle tables), so the cotangent takes ONE inverse
megakernel dispatch for ``B⁻¹`` plus cheap jnp pairwise VJPs — the
per-stage inverse replay survives only as the fallback for layouts the
tables don't model (complex butterflies).

Batching: ``run_program`` / ``CompiledExpr.__call__`` take
``batched=True`` to accept a leading batch axis — ``(B, 2^n)`` or
``(B, 2^n, d)`` — folded into the kernel grid with the tile plan shared
across the batch. Injected engines that don't understand ``batched``
are transparently wrapped with ``jax.vmap`` (the vmap fallback).

Fused stages (DESIGN.md §10): on the "pallas" engine the compiled
program is additionally run through :func:`repro.combinators.optimize.
cluster`, which groups ``Perm → compute → Perm`` runs into
:class:`~repro.combinators.optimize.FusedStage`\\ s. A FusedStage
dispatches to the double-buffered megakernel — one HBM round trip for
the whole run, with the interior ``CmpHalves``/``Bfly``/``Map`` stages
applied to each tile in VMEM. Every other engine (the "ref" oracle,
injected engines) executes the cluster's original stages one at a time,
while the megakernel's backward pass dispatches the *inverse cluster*
(permutation-only clusters, zero residuals) or the pulled-back compute
chain (§13). Clusters whose layout the kernel cannot take (complex
dtype, non-planar butterflies, arrays too small to tile) transparently
fall back to stage-at-a-time execution.
"""
from __future__ import annotations

import collections
import functools
import inspect
import threading
import time
import weakref
from typing import Callable, Dict, Optional, Sequence, Union

import jax
import jax.numpy as jnp
import numpy as np

from ..core.bmmc import Bmmc
from ..obs import metrics as _ometrics
from ..obs import trace as _otrace
from ..core.tiling import (compute_tables, pairing_vector, plan_bmmc,
                           plan_general)
from ..kernels import ref as _ref
from ..kernels.bmmc_permute import (block_geometry, block_permute_tables,
                                    lane_geometry, lane_permute_tables,
                                    plan_geometry, tiled_permute_bwd_tables,
                                    tiled_permute_tables)
from .ir import Bfly, CmpHalves, Expr, Map, Perm
from .optimize import (COMPUTES, Program, FusedStage, _run_fused, cluster,
                       fold_free, lower, fuse, inverse_program,
                       inverse_stage, is_perm_program)

EngineFn = Callable[[jax.Array, Bmmc], jax.Array]

_ENGINES: Dict[str, EngineFn] = {}


def register_engine(name: str, fn: EngineFn) -> None:
    _ENGINES[name] = fn


def get_engine(engine: Union[str, EngineFn, None]) -> EngineFn:
    if engine is None:
        return _ENGINES["ref"]
    if callable(engine):
        return engine
    try:
        return _ENGINES[engine]
    except KeyError:
        from ..guard.errors import UnknownEngine
        raise UnknownEngine(
            f"unknown engine {engine!r}; registered engines: "
            f"{sorted(_ENGINES)}") from None


def engines() -> tuple:
    return tuple(sorted(_ENGINES))


# ---------------------------------------------------------------------------
# The "pallas" engine: geometry-cached kernel executables.
# ---------------------------------------------------------------------------

@functools.lru_cache(maxsize=512)
def _geom_executable(geometry: tuple, interpret: bool, batched: bool = False,
                     epilogue: tuple = (), map_fns: tuple = ()):
    """One jitted tiled-pass executable per (tile geometry, epilogue
    signature). Index/epilogue tables are arguments, so every stage
    sharing this key reuses the trace. The cache key is independent of
    the batch size: growing B re-specializes the jit trace but never
    adds a geometry entry."""
    return jax.jit(functools.partial(
        tiled_permute_tables, geometry=geometry, interpret=interpret,
        batched=batched, epilogue=epilogue, map_fns=map_fns))


@functools.lru_cache(maxsize=512)
def _geom_bwd_executable(geometry: tuple, interpret: bool,
                         batched: bool = False, epilogue: tuple = (),
                         map_fns: tuple = ()):
    """One jitted gradient-megakernel executable per (tile geometry,
    epilogue signature) — the backward twin of :func:`_geom_executable`,
    same cache-key discipline (tables are runtime arguments)."""
    return jax.jit(functools.partial(
        tiled_permute_bwd_tables, geometry=geometry, interpret=interpret,
        batched=batched, epilogue=epilogue, map_fns=map_fns))


@functools.lru_cache(maxsize=256)
def _block_executable(geometry: tuple, interpret: bool,
                      batched: bool = False):
    """One jitted block-permute (grid-remapped DMA copy) executable per
    geometry; the source-row table is a runtime argument."""
    return jax.jit(functools.partial(
        block_permute_tables, geometry=geometry, interpret=interpret,
        batched=batched))


@functools.lru_cache(maxsize=256)
def _lane_executable(geometry: tuple, interpret: bool,
                     batched: bool = False):
    """One jitted lane-permute (in-VMEM row gather) executable per
    geometry; the lane table is a runtime argument."""
    return jax.jit(functools.partial(
        lane_permute_tables, geometry=geometry, interpret=interpret,
        batched=batched))


def _pallas_engine(x: jax.Array, bmmc: Bmmc, *, t: Optional[int] = None,
                   interpret: bool = True, batched: bool = False) -> jax.Array:
    from ..kernels import ops

    if bmmc.is_identity_perm():
        _ometrics.inc("dispatch.kernel", kernel="none")
        return x
    if jnp.iscomplexobj(x):
        # pallas TPU has no complex dtype; a permutation is dtype-agnostic,
        # so complex arrays ride the gather oracle (planar (re, im) float
        # layouts take the tiled kernels)
        return _ref.bmmc_ref(x, bmmc, batched=batched)
    got = ops.class_dispatch(x, bmmc, t, batched)
    if got is None:  # too small to tile; whole array fits anywhere
        return _ref.bmmc_ref(x, bmmc, batched=batched)
    kernel, payload = got
    if kernel == "none":
        return x
    if kernel == "block":
        run = _block_executable(block_geometry(payload), interpret, batched)
        return run(x, payload.src_rows)
    if kernel == "lane":
        run = _lane_executable(lane_geometry(payload), interpret, batched)
        return run(x, payload.src_lane)
    for plan in payload:
        run = _geom_executable(plan_geometry(plan), interpret, batched)
        x = run(x, plan.in_rows, plan.out_rows, plan.xor_low, plan.src0)
    return x


register_engine("ref", _ref.bmmc_ref)
register_engine("pallas", _pallas_engine)


# ---------------------------------------------------------------------------
# Fused-stage execution: the megakernel dispatch path (DESIGN.md §10)
# ---------------------------------------------------------------------------

def _fused_entries(plans, computes):
    entries = []
    for comp, prefix in computes:
        if isinstance(comp, Map):
            entries.append(("map", comp))
            continue
        kind = "cmp" if isinstance(comp, CmpHalves) else "bfly"
        ct = compute_tables(plans[0], prefix, kind)
        if ct is None:
            return None
        entries.append((kind, comp, ct))
    return tuple(entries)


def _build_fused_plan(fs: FusedStage, t: int):
    """Plan a cluster from scratch (the store's ``build`` rung).

    A classic plan's tile span can be narrower than the maximal
    ``ker(A[t:, :])`` span the clustering validated against; when a
    compute's pairing vector needs the extra room, the first pass is
    re-planned with :func:`repro.core.tiling.plan_general`, whose span
    IS the maximum."""
    try:
        plans = list(plan_bmmc(fs.bmmc, t))
    except ValueError:
        return None
    entries = _fused_entries(plans, fs.computes)
    if entries is None and plans[0].row_cols:
        general = plan_general(plans[0].bmmc, t)
        if general is not None:
            plans[0] = general
            entries = _fused_entries(plans, fs.computes)
    if entries is None:
        return None
    return tuple(plans), tuple(entries)


@functools.lru_cache(maxsize=256)
def _fused_plan_cached(fs: FusedStage, t: int):
    """(pass plans, per-compute ComputeTables-or-Map entries) for a
    cluster, or None when the megakernel cannot run it at this tile
    parameter (no pass plannable, or a compute not tile-local in the
    first pass — possible when the runtime ``t`` differs from the
    clustering ``t``). The composed BMMC runs as ONE tiled pass (classic
    witness columns or generalized witness directions), falling back to
    the §5.2 two-pass factorization only for t > n/2; computes always
    ride the FIRST pass's tiles (they are pulled back to input space,
    where pass 1 reads).

    Backed by the durable plan store when one is configured
    (``REPRO_STORE``): only the offline tables travel to disk — compute
    entries are re-seated against this cluster's live ``computes`` on
    decode, so Map callables never serialize — and every loaded plan is
    re-audited through guard ring 1 before it is trusted."""
    from .. import store as _store

    return _store.fused_plan_through(
        fs, t, lambda: _build_fused_plan(fs, t))


@functools.lru_cache(maxsize=64)
def _w_planar_cached(twiddles: tuple, dtype: str) -> np.ndarray:
    """The (2^(n-1), 2) resident (re, im) twiddle-value table."""
    return np.stack([np.asarray([w.real for w in twiddles], dtype=dtype),
                     np.asarray([w.imag for w in twiddles], dtype=dtype)],
                    axis=-1)


def _fused_tile(x: jax.Array, fs: FusedStage, batched: bool) -> Optional[int]:
    """The tile parameter the megakernel would use on ``x``, or None when
    the fused fast path cannot take this input (falls back per-stage)."""
    from ..kernels import ops

    lead = 1 if batched else 0
    if x.ndim not in (1 + lead, 2 + lead) or jnp.iscomplexobj(x):
        return None
    d = x.shape[1 + lead] if x.ndim == 2 + lead else 1
    if any(isinstance(c, Bfly) for c, _ in fs.computes):
        if x.ndim != 2 + lead or d != 2:
            return None  # butterflies need the planar (re, im) layout
    t = ops.choose_tile(fs.bmmc.n, x.dtype.itemsize, d)
    if t is None or _fused_plan_cached(fs, t) is None:
        return None
    return t


def _fused_kernel_args(entries: tuple, dtype) -> tuple:
    """(signature, scalar tables, VMEM tables, map fns) shared verbatim
    by the forward megakernel and its gradient twin — one table set, two
    kernels."""
    sig, scal, vmem, map_fns = [], [], [], []
    for e in entries:
        if e[0] == "map":
            sig.append(("map", e[1].name))
            map_fns.append(e[1].fn)
            scal.append(())
            vmem.append(())
            continue
        kind, comp, ct = e
        if kind == "cmp":
            sig.append(("cmp", ct.vr, ct.vc))
            scal.append((ct.hi_base,))
            vmem.append((ct.hi_row, ct.hi_lane))
        else:
            w = _w_planar_cached(comp.twiddles, np.dtype(dtype).name)
            sig.append(("bfly", ct.vr, ct.vc, len(comp.twiddles)))
            scal.append((ct.hi_base, ct.tw_base))
            vmem.append((ct.hi_row, ct.hi_lane, ct.tw_row, ct.tw_lane, w))
    return tuple(sig), tuple(scal), tuple(vmem), tuple(map_fns)


def _fused_pallas(x: jax.Array, fs: FusedStage, t: int, *,
                  interpret: bool = True, batched: bool = False) -> jax.Array:
    """Run one cluster as a double-buffered megakernel dispatch: the
    first tiled pass carries every fused compute as an in-VMEM epilogue;
    a second plain pass (general BMMCs only, §5.2) finishes the
    permutation."""
    plans, entries = _fused_plan_cached(fs, t)
    plan = plans[0]
    sig, scal, vmem, map_fns = _fused_kernel_args(entries, x.dtype)
    run = _geom_executable(plan_geometry(plan), interpret, batched,
                           sig, map_fns)
    x = run(x, plan.in_rows, plan.out_rows, plan.xor_low, plan.src0,
            epi_scalar=scal, epi_vmem=vmem)
    for plan in plans[1:]:
        run = _geom_executable(plan_geometry(plan), interpret, batched)
        x = run(x, plan.in_rows, plan.out_rows, plan.xor_low, plan.src0)
    return x


def _fused_forward(x, fs, engine, batched):
    if engine == "pallas":
        t = _fused_tile(x, fs, batched)
        if t is not None:
            if _otrace._state.enabled:
                plans, _ = _fused_plan_cached(fs, t)
                _ometrics.inc("dispatch.kernel", kernel="fused")
                _ometrics.inc("model.round_trips", len(plans))
                _ometrics.inc("dma.descriptors",
                              sum(p.dma_descriptors() for p in plans))
                with _otrace.span("kernel.fused", stages=len(fs.stages),
                                  passes=len(plans), t=t):
                    return _fused_pallas(x, fs, t, batched=batched)
            return _fused_pallas(x, fs, t, batched=batched)
    if engine == "pallas":
        # cluster validated at plan time but re-rejected for this input
        # (dtype/shape/tile mismatch): the honest count the model lacks
        _ometrics.inc("dispatch.fused_fallback")
    return run_program(fs.stages, x, engine, batched=batched)


# ---------------------------------------------------------------------------
# Compiled backward pass (DESIGN.md §13)
#
# Every custom-VJP backward rule below runs under _vjp_observed, which
# opens a "<kind>.vjp" span and credits the modeled round trips the rule
# dispatches to ``model.vjp_round_trips`` — the backward twin of the
# forward ``model.round_trips`` accounting, so one cold backward call's
# counter delta can be held against ``program_cost(inverse_program(p))``.
# ---------------------------------------------------------------------------

_VJP_STATE = threading.local()


def _vjp_observed(kind: str, fn: Callable):
    """Run one backward-rule body under a ``<kind>.vjp`` span.

    Counters fire at trace time (host-side Python), so the delta of
    ``model.round_trips`` across the rule IS the modeled cost of the
    backward program it dispatched. Nested rules — e.g. per-stage
    ``Perm`` VJPs inside a fused fallback replay — fold into the
    outermost rule's span via the reentrancy depth guard, never
    double-counting ``model.vjp_round_trips``.
    """
    if not _otrace._state.enabled or getattr(_VJP_STATE, "depth", 0):
        return fn()
    _VJP_STATE.depth = 1
    try:
        rt0 = _ometrics.counter_total("model.round_trips")
        with _otrace.span(kind + ".vjp") as sargs:
            out = fn()
            delta = _ometrics.counter_total("model.round_trips") - rt0
            sargs["model_round_trips"] = delta
        _ometrics.inc("dispatch.vjp", kind=kind)
        if delta:
            _ometrics.inc("model.vjp_round_trips", delta)
    finally:
        _VJP_STATE.depth = 0
    return out


@functools.lru_cache(maxsize=512)
def _fused_inverse_cached(fs: FusedStage) -> FusedStage:
    """The offline inverse of a permutation-only cluster — itself a
    cluster (per-class closure, DESIGN.md §13)."""
    return inverse_stage(fs)


def _np_parity(vals: np.ndarray) -> np.ndarray:
    """Elementwise F2 parity (popcount mod 2) of an int64 index array."""
    v = vals.astype(np.int64)
    for s in (32, 16, 8, 4, 2, 1):
        v ^= v >> s
    return v & 1


@functools.lru_cache(maxsize=256)
def _pulled_back_tables(prefix: Bmmc, kind: str) -> tuple:
    """Offline numpy tables for one pulled-back compute ``C̃ = M⁻¹CM``.

    ``partner[i] = i ^ v`` with ``v = A_M⁻¹ e_{n-1}`` the pairing
    vector; ``side0[i]`` marks the "lo" role (bit n-1 of ``M(i)`` clear)
    — the same predicate :func:`repro.core.tiling.compute_tables` splits
    into per-row/lane/tile terms for the in-VMEM epilogue; ``w_idx[i]``
    (bfly only) the twiddle slot = ``M(i)`` with the pair bit dropped,
    shared by both partners since ``M(v) = e_{n-1}``.
    """
    n = prefix.n
    idx = np.arange(1 << n, dtype=np.int64)
    partner = (idx ^ pairing_vector(prefix)).astype(np.int32)
    side0 = (_np_parity(idx & prefix.rows[n - 1])
             ^ ((prefix.c >> (n - 1)) & 1)) == 0
    w_idx = None
    if kind == "bfly":
        w_idx = np.zeros(1 << n, dtype=np.int64)
        for j in range(n - 1):
            w_idx |= _np_parity(idx & prefix.rows[j]) << j
        w_idx = (w_idx ^ (prefix.c & ((1 << (n - 1)) - 1))).astype(np.int32)
    return partner, side0, w_idx


@functools.lru_cache(maxsize=256)
def _pulled_back_fn(comp: Expr, prefix: Bmmc, batched: bool) -> tuple:
    """The compute conjugated into the cluster's input space, as an
    explicit ``(fwd, bwd)`` pair of plain-jnp functions.

    ``fwd(u)`` recomputes the conjugated stage — an XOR-partner gather
    plus the pairwise compute, bitwise-matching the per-stage oracle:
    the (lo, hi) argument ORDER of the min/max (and the ``lo ± w·hi``
    butterfly terms) is canonicalized by the side predicate, so
    tie-breaking and NaN routing agree with :func:`run_program`'s replay
    exactly. ``bwd(u, ct)`` is the hand-written VJP: the backward rule
    only needs cotangent VALUES, and keeping these plain functions —
    no nested ``custom_vjp`` wrapper — avoids the exponential jaxpr
    growth jax exhibits when chained custom-vjp calls are linearized
    inside another rule's transpose.
    """
    if isinstance(comp, Map):
        def map_bwd(u, ct):
            _, vjp = jax.vjp(comp.fn, u)
            return vjp(ct)[0]
        # elementwise: conjugation by a permutation is a no-op
        return comp.fn, map_bwd
    axis = 1 if batched else 0
    kind = "cmp" if isinstance(comp, CmpHalves) else "bfly"
    # the closures hold NUMPY tables, lifted to constants by the jnp ops
    # at each trace — caching a jnp.asarray here would pin a tracer when
    # the first build happens under an active trace (e.g. linearization
    # of the whole-program executable) and leak it into later traces
    partner, side0, w_idx = _pulled_back_tables(prefix, kind)

    def expand(tbl, ndim):
        return tbl.reshape((1,) * axis + (-1,) + (1,) * (ndim - axis - 1))

    if kind == "cmp":
        def g(u, up):  # elementwise pairwise compare, canonical arg order
            s0 = expand(side0, u.ndim)
            lo = jnp.where(s0, u, up)
            hi = jnp.where(s0, up, u)
            return jnp.where(s0, jnp.minimum(lo, hi), jnp.maximum(lo, hi))
    else:
        w = np.asarray(comp.twiddles, dtype=np.complex128)[w_idx]
        w_re = np.ascontiguousarray(w.real)
        w_im = np.ascontiguousarray(w.imag)
        side0_b = side0[:, None]  # broadcasts over the (re, im) dim

        def g(u, up):  # planar layout: (..., 2^n, 2)
            wr = w_re.astype(u.dtype)
            wi = w_im.astype(u.dtype)
            lo = jnp.where(side0_b, u, up)
            hi = jnp.where(side0_b, up, u)
            tre = wr * hi[..., 0] - wi * hi[..., 1]
            tim = wr * hi[..., 1] + wi * hi[..., 0]
            t = jnp.stack([tre, tim], axis=-1)
            return jnp.where(side0_b, lo + t, lo - t)

    # fwd = g(u, P u) with P the (involutive) partner gather. The VJP is
    # written by hand so the gather's transpose stays a GATHER — XLA
    # would otherwise emit a scatter-add for the take's transpose, which
    # dominated the backward wall clock. ``Pᵀ = P`` for an involution,
    # so ct_u = ∂g/∂u · ct + P(∂g/∂up · ct); the elementwise partials
    # come from jax.vjp of the pure-elementwise g, keeping the min/max
    # tie-breaking and NaN routing bit-identical to the per-stage oracle.
    def fwd(u):
        return g(u, jnp.take(u, partner, axis=axis))

    def bwd(u, ct):
        up = jnp.take(u, partner, axis=axis)
        _, vjp = jax.vjp(g, u, up)
        d1, d2 = vjp(ct)
        return d1 + jnp.take(d2, partner, axis=axis)

    return fwd, bwd


def _bmmc_table(b: Bmmc) -> np.ndarray:
    """``tab[i] = b.apply(i)`` vectorized over all ``2^n`` indices."""
    idx = np.arange(1 << b.n, dtype=np.int64)
    out = np.zeros_like(idx)
    for j, row in enumerate(b.rows):
        out |= _np_parity(idx & row) << j
    return out ^ b.c


_BwdPlan = collections.namedtuple(
    "_BwdPlan", ["n", "recs", "links", "segs", "final", "has_bfly"])


@functools.lru_cache(maxsize=256)
def _program_bwd_plan(prog: Program, batched: bool):
    """The collapsed whole-program backward plan (DESIGN.md §13), or
    None when a stage falls outside the pairwise algebra (``Map``).

    Every transposed compute in the backward chain is a PAIRWISE op
    (XOR-partner gather plus elementwise math), so it can be conjugated
    through the BMMC passes that follow it in backward time: with
    ``Π`` the accumulated permutation, ``Lᵀ`` becomes ``Π⁻¹ Lᵀ Π`` —
    still pairwise, with pairing vector and per-element tables permuted
    OFFLINE (closure of the affine group under conjugation, the same
    §7.2 algebra the forward clusterer uses). Bubbling every perm to
    the end collapses the entire backward to: all transposed computes
    in forward-OUTPUT coordinates, then ONE composed inverse BMMC pass
    — the backward mirror of the paper's "everything is one BMMC"
    thesis, and the reason fwd+bwd costs ~2 passes, not ~2 per stage.

    The sweep executes maximal same-kind link runs as single
    :func:`jax.lax.scan`\\ s over stacked per-link tables. This is not
    just compile-size hygiene: XLA CPU's loop-fusion emitter re-emits a
    producer once per in-fusion gather consumer, so a chained
    gather-of-the-cotangent backward fused into one kLoop recomputes
    the upstream chain at a fresh permuted index every link — measured
    EXPONENTIAL wall clock in chain depth (k=5: 351µs → k=7: 4.9ms on a
    2^8×8 batch) with a linear-size HLO, and ``optimization_barrier``
    does not split the fusion. A scan body is a separate XLA
    computation, so fusion physically cannot span links.

    Returns ``(n, recs, links, segs, final, has_bfly)``:

    - ``recs[k] = (res_index, fwd_fns | None)`` — one per compute-bearing
      stage in BACKWARD order; ``fwd_fns`` recomputes the pulled-back
      intermediate chain from the saved stage input (None when no link
      needs intermediates, e.g. all-butterfly: linear, residual-free).
    - ``links`` — transposed computes in backward-time order, conjugated
      into output coordinates: ``("cmp", rec, j, gu, gup, pY)`` with
      ``gu``/``gup`` the static u/partner gather tables and ``pY`` the
      conjugated pairing; ``("bfly", pY, side0, w_re, w_im)``.
    - ``segs`` — maximal same-kind runs ``(kind, link indices)``.
    - ``final`` — the composed inverse BMMC as a compute-free
      :class:`FusedStage` (one megakernel/class-dispatch pass), or None
      if it collapses to the identity.
    """
    n = None
    for st in prog:
        if isinstance(st, FusedStage):
            if any(isinstance(c, Map) for c, _ in st.computes):
                return None
            n = st.bmmc.n
        elif isinstance(st, Perm):
            n = st.bmmc.n
        elif not isinstance(st, (CmpHalves, Bfly)):
            return None
    if n is None:
        return None
    ident = Bmmc.identity(n)
    # residual slots: res[0] is the program input (kept for the replay
    # fallback), then one entry per compute-bearing stage in forward
    # order — permutation stages and perm-only clusters save NOTHING
    res_of, ri = {}, 1
    for si, st in enumerate(prog):
        if isinstance(st, (CmpHalves, Bfly)) or (
                isinstance(st, FusedStage) and st.computes):
            res_of[si] = ri
            ri += 1
    sigma = ident  # X-coords -> Y-coords map of the perms bubbled so far
    links, recs = [], []
    has_bfly = False
    for si in range(len(prog) - 1, -1, -1):
        st = prog[si]
        if isinstance(st, Perm):
            sigma = sigma @ st.bmmc
            continue
        if isinstance(st, FusedStage):
            # FSᵀ = c̃1ᵀ ∘ … ∘ c̃mᵀ ∘ B⁻¹: the B⁻¹ factor bubbles first,
            # so the cluster's own links are conjugated through it too
            sigma = sigma @ st.bmmc
            comps = st.computes
        else:
            comps = ((st, ident),)
        if not comps:
            continue
        rec_id = len(recs)
        fwds = tuple(_pulled_back_fn(c, p, batched)[0] for c, p in comps)
        recs.append([res_of[si], fwds, False])
        tau_tab = _bmmc_table(sigma.inverse())  # Y index -> link-space index
        a_off = sigma.apply(0)
        for j in range(len(comps) - 1, -1, -1):
            comp, prefix = comps[j]
            kind = "cmp" if isinstance(comp, CmpHalves) else "bfly"
            partner, side0, w_idx = _pulled_back_tables(prefix, kind)
            pv = int(pairing_vector(prefix))
            # conjugated pairing: partner'(y) = σ(σ⁻¹(y) ^ v) = y ^ A_σ v
            p_y = (np.arange(1 << n, dtype=np.int64)
                   ^ (sigma.apply(pv) ^ a_off)).astype(np.int32)
            if kind == "cmp":
                recs[rec_id][2] = True  # masks need the recomputed chain
                links.append(("cmp", rec_id, j, tau_tab.astype(np.int32),
                              (tau_tab ^ pv).astype(np.int32), p_y))
            else:
                has_bfly = True
                w = np.asarray(comp.twiddles, np.complex128)[w_idx]
                links.append(("bfly", p_y, side0[tau_tab],
                              np.ascontiguousarray(w.real)[tau_tab],
                              np.ascontiguousarray(w.imag)[tau_tab]))
    recs = tuple((r[0], r[1] if r[2] else None) for r in recs)
    segs, start = [], 0
    for i in range(1, len(links) + 1):
        if i == len(links) or links[i][0] != links[start][0]:
            segs.append((links[start][0], tuple(range(start, i))))
            start = i
    final = None
    if not sigma.is_identity_perm():
        # Perm(g) gathers from g⁻¹, so realizing the bubbled op (source
        # map σ) takes the stage whose BMMC is σ⁻¹
        final = _run_fused((Perm(sigma.inverse()),), n)
    return _BwdPlan(n, recs, tuple(links), tuple(segs), final, has_bfly)


def _collapsed_cmp_sweep(ct, entries, us, axis):
    """Backward sweep over a run of conjugated transposed compares, two
    links per scan step (backward-time order).

    The compare's VJP factors as ``ct ↦ m1 ⊙ ct + P(m2 ⊙ ct)`` with
    jax's balanced-eq tie masks ``m1 = 1{u==o} / (1 + 1{up==o})`` (and
    ``m2`` with the roles swapped) — identical on both min/max branches
    GIVEN the forward output ``o``, so the side predicate drops out.
    The masks depend only on the recomputed intermediates, never on the
    cotangent, so they are computed VECTORIZED over the link axis
    outside the loop; the scan body — the only sequential part — is
    four ops per link. Mask values are exactly ``{0, 1/2, 1}`` built by
    nested selects (no divide), bitwise-equal to the balanced-eq
    quotient, so VALUES match ``jax.vjp`` of the per-stage replay
    exactly; only their positions ride in permuted coordinates until
    the final composed pass.

    Layout notes, all measured on the 2^8×8 sort backward: the link
    axis is stacked at ``axis`` (right before the index axis) and then
    FLATTENED into it, so the three conjugation gathers are plain 1-D
    static ``take``\\ s — the batched ``take_along_axis`` form lowers to
    an XLA gather with batch dims that costs ~2.5× more here. Pairing
    two links per scan step halves the loop overhead; wider groups
    regress (G=6 is 4× slower than G=2) because XLA CPU's fusion
    emitter re-emits the cotangent chain once per in-body gather
    consumer — the same recompute pathology that makes the scan
    necessary in the first place (see :func:`_program_bwd_plan`)."""
    dt = ct.dtype
    L = len(entries)
    n_idx = entries[0][3].size
    # stack links at `axis`, flatten (L, 2^n) -> (L*2^n,) for flat takes
    u_stack = jnp.stack([us[e[1]][e[2]] for e in entries], axis=axis)
    o_stack = jnp.stack([us[e[1]][e[2] + 1] for e in entries], axis=axis)
    flat_shape = u_stack.shape[:axis] + (L * n_idx,) + u_stack.shape[axis + 2:]
    u_stack = u_stack.reshape(flat_shape)
    o_stack = o_stack.reshape(flat_shape)
    offs = np.arange(L, dtype=np.int64)[:, None] * n_idx

    def flat_idx(tabs):
        idx = offs + np.stack(tabs).astype(np.int64)
        return idx.reshape(-1).astype(np.int32 if L * n_idx < 2**31
                                      else np.int64)

    f_tab = flat_idx([e[3] for e in entries])
    f_tabp = flat_idx([e[4] for e in entries])
    ueq = jnp.take(u_stack, f_tab, axis=axis) == jnp.take(
        o_stack, f_tab, axis=axis)
    peq = jnp.take(u_stack, f_tabp, axis=axis) == jnp.take(
        o_stack, f_tab, axis=axis)
    half = jnp.asarray(0.5, dt)
    one = jnp.ones((), dt)
    zero = jnp.zeros((), dt)
    m1 = jnp.where(ueq, jnp.where(peq, half, one), zero)
    m2 = jnp.where(peq, jnp.where(ueq, half, one), zero)
    link_shape = m1.shape[:axis] + (L, n_idx) + m1.shape[axis + 1:]
    m1 = jnp.moveaxis(m1.reshape(link_shape), axis, 0)
    m2 = jnp.moveaxis(m2.reshape(link_shape), axis, 0)
    p_stack = np.stack([e[5] for e in entries])

    def one_link(c, m1_, m2_, p_):
        return m1_ * c + jnp.take(m2_ * c, p_, axis=axis)

    head = L % 2
    if head:
        ct = one_link(ct, m1[0], m2[0], p_stack[0])
    if L > head:
        pairs = (L - head) // 2
        m1g = m1[head:].reshape((pairs, 2) + m1.shape[1:])
        m2g = m2[head:].reshape((pairs, 2) + m2.shape[1:])
        pg = p_stack[head:].reshape(pairs, 2, -1)

        def body(c, xs):
            m1_, m2_, p_ = xs
            c = one_link(c, m1_[0], m2_[0], p_[0])
            return one_link(c, m1_[1], m2_[1], p_[1]), None

        ct, _ = jax.lax.scan(body, ct, (m1g, m2g, pg))
    return ct


def _collapsed_bfly_sweep(ct, entries, axis):
    """Backward sweep over a run of conjugated transposed butterflies
    (planar layout), one scan step per link in backward-time order. The
    stage is LINEAR — pair ``(a₀, a₁) ↦ (a₀ + W a₁, a₀ − W a₁)`` with
    ``W`` the twiddle rotation — so its transpose ``ct₀ ↦ ct₀ + ct₁,
    ct₁ ↦ Wᵀ(ct₀ − ct₁)`` needs no forward intermediates at all."""
    dt = ct.dtype
    p_stack = np.stack([e[1] for e in entries])
    # side0 stays 1-D: the body selects on component slices ``c[..., k]``
    # whose planar axis is already gone, so it broadcasts over the index
    # axis only (leading batch dims broadcast from the left)
    s_stack = np.stack([e[2] for e in entries])
    wr_stack = np.stack([e[3] for e in entries]).astype(dt)
    wi_stack = np.stack([e[4] for e in entries]).astype(dt)

    def body(c, xs):
        p, s0, wr, wi = xs
        q = jnp.take(c, p, axis=axis)
        s_re = q[..., 0] - c[..., 0]
        s_im = q[..., 1] - c[..., 1]
        wt_re = wr * s_re + wi * s_im
        wt_im = wr * s_im - wi * s_re
        out = jnp.stack([jnp.where(s0, c[..., 0] + q[..., 0], wt_re),
                         jnp.where(s0, c[..., 1] + q[..., 1], wt_im)],
                        axis=-1)
        return out, None

    ct, _ = jax.lax.scan(body, ct, (p_stack, s_stack, wr_stack, wi_stack))
    return ct


def _collapsed_bwd(plan, res, ct, engine, batched):
    """Execute a collapsed backward plan: recompute the pulled-back
    intermediate chains from the saved stage inputs, sweep every
    transposed compute in forward-output coordinates, then dispatch the
    ONE composed inverse BMMC pass through the fused engine."""
    axis = 1 if batched else 0
    us = []
    for res_i, fwds in plan.recs:
        if fwds is None:
            us.append(None)
            continue
        chain = [res[res_i]]
        for f in fwds:
            chain.append(f(chain[-1]))
        us.append(chain)
    for kind, idxs in plan.segs:
        entries = [plan.links[i] for i in idxs]
        if kind == "cmp":
            ct = _collapsed_cmp_sweep(ct, entries, us, axis)
        else:
            ct = _collapsed_bfly_sweep(ct, entries, axis)
    if plan.final is not None:
        ct = fused_apply(ct, plan.final, engine, batched)
    return ct


@functools.lru_cache(maxsize=256)
def _fused_bwd_kernel_plan(fs: FusedStage, t: int):
    """Offline artifacts of the gradient megakernel for one cluster, or
    None when it can't run at this tile parameter: the forward plan +
    epilogue entries (shared tables), the inverse ``src0`` gather table
    (``inv[src0[j]] = j``; the per-tile XOR folds into the lookup at
    kernel time), and the inverse plans of any trailing plain passes
    (§5.2 two-pass factorizations — undone pass-by-pass before the
    gradient kernel, keeping the backward round-trip count equal to the
    forward's)."""
    got = _fused_plan_cached(fs, t)
    if got is None:
        return None
    plans, entries = got
    p = plans[0].src0.reshape(-1)
    inv_src0 = np.empty_like(p)
    inv_src0[p] = np.arange(p.size, dtype=p.dtype)
    inv_src0 = inv_src0.reshape(plans[0].src0.shape)
    extra = []
    for pass_plan in plans[1:]:
        try:
            extra.append(tuple(plan_bmmc(pass_plan.bmmc.inverse(), t)))
        except ValueError:
            return None
        if len(extra[-1]) != 1:
            return None  # inverse pass count must mirror the forward's
    return plans, entries, inv_src0, tuple(extra)


def _fused_bwd_pallas(fs, t, batched, x, ct, *, interpret=True):
    """One-kernel cluster backward: undo the trailing plain passes, then
    dispatch the gradient megakernel over the forward's own plan."""
    plans, entries, inv_src0, extra = _fused_bwd_kernel_plan(fs, t)
    for inv_plans in reversed(extra):
        for p in inv_plans:
            run = _geom_executable(plan_geometry(p), interpret, batched)
            ct = run(ct, p.in_rows, p.out_rows, p.xor_low, p.src0)
    plan = plans[0]
    sig, scal, vmem, map_fns = _fused_kernel_args(entries, x.dtype)
    run = _geom_bwd_executable(plan_geometry(plan), interpret, batched,
                               sig, map_fns)
    return run(x, ct, plan.in_rows, plan.out_rows, plan.xor_low, inv_src0,
               epi_scalar=scal, epi_vmem=vmem)


# The one-kernel gradient megakernel (`_tile_bwd_kernel`) is the
# hardware-shaped backward: ONE pallas round trip per compute cluster,
# streaming the saved input alongside the cotangent and replaying /
# transposing every epilogue in VMEM. Under interpret mode the emulated
# kernel's cost scales with the traced in-VMEM body (measured 1.7-3x the
# forward per cluster at 2^8), so the mask-precomputed scan sweep below
# — which keeps all link-parallel work in plain XLA fusions and carries
# only the cotangent through the sequential part — is faster on this
# backend. Flip this for compiled-backend runs; the kernel path keeps
# bitwise-parity coverage in tests either way.
BWD_MEGAKERNEL = False


def _fused_bwd_impl(fs, engine, batched, x, ct):
    if not fs.computes:
        # permutation-only: dispatch the precompiled inverse cluster —
        # same megakernel path, same class, zero residuals (x is None)
        return fused_apply(ct, _fused_inverse_cached(fs), engine, batched)
    lead = 1 if batched else 0
    planar = ct.ndim == 2 + lead and ct.shape[-1] == 2
    if jnp.iscomplexobj(ct) or (not planar and any(
            isinstance(c, Bfly) for c, _ in fs.computes)):
        # layouts the pulled-back tables don't model (complex / non-planar
        # butterflies): replay the stage program under jax.vjp, matching
        # the forward's own oracle fallback for these inputs
        _, vjp = jax.vjp(
            lambda v: run_program(fs.stages, v, engine, batched=batched), x)
        return vjp(ct)[0]
    if engine == "pallas" and BWD_MEGAKERNEL:
        t = _fused_tile(x, fs, batched)
        if t is not None and _fused_bwd_kernel_plan(fs, t) is not None:
            if _otrace._state.enabled:
                plans, _, _, extra = _fused_bwd_kernel_plan(fs, t)
                rt = 1 + sum(len(ip) for ip in extra)
                _ometrics.inc("dispatch.kernel", kernel="fused")
                _ometrics.inc("model.round_trips", rt)
                # the gradient kernel streams x in ADDITION to ct: its
                # descriptor count is the forward's plus one extra read
                # stream per tile — counted honestly, not mirrored
                p0 = plans[0]
                _ometrics.inc(
                    "dma.descriptors",
                    p0.dma_descriptors()
                    + p0.n_tiles * (p0.rows_per_tile // p0.in_run)
                    + sum(p.dma_descriptors()
                          for ip in extra for p in ip))
                with _otrace.span("kernel.fused_bwd", stages=len(fs.stages),
                                  passes=rt, t=t):
                    return _fused_bwd_pallas(fs, t, batched, x, ct)
            return _fused_bwd_pallas(fs, t, batched, x, ct)
    plan = _program_bwd_plan((fs,), batched)
    if plan is None:
        # Map-bearing cluster: replay the stage program under jax.vjp
        # (per-stage custom-vjp boundaries — linear, no fusion blowup)
        _, vjp = jax.vjp(
            lambda v: run_program(fs.stages, v, engine, batched=batched), x)
        return vjp(ct)[0]
    return _collapsed_bwd(plan, (x, x), ct, engine, batched)


@functools.partial(jax.custom_vjp, nondiff_argnums=(1, 2, 3))
def fused_apply(x: jax.Array, fs: FusedStage,
                engine: Union[str, EngineFn, None] = None,
                batched: bool = False) -> jax.Array:
    """Differentiable fused-cluster execution.

    Forward: ONE megakernel pass on the "pallas" engine (per-stage
    otherwise). Backward (DESIGN.md §13): a permutation-only cluster
    saves NO residual and dispatches its precompiled inverse cluster;
    a compute-bearing cluster saves only its input and runs the
    pulled-back backward — one inverse megakernel for the composed
    ``B⁻¹`` plus the jnp VJPs of the input-space pairwise computes.
    The old per-stage ``jax.vjp`` replay survives only as the fallback
    for layouts the pulled-back tables don't model.
    """
    return _fused_forward(x, fs, engine, batched)


def _fused_fwd(x, fs, engine, batched):
    # permutation-only clusters need no residual: their cotangent rule
    # is the precompiled inverse cluster applied to ``ct`` alone
    return (_fused_forward(x, fs, engine, batched),
            x if fs.computes else None)


def _fused_bwd(fs, engine, batched, x, ct):
    return (_vjp_observed(
        "fused", lambda: _fused_bwd_impl(fs, engine, batched, x, ct)),)


fused_apply.defvjp(_fused_fwd, _fused_bwd)


# ---------------------------------------------------------------------------
# perm_apply — the differentiable permutation primitive
# ---------------------------------------------------------------------------

_BATCHED_SIG = weakref.WeakKeyDictionary()  # doesn't pin injected engines


def _accepts_batched(fn: Callable) -> bool:
    # only an explicit ``batched`` parameter proves support — a bare
    # ``**kwargs`` would swallow the flag and permute the wrong axis
    try:
        return _BATCHED_SIG[fn]
    except (KeyError, TypeError):
        pass
    try:
        got = "batched" in inspect.signature(fn).parameters
    except (TypeError, ValueError):  # builtins, odd callables
        got = False
    try:
        _BATCHED_SIG[fn] = got
    except TypeError:  # not weakref-able; just re-probe next time
        pass
    return got


def _call_engine(fn: EngineFn, x: jax.Array, bmmc: Bmmc,
                 batched: bool) -> jax.Array:
    """Invoke an engine, vmapping over the batch axis if it only speaks the
    unbatched ``(x, bmmc) -> x`` protocol."""
    if not batched:
        return fn(x, bmmc)
    if _accepts_batched(fn):
        return fn(x, bmmc, batched=True)
    return jax.vmap(lambda xb: fn(xb, bmmc))(x)


@functools.partial(jax.custom_vjp, nondiff_argnums=(1, 2, 3))
def perm_apply(x: jax.Array, bmmc: Bmmc,
               engine: Union[str, EngineFn, None] = None,
               batched: bool = False) -> jax.Array:
    """Differentiable BMMC permutation through any engine.

    The VJP applies ``bmmc.inverse()`` — precomputed offline over F2 —
    through the *same* engine: the cotangent of a pallas-permuted array is
    itself a pallas permutation (no gather transpose is materialized, and
    backward passes share the forward geometry cache).
    """
    return _call_engine(get_engine(engine), x, bmmc, batched)


def _perm_apply_fwd(x, bmmc, engine, batched):
    return perm_apply(x, bmmc, engine, batched), None


def _perm_apply_bwd(bmmc, engine, batched, _res, ct):
    return (_vjp_observed("stage", lambda: perm_apply(
        ct, bmmc.inverse(), engine, batched)),)


perm_apply.defvjp(_perm_apply_fwd, _perm_apply_bwd)


# ---------------------------------------------------------------------------
# Program execution
# ---------------------------------------------------------------------------

def _apply_bfly(x: jax.Array, twiddles: tuple, axis: int = 0) -> jax.Array:
    """(lo, hi) -> (lo + w·hi, lo - w·hi) along ``axis``. Complex arrays, or
    float arrays with a trailing dim of 2 holding (re, im) channels."""
    h = x.shape[axis] // 2
    lo = jax.lax.slice_in_dim(x, 0, h, axis=axis)
    hi = jax.lax.slice_in_dim(x, h, 2 * h, axis=axis)
    if jnp.iscomplexobj(x):
        w = jnp.asarray(np.asarray(twiddles, dtype=np.complex64))
        w = w.reshape((1,) * axis + (h,) + (1,) * (x.ndim - axis - 1))
        t = w * hi
        return jnp.concatenate([lo + t, lo - t], axis=axis)
    if x.ndim != axis + 2 or x.shape[-1] != 2:
        from ..guard.errors import BadInput
        raise BadInput("real-typed Bfly input must have a trailing "
                       f"(re, im) dim of 2; got shape {x.shape}")
    wshape = (1,) * axis + (h,)
    wr = jnp.asarray(np.asarray([w.real for w in twiddles],
                                dtype=x.dtype)).reshape(wshape)
    wi = jnp.asarray(np.asarray([w.imag for w in twiddles],
                                dtype=x.dtype)).reshape(wshape)
    tre = wr * hi[..., 0] - wi * hi[..., 1]
    tim = wr * hi[..., 1] + wi * hi[..., 0]
    t = jnp.stack([tre, tim], axis=-1)
    return jnp.concatenate([lo + t, lo - t], axis=axis)


def _exec_stage(s: Expr, x: jax.Array, engine, batched: bool,
                axis: int) -> jax.Array:
    """Dispatch ONE primitive/fused stage (the run_program loop body)."""
    if isinstance(s, Perm):
        return perm_apply(x, s.bmmc, engine, batched)
    if isinstance(s, FusedStage):
        return fused_apply(x, s, engine, batched)
    if isinstance(s, CmpHalves):
        h = x.shape[axis] // 2
        lo = jax.lax.slice_in_dim(x, 0, h, axis=axis)
        hi = jax.lax.slice_in_dim(x, h, 2 * h, axis=axis)
        return jnp.concatenate([jnp.minimum(lo, hi), jnp.maximum(lo, hi)],
                               axis=axis)
    if isinstance(s, Bfly):
        return _apply_bfly(x, s.twiddles, axis)
    if isinstance(s, Map):
        return s.fn(x)
    from ..guard.errors import BadStage
    raise BadStage(f"non-primitive stage {type(s).__name__}; "
                   "lower() the expression first")


def run_program(program: Sequence[Expr], x: jax.Array,
                engine: Union[str, EngineFn, None] = None,
                *, batched: bool = False) -> jax.Array:
    """Execute a lowered (primitive-only) stage program.

    Differentiable: ``Perm`` stages go through :func:`perm_apply` (offline
    -inverted backward pass), the rest are plain jnp. ``batched=True``
    moves the permuted axis to axis 1, with a leading batch dim.

    When telemetry is enabled each stage records a ``stage.*`` span and
    standalone computes count as ``sweep`` kernel dispatches (matching
    :func:`repro.combinators.optimize.program_cost`); the check is one
    module attribute, so the disabled path is the plain loop below.
    """
    get_engine(engine)  # validate the name up front, even for Perm-free
    axis = 1 if batched else 0
    if not _otrace._state.enabled:
        for s in program:
            x = _exec_stage(s, x, engine, batched, axis)
        return x
    for s in program:
        kind = type(s).__name__.lower()
        with _otrace.span("stage." + kind):
            x = _exec_stage(s, x, engine, batched, axis)
        if isinstance(s, COMPUTES):
            # a standalone compute pays one full elementwise HBM sweep —
            # the same unit program_cost charges it
            _ometrics.inc("dispatch.kernel", kernel="sweep")
            _ometrics.inc("model.round_trips", 1)
    return x


# ---------------------------------------------------------------------------
# compile_expr — the compiled-plan cache
# ---------------------------------------------------------------------------

@functools.lru_cache(maxsize=1024)
def _lowered_cached(expr: Expr, n: int, optimized: bool) -> Program:
    prog = lower(expr, n)
    return fuse(prog) if optimized else prog


@functools.lru_cache(maxsize=1024)
def _clustered_cached(expr: Expr, n: int, optimized: bool,
                      t: int) -> tuple:
    prog = cluster(_lowered_cached(expr, n, optimized), n, t)
    return fold_free(prog, n, t)


# ---------------------------------------------------------------------------
# Whole-program executables: ONE jitted callable per (program, engine,
# batched) key. All per-stage Python work — plan-cache lookups, table ->
# device conversion, DMA descriptor enumeration, kernel re-dispatch —
# happens once at trace time; the offline tables are baked into the
# jaxpr as constants. Repeated calls pay a single XLA dispatch instead
# of one Python round per stage (the host-side overhead that dominates
# multi-stage programs: the 2^12 sort re-dispatched 79 fused stages per
# call before this cache). The key is independent of batch size, dtype
# and trailing dims — jax.jit re-specializes on those internally without
# growing this cache.
# ---------------------------------------------------------------------------


def _has_map(prog: Program) -> bool:
    """Does the program carry a user ``Map`` callable (top-level or
    inside a cluster's replay stages)?"""
    return any(isinstance(s, Map)
               or (isinstance(s, FusedStage)
                   and any(isinstance(ss, Map) for ss in s.stages))
               for s in prog)


@functools.lru_cache(maxsize=512)
def _program_executable(prog: Program, engine: str, batched: bool):
    def run(x):
        return run_program(prog, x, engine, batched=batched)
    return jax.jit(run)


@functools.lru_cache(maxsize=512)
def _program_round_trips(prog: Program, t: Optional[int]) -> Optional[int]:
    """Modeled HBM round trips of a resolved program — the per-call
    model-vs-measured accounting unit (telemetry only)."""
    if t is None:
        return None
    from .optimize import program_cost
    return program_cost(prog, t)["round_trips"]


@functools.lru_cache(maxsize=512)
def _inverse_program_cached(prog: Program) -> Program:
    """The offline-inverted program (clusters invert to clusters) —
    what :func:`program_apply`'s backward dispatches."""
    return inverse_program(prog)


def _observed_program_call(prog: Program, t: Optional[int], x: jax.Array,
                           engine, batched: bool,
                           use_exec: bool) -> jax.Array:
    """The telemetry-enabled whole-program call path: one
    ``program.call`` span + latency histogram per invocation, warm/cold
    labeled by whether a fresh jit trace ran, and the modeled round
    trips accumulated so ``obs.model_vs_measured()`` can hold the
    transaction model against the wall clock. Blocks on the result only
    when ``obs.enable(sync=True)`` asked for end-to-end timings."""
    eng = engine if isinstance(engine, str) else "injected"
    with _otrace.span("program.call", engine=eng, stages=len(prog),
                      path="executable" if use_exec else "per-stage",
                      batched=batched) as sargs:
        t0 = time.perf_counter_ns()
        if use_exec:
            misses0 = _program_executable.cache_info().misses
            out = _program_executable(prog, engine, batched)(x)
            cold = _program_executable.cache_info().misses > misses0
        else:
            out = run_program(prog, x, engine, batched=batched)
            cold = False
        if _otrace._state.sync:
            jax.block_until_ready(out)
        dur_us = (time.perf_counter_ns() - t0) / 1e3
        rt = _program_round_trips(prog, t)
        sargs["dur_us"] = round(dur_us, 1)
        sargs["cache"] = "cold" if cold else "warm"
        if rt is not None:
            sargs["model_round_trips"] = rt
    _ometrics.observe("program.call_us", dur_us, engine=eng,
                      cache="cold" if cold else "warm")
    if rt is not None:
        _ometrics.inc("program.model_round_trips", rt)
        if not cold:
            _ometrics.observe("program.us_per_round_trip",
                              dur_us / max(rt, 1), engine=eng)
    return out


def _dispatch_program(prog: Program, t: Optional[int], x: jax.Array,
                      engine, batched: bool) -> jax.Array:
    """Run a resolved program: whole-program executable when the engine
    is named and the program carries no user ``Map`` (one XLA dispatch
    per call), eager per-stage otherwise; observed when telemetry is on."""
    use_exec = isinstance(engine, str) and not _has_map(prog)
    if not _otrace._state.enabled:
        if use_exec:
            return _program_executable(prog, engine, batched)(x)
        return run_program(prog, x, engine, batched=batched)
    return _observed_program_call(prog, t, x, engine, batched, use_exec)


@functools.partial(jax.custom_vjp, nondiff_argnums=(1, 2, 3, 4))
def program_apply(x: jax.Array, prog: Program, t: Optional[int],
                  engine: Union[str, EngineFn, None] = None,
                  batched: bool = False) -> jax.Array:
    """Differentiable whole-program execution.

    Forward and backward are SYMMETRIC compiled programs, and the whole
    call is ONE custom-vjp boundary (the per-stage ``perm_apply`` /
    ``fused_apply`` rules never fire under it):

    - permutation-only programs dispatch the offline-inverted program —
      the *clustered* inverse of a clustered forward, so every stage
      keeps its kernel class — through its own ``(program, engine,
      batched)`` whole-program executable entry; NO residuals are saved.
    - compute-bearing programs run the COLLAPSED backward
      (:func:`_program_bwd_plan`): every transposed pairwise compute
      conjugated into forward-output coordinates, then ONE composed
      inverse BMMC pass. Residuals are the inputs of compute-bearing
      stages only (a permutation needs none).
    - anything else (``Map`` stages, complex dtypes, non-planar
      butterflies) falls back to the per-stage ``jax.vjp`` replay.
    """
    return _dispatch_program(prog, t, x, engine, batched)


def _program_apply_fwd(x, prog, t, engine, batched):
    if is_perm_program(prog):
        return program_apply(x, prog, t, engine, batched), None
    res = [x]
    v = x
    for st in prog:
        if isinstance(st, (CmpHalves, Bfly, Map)) or (
                isinstance(st, FusedStage) and st.computes):
            res.append(v)
        v = run_program((st,), v, engine, batched=batched)
    return v, tuple(res)


def _program_apply_bwd(prog, t, engine, batched, res, ct):
    if is_perm_program(prog):
        return (_vjp_observed("program", lambda: program_apply(
            ct, _inverse_program_cached(prog), t, engine, batched)),)
    plan = _program_bwd_plan(prog, batched)
    lead = 1 if batched else 0
    planar = ct.ndim == 2 + lead and ct.shape[-1] == 2
    if plan is None or jnp.iscomplexobj(ct) or (
            plan.has_bfly and not planar):
        x0 = res[0]

        def replay():
            _, vjp = jax.vjp(lambda v: run_program(
                prog, v, engine, batched=batched), x0)
            return vjp(ct)[0]

        return (_vjp_observed("program", replay),)
    return (_vjp_observed("program", lambda: _collapsed_bwd(
        plan, res, ct, engine, batched)),)


program_apply.defvjp(_program_apply_fwd, _program_apply_bwd)


CacheStats = collections.namedtuple(
    "CacheStats", ["hits", "misses", "maxsize", "currsize"])


def cache_stats() -> Dict[str, CacheStats]:
    """Aggregate stats for EVERY executor/ops cache, by name.

    Covers the kernel-executable caches (``geom`` / ``block`` / ``lane``
    / ``program``), the plan/table caches (``fused_plan`` / ``w_planar``
    / ``lowered`` / ``clustered`` / ``model_round_trips`` and the ops
    ``plans`` / ``class_plan``), and the ``compiled_exprs`` memo.
    Replaces the old single-cache ``geom_cache_info`` /
    ``program_cache_info`` pair, which made every other cache invisible.
    """
    from ..kernels import ops

    out = {
        "geom": _geom_executable,
        "block": _block_executable,
        "lane": _lane_executable,
        "program": _program_executable,
        "fused_plan": _fused_plan_cached,
        "w_planar": _w_planar_cached,
        "lowered": _lowered_cached,
        "clustered": _clustered_cached,
        "model_round_trips": _program_round_trips,
        "inverse_program": _inverse_program_cached,
        "fused_inverse": _fused_inverse_cached,
        "program_bwd_plan": _program_bwd_plan,
        "fused_bwd_kernel_plan": _fused_bwd_kernel_plan,
        "geom_bwd": _geom_bwd_executable,
        "pulled_back": _pulled_back_fn,
        "plans": ops._plans_cached,
        "class_plan": ops._class_plan_cached,
    }
    stats = {name: CacheStats(*fn.cache_info()) for name, fn in out.items()}
    stats["compiled_exprs"] = CacheStats(
        hits=_compiled_stats["hits"], misses=_compiled_stats["misses"],
        maxsize=None, currsize=len(_COMPILED))
    from ..guard.validate import guard_cache_stats
    for name, info in guard_cache_stats().items():
        stats[name] = CacheStats(*info)
    from .. import store as _store
    ss = _store.stats()
    st = _store.active()
    stats["store"] = CacheStats(
        hits=ss["hit"], misses=ss["miss"], maxsize=None,
        currsize=st.entry_count() if st is not None else 0)
    return stats


class CompiledExpr:
    """A callable compiled combinator expression — a first-class JAX value.

    Calling it executes the (fused) stage program through the chosen
    engine; the result is jit-able, ``jax.grad``-able (``Perm`` stages
    carry the offline-inverted custom VJP) and batchable via
    ``batched=True`` (leading batch dim sharing one tile plan).
    ``program(n)`` exposes the stage program for inspection; ``cost(n,
    t)`` the modeled transaction report; ``vjp_program(n)`` the exact
    program the backward pass of a permutation-only expression executes.
    """

    def __init__(self, expr: Expr, engine: Union[str, EngineFn],
                 optimized: bool):
        self.expr = expr
        self.engine = engine
        self.optimized = optimized

    def program(self, n: int) -> Program:
        return _lowered_cached(self.expr, n, self.optimized)

    def clustered_program(self, n: int, t: int) -> tuple:
        """The program with ``Perm → compute → Perm`` runs grouped into
        megakernel :class:`FusedStage`\\ s for tile parameter ``t`` —
        what the "pallas" engine actually executes."""
        return _clustered_cached(self.expr, n, self.optimized, t)

    def cost(self, n: int, t: int, itemsize: int = 4, *,
             clustered: bool = False) -> dict:
        from .optimize import program_cost
        prog = (self.clustered_program(n, t) if clustered
                else self.program(n))
        return program_cost(prog, t, itemsize)

    def is_permutation(self, n: int) -> bool:
        """True if the program is pure ``Perm`` stages (hence invertible)."""
        return all(isinstance(s, Perm) for s in self.program(n))

    def vjp_program(self, n: int, t: Optional[int] = None) -> Program:
        """The offline-inverted program (reversed stages, each BMMC
        inverted) — what the cotangent flows through. With ``t`` the
        CLUSTERED inverse — clusters invert to clusters (§13), which is
        exactly what the "pallas" backward executes. Permutation-only."""
        prog = self.program(n) if t is None else self.clustered_program(n, t)
        return inverse_program(prog)

    def vjp_round_trips(self, n: int, t: Optional[int],
                        batched: bool = False) -> Optional[int]:
        """Modeled HBM round trips of ONE backward (cotangent) pass —
        what a cold backward call's ``model.round_trips`` counter delta
        should equal (the backward honesty gate, DESIGN.md §13).
        Permutation-only programs dispatch the clustered inverse
        program; compute-bearing programs with a collapsed plan pay
        exactly the final composed pass. None when the backward is the
        per-stage replay (no compiled model to hold it against)."""
        from .optimize import program_cost
        prog = (self.clustered_program(n, t)
                if self.engine == "pallas" and self.optimized
                and t is not None else self.program(n))
        if is_perm_program(prog):
            if t is None:
                return None
            return program_cost(inverse_program(prog), t)["round_trips"]
        plan = _program_bwd_plan(prog, batched)
        if plan is None or t is None:
            return None
        if plan.final is None:
            return 0
        return program_cost((plan.final,), t)["round_trips"]

    def inverse(self, n: int) -> "CompiledExpr":
        """The compiled inverse of a permutation-only expression."""
        from .ir import seq
        inv = seq(*self.vjp_program(n))
        return compile_expr(inv, engine=self.engine, optimize=self.optimized)

    def _resolve(self, x: jax.Array, batched: bool) -> tuple:
        """(program, tile parameter) the executor will run on ``x``."""
        from ..guard.errors import BadInput
        axis = 1 if batched else 0
        if x.ndim <= axis:
            what = ("a leading batch dim plus the permuted axis" if batched
                    else "a permutable leading axis")
            raise BadInput(f"input needs {what}, got shape {x.shape}")
        n = int(x.shape[axis]).bit_length() - 1
        if (1 << n) != x.shape[axis]:
            raise BadInput(
                f"array length {x.shape[axis]} is not a power of 2")
        from ..kernels.ops import choose_tile
        d = x.shape[axis + 1] if x.ndim == axis + 2 else 1
        t = choose_tile(n, x.dtype.itemsize, d)
        prog = self.program(n)
        if self.engine == "pallas" and self.optimized and t is not None:
            # megakernel clustering + free-stage folding; the ref oracle
            # and injected engines stay stage-at-a-time
            prog = self.clustered_program(n, t)
        from .. import guard as _g
        if _g.enabled():
            # ring 1: prove the resolved program's invariants (BMMC
            # invertibility, class-predicate consistency, descriptor
            # bounds) before any executable bakes its tables in. Cached
            # per (program, t); warm calls pay an identity-memo hit
            # (the deep program-tuple hash is too slow per call).
            from ..guard.validate import validate_program_fast
            validate_program_fast(tuple(prog), t)
        return prog, t

    def _resolve_program(self, x: jax.Array, batched: bool) -> Program:
        return self._resolve(x, batched)[0]

    def __call__(self, x: jax.Array, *, batched: bool = False) -> jax.Array:
        prog, t = self._resolve(x, batched)
        from .. import guard as _g
        if _g.enabled():
            from ..guard import runtime as _grt
            if _grt._trace_state_clean():
                # ring 2: guarded dispatch — program + in-program
                # probes in one executable (wrapping the inner jitted
                # _program_executable, so the cache/telemetry contracts
                # hold), flags resolved at this edge, with the pallas →
                # ref fallback machine on a trap. Skipped under an
                # outer trace (the flag readback needs a concrete
                # value); ring 1 in _resolve still ran.
                return _grt.guarded_call(prog, t, x, self.engine, batched)
        # Programs carrying user Map callables stay on the eager
        # per-stage path (inside _dispatch_program): Map's contract says
        # "a jax function", but eager execution historically tolerated
        # trace-unsafe fns (concrete-value branching, numpy round trips)
        # and wrapping them in jit would turn that tolerance into a crash.
        if is_perm_program(prog):
            # permutation-only: the whole call is ONE custom-vjp
            # primitive whose backward dispatches the precompiled
            # inverse program. Warm the inverse's executable-cache
            # entry alongside the forward so a training step's first
            # backward pays no extra Python-side cache miss.
            if isinstance(self.engine, str):
                _program_executable(_inverse_program_cached(prog),
                                    self.engine, batched)
            return program_apply(x, prog, t, self.engine, batched)
        if (not _has_map(prog)
                and _program_bwd_plan(prog, batched) is not None):
            # compute-bearing program with a collapsed backward plan:
            # one custom-vjp boundary; the backward sweeps every
            # transposed pairwise compute in forward-output coordinates
            # and finishes with ONE composed inverse BMMC pass (§13)
            return program_apply(x, prog, t, self.engine, batched)
        return _dispatch_program(prog, t, x, self.engine, batched)

    def call_per_stage(self, x: jax.Array, *,
                       batched: bool = False) -> jax.Array:
        """Execute stage-at-a-time through the Python dispatcher —
        the pre-executable path, kept for the host-side dispatch-
        overhead microbenchmark and as a debugging aid."""
        prog = self._resolve_program(x, batched)
        return run_program(prog, x, self.engine, batched=batched)


_COMPILED: Dict[tuple, CompiledExpr] = {}
_compiled_stats = {"hits": 0, "misses": 0}


def clear_caches() -> None:
    """Drop every compiled artifact the executor pins, and reset the
    telemetry counters/spans with them (cache hygiene: hit/miss counts
    and dispatch counters describe the caches being dropped).

    The geometry / block / lane / whole-program executable caches hold
    jitted pallas executables (each pinning a traced kernel),
    ``_COMPILED`` grows one entry per ``(expr, engine, optimize)``
    triple, and the plan/table caches hold offline numpy tables — none
    of which is bounded across a long geometry sweep. Test fixtures that
    iterate many sizes/dtypes call this between sweeps to keep memory
    flat.
    """
    from ..kernels import ops
    from .. import obs

    _geom_executable.cache_clear()
    _block_executable.cache_clear()
    _lane_executable.cache_clear()
    _program_executable.cache_clear()
    _fused_plan_cached.cache_clear()
    _w_planar_cached.cache_clear()
    _lowered_cached.cache_clear()
    _clustered_cached.cache_clear()
    _program_round_trips.cache_clear()
    _inverse_program_cached.cache_clear()
    _fused_inverse_cached.cache_clear()
    _program_bwd_plan.cache_clear()
    _fused_bwd_kernel_plan.cache_clear()
    _geom_bwd_executable.cache_clear()
    _pulled_back_fn.cache_clear()
    _pulled_back_tables.cache_clear()
    _COMPILED.clear()
    _compiled_stats["hits"] = _compiled_stats["misses"] = 0
    ops._plans_cached.cache_clear()
    ops._class_plan_cached.cache_clear()
    from ..guard.validate import clear_guard_caches
    clear_guard_caches()
    from .. import guard, resilience, store
    guard.reset_stats()
    store.reset_stats()
    resilience.reset()
    obs.reset()


def compile_expr(expr: Expr, *, engine: Union[str, EngineFn] = "pallas",
                 optimize: bool = True) -> CompiledExpr:
    """Compile ``expr`` to a jit-able function running minimal tiled passes.

    Lowered/fused programs, kernel plans, and kernel executables are all
    cached, so repeated calls (and repeated ``compile_expr`` of the same
    expression) share everything expensive.
    """
    key = (expr, engine if isinstance(engine, str) else id(engine), optimize)
    got = _COMPILED.get(key)
    if got is None:
        _compiled_stats["misses"] += 1
        got = _COMPILED[key] = CompiledExpr(expr, engine, optimize)
    else:
        _compiled_stats["hits"] += 1
    return got
