"""Multi-engine executor with a compiled-plan cache.

Engines map a ``Perm`` stage to an actual array permutation:

* ``"ref"``    — the pure-jnp gather oracle (:mod:`repro.kernels.ref`).
* ``"pallas"`` — the tiled Pallas pipeline (:mod:`repro.kernels`), with a
  twist: the per-stage kernel executable is cached by *tile geometry*
  (:func:`repro.kernels.bmmc_permute.plan_geometry`), and the per-stage
  index tables are passed as runtime arguments. A fused program with many
  distinct BMMCs but few distinct geometries therefore pays the pallas
  trace/lower cost only once per geometry, not once per stage.

Any callable ``(x, bmmc) -> x`` is also accepted wherever an engine name
is, so tests can inject instrumented engines.

``compile_expr(expr)`` is the user entry point: lowering + fusion happen
once per ``(expr, n)``; kernel plans once per ``(bmmc, t)``; kernel
executables once per geometry. The returned function is jax-traceable
(it can be wrapped in ``jax.jit``), and cheap to call as-is.

Autodiff (DESIGN.md §9): every ``Perm`` stage executes through
:func:`perm_apply`, a ``jax.custom_vjp`` primitive whose backward pass
applies the *offline-inverted* BMMC (``Bmmc.inverse``) through the same
engine. A BMMC permutation is orthogonal — its Jacobian transpose is the
inverse permutation — so no residuals are saved and cotangents ride the
same geometry-cached tiled kernels as the forward pass (the backward
pass of a composed program runs the inverted stages in reversed order,
exactly :func:`repro.combinators.optimize.inverse_program`). Pallas DMA
kernels have no JVP/transpose rules of their own; this rule is what
makes ``jax.grad`` flow through the "pallas" engine at all.

Batching: ``run_program`` / ``CompiledExpr.__call__`` take
``batched=True`` to accept a leading batch axis — ``(B, 2^n)`` or
``(B, 2^n, d)`` — folded into the kernel grid with the tile plan shared
across the batch. Injected engines that don't understand ``batched``
are transparently wrapped with ``jax.vmap`` (the vmap fallback).

Fused stages (DESIGN.md §10): on the "pallas" engine the compiled
program is additionally run through :func:`repro.combinators.optimize.
cluster`, which groups ``Perm → compute → Perm`` runs into
:class:`~repro.combinators.optimize.FusedStage`\\ s. A FusedStage
dispatches to the double-buffered megakernel — one HBM round trip for
the whole run, with the interior ``CmpHalves``/``Bfly``/``Map`` stages
applied to each tile in VMEM. Every other engine (the "ref" oracle,
injected engines) executes the cluster's original stages one at a time,
as does the megakernel's backward pass: :func:`fused_apply` is a
``custom_vjp`` primitive that saves only the input and replays the
per-stage program under ``jax.vjp`` — ``Perm`` cotangents still ride
the offline-inverted tiled kernels, compute cotangents the plain jnp
rules. Clusters whose layout the kernel cannot take (complex dtype,
non-planar butterflies, arrays too small to tile) transparently fall
back to stage-at-a-time execution.
"""
from __future__ import annotations

import collections
import functools
import inspect
import time
import weakref
from typing import Callable, Dict, Optional, Sequence, Union

import jax
import jax.numpy as jnp
import numpy as np

from ..core.bmmc import Bmmc
from ..obs import metrics as _ometrics
from ..obs import trace as _otrace
from ..core.tiling import compute_tables, plan_bmmc, plan_general
from ..kernels import ref as _ref
from ..kernels.bmmc_permute import (block_geometry, block_permute_tables,
                                    lane_geometry, lane_permute_tables,
                                    plan_geometry, tiled_permute_tables)
from .ir import Bfly, CmpHalves, Expr, Map, Perm
from .optimize import (COMPUTES, Program, FusedStage, cluster, fold_free,
                       lower, fuse, inverse_program)

EngineFn = Callable[[jax.Array, Bmmc], jax.Array]

_ENGINES: Dict[str, EngineFn] = {}


def register_engine(name: str, fn: EngineFn) -> None:
    _ENGINES[name] = fn


def get_engine(engine: Union[str, EngineFn, None]) -> EngineFn:
    if engine is None:
        return _ENGINES["ref"]
    if callable(engine):
        return engine
    try:
        return _ENGINES[engine]
    except KeyError:
        raise KeyError(f"unknown engine {engine!r}; registered engines: "
                       f"{sorted(_ENGINES)}") from None


def engines() -> tuple:
    return tuple(sorted(_ENGINES))


# ---------------------------------------------------------------------------
# The "pallas" engine: geometry-cached kernel executables.
# ---------------------------------------------------------------------------

@functools.lru_cache(maxsize=512)
def _geom_executable(geometry: tuple, interpret: bool, batched: bool = False,
                     epilogue: tuple = (), map_fns: tuple = ()):
    """One jitted tiled-pass executable per (tile geometry, epilogue
    signature). Index/epilogue tables are arguments, so every stage
    sharing this key reuses the trace. The cache key is independent of
    the batch size: growing B re-specializes the jit trace but never
    adds a geometry entry."""
    return jax.jit(functools.partial(
        tiled_permute_tables, geometry=geometry, interpret=interpret,
        batched=batched, epilogue=epilogue, map_fns=map_fns))


@functools.lru_cache(maxsize=256)
def _block_executable(geometry: tuple, interpret: bool,
                      batched: bool = False):
    """One jitted block-permute (grid-remapped DMA copy) executable per
    geometry; the source-row table is a runtime argument."""
    return jax.jit(functools.partial(
        block_permute_tables, geometry=geometry, interpret=interpret,
        batched=batched))


@functools.lru_cache(maxsize=256)
def _lane_executable(geometry: tuple, interpret: bool,
                     batched: bool = False):
    """One jitted lane-permute (in-VMEM row gather) executable per
    geometry; the lane table is a runtime argument."""
    return jax.jit(functools.partial(
        lane_permute_tables, geometry=geometry, interpret=interpret,
        batched=batched))


def _pallas_engine(x: jax.Array, bmmc: Bmmc, *, t: Optional[int] = None,
                   interpret: bool = True, batched: bool = False) -> jax.Array:
    from ..kernels import ops

    if bmmc.is_identity_perm():
        _ometrics.inc("dispatch.kernel", kernel="none")
        return x
    if jnp.iscomplexobj(x):
        # pallas TPU has no complex dtype; a permutation is dtype-agnostic,
        # so complex arrays ride the gather oracle (planar (re, im) float
        # layouts take the tiled kernels)
        return _ref.bmmc_ref(x, bmmc, batched=batched)
    got = ops.class_dispatch(x, bmmc, t, batched)
    if got is None:  # too small to tile; whole array fits anywhere
        return _ref.bmmc_ref(x, bmmc, batched=batched)
    kernel, payload = got
    if kernel == "none":
        return x
    if kernel == "block":
        run = _block_executable(block_geometry(payload), interpret, batched)
        return run(x, payload.src_rows)
    if kernel == "lane":
        run = _lane_executable(lane_geometry(payload), interpret, batched)
        return run(x, payload.src_lane)
    for plan in payload:
        run = _geom_executable(plan_geometry(plan), interpret, batched)
        x = run(x, plan.in_rows, plan.out_rows, plan.xor_low, plan.src0)
    return x


register_engine("ref", _ref.bmmc_ref)
register_engine("pallas", _pallas_engine)


# ---------------------------------------------------------------------------
# Fused-stage execution: the megakernel dispatch path (DESIGN.md §10)
# ---------------------------------------------------------------------------

def _fused_entries(plans, computes):
    entries = []
    for comp, prefix in computes:
        if isinstance(comp, Map):
            entries.append(("map", comp))
            continue
        kind = "cmp" if isinstance(comp, CmpHalves) else "bfly"
        ct = compute_tables(plans[0], prefix, kind)
        if ct is None:
            return None
        entries.append((kind, comp, ct))
    return tuple(entries)


@functools.lru_cache(maxsize=256)
def _fused_plan_cached(fs: FusedStage, t: int):
    """(pass plans, per-compute ComputeTables-or-Map entries) for a
    cluster, or None when the megakernel cannot run it at this tile
    parameter (no pass plannable, or a compute not tile-local in the
    first pass — possible when the runtime ``t`` differs from the
    clustering ``t``). The composed BMMC runs as ONE tiled pass (classic
    witness columns or generalized witness directions), falling back to
    the §5.2 two-pass factorization only for t > n/2; computes always
    ride the FIRST pass's tiles (they are pulled back to input space,
    where pass 1 reads).

    A classic plan's tile span can be narrower than the maximal
    ``ker(A[t:, :])`` span the clustering validated against; when a
    compute's pairing vector needs the extra room, the first pass is
    re-planned with :func:`repro.core.tiling.plan_general`, whose span
    IS the maximum."""
    try:
        plans = list(plan_bmmc(fs.bmmc, t))
    except ValueError:
        return None
    entries = _fused_entries(plans, fs.computes)
    if entries is None and plans[0].row_cols:
        general = plan_general(plans[0].bmmc, t)
        if general is not None:
            plans[0] = general
            entries = _fused_entries(plans, fs.computes)
    if entries is None:
        return None
    return tuple(plans), tuple(entries)


@functools.lru_cache(maxsize=64)
def _w_planar_cached(twiddles: tuple, dtype: str) -> np.ndarray:
    """The (2^(n-1), 2) resident (re, im) twiddle-value table."""
    return np.stack([np.asarray([w.real for w in twiddles], dtype=dtype),
                     np.asarray([w.imag for w in twiddles], dtype=dtype)],
                    axis=-1)


def _fused_tile(x: jax.Array, fs: FusedStage, batched: bool) -> Optional[int]:
    """The tile parameter the megakernel would use on ``x``, or None when
    the fused fast path cannot take this input (falls back per-stage)."""
    from ..kernels import ops

    lead = 1 if batched else 0
    if x.ndim not in (1 + lead, 2 + lead) or jnp.iscomplexobj(x):
        return None
    d = x.shape[1 + lead] if x.ndim == 2 + lead else 1
    if any(isinstance(c, Bfly) for c, _ in fs.computes):
        if x.ndim != 2 + lead or d != 2:
            return None  # butterflies need the planar (re, im) layout
    t = ops.choose_tile(fs.bmmc.n, x.dtype.itemsize, d)
    if t is None or _fused_plan_cached(fs, t) is None:
        return None
    return t


def _fused_pallas(x: jax.Array, fs: FusedStage, t: int, *,
                  interpret: bool = True, batched: bool = False) -> jax.Array:
    """Run one cluster as a double-buffered megakernel dispatch: the
    first tiled pass carries every fused compute as an in-VMEM epilogue;
    a second plain pass (general BMMCs only, §5.2) finishes the
    permutation."""
    plans, entries = _fused_plan_cached(fs, t)
    plan = plans[0]
    sig, scal, vmem, map_fns = [], [], [], []
    for e in entries:
        if e[0] == "map":
            sig.append(("map", e[1].name))
            map_fns.append(e[1].fn)
            scal.append(())
            vmem.append(())
            continue
        kind, comp, ct = e
        if kind == "cmp":
            sig.append(("cmp", ct.vr, ct.vc))
            scal.append((ct.hi_base,))
            vmem.append((ct.hi_row, ct.hi_lane))
        else:
            w = _w_planar_cached(comp.twiddles, np.dtype(x.dtype).name)
            sig.append(("bfly", ct.vr, ct.vc, len(comp.twiddles)))
            scal.append((ct.hi_base, ct.tw_base))
            vmem.append((ct.hi_row, ct.hi_lane, ct.tw_row, ct.tw_lane, w))
    run = _geom_executable(plan_geometry(plan), interpret, batched,
                           tuple(sig), tuple(map_fns))
    x = run(x, plan.in_rows, plan.out_rows, plan.xor_low, plan.src0,
            epi_scalar=tuple(scal), epi_vmem=tuple(vmem))
    for plan in plans[1:]:
        run = _geom_executable(plan_geometry(plan), interpret, batched)
        x = run(x, plan.in_rows, plan.out_rows, plan.xor_low, plan.src0)
    return x


def _fused_forward(x, fs, engine, batched):
    if engine == "pallas":
        t = _fused_tile(x, fs, batched)
        if t is not None:
            if _otrace._state.enabled:
                plans, _ = _fused_plan_cached(fs, t)
                _ometrics.inc("dispatch.kernel", kernel="fused")
                _ometrics.inc("model.round_trips", len(plans))
                _ometrics.inc("dma.descriptors",
                              sum(p.dma_descriptors() for p in plans))
                with _otrace.span("kernel.fused", stages=len(fs.stages),
                                  passes=len(plans), t=t):
                    return _fused_pallas(x, fs, t, batched=batched)
            return _fused_pallas(x, fs, t, batched=batched)
    if engine == "pallas":
        # cluster validated at plan time but re-rejected for this input
        # (dtype/shape/tile mismatch): the honest count the model lacks
        _ometrics.inc("dispatch.fused_fallback")
    return run_program(fs.stages, x, engine, batched=batched)


@functools.partial(jax.custom_vjp, nondiff_argnums=(1, 2, 3))
def fused_apply(x: jax.Array, fs: FusedStage,
                engine: Union[str, EngineFn, None] = None,
                batched: bool = False) -> jax.Array:
    """Differentiable fused-cluster execution.

    Forward: ONE megakernel pass on the "pallas" engine (per-stage
    otherwise). Backward: the per-stage program is replayed under
    ``jax.vjp`` from the saved input — ``Perm`` stages keep their
    offline-inverted custom VJP (cotangents ride the tiled kernels, and
    for a permutation-only cluster that is exactly the inverse cluster),
    compute stages their native jnp rules.
    """
    return _fused_forward(x, fs, engine, batched)


def _fused_fwd(x, fs, engine, batched):
    return _fused_forward(x, fs, engine, batched), x


def _fused_bwd(fs, engine, batched, x, ct):
    _, vjp = jax.vjp(
        lambda v: run_program(fs.stages, v, engine, batched=batched), x)
    return vjp(ct)


fused_apply.defvjp(_fused_fwd, _fused_bwd)


# ---------------------------------------------------------------------------
# perm_apply — the differentiable permutation primitive
# ---------------------------------------------------------------------------

_BATCHED_SIG = weakref.WeakKeyDictionary()  # doesn't pin injected engines


def _accepts_batched(fn: Callable) -> bool:
    # only an explicit ``batched`` parameter proves support — a bare
    # ``**kwargs`` would swallow the flag and permute the wrong axis
    try:
        return _BATCHED_SIG[fn]
    except (KeyError, TypeError):
        pass
    try:
        got = "batched" in inspect.signature(fn).parameters
    except (TypeError, ValueError):  # builtins, odd callables
        got = False
    try:
        _BATCHED_SIG[fn] = got
    except TypeError:  # not weakref-able; just re-probe next time
        pass
    return got


def _call_engine(fn: EngineFn, x: jax.Array, bmmc: Bmmc,
                 batched: bool) -> jax.Array:
    """Invoke an engine, vmapping over the batch axis if it only speaks the
    unbatched ``(x, bmmc) -> x`` protocol."""
    if not batched:
        return fn(x, bmmc)
    if _accepts_batched(fn):
        return fn(x, bmmc, batched=True)
    return jax.vmap(lambda xb: fn(xb, bmmc))(x)


@functools.partial(jax.custom_vjp, nondiff_argnums=(1, 2, 3))
def perm_apply(x: jax.Array, bmmc: Bmmc,
               engine: Union[str, EngineFn, None] = None,
               batched: bool = False) -> jax.Array:
    """Differentiable BMMC permutation through any engine.

    The VJP applies ``bmmc.inverse()`` — precomputed offline over F2 —
    through the *same* engine: the cotangent of a pallas-permuted array is
    itself a pallas permutation (no gather transpose is materialized, and
    backward passes share the forward geometry cache).
    """
    return _call_engine(get_engine(engine), x, bmmc, batched)


def _perm_apply_fwd(x, bmmc, engine, batched):
    return perm_apply(x, bmmc, engine, batched), None


def _perm_apply_bwd(bmmc, engine, batched, _res, ct):
    return (perm_apply(ct, bmmc.inverse(), engine, batched),)


perm_apply.defvjp(_perm_apply_fwd, _perm_apply_bwd)


# ---------------------------------------------------------------------------
# Program execution
# ---------------------------------------------------------------------------

def _apply_bfly(x: jax.Array, twiddles: tuple, axis: int = 0) -> jax.Array:
    """(lo, hi) -> (lo + w·hi, lo - w·hi) along ``axis``. Complex arrays, or
    float arrays with a trailing dim of 2 holding (re, im) channels."""
    h = x.shape[axis] // 2
    lo = jax.lax.slice_in_dim(x, 0, h, axis=axis)
    hi = jax.lax.slice_in_dim(x, h, 2 * h, axis=axis)
    if jnp.iscomplexobj(x):
        w = jnp.asarray(np.asarray(twiddles, dtype=np.complex64))
        w = w.reshape((1,) * axis + (h,) + (1,) * (x.ndim - axis - 1))
        t = w * hi
        return jnp.concatenate([lo + t, lo - t], axis=axis)
    if x.ndim != axis + 2 or x.shape[-1] != 2:
        raise ValueError("real-typed Bfly input must have a trailing "
                         "(re, im) dim of 2")
    wshape = (1,) * axis + (h,)
    wr = jnp.asarray(np.asarray([w.real for w in twiddles],
                                dtype=x.dtype)).reshape(wshape)
    wi = jnp.asarray(np.asarray([w.imag for w in twiddles],
                                dtype=x.dtype)).reshape(wshape)
    tre = wr * hi[..., 0] - wi * hi[..., 1]
    tim = wr * hi[..., 1] + wi * hi[..., 0]
    t = jnp.stack([tre, tim], axis=-1)
    return jnp.concatenate([lo + t, lo - t], axis=axis)


def _exec_stage(s: Expr, x: jax.Array, engine, batched: bool,
                axis: int) -> jax.Array:
    """Dispatch ONE primitive/fused stage (the run_program loop body)."""
    if isinstance(s, Perm):
        return perm_apply(x, s.bmmc, engine, batched)
    if isinstance(s, FusedStage):
        return fused_apply(x, s, engine, batched)
    if isinstance(s, CmpHalves):
        h = x.shape[axis] // 2
        lo = jax.lax.slice_in_dim(x, 0, h, axis=axis)
        hi = jax.lax.slice_in_dim(x, h, 2 * h, axis=axis)
        return jnp.concatenate([jnp.minimum(lo, hi), jnp.maximum(lo, hi)],
                               axis=axis)
    if isinstance(s, Bfly):
        return _apply_bfly(x, s.twiddles, axis)
    if isinstance(s, Map):
        return s.fn(x)
    raise TypeError(f"non-primitive stage {type(s).__name__}; "
                    "lower() the expression first")


def run_program(program: Sequence[Expr], x: jax.Array,
                engine: Union[str, EngineFn, None] = None,
                *, batched: bool = False) -> jax.Array:
    """Execute a lowered (primitive-only) stage program.

    Differentiable: ``Perm`` stages go through :func:`perm_apply` (offline
    -inverted backward pass), the rest are plain jnp. ``batched=True``
    moves the permuted axis to axis 1, with a leading batch dim.

    When telemetry is enabled each stage records a ``stage.*`` span and
    standalone computes count as ``sweep`` kernel dispatches (matching
    :func:`repro.combinators.optimize.program_cost`); the check is one
    module attribute, so the disabled path is the plain loop below.
    """
    get_engine(engine)  # validate the name up front, even for Perm-free
    axis = 1 if batched else 0
    if not _otrace._state.enabled:
        for s in program:
            x = _exec_stage(s, x, engine, batched, axis)
        return x
    for s in program:
        kind = type(s).__name__.lower()
        with _otrace.span("stage." + kind):
            x = _exec_stage(s, x, engine, batched, axis)
        if isinstance(s, COMPUTES):
            # a standalone compute pays one full elementwise HBM sweep —
            # the same unit program_cost charges it
            _ometrics.inc("dispatch.kernel", kernel="sweep")
            _ometrics.inc("model.round_trips", 1)
    return x


# ---------------------------------------------------------------------------
# compile_expr — the compiled-plan cache
# ---------------------------------------------------------------------------

@functools.lru_cache(maxsize=1024)
def _lowered_cached(expr: Expr, n: int, optimized: bool) -> Program:
    prog = lower(expr, n)
    return fuse(prog) if optimized else prog


@functools.lru_cache(maxsize=1024)
def _clustered_cached(expr: Expr, n: int, optimized: bool,
                      t: int) -> tuple:
    prog = cluster(_lowered_cached(expr, n, optimized), n, t)
    return fold_free(prog, n, t)


# ---------------------------------------------------------------------------
# Whole-program executables: ONE jitted callable per (program, engine,
# batched) key. All per-stage Python work — plan-cache lookups, table ->
# device conversion, DMA descriptor enumeration, kernel re-dispatch —
# happens once at trace time; the offline tables are baked into the
# jaxpr as constants. Repeated calls pay a single XLA dispatch instead
# of one Python round per stage (the host-side overhead that dominates
# multi-stage programs: the 2^12 sort re-dispatched 79 fused stages per
# call before this cache). The key is independent of batch size, dtype
# and trailing dims — jax.jit re-specializes on those internally without
# growing this cache.
# ---------------------------------------------------------------------------


def _has_map(prog: Program) -> bool:
    """Does the program carry a user ``Map`` callable (top-level or
    inside a cluster's replay stages)?"""
    return any(isinstance(s, Map)
               or (isinstance(s, FusedStage)
                   and any(isinstance(ss, Map) for ss in s.stages))
               for s in prog)


@functools.lru_cache(maxsize=512)
def _program_executable(prog: Program, engine: str, batched: bool):
    def run(x):
        return run_program(prog, x, engine, batched=batched)
    return jax.jit(run)


@functools.lru_cache(maxsize=512)
def _program_round_trips(prog: Program, t: Optional[int]) -> Optional[int]:
    """Modeled HBM round trips of a resolved program — the per-call
    model-vs-measured accounting unit (telemetry only)."""
    if t is None:
        return None
    from .optimize import program_cost
    return program_cost(prog, t)["round_trips"]


CacheStats = collections.namedtuple(
    "CacheStats", ["hits", "misses", "maxsize", "currsize"])


def cache_stats() -> Dict[str, CacheStats]:
    """Aggregate stats for EVERY executor/ops cache, by name.

    Covers the kernel-executable caches (``geom`` / ``block`` / ``lane``
    / ``program``), the plan/table caches (``fused_plan`` / ``w_planar``
    / ``lowered`` / ``clustered`` / ``model_round_trips`` and the ops
    ``plans`` / ``class_plan``), and the ``compiled_exprs`` memo.
    Replaces the old single-cache ``geom_cache_info`` /
    ``program_cache_info`` pair, which made every other cache invisible.
    """
    from ..kernels import ops

    out = {
        "geom": _geom_executable,
        "block": _block_executable,
        "lane": _lane_executable,
        "program": _program_executable,
        "fused_plan": _fused_plan_cached,
        "w_planar": _w_planar_cached,
        "lowered": _lowered_cached,
        "clustered": _clustered_cached,
        "model_round_trips": _program_round_trips,
        "plans": ops._plans_cached,
        "class_plan": ops._class_plan_cached,
    }
    stats = {name: CacheStats(*fn.cache_info()) for name, fn in out.items()}
    stats["compiled_exprs"] = CacheStats(
        hits=_compiled_stats["hits"], misses=_compiled_stats["misses"],
        maxsize=None, currsize=len(_COMPILED))
    return stats


class CompiledExpr:
    """A callable compiled combinator expression — a first-class JAX value.

    Calling it executes the (fused) stage program through the chosen
    engine; the result is jit-able, ``jax.grad``-able (``Perm`` stages
    carry the offline-inverted custom VJP) and batchable via
    ``batched=True`` (leading batch dim sharing one tile plan).
    ``program(n)`` exposes the stage program for inspection; ``cost(n,
    t)`` the modeled transaction report; ``vjp_program(n)`` the exact
    program the backward pass of a permutation-only expression executes.
    """

    def __init__(self, expr: Expr, engine: Union[str, EngineFn],
                 optimized: bool):
        self.expr = expr
        self.engine = engine
        self.optimized = optimized

    def program(self, n: int) -> Program:
        return _lowered_cached(self.expr, n, self.optimized)

    def clustered_program(self, n: int, t: int) -> tuple:
        """The program with ``Perm → compute → Perm`` runs grouped into
        megakernel :class:`FusedStage`\\ s for tile parameter ``t`` —
        what the "pallas" engine actually executes."""
        return _clustered_cached(self.expr, n, self.optimized, t)

    def cost(self, n: int, t: int, itemsize: int = 4, *,
             clustered: bool = False) -> dict:
        from .optimize import program_cost
        prog = (self.clustered_program(n, t) if clustered
                else self.program(n))
        return program_cost(prog, t, itemsize)

    def is_permutation(self, n: int) -> bool:
        """True if the program is pure ``Perm`` stages (hence invertible)."""
        return all(isinstance(s, Perm) for s in self.program(n))

    def vjp_program(self, n: int) -> Program:
        """The offline-inverted program (reversed stages, each BMMC
        inverted) — what the cotangent flows through. Permutation-only."""
        return inverse_program(self.program(n))

    def inverse(self, n: int) -> "CompiledExpr":
        """The compiled inverse of a permutation-only expression."""
        from .ir import seq
        inv = seq(*self.vjp_program(n))
        return compile_expr(inv, engine=self.engine, optimize=self.optimized)

    def _resolve(self, x: jax.Array, batched: bool) -> tuple:
        """(program, tile parameter) the executor will run on ``x``."""
        axis = 1 if batched else 0
        if x.ndim <= axis:
            what = ("a leading batch dim plus the permuted axis" if batched
                    else "a permutable leading axis")
            raise ValueError(f"input needs {what}, got shape {x.shape}")
        n = int(x.shape[axis]).bit_length() - 1
        if (1 << n) != x.shape[axis]:
            raise ValueError(
                f"array length {x.shape[axis]} is not a power of 2")
        from ..kernels.ops import choose_tile
        d = x.shape[axis + 1] if x.ndim == axis + 2 else 1
        t = choose_tile(n, x.dtype.itemsize, d)
        prog = self.program(n)
        if self.engine == "pallas" and self.optimized and t is not None:
            # megakernel clustering + free-stage folding; the ref oracle
            # and injected engines stay stage-at-a-time
            prog = self.clustered_program(n, t)
        return prog, t

    def _resolve_program(self, x: jax.Array, batched: bool) -> Program:
        return self._resolve(x, batched)[0]

    def __call__(self, x: jax.Array, *, batched: bool = False) -> jax.Array:
        prog, t = self._resolve(x, batched)
        use_exec = isinstance(self.engine, str) and not _has_map(prog)
        # Programs carrying user Map callables stay on the eager
        # per-stage path: Map's contract says "a jax function", but
        # eager execution historically tolerated trace-unsafe fns
        # (concrete-value branching, numpy round trips) and wrapping
        # them in jit would turn that tolerance into a crash.
        if not _otrace._state.enabled:
            if use_exec:
                # whole-program compiled executable: one XLA dispatch per
                # call, per-stage Python enumeration only at trace time
                return _program_executable(prog, self.engine, batched)(x)
            return run_program(prog, x, self.engine, batched=batched)
        return self._call_observed(prog, t, x, batched, use_exec)

    def _call_observed(self, prog: Program, t: Optional[int], x: jax.Array,
                       batched: bool, use_exec: bool) -> jax.Array:
        """The telemetry-enabled call path: one ``program.call`` span +
        latency histogram per invocation, warm/cold labeled by whether a
        fresh jit trace ran, and the modeled round trips accumulated so
        ``obs.model_vs_measured()`` can hold the transaction model
        against the wall clock. Blocks on the result only when
        ``obs.enable(sync=True)`` asked for end-to-end timings."""
        eng = self.engine if isinstance(self.engine, str) else "injected"
        with _otrace.span("program.call", engine=eng, stages=len(prog),
                          path="executable" if use_exec else "per-stage",
                          batched=batched) as sargs:
            t0 = time.perf_counter_ns()
            if use_exec:
                misses0 = _program_executable.cache_info().misses
                out = _program_executable(prog, self.engine, batched)(x)
                cold = _program_executable.cache_info().misses > misses0
            else:
                out = run_program(prog, x, self.engine, batched=batched)
                cold = False
            if _otrace._state.sync:
                jax.block_until_ready(out)
            dur_us = (time.perf_counter_ns() - t0) / 1e3
            rt = _program_round_trips(prog, t)
            sargs["dur_us"] = round(dur_us, 1)
            sargs["cache"] = "cold" if cold else "warm"
            if rt is not None:
                sargs["model_round_trips"] = rt
        _ometrics.observe("program.call_us", dur_us, engine=eng,
                          cache="cold" if cold else "warm")
        if rt is not None:
            _ometrics.inc("program.model_round_trips", rt)
            if not cold:
                _ometrics.observe("program.us_per_round_trip",
                                  dur_us / max(rt, 1), engine=eng)
        return out

    def call_per_stage(self, x: jax.Array, *,
                       batched: bool = False) -> jax.Array:
        """Execute stage-at-a-time through the Python dispatcher —
        the pre-executable path, kept for the host-side dispatch-
        overhead microbenchmark and as a debugging aid."""
        prog = self._resolve_program(x, batched)
        return run_program(prog, x, self.engine, batched=batched)


_COMPILED: Dict[tuple, CompiledExpr] = {}
_compiled_stats = {"hits": 0, "misses": 0}


def clear_caches() -> None:
    """Drop every compiled artifact the executor pins, and reset the
    telemetry counters/spans with them (cache hygiene: hit/miss counts
    and dispatch counters describe the caches being dropped).

    The geometry / block / lane / whole-program executable caches hold
    jitted pallas executables (each pinning a traced kernel),
    ``_COMPILED`` grows one entry per ``(expr, engine, optimize)``
    triple, and the plan/table caches hold offline numpy tables — none
    of which is bounded across a long geometry sweep. Test fixtures that
    iterate many sizes/dtypes call this between sweeps to keep memory
    flat.
    """
    from ..kernels import ops
    from .. import obs

    _geom_executable.cache_clear()
    _block_executable.cache_clear()
    _lane_executable.cache_clear()
    _program_executable.cache_clear()
    _fused_plan_cached.cache_clear()
    _w_planar_cached.cache_clear()
    _lowered_cached.cache_clear()
    _clustered_cached.cache_clear()
    _program_round_trips.cache_clear()
    _COMPILED.clear()
    _compiled_stats["hits"] = _compiled_stats["misses"] = 0
    ops._plans_cached.cache_clear()
    ops._class_plan_cached.cache_clear()
    obs.reset()


def compile_expr(expr: Expr, *, engine: Union[str, EngineFn] = "pallas",
                 optimize: bool = True) -> CompiledExpr:
    """Compile ``expr`` to a jit-able function running minimal tiled passes.

    Lowered/fused programs, kernel plans, and kernel executables are all
    cached, so repeated calls (and repeated ``compile_expr`` of the same
    expression) share everything expensive.
    """
    key = (expr, engine if isinstance(engine, str) else id(engine), optimize)
    got = _COMPILED.get(key)
    if got is None:
        _compiled_stats["misses"] += 1
        got = _COMPILED[key] = CompiledExpr(expr, engine, optimize)
    else:
        _compiled_stats["hits"] += 1
    return got
