"""Multi-engine executor with a compiled-plan cache.

Engines map a ``Perm`` stage to an actual array permutation:

* ``"ref"``    — the pure-jnp gather oracle (:mod:`repro.kernels.ref`).
* ``"pallas"`` — the tiled Pallas pipeline (:mod:`repro.kernels`), with a
  twist: the per-stage kernel executable is cached by *tile geometry*
  (:func:`repro.kernels.bmmc_permute.plan_geometry`), and the per-stage
  index tables are passed as runtime arguments. A fused program with many
  distinct BMMCs but few distinct geometries therefore pays the pallas
  trace/lower cost only once per geometry, not once per stage.

Any callable ``(x, bmmc) -> x`` is also accepted wherever an engine name
is, so tests can inject instrumented engines.

``compile_expr(expr)`` is the user entry point: lowering + fusion happen
once per ``(expr, n)``; kernel plans once per ``(bmmc, t)``; kernel
executables once per geometry. The returned function is jax-traceable
(it can be wrapped in ``jax.jit``), and cheap to call as-is.
"""
from __future__ import annotations

import functools
from typing import Callable, Dict, Optional, Sequence, Union

import jax
import jax.numpy as jnp
import numpy as np

from ..core.bmmc import Bmmc
from ..kernels import ref as _ref
from ..kernels.bmmc_permute import plan_geometry, tiled_permute_tables
from .ir import Bfly, CmpHalves, Expr, Map, Perm
from .optimize import Program, lower, fuse

EngineFn = Callable[[jax.Array, Bmmc], jax.Array]

_ENGINES: Dict[str, EngineFn] = {}


def register_engine(name: str, fn: EngineFn) -> None:
    _ENGINES[name] = fn


def get_engine(engine: Union[str, EngineFn, None]) -> EngineFn:
    if engine is None:
        return _ENGINES["ref"]
    if callable(engine):
        return engine
    try:
        return _ENGINES[engine]
    except KeyError:
        raise KeyError(f"unknown engine {engine!r}; registered engines: "
                       f"{sorted(_ENGINES)}") from None


def engines() -> tuple:
    return tuple(sorted(_ENGINES))


# ---------------------------------------------------------------------------
# The "pallas" engine: geometry-cached kernel executables.
# ---------------------------------------------------------------------------

@functools.lru_cache(maxsize=512)
def _geom_executable(geometry: tuple, interpret: bool):
    """One jitted tiled-pass executable per tile geometry. Index tables are
    arguments, so every stage sharing this geometry reuses the trace."""
    return jax.jit(functools.partial(
        tiled_permute_tables, geometry=geometry, interpret=interpret))


def _pallas_engine(x: jax.Array, bmmc: Bmmc, *, t: Optional[int] = None,
                   interpret: bool = True) -> jax.Array:
    from ..kernels import ops

    if bmmc.is_identity_perm():
        return x
    d = x.shape[1] if x.ndim == 2 else 1
    teff = ops.choose_tile(bmmc.n, x.dtype.itemsize, d, t)
    if teff is None:  # too small to tile; whole array fits anywhere
        return _ref.bmmc_ref(x, bmmc)
    for plan in ops.bmmc_plans(bmmc, teff):
        run = _geom_executable(plan_geometry(plan), interpret)
        x = run(x, plan.in_rows, plan.out_rows, plan.xor_low, plan.src0)
    return x


register_engine("ref", _ref.bmmc_ref)
register_engine("pallas", _pallas_engine)


# ---------------------------------------------------------------------------
# Program execution
# ---------------------------------------------------------------------------

def _apply_bfly(x: jax.Array, twiddles: tuple) -> jax.Array:
    """(lo, hi) -> (lo + w·hi, lo - w·hi). Complex arrays, or float arrays
    with a trailing dim of 2 holding (re, im) channels."""
    h = x.shape[0] // 2
    lo, hi = x[:h], x[h:]
    if jnp.iscomplexobj(x):
        w = jnp.asarray(np.asarray(twiddles, dtype=np.complex64))
        if x.ndim > 1:
            w = w.reshape((h,) + (1,) * (x.ndim - 1))
        t = w * hi
        return jnp.concatenate([lo + t, lo - t], axis=0)
    if x.ndim != 2 or x.shape[1] != 2:
        raise ValueError("real-typed Bfly input must have shape (2^n, 2)")
    wr = jnp.asarray(np.asarray([w.real for w in twiddles], dtype=x.dtype))
    wi = jnp.asarray(np.asarray([w.imag for w in twiddles], dtype=x.dtype))
    tre = wr * hi[:, 0] - wi * hi[:, 1]
    tim = wr * hi[:, 1] + wi * hi[:, 0]
    t = jnp.stack([tre, tim], axis=1)
    return jnp.concatenate([lo + t, lo - t], axis=0)


def run_program(program: Sequence[Expr], x: jax.Array,
                engine: Union[str, EngineFn, None] = None) -> jax.Array:
    """Execute a lowered (primitive-only) stage program."""
    engine_fn = get_engine(engine)
    for s in program:
        if isinstance(s, Perm):
            x = engine_fn(x, s.bmmc)
        elif isinstance(s, CmpHalves):
            h = x.shape[0] // 2
            lo, hi = x[:h], x[h:]
            x = jnp.concatenate([jnp.minimum(lo, hi), jnp.maximum(lo, hi)],
                                axis=0)
        elif isinstance(s, Bfly):
            x = _apply_bfly(x, s.twiddles)
        elif isinstance(s, Map):
            x = s.fn(x)
        else:
            raise TypeError(f"non-primitive stage {type(s).__name__}; "
                            "lower() the expression first")
    return x


# ---------------------------------------------------------------------------
# compile_expr — the compiled-plan cache
# ---------------------------------------------------------------------------

@functools.lru_cache(maxsize=1024)
def _lowered_cached(expr: Expr, n: int, optimized: bool) -> Program:
    prog = lower(expr, n)
    return fuse(prog) if optimized else prog


class CompiledExpr:
    """A callable compiled combinator expression.

    Calling it executes the (fused) stage program through the chosen
    engine. ``program(n)`` exposes the stage program for inspection;
    ``cost(n, t)`` the modeled transaction report.
    """

    def __init__(self, expr: Expr, engine: Union[str, EngineFn],
                 optimized: bool):
        self.expr = expr
        self.engine = engine
        self.optimized = optimized

    def program(self, n: int) -> Program:
        return _lowered_cached(self.expr, n, self.optimized)

    def cost(self, n: int, t: int, itemsize: int = 4) -> dict:
        from .optimize import program_cost
        return program_cost(self.program(n), t, itemsize)

    def __call__(self, x: jax.Array) -> jax.Array:
        n = int(x.shape[0]).bit_length() - 1
        if (1 << n) != x.shape[0]:
            raise ValueError(f"array length {x.shape[0]} is not a power of 2")
        return run_program(self.program(n), x, self.engine)


_COMPILED: Dict[tuple, CompiledExpr] = {}


def compile_expr(expr: Expr, *, engine: Union[str, EngineFn] = "pallas",
                 optimize: bool = True) -> CompiledExpr:
    """Compile ``expr`` to a jit-able function running minimal tiled passes.

    Lowered/fused programs, kernel plans, and kernel executables are all
    cached, so repeated calls (and repeated ``compile_expr`` of the same
    expression) share everything expensive.
    """
    key = (expr, engine if isinstance(engine, str) else id(engine), optimize)
    got = _COMPILED.get(key)
    if got is None:
        got = _COMPILED[key] = CompiledExpr(expr, engine, optimize)
    return got
