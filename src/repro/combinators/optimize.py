"""Lowering and the §7.2 rewrite algebra for combinator expressions.

``lower(expr, n)`` eliminates every structured node, producing a flat
tuple of primitive stages (``Perm`` / ``CmpHalves`` / ``Bfly`` / ``Map``):

* ``Seq``            — concatenation of the lowered parts.
* ``Two(f)``         — lower ``f`` on 2^(n-1) and *lift* each stage:
    - ``Perm(A)``    -> ``Perm(diag(A, 1))`` (block diagonal, top bit fixed),
    - ``Map``        -> unchanged (elementwise),
    - ``CmpHalves``  -> conjugated by the top-two-bit swap,
    - ``Bfly(w)``    -> conjugated by the swap, twiddles tiled (``w ++ w``).
* ``ParmE(mask, f)`` — paper §7.2: ``Perm(A_mask) ; lift(f) ; Perm(A_mask^-1)``
  with ``A_mask = parm_matrix`` (Fig. 13), i.e. ``parm`` reduces to ``two``
  conjugated by one BMMC on each side.
* ``Ilv(f)``         — sugar for ``ParmE(1, f)``.

``fuse(program)`` applies the rewrite algebra::

    bmmc B ∘ bmmc A          ->  bmmc (B A)          (fusion)
    bmmc A ∘ bmmc A^-1       ->  id                  (cancellation, via fusion)
    id                       ->  (dropped)

Fusion can only ever *merge or drop* ``Perm`` stages, so the optimized
program never has more permutation stages — and therefore never more
tiled kernel passes — than the raw lowering (tested property).
"""
from __future__ import annotations

from typing import Iterable, List, Sequence, Tuple

from ..core.bmmc import Bmmc
from ..core.parm import parm_matrix
from .ir import (Bfly, CmpHalves, Expr, Id, Ilv, Map, ParmE, Perm, Seq, Two,
                 PRIMITIVES)

Program = Tuple[Expr, ...]  # primitives only


def _lift(stages: Program, n: int) -> Program:
    """Lift a program on 2^(n-1) arrays to act on both halves of 2^n."""
    swap = Bmmc.from_perm([*range(n - 2), n - 1, n - 2]) if n >= 2 else None
    out: List[Expr] = []
    for s in stages:
        if isinstance(s, Perm):
            rows = tuple(s.bmmc.rows) + (1 << (n - 1),)
            out.append(Perm(Bmmc(rows, s.bmmc.c)))
        elif isinstance(s, Map):
            out.append(s)
        elif isinstance(s, CmpHalves):
            out.extend([Perm(swap), CmpHalves(), Perm(swap)])
        elif isinstance(s, Bfly):
            out.extend([Perm(swap), Bfly(s.twiddles + s.twiddles), Perm(swap)])
        else:  # pragma: no cover - lower() only emits primitives
            raise TypeError(f"cannot lift {type(s).__name__}")
    return tuple(out)


def lower(expr: Expr, n: int) -> Program:
    """Flatten ``expr`` (on arrays of 2^n) into primitive stages."""
    if isinstance(expr, Id):
        return ()
    if isinstance(expr, Seq):
        out: List[Expr] = []
        for f in expr.fs:
            out.extend(lower(f, n))
        return tuple(out)
    if isinstance(expr, Two):
        if n < 1:
            raise ValueError("Two needs n >= 1")
        return _lift(lower(expr.f, n - 1), n)
    if isinstance(expr, Ilv):
        return lower(ParmE(1, expr.f), n)
    if isinstance(expr, ParmE):
        if not expr.mask < (1 << n):
            raise ValueError(f"parm mask {expr.mask:#x} out of range for n={n}")
        a = parm_matrix(n, expr.mask)
        body = _lift(lower(expr.f, n - 1), n)
        return (Perm(a),) + body + (Perm(a.inverse()),)
    if isinstance(expr, Perm):
        if expr.bmmc.n != n:
            raise ValueError(f"Perm is on {expr.bmmc.n} bits, array has {n}")
        return (expr,)
    if isinstance(expr, Bfly):
        if expr.size_bits() != n:
            raise ValueError(f"Bfly is on {expr.size_bits()} bits, array has {n}")
        return (expr,)
    if isinstance(expr, PRIMITIVES):
        return (expr,)
    raise TypeError(f"unknown Expr node {type(expr).__name__}")


def fuse(program: Sequence[Expr]) -> Program:
    """Fuse adjacent ``Perm`` stages and drop identity permutations."""
    out: List[Expr] = []
    for s in program:
        if isinstance(s, Perm) and out and isinstance(out[-1], Perm):
            out[-1] = Perm(s.bmmc @ out[-1].bmmc)
        else:
            out.append(s)
    return tuple(s for s in out
                 if not (isinstance(s, Perm) and s.bmmc.is_identity_perm()))


def optimize(expr: Expr, n: int) -> Program:
    """Lower and fuse: the full offline pipeline."""
    return fuse(lower(expr, n))


def inverse_program(program: Sequence[Expr]) -> Program:
    """The exact inverse of a permutation-only program: stages reversed,
    each BMMC replaced by its offline F2 inverse.

    This is also the *VJP program* of the forward program — a BMMC
    permutation matrix is orthogonal over the reals, so its Jacobian
    transpose equals its inverse — which is what lets the executor's
    backward pass ride the same tiled kernels (DESIGN.md §9). Raises
    ``TypeError`` on non-``Perm`` stages (``CmpHalves`` is not
    invertible; ``Bfly``/``Map`` have state-dependent adjoints handled
    by jax autodiff instead).
    """
    out: List[Expr] = []
    for s in reversed(tuple(program)):
        if not isinstance(s, Perm):
            raise TypeError(
                f"inverse_program needs a permutation-only program; "
                f"found {type(s).__name__}")
        out.append(Perm(s.bmmc.inverse()))
    return tuple(out)


def num_perm_stages(program: Iterable[Expr]) -> int:
    return sum(isinstance(s, Perm) for s in program)


def program_cost(program: Sequence[Expr], t: int, itemsize: int = 4) -> dict:
    """Offline cost report: tiled passes + DMA descriptors (transaction model).

    ``t`` is the tile parameter of the executing kernel; each ``Perm``
    contributes 1 pass if tiled, else 2 (paper §5.2). Descriptor counts
    come from :func:`repro.kernels.ops.modeled_transactions`.
    """
    from ..kernels.ops import modeled_transactions

    perms = [s for s in program if isinstance(s, Perm)]
    passes = 0
    descriptors = 0
    bytes_moved = 0
    for s in perms:
        tx = modeled_transactions(s.bmmc, t, itemsize)
        passes += tx["passes"]
        descriptors += tx["descriptors"]
        bytes_moved += tx["bytes_moved"]
    return {
        "stages": len(tuple(program)),
        "perm_stages": len(perms),
        "tiled_passes": passes,
        "descriptors": descriptors,
        "bytes_moved": bytes_moved,
    }
