"""Lowering and the §7.2 rewrite algebra for combinator expressions.

``lower(expr, n)`` eliminates every structured node, producing a flat
tuple of primitive stages (``Perm`` / ``CmpHalves`` / ``Bfly`` / ``Map``):

* ``Seq``            — concatenation of the lowered parts.
* ``Two(f)``         — lower ``f`` on 2^(n-1) and *lift* each stage:
    - ``Perm(A)``    -> ``Perm(diag(A, 1))`` (block diagonal, top bit fixed),
    - ``Map``        -> unchanged (elementwise),
    - ``CmpHalves``  -> conjugated by the top-two-bit swap,
    - ``Bfly(w)``    -> conjugated by the swap, twiddles tiled (``w ++ w``).
* ``ParmE(mask, f)`` — paper §7.2: ``Perm(A_mask) ; lift(f) ; Perm(A_mask^-1)``
  with ``A_mask = parm_matrix`` (Fig. 13), i.e. ``parm`` reduces to ``two``
  conjugated by one BMMC on each side.
* ``Ilv(f)``         — sugar for ``ParmE(1, f)``.

``fuse(program)`` applies the rewrite algebra::

    bmmc B ∘ bmmc A          ->  bmmc (B A)          (fusion)
    bmmc A ∘ bmmc A^-1       ->  id                  (cancellation, via fusion)
    id                       ->  (dropped)

Fusion can only ever *merge or drop* ``Perm`` stages, so the optimized
program never has more permutation stages — and therefore never more
tiled kernel passes — than the raw lowering (tested property).

``cluster(program, n, t)`` goes one level deeper than ``fuse``: it groups
``Perm → compute → Perm → …`` runs into :class:`FusedStage` objects that
a single tiled megakernel pass can execute — the composed permutation is
applied by the pass's DMA + gather, and each interior compute
(``CmpHalves`` / ``Bfly`` / ``Map``) runs on the tile while it sits in
VMEM. A compute is *tile-local* (free to fuse) iff its pairing vector,
pulled back to input space through the perms preceding it in the run,
lies in the span of the composed plan's tile row/column bits — then both
elements of every compare/butterfly pair are resident in the same tile
and the compute costs zero extra HBM traffic (DESIGN.md §10).
"""
from __future__ import annotations

import dataclasses
from typing import Iterable, List, Optional, Sequence, Tuple

from ..core import f2
from ..core.bmmc import Bmmc
from ..core.parm import parm_matrix
from ..core.tiling import pairing_vector, pass_spans
from ..obs import metrics as _ometrics
from .ir import (Bfly, CmpHalves, Expr, Id, Ilv, Map, ParmE, Perm, Seq, Two,
                 PRIMITIVES)

Program = Tuple[Expr, ...]  # primitives only

COMPUTES = (CmpHalves, Bfly, Map)

# VMEM budget for a Bfly twiddle-value table held resident by the fused
# kernel ((2^(n-1), 2) float32); butterflies past this stay unfused.
_W_TABLE_BYTES = 1 * 1024 * 1024


def _lift(stages: Program, n: int) -> Program:
    """Lift a program on 2^(n-1) arrays to act on both halves of 2^n."""
    swap = Bmmc.from_perm([*range(n - 2), n - 1, n - 2]) if n >= 2 else None
    out: List[Expr] = []
    for s in stages:
        if isinstance(s, Perm):
            rows = tuple(s.bmmc.rows) + (1 << (n - 1),)
            out.append(Perm(Bmmc(rows, s.bmmc.c)))
        elif isinstance(s, Map):
            out.append(s)
        elif isinstance(s, CmpHalves):
            out.extend([Perm(swap), CmpHalves(), Perm(swap)])
        elif isinstance(s, Bfly):
            out.extend([Perm(swap), Bfly(s.twiddles + s.twiddles), Perm(swap)])
        else:  # pragma: no cover - lower() only emits primitives
            raise TypeError(f"cannot lift {type(s).__name__}")
    return tuple(out)


def lower(expr: Expr, n: int) -> Program:
    """Flatten ``expr`` (on arrays of 2^n) into primitive stages."""
    if isinstance(expr, Id):
        return ()
    if isinstance(expr, Seq):
        out: List[Expr] = []
        for f in expr.fs:
            out.extend(lower(f, n))
        return tuple(out)
    if isinstance(expr, Two):
        if n < 1:
            raise ValueError("Two needs n >= 1")
        return _lift(lower(expr.f, n - 1), n)
    if isinstance(expr, Ilv):
        return lower(ParmE(1, expr.f), n)
    if isinstance(expr, ParmE):
        if not expr.mask < (1 << n):
            raise ValueError(f"parm mask {expr.mask:#x} out of range for n={n}")
        a = parm_matrix(n, expr.mask)
        body = _lift(lower(expr.f, n - 1), n)
        return (Perm(a),) + body + (Perm(a.inverse()),)
    if isinstance(expr, Perm):
        if expr.bmmc.n != n:
            from ..guard.errors import BadInput
            raise BadInput(f"Perm is on {expr.bmmc.n} bits, array has {n}")
        return (expr,)
    if isinstance(expr, Bfly):
        if expr.size_bits() != n:
            from ..guard.errors import BadInput
            raise BadInput(
                f"Bfly is on {expr.size_bits()} bits, array has {n}")
        return (expr,)
    if isinstance(expr, PRIMITIVES):
        return (expr,)
    raise TypeError(f"unknown Expr node {type(expr).__name__}")


def fuse(program: Sequence[Expr]) -> Program:
    """Fuse adjacent ``Perm`` stages and drop identity permutations."""
    out: List[Expr] = []
    for s in program:
        if isinstance(s, Perm) and out and isinstance(out[-1], Perm):
            out[-1] = Perm(s.bmmc @ out[-1].bmmc)
        else:
            out.append(s)
    return tuple(s for s in out
                 if not (isinstance(s, Perm) and s.bmmc.is_identity_perm()))


def optimize(expr: Expr, n: int) -> Program:
    """Lower and fuse: the full offline pipeline."""
    return fuse(lower(expr, n))


# ---------------------------------------------------------------------------
# Fused-stage clustering (the megakernel grouping pass)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class FusedStage:
    """A ``Perm → compute → … → Perm`` run executable as ONE tiled pass.

    ``stages`` is the original primitive run (the oracle / fallback / VJP
    replay path executes it stage-at-a-time); ``bmmc`` the composed
    permutation the megakernel's DMA+gather realizes; ``computes`` the
    interior compute stages paired with the *prefix* permutation (the
    composition of the run's perms before them) whose output index space
    they act in. Hashable, so fused programs can key plan caches.
    """

    stages: Program
    bmmc: Bmmc
    computes: Tuple[Tuple[Expr, Bmmc], ...]

    def size_bits(self) -> int:
        return self.bmmc.n


def _run_fused(stages: Sequence[Expr], n: int) -> FusedStage:
    """Build the FusedStage for a validated run."""
    prefix = Bmmc.identity(n)
    computes: List[tuple] = []
    for s in stages:
        if isinstance(s, Perm):
            prefix = s.bmmc @ prefix
        else:
            computes.append((s, prefix))
    return FusedStage(tuple(stages), prefix, tuple(computes))


def _run_valid(stages: Sequence[Expr], n: int, t: int) -> bool:
    """Can this run execute as one fused megakernel dispatch?

    The composed permutation runs as its tiled passes (ONE for any BMMC
    the classic or generalized witness-direction planner takes — i.e.
    always when 2t <= n — else the §5.2 two-pass factorization), and
    every interior compute must be tile-local *in the first pass*: its
    pairing vector ``A_M^{-1} e_{n-1}`` (``M`` = prefix perms), pulled
    back to input space, lies in the span of the first pass's tile
    directions (witness directions plus the low lane bits), so both
    halves of every pair land in the same VMEM tile. (Computes are
    applied to the input tile before the first gather — a permutation
    only moves values, so a compute pulled back through its prefix
    commutes exactly.) ``Map`` is elementwise and always local; ``Bfly``
    additionally gates on its resident twiddle table fitting the VMEM
    budget.
    """
    fs = _run_fused(stages, n)
    spans = pass_spans(fs.bmmc, t)
    if spans is None:
        return False
    first = spans[0]
    for comp, prefix in fs.computes:
        if isinstance(comp, Map):
            continue
        if isinstance(comp, Bfly):
            if len(comp.twiddles) * 8 > _W_TABLE_BYTES:
                return False
        if not f2.in_span(pairing_vector(prefix), first):
            return False
    return True


def cluster(program: Sequence[Expr], n: int,
            t: Optional[int]) -> Tuple[Expr, ...]:
    """Greedily group runs of a fused program into :class:`FusedStage`\\ s.

    Starting at each ``Perm`` — or at a *compute* whose pairing vector
    is already tile-local in the following permutation's first pass
    (prefix = identity), so it rides that pass's tiles instead of paying
    its own elementwise HBM sweep — the run is extended one stage at a
    time, or by a ``(compute, Perm)`` pair when the compute only becomes
    tile-local under the *longer* composition, while :func:`_run_valid`
    holds. ``t=None`` (array too small to tile) disables clustering.
    Stages outside any run pass through unchanged, so ``cluster`` is the
    identity on programs the megakernel cannot speed up.
    """
    prog = tuple(program)
    if t is None:
        return prog
    out: List[Expr] = []
    i = 0
    while i < len(prog):
        s = prog[i]
        run: List[Expr] = []
        j = i
        if isinstance(s, COMPUTES):
            # leading computes: absorb the longest suffix of the compute
            # block that is tile-local in the next Perm's first pass
            k = i
            while k < len(prog) and isinstance(prog[k], COMPUTES):
                k += 1
            if k < len(prog) and isinstance(prog[k], Perm):
                for start in range(i, k):
                    cand = list(prog[start:k + 1])
                    if _run_valid(cand, n, t):
                        out.extend(prog[i:start])
                        run = cand
                        j = k + 1
                        break
            if not run:
                out.append(s)
                i += 1
                continue
        elif isinstance(s, Perm):
            run = [s]
            j = i + 1
        else:
            out.append(s)
            i += 1
            continue
        while j < len(prog):
            if _run_valid(run + [prog[j]], n, t):
                run.append(prog[j])
                j += 1
            elif (isinstance(prog[j], COMPUTES) and j + 1 < len(prog)
                  and isinstance(prog[j + 1], Perm)
                  and _run_valid(run + [prog[j], prog[j + 1]], n, t)):
                run.extend((prog[j], prog[j + 1]))
                j += 2
            else:
                break
        if len(run) == 1:
            out.append(s)
            i += 1
        else:
            # telemetry: planner decisions, recorded at plan time (the
            # clustered-program cache makes this once per (expr, n, t))
            _ometrics.inc("optimize.clusters")
            _ometrics.inc("optimize.cluster_stages_absorbed", len(run))
            out.append(_run_fused(run, n))
            i = j
    return tuple(out)


# ---------------------------------------------------------------------------
# Free-stage folding (DESIGN.md §11): complement-only and tile-index-only
# permutations never deserve their own HBM round trip — a complement
# changes only the affine offset of a neighbouring stage's DMA source
# map (same matrix, same tile geometry), and a tile-index-only
# permutation relabels whole rows, which the neighbouring pass's
# ``in_rows``/``out_rows`` tables absorb verbatim.
# ---------------------------------------------------------------------------

FREE_CLASSES = ("complement", "block")


def _merge_stages(a: Expr, b: Expr) -> tuple:
    sa = a.stages if isinstance(a, FusedStage) else (a,)
    sb = b.stages if isinstance(b, FusedStage) else (b,)
    return tuple(sa) + tuple(sb)


def fold_free(program: Sequence[Expr], n: int,
              t: Optional[int]) -> Tuple[Expr, ...]:
    """Fold standalone free-class ``Perm`` stages (complement-only /
    tile-index-only at ``t``) into an adjacent ``Perm``/:class:
    `FusedStage`, so they cost zero HBM round trips.

    Folding into the *following* stage composes the free BMMC into that
    stage's DMA **source** map; folding into the *preceding* stage
    composes into its **output** map. Either way the merged run is
    re-validated with :func:`_run_valid` (a complement fold always
    passes — the composed matrix is unchanged — and a block fold passes
    whenever the composed plan keeps every compute tile-local), so the
    pass is conservative: stages that cannot fold stay standalone.
    """
    prog = list(program)
    if t is None:
        return tuple(prog)
    changed = True
    while changed:
        changed = False
        for i, s in enumerate(prog):
            if not isinstance(s, Perm):
                continue
            if s.bmmc.bmmc_class(t) not in FREE_CLASSES:
                continue
            for j in (i + 1, i - 1):
                if not 0 <= j < len(prog):
                    continue
                other = prog[j]
                if not isinstance(other, (Perm, FusedStage)):
                    continue
                merged = (_merge_stages(s, other) if j > i
                          else _merge_stages(other, s))
                if _run_valid(merged, n, t):
                    lo, hi = min(i, j), max(i, j)
                    prog[lo:hi + 1] = [_run_fused(merged, n)]
                    _ometrics.inc("optimize.fold_free_folds",
                                  cls=s.bmmc.bmmc_class(t))
                    changed = True
                    break
            if changed:
                break
    return tuple(prog)


def expand_clusters(program: Sequence[Expr]) -> Program:
    """Inverse of :func:`cluster`: replace FusedStages by their stages."""
    out: List[Expr] = []
    for s in program:
        if isinstance(s, FusedStage):
            out.extend(s.stages)
        else:
            out.append(s)
    return tuple(out)


def is_perm_program(program: Iterable[Expr]) -> bool:
    """True iff every stage is a ``Perm`` or a compute-free
    :class:`FusedStage` — the programs with an exact offline inverse
    (and therefore a fully precompiled backward pass, DESIGN.md §13)."""
    return all(isinstance(s, Perm)
               or (isinstance(s, FusedStage) and not s.computes)
               for s in program)


def inverse_stage(s: Expr) -> Expr:
    """The offline inverse of one permutation stage.

    A ``Perm``'s inverse is the offline F2-inverted BMMC. A compute-free
    :class:`FusedStage`'s inverse is a FusedStage of the inverted member
    stages in reverse order — its composed BMMC is ``bmmc.inverse()``,
    so it dispatches through the same megakernel machinery as the
    forward cluster (per-class closure: identity / complement / block /
    lane BMMCs invert within their class, and any invertible BMMC keeps
    its one-pass plan when ``2t <= n``, DESIGN.md §13). Compute-bearing
    clusters have no static inverse (``CmpHalves``' adjoint routes by
    the primal values); their backward is handled by the executor's
    pulled-back VJP instead (:func:`repro.combinators.execute.
    fused_apply`).
    """
    if isinstance(s, Perm):
        return Perm(s.bmmc.inverse())
    if isinstance(s, FusedStage) and not s.computes:
        return _run_fused(
            tuple(Perm(st.bmmc.inverse()) for st in reversed(s.stages)),
            s.bmmc.n)
    from ..guard.errors import BadStage
    raise BadStage(
        f"inverse_program needs a permutation-only program; "
        f"found {type(s).__name__}"
        + (" with compute stages" if isinstance(s, FusedStage) else ""))


def inverse_program(program: Sequence[Expr]) -> Program:
    """The exact inverse of a permutation-only program: stages reversed,
    each stage replaced by its offline inverse (``Perm`` → inverted
    BMMC; compute-free :class:`FusedStage` → the inverted cluster, see
    :func:`inverse_stage`) — so the inverse of a *clustered* program is
    itself clustered, mirroring the forward plan stage for stage.

    This is also the *VJP program* of the forward program — a BMMC
    permutation matrix is orthogonal over the reals, so its Jacobian
    transpose equals its inverse — which is what lets the executor's
    backward pass ride the same megakernel/class-dispatch executables
    as the forward (DESIGN.md §9/§13). Raises ``TypeError`` on
    non-``Perm`` stages (``CmpHalves`` is not invertible; ``Bfly``/
    ``Map`` have state-dependent adjoints handled by the executor's
    compute-VJP path instead).
    """
    return tuple(inverse_stage(s) for s in reversed(tuple(program)))


def num_perm_stages(program: Iterable[Expr]) -> int:
    return sum(isinstance(s, Perm) for s in program)


def program_cost(program: Sequence[Expr], t: int, itemsize: int = 4) -> dict:
    """Offline cost report: HBM round trips + DMA descriptors + per-class
    kernel counts.

    ``t`` is the tile parameter of the executing kernel. Each ``Perm``
    contributes its class-dispatched kernel — zero passes for an
    identity, ONE for block / lane / tiled / generalized-tiled, two only
    for the §5.2 fallback; each :class:`FusedStage` likewise, regardless
    of how many stages it swallowed (that is the megakernel's whole
    point); each *standalone* compute stage one full elementwise sweep
    (read + write of the array — what the per-stage jnp path pays).
    ``round_trips`` totals them; ``round_trips_unfused`` is the same
    program with every cluster expanded, so ``round_trips_saved`` is the
    megakernel's win as seen by the transaction model.

    ``kernels`` counts stage dispatches per kernel class (DESIGN.md §11
    — ``block``/``lane``/``tiled``/``general``/``general2`` for
    standalone ``Perm``\\ s, ``fused`` for megakernel clusters, which
    always run the tiled pipeline regardless of their composed BMMC's
    class, plus ``sweep`` for standalone computes); ``roofline_ratio``
    is modeled
    copy-kernel descriptors over program descriptors — 1.0 means the
    whole program runs at the speed of ``round_trips`` array copies.
    """
    from ..core.tiling import copy_descriptors
    from ..kernels.ops import modeled_transactions

    prog = tuple(program)
    n = None
    for s in prog:
        if isinstance(s, (Perm, FusedStage)):
            n = s.bmmc.n
            break
    passes = 0
    descriptors = 0
    bytes_moved = 0
    round_trips = 0
    compute_sweeps = 0
    fused_stages = 0
    kernels: dict = {}
    copy_desc = 0
    for s in prog:
        if isinstance(s, (Perm, FusedStage)):
            if isinstance(s, FusedStage):
                # a cluster always executes through the tiled megakernel
                # (it needs the gather + epilogue machinery), so model
                # its tiled passes — NOT the class fast path its composed
                # BMMC might qualify for standalone
                from ..core.tiling import stats_bmmc
                stats = stats_bmmc(s.bmmc, t)
                tx = {"passes": len(stats),
                      "descriptors": sum(p.dma_descriptors() for p in stats),
                      "bytes_moved": 2 * (1 << s.bmmc.n) * itemsize
                      * len(stats),
                      "kernel": "fused"}
                fused_stages += 1
            else:
                tx = modeled_transactions(s.bmmc, t, itemsize)
            passes += tx["passes"]
            round_trips += tx["passes"]
            descriptors += tx["descriptors"]
            bytes_moved += tx["bytes_moved"]
            kernels[tx["kernel"]] = kernels.get(tx["kernel"], 0) + 1
            copy_desc += copy_descriptors(s.bmmc.n) * tx["passes"]
        else:  # standalone compute: one full elementwise sweep over HBM
            compute_sweeps += 1
            round_trips += 1
            kernels["sweep"] = kernels.get("sweep", 0) + 1
            if n is not None:
                descriptors += copy_descriptors(n)
                copy_desc += copy_descriptors(n)
                bytes_moved += 2 * (1 << n) * itemsize
    cost = {
        "stages": len(prog),
        "perm_stages": num_perm_stages(prog),
        "fused_stages": fused_stages,
        "compute_sweeps": compute_sweeps,
        "tiled_passes": passes,
        "descriptors": descriptors,
        "bytes_moved": bytes_moved,
        "round_trips": round_trips,
        "kernels": kernels,
        "roofline_ratio": copy_desc / max(descriptors, 1),
    }
    if fused_stages:
        unfused = program_cost(expand_clusters(prog), t, itemsize)
        cost["round_trips_unfused"] = unfused["round_trips"]
        cost["round_trips_saved"] = (unfused["round_trips"]
                                     - cost["round_trips"])
    return cost
