"""Lazy BMMC expression IR (the "first step towards array combinators").

An ``Expr`` is a *description* of a size-preserving transformation on an
array of 2^n elements (optionally with a trailing feature dim). Nothing
executes at construction time: expressions are lowered to a flat *stage
program* by :mod:`repro.combinators.optimize` and compiled/executed by
:mod:`repro.combinators.execute`.

Node kinds
----------

Primitive stages (survive lowering; a lowered program is a tuple of these):

* ``Perm(bmmc)``   — the affine index permutation ``out[A i ^ c] = x[i]``.
* ``CmpHalves()``  — ``out[:h] = min(x[:h], x[h:]); out[h:] = max`` — the
  full-width compare-exchange sweep of sorting networks (paper §7.1).
* ``Bfly(w)``      — radix-2 butterfly between halves with per-pair complex
  twiddles: ``out[:h] = lo + w*hi; out[h:] = lo - w*hi``.
* ``Map(name, fn)``— an elementwise (position-independent) jax function.

Structured nodes (eliminated by lowering):

* ``Id()``             — the identity.
* ``Seq(fs)``          — sequential pipeline; ``fs[0]`` is applied first.
* ``Two(f)``           — apply ``f`` independently to the two *contiguous*
  halves (the paper's ``two`` combinator; split on the top index bit).
* ``Ilv(f)``           — apply ``f`` to the even- and odd-indexed
  interleaved sub-arrays (the paper's ``ilv``; split on the bottom bit).
* ``ParmE(mask, f)``   — the general ``parm`` (paper §7): split by the F2
  inner product ``i·mask``; generalizes ``Two`` (mask = 2^(n-1)) and
  ``Ilv`` (mask = 1).

All nodes are frozen, hashable dataclasses, so expressions can key the
compiled-plan cache. ``Map`` hashes by its ``name`` only — the name must
uniquely identify the function.

Composition reads left to right: ``a >> b`` means "apply ``a``, then
``b``" (pipeline order, matching how stage programs execute).
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Tuple

from ..core.bmmc import Bmmc


class Expr:
    """Base class for all IR nodes."""

    def __rshift__(self, other: "Expr") -> "Expr":
        return seq(self, other)

    def size_bits(self) -> int | None:
        """The array size 2^n this node requires, or None if polymorphic."""
        return None


@dataclasses.dataclass(frozen=True)
class Perm(Expr):
    """Primitive: BMMC index permutation ``out[A i ^ c] = x[i]``.

    ``bmmc_class(t)`` exposes the kernel-class hierarchy of the
    underlying BMMC (identity < complement < block < lane < tiled <
    general; DESIGN.md §11) — the optimizer folds the free classes
    (complement / block) into a neighbouring stage's DMA maps, and the
    executor dispatches the rest to class-specialized kernels.
    """

    bmmc: Bmmc

    def size_bits(self):
        return self.bmmc.n

    def bmmc_class(self, t: int) -> str:
        return self.bmmc.bmmc_class(t)


@dataclasses.dataclass(frozen=True)
class CmpHalves(Expr):
    """Primitive: one full-width min/max sweep between the two halves."""


@dataclasses.dataclass(frozen=True)
class Bfly(Expr):
    """Primitive: butterfly between halves, ``(lo + w·hi, lo - w·hi)``.

    ``twiddles`` is a tuple of 2^(n-1) python complex numbers (hashable,
    offline). Arrays may be complex, or float with a trailing dim of 2
    holding (re, im) — the layout the tiled kernels prefer.
    """

    twiddles: Tuple[complex, ...]

    def size_bits(self):
        return len(self.twiddles).bit_length()  # 2^(n-1) pairs -> n


@dataclasses.dataclass(frozen=True)
class Map(Expr):
    """Primitive: elementwise jax function. Hashes/compares by ``name``."""

    name: str
    fn: Callable = dataclasses.field(compare=False, hash=False, repr=False)


@dataclasses.dataclass(frozen=True)
class Id(Expr):
    """Structured: the identity transformation."""


@dataclasses.dataclass(frozen=True)
class Seq(Expr):
    """Structured: pipeline; ``fs[0]`` applied first."""

    fs: Tuple[Expr, ...]

    def size_bits(self):
        for f in self.fs:
            n = f.size_bits()
            if n is not None:
                return n
        return None


@dataclasses.dataclass(frozen=True)
class Two(Expr):
    """Structured: apply ``f`` to each contiguous half (top-bit split)."""

    f: Expr

    def size_bits(self):
        n = self.f.size_bits()
        return None if n is None else n + 1


@dataclasses.dataclass(frozen=True)
class Ilv(Expr):
    """Structured: apply ``f`` to evens and odds (bottom-bit split)."""

    f: Expr

    def size_bits(self):
        n = self.f.size_bits()
        return None if n is None else n + 1


@dataclasses.dataclass(frozen=True)
class ParmE(Expr):
    """Structured: the general ``parm mask f`` (paper §7.2)."""

    mask: int
    f: Expr

    def __post_init__(self):
        if self.mask <= 0:
            raise ValueError("parm mask must be positive")

    def size_bits(self):
        n = self.f.size_bits()
        return None if n is None else n + 1


Compose = Seq  # paper-facing alias for the sequential-composition node

PRIMITIVES = (Perm, CmpHalves, Bfly, Map)


def seq(*fs: Expr) -> Expr:
    """Sequential composition, flattening nested ``Seq`` and dropping ``Id``."""
    flat: list = []
    for f in fs:
        if isinstance(f, Seq):
            flat.extend(f.fs)
        elif not isinstance(f, Id):
            flat.append(f)
    if not flat:
        return Id()
    if len(flat) == 1:
        return flat[0]
    return Seq(tuple(flat))
