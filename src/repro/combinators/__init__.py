"""Array combinators over BMMC index permutations (paper §7, generalized).

A lazy expression IR (:mod:`.ir`), a vocabulary of named combinators
(:mod:`.vocab`), a fusing optimizer implementing the §7.2 rewrite algebra
(:mod:`.optimize`), and a multi-engine executor with a compiled-plan
cache (:mod:`.execute`). Workloads: the balanced-periodic sorting network
(:mod:`.sort`) and a radix-2 FFT (:mod:`.fft`).

Quick tour::

    from repro.combinators import vocab as V, compile_expr

    e = V.riffle(10) >> V.bit_reverse(10) >> V.rev(10)
    f = compile_expr(e, engine="pallas")   # one fused tiled pass
    y = f(x)
"""
from .ir import (Bfly, CmpHalves, Compose, Expr, Id, Ilv, Map, ParmE, Perm,
                 Seq, Two, seq)
from .optimize import (FusedStage, cluster, expand_clusters, fold_free, fuse,
                       inverse_program, inverse_stage, is_perm_program,
                       lower, num_perm_stages, optimize, program_cost)
from .execute import (CompiledExpr, cache_stats, clear_caches, compile_expr,
                      engines, fused_apply, get_engine, perm_apply,
                      program_apply, register_engine, run_program)
from . import vocab
from .sort import compiled_sort, sort_expr
# NB: the fft *function* stays in .fft to avoid shadowing the submodule
# attribute (``repro.combinators.fft`` must remain the module).
from .fft import compiled_fft, fft_expr

__all__ = [
    "Bfly", "CmpHalves", "Compose", "Expr", "Id", "Ilv", "Map", "ParmE",
    "Perm", "Seq", "Two", "seq", "FusedStage", "cluster", "expand_clusters",
    "fold_free", "fuse", "inverse_program", "inverse_stage",
    "is_perm_program", "lower", "num_perm_stages", "optimize",
    "program_cost", "CompiledExpr", "cache_stats", "clear_caches",
    "compile_expr", "engines", "fused_apply", "get_engine", "perm_apply",
    "program_apply", "register_engine", "run_program",
    "vocab", "compiled_sort", "sort_expr", "compiled_fft", "fft_expr",
]
