"""Combinator vocabulary: named BMMC permutations and lifts as IR builders.

Every function returns an :class:`~repro.combinators.ir.Expr`; nothing
executes until :func:`~repro.combinators.execute.compile_expr`. The pure
permutations are all BPCs, so each costs exactly one tiled kernel pass —
and adjacent ones fuse into a single BMMC by the optimizer.

Index conventions (array size 2^n, bit 0 = least significant):

* ``riffle``  — the perfect out-shuffle: ``[a..., b...] -> [a0, b0, a1,
  b1, ...]``; destination index = source index bits rotated left by 1.
* ``unriffle``/``evens_odds`` — its inverse: evens to the low half, odds
  to the high half.
* ``stride_permute(n, k)`` — gather with stride 2^k (destination bits =
  source bits rotated *right* by ``k``); ``stride_permute(n, 1) ==
  unriffle(n)`` and ``stride_permute(n, n-1) == riffle(n)``.
"""
from __future__ import annotations

from typing import Callable

from ..core.bmmc import Bmmc
from .ir import (Bfly, CmpHalves, Expr, Id, Ilv, Map, ParmE, Perm, Seq, Two,
                 seq)

__all__ = [
    "perm", "identity", "rev", "bit_reverse", "transpose", "riffle",
    "unriffle", "interleave", "evens_odds", "stride_permute", "rotate_bits",
    "xor_shift", "parm", "two", "ilv", "cmp_halves", "emap", "bfly", "seq",
]


def perm(bmmc: Bmmc) -> Expr:
    """An arbitrary BMMC permutation as an expression leaf."""
    return Perm(bmmc)


def identity() -> Expr:
    return Id()


def rev(n: int) -> Expr:
    """Array reversal: ``out[i] = x[2^n - 1 - i]`` (complement-only BPC)."""
    return Perm(Bmmc.reverse_array(n))


def bit_reverse(n: int) -> Expr:
    """Bit-reversal permutation (FFT input reordering)."""
    return Perm(Bmmc.bit_reverse(n))


def transpose(row_bits: int, col_bits: int) -> Expr:
    """Transpose of a (2^row_bits, 2^col_bits) row-major matrix."""
    return Perm(Bmmc.matrix_transpose(row_bits, col_bits))


def rotate_bits(n: int, k: int) -> Expr:
    """Destination index = source index bits rotated left by ``k``."""
    return Perm(Bmmc.rotate_bits(n, k % n)) if k % n else Id()


def stride_permute(n: int, k: int) -> Expr:
    """Stride-2^k gather (the classic L^{2^n}_{2^k} stride permutation):
    ``out[c·2^(n-k) + r] = x[r·2^k + c]`` — destination index = source
    index bits rotated right by ``k``. ``stride_permute(n, 1) ==
    unriffle(n)`` (evens first); ``stride_permute(n, n-1) == riffle(n)``."""
    return rotate_bits(n, n - (k % n))


def riffle(n: int) -> Expr:
    """Perfect out-shuffle: interleave the two halves, low half first."""
    return rotate_bits(n, 1)


def unriffle(n: int) -> Expr:
    """Inverse riffle: evens to the low half, odds to the high half."""
    return rotate_bits(n, n - 1)


def interleave(n: int) -> Expr:
    """Alias of :func:`riffle` (zip the halves together)."""
    return riffle(n)


def evens_odds(n: int) -> Expr:
    """Alias of :func:`unriffle` (unzip into evens then odds)."""
    return unriffle(n)


def xor_shift(n: int, c: int) -> Expr:
    """Pure complement: ``out[i ^ c] = x[i]``."""
    return Perm(Bmmc.xor_shift(n, c))


def parm(mask: int, f: Expr) -> Expr:
    """The paper's ``parm``: split by the F2 inner product ``i·mask``,
    apply ``f`` to both sub-arrays (paper §7)."""
    return ParmE(mask, f)


def two(f: Expr) -> Expr:
    """Apply ``f`` to each contiguous half (top-bit split)."""
    return Two(f)


def ilv(f: Expr) -> Expr:
    """Apply ``f`` to the even- and odd-indexed sub-arrays (bottom bit)."""
    return Ilv(f)


def cmp_halves() -> Expr:
    """Full-width compare-exchange sweep (sorting networks)."""
    return CmpHalves()


def emap(name: str, fn: Callable) -> Expr:
    """Elementwise map; ``name`` must uniquely identify ``fn`` (cache key)."""
    return Map(name, fn)


def bfly(twiddles) -> Expr:
    """Butterfly between halves with the given per-pair complex twiddles."""
    return Bfly(tuple(complex(w) for w in twiddles))
