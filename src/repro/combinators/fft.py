"""Radix-2 DIT FFT as a combinator expression (bit-reversal + butterflies).

The classic iterative FFT on 2^n points is::

    bit_reverse  >>  stage 0  >>  stage 1  >>  ...  >>  stage n-1

where stage ``s`` applies, within each contiguous block of 2^(s+1)
elements, the butterfly pairing ``j <-> j + 2^s`` with twiddle
``exp(-2πi j / 2^(s+1))``. In the IR that is ``two``-lifted ``n-s-1``
times over a full-width :func:`~repro.combinators.vocab.bfly` core —
every reordering (the bit-reversal and the block-bit swaps each ``two``
lift introduces) is a BMMC permutation, so the optimizer fuses them
across stages: the fused program has exactly one ``Perm`` between
butterflies instead of a growing conjugation chain.

Complex data is carried either natively (``complex64`` arrays, "ref"
engine) or as ``(2^n, 2)`` float arrays of (re, im) channels — the layout
the tiled Pallas kernels take (a permutation moves both channels of an
element together, exercising the kernels' trailing-dim path).
"""
from __future__ import annotations

import functools
import math

import jax.numpy as jnp
import numpy as np

from .execute import CompiledExpr, compile_expr
from .ir import Expr
from .vocab import bfly, bit_reverse, seq, two


def _stage_core(s: int) -> Expr:
    """Butterfly core on 2^(s+1) elements: pairs (j, j + 2^s)."""
    m = 1 << (s + 1)
    ws = [complex(math.cos(-2 * math.pi * j / m),
                  math.sin(-2 * math.pi * j / m)) for j in range(m // 2)]
    return bfly(ws)


@functools.lru_cache(maxsize=None)
def fft_expr(n: int) -> Expr:
    """The full 2^n-point DIT FFT expression."""
    stages = [bit_reverse(n)]
    for s in range(n):
        e = _stage_core(s)
        for _ in range(n - s - 1):
            e = two(e)
        stages.append(e)
    return seq(*stages)


def compiled_fft(n: int, *, engine="ref", optimize: bool = True) -> CompiledExpr:
    return compile_expr(fft_expr(n), engine=engine, optimize=optimize)


def fft(x, *, engine="ref"):
    """FFT of a complex jax array of 2^n points via the combinator program."""
    n = int(np.log2(np.shape(x)[0]))
    x = jnp.asarray(x, jnp.complex64)
    return compiled_fft(n, engine=engine)(x)


def fft_planar(x_ri, *, engine="pallas"):
    """FFT on the planar (2^n, 2) float (re, im) layout — kernel-friendly."""
    n = int(np.log2(np.shape(x_ri)[0]))
    return compiled_fft(n, engine=engine)(x_ri)


def to_planar(x) -> jnp.ndarray:
    x = jnp.asarray(x, jnp.complex64)
    return jnp.stack([x.real, x.imag], axis=-1).astype(jnp.float32)


def from_planar(x_ri) -> jnp.ndarray:
    return x_ri[..., 0] + 1j * x_ri[..., 1]
