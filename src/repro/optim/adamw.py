"""AdamW with fully-sharded states + optional 8-bit block-quantized moments.

States mirror parameter sharding (FSDP): with ``state_bits=8`` the first and
second moments are stored as int8 with per-block float32 scales (block =
trailing 256 elements), cutting optimizer memory 8x vs f32 — required to fit
kimi-k2-1t (1.03T params) in 512 x 16 GB HBM (see DESIGN.md §5).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np

_BLOCK = 256


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    state_bits: int = 32          # 32 (f32 moments) or 8 (quantized)


# -- int8 block quantization -------------------------------------------------
#
# Blocks run along the LAST axis only: q keeps the parameter's leading dims,
# so the quantized moments inherit the parameter's sharding unchanged.
# (A flat (-1, 256) layout forced GSPMD to re-shard every step — measured as
# a 1.6e11 B/device all-gather plus "involuntary full rematerialization"
# warnings on kimi-k2; see EXPERIMENTS.md §Perf iteration 2.)

def _q_shape(shape):
    last = shape[-1] if shape else 1
    blk = min(_BLOCK, last)
    nb = -(-last // blk)
    return shape[:-1] + (nb, blk), blk, nb * blk - last


def quantize8(x) -> Dict[str, jax.Array]:
    if x.ndim == 0:
        x = x[None]
    qshape, blk, pad = _q_shape(x.shape)
    if pad:
        x = jnp.pad(x, [(0, 0)] * (x.ndim - 1) + [(0, pad)])
    blocks = x.reshape(qshape)
    scale = jnp.max(jnp.abs(blocks), axis=-1, keepdims=True) / 127.0
    q = jnp.round(blocks / jnp.maximum(scale, 1e-20)).astype(jnp.int8)
    return {"q": q, "s": scale.astype(jnp.float32)}


def dequantize8(qt: Dict[str, jax.Array], shape) -> jax.Array:
    if not shape:
        shape = (1,)
    blocks = qt["q"].astype(jnp.float32) * qt["s"]
    flatlast = blocks.reshape(shape[:-1] + (-1,))
    return flatlast[..., :shape[-1]].reshape(shape)


def _q8_zeros_like(x):
    shape = x.shape if x.ndim else (1,)
    qshape, _, _ = _q_shape(shape)
    return {"q": jnp.zeros(qshape, jnp.int8),
            "s": jnp.zeros(qshape[:-1] + (1,), jnp.float32)}


# -- optimizer ----------------------------------------------------------------

class AdamWState(NamedTuple):
    step: jax.Array
    m: Any
    v: Any


def adamw_init(params, cfg: AdamWConfig) -> AdamWState:
    if cfg.state_bits == 8:
        m = jax.tree.map(_q8_zeros_like, params)
        v = jax.tree.map(_q8_zeros_like, params)
    else:
        m = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
        v = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
    return AdamWState(step=jnp.zeros((), jnp.int32), m=m, v=v)


def adamw_update(params, grads, state: AdamWState, cfg: AdamWConfig,
                 lr_scale=1.0):
    step = state.step + 1
    t = step.astype(jnp.float32)
    c1 = 1.0 - cfg.b1 ** t
    c2 = 1.0 - cfg.b2 ** t
    lr = cfg.lr * lr_scale

    def upd(p, g, m, v):
        gf = g.astype(jnp.float32)
        if cfg.state_bits == 8:
            mf = dequantize8(m, p.shape)
            vf = dequantize8(v, p.shape)
        else:
            mf, vf = m, v
        mf = cfg.b1 * mf + (1 - cfg.b1) * gf
        vf = cfg.b2 * vf + (1 - cfg.b2) * gf * gf
        mhat = mf / c1
        vhat = vf / c2
        pf = p.astype(jnp.float32)
        pf = pf - lr * (mhat / (jnp.sqrt(vhat) + cfg.eps) + cfg.weight_decay * pf)
        new_p = pf.astype(p.dtype)
        if cfg.state_bits == 8:
            return new_p, quantize8(mf), quantize8(vf)
        return new_p, mf, vf

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_m = treedef.flatten_up_to(state.m)
    flat_v = treedef.flatten_up_to(state.v)
    out = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_params = treedef.unflatten([o[0] for o in out])
    new_m = treedef.unflatten([o[1] for o in out])
    new_v = treedef.unflatten([o[2] for o in out])
    return new_params, AdamWState(step=step, m=new_m, v=new_v)


def state_shapes(param_shapes, cfg: AdamWConfig):
    """ShapeDtypeStruct tree for the optimizer state (dry-run stand-in)."""
    def q8_shape(p):
        shape = p.shape if p.shape else (1,)
        qshape, _, _ = _q_shape(shape)
        return {"q": jax.ShapeDtypeStruct(qshape, jnp.int8),
                "s": jax.ShapeDtypeStruct(qshape[:-1] + (1,), jnp.float32)}
    if cfg.state_bits == 8:
        m = jax.tree.map(q8_shape, param_shapes)
    else:
        m = jax.tree.map(lambda p: jax.ShapeDtypeStruct(p.shape, jnp.float32),
                         param_shapes)
    v = jax.tree.map(lambda x: x, m)
    return AdamWState(step=jax.ShapeDtypeStruct((), jnp.int32), m=m, v=v)
