"""Learning-rate schedules."""
from __future__ import annotations

import jax.numpy as jnp


def warmup_cosine(step, *, warmup: int, total: int, floor: float = 0.1):
    """Scale factor in [floor, 1]: linear warmup then cosine decay."""
    s = jnp.asarray(step, jnp.float32)
    warm = s / jnp.maximum(warmup, 1)
    frac = jnp.clip((s - warmup) / jnp.maximum(total - warmup, 1), 0.0, 1.0)
    cos = floor + (1 - floor) * 0.5 * (1 + jnp.cos(jnp.pi * frac))
    return jnp.where(s < warmup, warm, cos)
