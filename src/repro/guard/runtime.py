"""Ring 2 — checkify-style guarded dispatch (opt-in; DESIGN.md §14).

The guarded executable jits the *inner* whole-program executable
(:func:`~repro.combinators.execute._program_executable` — jit-of-jit,
so the unguarded executable cache still populates under its usual
keys and the per-kernel counters still fire at the inner trace)
together with the probes into ONE outer dispatch returning
``(y, flags)`` — checkify's pattern, with a warm guarded call costing
a single XLA dispatch just like an unguarded one. The flags are an
in-program int32 bitmask — no host sync happens inside the program;
the single ``int(flags)`` readback at the API edge is the resolve
step. Per-call ``program.call`` telemetry is mirrored from the
unguarded path in :func:`_observed_guarded_call`. The probed trap
kinds:

* **OOB descriptor trap** (bit 1): every gather/DMA table the program
  bakes in is bounds-checked *inside the traced program* (the tables
  are trace-time constants, so a clean table's check constant-folds to
  zero — the trap is free unless it fires at trace time, which is
  exactly when a poisoned table would be baked in).
* **NaN/Inf sentinel** (bit 2): compute-bearing float programs flag an
  output nonfinite that the input did not already carry — a compute
  epilogue manufactured it.
* **XOR-parity round-trip probe** (bit 4): for permutation-only
  programs the composed BMMC σ is built offline, and the program's
  claim ``y[σ(i)] == x[i]`` is checked at a deterministic sampled slice
  — ``apply ∘ inverse`` on the sample, with the inverse collapsed
  offline so the probe costs two K-element gathers, not a second pass.

Graceful degradation (the fallback state machine): a trapped "pallas"
call re-dispatches the same program through the guarded "ref" engine —
whose gather table is independent of every pallas plan cache — records
``guard.trap{kind}`` / ``guard.fallback{engine}`` counters, and returns
the recovered result. Only if the fallback traps too does the request
fail loudly: :class:`~.errors.CachePoisoned` when the live plan tables
no longer match their ring-1 fingerprints, :class:`~.errors.GuardTrap`
otherwise.
"""
from __future__ import annotations

import functools
import time

import jax
import jax.numpy as jnp
import numpy as np

from ..core.bmmc import Bmmc
from .errors import GuardTrap

TRAP_KINDS = {"oob": 1, "nonfinite": 2, "parity": 4}
_PARITY_SAMPLES = 64


def resolve_flags(mask: int) -> tuple:
    """Decode a flag bitmask into the trap-kind names that fired."""
    return tuple(k for k, bit in sorted(TRAP_KINDS.items()) if mask & bit)


def _trace_state_clean() -> bool:
    try:
        return jax.core.trace_state_clean()
    except AttributeError:  # pragma: no cover - older/newer jax
        return True


# ---------------------------------------------------------------------------
# probe construction (host side, per (program, t, engine); tables and
# sample indices are offline — only the checks themselves are traced)
# ---------------------------------------------------------------------------

def _stage_tables(prog, t: int, engine: str):
    """Every (table, exclusive upper bound) pair the resolved program
    will bake into its trace — the OOB trap's audit list."""
    from ..combinators.ir import Perm
    from ..combinators.optimize import FusedStage
    from ..combinators import execute as _ex
    from ..kernels import ops, ref as _ref

    out = []

    def add_tile(plan):
        n_rows = 1 << (plan.n - plan.t)
        out.append((plan.in_rows, n_rows))
        out.append((plan.out_rows, n_rows))
        out.append((plan.xor_low, plan.row_len))
        out.append((plan.src0, plan.rows_per_tile * plan.row_len))

    for st in prog:
        if isinstance(st, Perm):
            if engine == "ref" or t is None:
                out.append((_ref._src_table(st.bmmc.rows, st.bmmc.c),
                            st.bmmc.size))
                continue
            kernel, payload = ops.class_plan(st.bmmc, t)
            if kernel == "block":
                out.append((payload.src_rows, payload.n_rows))
            elif kernel == "lane":
                out.append((payload.src_lane, 1 << payload.t))
            elif kernel != "none":
                for plan in payload:
                    add_tile(plan)
        elif isinstance(st, FusedStage):
            if engine != "pallas" or t is None:
                for ss in st.stages:
                    if hasattr(ss, "bmmc"):
                        out.append((_ref._src_table(ss.bmmc.rows, ss.bmmc.c),
                                    ss.bmmc.size))
                continue
            got = _ex._fused_plan_cached(st, t)
            if got is None:
                continue
            for plan in got[0]:
                add_tile(plan)
    return out


def _program_sigma(prog):
    """The composed input→output BMMC of a permutation-only program
    (``out[σ(i)] = x[i]``), or None for compute-bearing programs."""
    from ..combinators.optimize import is_perm_program

    if not prog or not is_perm_program(prog):
        return None
    sigma = None
    for st in prog:
        b = st.bmmc
        sigma = b if sigma is None else b @ sigma
    return sigma


def _parity_sample(sigma: Bmmc):
    size = sigma.size
    k = min(size, _PARITY_SAMPLES)
    xs = (np.arange(k, dtype=np.int64) * max(1, size // k)) % size
    ys = np.fromiter((sigma.apply(int(i)) for i in xs),
                     dtype=np.int64, count=k)
    return xs.astype(np.int32), ys.astype(np.int32)


def _has_compute(prog) -> bool:
    from ..combinators.ir import Bfly, CmpHalves
    from ..combinators.optimize import FusedStage

    return any(isinstance(st, (CmpHalves, Bfly))
               or (isinstance(st, FusedStage) and st.computes)
               for st in prog)


def _build_probe(prog, t, engine: str, batched: bool):
    """Closure ``(x, y) -> int32 flags`` traced inside the guarded
    executable. All table/sample data is resolved offline here."""
    tables = _stage_tables(prog, t, engine)
    sigma = _program_sigma(prog)
    sample = _parity_sample(sigma) if sigma is not None else None
    check_finite = _has_compute(prog)
    axis = 1 if batched else 0

    def probe(x, y):
        flags = jnp.int32(0)
        oob = jnp.asarray(False)
        for tab, hi in tables:
            ta = jnp.asarray(tab)
            oob = oob | (ta.min() < 0) | (ta.max() >= hi)
        flags = flags | (jnp.int32(TRAP_KINDS["oob"])
                         * oob.astype(jnp.int32))
        if check_finite and jnp.issubdtype(y.dtype, jnp.floating):
            made_bad = ((~jnp.isfinite(y)).any()
                        & jnp.isfinite(x).all())
            flags = flags | (jnp.int32(TRAP_KINDS["nonfinite"])
                             * made_bad.astype(jnp.int32))
        if sample is not None:
            xs, ys = sample
            a = jnp.take(x, jnp.asarray(xs), axis=axis)
            b = jnp.take(y, jnp.asarray(ys), axis=axis)
            eq = a == b
            if jnp.issubdtype(y.dtype, jnp.floating):
                eq = eq | (jnp.isnan(a) & jnp.isnan(b))
            flags = flags | (jnp.int32(TRAP_KINDS["parity"])
                             * (~eq.all()).astype(jnp.int32))
        return flags

    return probe


# ---------------------------------------------------------------------------
# guarded executables
# ---------------------------------------------------------------------------

@functools.lru_cache(maxsize=256)
def _guarded_executable(prog: tuple, t, engine: str, batched: bool):
    """One jitted ``x -> (y, flags)`` per (program, engine) — the
    program and its probes fused into a single dispatch, flags resolved
    only by the caller.

    The traced body calls the *inner jitted*
    :func:`~repro.combinators.execute._program_executable` rather than
    re-tracing ``run_program`` itself: the inner lru populates under
    the exact unguarded cache key (so ``cache_stats()["program"]`` and
    its batch-size-independence hold with guards on), the per-kernel
    dispatch counters fire at the inner trace exactly as they do with
    guards off, and XLA inlines the nested call — a warm guarded call
    is still one dispatch. The probe's tables are baked at the *outer*
    trace, so a plan cache poisoned after the inner executable warmed
    is still re-read here and trapped."""
    from ..combinators import execute as _ex

    probe = _build_probe(prog, t, engine, batched)

    def run(x):
        y = _ex._program_executable(prog, engine, batched)(x)
        return y, probe(x, y)

    return jax.jit(run)


# Identity-keyed front memo over _guarded_executable, the same trick
# (and the same id-aliasing defense) as validate._VALIDATED_FAST:
# resolved program tuples are stable lru-cached objects, and skipping
# the deep lru-key hash on warm calls is what keeps guarded dispatch
# inside the ≤5% overhead budget. Bounded (LRU) so a long-lived serving
# process can't grow it without limit; cleared alongside the lru caches
# in validate.clear_guard_caches and inject._clear_runtime_only.
from .validate import IdentityMemo as _IdentityMemo  # noqa: E402

_EXEC_MEMO = _IdentityMemo(maxsize=1024)


def _guarded_exec_fast(prog: tuple, t, engine: str, batched: bool):
    key = (id(prog), t, engine, batched)
    hit = _EXEC_MEMO.lookup(key, prog)
    if hit is not None:
        return hit
    ex = _guarded_executable(prog, t, engine, batched)
    _EXEC_MEMO.store(key, prog, ex)
    return ex


def _observed_guarded_call(prog: tuple, t, x, engine: str, batched: bool):
    """Telemetry mirror of
    :func:`~repro.combinators.execute._observed_program_call` for the
    guarded executable: one ``program.call`` span + latency histogram
    per invocation (the executor sites inside fire at trace time only),
    cold/warm labeled by the guarded cache, modeled round trips
    accumulated for ``obs.model_vs_measured()``."""
    from ..combinators import execute as _ex
    from ..obs import metrics as _ometrics, trace as _otrace

    with _otrace.span("program.call", engine=engine, stages=len(prog),
                      path="guarded", batched=batched) as sargs:
        t0 = time.perf_counter_ns()
        misses0 = _guarded_executable.cache_info().misses
        y, flags = _guarded_exec_fast(prog, t, engine, batched)(x)
        cold = _guarded_executable.cache_info().misses > misses0
        if _otrace._state.sync:
            jax.block_until_ready(y)
        dur_us = (time.perf_counter_ns() - t0) / 1e3
        rt = _ex._program_round_trips(prog, t)
        sargs["dur_us"] = round(dur_us, 1)
        sargs["cache"] = "cold" if cold else "warm"
        if rt is not None:
            sargs["model_round_trips"] = rt
    _ometrics.observe("program.call_us", dur_us, engine=engine,
                      cache="cold" if cold else "warm")
    if rt is not None:
        _ometrics.inc("program.model_round_trips", rt)
        if not cold:
            _ometrics.observe("program.us_per_round_trip",
                              dur_us / max(rt, 1), engine=engine)
    return y, flags


@functools.lru_cache(maxsize=256)
def _guarded_permute_executable(rows: tuple, c: int, t, engine: str,
                                interpret: bool, batched: bool):
    """Guarded twin of :func:`repro.kernels.ops.bmmc_permute` for one
    BMMC: kernel dispatch + probes in one jit."""
    from ..combinators.ir import Perm
    from ..kernels import ops

    bmmc = Bmmc(rows, c)
    probe = _build_probe((Perm(bmmc),), t, engine, batched)

    def run(x):
        y = ops.bmmc_permute(x, bmmc, t=t, engine=engine,
                             interpret=interpret, batched=batched)
        return y, probe(x, y)

    return jax.jit(run)


def _diagnose(prog, t, kinds, engine):
    """Classify an unrecovered trap: poisoned caches get the precise
    :class:`CachePoisoned`; anything else fails as :class:`GuardTrap`."""
    from .errors import CachePoisoned
    from . import validate as _v

    poisoned = _v.check_fingerprints(prog, t)
    if poisoned:
        return CachePoisoned(
            f"guard trap(s) {sorted(kinds)} with {len(poisoned)} plan "
            f"fingerprint mismatch(es) — cached tables were mutated "
            f"after validation: {poisoned[:3]!r}")
    return GuardTrap(kinds, engine)


def _resolve_or_fallback(prog, t, x, engine, batched, run_engine):
    """The fallback state machine: run guarded on ``engine``; on a trap,
    degrade pallas → ref; raise typed only when the last engine traps.

    The resilience breaker board (DESIGN.md §16) fronts the dispatch:
    an open circuit rewrites ``engine`` to its fallback *before* the
    call — one clean ref dispatch, zero per-call trap/fallback cost on
    the condemned engine — and clean/trapped outcomes on the requested
    engine feed the circuit state (shunted outcomes deliberately do
    not: a shunted call's behavior says nothing about pallas health)."""
    from .. import guard as _g
    from ..resilience import breaker as _breaker

    board = _breaker.board()
    route = board.route(engine)
    engine = route.engine      # an open circuit shunts to the fallback
    y, flags = run_engine(engine)(x)
    mask = int(flags)          # the ONE host readback, at the API edge
    if not mask:
        board.on_success(route)
        return y
    kinds = resolve_flags(mask)
    for k in kinds:
        _g._record_trap(k, engine)
    board.on_trap(route, kinds)
    if engine != "ref":
        _g._record_fallback("ref")
        y2, flags2 = run_engine("ref")(x)
        mask2 = int(flags2)
        if not mask2:
            _g._record_recovered()
            return y2
        kinds = resolve_flags(mask2)
        for k in kinds:
            _g._record_trap(k, "ref")
    err = _diagnose(prog, t, kinds, "ref")
    _g._record_raised(err)
    raise err


def guarded_call(prog, t, x, engine, batched: bool):
    """Guarded :class:`~repro.combinators.execute.CompiledExpr` call:
    ring-1 validation (cached), then the guarded executable with
    in-program flags and the pallas → ref → loud-failure machine."""
    from ..combinators import execute as _ex
    from ..obs import trace as _otrace
    from . import validate as _v

    prog = tuple(prog)
    _v.validate_program_fast(prog, t)
    _v.validate_input(x.shape, x.dtype, batched=batched)
    if not isinstance(engine, str) or _ex._has_map(prog):
        # injected engines and user-Map programs stay on the eager
        # unguarded dispatch path (jitting an unknown callable would
        # break the Map contract's trace-tolerance); ring 1 still ran
        return _ex._dispatch_program(prog, t, x, engine, batched)

    def run_engine(eng):
        if _otrace._state.enabled:
            return lambda xx: _observed_guarded_call(
                prog, t, xx, eng, batched)
        return _guarded_exec_fast(prog, t, eng, batched)

    return _resolve_or_fallback(prog, t, x, engine, batched, run_engine)


def guarded_bmmc_permute(x, bmmc: Bmmc, *, t, engine: str, interpret: bool,
                         batched: bool):
    """Guarded :func:`repro.kernels.ops.bmmc_permute`: verify + dispatch
    validation, probes in-program, pallas → ref fallback."""
    from ..kernels import ops
    from . import validate as _v

    _v.verify_bmmc(bmmc)
    _v.validate_input(x.shape, x.dtype, batched=batched, n=bmmc.n)
    teff = ops.choose_tile(bmmc.n, x.dtype.itemsize,
                           x.shape[2 if batched else 1]
                           if x.ndim == (3 if batched else 2) else 1, t)
    if teff is not None and engine == "pallas":
        _v.validate_dispatch(bmmc.rows, bmmc.c, teff)

    def run_engine(eng):
        return _guarded_permute_executable(bmmc.rows, bmmc.c, t, eng,
                                           interpret, batched)

    from ..combinators.ir import Perm
    return _resolve_or_fallback((Perm(bmmc),), teff, x, engine, batched,
                                run_engine)
