"""Ring 1 — plan-time validation (always on; DESIGN.md §14).

Proves a compiled program's invariants before its plans are trusted:

* **BMMC invertibility** — :func:`verify_bmmc` re-runs the F2 rank
  check on the actual matrix (``__post_init__`` ran it at construction,
  but a matrix reaching the planner through ``object.__setattr__`` — or
  a poisoned cache — never went through the constructor).
* **Class-predicate consistency** — :func:`validate_dispatch` re-derives
  the class dispatch from the matrix and holds it against the cached
  plan: a payload dispatched as ``block``/``lane``/``tiled`` must still
  satisfy that class predicate, and a fold-free :class:`FusedStage`'s
  composed BMMC must equal the recomposition of its member stages.
* **Descriptor-bounds + semantic audit** — :func:`audit_tile_plan` /
  :func:`audit_block_plan` / :func:`audit_lane_plan` check every table
  entry against the geometry (bounds, bijectivity) and then check the
  kernel contract itself against the ground-truth permutation table
  ``tab[i] = bmmc.apply(i)``: for a tiled pass,

      ``out.flat[j] = tile.flat[src0[j ^ xor_low[g]]]``

  must route exactly ``tab``. Full over all tiles up to
  ``_FULL_AUDIT_TILES``; deterministically sampled beyond (``log()``-
  free: the sample is fixed, never random).
* **Input preconditions** — :func:`validate_input` (shape, power-of-2
  length, dtype known) raising :class:`~.errors.BadInput`.

Every validated plan's tables are fingerprinted (position-sensitive
XOR-fold, so swapping two entries changes the fingerprint);
:func:`check_fingerprints` re-hashes the live caches against the
recorded values so a runtime trap can be classified as
:class:`~.errors.CachePoisoned` (tables mutated *after* validation).

Validation is cached per ``(program, t)`` — the always-on ring costs one
pass per compiled program, never one per call.
"""
from __future__ import annotations

import functools
import threading

import numpy as np

from ..core import f2
from ..core.bmmc import Bmmc
from ..core.tiling import BlockPlan, LanePlan, TilePlan
from .errors import (BadInput, CachePoisoned, ClassMismatch, DescriptorOOB,
                     NotInvertible)

_FULL_AUDIT_TILES = 64        # audit every tile up to this many
_SAMPLE_TILES = 16            # strided sample beyond

_FP_LOCK = threading.Lock()
_FINGERPRINTS: dict = {}      # plan key -> recorded table fingerprint


# ---------------------------------------------------------------------------
# ground truth: the full permutation table, vectorized over numpy
# ---------------------------------------------------------------------------

def _np_parity(vals: np.ndarray) -> np.ndarray:
    v = vals.astype(np.int64)
    for s in (32, 16, 8, 4, 2, 1):
        v ^= v >> s
    return v & 1


def _bmmc_table(b: Bmmc) -> np.ndarray:
    """``tab[i] = b.apply(i)`` for all ``2^n`` indices."""
    idx = np.arange(1 << b.n, dtype=np.int64)
    out = np.zeros_like(idx)
    for j, row in enumerate(b.rows):
        out |= _np_parity(idx & row) << j
    return out ^ b.c


# ---------------------------------------------------------------------------
# BMMC / input preconditions
# ---------------------------------------------------------------------------

def verify_bmmc(bmmc: Bmmc) -> Bmmc:
    """Prove ``bmmc`` is a well-formed affine permutation: square
    bit-ranged rows, ``c`` in range, and full F2 rank. Returns the BMMC
    so call sites can validate inline."""
    n = len(bmmc.rows)
    mask = (1 << n) - 1
    bad = [i for i, r in enumerate(bmmc.rows)
           if not isinstance(r, int) or r < 0 or r > mask]
    if bad:
        raise NotInvertible(
            f"BMMC row(s) {bad} fall outside the {n}-bit column range "
            f"(expected 0 <= row <= {mask:#x})")
    if not 0 <= bmmc.c <= mask:
        raise NotInvertible(
            f"BMMC complement {bmmc.c:#x} outside the {n}-bit range")
    r = f2.rank(bmmc.rows)
    if r != n:
        raise NotInvertible(
            f"BMMC matrix is singular over F2: rank {r}, expected {n} "
            f"(a corrupted row makes the 'permutation' lossy)")
    return bmmc


def validate_input(shape: tuple, dtype, *, batched: bool = False,
                   n: int = None) -> int:
    """Shape/dtype preconditions on a program input. Returns the size
    exponent of the permuted axis; raises :class:`BadInput` otherwise."""
    axis = 1 if batched else 0
    if len(shape) <= axis:
        what = ("a leading batch dim plus the permuted axis" if batched
                else "a permutable leading axis")
        raise BadInput(f"input needs {what}, got shape {tuple(shape)}")
    if len(shape) > axis + 2:
        raise BadInput(
            f"input rank {len(shape)} unsupported: expected "
            f"{'(B, 2^n[, d])' if batched else '(2^n[, d])'}, "
            f"got shape {tuple(shape)}")
    size = shape[axis]
    got_n = int(size).bit_length() - 1
    if size <= 0 or (1 << got_n) != size:
        raise BadInput(
            f"array length {size} on axis {axis} is not a power of 2")
    if n is not None and got_n != n:
        raise BadInput(
            f"program expects a 2^{n}-length axis, got 2^{got_n} "
            f"({size}) in shape {tuple(shape)}")
    try:
        np.dtype(dtype)
    except TypeError:
        raise BadInput(f"unknown input dtype {dtype!r}") from None
    return got_n


# ---------------------------------------------------------------------------
# descriptor audits
# ---------------------------------------------------------------------------

def _bounds(name: str, arr: np.ndarray, lo: int, hi: int, where: str):
    a = np.asarray(arr)
    if a.size and (a.min() < lo or a.max() >= hi):
        raise DescriptorOOB(
            f"{where}: {name} entries fall outside [{lo}, {hi}): "
            f"min {int(a.min())}, max {int(a.max())}")


def _tile_sample(n_tiles: int):
    if n_tiles <= _FULL_AUDIT_TILES:
        return range(n_tiles)
    step = max(1, n_tiles // _SAMPLE_TILES)
    picks = set(range(0, n_tiles, step))
    picks.update((0, n_tiles - 1))
    return sorted(picks)


def audit_tile_plan(plan: TilePlan) -> None:
    """Bounds + semantic audit of one tiled pass against the kernel
    contract ``out.flat[j] = tile.flat[src0[j ^ xor_low[g]]]``."""
    n, t = plan.n, plan.t
    rpt, row_len = plan.rows_per_tile, plan.row_len
    n_rows = 1 << (n - t)
    where = f"TilePlan(n={n}, t={t})"
    for nm, arr, shape in (("in_rows", plan.in_rows, (plan.n_tiles, rpt)),
                           ("out_rows", plan.out_rows, (plan.n_tiles, rpt)),
                           ("xor_low", plan.xor_low, (plan.n_tiles,)),
                           ("src0", plan.src0, (rpt, row_len))):
        if np.asarray(arr).shape != shape:
            raise DescriptorOOB(
                f"{where}: {nm} shape {np.asarray(arr).shape} != "
                f"expected {shape} (truncated or mis-stacked table)")
    _bounds("in_rows", plan.in_rows, 0, n_rows, where)
    _bounds("out_rows", plan.out_rows, 0, n_rows, where)
    _bounds("xor_low", plan.xor_low, 0, row_len, where)
    _bounds("src0", plan.src0, 0, rpt * row_len, where)
    src_flat = plan.src0.reshape(-1).astype(np.int64)
    if np.unique(src_flat).size != src_flat.size:
        raise DescriptorOOB(
            f"{where}: src0 gather table is not a bijection of the tile "
            f"(duplicate sources silently drop elements)")
    # semantic: route every audited tile through the contract and hold
    # the resulting global (input -> output) map against the BMMC itself
    tab = _bmmc_table(plan.bmmc)
    j = np.arange(rpt * row_len, dtype=np.int64)
    rp, cp = j // row_len, j % row_len
    for g in _tile_sample(plan.n_tiles):
        src = src_flat[j ^ int(plan.xor_low[g])]
        r, c = src // row_len, src % row_len
        x_glob = plan.in_rows[g, r].astype(np.int64) * row_len + c
        y_glob = plan.out_rows[g, rp].astype(np.int64) * row_len + cp
        bad = tab[x_glob] != y_glob
        if bad.any():
            k = int(np.argmax(bad))
            raise DescriptorOOB(
                f"{where}: tile {g} routes input {int(x_glob[k])} to "
                f"output {int(y_glob[k])}, but the BMMC maps it to "
                f"{int(tab[x_glob[k]])} (swapped/corrupted descriptor)")


def audit_block_plan(plan: BlockPlan) -> None:
    n, b = plan.n, plan.b
    n_rows = 1 << (n - b)
    where = f"BlockPlan(n={n}, b={b})"
    src = np.asarray(plan.src_rows)
    if src.shape != (n_rows,):
        raise DescriptorOOB(f"{where}: src_rows shape {src.shape} != "
                            f"expected {(n_rows,)}")
    _bounds("src_rows", src, 0, n_rows, where)
    if np.unique(src).size != src.size:
        raise DescriptorOOB(f"{where}: src_rows is not a permutation of "
                            f"the {n_rows} blocks")
    tab = _bmmc_table(plan.bmmc)
    blk = 1 << b
    g = np.arange(n_rows, dtype=np.int64)
    offs = sorted({0, 1 % blk, blk // 2, blk - 1})
    for off in offs:
        got = tab[src.astype(np.int64) * blk + off]
        want = g * blk + off
        bad = got != want
        if bad.any():
            k = int(np.argmax(bad))
            raise DescriptorOOB(
                f"{where}: block {k} reads input block {int(src[k])}, "
                f"but the BMMC maps element {int(src[k]) * blk + off} to "
                f"{int(got[k])}, not {int(want[k])}")


def audit_lane_plan(plan: LanePlan) -> None:
    n, t = plan.n, plan.t
    row_len = 1 << t
    where = f"LanePlan(n={n}, t={t})"
    src = np.asarray(plan.src_lane)
    if src.shape != (row_len,):
        raise DescriptorOOB(f"{where}: src_lane shape {src.shape} != "
                            f"expected {(row_len,)}")
    _bounds("src_lane", src, 0, row_len, where)
    if np.unique(src).size != src.size:
        raise DescriptorOOB(f"{where}: src_lane is not a permutation of "
                            f"the {row_len} lanes")
    tab = _bmmc_table(plan.bmmc)
    lane = np.arange(row_len, dtype=np.int64)
    for row in sorted({0, plan.n_rows // 2, plan.n_rows - 1}):
        got = tab[row * row_len + src.astype(np.int64)]
        want = row * row_len + lane
        bad = got != want
        if bad.any():
            k = int(np.argmax(bad))
            raise DescriptorOOB(
                f"{where}: row {row} lane {k} reads lane {int(src[k])}, "
                f"but the BMMC maps it to {int(got[k])}, not "
                f"{int(want[k])}")


def _audit_compute_tables(ct, plan: TilePlan, where: str) -> None:
    """Shape audit of one epilogue's parity/twiddle tables (the
    truncated-parity-table corruption class)."""
    rpt, row_len, n_tiles = (plan.rows_per_tile, plan.row_len, plan.n_tiles)
    want = {"hi_row": (rpt,), "hi_lane": (row_len,), "hi_base": (n_tiles,),
            "tw_row": (rpt,), "tw_lane": (row_len,), "tw_base": (n_tiles,)}
    for nm, shape in want.items():
        arr = getattr(ct, nm, None)
        if arr is None:
            continue
        got = np.asarray(arr).shape
        if got != shape:
            raise DescriptorOOB(
                f"{where}: epilogue {ct.kind} table {nm} shape {got} != "
                f"expected {shape} (truncated parity/twiddle table)")


# ---------------------------------------------------------------------------
# fingerprints (cache-poisoning detection)
# ---------------------------------------------------------------------------

def _fp_array(arr) -> int:
    a = np.ascontiguousarray(np.asarray(arr)).astype(np.uint64)
    idx = np.arange(a.size, dtype=np.uint64)
    with np.errstate(over="ignore"):
        mixed = (a.reshape(-1) + np.uint64(0x9E3779B97F4A7C15)) * (
            (idx << np.uint64(1)) | np.uint64(1))
    return int(np.bitwise_xor.reduce(mixed)) ^ (a.size << 1)


def plan_fingerprint(kernel: str, payload) -> int:
    """Position-sensitive XOR-fold over every table of a class-dispatch
    payload — swapping two entries changes it, unlike a plain XOR."""
    fp = hash(kernel) & 0xFFFFFFFF
    if kernel == "block":
        return fp ^ _fp_array(payload.src_rows)
    if kernel == "lane":
        return fp ^ _fp_array(payload.src_lane)
    if kernel == "none":
        return fp
    for plan in payload:
        for arr in (plan.in_rows, plan.out_rows, plan.xor_low, plan.src0):
            fp ^= _fp_array(arr)
    return fp


def _record_fp(key, fp: int) -> None:
    with _FP_LOCK:
        _FINGERPRINTS[key] = fp


def check_fingerprints(prog, t) -> list:
    """Re-hash the LIVE plan caches of every stage against the
    fingerprints recorded at validation; returns the mismatched keys
    (non-empty == the cache was mutated after ring 1 signed off)."""
    from ..combinators.optimize import FusedStage
    from ..combinators import execute as _ex
    from ..kernels import ops

    poisoned = []
    for st in prog:
        if isinstance(st, FusedStage):
            key = ("fused", st, t)
            got = _ex._fused_plan_cached(st, t)
            if got is None:
                continue
            plans, entries = got
            fp = 0
            for p in plans:
                fp ^= plan_fingerprint("tiled", (p,)) ^ hash("tiled")
            fp ^= hash("tiled")  # fold the per-call kernel hash back in
        elif hasattr(st, "bmmc"):
            key = ("class", st.bmmc.rows, st.bmmc.c, t)
            kernel, payload = ops.class_plan(st.bmmc, t)
            fp = plan_fingerprint(kernel, payload)
        else:
            continue
        with _FP_LOCK:
            want = _FINGERPRINTS.get(key)
        if want is not None and want != fp:
            poisoned.append(key)
    return poisoned


# ---------------------------------------------------------------------------
# dispatch + whole-program validation (cached)
# ---------------------------------------------------------------------------

def _audit_payload(bmmc: Bmmc, t: int, kernel: str, payload) -> None:
    if kernel == "block":
        if not isinstance(payload, BlockPlan):
            raise ClassMismatch(
                f"kernel 'block' carries a {type(payload).__name__} "
                f"payload, expected BlockPlan")
        if bmmc.block_bits() < payload.b:
            raise ClassMismatch(
                f"plan dispatched as 'block' (b={payload.b}) but the "
                f"matrix is only block-granular to "
                f"{bmmc.block_bits()} bits")
        audit_block_plan(payload)
    elif kernel == "lane":
        if not isinstance(payload, LanePlan):
            raise ClassMismatch(
                f"kernel 'lane' carries a {type(payload).__name__} "
                f"payload, expected LanePlan")
        if not (bmmc.is_lane_local(t) or
                (bmmc.is_complement_only() and bmmc.c >> t == 0)):
            raise ClassMismatch(
                f"plan dispatched as 'lane' but the matrix is not "
                f"lane-local at t={t}")
        audit_lane_plan(payload)
    elif kernel != "none":
        for plan in payload:
            if not isinstance(plan, TilePlan):
                raise ClassMismatch(
                    f"kernel {kernel!r} pass carries a "
                    f"{type(plan).__name__}, expected TilePlan")
            audit_tile_plan(plan)


@functools.lru_cache(maxsize=512)
def validate_dispatch(rows: tuple, c: int, t: int) -> str:
    """Prove the cached class-dispatch decision for ``(bmmc, t)``:
    re-derive the kernel from the matrix, check the payload satisfies
    the class predicate, audit its tables, and record the fingerprint.
    Returns the kernel name."""
    from ..core.tiling import dispatch_kernel
    from ..kernels import ops

    # build without __post_init__ so a singular matrix reaches the rank
    # check here and raises the typed NotInvertible, not a bare error
    bmmc = Bmmc.__new__(Bmmc)
    object.__setattr__(bmmc, "rows", tuple(rows))
    object.__setattr__(bmmc, "c", c)
    verify_bmmc(bmmc)
    kernel, payload = ops.class_plan(bmmc, t)
    fresh = dispatch_kernel(bmmc, t)
    if kernel != fresh:
        raise ClassMismatch(
            f"cached dispatch says kernel {kernel!r} for this matrix at "
            f"t={t}, but re-deriving from the matrix gives {fresh!r} "
            f"(stale or poisoned class-plan cache)")
    _audit_payload(bmmc, t, kernel, payload)
    _record_fp(("class", rows, c, t), plan_fingerprint(kernel, payload))
    return kernel


def _validate_fused(fs, t: int) -> None:
    from ..combinators import execute as _ex
    from ..combinators.optimize import _run_fused

    verify_bmmc(fs.bmmc)
    recomposed = _run_fused(fs.stages, fs.bmmc.n)
    if recomposed.bmmc != fs.bmmc:
        raise ClassMismatch(
            f"FusedStage composed BMMC {fs.bmmc!r} does not equal the "
            f"recomposition of its member stages {recomposed.bmmc!r} "
            f"(fold-free/cluster bookkeeping drift)")
    got = _ex._fused_plan_cached(fs, t)
    if got is None:
        return  # megakernel rejects it; executor replays per stage
    plans, entries = got
    fp = 0
    for p in plans:
        verify_bmmc(p.bmmc)
        audit_tile_plan(p)
        fp ^= plan_fingerprint("tiled", (p,)) ^ hash("tiled")
    fp ^= hash("tiled")
    where = f"FusedStage(n={fs.bmmc.n}, t={t})"
    for e in entries:
        if e[0] in ("cmp", "bfly"):
            _audit_compute_tables(e[2], plans[0], where)
    _record_fp(("fused", fs, t), fp)


@functools.lru_cache(maxsize=1024)
def validate_program(prog: tuple, t) -> int:
    """Ring-1 entry point: prove every stage of a resolved program
    before its plans are trusted (cached per ``(program, t)`` — one
    validation pass per compiled program, not per call). Returns the
    number of stages audited."""
    from ..combinators.ir import Perm
    from ..combinators.optimize import FusedStage

    audited = 0
    for si, st in enumerate(prog):
        try:
            if isinstance(st, Perm):
                verify_bmmc(st.bmmc)
                if t is not None:
                    validate_dispatch(st.bmmc.rows, st.bmmc.c, t)
                audited += 1
            elif isinstance(st, FusedStage):
                if t is not None:
                    _validate_fused(st, t)
                else:
                    verify_bmmc(st.bmmc)
                audited += 1
        except (NotInvertible, ClassMismatch, DescriptorOOB, BadInput,
                CachePoisoned) as e:
            e.args = (f"stage {si}/{len(prog)} "
                      f"({type(st).__name__}): {e.args[0]}",) + e.args[1:]
            raise
    return audited


class IdentityMemo:
    """Bounded identity-keyed front memo with LRU eviction.

    Keys on ``id(owner)`` and stores a strong reference to the owner,
    so a stale id can never alias a different (garbage-collected)
    object: :meth:`lookup`'s ``is`` check proves the key still names
    the memoized owner. Bounded (``maxsize``, least-recently-used out
    first) so a long-lived serving process sweeping many programs does
    not grow without limit — the strong owner references would
    otherwise pin every program ever validated."""

    def __init__(self, maxsize: int):
        import collections
        self.maxsize = maxsize
        self._d: "collections.OrderedDict" = collections.OrderedDict()
        self.hits = 0
        self.misses = 0

    def lookup(self, key, owner):
        hit = self._d.get(key)
        if hit is not None and hit[0] is owner:
            self._d.move_to_end(key)
            self.hits += 1
            return hit[1]
        self.misses += 1
        return None

    def store(self, key, owner, value) -> None:
        self._d[key] = (owner, value)
        self._d.move_to_end(key)
        while len(self._d) > self.maxsize:
            self._d.popitem(last=False)

    def clear(self) -> None:
        self._d.clear()
        self.hits = self.misses = 0

    def __len__(self) -> int:
        return len(self._d)

    def cache_info(self) -> tuple:
        """(hits, misses, maxsize, currsize) — the lru_cache vocabulary,
        so ``cache_stats()`` folds these in uniformly."""
        return (self.hits, self.misses, self.maxsize, len(self._d))


# Identity-keyed front memo over validate_program. Resolved program
# tuples are themselves lru-cached (execute._clustered_cached), so the
# same object arrives on every warm call — but hashing the deep
# (stages × BMMC-rows) lru key costs tens of µs per lookup, which alone
# would blow the ≤5% warm-overhead budget on small programs.
_VALIDATED_FAST = IdentityMemo(maxsize=2048)


def validate_program_fast(prog: tuple, t) -> None:
    key = (id(prog), t)
    if _VALIDATED_FAST.lookup(key, prog) is None:
        validate_program(prog, t)
        _VALIDATED_FAST.store(key, prog, True)


# ---------------------------------------------------------------------------
# cache hygiene
# ---------------------------------------------------------------------------

def guard_cache_stats() -> dict:
    """Guard-cache stats in the executor's ``CacheStats`` vocabulary —
    merged into :func:`repro.combinators.execute.cache_stats`."""
    out = {"guard_validate": validate_program.cache_info(),
           "guard_dispatch": validate_dispatch.cache_info(),
           "guard_validate_fast": _VALIDATED_FAST.cache_info()}
    from . import runtime as _rt
    out["guard_program"] = _rt._guarded_executable.cache_info()
    out["guard_permute"] = _rt._guarded_permute_executable.cache_info()
    out["guard_exec_memo"] = _rt._EXEC_MEMO.cache_info()
    return out


def clear_guard_caches() -> None:
    validate_program.cache_clear()
    validate_dispatch.cache_clear()
    _VALIDATED_FAST.clear()
    with _FP_LOCK:
        _FINGERPRINTS.clear()
    from . import runtime as _rt
    _rt._guarded_executable.cache_clear()
    _rt._guarded_permute_executable.cache_clear()
    _rt._EXEC_MEMO.clear()
