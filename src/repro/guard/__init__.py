"""Validated execution: three guard rings around the permutation engine.

DESIGN.md §14. The correctness story of the whole stack rests on two
families of invariants the planner historically *assumed*: every BMMC
is invertible over F2, and every offline table (tile plans, DMA maps,
gather tables, parity tables) stays inside the geometry it addresses.
This package makes those invariants *enforced*:

* **Ring 1 — plan time, always on** (:mod:`.validate`): before a
  compiled program (or a standalone class-dispatch plan) is trusted,
  its invariants are proved — F2 rank of every matrix, class-predicate
  consistency of every fast-path dispatch, descriptor-bounds + semantic
  audit of every tile/DMA table, recorded XOR fingerprints for later
  poisoning detection. Failures raise the typed taxonomy in
  :mod:`.errors` (``NotInvertible`` / ``ClassMismatch`` /
  ``DescriptorOOB`` / ``BadInput`` / ``CachePoisoned`` …), each keeping
  its backward-compatible builtin base. Validation is cached, so the
  always-on ring costs one pass per (program, tile) — never per call.

* **Ring 2 — run time, opt-in, no host sync in the program**
  (:mod:`.runtime`): ``enable()`` (or ``REPRO_GUARD=1`` in the
  environment) switches :class:`repro.combinators.execute.CompiledExpr`
  and :func:`repro.kernels.ops.bmmc_permute` to guarded dispatch:
  checkify-style error *flags* — an OOB descriptor trap, a NaN/Inf
  sentinel on compute epilogues, and an XOR-parity round-trip probe
  (``apply ∘ inverse`` collapsed offline to a sampled-slice gather
  compare) — are computed *inside* the jitted program and accumulate
  into one int32 error value resolved only at the API edge. On a
  trapped pallas fault the call degrades gracefully to the ref engine
  (``guard.trap{kind}`` / ``guard.fallback{engine}`` counters) and
  fails loudly — :class:`~.errors.GuardTrap` — only if the fallback
  traps too.

* **Ring 3 — test time** (:mod:`.inject`): a fault-injection harness
  that deliberately corrupts each layer (bit-flip a BMMC row, swap
  descriptor entries, poison a cached plan, truncate a parity table,
  feed malformed inputs) so the suite can assert every corruption class
  is *caught* — typed error or recovered fallback — never silently
  wrong.
"""
from __future__ import annotations

import os
import threading

from .errors import (BadInput, BadStage, CachePoisoned, ClassMismatch,
                     DescriptorOOB, GuardError, GuardTrap, NotInvertible,
                     UnknownEngine)

_state = threading.local()
_STATS_LOCK = threading.Lock()
_STATS: dict = {"traps": {}, "fallbacks": {}, "recovered": 0, "raised": {},
                "store_quarantined": {}}

_ENV_FLAG = os.environ.get("REPRO_GUARD", "").strip().lower() in (
    "1", "true", "on", "yes")
_enabled = _ENV_FLAG


def enable() -> None:
    """Turn on ring-2 guarded dispatch for subsequent calls."""
    global _enabled
    _enabled = True


def disable() -> None:
    global _enabled
    _enabled = False


def enabled() -> bool:
    """Is ring-2 guarded dispatch active (``enable()`` or
    ``REPRO_GUARD=1``)?"""
    return _enabled


class guarded:
    """Context manager: guards on inside the block, restored after."""

    def __enter__(self):
        self._prev = _enabled
        enable()
        return self

    def __exit__(self, *exc):
        global _enabled
        _enabled = self._prev
        return False


def _record_trap(kind: str, engine: str) -> None:
    from ..obs import metrics as _om
    with _STATS_LOCK:
        k = (kind, engine)
        _STATS["traps"][k] = _STATS["traps"].get(k, 0) + 1
    _om.inc("guard.trap", kind=kind, engine=engine)


def _record_fallback(engine: str) -> None:
    from ..obs import metrics as _om
    with _STATS_LOCK:
        _STATS["fallbacks"][engine] = _STATS["fallbacks"].get(engine, 0) + 1
    _om.inc("guard.fallback", engine=engine)


def _record_recovered() -> None:
    from ..obs import metrics as _om
    with _STATS_LOCK:
        _STATS["recovered"] += 1
    _om.inc("guard.recovered")


def _record_raised(err: BaseException) -> None:
    from ..obs import metrics as _om
    name = type(err).__name__
    with _STATS_LOCK:
        _STATS["raised"][name] = _STATS["raised"].get(name, 0) + 1
    _om.inc("guard.raised", error=name)


def _record_store_quarantine(reason: str) -> None:
    """Mirror of the plan store's quarantine events: a quarantined disk
    entry IS a CachePoisoned detection, so it shows up in the guard
    report alongside traps and fallbacks (DESIGN.md §15)."""
    with _STATS_LOCK:
        q = _STATS["store_quarantined"]
        q[reason] = q.get(reason, 0) + 1


def stats() -> dict:
    """Guard-subsystem counters (always recorded while guards are on,
    independent of :mod:`repro.obs` being enabled): per-(kind, engine)
    trap counts, per-engine fallback counts, recovered-request count,
    per-type raised-error counts, and per-reason plan-store quarantine
    counts (mirrored from :func:`repro.store.stats`)."""
    with _STATS_LOCK:
        return {"traps": dict(_STATS["traps"]),
                "fallbacks": dict(_STATS["fallbacks"]),
                "recovered": _STATS["recovered"],
                "raised": dict(_STATS["raised"]),
                "store_quarantined": dict(_STATS["store_quarantined"])}


def reset_stats() -> None:
    with _STATS_LOCK:
        _STATS["traps"].clear()
        _STATS["fallbacks"].clear()
        _STATS["raised"].clear()
        _STATS["store_quarantined"].clear()
        _STATS["recovered"] = 0


from .validate import (  # noqa: E402  (needs the state above)
    audit_block_plan, audit_lane_plan, audit_tile_plan, clear_guard_caches,
    guard_cache_stats, plan_fingerprint, validate_dispatch, validate_input,
    validate_program, verify_bmmc)
from .runtime import (  # noqa: E402
    TRAP_KINDS, guarded_bmmc_permute, guarded_call, resolve_flags)

__all__ = [
    "GuardError", "NotInvertible", "ClassMismatch", "DescriptorOOB",
    "BadInput", "BadStage", "UnknownEngine", "CachePoisoned", "GuardTrap",
    "enable", "disable", "enabled", "guarded", "stats", "reset_stats",
    "verify_bmmc", "validate_dispatch", "validate_program",
    "validate_input", "audit_tile_plan", "audit_block_plan",
    "audit_lane_plan", "plan_fingerprint", "guard_cache_stats",
    "clear_guard_caches", "guarded_call", "resolve_flags",
    "guarded_bmmc_permute", "TRAP_KINDS",
]
