"""Ring 3 — fault injection (test time; DESIGN.md §14).

Each injector deliberately corrupts ONE layer of the stack the way a
real defect would — a flipped bit in a matrix row, a swapped pair of
descriptor entries, a cache whose tables were mutated in place, a
truncated parity table, a malformed input — and restores the original
state on exit. The harness (:func:`run_fault_matrix`) drives every
corruption class against a guarded engine and reports, per fault,
whether the stack *caught* it: a typed :class:`~.errors.GuardError`, or
a recovered engine fallback whose result still bitwise-matches the
oracle. A fault that produces a silently wrong output is the one
outcome the suite must never see.

Corruption mechanics worth noting:

* ``corrupt_bmmc`` bypasses ``Bmmc.__post_init__`` (via ``__new__`` +
  ``object.__setattr__``) exactly because the constructor would reject
  a singular matrix — the injected object models a matrix corrupted
  *after* construction (bit flip in a cached row).
* ``swap_descriptors`` / ``poison_plan`` mutate the *cached* numpy
  tables in place — the same arrays every future trace bakes in — so
  they model cache poisoning, not a planner bug. Both restore the
  original bytes on exit.
* ``truncate_parity_table`` shrinks a fused epilogue's per-lane parity
  table through ``object.__setattr__`` on the frozen dataclass.
"""
from __future__ import annotations

import contextlib

import numpy as np

from ..core.bmmc import Bmmc
from .errors import GuardError

STORE_FAULT_KINDS = ("disk_truncate", "disk_bitflip", "disk_version_skew",
                     "disk_torn_write", "disk_quarantine_race")

FAULT_KINDS = ("bitflip_bmmc", "swap_descriptor", "poison_cache",
               "truncate_parity_table", "bad_input") + STORE_FAULT_KINDS


def corrupt_bmmc(bmmc: Bmmc) -> Bmmc:
    """A bit-flipped copy of ``bmmc`` that is singular over F2 (row 0
    XORed into row 1 makes them sum to zero), built WITHOUT running
    ``__post_init__`` — modeling a matrix corrupted after construction."""
    rows = list(bmmc.rows)
    rows[1] = rows[0]            # two equal rows: rank < n
    bad = Bmmc.__new__(Bmmc)
    object.__setattr__(bad, "rows", tuple(rows))
    object.__setattr__(bad, "c", bmmc.c)
    return bad


def _payload_tables(kernel: str, payload) -> list:
    """The in-place-mutable numpy tables of one class-dispatch payload,
    with their exclusive index bounds."""
    if kernel == "block":
        return [(payload.src_rows, payload.n_rows)]
    if kernel == "lane":
        return [(payload.src_lane, 1 << payload.t)]
    if kernel == "none":
        return []
    out = []
    for plan in payload:
        out.append((plan.src0, plan.rows_per_tile * plan.row_len))
    return out


def _cached_tables(bmmc: Bmmc, t: int) -> list:
    from ..kernels import ops

    kernel, payload = ops.class_plan(bmmc, t)
    tables = _payload_tables(kernel, payload)
    if not tables:
        raise ValueError(f"kernel {kernel!r} has no table to corrupt")
    return tables


@contextlib.contextmanager
def swap_descriptors(bmmc: Bmmc, t: int):
    """Swap the first and last entry of the cached plan's main gather
    table IN PLACE (stays in-bounds: only the semantic audit or the
    runtime parity probe can see it). Restores on exit."""
    tab, _ = _cached_tables(bmmc, t)[0]
    flat = tab.reshape(-1)
    a, b = int(flat[0]), int(flat[-1])
    if a == b:
        raise ValueError("degenerate table: swap would be a no-op")
    flat[0], flat[-1] = b, a
    try:
        yield tab
    finally:
        flat[0], flat[-1] = a, b


@contextlib.contextmanager
def poison_plan(bmmc: Bmmc, t: int):
    """Overwrite one cached descriptor with an out-of-range index —
    the corruption the in-program OOB trap exists for. Restores on
    exit."""
    tab, bound = _cached_tables(bmmc, t)[0]
    flat = tab.reshape(-1)
    orig = int(flat[0])
    flat[0] = bound + 7
    try:
        yield tab
    finally:
        flat[0] = orig


@contextlib.contextmanager
def poison_ref_table(bmmc: Bmmc):
    """Overwrite one entry of the ref engine's cached gather table with
    an out-of-range index (the ref twin of :func:`poison_plan`).
    Restores on exit."""
    from ..kernels import ref as _ref

    tab = _ref._src_table(bmmc.rows, bmmc.c)
    orig = int(tab[0])
    tab[0] = bmmc.size + 7
    try:
        yield tab
    finally:
        tab[0] = orig


@contextlib.contextmanager
def truncate_parity_table(fs, t: int):
    """Truncate a fused epilogue's per-lane parity table to half length
    through the frozen dataclass — ring 1's shape audit must refuse the
    plan. ``fs`` is a compute-bearing FusedStage."""
    from ..combinators import execute as _ex

    got = _ex._fused_plan_cached(fs, t)
    if got is None:
        raise ValueError("cluster has no fused plan at this t")
    entries = got[1]
    cts = [e[2] for e in entries if e[0] in ("cmp", "bfly")]
    if not cts:
        raise ValueError("cluster has no parity-table-bearing epilogue")
    ct = cts[0]
    orig = ct.hi_lane
    object.__setattr__(ct, "hi_lane", np.ascontiguousarray(
        orig[:max(1, orig.size // 2)]))
    try:
        yield ct
    finally:
        object.__setattr__(ct, "hi_lane", orig)


# ---------------------------------------------------------------------------
# disk faults (the durable plan store; DESIGN.md §15)
# ---------------------------------------------------------------------------

def _skewed_entry(data: bytes) -> bytes:
    """Re-sign ``data``'s header with a bumped schema version — an
    *intact* entry from a different planner generation, the one fault
    class that must read as a miss, never a quarantine."""
    import json
    import struct

    from ..store import codec as _codec

    hlen, _ = struct.unpack_from(_codec._HEADER_FMT, data, len(_codec.MAGIC))
    hj = data[_codec._PREFIX_LEN:_codec._PREFIX_LEN + hlen]
    header = json.loads(hj)
    header["schema"] = header["schema"] + 1
    hj2 = json.dumps(header, sort_keys=True).encode("utf-8")
    return b"".join((
        _codec.MAGIC,
        struct.pack(_codec._HEADER_FMT, len(hj2), _codec._fp_bytes(hj2)),
        hj2, data[_codec._PREFIX_LEN + hlen:]))


@contextlib.contextmanager
def corrupt_store_entry(st, key: str, mode: str):
    """Corrupt one on-disk entry the way a real disk fault would:
    ``truncate`` (short file), ``bitflip`` (one payload bit), ``skew``
    (intact entry, older schema), ``torn`` (a partial write that landed
    at the final path — what the tmp+fsync+rename protocol prevents the
    store itself from ever producing). The CLEAN bytes are written back
    on exit, whether or not the corrupt entry was quarantined and
    rebuilt in between."""
    path = st.path_for(key)
    with open(path, "rb") as f:
        clean = f.read()
    if mode == "truncate":
        bad = clean[:max(1, len(clean) // 3)]
    elif mode == "bitflip":
        flipped = clean[-1] ^ 0x10            # last payload byte
        bad = clean[:-1] + bytes([flipped])
    elif mode == "skew":
        bad = _skewed_entry(clean)
    elif mode == "torn":
        bad = clean[:len(clean) // 2][:200]   # torn mid-header
    else:
        raise ValueError(f"unknown corruption mode {mode!r}")
    with open(path, "wb") as f:
        f.write(bad)
    try:
        yield path
    finally:
        st.write_bytes(key, clean)


def _clear_replan_path():
    """Clear every in-process cache between a disk corruption and the
    next call, so the executor's next plan lookup genuinely reaches the
    store: plan lrus, kernel/program executables (tables are baked into
    traces), and the guard caches (ring 1 re-proves on the reload)."""
    from ..combinators import execute as _ex
    from ..kernels import ops

    ops._class_plan_cached.cache_clear()
    ops._plans_cached.cache_clear()
    _ex._fused_plan_cached.cache_clear()
    _ex._program_executable.cache_clear()
    _ex._geom_executable.cache_clear()
    _ex._block_executable.cache_clear()
    _ex._lane_executable.cache_clear()
    _fresh_guard_state()


def run_disk_fault_matrix(n: int = 6) -> dict:
    """Inject every disk-fault class against a store-backed pallas
    engine and report ``{injected, caught, cases}`` in the
    :func:`run_fault_matrix` vocabulary. A fault is caught when the
    degradation ladder holds: the corruption is *detected* (quarantine
    + ``CachePoisoned`` classification, or a version-skew miss), the
    call recovers bitwise-equal to fresh planning, and a racing
    quarantine resolves exactly once. Always drives the pallas engine —
    the store holds pallas plans; the ref engine never consults it."""
    import tempfile
    import threading

    import jax.numpy as jnp

    from .. import store as _store
    from ..combinators import vocab as V
    from ..combinators.execute import compile_expr
    from ..kernels import ops, ref as _ref

    x = jnp.arange(1 << n, dtype=jnp.float32)
    bmmc = Bmmc.bit_reverse(n)
    t = ops.choose_tile(n, 4)
    oracle = np.asarray(_ref.bmmc_ref(x, bmmc))
    cases = []

    def record(kind, caught, how):
        cases.append({"kind": kind, "caught": bool(caught), "how": how})

    prev = _store.active()
    root = tempfile.mkdtemp(prefix="repro-store-fault-")
    try:
        st = _store.configure(root)
        _clear_replan_path()
        ce = compile_expr(V.bit_reverse(n), engine="pallas", optimize=False)
        ce(x)  # populate the store
        key = _store.class_key(bmmc.rows, bmmc.c, t)
        if _store.active().read_bytes(key) is None:
            raise RuntimeError("store population failed: no entry for key")

        for kind, mode in (("disk_truncate", "truncate"),
                           ("disk_bitflip", "bitflip"),
                           ("disk_version_skew", "skew"),
                           ("disk_torn_write", "torn")):
            base = _store.stats()
            try:
                with corrupt_store_entry(st, key, mode):
                    _clear_replan_path()
                    y = ce(x)
                now = _store.stats()
                ok = np.array_equal(np.asarray(y), oracle)
                if mode == "skew":
                    detected = (now["version_skew"] > base["version_skew"]
                                and now["quarantined"] == base["quarantined"])
                    hownote = "skew-miss + replanned"
                else:
                    detected = now["quarantined"] > base["quarantined"]
                    hownote = "quarantined + replanned"
                record(kind, ok and detected,
                       hownote if ok and detected
                       else ("not detected" if ok
                             else "SILENT WRONG OUTPUT"))
            except GuardError as e:
                record(kind, True, type(e).__name__)

        # racing readers on one corrupt entry: every reader must detect
        # and rebuild correctly; the quarantine rename resolves ONCE
        base = _store.stats()
        fresh = ops._build_class_plan(bmmc.rows, bmmc.c, t)
        try:
            with corrupt_store_entry(st, key, "bitflip"):
                _clear_replan_path()
                results, errs = [], []

                def reader():
                    try:
                        results.append(_store.class_plan_through(
                            bmmc.rows, bmmc.c, t,
                            lambda: ops._build_class_plan(
                                bmmc.rows, bmmc.c, t)))
                    except BaseException as e:  # noqa: BLE001
                        errs.append(e)

                threads = [threading.Thread(target=reader)
                           for _ in range(4)]
                for th in threads:
                    th.start()
                for th in threads:
                    th.join()
            from .validate import plan_fingerprint as _pfp
            now = _store.stats()
            want_fp = _pfp(*fresh)
            same = all(r[0] == fresh[0] and _pfp(*r) == want_fp
                       for r in results)
            quarantines = now["quarantined"] - base["quarantined"]
            ok = (not errs and len(results) == 4 and same
                  and quarantines == 1)
            record("disk_quarantine_race", ok,
                   "single quarantine, all readers recovered" if ok
                   else (f"errors={[type(e).__name__ for e in errs]} "
                         f"quarantines={quarantines}"))
        except GuardError as e:
            record("disk_quarantine_race", True, type(e).__name__)
    finally:
        _store.configure(prev.root if prev is not None else None)
        _clear_replan_path()

    caught = sum(1 for c in cases if c["caught"])
    return {"injected": len(cases), "caught": caught, "cases": cases}

def _fresh_guard_state():
    """Clear every cache a fault could hide behind: guard validation +
    guarded executables (so ring 1 re-proves and ring 2 re-bakes), and
    the resilience breaker board (a test's traps must not leave a
    condemned engine behind for the next test)."""
    from .. import resilience
    from . import validate as _v

    _v.clear_guard_caches()
    resilience.board().reset()


def _clear_runtime_only():
    """Keep ring-1 signatures warm but force the guarded executables to
    re-trace — modeling corruption that lands AFTER validation."""
    from . import runtime as _rt

    _rt._guarded_executable.cache_clear()
    _rt._guarded_permute_executable.cache_clear()
    _rt._EXEC_MEMO.clear()


def run_fault_matrix(engine: str = "pallas", n: int = 6) -> dict:
    """Inject every corruption class against a guarded ``engine`` and
    report ``{injected, caught, cases}``. Each case is caught when the
    stack raises a typed :class:`GuardError` subclass (plan-time
    detection) or recovers via engine fallback with a bitwise-correct
    result (run-time detection). A silently wrong output marks the case
    uncaught — the outcome this harness exists to rule out.
    """
    import jax.numpy as jnp

    from .. import guard as _g
    from ..combinators import vocab as V
    from ..combinators.execute import compile_expr
    from ..kernels import ops, ref as _ref

    x = jnp.arange(1 << n, dtype=jnp.float32)
    bmmc = Bmmc.bit_reverse(n)
    t = ops.choose_tile(n, 4)
    oracle = np.asarray(_ref.bmmc_ref(x, bmmc))
    cases = []

    def record(kind, caught, how):
        cases.append({"kind": kind, "caught": bool(caught), "how": how})

    with _g.guarded():
        # 1. bit-flipped BMMC row -> singular matrix -> NotInvertible
        bad = corrupt_bmmc(bmmc)
        try:
            from . import validate as _v
            _v.verify_bmmc(bad)
            record("bitflip_bmmc", False, "validated a singular matrix")
        except GuardError as e:
            record("bitflip_bmmc", True, type(e).__name__)

        # 2. swapped descriptor entries, in-bounds -> ring-1 semantic
        # audit (fresh validation) must refuse the plan
        ce = compile_expr(V.bit_reverse(n), engine=engine, optimize=False)
        ce(x)  # warm plans + caches
        _fresh_guard_state()
        try:
            with swap_descriptors(bmmc, t):
                y = ce(x)
                wrong = not np.array_equal(np.asarray(y), oracle)
                record("swap_descriptor", not wrong,
                       "fallback-recovered" if not wrong
                       else "SILENT WRONG OUTPUT")
        except GuardError as e:
            record("swap_descriptor", True, type(e).__name__)
        _fresh_guard_state()

        # 3. poisoned cache AFTER validation -> runtime OOB/parity trap
        # -> pallas degrades to ref and recovers (or typed error)
        ce(x)  # re-warm and re-validate the clean plans
        base = _g.stats()
        try:
            # poison the table the CHOSEN engine actually bakes in: the
            # ref gather table and the pallas plan caches are disjoint
            ctx = (poison_ref_table(bmmc) if engine == "ref"
                   else poison_plan(bmmc, t))
            with ctx:
                _clear_runtime_only()  # re-bake the poisoned tables
                y = ce(x)
            ok = np.array_equal(np.asarray(y), oracle)
            now = _g.stats()
            trapped = sum(now["traps"].values()) > sum(
                base["traps"].values())
            record("poison_cache", ok and trapped,
                   "fallback-recovered" if ok and trapped
                   else ("no trap recorded" if ok
                         else "SILENT WRONG OUTPUT"))
        except GuardError as e:
            record("poison_cache", True, type(e).__name__)
        finally:
            _fresh_guard_state()

        # 4. truncated parity table on a fused compute cluster -> ring-1
        # shape audit -> DescriptorOOB
        from ..combinators.sort import sort_expr
        sce = compile_expr(sort_expr(n), engine="pallas", optimize=True)
        xs = jnp.asarray(np.random.default_rng(0).standard_normal(1 << n),
                         dtype=jnp.float32)
        sce(xs)  # warm: builds the fused plans + compute tables
        prog, st = sce._resolve(xs, False)
        fused = [s for s in prog
                 if getattr(s, "computes", ())]
        try:
            if not fused:
                record("truncate_parity_table", False, "no cluster found")
            else:
                _fresh_guard_state()
                with truncate_parity_table(fused[0], st):
                    sce(xs)
                record("truncate_parity_table", False,
                       "validated a truncated table")
        except GuardError as e:
            record("truncate_parity_table", True, type(e).__name__)
        except ValueError as e:
            record("truncate_parity_table", False, f"inject failed: {e}")
        finally:
            _fresh_guard_state()

        # 5. malformed inputs: wrong length / missing axis -> BadInput
        try:
            ce(jnp.arange(24.0))
            record("bad_input", False, "accepted a non-power-of-2 input")
        except GuardError as e:
            record("bad_input", True, type(e).__name__)

    # 6-10. durable-store faults: truncation, bit flip, version skew,
    # torn write, quarantine race (ring-1-on-load; store-engine pallas)
    cases.extend(run_disk_fault_matrix(n=n)["cases"])

    caught = sum(1 for c in cases if c["caught"])
    return {"injected": len(cases), "caught": caught, "cases": cases}
