"""Ring 3 — fault injection (test time; DESIGN.md §14).

Each injector deliberately corrupts ONE layer of the stack the way a
real defect would — a flipped bit in a matrix row, a swapped pair of
descriptor entries, a cache whose tables were mutated in place, a
truncated parity table, a malformed input — and restores the original
state on exit. The harness (:func:`run_fault_matrix`) drives every
corruption class against a guarded engine and reports, per fault,
whether the stack *caught* it: a typed :class:`~.errors.GuardError`, or
a recovered engine fallback whose result still bitwise-matches the
oracle. A fault that produces a silently wrong output is the one
outcome the suite must never see.

Corruption mechanics worth noting:

* ``corrupt_bmmc`` bypasses ``Bmmc.__post_init__`` (via ``__new__`` +
  ``object.__setattr__``) exactly because the constructor would reject
  a singular matrix — the injected object models a matrix corrupted
  *after* construction (bit flip in a cached row).
* ``swap_descriptors`` / ``poison_plan`` mutate the *cached* numpy
  tables in place — the same arrays every future trace bakes in — so
  they model cache poisoning, not a planner bug. Both restore the
  original bytes on exit.
* ``truncate_parity_table`` shrinks a fused epilogue's per-lane parity
  table through ``object.__setattr__`` on the frozen dataclass.
"""
from __future__ import annotations

import contextlib

import numpy as np

from ..core.bmmc import Bmmc
from .errors import GuardError

FAULT_KINDS = ("bitflip_bmmc", "swap_descriptor", "poison_cache",
               "truncate_parity_table", "bad_input")


def corrupt_bmmc(bmmc: Bmmc) -> Bmmc:
    """A bit-flipped copy of ``bmmc`` that is singular over F2 (row 0
    XORed into row 1 makes them sum to zero), built WITHOUT running
    ``__post_init__`` — modeling a matrix corrupted after construction."""
    rows = list(bmmc.rows)
    rows[1] = rows[0]            # two equal rows: rank < n
    bad = Bmmc.__new__(Bmmc)
    object.__setattr__(bad, "rows", tuple(rows))
    object.__setattr__(bad, "c", bmmc.c)
    return bad


def _payload_tables(kernel: str, payload) -> list:
    """The in-place-mutable numpy tables of one class-dispatch payload,
    with their exclusive index bounds."""
    if kernel == "block":
        return [(payload.src_rows, payload.n_rows)]
    if kernel == "lane":
        return [(payload.src_lane, 1 << payload.t)]
    if kernel == "none":
        return []
    out = []
    for plan in payload:
        out.append((plan.src0, plan.rows_per_tile * plan.row_len))
    return out


def _cached_tables(bmmc: Bmmc, t: int) -> list:
    from ..kernels import ops

    kernel, payload = ops.class_plan(bmmc, t)
    tables = _payload_tables(kernel, payload)
    if not tables:
        raise ValueError(f"kernel {kernel!r} has no table to corrupt")
    return tables


@contextlib.contextmanager
def swap_descriptors(bmmc: Bmmc, t: int):
    """Swap the first and last entry of the cached plan's main gather
    table IN PLACE (stays in-bounds: only the semantic audit or the
    runtime parity probe can see it). Restores on exit."""
    tab, _ = _cached_tables(bmmc, t)[0]
    flat = tab.reshape(-1)
    a, b = int(flat[0]), int(flat[-1])
    if a == b:
        raise ValueError("degenerate table: swap would be a no-op")
    flat[0], flat[-1] = b, a
    try:
        yield tab
    finally:
        flat[0], flat[-1] = a, b


@contextlib.contextmanager
def poison_plan(bmmc: Bmmc, t: int):
    """Overwrite one cached descriptor with an out-of-range index —
    the corruption the in-program OOB trap exists for. Restores on
    exit."""
    tab, bound = _cached_tables(bmmc, t)[0]
    flat = tab.reshape(-1)
    orig = int(flat[0])
    flat[0] = bound + 7
    try:
        yield tab
    finally:
        flat[0] = orig


@contextlib.contextmanager
def poison_ref_table(bmmc: Bmmc):
    """Overwrite one entry of the ref engine's cached gather table with
    an out-of-range index (the ref twin of :func:`poison_plan`).
    Restores on exit."""
    from ..kernels import ref as _ref

    tab = _ref._src_table(bmmc.rows, bmmc.c)
    orig = int(tab[0])
    tab[0] = bmmc.size + 7
    try:
        yield tab
    finally:
        tab[0] = orig


@contextlib.contextmanager
def truncate_parity_table(fs, t: int):
    """Truncate a fused epilogue's per-lane parity table to half length
    through the frozen dataclass — ring 1's shape audit must refuse the
    plan. ``fs`` is a compute-bearing FusedStage."""
    from ..combinators import execute as _ex

    got = _ex._fused_plan_cached(fs, t)
    if got is None:
        raise ValueError("cluster has no fused plan at this t")
    entries = got[1]
    cts = [e[2] for e in entries if e[0] in ("cmp", "bfly")]
    if not cts:
        raise ValueError("cluster has no parity-table-bearing epilogue")
    ct = cts[0]
    orig = ct.hi_lane
    object.__setattr__(ct, "hi_lane", np.ascontiguousarray(
        orig[:max(1, orig.size // 2)]))
    try:
        yield ct
    finally:
        object.__setattr__(ct, "hi_lane", orig)


# ---------------------------------------------------------------------------
# the injection harness
# ---------------------------------------------------------------------------

def _fresh_guard_state():
    """Clear every cache a fault could hide behind: guard validation +
    guarded executables (so ring 1 re-proves and ring 2 re-bakes)."""
    from . import validate as _v

    _v.clear_guard_caches()


def _clear_runtime_only():
    """Keep ring-1 signatures warm but force the guarded executables to
    re-trace — modeling corruption that lands AFTER validation."""
    from . import runtime as _rt

    _rt._guarded_executable.cache_clear()
    _rt._guarded_permute_executable.cache_clear()
    _rt._EXEC_MEMO.clear()


def run_fault_matrix(engine: str = "pallas", n: int = 6) -> dict:
    """Inject every corruption class against a guarded ``engine`` and
    report ``{injected, caught, cases}``. Each case is caught when the
    stack raises a typed :class:`GuardError` subclass (plan-time
    detection) or recovers via engine fallback with a bitwise-correct
    result (run-time detection). A silently wrong output marks the case
    uncaught — the outcome this harness exists to rule out.
    """
    import jax.numpy as jnp

    from .. import guard as _g
    from ..combinators import vocab as V
    from ..combinators.execute import compile_expr
    from ..kernels import ops, ref as _ref

    x = jnp.arange(1 << n, dtype=jnp.float32)
    bmmc = Bmmc.bit_reverse(n)
    t = ops.choose_tile(n, 4)
    oracle = np.asarray(_ref.bmmc_ref(x, bmmc))
    cases = []

    def record(kind, caught, how):
        cases.append({"kind": kind, "caught": bool(caught), "how": how})

    with _g.guarded():
        # 1. bit-flipped BMMC row -> singular matrix -> NotInvertible
        bad = corrupt_bmmc(bmmc)
        try:
            from . import validate as _v
            _v.verify_bmmc(bad)
            record("bitflip_bmmc", False, "validated a singular matrix")
        except GuardError as e:
            record("bitflip_bmmc", True, type(e).__name__)

        # 2. swapped descriptor entries, in-bounds -> ring-1 semantic
        # audit (fresh validation) must refuse the plan
        ce = compile_expr(V.bit_reverse(n), engine=engine, optimize=False)
        ce(x)  # warm plans + caches
        _fresh_guard_state()
        try:
            with swap_descriptors(bmmc, t):
                y = ce(x)
                wrong = not np.array_equal(np.asarray(y), oracle)
                record("swap_descriptor", not wrong,
                       "fallback-recovered" if not wrong
                       else "SILENT WRONG OUTPUT")
        except GuardError as e:
            record("swap_descriptor", True, type(e).__name__)
        _fresh_guard_state()

        # 3. poisoned cache AFTER validation -> runtime OOB/parity trap
        # -> pallas degrades to ref and recovers (or typed error)
        ce(x)  # re-warm and re-validate the clean plans
        base = _g.stats()
        try:
            # poison the table the CHOSEN engine actually bakes in: the
            # ref gather table and the pallas plan caches are disjoint
            ctx = (poison_ref_table(bmmc) if engine == "ref"
                   else poison_plan(bmmc, t))
            with ctx:
                _clear_runtime_only()  # re-bake the poisoned tables
                y = ce(x)
            ok = np.array_equal(np.asarray(y), oracle)
            now = _g.stats()
            trapped = sum(now["traps"].values()) > sum(
                base["traps"].values())
            record("poison_cache", ok and trapped,
                   "fallback-recovered" if ok and trapped
                   else ("no trap recorded" if ok
                         else "SILENT WRONG OUTPUT"))
        except GuardError as e:
            record("poison_cache", True, type(e).__name__)
        finally:
            _fresh_guard_state()

        # 4. truncated parity table on a fused compute cluster -> ring-1
        # shape audit -> DescriptorOOB
        from ..combinators.sort import sort_expr
        sce = compile_expr(sort_expr(n), engine="pallas", optimize=True)
        xs = jnp.asarray(np.random.default_rng(0).standard_normal(1 << n),
                         dtype=jnp.float32)
        sce(xs)  # warm: builds the fused plans + compute tables
        prog, st = sce._resolve(xs, False)
        fused = [s for s in prog
                 if getattr(s, "computes", ())]
        try:
            if not fused:
                record("truncate_parity_table", False, "no cluster found")
            else:
                _fresh_guard_state()
                with truncate_parity_table(fused[0], st):
                    sce(xs)
                record("truncate_parity_table", False,
                       "validated a truncated table")
        except GuardError as e:
            record("truncate_parity_table", True, type(e).__name__)
        except ValueError as e:
            record("truncate_parity_table", False, f"inject failed: {e}")
        finally:
            _fresh_guard_state()

        # 5. malformed inputs: wrong length / missing axis -> BadInput
        try:
            ce(jnp.arange(24.0))
            record("bad_input", False, "accepted a non-power-of-2 input")
        except GuardError as e:
            record("bad_input", True, type(e).__name__)

    caught = sum(1 for c in cases if c["caught"])
    return {"injected": len(cases), "caught": caught, "cases": cases}
