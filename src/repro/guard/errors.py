"""Typed guard-error taxonomy (DESIGN.md §14).

Every validation failure in the guarded-execution subsystem raises a
:class:`GuardError` subclass instead of a bare ``ValueError`` /
``TypeError`` / ``KeyError``, so callers (and the fault-injection
suite) can match on the *corruption class*, not on message text. Each
subclass keeps the backward-compatible builtin base the pre-guard code
raised at the same site — ``pytest.raises(ValueError)`` written against
the old executor still passes:

=================  ==========================  ===========================
error              builtin base                raised when
=================  ==========================  ===========================
NotInvertible      f2.SingularError/ValueError BMMC fails the F2 rank check
ClassMismatch      ValueError                  fast-path plan contradicts
                                               its class predicate
DescriptorOOB      IndexError                  tile/DMA table out of bounds
                                               or semantically wrong
BadInput           ValueError                  shape/dtype/planarity
                                               precondition on a program
                                               input fails
BadStage           TypeError                   non-primitive stage reached
                                               the executor
UnknownEngine      KeyError                    engine-name lookup miss
CachePoisoned      ValueError                  validated plan's fingerprint
                                               changed under the cache
GuardTrap          RuntimeError                runtime guard flags stayed
                                               set after every fallback
=================  ==========================  ===========================
"""
from __future__ import annotations

from ..core import f2


class GuardError(Exception):
    """Base of the validated-execution error taxonomy.

    Never raised directly — every guard failure is one of the typed
    subclasses below, each of which also subclasses the builtin the
    pre-guard code raised at the same site (backward compatibility).
    """


class NotInvertible(GuardError, f2.SingularError):
    """A BMMC matrix failed the plan-time F2 rank check.

    ``f2.SingularError`` is itself a ``ValueError``, so code catching
    either keeps working.
    """


class ClassMismatch(GuardError, ValueError):
    """A plan dispatched as a fast-path class (block / lane / ...) whose
    matrix does not actually satisfy that class predicate — e.g. a
    poisoned class-plan cache handing a general BMMC the block kernel.
    """


class DescriptorOOB(GuardError, IndexError):
    """A tile-plan / DMA descriptor table points outside the array
    geometry, or disagrees with the BMMC it claims to realize (swapped
    entries, truncated tables, out-of-range row ids)."""


class BadInput(GuardError, ValueError):
    """A program input violates a shape / dtype / planarity
    precondition (wrong axis length, non-power-of-2 size, complex input
    to a planar-only path, missing (re, im) trailing dim)."""


class BadStage(GuardError, TypeError):
    """A non-primitive (un-lowered) stage reached the stage executor."""


class UnknownEngine(GuardError, KeyError):
    """Engine-name lookup failed. Subclasses ``KeyError`` so pre-guard
    callers catching that keep working."""


class CachePoisoned(GuardError, ValueError):
    """A plan that passed ring-1 validation no longer matches its
    recorded XOR fingerprint — its cached tables were mutated after
    validation (the cache-poisoning corruption class)."""


class GuardTrap(GuardError, RuntimeError):
    """Runtime guard flags (OOB trap, non-finite sentinel, parity-probe
    mismatch) remained set after the last fallback engine — the request
    fails loudly instead of returning silently-wrong data.

    ``kinds`` names the trap kinds that fired; ``engine`` the last
    engine tried.
    """

    def __init__(self, kinds, engine):
        self.kinds = tuple(kinds)
        self.engine = engine
        super().__init__(
            f"guard trap(s) {sorted(self.kinds)} unrecovered on engine "
            f"{engine!r}; no fallback engine left")
