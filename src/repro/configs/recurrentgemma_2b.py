"""RecurrentGemma-2B [arXiv:2402.19427] — hybrid RG-LRU + local attention 1:2.

26 layers: 8 periods of (rec, rec, local-attn) + 2 trailing recurrent
layers; sliding window 2048; GQA kv=1 on the attention layers.
"""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="recurrentgemma-2b", family="hybrid",
    d_model=2560, n_heads=10, n_kv_heads=1, d_ff=7680, vocab_size=256000,
    pattern=("rec", "rec", "local"), n_periods=8, tail=("rec", "rec"),
    head_dim=256, window=2048, lru_width=2560,
    mlp="geglu", norm="rms", tie_embeddings=True,
    source="arXiv:2402.19427",
)
