"""Mistral-Nemo-12B [hf:mistralai/Mistral-Nemo-Base-2407] — dense GQA kv=8, 128k ctx."""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="mistral-nemo-12b", family="dense",
    d_model=5120, n_heads=32, n_kv_heads=8, d_ff=14336, vocab_size=131072,
    pattern=("dense",), n_periods=40,
    head_dim=128, rope_theta=1e6,
    mlp="swiglu", norm="rms",
    seq_parallel=True,  # Megatron-SP: see EXPERIMENTS.md §Perf hillclimb 4
    source="hf:mistralai/Mistral-Nemo-Base-2407",
)
