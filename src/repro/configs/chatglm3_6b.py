"""ChatGLM3-6B [arXiv:2406.12793; hf] — dense, GQA kv=2, 2d (partial) RoPE."""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="chatglm3-6b", family="dense",
    d_model=4096, n_heads=32, n_kv_heads=2, d_ff=13696, vocab_size=65024,
    pattern=("dense",), n_periods=28,
    head_dim=128, qkv_bias=True, rope_theta=1e4, rotary_frac=0.5,
    mlp="swiglu", norm="rms",
    seq_parallel=True,  # Megatron-SP: see EXPERIMENTS.md §Perf hillclimb 4
    source="arXiv:2406.12793",
)
