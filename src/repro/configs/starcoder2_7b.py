"""StarCoder2-7B [arXiv:2402.19173; hf] — dense, GQA kv=4, RoPE, GELU MLP."""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="starcoder2-7b", family="dense",
    d_model=4608, n_heads=36, n_kv_heads=4, d_ff=18432, vocab_size=49152,
    pattern=("dense",), n_periods=32,
    head_dim=128, qkv_bias=True, rope_theta=1e5,
    mlp="gelu", norm="ln", tie_embeddings=True,
    seq_parallel=True,  # Megatron-SP: see EXPERIMENTS.md §Perf hillclimb 4
    source="arXiv:2402.19173",
)
