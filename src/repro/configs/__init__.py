"""Architecture registry: --arch <id> -> ArchConfig."""
from .base import ArchConfig, ShapeConfig, SHAPES, reduce_for_smoke

from .starcoder2_7b import CONFIG as _starcoder2
from .mistral_nemo_12b import CONFIG as _nemo
from .qwen15_32b import CONFIG as _qwen
from .chatglm3_6b import CONFIG as _chatglm
from .llama32_vision_90b import CONFIG as _llama_v
from .recurrentgemma_2b import CONFIG as _rgemma
from .kimi_k2_1t import CONFIG as _kimi
from .phi35_moe_42b import CONFIG as _phi
from .mamba2_130m import CONFIG as _mamba2
from .seamless_m4t_medium import CONFIG as _seamless

ARCHS = {c.name: c for c in [
    _starcoder2, _nemo, _qwen, _chatglm, _llama_v,
    _rgemma, _kimi, _phi, _mamba2, _seamless,
]}


def get_config(name: str) -> ArchConfig:
    if name not in ARCHS:
        raise KeyError(f"unknown arch {name!r}; available: {sorted(ARCHS)}")
    return ARCHS[name]


def list_archs():
    return sorted(ARCHS)
