"""ArchConfig: declarative architecture description + shape registry.

Layer stacking is declared as ``prefix + pattern * n_periods + tail`` where
each entry is a block kind: "dense", "moe", "cross", "rec", "local",
"mamba", "enc", "dec". The repeating ``pattern`` is executed with
``lax.scan`` over stacked parameters (HLO size independent of depth).
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                       # dense | moe | ssm | hybrid | vlm | audio
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    # stack structure
    pattern: Tuple[str, ...] = ("dense",)
    n_periods: int = 0
    prefix: Tuple[str, ...] = ()
    tail: Tuple[str, ...] = ()
    # attention details
    head_dim: Optional[int] = None
    qkv_bias: bool = False
    rope_theta: float = 1e4
    rotary_frac: float = 1.0          # fraction of head_dim rotated (chatglm: 0.5)
    window: Optional[int] = None      # sliding-window size for "local" blocks
    head_shuffle: Optional[str] = None  # BMMC kv-head shuffle engine
    #   (None = off; "ref" | "pallas" route the shuffle through that
    #   combinator engine — semantically neutral, see models/attention.py)
    # mlp
    mlp: str = "swiglu"               # swiglu | gelu
    norm: str = "rms"                 # rms | ln
    tie_embeddings: bool = False
    # moe
    n_experts: int = 0
    top_k: int = 0
    moe_d_ff: int = 0
    n_shared_experts: int = 0
    capacity_factor: float = 1.25
    moe_impl: str = "gspmd"           # gspmd (capacity+all-reduce) | a2a (shard_map)
    # ssm
    ssm_state: int = 0
    ssm_headdim: int = 64
    ssm_conv: int = 4
    ssm_expand: int = 2
    ssm_chunk: int = 256
    # rg-lru
    lru_width: Optional[int] = None
    # enc-dec / multimodal stubs
    n_enc_periods: int = 0
    enc_pattern: Tuple[str, ...] = ("enc",)
    src_len: int = 0                  # audio frames / vision patches (stub frontend)
    # numerics
    dtype: object = jnp.bfloat16
    remat: bool = True
    remat_policy: str = "nothing"     # nothing | dots (save matmul outputs)
    seq_parallel: bool = False        # Megatron-SP activation sharding
    kv_block: int = 1024
    opt_bits: int = 32                # 8 => block-quantized AdamW moments
    # misc metadata
    source: str = ""

    # -- derived -------------------------------------------------------------
    @property
    def hd(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    @property
    def layer_kinds(self) -> Tuple[str, ...]:
        return self.prefix + self.pattern * self.n_periods + self.tail

    @property
    def n_layers(self) -> int:
        return len(self.layer_kinds)

    @property
    def is_encdec(self) -> bool:
        return self.n_enc_periods > 0

    @property
    def sub_quadratic(self) -> bool:
        """True if decode memory is o(seq): pure SSM / windowed hybrid."""
        kinds = set(self.layer_kinds)
        full_attn = {"dense", "moe", "cross", "dec", "enc"} & kinds
        return not full_attn

    def n_params(self) -> int:
        """Approximate parameter count (embedding + blocks)."""
        e, h = self.d_model, self.hd
        total = self.vocab_size * e * (1 if self.tie_embeddings else 2)
        for kind in self.layer_kinds:
            if kind in ("dense", "local", "enc"):
                total += self._attn_params() + self._mlp_params()
            elif kind == "moe":
                total += self._attn_params() + self._moe_params()
            elif kind in ("cross", "dec"):
                total += self._attn_params() * (2 if kind == "dec" else 1) + self._mlp_params()
                if kind == "cross":
                    total += self._attn_params()
            elif kind == "rec":
                w = self.lru_width or self.d_model
                total += 2 * e * w + 2 * w * w // 1 + w * e + self._mlp_params()
            elif kind == "mamba":
                di = self.ssm_expand * e
                g_n = self.ssm_state
                nh = di // self.ssm_headdim
                total += e * (2 * di + 2 * g_n + nh) + di * e
        return total

    def n_active_params(self) -> int:
        """Active params per token (MoE: only routed top_k + shared)."""
        if self.n_experts == 0:
            return self.n_params()
        e = self.d_model
        per_expert = 3 * e * self.moe_d_ff
        routed_total = self.n_experts * per_expert * self._n_moe_layers()
        routed_active = (self.top_k + self.n_shared_experts) * per_expert * self._n_moe_layers()
        return self.n_params() - routed_total + routed_active

    def _n_moe_layers(self) -> int:
        return sum(k == "moe" for k in self.layer_kinds)

    def _attn_params(self) -> int:
        e, h = self.d_model, self.hd
        return e * self.n_heads * h + 2 * e * self.n_kv_heads * h + self.n_heads * h * e

    def _mlp_params(self) -> int:
        mult = 3 if self.mlp == "swiglu" else 2
        return mult * self.d_model * self.d_ff

    def _moe_params(self) -> int:
        per = 3 * self.d_model * self.moe_d_ff
        return (self.n_experts + self.n_shared_experts) * per + self.d_model * self.n_experts


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str        # train | prefill | decode


SHAPES = {
    "train_4k": ShapeConfig("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524288, 1, "decode"),
}


def reduce_for_smoke(cfg: ArchConfig) -> ArchConfig:
    """Same family, tiny dims — for CPU smoke tests (one step, no NaNs)."""
    return dataclasses.replace(
        cfg,
        d_model=64,
        n_heads=4,
        n_kv_heads=min(cfg.n_kv_heads, 2) if cfg.n_kv_heads else 0,
        head_dim=16,
        d_ff=128,
        vocab_size=256,
        n_periods=min(cfg.n_periods, 2),
        prefix=cfg.prefix[:1],
        tail=cfg.tail[:1],
        n_experts=min(cfg.n_experts, 4) if cfg.n_experts else 0,
        top_k=min(cfg.top_k, 2) if cfg.top_k else 0,
        moe_d_ff=64 if cfg.moe_d_ff else 0,
        n_shared_experts=min(cfg.n_shared_experts, 1),
        ssm_state=min(cfg.ssm_state, 16) if cfg.ssm_state else 0,
        ssm_headdim=8 if cfg.ssm_state else 64,
        ssm_chunk=8,
        lru_width=64 if cfg.lru_width else None,
        window=min(cfg.window, 8) if cfg.window else None,
        n_enc_periods=min(cfg.n_enc_periods, 2),
        src_len=16 if cfg.src_len else 0,
        dtype=jnp.float32,
        remat=False,
        kv_block=8,
    )
