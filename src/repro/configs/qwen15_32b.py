"""Qwen1.5-32B [hf:Qwen] — dense, GQA kv=40 (MHA-width kv), QKV bias."""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="qwen1.5-32b", family="dense",
    d_model=5120, n_heads=40, n_kv_heads=40, d_ff=27392, vocab_size=152064,
    pattern=("dense",), n_periods=64,
    head_dim=128, qkv_bias=True, rope_theta=1e6,
    mlp="swiglu", norm="rms",
    seq_parallel=True,  # Megatron-SP: see EXPERIMENTS.md §Perf hillclimb 4
    source="hf:Qwen/Qwen1.5-32B",
)
