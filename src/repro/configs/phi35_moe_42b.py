"""Phi-3.5-MoE 42B-A6.6B [hf:microsoft/Phi-3.5-MoE-instruct] — 16e top-2 MoE."""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="phi3.5-moe-42b-a6.6b", family="moe",
    d_model=4096, n_heads=32, n_kv_heads=8, d_ff=6400, vocab_size=32064,
    pattern=("moe",), n_periods=32,
    head_dim=128, rope_theta=1e4,
    mlp="swiglu", norm="ln",
    n_experts=16, top_k=2, moe_d_ff=6400,
    moe_impl="a2a",     # explicit all-to-all dispatch (EXPERIMENTS §Perf h.5)
    seq_parallel=True,  # matches the a2a token layout
    source="hf:microsoft/Phi-3.5-MoE-instruct",
)
