"""Mamba2-130M [arXiv:2405.21060] — attention-free SSD (state-space duality)."""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="mamba2-130m", family="ssm",
    d_model=768, n_heads=12, n_kv_heads=12, d_ff=0, vocab_size=50280,
    pattern=("mamba",), n_periods=24,
    ssm_state=128, ssm_headdim=64, ssm_conv=4, ssm_expand=2, ssm_chunk=256,
    mlp="swiglu", norm="rms", tie_embeddings=True,
    source="arXiv:2405.21060",
)
