"""SeamlessM4T-medium [arXiv:2308.11596] — enc-dec, multimodal backbone.

The audio frontend is a STUB per the assignment: input_specs() supplies
precomputed frame embeddings (B, src_len, d_model) consumed by a 12-layer
encoder; the 12-layer decoder attends via cross-attention.
"""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="seamless-m4t-medium", family="audio",
    d_model=1024, n_heads=16, n_kv_heads=16, d_ff=4096, vocab_size=256206,
    pattern=("dec",), n_periods=12,
    enc_pattern=("enc",), n_enc_periods=12,
    head_dim=64, rope_theta=1e4,
    mlp="gelu", norm="ln",
    src_len=4096,  # precomputed audio frame embeddings (stub)
    source="arXiv:2308.11596",
)
