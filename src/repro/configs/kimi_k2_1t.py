"""Kimi-K2 1T-A32B [arXiv:2501 (kimi2); unverified] — trillion-param MoE.

61 layers: 1 dense prefix layer + 60 MoE layers, 384 experts top-8 with one
shared expert, expert d_ff=2048 (assignment), dense-layer d_ff=18432.
Requires EP over model axis + FSDP over (pod, data) + 8-bit optimizer
states to fit 512 x 16 GB (see parallel/sharding.py, optim/adamw.py).
"""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="kimi-k2-1t-a32b", family="moe",
    d_model=7168, n_heads=64, n_kv_heads=8, d_ff=18432, vocab_size=163840,
    prefix=("dense",), pattern=("moe",), n_periods=60,
    head_dim=128, rope_theta=5e4,
    mlp="swiglu", norm="rms",
    n_experts=384, top_k=8, moe_d_ff=2048, n_shared_experts=1,
    opt_bits=8,  # 1.03T params: int8 AdamW moments to fit 512 x 16 GB
    moe_impl="a2a",     # explicit all-to-all dispatch (EXPERIMENTS §Perf h.5)
    seq_parallel=True,  # matches the a2a token layout
    source="arXiv:2501.kimi2 (paper-table)",
)
