"""Llama-3.2-Vision-90B [hf:meta-llama/Llama-3.2-11B-Vision scaled] — VLM.

100-layer decoder; every 5th layer is a gated cross-attention layer over
precomputed patch embeddings (the vision frontend is a STUB per the
assignment: input_specs() supplies (B, n_patches, d_model) embeddings).
"""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="llama-3.2-vision-90b", family="vlm",
    d_model=8192, n_heads=64, n_kv_heads=8, d_ff=28672, vocab_size=128256,
    pattern=("dense", "dense", "dense", "dense", "cross"), n_periods=20,
    head_dim=128, rope_theta=5e5,
    mlp="swiglu", norm="rms",
    seq_parallel=True,  # Megatron-SP: see EXPERIMENTS.md §Perf hillclimb 4
    src_len=6400,  # ~4 tiles x 1601 patches, precomputed embeddings (stub)
    source="hf:meta-llama/Llama-3.2-11B-Vision (90B scaling)",
)
