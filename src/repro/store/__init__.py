"""Durable plan store: crash-safe persistent compile cache (DESIGN.md §15).

Load-through layer beneath the executor's in-process lru caches.
A warm process behaves identically; a cold process that finds the
store populated decodes its plans from disk instead of re-planning —
and every decoded plan is held to the same ring-1 standard as a fresh
one (:mod:`repro.guard.validate` audits re-run on load), so the
degradation ladder is

    disk hit -> (integrity failure? quarantine, count, fall through)
             -> replan -> (runtime trap? ref-engine fallback)

Silent wrong plans cannot enter the process: a torn, truncated,
bit-flipped, or colliding entry classifies as
:class:`~repro.guard.errors.CachePoisoned`, is quarantined on disk,
and the caller replans. A version-skewed entry (older schema or
planner generation) is a plain miss — legal, just unusable — and is
overwritten by the rebuild.

Enable with ``REPRO_STORE=1`` (default root ``~/.cache/repro/planstore``)
or ``REPRO_STORE=/path/to/root``, or programmatically via
:func:`configure`. Session counters (`stats()`) are always on,
independent of :mod:`repro.obs` telemetry; the same events mirror into
``store.*`` obs counters when telemetry is enabled and quarantines
additionally mirror into ``guard.stats()``.
"""
from __future__ import annotations

import os
import threading
from typing import Optional

from . import codec
from .codec import (CODE_VERSION, SCHEMA_VERSION, EntryCorrupt, EntrySkew,
                    class_key, fused_key, key_digest)
from .store import PlanStore

_DEFAULT_ROOT = "~/.cache/repro/planstore"

_LOCK = threading.Lock()
_STATS: dict = {"hit": 0, "miss": 0, "write": 0, "write_failed": 0,
                "corrupt": 0, "quarantined": 0, "version_skew": 0,
                "plan_built": 0}

_active: Optional[PlanStore] = None
_configured = False


def _env_root() -> Optional[str]:
    raw = os.environ.get("REPRO_STORE", "").strip()
    if not raw or raw.lower() in ("0", "false", "off", "no"):
        return None
    if raw.lower() in ("1", "true", "on", "yes"):
        return _DEFAULT_ROOT
    return raw


def configure(root: Optional[str]) -> Optional[PlanStore]:
    """Point the process at a store root (None disables). Returns the
    active store."""
    global _active, _configured
    with _LOCK:
        _active = PlanStore(root) if root else None
        _configured = True
        return _active


def active() -> Optional[PlanStore]:
    """The process-wide store, lazily resolved from ``REPRO_STORE`` on
    first use; None when disabled."""
    global _active, _configured
    with _LOCK:
        if not _configured:
            root = _env_root()
            _active = PlanStore(root) if root else None
            _configured = True
        return _active


def enabled() -> bool:
    return active() is not None


# ---------------------------------------------------------------------------
# session counters (always on; see obs/metrics.py for the store.* mirror)
# ---------------------------------------------------------------------------

def _count(event: str, n: int = 1, **labels) -> None:
    from ..obs import metrics as _om
    with _LOCK:
        _STATS[event] = _STATS.get(event, 0) + n
    _om.inc(f"store.{event}", n, **labels)


def stats() -> dict:
    with _LOCK:
        return dict(_STATS)


def reset_stats() -> None:
    with _LOCK:
        for k in _STATS:
            _STATS[k] = 0


# ---------------------------------------------------------------------------
# load-through core
# ---------------------------------------------------------------------------

def _quarantine(st: PlanStore, key: str, reason: str, err,
                raw: bytes) -> None:
    from .. import guard as _g

    _count("corrupt", kind=reason)
    # conditional on the corrupt bytes: N racing detectors quarantine
    # exactly once, and never sweep up a winner's rebuilt entry
    if st.quarantine(key, reason, expect=raw):
        _count("quarantined", kind=reason)
        _g._record_store_quarantine(reason)


def _load(st: PlanStore, key: str, decode_validate):
    """One integrity-checked disk probe: decoded+audited value on hit,
    None on miss/corruption (corruption quarantined + counted)."""
    from ..guard.errors import GuardError

    raw = st.read_bytes(key)
    if raw is None:
        return None
    try:
        header, arrays = codec.decode_entry(raw, key)
    except EntrySkew:
        _count("version_skew")
        return None
    except EntryCorrupt as e:
        _quarantine(st, key, "corrupt", e, raw)
        return None
    try:
        return decode_validate(header, arrays)
    except (GuardError, EntryCorrupt, ValueError, KeyError, TypeError,
            IndexError, AssertionError) as e:
        # a decoded-but-wrong plan is exactly what ring 1 exists to
        # refuse: CachePoisoned class, quarantine, replan
        _quarantine(st, key, "audit", e, raw)
        return None


# -- class plans -------------------------------------------------------------

def _typed_bmmc(rows: tuple, c: int):
    """Build WITHOUT __post_init__ so corrupt rows raise the typed
    NotInvertible from verify_bmmc, not a bare constructor error."""
    from ..core.bmmc import Bmmc
    from ..guard import validate as _v

    b = Bmmc.__new__(Bmmc)
    object.__setattr__(b, "rows", tuple(rows))
    object.__setattr__(b, "c", c)
    return _v.verify_bmmc(b)


def _audit_class(rows: tuple, c: int, t: int, kernel: str, payload) -> None:
    """Ring-1 audit of a disk-loaded class plan: re-derive the dispatch,
    bounds/bijection/semantic audit of every table, and tie the payload
    matrices back to the KEY's matrix (a valid plan for the wrong
    matrix must not pass)."""
    from ..core.tiling import dispatch_kernel
    from ..guard import validate as _v
    from ..guard.errors import ClassMismatch

    bmmc = _typed_bmmc(rows, c)
    fresh = dispatch_kernel(bmmc, t)
    if kernel != fresh:
        raise ClassMismatch(
            f"stored plan dispatched as {kernel!r}, matrix re-derives "
            f"{fresh!r} at t={t}")
    if kernel in ("block", "lane"):
        if payload.bmmc != bmmc:
            raise ClassMismatch(
                f"stored {kernel} plan answers for a different matrix "
                f"than its key")
    elif kernel != "none":
        total = payload[0].bmmc
        for p in payload[1:]:
            total = p.bmmc @ total
        if total != bmmc:
            raise ClassMismatch(
                "stored pass composition does not equal the key's matrix")
        for p in payload:
            _v.verify_bmmc(p.bmmc)
    _v._audit_payload(bmmc, t, kernel, payload)


def class_plan_through(rows: tuple, c: int, t: int, build) -> tuple:
    """Load-through for :func:`repro.kernels.ops._class_plan_cached`:
    disk hit (audited) or ``build()`` + write-back."""
    st = active()
    key = codec.class_key(rows, c, t)
    if st is not None:
        def _dv(header, arrays):
            kernel, payload = codec.decode_class_payload(
                header["meta"], arrays)
            _audit_class(rows, c, t, kernel, payload)
            return kernel, payload
        got = _load(st, key, _dv)
        if got is not None:
            _count("hit", kind="class")
            return got
        _count("miss", kind="class")
    result = build()
    _count("plan_built", kind="class")
    if st is not None:
        meta, arrays = codec.encode_class_payload(*result)
        if st.put(key, "class", meta, arrays):
            _count("write", kind="class")
        else:
            _count("write_failed", kind="class")
    return result


# -- fused plans -------------------------------------------------------------

def _audit_fused(fs, t: int, plans: tuple, entries: tuple) -> None:
    from ..guard import validate as _v
    from ..guard.errors import ClassMismatch

    _v.verify_bmmc(fs.bmmc)
    total = plans[0].bmmc
    for p in plans[1:]:
        total = p.bmmc @ total
    if total != fs.bmmc:
        raise ClassMismatch(
            "stored fused pass composition does not equal the cluster's "
            "composed matrix")
    for p in plans:
        _v.verify_bmmc(p.bmmc)
        _v.audit_tile_plan(p)
    where = f"store:FusedStage(n={fs.bmmc.n}, t={t})"
    for e in entries:
        if e[0] in ("cmp", "bfly"):
            _v._audit_compute_tables(e[2], plans[0], where)


def fused_plan_through(fs, t: int, build):
    """Load-through for ``execute._fused_plan_cached``. Unplannable
    clusters are stored as an explicit negative entry so a warm boot
    skips the (failing) planning attempt too."""
    st = active()
    key = codec.fused_key(fs, t)
    sentinel = object()
    if st is not None:
        def _dv(header, arrays):
            if not header["meta"].get("plannable", True):
                return sentinel
            plans, entries = codec.decode_fused_payload(
                header["meta"], arrays, fs.computes)
            _audit_fused(fs, t, plans, entries)
            return plans, entries
        got = _load(st, key, _dv)
        if got is not None:
            _count("hit", kind="fused")
            return None if got is sentinel else got
        _count("miss", kind="fused")
    result = build()
    _count("plan_built", kind="fused")
    if st is not None:
        if result is None:
            meta, arrays = {"plannable": False}, []
        else:
            meta, arrays = codec.encode_fused_payload(*result)
            meta["plannable"] = True
        if st.put(key, "fused", meta, arrays):
            _count("write", kind="fused")
        else:
            _count("write_failed", kind="fused")
    return result


__all__ = [
    "PlanStore", "SCHEMA_VERSION", "CODE_VERSION", "EntryCorrupt",
    "EntrySkew", "class_key", "fused_key", "key_digest", "configure",
    "active", "enabled", "stats", "reset_stats", "class_plan_through",
    "fused_plan_through", "codec",
]
