"""Entry format + plan serialization for the durable plan store.

One entry is one file::

    MAGIC (8) | header_len u32 LE | header_fp u64 LE | header JSON | payload

The header carries the schema/code version, the key the entry answers
for, a manifest of the payload arrays (name, dtype, shape, offset,
nbytes, per-array checksum), a whole-payload checksum, and a reserved
``measured_cost`` slot for the future autotune pass (DESIGN.md §15).
All checksums reuse the guard subsystem's position-sensitive XOR-fold
(:func:`repro.guard.validate._fp_array`) so a swapped pair of bytes —
not just a flipped one — changes the value.

Decoding is paranoid by construction: a short file is a torn/truncated
write, a header that fails its own checksum or does not parse is
corruption, a version skew is a plain miss (old entries are legal,
just unusable), and a payload whose per-array or whole-payload
checksum mismatches is :class:`~repro.guard.errors.CachePoisoned`
territory for the caller. Every decoded array is copied out of the
file buffer so downstream in-place mutation (fault injection included)
never aliases the mapped bytes.
"""
from __future__ import annotations

import hashlib
import json
import struct
from typing import Optional

import numpy as np

from ..core.bmmc import Bmmc
from ..core.tiling import BlockPlan, ComputeTables, LanePlan, TilePlan
from ..guard.validate import _fp_array

MAGIC = b"RPSTORE1"
SCHEMA_VERSION = 1
# Code fingerprint: entries planned by a different planner generation
# are version-skew misses, never trusted. Bump alongside planner or
# table-layout changes.
CODE_VERSION = "plan-v1"

_HEADER_FMT = "<IQ"  # header_len, header_fp
_PREFIX_LEN = len(MAGIC) + struct.calcsize(_HEADER_FMT)


class EntryCorrupt(Exception):
    """Raised by :func:`decode_entry` on any integrity failure worth
    quarantining (short read, bad magic, checksum mismatch, malformed
    manifest). Callers classify it as CachePoisoned."""


class EntrySkew(Exception):
    """Raised when an entry is intact but written by a different
    schema/code version — a miss, not a corruption."""


def _fp_bytes(buf) -> int:
    return _fp_array(np.frombuffer(buf, dtype=np.uint8))


# ---------------------------------------------------------------------------
# keys + fingerprints
# ---------------------------------------------------------------------------

def key_digest(key: str) -> str:
    return hashlib.sha256(key.encode("utf-8")).hexdigest()


def class_key(rows: tuple, c: int, t: int, backend: str = "pallas") -> str:
    rows_tok = ",".join(format(r, "x") for r in rows)
    return f"class|{backend}|n={len(rows)}|t={t}|c={c:x}|rows={rows_tok}"


def _stage_token(stage) -> str:
    from ..combinators.ir import Bfly, CmpHalves, Map, Perm
    from ..combinators.optimize import FusedStage

    if isinstance(stage, Perm):
        b = stage.bmmc
        return "P:%x:%s" % (b.c, ",".join(format(r, "x") for r in b.rows))
    if isinstance(stage, CmpHalves):
        return "C"
    if isinstance(stage, Bfly):
        tw = np.asarray(stage.twiddles, dtype=np.complex128)
        return "B:" + hashlib.sha256(tw.tobytes()).hexdigest()[:16]
    if isinstance(stage, Map):
        return "M:" + stage.name
    if isinstance(stage, FusedStage):
        return "F(" + ";".join(_stage_token(s) for s in stage.stages) + ")"
    raise TypeError(f"unfingerprintable stage {type(stage).__name__}")


def fused_key(fs, t: int, backend: str = "pallas") -> str:
    """Content key of a cluster's fused plan: the member stages (which
    determine the composed BMMC and every compute's pullback) plus the
    tile parameter. ``Map`` stages contribute their registered *name* —
    the same identity the IR's hash/eq contract uses — so the callable
    itself never reaches the key or the disk."""
    tok = hashlib.sha256(_stage_token(fs).encode("utf-8")).hexdigest()[:32]
    return f"fused|{backend}|n={fs.bmmc.n}|t={t}|prog={tok}"


# ---------------------------------------------------------------------------
# entry encode / decode
# ---------------------------------------------------------------------------

def encode_entry(key: str, kind: str, meta: dict, arrays: list,
                 measured_cost=None) -> bytes:
    """Serialize ``arrays`` — a list of ``(name, np.ndarray)`` — behind a
    checksummed header. ``meta`` is kind-specific plan structure (scalar
    fields only); ``measured_cost`` fills the reserved autotune slot."""
    manifest, chunks, off = [], [], 0
    for name, arr in arrays:
        a = np.ascontiguousarray(arr)
        raw = a.tobytes()
        manifest.append({"name": name, "dtype": a.dtype.str,
                         "shape": list(a.shape), "offset": off,
                         "nbytes": len(raw), "fp": _fp_array(a)})
        chunks.append(raw)
        off += len(raw)
    payload = b"".join(chunks)
    header = {
        "schema": SCHEMA_VERSION,
        "code": CODE_VERSION,
        "kind": kind,
        "key": key,
        "meta": meta,
        "arrays": manifest,
        "payload_nbytes": len(payload),
        "payload_fp": _fp_bytes(payload) if payload else 0,
        "measured_cost": measured_cost,   # reserved: autotuner substrate
    }
    hj = json.dumps(header, sort_keys=True).encode("utf-8")
    return b"".join((MAGIC, struct.pack(_HEADER_FMT, len(hj), _fp_bytes(hj)),
                     hj, payload))


def decode_entry(data: bytes, key: Optional[str] = None) -> tuple:
    """``(header, arrays_by_name)`` from raw entry bytes, verifying magic,
    header checksum, version, length, and every payload checksum.
    Raises :class:`EntryCorrupt` / :class:`EntrySkew`."""
    if len(data) < _PREFIX_LEN or data[:len(MAGIC)] != MAGIC:
        raise EntryCorrupt("short or unmagical entry prefix")
    hlen, hfp = struct.unpack_from(_HEADER_FMT, data, len(MAGIC))
    body = data[_PREFIX_LEN:]
    if len(body) < hlen:
        raise EntryCorrupt(f"torn header: {len(body)} of {hlen} bytes")
    hj = body[:hlen]
    if _fp_bytes(hj) != hfp:
        raise EntryCorrupt("header checksum mismatch")
    try:
        header = json.loads(hj.decode("utf-8"))
    except (UnicodeDecodeError, json.JSONDecodeError) as e:
        raise EntryCorrupt(f"header does not parse: {e}") from None
    if header.get("schema") != SCHEMA_VERSION or (
            header.get("code") != CODE_VERSION):
        raise EntrySkew(
            f"entry written by schema={header.get('schema')} "
            f"code={header.get('code')!r}, this build is "
            f"schema={SCHEMA_VERSION} code={CODE_VERSION!r}")
    if key is not None and header.get("key") != key:
        raise EntryCorrupt(
            f"entry answers for key {header.get('key')!r}, asked for "
            f"{key!r} (hash collision or tampering)")
    payload = body[hlen:]
    want = header.get("payload_nbytes", -1)
    if len(payload) < want:
        raise EntryCorrupt(f"torn payload: {len(payload)} of {want} bytes")
    payload = payload[:want]
    if want and _fp_bytes(payload) != header.get("payload_fp"):
        raise EntryCorrupt("whole-payload checksum mismatch")
    arrays = {}
    try:
        for m in header["arrays"]:
            raw = payload[m["offset"]:m["offset"] + m["nbytes"]]
            if len(raw) != m["nbytes"]:
                raise EntryCorrupt(f"array {m['name']!r} truncated")
            a = np.frombuffer(raw, dtype=np.dtype(m["dtype"]))
            a = np.array(a.reshape(m["shape"]))  # writable copy, off-buffer
            if _fp_array(a) != m["fp"]:
                raise EntryCorrupt(f"array {m['name']!r} checksum mismatch")
            arrays[m["name"]] = a
    except EntryCorrupt:
        raise
    except (KeyError, TypeError, ValueError) as e:
        raise EntryCorrupt(f"malformed array manifest: {e}") from None
    return header, arrays


# ---------------------------------------------------------------------------
# plan payloads <-> (meta, arrays)
# ---------------------------------------------------------------------------

def _bmmc_meta(b: Bmmc) -> dict:
    return {"rows": [format(r, "x") for r in b.rows], "c": format(b.c, "x")}


def _bmmc_from_meta(m: dict) -> Bmmc:
    # the constructor re-runs the rank check: corrupt rows raise here
    return Bmmc(tuple(int(r, 16) for r in m["rows"]), int(m["c"], 16))


def _tile_plan_meta(p: TilePlan) -> dict:
    return {"bmmc": _bmmc_meta(p.bmmc), "t": p.t,
            "row_cols": list(p.row_cols), "n_over": p.n_over,
            "tb_positions": list(p.tb_positions), "in_run": p.in_run,
            "out_run": p.out_run, "row_dirs": list(p.row_dirs)}


def _tile_plan_arrays(prefix: str, p: TilePlan) -> list:
    return [(prefix + "in_rows", p.in_rows), (prefix + "out_rows", p.out_rows),
            (prefix + "xor_low", p.xor_low), (prefix + "src0", p.src0)]


def _tile_plan_from(m: dict, prefix: str, arrays: dict) -> TilePlan:
    return TilePlan(
        bmmc=_bmmc_from_meta(m["bmmc"]), t=int(m["t"]),
        row_cols=tuple(m["row_cols"]), n_over=int(m["n_over"]),
        tb_positions=tuple(m["tb_positions"]),
        in_rows=arrays[prefix + "in_rows"], out_rows=arrays[prefix + "out_rows"],
        xor_low=arrays[prefix + "xor_low"], src0=arrays[prefix + "src0"],
        in_run=int(m["in_run"]), out_run=int(m["out_run"]),
        row_dirs=tuple(m["row_dirs"]))


def encode_class_payload(kernel: str, payload) -> tuple:
    """``(meta, arrays)`` for one class-dispatch ``(kernel, payload)``."""
    if kernel == "none":
        return {"kernel": kernel}, []
    if kernel == "block":
        return ({"kernel": kernel, "b": payload.b,
                 "bmmc": _bmmc_meta(payload.bmmc)},
                [("src_rows", payload.src_rows)])
    if kernel == "lane":
        return ({"kernel": kernel, "t": payload.t,
                 "rows_per_block": payload.rows_per_block,
                 "bmmc": _bmmc_meta(payload.bmmc)},
                [("src_lane", payload.src_lane)])
    meta = {"kernel": kernel,
            "passes": [_tile_plan_meta(p) for p in payload]}
    arrays = []
    for i, p in enumerate(payload):
        arrays.extend(_tile_plan_arrays(f"p{i}.", p))
    return meta, arrays


def decode_class_payload(meta: dict, arrays: dict) -> tuple:
    kernel = meta["kernel"]
    if kernel == "none":
        return kernel, ()
    if kernel == "block":
        return kernel, BlockPlan(bmmc=_bmmc_from_meta(meta["bmmc"]),
                                 b=int(meta["b"]),
                                 src_rows=arrays["src_rows"])
    if kernel == "lane":
        return kernel, LanePlan(bmmc=_bmmc_from_meta(meta["bmmc"]),
                                t=int(meta["t"]),
                                src_lane=arrays["src_lane"],
                                rows_per_block=int(meta["rows_per_block"]))
    plans = tuple(_tile_plan_from(m, f"p{i}.", arrays)
                  for i, m in enumerate(meta["passes"]))
    return kernel, plans


_CT_FIELDS = ("hi_row", "hi_lane", "hi_base", "tw_row", "tw_lane", "tw_base")


def encode_fused_payload(plans: tuple, entries: tuple) -> tuple:
    """``(meta, arrays)`` for one fused-cluster plan. Only the offline
    tables travel: compute entries are re-seated against the cluster's
    live ``computes`` on decode (Map callables never serialize)."""
    meta = {"passes": [_tile_plan_meta(p) for p in plans], "entries": []}
    arrays = []
    for i, p in enumerate(plans):
        arrays.extend(_tile_plan_arrays(f"p{i}.", p))
    for i, e in enumerate(entries):
        if e[0] == "map":
            meta["entries"].append({"kind": "map"})
            continue
        kind, _, ct = e
        em = {"kind": kind, "vr": ct.vr, "vc": ct.vc}
        for f in _CT_FIELDS:
            arr = getattr(ct, f)
            em[f] = arr is not None
            if arr is not None:
                arrays.append((f"e{i}.{f}", arr))
        meta["entries"].append(em)
    return meta, arrays


def decode_fused_payload(meta: dict, arrays: dict, computes: tuple) -> tuple:
    """``(plans, entries)`` re-seated against the live ``fs.computes``.
    Raises :class:`EntryCorrupt` when the stored entry list does not
    line up with the cluster (collision / drift)."""
    from ..combinators.ir import Bfly, CmpHalves, Map

    plans = tuple(_tile_plan_from(m, f"p{i}.", arrays)
                  for i, m in enumerate(meta["passes"]))
    ems = meta["entries"]
    if len(ems) != len(computes):
        raise EntryCorrupt(
            f"stored {len(ems)} compute entries for a cluster with "
            f"{len(computes)} computes")
    entries = []
    for i, ((comp, _prefix), em) in enumerate(zip(computes, ems)):
        want = ("map" if isinstance(comp, Map)
                else "cmp" if isinstance(comp, CmpHalves)
                else "bfly" if isinstance(comp, Bfly) else None)
        if em["kind"] != want:
            raise EntryCorrupt(
                f"entry {i} stored as {em['kind']!r}, cluster compute is "
                f"{type(comp).__name__}")
        if want == "map":
            entries.append(("map", comp))
            continue
        fields = {}
        for f in _CT_FIELDS:
            fields[f] = arrays[f"e{i}.{f}"] if em.get(f) else None
        entries.append((want, comp, ComputeTables(
            kind=want, vr=int(em["vr"]), vc=int(em["vc"]), **fields)))
    return plans, tuple(entries)
