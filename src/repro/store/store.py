"""On-disk content-addressed plan store (DESIGN.md §15).

Layout under one root::

    objects/<hh>/<sha256(key)>.plan     hh = first two hex digits
    quarantine/<sha256(key)>.<reason>.<uniq>.plan
    tmp/<pid>.<seq>.tmp

Durability contract:

* **Atomic writes** — every entry lands via tmp-file write + flush +
  ``fsync`` + ``os.replace`` (POSIX rename atomicity), so a concurrent
  reader sees either the old complete entry or the new complete entry,
  never a torn one. A crash mid-write leaves at worst an orphan in
  ``tmp/``, which is swept opportunistically.
* **Single writer per key, many readers** — writers race benignly
  (last ``os.replace`` wins, both entries were complete); readers never
  lock.
* **Quarantine, not deletion** — an entry that fails integrity is moved
  aside (again via ``os.replace``, so exactly one of N racing readers
  wins the move and the rest see a clean miss), preserving the corrupt
  bytes for post-mortem.

The store never raises on I/O trouble in the hot path: ``get`` returns
``None`` and ``put`` returns ``False`` on OSError — disk failure
degrades to replanning, the same ladder as every other fault.
"""
from __future__ import annotations

import itertools
import os
import threading
from typing import Optional

from . import codec

_TMP_SEQ = itertools.count()
_TMP_LOCK = threading.Lock()


def _next_tmp_name() -> str:
    with _TMP_LOCK:
        seq = next(_TMP_SEQ)
    return f"{os.getpid()}.{seq}.tmp"


class PlanStore:
    """One store root. Thread-safe; cheap to construct."""

    def __init__(self, root: str):
        self.root = os.path.abspath(os.path.expanduser(root))
        self.objects = os.path.join(self.root, "objects")
        self.quarantine_dir = os.path.join(self.root, "quarantine")
        self.tmp_dir = os.path.join(self.root, "tmp")
        for d in (self.objects, self.quarantine_dir, self.tmp_dir):
            os.makedirs(d, exist_ok=True)

    # -- paths -------------------------------------------------------------

    def path_for(self, key: str) -> str:
        h = codec.key_digest(key)
        return os.path.join(self.objects, h[:2], h + ".plan")

    # -- raw I/O -----------------------------------------------------------

    def read_bytes(self, key: str) -> Optional[bytes]:
        try:
            with open(self.path_for(key), "rb") as f:
                return f.read()
        except OSError:
            return None

    def write_bytes(self, key: str, data: bytes) -> bool:
        """Atomic: tmp + fsync + rename. False on any I/O failure."""
        final = self.path_for(key)
        tmp = os.path.join(self.tmp_dir, _next_tmp_name())
        try:
            os.makedirs(os.path.dirname(final), exist_ok=True)
            with open(tmp, "wb") as f:
                f.write(data)
                f.flush()
                os.fsync(f.fileno())
            os.replace(tmp, final)
        except OSError:
            try:
                os.unlink(tmp)
            except OSError:
                pass
            return False
        try:  # make the rename itself durable; best-effort
            dfd = os.open(os.path.dirname(final), os.O_RDONLY)
            try:
                os.fsync(dfd)
            finally:
                os.close(dfd)
        except OSError:
            pass
        return True

    # -- entry API ---------------------------------------------------------

    def put(self, key: str, kind: str, meta: dict, arrays: list) -> bool:
        return self.write_bytes(
            key, codec.encode_entry(key, kind, meta, arrays))

    def get(self, key: str) -> Optional[tuple]:
        """``(header, arrays)`` or None on miss. Integrity failures
        propagate as :class:`codec.EntryCorrupt` / :class:`codec.EntrySkew`
        for the load-through layer to classify."""
        data = self.read_bytes(key)
        if data is None:
            return None
        return codec.decode_entry(data, key)

    def quarantine(self, key: str, reason: str,
                   expect: Optional[bytes] = None) -> bool:
        """Move the entry aside. Returns True only for the caller whose
        rename won (N racing detectors quarantine exactly once: the
        losers' ``os.replace`` finds the path already gone). When
        ``expect`` is given, the move is skipped if the path no longer
        holds those bytes — a racing detector that lost the rename AND
        already saw the winner's rebuilt entry must not quarantine the
        fresh plan it just replanned past."""
        src = self.path_for(key)
        if expect is not None:
            try:
                with open(src, "rb") as f:
                    if f.read() != expect:
                        return False
            except OSError:
                return False
        dst = os.path.join(
            self.quarantine_dir,
            f"{codec.key_digest(key)}.{reason}.{_next_tmp_name()}.plan")
        try:
            os.replace(src, dst)
            return True
        except OSError:
            return False

    def annotate_cost(self, key: str, cost) -> bool:
        """Fill the reserved ``measured_cost`` header slot (the autotune
        substrate) and rewrite the entry atomically. False when the
        entry is absent or unreadable."""
        data = self.read_bytes(key)
        if data is None:
            return False
        try:
            header, arrays = codec.decode_entry(data, key)
        except (codec.EntryCorrupt, codec.EntrySkew):
            return False
        rebuilt = codec.encode_entry(
            key, header["kind"], header["meta"],
            [(m["name"], arrays[m["name"]]) for m in header["arrays"]],
            measured_cost=cost)
        return self.write_bytes(key, rebuilt)

    # -- hygiene / introspection -------------------------------------------

    def entry_count(self) -> int:
        total = 0
        try:
            for sub in os.scandir(self.objects):
                if sub.is_dir():
                    total += sum(1 for e in os.scandir(sub.path)
                                 if e.name.endswith(".plan"))
        except OSError:
            pass
        return total

    def quarantined_count(self) -> int:
        try:
            return sum(1 for e in os.scandir(self.quarantine_dir)
                       if e.name.endswith(".plan"))
        except OSError:
            return 0

    def sweep_tmp(self) -> int:
        """Remove orphaned tmp files from crashed writers (not this
        process's pid). Returns the count removed."""
        removed = 0
        pid = f"{os.getpid()}."
        try:
            for e in os.scandir(self.tmp_dir):
                if e.name.startswith(pid):
                    continue
                try:
                    os.unlink(e.path)
                    removed += 1
                except OSError:
                    pass
        except OSError:
            pass
        return removed
