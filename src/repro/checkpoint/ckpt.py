"""Checkpoint/restore with integrity manifest + elastic resharding.

Layout (one directory per step)::

    <dir>/step_000100/
        manifest.json      # step, arch hash, data-pipeline state, leaf index,
                           # per-leaf sha256 — integrity-checked on restore
        arrays.npz         # flattened leaves (host-local full arrays)

On a real multi-host cluster each host writes its own shard file (the leaf
index records shardings); in this single-host container arrays are full.
Restore is **elastic**: arrays are re-sharded onto whatever mesh the new job
runs (``jax.device_put`` against the new shardings), and the data-pipeline
BMMC shuffle state is mesh-independent, so a restarted job consumes exactly
the unconsumed samples.
"""
from __future__ import annotations

import hashlib
import json
import os
import shutil
import tempfile
from typing import Any, Dict, Optional

import jax
import numpy as np


def _flatten(tree) -> Dict[str, np.ndarray]:
    flat = {}
    leaves, treedef = jax.tree_util.tree_flatten_with_path(tree)
    for path, leaf in leaves:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path)
        flat[key] = np.asarray(leaf)
    return flat


def _fsync_dir(path: str) -> None:
    """Best-effort directory fsync (persists the rename itself)."""
    try:
        fd = os.open(path, os.O_RDONLY)
    except OSError:  # pragma: no cover - platform without dir-open
        return
    try:
        os.fsync(fd)
    finally:
        os.close(fd)


def save(dirpath: str, step: int, tree: Any, *,
         extra_state: Optional[Dict] = None, keep_last: int = 3) -> str:
    """Atomic + durable checkpoint write; prunes old steps.

    Same discipline as the plan store (DESIGN.md §15): every payload is
    flushed and fsync'd inside a hidden tmp dir, the tmp dir itself is
    fsync'd, and only then does a single ``os.replace`` publish the
    step directory (parent dir fsync'd after, so the rename survives a
    power cut). A job killed at ANY instant therefore leaves either the
    complete published step or an invisible ``.tmp_ckpt_*`` orphan —
    never a torn ``step_*`` a restore could trip over
    (``tests/test_data_ckpt.py`` kills a writer mid-save to prove it).
    """
    target = os.path.join(dirpath, f"step_{step:08d}")
    os.makedirs(dirpath, exist_ok=True)
    tmp = tempfile.mkdtemp(dir=dirpath, prefix=".tmp_ckpt_")
    try:
        flat = _flatten(tree)
        with open(os.path.join(tmp, "arrays.npz"), "wb") as f:
            np.savez(f, **flat)
            f.flush()
            os.fsync(f.fileno())
        manifest = {
            "step": step,
            "extra_state": extra_state or {},
            "leaves": {k: {"shape": list(v.shape), "dtype": str(v.dtype),
                           "sha256": hashlib.sha256(v.tobytes()).hexdigest()}
                       for k, v in flat.items()},
        }
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f, indent=1)
            f.flush()
            os.fsync(f.fileno())
        _fsync_dir(tmp)
        if os.path.exists(target):
            shutil.rmtree(target)
        os.replace(tmp, target)
        _fsync_dir(dirpath)
    except Exception:
        shutil.rmtree(tmp, ignore_errors=True)
        raise
    _prune(dirpath, keep_last)
    return target


def _prune(dirpath: str, keep_last: int):
    steps = sorted(d for d in os.listdir(dirpath) if d.startswith("step_"))
    for d in steps[:-keep_last] if keep_last else []:
        shutil.rmtree(os.path.join(dirpath, d), ignore_errors=True)


def latest_step(dirpath: str) -> Optional[int]:
    if not os.path.isdir(dirpath):
        return None
    steps = [int(d.split("_")[1]) for d in os.listdir(dirpath)
             if d.startswith("step_")]
    return max(steps) if steps else None


def restore(dirpath: str, step: int, template: Any, *,
            shardings: Any = None, verify: bool = True):
    """Restore a pytree; optionally device_put onto (new-mesh) shardings.

    ``template`` supplies the tree structure; raises on integrity mismatch.
    Returns (tree, extra_state).
    """
    target = os.path.join(dirpath, f"step_{step:08d}")
    with open(os.path.join(target, "manifest.json")) as f:
        manifest = json.load(f)
    data = np.load(os.path.join(target, "arrays.npz"))
    flat_template = _flatten(template)
    out_flat = {}
    for key in flat_template:
        if key not in data:
            raise KeyError(f"checkpoint missing leaf {key!r}")
        arr = data[key]
        meta = manifest["leaves"][key]
        if verify:
            digest = hashlib.sha256(arr.tobytes()).hexdigest()
            if digest != meta["sha256"]:
                raise IOError(f"integrity failure for leaf {key!r}")
        out_flat[key] = arr
    leaves, treedef = jax.tree_util.tree_flatten_with_path(template)
    ordered = []
    for path, _ in leaves:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path)
        ordered.append(out_flat[key])
    tree = jax.tree.unflatten(jax.tree.structure(template), ordered)
    if shardings is not None:
        tree = jax.device_put(tree, shardings)
    return tree, manifest["extra_state"]
