"""Export + rendering: Chrome-trace JSON and the ``obs.report()`` table.

``export_trace(path)`` writes the recorded spans as a Chrome trace
(``chrome://tracing`` / Perfetto `ui.perfetto.dev` both open it).
``report()`` renders the counters, histograms, cache stats and the
model-vs-measured accounting as one plain-text summary; ``snapshot()``
is the same content as a JSON-serializable dict (what the benchmark
``--json`` payloads embed).
"""
from __future__ import annotations

import json
from typing import Optional

from . import metrics as _metrics
from . import trace as _trace


def export_trace(path: str) -> str:
    """Write the span buffer as Chrome-trace JSON; returns ``path``."""
    payload = {
        "traceEvents": _trace.events(),
        "displayTimeUnit": "ms",
        "otherData": {"source": "repro.obs", "dropped": _trace.dropped()},
    }
    with open(path, "w") as f:
        json.dump(payload, f)
        f.write("\n")
    return path


def _fmt_key(key: tuple) -> str:
    name, labels = key
    if not labels:
        return name
    return name + "{" + ",".join(f"{k}={v}" for k, v in labels) + "}"


def cache_stats() -> dict:
    """Aggregate executor/ops cache stats (see
    :func:`repro.combinators.execute.cache_stats`)."""
    from ..combinators.execute import cache_stats as _cs
    return {name: info._asdict() for name, info in _cs().items()}


def snapshot() -> dict:
    """JSON-serializable summary of everything recorded so far."""
    return {
        "kernel_counts": _metrics.kernel_counts(),
        "class_counts": _metrics.class_counts(),
        "counters": {_fmt_key(k): v for k, v in
                     sorted(_metrics.counters().items())},
        "histograms": {_fmt_key(k): s for k, s in
                       sorted(_metrics.histograms().items())},
        "caches": cache_stats(),
        "trace_events": len(_trace.events()),
        "model_vs_measured": model_vs_measured(),
    }


def model_vs_measured() -> dict:
    """The accounting the honesty gate reads: modeled round trips and
    DMA descriptors accumulated at dispatch time vs the measured (sync)
    wall-clock the program-call histogram recorded."""
    rt = _metrics.counter_total("model.round_trips")
    desc = _metrics.counter_total("dma.descriptors")
    calls = 0
    wall_us = 0.0
    for (name, _), s in _metrics.histograms().items():
        if name == "program.call_us":
            calls += s["count"]
            wall_us += s["sum"]
    out = {
        "modeled_round_trips": int(rt),
        "modeled_dma_descriptors": int(desc),
        "program_calls": int(calls),
        "measured_wall_us": round(wall_us, 1),
    }
    if rt and wall_us:
        out["us_per_modeled_round_trip"] = round(wall_us / rt, 3)
    return out


def _table(rows: list, headers: tuple) -> list:
    widths = [len(h) for h in headers]
    srows = [[str(c) for c in r] for r in rows]
    for r in srows:
        widths = [max(w, len(c)) for w, c in zip(widths, r)]
    lines = ["  ".join(h.ljust(w) for h, w in zip(headers, widths)).rstrip()]
    lines.append("  ".join("-" * w for w in widths))
    for r in srows:
        lines.append("  ".join(c.ljust(w) for c, w in zip(r, widths)).rstrip())
    return lines


def report(file=None) -> str:
    """Render the telemetry summary; printed to ``file`` when given."""
    lines = ["== repro.obs report =="]
    state = ("enabled" if _trace.enabled() else "disabled")
    lines.append(f"telemetry: {state} (sync="
                 f"{_trace._state.sync}); spans recorded: "
                 f"{len(_trace.events())} (dropped {_trace.dropped()})")

    kc = _metrics.kernel_counts()
    if kc:
        lines.append("")
        lines.append("-- kernel dispatches (program_cost vocabulary) --")
        lines.extend(_table(sorted(kc.items()), ("kernel", "count")))
    cc = _metrics.class_counts()
    if cc:
        lines.append("")
        lines.append("-- BMMC classes dispatched --")
        lines.extend(_table(sorted(cc.items()), ("class", "count")))

    other = [( _fmt_key(k), v) for k, v in sorted(_metrics.counters().items())
             if k[0] not in ("dispatch.kernel", "dispatch.class")]
    if other:
        lines.append("")
        lines.append("-- counters --")
        lines.extend(_table(other, ("counter", "value")))

    hists = _metrics.histograms()
    if hists:
        lines.append("")
        lines.append("-- histograms (µs unless noted) --")
        rows = [(_fmt_key(k), s["count"], f"{s['mean']:.1f}",
                 f"{s['p50']:.1f}", f"{s['p99']:.1f}", f"{s['max']:.1f}")
                for k, s in sorted(hists.items())]
        lines.extend(_table(rows, ("histogram", "n", "mean", "p50",
                                   "p99", "max")))

    mm = model_vs_measured()
    lines.append("")
    lines.append("-- model vs measured --")
    lines.extend(_table(sorted(mm.items()), ("quantity", "value")))

    try:
        caches = cache_stats()
    except Exception:  # combinators not imported yet: nothing to report
        caches = {}
    if caches:
        lines.append("")
        lines.append("-- caches --")
        rows = [(name, c["hits"], c["misses"], c["currsize"],
                 c["maxsize"] if c["maxsize"] is not None else "-")
                for name, c in sorted(caches.items())]
        lines.extend(_table(rows, ("cache", "hits", "misses",
                                   "size", "max")))

    text = "\n".join(lines)
    if file is not None:
        print(text, file=file)
    return text
