"""Structured tracing: hierarchical spans with Chrome-trace export.

Spans record at *dispatch/trace time* — the host-side Python that plans,
traces jaxprs, and launches kernels — never inside kernel bodies, so the
layer adds nothing to the compiled program and forces no host sync of
its own. Disabled (the default) every instrumentation site reduces to a
single module-attribute check.

Span taxonomy (DESIGN.md §12): ``program.call`` (one CompiledExpr
invocation) > ``stage.*`` (one primitive/fused stage as the executor
walks the program — under the whole-program executable these appear
once, at trace time) > ``kernel.dispatch`` (one class-dispatch
decision). Drivers add ``serve.*`` / ``train.step`` roots.

``enable(sync=True)`` additionally lets *measurement sites* (program
calls, serve/train drivers) block on device results so recorded
wall-clock is end-to-end; ``sync=False`` keeps the layer strictly
non-blocking and the recorded durations are dispatch time only.
"""
from __future__ import annotations

import threading
import time
from contextlib import contextmanager
from typing import Iterator, Optional

_MAX_EVENTS = 200_000  # hard bound; events past it are counted, not kept


class _State:
    __slots__ = ("enabled", "sync")

    def __init__(self) -> None:
        self.enabled = False
        self.sync = True


_state = _State()
_events: list = []
_dropped = 0
_lock = threading.Lock()
_tls = threading.local()


def enabled() -> bool:
    """Is telemetry recording?  The one check every site pays."""
    return _state.enabled


def sync_enabled() -> bool:
    """May measurement sites block on device results for end-to-end
    wall-clock?  (Never True when telemetry is off.)"""
    return _state.enabled and _state.sync


def enable(sync: bool = True) -> None:
    _state.enabled = True
    _state.sync = sync


def disable() -> None:
    _state.enabled = False


def reset() -> None:
    """Drop all recorded events (counters live in :mod:`.metrics`)."""
    global _dropped
    with _lock:
        _events.clear()
        _dropped = 0


def now_us() -> float:
    """The trace clock (µs); shared by every event so exports line up."""
    return time.perf_counter_ns() / 1e3


def _stack() -> list:
    st = getattr(_tls, "stack", None)
    if st is None:
        st = _tls.stack = []
    return st


@contextmanager
def span(name: str, cat: str = "repro", **args) -> Iterator[Optional[dict]]:
    """Hierarchical trace span. Yields a mutable dict merged into the
    event's args at exit, so callers can attach facts discovered inside
    (e.g. the dispatched kernel). No-op when disabled."""
    if not _state.enabled:
        yield None
        return
    stack = _stack()
    parent = stack[-1] if stack else None
    ev_args = dict(args)
    stack.append(name)
    t0 = now_us()
    try:
        yield ev_args
    finally:
        dur = now_us() - t0
        stack.pop()
        record_event(name, cat, t0, dur, ev_args,
                     parent=parent, depth=len(stack))


def record_event(name: str, cat: str, ts_us: float, dur_us: float,
                 args: Optional[dict] = None, parent: Optional[str] = None,
                 depth: int = 0) -> None:
    """Append one Chrome-trace complete event (``ph: "X"``)."""
    if not _state.enabled:
        return
    global _dropped
    ev = {
        "name": name, "cat": cat, "ph": "X", "pid": 1,
        "tid": threading.get_ident() % 1_000_000,
        "ts": round(ts_us, 3), "dur": round(dur_us, 3),
        "args": dict(args or {}),
    }
    if parent is not None:
        ev["args"]["parent"] = parent
    if depth:
        ev["args"]["depth"] = depth
    with _lock:
        if len(_events) >= _MAX_EVENTS:
            _dropped += 1
            return
        _events.append(ev)


def events() -> list:
    with _lock:
        return list(_events)


def dropped() -> int:
    return _dropped
