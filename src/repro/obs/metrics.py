"""Counters + histograms for the permutation executor stack.

Counters are labeled monotonic sums (``inc``); histograms keep running
count/sum/min/max plus a fixed-size deterministic reservoir for
percentiles (``observe``). Both are plain host-side Python — safe to
call at jit-trace time (values must be concrete Python numbers, which
every instrumentation site guarantees: they come from offline plans and
host clocks, never from traced arrays) — and both are no-ops while
telemetry is disabled.

Counter vocabulary used by the executor stack (DESIGN.md §12):

* ``dispatch.kernel{kernel=...}`` — one count per kernel dispatch, in
  the ``program_cost(...)["kernels"]`` vocabulary (``none`` / ``block``
  / ``lane`` / ``tiled`` / ``general`` / ``general2`` / ``fused`` /
  ``sweep``) plus ``ref`` for gather-oracle executions.
* ``dispatch.class{cls=...}`` — the BMMC *class* (identity / complement
  / block / lane / tiled / general) of each dispatched matrix.
* ``dma.descriptors`` / ``model.round_trips`` — modeled DMA descriptor
  and HBM-round-trip totals of everything dispatched.
* ``dispatch.vjp{kind=...}`` — one count per custom-vjp backward rule
  executed (``perm`` / ``collapsed`` / ``replay`` / ``fused`` /
  ``stage``), i.e. which backward compilation path (DESIGN.md §13) a
  gradient took.
* ``model.vjp_round_trips`` — the slice of ``model.round_trips``
  attributable to backward-rule bodies: each vjp rule records the
  ``model.round_trips`` delta its own dispatches produced, so a cold
  backward call's ``model.vjp_round_trips`` delta equals the modeled
  cost of the compiled inverse/collapsed program
  (``CompiledExpr.vjp_round_trips`` — the backward honesty gate).
* ``optimize.fold_free_folds`` / ``optimize.clusters`` /
  ``optimize.cluster_stages_absorbed`` — planner decisions.
* ``dispatch.fused_fallback`` — clusters replayed stage-at-a-time.
* ``guard.trap{kind=..., engine=...}`` — one count per runtime guard
  flag that fired (``oob`` / ``nonfinite`` / ``parity``; DESIGN.md
  §14), labeled with the engine it fired on.
* ``guard.fallback{engine=...}`` / ``guard.recovered`` — graceful
  degradations: a trapped pallas call re-dispatched through ``engine``
  (always ``ref`` today), and how many of those fallbacks came back
  clean.
* ``guard.raised{error=...}`` — unrecovered traps that escaped as a
  typed ``GuardError`` (``GuardTrap`` / ``CachePoisoned``), by type.

* ``store.hit{kind=...}`` / ``store.miss{kind=...}`` — durable plan
  store (DESIGN.md §15) probes by entry kind (``class`` / ``fused``).
  A hit means the plan was decoded from disk AND re-passed its ring-1
  audit; everything else falls through to a miss.
* ``store.write{kind=...}`` / ``store.write_failed{kind=...}`` —
  write-backs after a replan (failures are non-fatal: the store
  degrades to a pure in-process cache on a read-only disk).
* ``store.corrupt{kind=...}`` / ``store.quarantined{kind=...}`` —
  integrity failures by cause (``corrupt`` = checksum/structure,
  ``audit`` = decoded fine but refused by ring 1). ``quarantined``
  counts the entries actually moved to ``quarantine/`` — under a
  detection race exactly one detector wins the move, so
  ``quarantined <= corrupt``.
* ``store.version_skew`` — entries from an older schema or planner
  generation: a plain miss (legal, just unusable), overwritten by the
  rebuild, never quarantined.
* ``store.plan_built{kind=...}`` — plans built from scratch (the CI
  warm-start gate asserts this stays 0 on a disk-warm boot).
* ``store.warmstart_us{workload=...}`` — first-call latency histogram
  of disk-warm boots (benchmarks/store_warmstart.py).

* ``resilience.breaker.open{engine=...}`` /
  ``resilience.breaker.probe{engine=...}`` /
  ``resilience.breaker.close{engine=...}`` — circuit-breaker
  transitions (DESIGN.md §16): a protected engine condemned after
  ``threshold`` consecutive traps, the half-open health probe admitted
  after the cool-down, and a clean probe restoring full service.
* ``resilience.breaker.shunt{engine=...}`` — calls routed straight to
  the fallback engine at plan level while a circuit is open (the
  chaos gate's ``traps_while_open == 0`` verifies these pay zero
  per-call trap cost).
* ``resilience.retry`` — bounded retries of retryable GuardErrors
  (request policy backoff, and the validated train step's transient
  trap retries).
* ``resilience.deadline`` — requests that exhausted their deadline
  budget (including refusing a backoff sleep that could only end past
  the deadline).
* ``resilience.shed`` — requests refused at admission: backlog at
  capacity, or the EWMA-estimated drain time already exceeds the
  deadline budget.

The guard counters are *also* mirrored into ``repro.guard.stats()``,
which records regardless of obs being enabled — guards must count even
when telemetry is off. The store counters mirror the same way:
``repro.store.stats()`` is the always-on session record (plus a
``store_quarantined`` mirror inside ``guard.stats()``), and the
``store.*`` obs counters light up only under telemetry. The resilience
counters follow suit: ``repro.resilience.stats()`` aggregates the
always-on request-policy record plus the breaker board's transition
counts and live circuit snapshots.

Span vocabulary for gradients mirrors the forward's: ``program.vjp`` /
``fused.vjp`` / ``stage.vjp`` wrap the corresponding backward rule
bodies, and ``kernel.fused_bwd`` wraps the (gated) gradient megakernel.
"""
from __future__ import annotations

import math
import threading
from typing import Dict, Tuple

from . import trace as _trace

_RESERVOIR = 1024

_lock = threading.Lock()
_counters: Dict[tuple, float] = {}
_hists: Dict[tuple, "_Hist"] = {}

Key = Tuple[str, tuple]


class _Hist:
    __slots__ = ("count", "total", "vmin", "vmax", "sample")

    def __init__(self) -> None:
        self.count = 0
        self.total = 0.0
        self.vmin = math.inf
        self.vmax = -math.inf
        self.sample: list = []

    def add(self, v: float) -> None:
        self.count += 1
        self.total += v
        if v < self.vmin:
            self.vmin = v
        if v > self.vmax:
            self.vmax = v
        if len(self.sample) < _RESERVOIR:
            self.sample.append(v)
        else:  # deterministic overwrite (no RNG: identical across runs)
            self.sample[self.count % _RESERVOIR] = v

    def summary(self) -> dict:
        s = sorted(self.sample)

        def pct(p: float) -> float:
            return s[min(len(s) - 1, int(p * len(s)))] if s else 0.0

        return {
            "count": self.count, "sum": self.total,
            "min": self.vmin if self.count else 0.0,
            "max": self.vmax if self.count else 0.0,
            "mean": self.total / self.count if self.count else 0.0,
            "p50": pct(0.50), "p90": pct(0.90), "p99": pct(0.99),
        }


def _key(name: str, labels: dict) -> Key:
    return (name, tuple(sorted(labels.items())))


def inc(name: str, value: float = 1, **labels) -> None:
    """Add ``value`` to a labeled counter. No-op when disabled."""
    if not _trace._state.enabled:
        return
    k = _key(name, labels)
    with _lock:
        _counters[k] = _counters.get(k, 0) + value


def observe(name: str, value: float, **labels) -> None:
    """Record one histogram observation. No-op when disabled."""
    if not _trace._state.enabled:
        return
    k = _key(name, labels)
    with _lock:
        h = _hists.get(k)
        if h is None:
            h = _hists[k] = _Hist()
        h.add(value)


def counters() -> dict:
    """Snapshot ``{(name, ((label, value), ...)): count}``."""
    with _lock:
        return dict(_counters)


def counter_value(name: str, **labels) -> float:
    with _lock:
        return _counters.get(_key(name, labels), 0)


def counter_total(name: str) -> float:
    """Sum of a counter across all label sets."""
    with _lock:
        return sum(v for (n, _), v in _counters.items() if n == name)


def histograms() -> dict:
    """Snapshot ``{(name, labels): summary-dict}``."""
    with _lock:
        return {k: h.summary() for k, h in _hists.items()}


def _label_counts(name: str, label: str) -> dict:
    out: dict = {}
    with _lock:
        for (n, labels), v in _counters.items():
            if n != name:
                continue
            key = dict(labels).get(label, "?")
            out[key] = out.get(key, 0) + int(v)
    return out


def kernel_counts() -> dict:
    """Per-kernel dispatch counts in the ``program_cost`` vocabulary —
    directly comparable to ``CompiledExpr.cost(...)["kernels"]``."""
    return _label_counts("dispatch.kernel", "kernel")


def class_counts() -> dict:
    """Per-BMMC-class dispatch counts (identity/complement/block/lane/
    tiled/general)."""
    return _label_counts("dispatch.class", "cls")


def reset() -> None:
    with _lock:
        _counters.clear()
        _hists.clear()
