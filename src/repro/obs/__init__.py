"""Telemetry for the permutation executor stack (DESIGN.md §12).

Three layers, all zero-cost while disabled (the default — every
instrumentation site is one module-attribute check, and nothing is
recorded inside kernels or compiled jaxprs):

* :mod:`.trace`   — hierarchical spans (program > stage > kernel
  dispatch), recorded at dispatch/trace time on the host.
* :mod:`.metrics` — labeled counters + histograms: kernel-class
  dispatch counts, fold_free eliminations, DMA descriptors, modeled
  round trips, request/step latency.
* :mod:`.export`  — ``export_trace(path)`` (Chrome trace / Perfetto
  JSON), ``report()`` (plain-text summary), ``snapshot()`` (the same as
  a dict, embedded in benchmark ``--json`` payloads).

Quick tour::

    from repro import obs
    obs.enable()                   # sync=True: measured wall-clock
    y = compiled(x)                # instrumented executor records
    print(obs.report())
    obs.export_trace("run.trace.json")   # open in chrome://tracing
    obs.reset(); obs.disable()

``obs.kernel_counts()`` uses the same vocabulary as
``CompiledExpr.cost(...)["kernels"]``, so model honesty is one dict
comparison; ``obs.cache_stats()`` aggregates every executor/ops cache.
"""
from .trace import (disable, enable, enabled, events, record_event, reset as
                    _reset_trace, span, sync_enabled)
from .metrics import (class_counts, counter_total, counter_value, counters,
                      histograms, inc, kernel_counts, observe,
                      reset as _reset_metrics)
from .export import (cache_stats, export_trace, model_vs_measured, report,
                     snapshot)


def reset() -> None:
    """Drop all recorded spans, counters and histograms (the enabled
    flag is untouched)."""
    _reset_trace()
    _reset_metrics()


__all__ = [
    "enable", "disable", "enabled", "sync_enabled", "reset", "span",
    "events", "record_event", "inc", "observe", "counters",
    "counter_value", "counter_total", "histograms", "kernel_counts",
    "class_counts", "cache_stats", "export_trace", "model_vs_measured",
    "report", "snapshot",
]
