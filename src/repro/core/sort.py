"""Sorting networks via ``parm`` (paper §7.1) and their BMMC compilation.

The paper's example: a merge sort whose merger is the balanced periodic
merger [Dowd et al.]::

    sort 0 xs = xs
    sort n xs = merge n (parm 1 (sort (n-1)) xs)

    merge 0 xs = xs
    merge n xs = parm 2^(n-1) (merge (n-1)) (vcolumn n xs)

    vcolumn 1 = compare-exchange
    vcolumn n = parm 3 (vcolumn (n-1))

Two implementations are provided:

* ``sort_rec`` — direct recursion with ``parm`` (reference semantics).
* ``compile_sort`` — compiles the whole network into a *stage program*:
  an alternating sequence ``Perm(BMMC) / CmpHalves`` where adjacent BMMC
  permutations are **fused** (``bmmc B ∘ bmmc A = bmmc (B A)``, the rewrite
  algebra of §7.2), so the executed program is exactly one fused BMMC
  permutation between consecutive compare-exchange sweeps.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, List, Union

import jax.numpy as jnp
import numpy as np

from . import f2
from .bmmc import Bmmc
from .parm import parm_matrix, parm_ref


# ---------------------------------------------------------------------------
# Reference recursion (numpy oracle, paper pseudocode transliterated)
# ---------------------------------------------------------------------------

def _cmpex(xs):
    """Compare-exchange on a 2-element array: min first."""
    a, b = xs[0], xs[1]
    return np.stack([np.minimum(a, b), np.maximum(a, b)])


def vcolumn_rec(n: int, xs):
    if n == 0:
        return xs
    if n == 1:
        return _cmpex(xs)
    return parm_ref(3, lambda h: vcolumn_rec(n - 1, h), xs)


def merge_rec(n: int, xs):
    if n == 0:
        return xs
    ys = vcolumn_rec(n, xs)
    return parm_ref(1 << (n - 1), lambda h: merge_rec(n - 1, h), ys)


def sort_rec(n: int, xs):
    if n == 0:
        return xs
    ys = parm_ref(1, lambda h: sort_rec(n - 1, h), xs)
    return merge_rec(n, ys)


# ---------------------------------------------------------------------------
# Stage-program compilation
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class Perm:
    bmmc: Bmmc


@dataclasses.dataclass(frozen=True)
class CmpHalves:
    """out[:h] = min(a[:h], a[h:]); out[h:] = max — one full-width sweep."""


Stage = Union[Perm, CmpHalves]


def _lift(stages: List[Stage], n: int) -> List[Stage]:
    """Lift a program on 2^(n-1) arrays to act on both halves of a 2^n array.

    * ``Perm(A')`` lifts to the block-diagonal BMMC diag(A', 1).
    * ``CmpHalves`` on halves compares i <-> i + 2^(n-2) within each half;
      conjugating with the (n-2, n-1) bit swap turns it into a full-width
      ``CmpHalves`` (the swaps fuse with neighbouring perms).
    """
    out: List[Stage] = []
    swap = Bmmc.from_perm([*range(n - 2), n - 1, n - 2])  # exchange top two bits
    for s in stages:
        if isinstance(s, Perm):
            rows = tuple(s.bmmc.rows) + (1 << (n - 1),)
            out.append(Perm(Bmmc(rows, s.bmmc.c)))
        else:
            out.extend([Perm(swap), CmpHalves(), Perm(swap)])
    return out


def _parm_net(n: int, mask: int, sub: List[Stage]) -> List[Stage]:
    a = parm_matrix(n, mask)
    return [Perm(a)] + _lift(sub, n) + [Perm(a.inverse())]


def compile_vcolumn(n: int) -> List[Stage]:
    if n == 0:
        return []
    if n == 1:
        return [CmpHalves()]
    return _parm_net(n, 3, compile_vcolumn(n - 1))


def compile_merge(n: int) -> List[Stage]:
    if n == 0:
        return []
    return compile_vcolumn(n) + _parm_net(n, 1 << (n - 1), compile_merge(n - 1))


def compile_sort(n: int) -> List[Stage]:
    if n == 0:
        return []
    return _parm_net(n, 1, compile_sort(n - 1)) + compile_merge(n)


def fuse(stages: List[Stage]) -> List[Stage]:
    """Fuse adjacent Perm stages and drop identities (the §7.2 rewrite)."""
    out: List[Stage] = []
    for s in stages:
        if isinstance(s, Perm) and out and isinstance(out[-1], Perm):
            out[-1] = Perm(s.bmmc @ out[-1].bmmc)
        else:
            out.append(s)
    return [s for s in out
            if not (isinstance(s, Perm) and s.bmmc.is_identity_perm())]


def run_stages(stages: List[Stage], xs, *, engine: Callable = None):
    """Execute a stage program on a jax array of size 2^n."""
    if engine is None:
        from ..kernels import ref as _ref
        engine = _ref.bmmc_ref
    for s in stages:
        if isinstance(s, Perm):
            xs = engine(xs, s.bmmc)
        else:
            h = xs.shape[0] // 2
            lo, hi = xs[:h], xs[h:]
            xs = jnp.concatenate([jnp.minimum(lo, hi), jnp.maximum(lo, hi)])
    return xs


def sort_compiled(xs, *, engine: Callable = None):
    n = int(np.log2(xs.shape[0]))
    return run_stages(fuse(compile_sort(n)), xs, engine=engine)


def num_perm_stages(stages: List[Stage]) -> int:
    return sum(isinstance(s, Perm) for s in stages)
