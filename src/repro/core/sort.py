"""Sorting networks via ``parm`` (paper §7.1) — combinator-IR backed.

The paper's example: a merge sort whose merger is the balanced periodic
merger [Dowd et al.]::

    sort 0 xs = xs
    sort n xs = merge n (parm 1 (sort (n-1)) xs)

    merge 0 xs = xs
    merge n xs = parm 2^(n-1) (merge (n-1)) (vcolumn n xs)

    vcolumn 1 = compare-exchange
    vcolumn n = parm 3 (vcolumn (n-1))

Two implementations are provided:

* ``sort_rec`` — direct recursion with ``parm`` (reference semantics).
* ``compile_sort`` — the network as a :mod:`repro.combinators` stage
  program: ``fuse`` applies the §7.2 rewrite (``bmmc B ∘ bmmc A =
  bmmc (BA)``), leaving exactly one fused BMMC permutation between
  consecutive compare-exchange sweeps.

This module is a thin compatibility facade: the expression language,
optimizer, and executor live in :mod:`repro.combinators` (which see).
"""
from __future__ import annotations

from typing import Callable, List, Sequence, Union

import numpy as np

from ..combinators.execute import run_program
from ..combinators.ir import CmpHalves, Expr, Perm
from ..combinators.optimize import fuse as _fuse_program
from ..combinators.optimize import lower, num_perm_stages as _num_perm
from ..combinators.sort import merge_expr, sort_expr, vcolumn_expr
from .parm import parm_ref

Stage = Expr  # a lowered program is a sequence of primitive Expr stages

__all__ = ["Perm", "CmpHalves", "Stage", "sort_rec", "merge_rec",
           "vcolumn_rec", "compile_sort", "compile_merge", "compile_vcolumn",
           "fuse", "run_stages", "sort_compiled", "num_perm_stages"]


# ---------------------------------------------------------------------------
# Reference recursion (numpy oracle, paper pseudocode transliterated)
# ---------------------------------------------------------------------------

def _cmpex(xs):
    """Compare-exchange on a 2-element array: min first."""
    a, b = xs[0], xs[1]
    return np.stack([np.minimum(a, b), np.maximum(a, b)])


def vcolumn_rec(n: int, xs):
    if n == 0:
        return xs
    if n == 1:
        return _cmpex(xs)
    return parm_ref(3, lambda h: vcolumn_rec(n - 1, h), xs)


def merge_rec(n: int, xs):
    if n == 0:
        return xs
    ys = vcolumn_rec(n, xs)
    return parm_ref(1 << (n - 1), lambda h: merge_rec(n - 1, h), ys)


def sort_rec(n: int, xs):
    if n == 0:
        return xs
    ys = parm_ref(1, lambda h: sort_rec(n - 1, h), xs)
    return merge_rec(n, ys)


# ---------------------------------------------------------------------------
# Stage-program compilation (combinator IR lowering)
# ---------------------------------------------------------------------------

def compile_vcolumn(n: int) -> List[Stage]:
    return list(lower(vcolumn_expr(n), n))


def compile_merge(n: int) -> List[Stage]:
    return list(lower(merge_expr(n), n))


def compile_sort(n: int) -> List[Stage]:
    return list(lower(sort_expr(n), n))


def fuse(stages: Sequence[Stage]) -> List[Stage]:
    """Fuse adjacent Perm stages and drop identities (the §7.2 rewrite)."""
    return list(_fuse_program(tuple(stages)))


def run_stages(stages: Sequence[Stage], xs, *,
               engine: Union[str, Callable, None] = None):
    """Execute a stage program on a jax array of size 2^n.

    ``engine``: an engine name from :mod:`repro.combinators.execute`
    ("ref"/"pallas"), a callable ``(x, bmmc) -> x``, or None for "ref".
    """
    return run_program(tuple(stages), xs, engine)


def sort_compiled(xs, *, engine: Union[str, Callable, None] = None):
    n = int(np.log2(xs.shape[0]))
    return run_stages(fuse(compile_sort(n)), xs, engine=engine)


def num_perm_stages(stages: Sequence[Stage]) -> int:
    return _num_perm(stages)
