"""Distributed BMMC permutations over sharded arrays (beyond-paper).

For an array of 2^n elements sharded along the leading axis over 2^s
devices, the global index splits as x = (shard || local). This module
factors any global BMMC into a short sequence of *rounds*:

* ``LocalRound``   — per-shard BMMC on local indices, with a shard-dependent
                     complement (``c_eff = c ^ A_ls . shard``): zero
                     communication;
* ``PermuteRound`` — an affine relabeling of shards
                     (``shard' = S . shard ^ c_s``): one collective_permute;
* ``ExchangeRound``— swap the top-k local index bits with the low-k shard
                     bits: one (sub-axis) all_to_all.

Construction (generalizing paper §5.2 to the sharded setting): with the
F2 decomposition A = U L P and L = R U' R (R = bit reversal),

    A  =  U  ∘  R  ∘  U'  ∘  (R P)

where U, U' are shard-*separable* (upper-triangular => shard-out depends
only on shard-in) and R, RP are bit permutations, each of which lowers to
[permute, local, exchange(k), local, permute]. After fusing adjacent rounds
the worst case is **2 exchange rounds + 2 permute rounds + O(1) local
rounds** — the sharded analogue of the paper's two-pass theorem.

Every plan is verified *offline* by composing the rounds back into a global
BMMC (`plan_to_bmmc(plan) == A`); the executor (`run_plan`, shard_map over
a binary sub-axis mesh) is validated on fake multi-device CPU meshes in
tests/test_distributed.py.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Union

import numpy as np

from . import f2
from .bmmc import Bmmc


# ---------------------------------------------------------------------------
# Round IR
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class LocalRound:
    n_local: int
    rows: tuple          # (n_local) x (n_local) local matrix
    c: int               # static complement
    ls_rows: tuple       # n_local rows over s shard bits: c_eff ^= ls . shard


@dataclasses.dataclass(frozen=True)
class PermuteRound:
    s: int
    rows: tuple          # s x s shard matrix
    c: int


@dataclasses.dataclass(frozen=True)
class ExchangeRound:
    k: int               # swap local bits [n_local-k, n_local) with shard bits [0, k)


Round = Union[LocalRound, PermuteRound, ExchangeRound]


# ---------------------------------------------------------------------------
# Rounds -> global BMMC (offline verification)
# ---------------------------------------------------------------------------

def round_to_bmmc(r: Round, n: int, s: int) -> Bmmc:
    nl = n - s
    if isinstance(r, LocalRound):
        rows = [r.rows[i] | (r.ls_rows[i] << nl) for i in range(nl)]
        rows += [1 << i for i in range(nl, n)]
        return Bmmc(tuple(rows), r.c)
    if isinstance(r, PermuteRound):
        rows = [1 << i for i in range(nl)]
        rows += [r.rows[i - nl] << nl for i in range(nl, n)]
        return Bmmc(tuple(rows), r.c << nl)
    # ExchangeRound: transpositions local nl-k+m <-> shard nl+m
    p = list(range(n))
    for m in range(r.k):
        p[nl - r.k + m], p[nl + m] = p[nl + m], p[nl - r.k + m]
    return Bmmc.from_perm(p)


def plan_to_bmmc(plan: List[Round], n: int, s: int) -> Bmmc:
    out = Bmmc.identity(n)
    for r in plan:
        out = round_to_bmmc(r, n, s) @ out
    return out


# ---------------------------------------------------------------------------
# Plan construction
# ---------------------------------------------------------------------------

def _split_blocks(b: Bmmc, s: int):
    """A = [[A_ll, A_ls], [A_sl, A_ss]] in the (local, shard) basis."""
    n = b.n
    nl = n - s
    lmask = (1 << nl) - 1
    a_ll = tuple(b.rows[i] & lmask for i in range(nl))
    a_ls = tuple(b.rows[i] >> nl for i in range(nl))
    a_sl = tuple(b.rows[i] & lmask for i in range(nl, n))
    a_ss = tuple(b.rows[i] >> nl for i in range(nl, n))
    return a_ll, a_ls, a_sl, a_ss


def _separable_rounds(b: Bmmc, s: int) -> List[Round]:
    """b with A_sl == 0: local round then shard permute."""
    n = b.n
    nl = n - s
    a_ll, a_ls, a_sl, a_ss = _split_blocks(b, s)
    assert all(v == 0 for v in a_sl), "factor is not shard-separable"
    return [
        LocalRound(nl, a_ll, b.c & ((1 << nl) - 1), a_ls),
        PermuteRound(s, a_ss, b.c >> nl),
    ]


def _local_perm(positions_to_top: List[int], nl: int) -> list:
    """Local bit perm sending sorted(positions) to the top |positions| bits."""
    k = len(positions_to_top)
    rest = [j for j in range(nl) if j not in set(positions_to_top)]
    p = [0] * nl
    for i, j in enumerate(rest):
        p[j] = i
    for m, j in enumerate(sorted(positions_to_top)):
        p[j] = nl - k + m
    return p


def _bp_rounds(b: Bmmc, s: int) -> List[Round]:
    """Bit-permutation factor -> [permute, local, exchange, local, permute]."""
    n = b.n
    nl = n - s
    p = b.perm()
    assert p is not None and b.c == 0, "expected a BP factor"
    a2 = [j for j in range(nl) if p[j] >= nl]          # local -> shard
    b2 = [j for j in range(nl, n) if p[j] < nl]        # shard -> local
    k = len(a2)
    assert len(b2) == k
    rounds: List[Round] = []

    # sigma1: relabel shard bits so the departing ones (b2) occupy the
    # exchange window [0, k); the rest stack above in order.
    b2_bits = set(j - nl for j in b2)
    sig1 = [0] * s
    m = 0
    for j in sorted(b2_bits):
        sig1[j] = m
        m += 1
    fill = k
    for j in range(s):
        if j not in b2_bits:
            sig1[j] = fill
            fill += 1
    rounds.append(PermuteRound(s, f2.from_perm(sig1), 0))

    # L1: move the departing local bits (a2) to the top-k local positions
    l1 = _local_perm(a2, nl)
    rounds.append(LocalRound(nl, f2.from_perm(l1), 0, tuple([0] * nl)))

    if k:
        rounds.append(ExchangeRound(k))

    # solve the remainder: rho = b ∘ (sigma1;l1;X)^-1 must be block diagonal
    partial = plan_to_bmmc(rounds, n, s)
    rho = b @ partial.inverse()
    a_ll, a_ls, a_sl, a_ss = _split_blocks(rho, s)
    assert all(v == 0 for v in a_sl), "bp residue: shard<-local leak"
    assert all(v == 0 for v in a_ls), "bp residue: local<-shard leak"
    rounds.append(LocalRound(nl, a_ll, 0, tuple([0] * nl)))
    rounds.append(PermuteRound(s, a_ss, 0))
    return rounds


def _fuse(plan: List[Round], n: int, s: int) -> List[Round]:
    """Merge adjacent same-type rounds; drop identities."""
    nl = n - s
    out: List[Round] = []
    for r in plan:
        if out and isinstance(r, LocalRound) and isinstance(out[-1], LocalRound):
            prev = out[-1]
            rows = f2.matmul(r.rows, prev.rows)
            # combine: y = R2 (R1 x ^ L1 sigma ^ c1) ^ L2 sigma ^ c2
            ls_cols = []
            for bit in range(s):
                col_prev = sum(((prev.ls_rows[i] >> bit) & 1) << i
                               for i in range(nl))
                col_new = f2.matvec(r.rows, col_prev)
                col_new ^= sum(((r.ls_rows[i] >> bit) & 1) << i
                               for i in range(nl))
                ls_cols.append(col_new)
            ls = tuple(sum(((ls_cols[bit] >> i) & 1) << bit
                           for bit in range(s)) for i in range(nl))
            c = f2.matvec(r.rows, prev.c) ^ r.c
            out[-1] = LocalRound(nl, rows, c, ls)
        elif out and isinstance(r, PermuteRound) and isinstance(out[-1], PermuteRound):
            prev = out[-1]
            out[-1] = PermuteRound(s, f2.matmul(r.rows, prev.rows),
                                   f2.matvec(r.rows, prev.c) ^ r.c)
        else:
            out.append(r)
    cleaned = []
    for r in out:
        if isinstance(r, LocalRound) and r.rows == f2.identity(nl) \
                and r.c == 0 and all(v == 0 for v in r.ls_rows):
            continue
        if isinstance(r, PermuteRound) and r.rows == f2.identity(s) and r.c == 0:
            continue
        if isinstance(r, ExchangeRound) and r.k == 0:
            continue
        cleaned.append(r)
    return cleaned


def make_plan(bmmc: Bmmc, s: int) -> List[Round]:
    """Factor a global BMMC into rounds for 2^s leading-axis shards."""
    n = bmmc.n
    assert 0 < s < n
    a_ll, a_ls, a_sl, a_ss = _split_blocks(bmmc, s)
    if all(v == 0 for v in a_sl):
        plan = _separable_rounds(bmmc, s)
    else:
        u, l, p = f2.ulp(bmmc.rows)
        r = f2.reversal(n)
        u2 = f2.matmul(r, f2.matmul(l, r))            # upper (= R L R)
        rp = Bmmc(f2.matmul(r, p), 0)                 # BP
        plan = []
        plan += _bp_rounds(rp, s)
        plan += _separable_rounds(Bmmc(u2, 0), s)
        plan += _bp_rounds(Bmmc.bit_reverse(n), s)
        plan += _separable_rounds(Bmmc(u, bmmc.c), s)
    plan = _fuse(plan, n, s)
    got = plan_to_bmmc(plan, n, s)
    assert got.rows == bmmc.rows and got.c == bmmc.c, "plan verification failed"
    return plan


def plan_cost(plan: List[Round]) -> dict:
    return {
        "local": sum(isinstance(r, LocalRound) for r in plan),
        "permute": sum(isinstance(r, PermuteRound) for r in plan),
        "exchange": sum(isinstance(r, ExchangeRound) for r in plan),
        "exchange_bits": sum(r.k for r in plan if isinstance(r, ExchangeRound)),
    }


# ---------------------------------------------------------------------------
# Executor (shard_map over a binary sub-axis mesh)
# ---------------------------------------------------------------------------

def binary_mesh(s: int):
    """Mesh of 2^s devices as s binary axes sb{s-1}..sb0 (msb first)."""
    import jax
    names = tuple(f"sb{m}" for m in reversed(range(s)))
    kw = {}
    if hasattr(jax.sharding, "AxisType"):  # absent before jax 0.5
        kw["axis_types"] = (jax.sharding.AxisType.Auto,) * s
    return jax.make_mesh((2,) * s, names, **kw)


def run_plan(x, plan: List[Round], s: int, mesh=None):
    """Apply a distributed BMMC plan to ``x`` (shape (2^n,) or (2^n, d))."""
    import jax
    import jax.numpy as jnp
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P

    mesh = mesh or binary_mesh(s)
    names_msb = tuple(f"sb{m}" for m in reversed(range(s)))
    spec = P(names_msb) if x.ndim == 1 else P(names_msb, None)
    nl = int(np.log2(x.shape[0])) - s

    def shard_fn(xs):
        def my_shard():
            sig = jnp.zeros((), jnp.int32)
            for m in range(s):
                sig = sig | (jax.lax.axis_index(f"sb{m}").astype(jnp.int32) << m)
            return sig

        for r in plan:
            if isinstance(r, LocalRound):
                inv = f2.inverse(r.rows)
                y = np.arange(1 << nl, dtype=np.uint32)
                base = np.zeros_like(y)
                for i, row in enumerate(inv):
                    base |= ((np.bitwise_count(y & np.uint32(row)) & 1)
                             .astype(np.uint32)) << np.uint32(i)
                # dynamic complement: c_eff = c ^ (ls . shard);
                # src[y] = inv.(y ^ c_eff) = base[y] ^ inv.c_eff
                sig = my_shard()
                c_eff = jnp.uint32(r.c)
                for i in range(nl):
                    bit = jax.lax.population_count(
                        jnp.uint32(sum(((r.ls_rows[i] >> b) & 1) << b
                                       for b in range(s))) &
                        sig.astype(jnp.uint32)) & 1
                    c_eff = c_eff ^ (bit.astype(jnp.uint32) << i)
                inv_c = jnp.zeros((), jnp.uint32)
                for i, row in enumerate(inv):
                    bit = jax.lax.population_count(jnp.uint32(row) & c_eff) & 1
                    inv_c = inv_c | (bit.astype(jnp.uint32) << i)
                src = jnp.asarray(base) ^ inv_c
                xs = jnp.take(xs, src.astype(jnp.int32), axis=0)
            elif isinstance(r, PermuteRound):
                pairs = [(sig, f2.matvec(r.rows, sig) ^ r.c)
                         for sig in range(1 << s)]
                xs = jax.lax.ppermute(xs, names_msb, pairs)
            else:  # ExchangeRound
                k = r.k
                tail = xs.shape[1:]
                xs2 = xs.reshape((1 << k, 1 << (nl - k)) + tail)
                ex_names = tuple(f"sb{m}" for m in reversed(range(k)))
                xs2 = jax.lax.all_to_all(xs2, ex_names, split_axis=0,
                                         concat_axis=0, tiled=True)
                xs = xs2.reshape((1 << nl,) + tail)
        return xs

    fn = shard_map(shard_fn, mesh=mesh, in_specs=spec, out_specs=spec)
    from jax.sharding import NamedSharding
    x = jax.device_put(x, NamedSharding(mesh, spec))
    return fn(x)


def distributed_bmmc(x, bmmc: Bmmc, s: int, mesh=None):
    """End-to-end: plan + execute a BMMC over a 2^s-sharded array."""
    return run_plan(x, make_plan(bmmc, s), s, mesh)
