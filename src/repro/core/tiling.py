"""Tile-bit partitioning and offline table generation (paper §4.1-4.3, §5.1).

For a *tiled* BMMC ``(A, c)`` on ``n``-bit indices and tile parameter ``t``
(= ``n_tile``; one "row" = 2^t consecutive elements), input index bits are
partitioned into:

* tile column bits  — the low ``t`` bits (set L),
* tile row bits     — the witness columns ``i_1..i_t`` (set R; for a BPC these
  are exactly ``{j : p(j) < t}``),
* overlap bits      — R ∩ L (``n_over`` of them),
* thread-block bits — the rest (``n_TB = n - 2t + n_over``), all >= t.

One tile = all index combinations of (L ∪ R) bits with the block bits fixed:
``2^(t - n_over)`` full input rows, mapping onto ``2^(t - n_over)`` full
output rows. This module precomputes, per permutation (offline, matching the
paper's codegen setting):

* ``in_rows[g, r]``   — input row id read by tile ``g`` (row view: (2^(n-t), 2^t)),
* ``out_rows[g, r']`` — output row id written by tile ``g``,
* ``xor_low[g]``      — per-tile XOR on the intra-tile lane gather (the
  block-bit contribution to the low output bits; 0 for every BPC),
* ``src0``            — flat intra-tile gather table for tile 0:
  ``out_tile.flat[j] = in_tile.flat[src0[j ^ xor_low[g]]]``.

The per-tile XOR trick is the TPU replacement for re-deriving indices per
thread: tables are computed once; the kernel's scalar core only reads them.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np

from .bmmc import Bmmc
from . import f2


def _scatter_bits(value: int, positions: list) -> int:
    """Place bit k of ``value`` at ``positions[k]``."""
    out = 0
    for k, pos in enumerate(positions):
        if (value >> k) & 1:
            out |= 1 << pos
    return out


def _gather_bits(value: int, positions: list) -> int:
    """Collect bits of ``value`` at ``positions`` into a compact int."""
    out = 0
    for k, pos in enumerate(positions):
        if (value >> pos) & 1:
            out |= 1 << k
    return out


def _run_length(rows: np.ndarray) -> int:
    """Largest power-of-two run of consecutive row ids shared by all tiles.

    This is the DMA-merge factor: ``run`` consecutive rows can be copied by a
    single descriptor (the TPU analogue of the paper's §4.3 amortization).
    """
    n_tiles, rpt = rows.shape
    run = 1
    while run * 2 <= rpt:
        nxt = run * 2
        blocks = rows.reshape(n_tiles, rpt // nxt, nxt)
        diff = blocks - blocks[..., :1]
        if np.array_equal(diff, np.broadcast_to(np.arange(nxt), diff.shape)):
            run = nxt
        else:
            break
    return run


@dataclasses.dataclass(frozen=True)
class TilePlan:
    """Offline execution plan for one tiled-BMMC pass.

    ``row_dirs`` are the witness *directions* spanning the tile's row
    structure — full n-bit vectors whose high parts are independent;
    tile slot ``r`` holds rows offset by ``XOR(row_dirs[k] for bits k of
    r)``. For a classically tiled plan (paper §5.1) these are the unit
    vectors of the witness columns above ``t``; the generalized planner
    (:func:`plan_general`) uses any basis of ``ker(A[t:, :])``, which
    always exists — so every invertible BMMC gets a ONE-pass plan.
    """

    bmmc: Bmmc
    t: int                      # n_tile: log2 elements per row
    row_cols: tuple             # R, sorted (classic witness; () if general)
    n_over: int
    tb_positions: tuple         # thread-block bit positions, sorted (all >= t)
    in_rows: np.ndarray         # (n_tiles, rows_per_tile) int32
    out_rows: np.ndarray        # (n_tiles, rows_per_tile) int32
    xor_low: np.ndarray         # (n_tiles,) int32
    src0: np.ndarray            # (rows_per_tile, 2^t) int32 flat gather table
    in_run: int                 # input DMA merge run (rows)
    out_run: int                # output DMA merge run (rows)
    row_dirs: tuple = ()        # witness directions, len == log2(rows_per_tile)

    @property
    def n(self) -> int:
        return self.bmmc.n

    @property
    def n_tiles(self) -> int:
        return self.in_rows.shape[0]

    @property
    def rows_per_tile(self) -> int:
        return self.in_rows.shape[1]

    @property
    def row_len(self) -> int:
        return 1 << self.t

    # -- modeled memory transactions (the quantity behind the paper's
    # -- bandwidth results; used by the benchmark harness) -------------------
    def dma_descriptors(self) -> int:
        """Total HBM DMA descriptors issued (reads + writes)."""
        per_tile = self.rows_per_tile // self.in_run + self.rows_per_tile // self.out_run
        return self.n_tiles * per_tile

    def bytes_per_descriptor(self, itemsize: int) -> tuple:
        return (self.in_run * self.row_len * itemsize,
                self.out_run * self.row_len * itemsize)

    def audit(self) -> "TilePlan":
        """Descriptor-bounds + semantic audit (guard ring 1): every
        table entry within the geometry, ``src0`` a bijection, and the
        kernel contract routing exactly what the BMMC demands. Raises
        :class:`repro.guard.DescriptorOOB`."""
        from ..guard.validate import audit_tile_plan  # lazy: no cycle
        audit_tile_plan(self)
        return self


def plan_tiled(bmmc: Bmmc, t: int) -> Optional[TilePlan]:
    """Build a TilePlan, or None if ``bmmc`` is not tiled for this ``t``."""
    n = bmmc.n
    if 2 * t > n + t:  # t > n: nonsensical
        return None
    cols = bmmc.tiled_columns(t)
    if cols is None:
        return None
    low = set(range(t))
    r_set = set(cols)
    n_over = len(r_set & low)
    if n - 2 * t + n_over < 0:
        return None  # tile would exceed the array; caller falls back
    r_not_l = sorted(r_set - low)           # t - n_over positions, all >= t
    l_not_r = sorted(low - r_set)           # t - n_over positions, all < t
    tb = sorted(set(range(n)) - low - r_set)
    n_tb = len(tb)
    assert n_tb == n - 2 * t + n_over

    rpt = 1 << (t - n_over)                  # rows per tile
    n_tiles = 1 << n_tb
    row_len = 1 << t
    low_mask = row_len - 1

    ainv = bmmc.inverse()

    in_rows = np.empty((n_tiles, rpt), dtype=np.int32)
    out_rows = np.empty((n_tiles, rpt), dtype=np.int32)
    xor_low = np.empty((n_tiles,), dtype=np.int32)

    # Row tables. y_high = A[t:, :] x ^ c_high depends only on non-R bits of x
    # (the zero block kills R), i.e. on (L\R, TB): enumerate r' over L\R.
    for g in range(n_tiles):
        base = _scatter_bits(g, tb)
        delta = f2.matvec(bmmc.rows, base)
        xor_low[g] = delta & low_mask
        for r in range(rpt):
            in_rows[g, r] = (base | _scatter_bits(r, r_not_l)) >> t
        for rp in range(rpt):
            y = bmmc.apply(base | _scatter_bits(rp, l_not_r))
            out_rows[g, rp] = y >> t

    # Intra-tile gather table for tile 0 (other tiles differ by xor_low only).
    src0 = np.empty((rpt, row_len), dtype=np.int32)
    for rp in range(rpt):
        y_hi = int(out_rows[0, rp]) << t
        for cp in range(row_len):
            x = ainv.apply(y_hi | cp)
            assert _gather_bits(x, tb) == 0, "tile-0 source must be in tile 0"
            r = _gather_bits(x, r_not_l)
            src0[rp, cp] = r * row_len + (x & low_mask)
    return TilePlan(
        bmmc=bmmc, t=t, row_cols=tuple(sorted(cols)), n_over=n_over,
        tb_positions=tuple(tb), in_rows=in_rows, out_rows=out_rows,
        xor_low=xor_low, src0=src0,
        in_run=_run_length(in_rows), out_run=_run_length(out_rows),
        row_dirs=tuple(1 << p for p in r_not_l),
    )


# ---------------------------------------------------------------------------
# Generalized one-pass planning (§5.1 with witness *directions*).
#
# The classic tiled condition demands t witness COLUMNS: unit directions
# e_j with A e_j supported on the low t rows. But the kernel's actual
# requirements are weaker: (1) each tile reads whole input rows, (2)
# writes whole output rows, (3) tiles share one gather table up to a
# per-tile lane XOR. All three survive replacing unit directions by ANY
# basis of D = ker(A[t:, :]) — which has dimension exactly t for every
# invertible A. Splitting D into pure-low directions (a of them; the
# n_over analogue) and directions with independent high parts (the
# rows-per-tile span), and choosing the thread-block complement among
# the HIGH unit positions (so the per-tile base never touches the
# lanes), yields tables honouring the exact same kernel contract:
#
#     out.flat[j] = tile.flat[src0[j ^ xor_low[g]]]
#
# Consequence: any BMMC with n - 2t + a >= 0 (always true for 2t <= n)
# runs in ONE tiled pass — the §5.2 two-pass factorization becomes a
# fallback for t > n/2 instead of the general path.
# ---------------------------------------------------------------------------


def _split_directions(bmmc: Bmmc, t: int) -> tuple:
    """Basis of ``ker(A[t:, :])`` split into (a, row_dirs): ``a`` counts
    the pure-low directions; ``row_dirs`` have independent high parts."""
    d = f2.nullspace(bmmc.rows[t:], bmmc.n)
    assert len(d) == t, "kernel of the high rows must have dimension t"
    row_dirs: list = []
    a = 0
    for v in d:
        h = v >> t
        for w in row_dirs:  # eliminate previously-chosen high pivots
            if h & ((w >> t) & -(w >> t)):
                v ^= w
                h = v >> t
        if h == 0:
            a += 1
        else:
            row_dirs.append(v)
    return a, row_dirs


def _tb_complement(row_dirs: list, t: int, n: int) -> list:
    """High unit positions completing ``{high(row_dirs)}`` to F2^(n-t)."""
    gens = [v >> t for v in row_dirs]
    tb = []
    for pos in range(t, n):
        u = 1 << (pos - t)
        if not f2.in_span(u, gens):
            gens.append(u)
            tb.append(pos)
    return tb


def _xor_dirs(r: int, row_dirs) -> int:
    v = 0
    k = 0
    while r:
        if r & 1:
            v ^= row_dirs[k]
        r >>= 1
        k += 1
    return v


def _out_low_positions(bmmc: Bmmc, t: int, count: int) -> list:
    """Low unit positions whose images under A[t:, :] are independent —
    these enumerate a tile's distinct output rows."""
    chosen: list = []
    imgs: list = []
    for j in range(t):
        img = f2.matvec(bmmc.rows, 1 << j) >> t
        if img and not f2.in_span(img, imgs):
            imgs.append(img)
            chosen.append(j)
            if len(chosen) == count:
                break
    assert len(chosen) == count, "output row images must span"
    return chosen


def plan_general(bmmc: Bmmc, t: int) -> Optional[TilePlan]:
    """One-pass plan for an arbitrary invertible BMMC (see block comment
    above). Returns None when the tile would exceed the array
    (``n - 2t + a < 0``, only possible for t > n/2)."""
    n = bmmc.n
    if not 0 < t <= n:
        return None
    low_mask = (1 << t) - 1
    a, row_dirs = _split_directions(bmmc, t)
    if n - 2 * t + a < 0:
        return None
    tb = _tb_complement(row_dirs, t, n)
    rpt = 1 << (t - a)
    n_tiles = 1 << len(tb)
    row_len = 1 << t
    chosen_low = _out_low_positions(bmmc, t, t - a)
    ainv = bmmc.inverse()

    in_rows = np.empty((n_tiles, rpt), dtype=np.int32)
    out_rows = np.empty((n_tiles, rpt), dtype=np.int32)
    xor_low = np.empty((n_tiles,), dtype=np.int32)
    for g in range(n_tiles):
        base = _scatter_bits(g, tb)
        xor_low[g] = f2.matvec(bmmc.rows, base) & low_mask
        for r in range(rpt):
            in_rows[g, r] = (base ^ _xor_dirs(r, row_dirs)) >> t
        for rp in range(rpt):
            y = bmmc.apply(base ^ _scatter_bits(rp, chosen_low))
            out_rows[g, rp] = y >> t

    slot_of_row = {int(in_rows[0, r]): r for r in range(rpt)}
    assert len(slot_of_row) == rpt, "tile rows must be distinct"
    src0 = np.empty((rpt, row_len), dtype=np.int32)
    for rp in range(rpt):
        y_hi = int(out_rows[0, rp]) << t
        for cp in range(row_len):
            x = ainv.apply(y_hi | cp)
            r = slot_of_row.get(x >> t)
            assert r is not None, "tile-0 source must be in tile 0"
            src0[rp, cp] = r * row_len + (x & low_mask)
    return TilePlan(
        bmmc=bmmc, t=t, row_cols=(), n_over=a, tb_positions=tuple(tb),
        in_rows=in_rows, out_rows=out_rows, xor_low=xor_low, src0=src0,
        in_run=_run_length(in_rows), out_run=_run_length(out_rows),
        row_dirs=tuple(row_dirs),
    )


# ---------------------------------------------------------------------------
# Fused-compute tables: everything a megakernel epilogue needs to run a
# CmpHalves / Bfly stage on the tile while it sits in VMEM (DESIGN.md §10).
#
# The compute pairs intermediate index m with m ^ 2^(n-1), where m = M x
# (+) c_M and M is the composition of the run's perms *before* the
# compute. Pulled back to input space the partner of x is x ^ v with
# v = A_M^-1 e_{n-1}; when v lies in the span of the plan's tile row (R)
# and column (L) bits, the partner is resident in the same tile at
# position (r ^ vr, lane ^ vc). Which element of a pair is the "hi" half
# (bit n-1 of m set) and which twiddle a butterfly pair uses are affine
# in x, so they split into tiny per-row / per-lane tables XORed with one
# per-tile scalar — the same trick as `xor_low`.
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class ComputeTables:
    """Offline tables for one in-VMEM compute applied inside a tiled pass."""

    kind: str                        # "cmp" | "bfly"
    vr: int                          # partner XOR on the tile-row slot
    vc: int                          # partner XOR on the lane
    hi_row: np.ndarray               # (rows_per_tile,) int32 parity bits
    hi_lane: np.ndarray              # (row_len,) int32 parity bits
    hi_base: np.ndarray              # (n_tiles,) int32 per-tile parity bit
    tw_row: Optional[np.ndarray] = None    # (rows_per_tile,) int32 (bfly)
    tw_lane: Optional[np.ndarray] = None   # (row_len,) int32 (bfly)
    tw_base: Optional[np.ndarray] = None   # (n_tiles,) int32 (bfly)


def pairing_vector(prefix: Bmmc) -> int:
    """The input-space partner XOR ``v = A_M^{-1} e_{n-1}`` of a compute
    whose pair bit is n-1 in the output space of ``prefix``."""
    return f2.matvec(f2.inverse(prefix.rows), 1 << (prefix.n - 1))


def _dir_coords(v: int, row_dirs: tuple, t: int) -> Optional[int]:
    """Coordinates ``vr`` with ``high(v) == high(XOR(row_dirs[k] for bits
    k of vr))``, or None when ``high(v)`` escapes the span."""
    red: list = []                          # (high part, coordinate mask)
    for k, d in enumerate(row_dirs):
        hp, co = d >> t, 1 << k
        for rh, rc in red:
            if hp & (rh & -rh):
                hp ^= rh
                co ^= rc
        if hp:
            red.append((hp, co))
    h, coord = v >> t, 0
    for rh, rc in red:
        if h & (rh & -rh):
            h ^= rh
            coord ^= rc
    return coord if h == 0 else None


def compute_tables(plan: TilePlan, prefix: Bmmc,
                   kind: str) -> Optional[ComputeTables]:
    """Build the epilogue tables for one compute, or None if the compute
    is not tile-local under ``plan`` (pairing vector escapes the tile
    span — row directions plus the low lane bits)."""
    n, t = plan.n, plan.t
    dirs = plan.row_dirs
    tb = list(plan.tb_positions)
    low_mask = (1 << t) - 1

    v = pairing_vector(prefix)
    vr = _dir_coords(v, dirs, t)
    if vr is None:
        return None
    vc = v & low_mask   # slot lane == low bits of x, so the lane XOR is raw

    rowvec = prefix.rows[n - 1]            # row n-1 of A_M: hi(x) predicate
    cbit = (prefix.c >> (n - 1)) & 1
    rpt, row_len, n_tiles = plan.rows_per_tile, plan.row_len, plan.n_tiles
    hi_mask = ~low_mask  # slots address rows by direction HIGH parts only

    # hi(x) = <rowvec, x> is F2-linear, so it splits over the tile's
    # decomposition x = base_g ^ high(rowvec(r)) ^ lane: per-row (XOR of
    # direction high parts), per-lane, per-tile terms.
    hi_row = np.fromiter(
        (f2.parity(rowvec & (_xor_dirs(r, dirs) & hi_mask))
         for r in range(rpt)),
        dtype=np.int32, count=rpt)
    hi_lane = np.fromiter(
        (f2.parity(rowvec & c) for c in range(row_len)),
        dtype=np.int32, count=row_len)
    hi_base = np.fromiter(
        (f2.parity(rowvec & _scatter_bits(g, tb)) ^ cbit
         for g in range(n_tiles)),
        dtype=np.int32, count=n_tiles)

    tw_row = tw_lane = tw_base = None
    if kind == "bfly":
        twmask = (1 << (n - 1)) - 1        # pair index: m with bit n-1 dropped
        tw_row = np.fromiter(
            (f2.matvec(prefix.rows, _xor_dirs(r, dirs) & hi_mask) & twmask
             for r in range(rpt)), dtype=np.int32, count=rpt)
        tw_lane = np.fromiter(
            (f2.matvec(prefix.rows, c) & twmask for c in range(row_len)),
            dtype=np.int32, count=row_len)
        tw_base = np.fromiter(
            ((f2.matvec(prefix.rows, _scatter_bits(g, tb)) ^ prefix.c)
             & twmask for g in range(n_tiles)),
            dtype=np.int32, count=n_tiles)
    return ComputeTables(kind=kind, vr=vr, vc=vc, hi_row=hi_row,
                         hi_lane=hi_lane, hi_base=hi_base, tw_row=tw_row,
                         tw_lane=tw_lane, tw_base=tw_base)


@dataclasses.dataclass(frozen=True)
class PlanStats:
    """Analytic plan statistics — O(n^2) bit math, no table enumeration.

    Matches TilePlan's n_over / rows_per_tile / n_tiles / in_run / out_run
    (property-tested against the enumerated tables), usable at paper scale
    (n = 30 => 2^20 tiles) where building per-tile tables is infeasible.
    """
    n: int
    t: int
    n_over: int
    n_tiles: int
    rows_per_tile: int
    row_len: int
    in_run: int
    out_run: int

    def dma_descriptors(self) -> int:
        per_tile = (self.rows_per_tile // self.in_run
                    + self.rows_per_tile // self.out_run)
        return self.n_tiles * per_tile

    def bytes_per_descriptor(self, itemsize: int) -> tuple:
        return (self.in_run * self.row_len * itemsize,
                self.out_run * self.row_len * itemsize)


def plan_stats(bmmc: Bmmc, t: int) -> Optional[PlanStats]:
    """Analytic counterpart of ``plan_tiled`` (no per-tile enumeration)."""
    n = bmmc.n
    cols = bmmc.tiled_columns(t)
    if cols is None:
        return None
    low = set(range(t))
    r_set = set(cols)
    n_over = len(r_set & low)
    if n - 2 * t + n_over < 0:
        return None
    r_not_l = sorted(r_set - low)
    l_not_r = sorted(low - r_set)
    tb = sorted(set(range(n)) - low - r_set)
    rpt = 1 << (t - n_over)

    # input-run: rows consecutive iff the low R\L positions are t, t+1, ...
    k_in = 0
    while k_in < len(r_not_l) and r_not_l[k_in] == t + k_in:
        k_in += 1

    # output-run: out_rows[g, r'] = (A (base|scatter(r')) ^ c) >> t, affine in
    # the r' bits. Runs of 2^k are consecutive iff bit i of r' moves y_high
    # by exactly 2^i for i < k and no other contribution (base bits, c)
    # touches the low k bits of y_high.
    deltas = [f2.matvec(bmmc.rows, 1 << pos) >> t for pos in l_not_r]
    others = [f2.matvec(bmmc.rows, 1 << pos) >> t for pos in tb]
    others.append(bmmc.c >> t)
    k_out = 0
    while k_out < len(deltas):
        k = k_out + 1
        mask = (1 << k) - 1
        ok = all(deltas[i] == (1 << i) for i in range(k))
        ok = ok and all((d & mask) == 0 for d in deltas[k:])
        ok = ok and all((o & mask) == 0 for o in others)
        if not ok:
            break
        k_out = k
    return PlanStats(n=n, t=t, n_over=n_over, n_tiles=1 << len(tb),
                     rows_per_tile=rpt, row_len=1 << t,
                     in_run=1 << k_in, out_run=1 << k_out)


def plan_stats_general(bmmc: Bmmc, t: int) -> Optional[PlanStats]:
    """Analytic counterpart of :func:`plan_general` (O(n^2) bit math)."""
    n = bmmc.n
    if not 0 < t <= n:
        return None
    a, row_dirs = _split_directions(bmmc, t)
    if n - 2 * t + a < 0:
        return None
    tb = _tb_complement(row_dirs, t, n)
    rpt = 1 << (t - a)
    chosen_low = _out_low_positions(bmmc, t, t - a)

    # input-run: in_rows[g, r] counts binarily in r iff high(row_dirs[i])
    # == 2^i for i < k and nothing else (higher dirs, tb base bits)
    # touches the low k row-id bits.
    hi = [v >> t for v in row_dirs]
    k_in = 0
    while k_in < len(hi):
        k = k_in + 1
        mask = (1 << k) - 1
        ok = all(hi[i] == (1 << i) for i in range(k))
        ok = ok and all((h & mask) == 0 for h in hi[k:])
        ok = ok and all((pos - t) >= k for pos in tb)
        if not ok:
            break
        k_in = k

    deltas = [f2.matvec(bmmc.rows, 1 << pos) >> t for pos in chosen_low]
    others = [f2.matvec(bmmc.rows, 1 << pos) >> t for pos in tb]
    others.append(bmmc.c >> t)
    k_out = 0
    while k_out < len(deltas):
        k = k_out + 1
        mask = (1 << k) - 1
        ok = all(deltas[i] == (1 << i) for i in range(k))
        ok = ok and all((d & mask) == 0 for d in deltas[k:])
        ok = ok and all((o & mask) == 0 for o in others)
        if not ok:
            break
        k_out = k
    return PlanStats(n=n, t=t, n_over=a, n_tiles=1 << len(tb),
                     rows_per_tile=rpt, row_len=1 << t,
                     in_run=1 << k_in, out_run=1 << k_out)


def stats_bmmc(bmmc: Bmmc, t: int) -> list:
    """Analytic stats for the tiled passes of an arbitrary BMMC: one
    (classic or generalized) pass whenever possible, the §5.2 two-pass
    factorization as the fallback."""
    s = plan_stats(bmmc, t)
    if s is not None:
        return [s]
    s = plan_stats_general(bmmc, t)
    if s is not None:
        return [s]
    out = []
    for factor in bmmc.factor_tiled(t):
        s = plan_stats(factor, t) or plan_stats_general(factor, t)
        if s is None:
            raise ValueError(f"factor expected tiled for t={t}")
        out.append(s)
    return out


def plan_bmmc(bmmc: Bmmc, t: int) -> list:
    """Plan an arbitrary BMMC as tiled passes: 1 via the classic witness
    columns (paper §5.1) or the generalized witness directions
    (:func:`plan_general`), else 2 via the §5.2 factorization (now only
    reachable for t > n/2, where the direction split may fall short)."""
    p = plan_tiled(bmmc, t)
    if p is not None:
        return [p]
    p = plan_general(bmmc, t)
    if p is not None:
        return [p]
    plans = []
    for factor in bmmc.factor_tiled(t):
        p = plan_tiled(factor, t) or plan_general(factor, t)
        if p is None:
            raise ValueError(f"factor expected to be tiled for t={t}: {factor}")
        plans.append(p)
    return plans


def pass_spans(bmmc: Bmmc, t: int) -> Optional[list]:
    """Per-pass tile spans of :func:`plan_bmmc`, without table enumeration.

    Each span is a tuple of generating direction vectors: a vector ``v``
    is tile-local for that pass iff ``v`` lies in the span — the
    membership check :mod:`repro.combinators.optimize` uses to decide
    whether a compute can ride the pass's tiles. The first pass's span
    is the MAXIMAL achievable one, ``ker(A[t:, :]) + low`` — the classic
    witness-column span is always contained in it, and the plan builder
    falls back to :func:`plan_general` (whose span IS the maximum) when
    a compute needs the extra room. Returns None when a pass's tile
    would exceed the array (t > n/2 with a deficient direction split).
    """
    n = bmmc.n
    if not 0 < t <= n:
        return None
    low = tuple(1 << j for j in range(t))

    def span_of(b: Bmmc) -> Optional[tuple]:
        a, row_dirs = _split_directions(b, t)
        if n - 2 * t + a < 0:
            return None
        return tuple(row_dirs) + low

    s = span_of(bmmc)
    if s is not None:
        return [s]
    spans = []
    for factor in bmmc.factor_tiled(t):
        s = span_of(factor)
        if s is None:
            return None
        spans.append(s)
    return spans


# ---------------------------------------------------------------------------
# Class fast-path plans (DESIGN.md §11). The simplest BMMC classes skip
# the tiled gather pipeline entirely:
#
# * block (tile-index-only): whole aligned 2^b blocks move wholesale —
#   a grid-remapped DMA copy, descriptor count identical to the
#   copy-through-VMEM roofline baseline.
# * lane (lane-local): rows stay in place and every row is permuted
#   identically — a single in-VMEM row gather, no transpose pass.
# ---------------------------------------------------------------------------

_COPY_BLOCK_BITS = 11   # log2(8 rows x 256 lanes): copy_through_vmem's block


@dataclasses.dataclass(frozen=True)
class BlockPlan:
    """Grid-remapped DMA plan: output block ``g`` is input block
    ``src_rows[g]``, each block 2^b consecutive elements."""

    bmmc: Bmmc
    b: int                      # log2 elements per moved block
    src_rows: np.ndarray        # (2^(n-b),) int32

    @property
    def n(self) -> int:
        return self.bmmc.n

    @property
    def n_rows(self) -> int:
        return self.src_rows.shape[0]

    def dma_descriptors(self) -> int:
        """One read + one write per block — the copy kernel's count when
        ``b == _COPY_BLOCK_BITS``."""
        return 2 * self.n_rows

    def audit(self) -> "BlockPlan":
        """Guard ring-1 audit: ``src_rows`` a bounded permutation whose
        block map matches the BMMC. Raises
        :class:`repro.guard.DescriptorOOB`."""
        from ..guard.validate import audit_block_plan  # lazy: no cycle
        audit_block_plan(self)
        return self


@dataclasses.dataclass(frozen=True)
class LanePlan:
    """Single-pass in-VMEM row gather: ``out[row, lane] = x[row,
    src_lane[lane]]`` — rows never move, so there is no transpose pass."""

    bmmc: Bmmc
    t: int                      # log2 lanes per row
    src_lane: np.ndarray        # (2^t,) int32
    rows_per_block: int         # rows staged through VMEM per grid step

    @property
    def n(self) -> int:
        return self.bmmc.n

    @property
    def n_rows(self) -> int:
        return 1 << (self.n - self.t)

    def dma_descriptors(self) -> int:
        return 2 * (self.n_rows // self.rows_per_block)

    def audit(self) -> "LanePlan":
        """Guard ring-1 audit: ``src_lane`` a bounded permutation whose
        in-row gather matches the BMMC. Raises
        :class:`repro.guard.DescriptorOOB`."""
        from ..guard.validate import audit_lane_plan  # lazy: no cycle
        audit_lane_plan(self)
        return self


def _block_granularity(bmmc: Bmmc) -> int:
    """log2 elements per moved block: the class granularity capped at
    the copy baseline's block, so descriptor counts match
    ``copy_through_vmem`` exactly whenever the class allows it."""
    return min(bmmc.block_bits(), _COPY_BLOCK_BITS, bmmc.n - 1)


def _lane_rows_per_block(n: int, t: int) -> int:
    """Rows staged per grid step: one copy-sized block when available."""
    return max(1, min(1 << (n - t), 1 << max(0, _COPY_BLOCK_BITS - t)))


def plan_block(bmmc: Bmmc, t: int) -> Optional[BlockPlan]:
    """Block-permute plan, or None if not tile-index-only at ``t``."""
    n = bmmc.n
    k = bmmc.block_bits()
    if not (0 < t <= k < n):
        return None
    b = _block_granularity(bmmc)
    # sub-BMMC on the high n-b bits (rows >= b read only columns >= b)
    sub_rows = tuple(bmmc.rows[i] >> b for i in range(b, n))
    sub = Bmmc(sub_rows, bmmc.c >> b)
    sub_inv = sub.inverse()
    src = np.fromiter((sub_inv.apply(g) for g in range(1 << (n - b))),
                      dtype=np.int32, count=1 << (n - b))
    return BlockPlan(bmmc=bmmc, b=b, src_rows=src)


def plan_lane(bmmc: Bmmc, t: int) -> Optional[LanePlan]:
    """Lane-permute plan, or None if not lane-local at ``t``."""
    n = bmmc.n
    if not bmmc.is_lane_local(t):
        return None
    low_mask = (1 << t) - 1
    sub = Bmmc(tuple(bmmc.rows[i] & low_mask for i in range(t)),
               bmmc.c & low_mask)
    sub_inv = sub.inverse()
    src = np.fromiter((sub_inv.apply(l) for l in range(1 << t)),
                      dtype=np.int32, count=1 << t)
    return LanePlan(bmmc=bmmc, t=t, src_lane=src,
                    rows_per_block=_lane_rows_per_block(n, t))


def copy_descriptors(n: int) -> int:
    """Modeled descriptor count of the copy-through-VMEM roofline
    baseline for a 2^n array: one read + one write per copy block."""
    return 2 * (1 << max(0, n - _COPY_BLOCK_BITS))


def dispatch_kernel(bmmc: Bmmc, t: int) -> str:
    """The kernel the class dispatch selects (DESIGN.md §11):

    ``none`` (identity), ``block`` (grid-remapped DMA, no gather),
    ``lane`` (single in-VMEM row gather), ``tiled`` (classic §5.1 one-
    pass), ``general`` (generalized witness-direction one-pass), or
    ``general2`` (§5.2 two-pass fallback, t > n/2 only).
    """
    cls = bmmc.bmmc_class(t)
    if cls == "identity":
        return "none"
    if cls == "complement":
        # a high-only complement moves whole blocks; a low-only one
        # permutes lanes; a mixed complement is a BPC -> one tiled pass
        low_part, high_part = bmmc.c & ((1 << t) - 1), bmmc.c >> t
        if low_part and high_part:
            return "tiled"
        return "block" if not low_part else "lane"
    if cls in ("block", "lane", "tiled"):
        return cls
    return "general" if plan_stats_general(bmmc, t) else "general2"


def class_stats(bmmc: Bmmc, t: int) -> dict:
    """Analytic per-class execution stats: the BMMC class, dispatched
    kernel, pass count, modeled DMA descriptors, and the copy-roofline
    ratio (copy descriptors / class descriptors; 1.0 == executes at the
    speed of an array copy, the paper's §2.3 reference point)."""
    n = bmmc.n
    cls = bmmc.bmmc_class(t)
    kernel = dispatch_kernel(bmmc, t)
    copy_desc = copy_descriptors(n)
    # block / lane counts are closed-form (no table enumeration — the
    # PlanStats principle: usable at paper scale, n = 30)
    if kernel == "none":
        desc, passes = 0, 0
    elif kernel == "block":
        desc, passes = 2 * (1 << (n - _block_granularity(bmmc))), 1
    elif kernel == "lane":
        desc = 2 * ((1 << (n - t)) // _lane_rows_per_block(n, t))
        passes = 1
    else:
        stats = stats_bmmc(bmmc, t)
        desc = sum(s.dma_descriptors() for s in stats)
        passes = len(stats)
    return {"class": cls, "kernel": kernel, "passes": passes,
            "descriptors": desc, "copy_descriptors": copy_desc,
            "roofline_ratio": copy_desc / max(desc, 1) if passes else 1.0}


# ---------------------------------------------------------------------------
# Naive-kernel transaction model (paper §6 "naive" column): each warp/DMA
# touches whatever segments its element mapping hits. On TPU a naive gather
# issues one descriptor per non-contiguous run; we count exact runs.
# ---------------------------------------------------------------------------

def naive_write_runs(bmmc: Bmmc, seg_elems: int, sample_tiles: int = 64) -> float:
    """Average # of distinct segments written per contiguous input segment.

    ``seg_elems`` plays the role of warp-width/segment (32 for the paper's
    GPU model; a lane-row for TPU). 1.0 == fully coalesced.
    """
    n = bmmc.n
    size = 1 << n
    segs = min(sample_tiles, size // seg_elems)
    total = 0
    rng = np.random.default_rng(0)
    starts = rng.choice(size // seg_elems, size=segs, replace=False)
    for s in starts:
        ys = {bmmc.apply(int(s) * seg_elems + i) // seg_elems for i in range(seg_elems)}
        total += len(ys)
    return total / segs
