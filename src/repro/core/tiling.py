"""Tile-bit partitioning and offline table generation (paper §4.1-4.3, §5.1).

For a *tiled* BMMC ``(A, c)`` on ``n``-bit indices and tile parameter ``t``
(= ``n_tile``; one "row" = 2^t consecutive elements), input index bits are
partitioned into:

* tile column bits  — the low ``t`` bits (set L),
* tile row bits     — the witness columns ``i_1..i_t`` (set R; for a BPC these
  are exactly ``{j : p(j) < t}``),
* overlap bits      — R ∩ L (``n_over`` of them),
* thread-block bits — the rest (``n_TB = n - 2t + n_over``), all >= t.

One tile = all index combinations of (L ∪ R) bits with the block bits fixed:
``2^(t - n_over)`` full input rows, mapping onto ``2^(t - n_over)`` full
output rows. This module precomputes, per permutation (offline, matching the
paper's codegen setting):

* ``in_rows[g, r]``   — input row id read by tile ``g`` (row view: (2^(n-t), 2^t)),
* ``out_rows[g, r']`` — output row id written by tile ``g``,
* ``xor_low[g]``      — per-tile XOR on the intra-tile lane gather (the
  block-bit contribution to the low output bits; 0 for every BPC),
* ``src0``            — flat intra-tile gather table for tile 0:
  ``out_tile.flat[j] = in_tile.flat[src0[j ^ xor_low[g]]]``.

The per-tile XOR trick is the TPU replacement for re-deriving indices per
thread: tables are computed once; the kernel's scalar core only reads them.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np

from .bmmc import Bmmc
from . import f2


def _scatter_bits(value: int, positions: list) -> int:
    """Place bit k of ``value`` at ``positions[k]``."""
    out = 0
    for k, pos in enumerate(positions):
        if (value >> k) & 1:
            out |= 1 << pos
    return out


def _gather_bits(value: int, positions: list) -> int:
    """Collect bits of ``value`` at ``positions`` into a compact int."""
    out = 0
    for k, pos in enumerate(positions):
        if (value >> pos) & 1:
            out |= 1 << k
    return out


def _run_length(rows: np.ndarray) -> int:
    """Largest power-of-two run of consecutive row ids shared by all tiles.

    This is the DMA-merge factor: ``run`` consecutive rows can be copied by a
    single descriptor (the TPU analogue of the paper's §4.3 amortization).
    """
    n_tiles, rpt = rows.shape
    run = 1
    while run * 2 <= rpt:
        nxt = run * 2
        blocks = rows.reshape(n_tiles, rpt // nxt, nxt)
        diff = blocks - blocks[..., :1]
        if np.array_equal(diff, np.broadcast_to(np.arange(nxt), diff.shape)):
            run = nxt
        else:
            break
    return run


@dataclasses.dataclass(frozen=True)
class TilePlan:
    """Offline execution plan for one tiled-BMMC pass."""

    bmmc: Bmmc
    t: int                      # n_tile: log2 elements per row
    row_cols: tuple             # R, sorted
    n_over: int
    tb_positions: tuple         # thread-block bit positions, sorted (all >= t)
    in_rows: np.ndarray         # (n_tiles, rows_per_tile) int32
    out_rows: np.ndarray        # (n_tiles, rows_per_tile) int32
    xor_low: np.ndarray         # (n_tiles,) int32
    src0: np.ndarray            # (rows_per_tile, 2^t) int32 flat gather table
    in_run: int                 # input DMA merge run (rows)
    out_run: int                # output DMA merge run (rows)

    @property
    def n(self) -> int:
        return self.bmmc.n

    @property
    def n_tiles(self) -> int:
        return self.in_rows.shape[0]

    @property
    def rows_per_tile(self) -> int:
        return self.in_rows.shape[1]

    @property
    def row_len(self) -> int:
        return 1 << self.t

    # -- modeled memory transactions (the quantity behind the paper's
    # -- bandwidth results; used by the benchmark harness) -------------------
    def dma_descriptors(self) -> int:
        """Total HBM DMA descriptors issued (reads + writes)."""
        per_tile = self.rows_per_tile // self.in_run + self.rows_per_tile // self.out_run
        return self.n_tiles * per_tile

    def bytes_per_descriptor(self, itemsize: int) -> tuple:
        return (self.in_run * self.row_len * itemsize,
                self.out_run * self.row_len * itemsize)


def plan_tiled(bmmc: Bmmc, t: int) -> Optional[TilePlan]:
    """Build a TilePlan, or None if ``bmmc`` is not tiled for this ``t``."""
    n = bmmc.n
    if 2 * t > n + t:  # t > n: nonsensical
        return None
    cols = bmmc.tiled_columns(t)
    if cols is None:
        return None
    low = set(range(t))
    r_set = set(cols)
    n_over = len(r_set & low)
    if n - 2 * t + n_over < 0:
        return None  # tile would exceed the array; caller falls back
    r_not_l = sorted(r_set - low)           # t - n_over positions, all >= t
    l_not_r = sorted(low - r_set)           # t - n_over positions, all < t
    tb = sorted(set(range(n)) - low - r_set)
    n_tb = len(tb)
    assert n_tb == n - 2 * t + n_over

    rpt = 1 << (t - n_over)                  # rows per tile
    n_tiles = 1 << n_tb
    row_len = 1 << t
    low_mask = row_len - 1

    ainv = bmmc.inverse()

    in_rows = np.empty((n_tiles, rpt), dtype=np.int32)
    out_rows = np.empty((n_tiles, rpt), dtype=np.int32)
    xor_low = np.empty((n_tiles,), dtype=np.int32)

    # Row tables. y_high = A[t:, :] x ^ c_high depends only on non-R bits of x
    # (the zero block kills R), i.e. on (L\R, TB): enumerate r' over L\R.
    for g in range(n_tiles):
        base = _scatter_bits(g, tb)
        delta = f2.matvec(bmmc.rows, base)
        xor_low[g] = delta & low_mask
        for r in range(rpt):
            in_rows[g, r] = (base | _scatter_bits(r, r_not_l)) >> t
        for rp in range(rpt):
            y = bmmc.apply(base | _scatter_bits(rp, l_not_r))
            out_rows[g, rp] = y >> t

    # Intra-tile gather table for tile 0 (other tiles differ by xor_low only).
    src0 = np.empty((rpt, row_len), dtype=np.int32)
    for rp in range(rpt):
        y_hi = int(out_rows[0, rp]) << t
        for cp in range(row_len):
            x = ainv.apply(y_hi | cp)
            assert _gather_bits(x, tb) == 0, "tile-0 source must be in tile 0"
            r = _gather_bits(x, r_not_l)
            src0[rp, cp] = r * row_len + (x & low_mask)
    return TilePlan(
        bmmc=bmmc, t=t, row_cols=tuple(sorted(cols)), n_over=n_over,
        tb_positions=tuple(tb), in_rows=in_rows, out_rows=out_rows,
        xor_low=xor_low, src0=src0,
        in_run=_run_length(in_rows), out_run=_run_length(out_rows),
    )


# ---------------------------------------------------------------------------
# Fused-compute tables: everything a megakernel epilogue needs to run a
# CmpHalves / Bfly stage on the tile while it sits in VMEM (DESIGN.md §10).
#
# The compute pairs intermediate index m with m ^ 2^(n-1), where m = M x
# (+) c_M and M is the composition of the run's perms *before* the
# compute. Pulled back to input space the partner of x is x ^ v with
# v = A_M^-1 e_{n-1}; when v lies in the span of the plan's tile row (R)
# and column (L) bits, the partner is resident in the same tile at
# position (r ^ vr, lane ^ vc). Which element of a pair is the "hi" half
# (bit n-1 of m set) and which twiddle a butterfly pair uses are affine
# in x, so they split into tiny per-row / per-lane tables XORed with one
# per-tile scalar — the same trick as `xor_low`.
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class ComputeTables:
    """Offline tables for one in-VMEM compute applied inside a tiled pass."""

    kind: str                        # "cmp" | "bfly"
    vr: int                          # partner XOR on the tile-row slot
    vc: int                          # partner XOR on the lane
    hi_row: np.ndarray               # (rows_per_tile,) int32 parity bits
    hi_lane: np.ndarray              # (row_len,) int32 parity bits
    hi_base: np.ndarray              # (n_tiles,) int32 per-tile parity bit
    tw_row: Optional[np.ndarray] = None    # (rows_per_tile,) int32 (bfly)
    tw_lane: Optional[np.ndarray] = None   # (row_len,) int32 (bfly)
    tw_base: Optional[np.ndarray] = None   # (n_tiles,) int32 (bfly)


def pairing_vector(prefix: Bmmc) -> int:
    """The input-space partner XOR ``v = A_M^{-1} e_{n-1}`` of a compute
    whose pair bit is n-1 in the output space of ``prefix``."""
    return f2.matvec(f2.inverse(prefix.rows), 1 << (prefix.n - 1))


def compute_tables(plan: TilePlan, prefix: Bmmc,
                   kind: str) -> Optional[ComputeTables]:
    """Build the epilogue tables for one compute, or None if the compute
    is not tile-local under ``plan`` (pairing vector escapes L ∪ R)."""
    n, t = plan.n, plan.t
    low = set(range(t))
    r_set = set(plan.row_cols)
    r_not_l = sorted(r_set - low)
    tb = list(plan.tb_positions)
    low_mask = (1 << t) - 1
    lr_mask = low_mask
    for pos in plan.row_cols:
        lr_mask |= 1 << pos

    v = pairing_vector(prefix)
    if v & ~lr_mask:
        return None
    vr = _gather_bits(v, r_not_l)
    vc = v & low_mask

    rowvec = prefix.rows[n - 1]            # row n-1 of A_M: hi(x) predicate
    cbit = (prefix.c >> (n - 1)) & 1
    rpt, row_len, n_tiles = plan.rows_per_tile, plan.row_len, plan.n_tiles

    hi_row = np.fromiter(
        (f2.parity(rowvec & _scatter_bits(r, r_not_l)) for r in range(rpt)),
        dtype=np.int32, count=rpt)
    hi_lane = np.fromiter(
        (f2.parity(rowvec & c) for c in range(row_len)),
        dtype=np.int32, count=row_len)
    hi_base = np.fromiter(
        (f2.parity(rowvec & _scatter_bits(g, tb)) ^ cbit
         for g in range(n_tiles)),
        dtype=np.int32, count=n_tiles)

    tw_row = tw_lane = tw_base = None
    if kind == "bfly":
        twmask = (1 << (n - 1)) - 1        # pair index: m with bit n-1 dropped
        tw_row = np.fromiter(
            (f2.matvec(prefix.rows, _scatter_bits(r, r_not_l)) & twmask
             for r in range(rpt)), dtype=np.int32, count=rpt)
        tw_lane = np.fromiter(
            (f2.matvec(prefix.rows, c) & twmask for c in range(row_len)),
            dtype=np.int32, count=row_len)
        tw_base = np.fromiter(
            ((f2.matvec(prefix.rows, _scatter_bits(g, tb)) ^ prefix.c)
             & twmask for g in range(n_tiles)),
            dtype=np.int32, count=n_tiles)
    return ComputeTables(kind=kind, vr=vr, vc=vc, hi_row=hi_row,
                         hi_lane=hi_lane, hi_base=hi_base, tw_row=tw_row,
                         tw_lane=tw_lane, tw_base=tw_base)


@dataclasses.dataclass(frozen=True)
class PlanStats:
    """Analytic plan statistics — O(n^2) bit math, no table enumeration.

    Matches TilePlan's n_over / rows_per_tile / n_tiles / in_run / out_run
    (property-tested against the enumerated tables), usable at paper scale
    (n = 30 => 2^20 tiles) where building per-tile tables is infeasible.
    """
    n: int
    t: int
    n_over: int
    n_tiles: int
    rows_per_tile: int
    row_len: int
    in_run: int
    out_run: int

    def dma_descriptors(self) -> int:
        per_tile = (self.rows_per_tile // self.in_run
                    + self.rows_per_tile // self.out_run)
        return self.n_tiles * per_tile

    def bytes_per_descriptor(self, itemsize: int) -> tuple:
        return (self.in_run * self.row_len * itemsize,
                self.out_run * self.row_len * itemsize)


def plan_stats(bmmc: Bmmc, t: int) -> Optional[PlanStats]:
    """Analytic counterpart of ``plan_tiled`` (no per-tile enumeration)."""
    n = bmmc.n
    cols = bmmc.tiled_columns(t)
    if cols is None:
        return None
    low = set(range(t))
    r_set = set(cols)
    n_over = len(r_set & low)
    if n - 2 * t + n_over < 0:
        return None
    r_not_l = sorted(r_set - low)
    l_not_r = sorted(low - r_set)
    tb = sorted(set(range(n)) - low - r_set)
    rpt = 1 << (t - n_over)

    # input-run: rows consecutive iff the low R\L positions are t, t+1, ...
    k_in = 0
    while k_in < len(r_not_l) and r_not_l[k_in] == t + k_in:
        k_in += 1

    # output-run: out_rows[g, r'] = (A (base|scatter(r')) ^ c) >> t, affine in
    # the r' bits. Runs of 2^k are consecutive iff bit i of r' moves y_high
    # by exactly 2^i for i < k and no other contribution (base bits, c)
    # touches the low k bits of y_high.
    deltas = [f2.matvec(bmmc.rows, 1 << pos) >> t for pos in l_not_r]
    others = [f2.matvec(bmmc.rows, 1 << pos) >> t for pos in tb]
    others.append(bmmc.c >> t)
    k_out = 0
    while k_out < len(deltas):
        k = k_out + 1
        mask = (1 << k) - 1
        ok = all(deltas[i] == (1 << i) for i in range(k))
        ok = ok and all((d & mask) == 0 for d in deltas[k:])
        ok = ok and all((o & mask) == 0 for o in others)
        if not ok:
            break
        k_out = k
    return PlanStats(n=n, t=t, n_over=n_over, n_tiles=1 << len(tb),
                     rows_per_tile=rpt, row_len=1 << t,
                     in_run=1 << k_in, out_run=1 << k_out)


def stats_bmmc(bmmc: Bmmc, t: int) -> list:
    """Analytic stats for the 1-2 tiled passes of an arbitrary BMMC."""
    out = []
    for factor in bmmc.factor_tiled(t):
        s = plan_stats(factor, t)
        if s is None:
            raise ValueError(f"factor expected tiled for t={t}")
        out.append(s)
    return out


def plan_bmmc(bmmc: Bmmc, t: int) -> list:
    """Plan an arbitrary BMMC as 1-2 tiled passes (paper §5.2)."""
    plans = []
    for factor in bmmc.factor_tiled(t):
        p = plan_tiled(factor, t)
        if p is None:
            raise ValueError(f"factor expected to be tiled for t={t}: {factor}")
        plans.append(p)
    return plans


# ---------------------------------------------------------------------------
# Naive-kernel transaction model (paper §6 "naive" column): each warp/DMA
# touches whatever segments its element mapping hits. On TPU a naive gather
# issues one descriptor per non-contiguous run; we count exact runs.
# ---------------------------------------------------------------------------

def naive_write_runs(bmmc: Bmmc, seg_elems: int, sample_tiles: int = 64) -> float:
    """Average # of distinct segments written per contiguous input segment.

    ``seg_elems`` plays the role of warp-width/segment (32 for the paper's
    GPU model; a lane-row for TPU). 1.0 == fully coalesced.
    """
    n = bmmc.n
    size = 1 << n
    segs = min(sample_tiles, size // seg_elems)
    total = 0
    rng = np.random.default_rng(0)
    starts = rng.choice(size // seg_elems, size=segs, replace=False)
    for s in starts:
        ys = {bmmc.apply(int(s) * seg_elems + i) // seg_elems for i in range(seg_elems)}
        total += len(ys)
    return total / segs
