"""BMMC (Bit Matrix Multiply Complement) index transformations.

A BMMC is an affine permutation of index space: ``y = A x (+) c`` over F2,
with ``A`` an invertible (n, n) binary matrix and ``c`` an n-bit complement
vector (paper §3). Sub-classes:

* BP  — A is a permutation matrix, c == 0 (e.g. bit-reversal, transpose).
* BPC — A is a permutation matrix, any c (e.g. array reversal).
* tiled BMMC — admits the single-pass tiled kernel (paper §5.1).
* general BMMC — factorizes into two tiled BMMCs (paper §5.2).
"""
from __future__ import annotations

import dataclasses
import random
from typing import Optional, Sequence

from . import f2


@dataclasses.dataclass(frozen=True)
class Bmmc:
    """Affine index permutation ``y = A x ^ c`` on n-bit indices."""

    rows: tuple  # tuple[int, ...], bit-packed rows of A
    c: int = 0

    # -- constructors ------------------------------------------------------
    @staticmethod
    def identity(n: int) -> "Bmmc":
        return Bmmc(f2.identity(n), 0)

    @staticmethod
    def from_perm(p: Sequence[int], c: int = 0) -> "Bmmc":
        """BPC from a bit permutation p (y_{p(j)} = x_j) and complement c."""
        return Bmmc(f2.from_perm(p), c)

    @staticmethod
    def bit_reverse(n: int) -> "Bmmc":
        return Bmmc(f2.reversal(n), 0)

    @staticmethod
    def reverse_array(n: int) -> "Bmmc":
        """Array reversal: y = x ^ (2^n - 1) (paper §3 example)."""
        return Bmmc(f2.identity(n), (1 << n) - 1)

    @staticmethod
    def matrix_transpose(row_bits: int, col_bits: int) -> "Bmmc":
        """Transpose of a (2^row_bits, 2^col_bits) row-major matrix.

        Index = (i << col_bits) | j  ->  (j << row_bits) | i: a rotation of
        the index bits (generalizes the paper's 4x4 example).
        """
        n = row_bits + col_bits
        p = [(j + row_bits) % n for j in range(n)]
        return Bmmc.from_perm(p)

    @staticmethod
    def rotate_bits(n: int, k: int) -> "Bmmc":
        """y's bits are x's bits rotated left by k: y_{(i+k)%n} = x_i."""
        return Bmmc.from_perm([(i + k) % n for i in range(n)])

    @staticmethod
    def xor_shift(n: int, c: int) -> "Bmmc":
        return Bmmc(f2.identity(n), c & ((1 << n) - 1))

    @staticmethod
    def random_bpc(n: int, rng: random.Random) -> "Bmmc":
        return Bmmc(f2.random_perm_matrix(n, rng), rng.randrange(1 << n))

    @staticmethod
    def random(n: int, rng: random.Random) -> "Bmmc":
        return Bmmc(f2.random_invertible(n, rng), rng.randrange(1 << n))

    # -- basic properties ---------------------------------------------------
    @property
    def n(self) -> int:
        return len(self.rows)

    @property
    def size(self) -> int:
        return 1 << self.n

    def __post_init__(self):
        if not f2.is_invertible(self.rows):
            raise f2.SingularError("BMMC matrix must be invertible")
        object.__setattr__(self, "c", self.c & ((1 << len(self.rows)) - 1))

    def apply(self, x: int) -> int:
        """y = A x ^ c for a single integer index."""
        return f2.matvec(self.rows, x) ^ self.c

    def verify(self) -> "Bmmc":
        """Re-prove well-formedness (bit ranges + F2 rank) through the
        guard subsystem, raising the typed
        :class:`repro.guard.NotInvertible` on failure. ``__post_init__``
        ran the same rank check at construction, but an instance reaching
        the planner through a cache (or ``object.__setattr__``) may never
        have been constructed — plan-time validation calls this
        (DESIGN.md §14, ring 1)."""
        from ..guard.validate import verify_bmmc  # lazy: no core->guard cycle
        return verify_bmmc(self)

    def inverse(self) -> "Bmmc":
        """The inverse transformation: x = A^-1 (y ^ c) = A^-1 y ^ A^-1 c."""
        ainv = f2.inverse(self.rows)
        return Bmmc(ainv, f2.matvec(ainv, self.c))

    def compose(self, other: "Bmmc") -> "Bmmc":
        """self ∘ other: apply ``other`` first. (BA, B(c_A) ^ c_B)."""
        return Bmmc(
            f2.matmul(self.rows, other.rows),
            f2.matvec(self.rows, other.c) ^ self.c,
        )

    def __matmul__(self, other: "Bmmc") -> "Bmmc":
        return self.compose(other)

    def is_identity_perm(self) -> bool:
        return self.rows == f2.identity(self.n) and self.c == 0

    # -- classification -----------------------------------------------------
    def perm(self) -> Optional[list]:
        """Bit permutation p if A is a permutation matrix, else None."""
        return f2.to_perm(self.rows)

    def is_bp(self) -> bool:
        return self.c == 0 and self.perm() is not None

    def is_bpc(self) -> bool:
        return self.perm() is not None

    def tiled_columns(self, t: int) -> Optional[list]:
        """Columns i_1..i_t witnessing tiled-ness (paper §5.1), or None."""
        return f2.tiled_columns(self.rows, t)

    def is_tiled(self, t: int) -> bool:
        return self.tiled_columns(t) is not None

    # -- class hierarchy (fast-path kernel dispatch; DESIGN.md §11) ----------
    def is_complement_only(self) -> bool:
        """y = x ^ c: A is the identity (c may be 0 -> identity perm)."""
        return self.rows == f2.identity(self.n)

    def block_bits(self) -> int:
        """Largest k such that the permutation moves whole aligned 2^k
        blocks: the low k bits pass through untouched (``rows[i] == e_i``
        for ``i < k``, ``c`` zero on them) and no high output reads them
        (``rows[i]`` zero on the low k columns for ``i >= k``). 0 when
        the BMMC is not block-granular at any size."""
        n = self.n
        k = 0
        while (k < n and self.rows[k] == (1 << k)
               and not (self.c >> k) & 1):
            k += 1
        while k > 0:
            mask = (1 << k) - 1
            if all((self.rows[i] & mask) == 0 for i in range(k, n)):
                break
            k -= 1
        return k

    def is_tile_index_only(self, t: int) -> bool:
        """Whole 2^t rows move wholesale: the block-permute fast path
        (grid-remapped DMA, no intra-tile gather)."""
        return 0 < t <= self.block_bits()

    def is_lane_local(self, t: int) -> bool:
        """Rows stay in place; each 2^t row is permuted identically in
        place by the same t-bit BMMC: the lane-permute fast path (single
        pass, in-VMEM row gather, no transpose pass)."""
        n = self.n
        if not 0 < t < n:
            return False
        return (all(self.rows[i] == (1 << i) for i in range(t, n))
                and (self.c >> t) == 0
                and all((self.rows[i] >> t) == 0 for i in range(t)))

    def bmmc_class(self, t: int) -> str:
        """The kernel class (most-specialized first; DESIGN.md §11):

        ``identity`` < ``complement`` < ``block`` < ``lane`` < ``tiled``
        < ``general``. Every class is also a member of all later classes
        (a complement is a BPC hence tiled; a tiled BMMC is general), so
        the classes *partition* BMMC space by first match.
        """
        if self.is_identity_perm():
            return "identity"
        if self.is_complement_only():
            return "complement"
        if self.is_tile_index_only(t):
            return "block"
        if self.is_lane_local(t):
            return "lane"
        if self.is_tiled(t):
            return "tiled"
        return "general"

    # -- factorization (paper §5.2) ------------------------------------------
    def factor_tiled(self, t: int) -> list:
        """Factor into tiled BMMCs to be applied *left to right*.

        Returns ``[self]`` if already tiled for tile size ``t``; otherwise
        uses A = U L P = (U R)(R L P): apply (RLP, 0) first, then (UR, c).
        Both factors are tiled for any t (UR via its last t columns; RLP via
        the images of the top-left anti-block), per paper §5.2 / Fig. 8.
        """
        if t >= self.n or self.is_tiled(t):
            return [self]
        u, l, p = f2.ulp(self.rows)
        r = f2.reversal(self.n)
        first = Bmmc(f2.matmul(r, f2.matmul(l, p)), 0)   # (R L P, 0)
        second = Bmmc(f2.matmul(u, r), self.c)            # (U R, c)
        assert first.is_tiled(t), "RLP factor must be tiled"
        assert second.is_tiled(t), "UR factor must be tiled"
        assert second.compose(first).rows == self.rows
        assert second.compose(first).c == self.c
        return [first, second]

    # -- pretty printing ------------------------------------------------------
    def __repr__(self) -> str:
        kind = "BP" if self.is_bp() else ("BPC" if self.is_bpc() else "BMMC")
        return f"{kind}(n={self.n}, c={self.c:#x})"
