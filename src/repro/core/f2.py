"""F2 (GF(2)) linear algebra on bit-packed binary matrices.

An (n, n) binary matrix is represented as a tuple of ``n`` Python ints:
``rows[i]`` is the bitmask of row ``i`` (bit ``j`` set <=> A[i, j] = 1).
Row/column index 0 corresponds to the least significant index bit, matching
the paper's convention ``y_i = sum_j a_ij x_j + c_i``.

Everything here is *offline* (trace-time) machinery, mirroring the paper's
offline setting: matrices are known before kernels are generated.
"""
from __future__ import annotations

import random
from typing import Optional, Sequence

Rows = tuple  # tuple[int, ...]


class SingularError(ValueError):
    """Raised when a matrix expected to be invertible is singular."""


# ---------------------------------------------------------------------------
# Constructors
# ---------------------------------------------------------------------------

def identity(n: int) -> Rows:
    return tuple(1 << i for i in range(n))


def zero(n: int) -> Rows:
    return tuple(0 for _ in range(n))


def from_perm(p: Sequence[int]) -> Rows:
    """Permutation matrix P with P[i, j] = 1 iff i = p(j) (paper eq. in §3).

    Applying P to an index vector x gives y with y_{p(j)} = x_j.
    """
    n = len(p)
    rows = [0] * n
    for j, pj in enumerate(p):
        rows[pj] |= 1 << j
    return tuple(rows)


def reversal(n: int) -> Rows:
    """Bit-reversal matrix R (anti-diagonal identity). R @ R = I."""
    return tuple(1 << (n - 1 - i) for i in range(n))


def from_dense(mat: Sequence[Sequence[int]]) -> Rows:
    return tuple(sum((int(v) & 1) << j for j, v in enumerate(row)) for row in mat)


def to_dense(rows: Rows) -> list:
    n = len(rows)
    return [[(rows[i] >> j) & 1 for j in range(n)] for i in range(n)]


# ---------------------------------------------------------------------------
# Basic operations
# ---------------------------------------------------------------------------

def parity(x: int) -> int:
    return bin(x).count("1") & 1


def matvec(rows: Rows, x: int) -> int:
    """y = A x over F2 (x, y are bit-packed index vectors)."""
    y = 0
    for i, r in enumerate(rows):
        y |= parity(r & x) << i
    return y


def matmul(a: Rows, b: Rows) -> Rows:
    """C = A @ B over F2. Row i of C = XOR of rows j of B where A[i, j] = 1."""
    out = []
    for ra in a:
        acc = 0
        j = 0
        r = ra
        while r:
            if r & 1:
                acc ^= b[j]
            r >>= 1
            j += 1
        out.append(acc)
    return tuple(out)


def transpose(rows: Rows) -> Rows:
    n = len(rows)
    out = [0] * n
    for i, r in enumerate(rows):
        for j in range(n):
            if (r >> j) & 1:
                out[j] |= 1 << i
    return tuple(out)


def column(rows: Rows, j: int) -> int:
    """Column j as a bitmask over row indices."""
    out = 0
    for i, r in enumerate(rows):
        if (r >> j) & 1:
            out |= 1 << i
    return out


def rank(rows: Rows) -> int:
    rs = [r for r in rows if r]
    rk = 0
    while rs:
        piv = rs.pop()
        if piv == 0:
            continue
        rk += 1
        low = piv & -piv
        rs = [(r ^ piv) if (r & low) else r for r in rs]
        rs = [r for r in rs if r]
    return rk


def is_invertible(rows: Rows) -> bool:
    return rank(rows) == len(rows)


def inverse(rows: Rows) -> Rows:
    """Gauss-Jordan inverse over F2; raises SingularError if singular."""
    n = len(rows)
    a = list(rows)
    inv = list(identity(n))
    for col in range(n):
        piv = None
        for i in range(col, n):
            if (a[i] >> col) & 1:
                piv = i
                break
        if piv is None:
            raise SingularError(f"matrix is singular (column {col})")
        a[col], a[piv] = a[piv], a[col]
        inv[col], inv[piv] = inv[piv], inv[col]
        for i in range(n):
            if i != col and ((a[i] >> col) & 1):
                a[i] ^= a[col]
                inv[i] ^= inv[col]
    return tuple(inv)


def to_perm(rows: Rows) -> Optional[list]:
    """If A is a permutation matrix, return p with P[i,j]=1 iff i=p(j); else None."""
    n = len(rows)
    p = [-1] * n
    seen = 0
    for i, r in enumerate(rows):
        if r == 0 or (r & (r - 1)):  # not exactly one bit
            return None
        j = r.bit_length() - 1
        if (seen >> j) & 1:
            return None
        seen |= 1 << j
        p[j] = i
    return p


def nullspace(rows: Sequence[int], ncols: int) -> list:
    """Basis of ``{x in F2^ncols : M x = 0}`` for a (possibly rectangular)
    matrix given as row bitmasks. Each basis vector is an ``ncols``-bit int.

    This is the workhorse of the *generalized* tiled planner (§5.1
    extended): the kernel of the high rows ``A[t:, :]`` of an invertible
    BMMC always has dimension ``t``, and any basis of it serves as the
    witness *directions* where the paper demands witness *columns*.
    """
    pivots: dict = {}  # pivot column -> index into ``red``
    red: list = []
    for r in rows:
        for c, ri in pivots.items():
            if (r >> c) & 1:
                r ^= red[ri]
        if r:
            c = (r & -r).bit_length() - 1
            pivots[c] = len(red)
            red.append(r)
    for c, ri in pivots.items():  # back-substitute to reduced echelon
        for ri2 in range(len(red)):
            if ri2 != ri and (red[ri2] >> c) & 1:
                red[ri2] ^= red[ri]
    basis = []
    for fc in range(ncols):
        if fc in pivots:
            continue
        v = 1 << fc
        for c, ri in pivots.items():
            if (red[ri] >> fc) & 1:
                v |= 1 << c
        basis.append(v)
    return basis


def in_span(v: int, gens: Sequence[int]) -> bool:
    """Is ``v`` in the F2 span of ``gens`` (arbitrary generating set)?"""
    red: list = []
    for g in gens:
        for r in red:
            if g & (r & -r):
                g ^= r
        if g:
            red.append(g)
    for r in red:
        if v & (r & -r):
            v ^= r
    return v == 0


# ---------------------------------------------------------------------------
# Triangularity predicates (row i, col j; "upper" = support on j >= i)
# ---------------------------------------------------------------------------

def is_upper(rows: Rows) -> bool:
    return all((r & ((1 << i) - 1)) == 0 for i, r in enumerate(rows))


def is_lower(rows: Rows) -> bool:
    n = len(rows)
    return all((r >> (i + 1)) == 0 for i, r in enumerate(rows))


def is_unit_diag(rows: Rows) -> bool:
    return all((r >> i) & 1 for i, r in enumerate(rows))


# ---------------------------------------------------------------------------
# Decompositions
# ---------------------------------------------------------------------------

def lup(m: Rows) -> tuple[Rows, Rows, Rows]:
    """Column-pivoted LU: returns (L, U, P) with  M = L @ U @ P  over F2.

    L is unit lower triangular, U is upper triangular (unit diagonal after
    pivoting), P is a permutation matrix. Requires M invertible.
    """
    n = len(m)
    a = list(m)
    colperm = list(range(n))  # colperm[k] = original column placed at position k
    lrows = list(identity(n))
    for k in range(n):
        # find pivot column among positions k..n-1 such that a[k] has a 1 there
        piv = None
        for jpos in range(k, n):
            if (a[k] >> colperm[jpos]) & 1:
                piv = jpos
                break
        if piv is None:
            raise SingularError("matrix is singular during LUP")
        colperm[k], colperm[piv] = colperm[piv], colperm[k]
        pk = colperm[k]
        for i in range(k + 1, n):
            if (a[i] >> pk) & 1:
                a[i] ^= a[k]
                lrows[i] ^= lrows[k]  # accumulate: L_inv_ops; fix below
    # After elimination: E @ M = U' where U' is upper in the *permuted* column
    # order, and lrows tracks E (product of elementary adds) applied to I.
    # So M = E^-1 @ U'.  U' in permuted order: U'[:, pos k] = a[:, colperm[k]].
    e = tuple(lrows)
    l = inverse(e)  # unit lower triangular
    # Build U in position space: U[i, k] = a[i, colperm[k]]
    urows = []
    for i in range(n):
        r = 0
        for kpos in range(n):
            if (a[i] >> colperm[kpos]) & 1:
                r |= 1 << kpos
        urows.append(r)
    u = tuple(urows)
    # Column permutation matrix C such that (X @ C)[:, k] = X[:, colperm[k]]:
    # C[j, k] = 1 iff j = colperm[k]  i.e. C = from_perm(q) with q(k)=colperm[k].
    # Then  M @ C = L @ U  =>  M = L @ U @ C^-1 ; C^-1 = C^T.
    c = from_perm([colperm[k] for k in range(n)])
    p = transpose(c)
    return l, u, p


def ulp(m: Rows) -> tuple[Rows, Rows, Rows]:
    """Paper §5.2 decomposition: returns (U, L, P) with  M = U @ L @ P.

    Computed by conjugating the column-pivoted LUP of R @ M with the
    bit-reversal matrix R:  R M = L' U' P'  =>  M = (R L' R)(R U' R)(R P').
    """
    n = len(m)
    r = reversal(n)
    l_, u_, p_ = lup(matmul(r, m))
    u = matmul(r, matmul(l_, r))
    l = matmul(r, matmul(u_, r))
    p = matmul(r, p_)
    # p must remain a permutation matrix (reversal of a permutation is one).
    return u, l, p


# ---------------------------------------------------------------------------
# Tiled-BMMC column finding (paper §5.1)
# ---------------------------------------------------------------------------

def _greedy_independent(rows: Rows, t: int, order: list) -> Optional[list]:
    low_mask = (1 << t) - 1
    basis: list = []
    chosen: list = []
    for j in order:
        v = column(rows, j) & low_mask
        for bv in basis:
            low = bv & -bv
            if v & low:
                v ^= bv
        if v:
            basis.append(v)
            chosen.append(j)
            if len(chosen) == t:
                return sorted(chosen)
    return None


def tiled_columns(rows: Rows, t: int, prefer_contiguous: bool = True) -> Optional[list]:
    """Find columns i_1..i_t making A a *tiled* BMMC for tile size 2^t.

    Requirements (paper §5.1): the submatrix of the first ``t`` rows on those
    columns is invertible, and the submatrix of the last ``n - t`` rows on
    those columns is zero. Returns the column list or None.

    ``prefer_contiguous`` (perf: kernel hillclimb iteration 3) biases the
    greedy independent-set search toward *contiguous runs* of candidate
    positions: each contiguous group of tile-row bit positions above ``t``
    collapses into one DMA stride dimension, so fewer groups means fewer
    descriptors (any valid witness is equally correct — this only changes
    which one we pick).
    """
    n = len(rows)
    if t > n:
        return None
    low_mask = (1 << t) - 1
    # candidate columns: support contained in the first t rows
    cands = [j for j in range(n)
             if (column(rows, j) >> t) == 0 and (column(rows, j) & low_mask)]
    if prefer_contiguous and len(cands) > t:
        # longest contiguous candidate runs first (preferring high positions,
        # which are thread-block-bit friendly), then the rest
        runs: list = []
        for j in sorted(cands):
            if runs and j == runs[-1][-1] + 1:
                runs[-1].append(j)
            else:
                runs.append([j])
        order = [j for run in sorted(runs, key=lambda r: (-len(r), -r[0]))
                 for j in run]
        got = _greedy_independent(rows, t, order)
        if got is not None:
            return got
    return _greedy_independent(rows, t, cands)


# ---------------------------------------------------------------------------
# Random generation (for tests / benchmarks; mirrors the paper's "random
# BPC / random BMMC" experiments)
# ---------------------------------------------------------------------------

def random_invertible(n: int, rng: random.Random) -> Rows:
    while True:
        rows = tuple(rng.randrange(1, 1 << n) for _ in range(n))
        if is_invertible(rows):
            return rows


def random_perm_matrix(n: int, rng: random.Random) -> Rows:
    p = list(range(n))
    rng.shuffle(p)
    return from_perm(p)
