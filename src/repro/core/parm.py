"""The ``parm`` combinator (paper §7) and its BMMC compilation.

``parm mask f xs`` partitions ``xs`` (size 2^n) into two sub-arrays by the
F2 dot product ``i * mask``, applies ``f`` to each, and stitches back.

Compilation (paper §7.2): ``parm m f = bmmc(A^-1, 0) ∘ parm 2^(n-1) f ∘
bmmc(A, 0)`` where ``A`` maps x to y with::

    y_i = x_i            (i < lsb(mask))
    y_i = x_{i+1}        (lsb(mask) <= i < n-1)
    y_{n-1} = x * mask   (the sub-array bit)

so the two sub-arrays become the two contiguous halves, preserving any
coalescing behaviour of ``f``.
"""
from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np

from . import f2
from .bmmc import Bmmc


def lsb(mask: int) -> int:
    assert mask > 0
    return (mask & -mask).bit_length() - 1


def parm_matrix(n: int, mask: int) -> Bmmc:
    """The matrix A of paper §7.2 (Fig. 13)."""
    assert 0 < mask < (1 << n)
    l = lsb(mask)
    rows = []
    for i in range(n - 1):
        rows.append(1 << (i if i < l else i + 1))
    rows.append(mask)
    return Bmmc(tuple(rows), 0)


# ---------------------------------------------------------------------------
# Reference (direct) semantics — no BMMC, used as the oracle in tests.
# ---------------------------------------------------------------------------

def _subarray_bits(n: int, mask: int) -> np.ndarray:
    idx = np.arange(1 << n)
    return np.bitwise_count(idx & mask).astype(np.int64) & 1


def parm_ref(mask: int, f: Callable, xs: np.ndarray) -> np.ndarray:
    """Direct index-partition semantics of ``parm`` (paper Fig. 3/13)."""
    n = int(np.log2(xs.shape[0]))
    assert (1 << n) == xs.shape[0]
    bit = _subarray_bits(n, mask)
    out = np.empty_like(xs)
    for b in (0, 1):
        sel = bit == b
        out[sel] = np.asarray(f(xs[sel]))
    return out


# ---------------------------------------------------------------------------
# BMMC-compiled semantics on jax arrays.
# ---------------------------------------------------------------------------

def parm(mask: int, f: Callable, xs: jax.Array, *, engine: Callable = None) -> jax.Array:
    """``parm`` compiled via BMMC permutations (paper §7.2).

    ``engine(xs, bmmc)`` applies a BMMC permutation to an array; defaults to
    the pure-jnp reference gather (``kernels.ref``). ``f`` maps arrays of
    size 2^(n-1) to arrays of size 2^(n-1) and must be jax-traceable.
    """
    if engine is None:
        from ..kernels import ref as _ref
        engine = _ref.bmmc_ref
    n = int(np.log2(xs.shape[0]))
    a = parm_matrix(n, mask)
    ys = engine(xs, a)
    half = xs.shape[0] // 2
    lo, hi = ys[:half], ys[half:]
    out = jnp.concatenate([f(lo), f(hi)], axis=0)
    return engine(out, a.inverse())
