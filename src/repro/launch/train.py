"""End-to-end training driver (CPU-runnable) with checkpoint/restart.

Trains a small-profile LM with the BMMC-shuffled data pipeline, periodic
integrity-checked checkpoints, and automatic resume — the single-host
version of the fault-tolerance story in DESIGN.md §5 (on a cluster, each
host runs this loop with its own loader shard; restore is elastic across
mesh changes).

Usage::

    python -m repro.launch.train --steps 200 --ckpt-dir /tmp/ckpt
    python -m repro.launch.train --arch mamba2-130m --profile smoke
    python -m repro.launch.train --profile 100m --steps 300   # ~100M params
"""
from __future__ import annotations

import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from ..checkpoint import ckpt
from ..configs import get_config, reduce_for_smoke
from ..configs.base import ArchConfig
from ..data.pipeline import DataConfig, ShardedLoader
from ..models import model as M
from ..optim.schedule import warmup_cosine
from ..train.step import init_opt, make_train_step

PROFILES = {
    # name -> (d_model, layers, heads, d_ff, vocab)  [~params]
    "smoke": (128, 4, 4, 512, 1024),          # ~1M: CI-speed
    "20m": (384, 8, 6, 1536, 8192),           # ~20M
    "100m": (768, 12, 12, 3072, 32768),       # ~124M (GPT-2-small-like)
}


def profile_config(profile: str, base: ArchConfig = None) -> ArchConfig:
    d, l, h, f, v = PROFILES[profile]
    kw = dict(d_model=d, n_heads=h, n_kv_heads=max(h // 2, 1), d_ff=f,
              vocab_size=v, n_periods=l, head_dim=d // h,
              dtype=jnp.float32, remat=False, kv_block=256)
    if base is None:
        return ArchConfig(name=f"lm-{profile}", family="dense",
                          pattern=("dense",), **kw)
    return dataclasses.replace(base, **kw)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None,
                    help="assigned arch id (reduced); default: plain dense LM")
    ap.add_argument("--profile", default="smoke", choices=sorted(PROFILES))
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=25)
    ap.add_argument("--log-every", type=int, default=5)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    if args.arch:
        cfg = reduce_for_smoke(get_config(args.arch))
    else:
        cfg = profile_config(args.profile)
    print(f"arch={cfg.name} params={cfg.n_params()/1e6:.1f}M "
          f"layers={cfg.n_layers}")

    dcfg = DataConfig(n_samples_log2=16, seq_len=args.seq,
                      vocab_size=cfg.vocab_size, seed=args.seed)
    loader = ShardedLoader(dcfg, batch_size=args.batch)

    key = jax.random.PRNGKey(args.seed)
    params = M.init(cfg, key)
    opt_state = init_opt(cfg, params)
    step_fn, opt_cfg = make_train_step(cfg)
    start = 0

    if args.ckpt_dir:
        last = ckpt.latest_step(args.ckpt_dir)
        if last is not None:
            (params, opt_state), extra = ckpt.restore(
                args.ckpt_dir, last, (params, opt_state))
            loader.restore(extra["loader"])
            start = last
            print(f"resumed from step {last} "
                  f"(epoch={loader.epoch}, loader step={loader.step})")

    jit_step = jax.jit(step_fn, donate_argnums=(0, 1))
    losses = []
    t0 = time.time()
    for step in range(start, args.steps):
        batch = {k: jnp.asarray(v) for k, v in next(loader).items()}
        lr_scale = warmup_cosine(step, warmup=20, total=args.steps)
        params, opt_state, metrics = jit_step(params, opt_state, batch)
        losses.append(float(metrics["loss"]))
        if step % args.log_every == 0 or step == args.steps - 1:
            dt = time.time() - t0
            tok_s = args.batch * args.seq * (step - start + 1) / max(dt, 1e-9)
            print(f"step {step:5d}  loss {losses[-1]:.4f}  "
                  f"grad_norm {float(metrics['grad_norm']):.3f}  "
                  f"tok/s {tok_s:,.0f}")
        if args.ckpt_dir and (step + 1) % args.ckpt_every == 0:
            path = ckpt.save(args.ckpt_dir, step + 1, (params, opt_state),
                             extra_state={"loader": loader.state(),
                                          "arch": cfg.name})
            print(f"checkpointed -> {path}")
    if len(losses) >= 10:
        first, last = np.mean(losses[:5]), np.mean(losses[-5:])
        print(f"loss: {first:.4f} -> {last:.4f} "
              f"({'improved' if last < first else 'NOT improved'})")
    return losses


if __name__ == "__main__":
    main()
