"""Production meshes. 16x16 = one v5e pod (256 chips); 2x16x16 = two pods.

Defined as functions (never module-level constants) so importing this module
does not touch jax device state.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False, shape=None):
    """Default (16,16) / (2,16,16); ``shape`` overrides the per-pod (data,
    model) factorization (perf knob: e.g. (32, 8) for 40-head archs whose
    heads don't divide 16 — see EXPERIMENTS.md §Perf)."""
    if shape is None:
        shape = (2, 16, 16) if multi_pod else (16, 16)
    elif multi_pod:
        shape = (2,) + tuple(shape)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(tuple(shape), axes)


def make_dev_mesh(n_data: int = 2, n_model: int = 2):
    """Small mesh for CPU multi-device tests (subprocess with fake devices)."""
    return jax.make_mesh((n_data, n_model), ("data", "model"))
