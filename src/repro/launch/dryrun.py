import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# ^ MUST precede every other import (jax locks the device count on first
# init). Only the dry-run sees 512 placeholder devices; tests/benches see 1.

import argparse
import json
import time
import traceback
from typing import Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from ..configs import ARCHS, SHAPES, get_config
from ..configs.base import ArchConfig, ShapeConfig
from ..models import model as M
from ..models.layers import shape_tree, axes_tree
from ..models.transformer import stack_cache_defs
from ..optim.adamw import AdamWConfig
from ..parallel.sharding import (batch_spec, param_shardings, spec_for)
from ..train.step import make_train_step, opt_state_shapes
from ..train.serve import make_decode_step, make_prefill_step
from .hlo_analysis import analyze_hlo
from .mesh import make_production_mesh
from . import hw

OUTDIR_DEFAULT = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                              "experiments", "dryrun")


# ---------------------------------------------------------------------------
# Input specs (ShapeDtypeStruct stand-ins; no allocation)
# ---------------------------------------------------------------------------

def input_specs(cfg: ArchConfig, shape: ShapeConfig) -> Dict:
    """Batch inputs for one step of the given kind."""
    b = shape.global_batch
    if shape.kind == "train":
        s = shape.seq_len
        batch = {"tokens": jax.ShapeDtypeStruct((b, s), jnp.int32),
                 "labels": jax.ShapeDtypeStruct((b, s), jnp.int32)}
    elif shape.kind == "prefill":
        s = shape.seq_len
        batch = {"tokens": jax.ShapeDtypeStruct((b, s), jnp.int32)}
    else:  # decode: one new token against a cache of seq_len
        batch = {"tokens": jax.ShapeDtypeStruct((b, 1), jnp.int32)}
    if (cfg.is_encdec or cfg.family == "vlm") and shape.kind != "decode":
        batch["src"] = jax.ShapeDtypeStruct((b, cfg.src_len, cfg.d_model),
                                            cfg.dtype)
    return batch


def batch_shardings(mesh: Mesh, batch: Dict):
    return {k: NamedSharding(mesh, batch_spec(mesh, v.shape[0], len(v.shape)))
            for k, v in batch.items()}


def _opt_shardings(mesh: Mesh, pshapes, paxes, opt_cfg: AdamWConfig):
    from ..optim.adamw import state_shapes
    osh = state_shapes(pshapes, opt_cfg)
    if opt_cfg.state_bits == 8:
        # Quantized moments keep the parameter's leading dims (blocks run
        # along the last axis), so they inherit the parameter's sharding
        # with the trailing (blocks, block)/(blocks, 1) dims replicated.
        def rec(sh, ax):
            if isinstance(sh, dict) and set(sh) == {"q", "s"}:
                lead = tuple(ax[:-1]) if ax else ()
                return {"q": NamedSharding(mesh, spec_for(
                            mesh, lead + (None, None), sh["q"].shape)),
                        "s": NamedSharding(mesh, spec_for(
                            mesh, lead + (None, None), sh["s"].shape))}
            return {k: rec(sh[k], ax[k]) for k in sh}
        return type(osh)(step=NamedSharding(mesh, P()),
                         m=rec(osh.m, paxes), v=rec(osh.v, paxes))
    pshard = param_shardings(mesh, pshapes, paxes)
    return type(osh)(step=NamedSharding(mesh, P()), m=pshard, v=pshard)


def _sharded_bytes(sds, sharding, mesh: Mesh) -> float:
    """Per-device bytes of one array under its sharding."""
    spec = sharding.spec
    shards = 1
    for entry in spec:
        if entry is None:
            continue
        names = entry if isinstance(entry, tuple) else (entry,)
        for n in names:
            shards *= mesh.shape[n]
    return sds.dtype.itemsize * float(np.prod(sds.shape, dtype=np.float64)) / shards


def _tree_bytes(shapes, shardings, mesh) -> float:
    total = 0.0
    flat_s = jax.tree.leaves(shapes)
    flat_h = jax.tree.leaves(shardings,
                             is_leaf=lambda x: isinstance(x, NamedSharding))
    for s, h in zip(flat_s, flat_h):
        total += _sharded_bytes(s, h, mesh)
    return total


# ---------------------------------------------------------------------------
# Cell construction
# ---------------------------------------------------------------------------

def build_cell(cfg: ArchConfig, shape: ShapeConfig, mesh: Mesh,
               grad_accum: int = 1):
    """Returns (fn, args, in_shardings, out_shardings, donate, analytic)."""
    pshapes = M.param_shapes(cfg)
    paxes = M.param_axes(cfg)
    pshard = param_shardings(mesh, pshapes, paxes)
    batch = input_specs(cfg, shape)
    bshard = batch_shardings(mesh, batch)
    analytic = {"param_bytes_per_device": _tree_bytes(pshapes, pshard, mesh)}

    if shape.kind == "train":
        opt_cfg = AdamWConfig(state_bits=cfg.opt_bits)
        oshapes = opt_state_shapes(cfg, pshapes, opt_cfg)
        oshard = _opt_shardings(mesh, pshapes, paxes, opt_cfg)
        analytic["opt_bytes_per_device"] = _tree_bytes(oshapes, oshard, mesh)
        step_fn, _ = make_train_step(cfg, mesh, opt_cfg, grad_accum=grad_accum)
        args = (pshapes, oshapes, batch)
        in_sh = (pshard, oshard, bshard)
        out_sh = (pshard, oshard, None)
        donate = (0, 1)
        return step_fn, args, in_sh, out_sh, donate, analytic

    if shape.kind == "prefill":
        cdefs = stack_cache_defs(cfg, shape.global_batch, shape.seq_len)
        cshapes, cax = shape_tree(cdefs), axes_tree(cdefs)
        cshard = param_shardings(mesh, cshapes, cax)
        analytic["cache_bytes_per_device"] = _tree_bytes(cshapes, cshard, mesh)
        fn = make_prefill_step(cfg, mesh)
        args = (pshapes, batch)
        in_sh = (pshard, bshard)
        out_sh = (None, cshard)
        return fn, args, in_sh, out_sh, (), analytic

    # decode
    cdefs = stack_cache_defs(cfg, shape.global_batch, shape.seq_len)
    cshapes, cax = shape_tree(cdefs), axes_tree(cdefs)
    cshard = param_shardings(mesh, cshapes, cax)
    analytic["cache_bytes_per_device"] = _tree_bytes(cshapes, cshard, mesh)
    fn = make_decode_step(cfg, mesh)
    tokens = batch["tokens"]
    pos = jax.ShapeDtypeStruct((), jnp.int32)
    args = (pshapes, cshapes, tokens, pos)
    in_sh = (pshard, cshard, bshard["tokens"], NamedSharding(mesh, P()))
    out_sh = (None, cshard)
    return fn, args, in_sh, out_sh, (1,), analytic


def model_flops(cfg: ArchConfig, shape: ShapeConfig) -> float:
    """6*N*D (train) / 2*N*D (inference); N = active params for MoE."""
    n = cfg.n_active_params()
    if shape.kind == "train":
        d = shape.global_batch * shape.seq_len
        return 6.0 * n * d
    if shape.kind == "prefill":
        d = shape.global_batch * shape.seq_len
        return 2.0 * n * d
    return 2.0 * n * shape.global_batch  # one token per sequence


def skip_reason(cfg: ArchConfig, shape: ShapeConfig) -> Optional[str]:
    if shape.name == "long_500k" and not cfg.sub_quadratic:
        return ("skipped (full attention): 500k-token decode requires "
                "sub-quadratic attention; this arch is full-attention "
                "(see DESIGN.md §4)")
    return None


# ---------------------------------------------------------------------------
# Runner
# ---------------------------------------------------------------------------

def run_cell(arch: str, shape_name: str, multi_pod: bool, outdir: str,
             resume: bool = True, mesh_shape=None, grad_accum: int = 1) -> Dict:
    import dataclasses
    cfg = get_config(arch)
    remat = os.environ.get("DRYRUN_REMAT")
    if remat:
        cfg = dataclasses.replace(cfg, remat_policy=remat)
    sp_env = os.environ.get("DRYRUN_SP")
    if sp_env is not None:
        cfg = dataclasses.replace(cfg, seq_parallel=sp_env not in ("0", "off"))
    moe_impl = os.environ.get("DRYRUN_MOE")
    if moe_impl:
        cfg = dataclasses.replace(cfg, moe_impl=moe_impl)
    shape = SHAPES[shape_name]
    if mesh_shape is None:
        mesh_name = "pod2x16x16" if multi_pod else "pod16x16"
    else:
        base = "x".join(str(d) for d in mesh_shape)
        mesh_name = f"pod2x{base}" if multi_pod else f"pod{base}"
    cell_id = f"{arch}__{shape_name}__{mesh_name}"
    if grad_accum > 1:
        cell_id += f"__ga{grad_accum}"
    if remat:
        cell_id += f"__remat-{remat}"
    if sp_env is not None:
        cell_id += "__sp" if cfg.seq_parallel else "__nosp"
    if moe_impl:
        cell_id += f"__moe-{moe_impl}"
    path = os.path.join(outdir, cell_id + ".json")
    if resume and os.path.exists(path):
        with open(path) as f:
            rec = json.load(f)
        if "error" not in rec:
            print(f"[skip: done] {cell_id}")
            return rec

    rec: Dict = {"arch": arch, "shape": shape_name, "mesh": mesh_name,
                 "n_devices": 512 if multi_pod else 256,
                 "kind": shape.kind,
                 "model_flops": model_flops(cfg, shape),
                 "n_params": cfg.n_params(),
                 "n_active_params": cfg.n_active_params()}
    reason = skip_reason(cfg, shape)
    if reason:
        rec["skipped"] = reason
        _save(path, rec)
        print(f"[skip: design] {cell_id}: {reason}")
        return rec

    try:
        mesh = make_production_mesh(multi_pod=multi_pod, shape=mesh_shape)
        t0 = time.time()
        fn, args, in_sh, out_sh, donate, analytic = build_cell(
            cfg, shape, mesh, grad_accum=grad_accum)
        rec.update(analytic)
        jitted = jax.jit(fn, in_shardings=in_sh, out_shardings=out_sh,
                         donate_argnums=donate)
        lowered = jitted.lower(*args)
        rec["lower_s"] = time.time() - t0
        t1 = time.time()
        compiled = lowered.compile()
        rec["compile_s"] = time.time() - t1
        ca = compiled.cost_analysis() or {}
        rec["cost_analysis"] = {k: float(v) for k, v in ca.items()
                                if isinstance(v, (int, float))
                                and k in ("flops", "bytes accessed",
                                          "optimal_seconds", "utilization")}
        ma = compiled.memory_analysis()
        if ma is not None:
            rec["memory_analysis"] = {
                k: int(getattr(ma, k)) for k in
                ("argument_size_in_bytes", "output_size_in_bytes",
                 "temp_size_in_bytes", "alias_size_in_bytes",
                 "generated_code_size_in_bytes") if hasattr(ma, k)}
        t2 = time.time()
        hlo = compiled.as_text()
        rec["hlo_bytes"] = len(hlo)
        rec["hlo_analysis"] = analyze_hlo(hlo)
        rec["analyze_s"] = time.time() - t2
        print(f"[ok] {cell_id}: compile {rec['compile_s']:.1f}s  "
              f"dot_flops/dev {rec['hlo_analysis'].get('dot_flops', 0):.3e}  "
              f"coll/dev {rec['hlo_analysis'].get('collective_total', 0):.3e}B")
    except Exception as e:  # record the failure; a failing cell is a bug
        rec["error"] = f"{type(e).__name__}: {e}"
        rec["traceback"] = traceback.format_exc()[-4000:]
        print(f"[FAIL] {cell_id}: {rec['error']}")
    _save(path, rec)
    return rec


def _save(path: str, rec: Dict):
    os.makedirs(os.path.dirname(path), exist_ok=True)
    with open(path, "w") as f:
        json.dump(rec, f, indent=1, default=float)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="all")
    ap.add_argument("--shape", default="all")
    ap.add_argument("--mesh", default="both", choices=["single", "multi", "both"])
    ap.add_argument("--outdir", default=os.environ.get("DRYRUN_OUT",
                                                       "experiments/dryrun"))
    ap.add_argument("--no-resume", action="store_true")
    ap.add_argument("--mesh-shape", default=None,
                    help="override per-pod (data,model), e.g. 32x8")
    ap.add_argument("--grad-accum", type=int, default=1)
    ap.add_argument("--remat", default=None, choices=[None, "nothing", "dots"])
    args = ap.parse_args()
    if args.remat:
        os.environ["DRYRUN_REMAT"] = args.remat
    mesh_shape = (tuple(int(d) for d in args.mesh_shape.split("x"))
                  if args.mesh_shape else None)

    archs = sorted(ARCHS) if args.arch == "all" else [args.arch]
    shapes = list(SHAPES) if args.shape == "all" else [args.shape]
    meshes = {"single": [False], "multi": [True], "both": [False, True]}[args.mesh]

    for arch in archs:
        for shape in shapes:
            for mp in meshes:
                run_cell(arch, shape, mp, args.outdir,
                         resume=not args.no_resume, mesh_shape=mesh_shape,
                         grad_accum=args.grad_accum)


if __name__ == "__main__":
    main()
