"""Serving driver: batched prefill + greedy decode with a KV cache.

Usage::

    python -m repro.launch.serve --arch mistral-nemo-12b --tokens 32
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from ..configs import get_config, reduce_for_smoke
from ..models import model as M
from ..models.layers import init_params


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="mistral-nemo-12b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--tokens", type=int, default=16)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = reduce_for_smoke(get_config(args.arch))
    key = jax.random.PRNGKey(args.seed)
    params = M.init(cfg, key)
    total = args.prompt_len + args.tokens

    prompts = jax.random.randint(key, (args.batch, args.prompt_len), 0,
                                 cfg.vocab_size)
    batch = {"tokens": prompts}
    if cfg.is_encdec or cfg.family == "vlm":
        batch["src"] = jax.random.normal(key, (args.batch, cfg.src_len,
                                               cfg.d_model), cfg.dtype)

    t0 = time.time()
    logits, caches = M.prefill(cfg, params, batch)
    # grow caches to the full decode horizon
    caches = M.grow_caches(caches, args.prompt_len, total)
    prefill_s = time.time() - t0

    decode = jax.jit(
        lambda p, c, t, pos: M.decode_step(cfg, p, c, t, pos),
        donate_argnums=(1,))

    tok = jnp.argmax(logits[:, -1], axis=-1)[:, None].astype(jnp.int32)
    out_tokens = [tok]
    t1 = time.time()
    for i in range(args.tokens - 1):
        logits, caches = decode(params, caches, tok,
                                jnp.int32(args.prompt_len + i))
        tok = jnp.argmax(logits[:, -1], axis=-1)[:, None].astype(jnp.int32)
        out_tokens.append(tok)
    decode_s = time.time() - t1
    gen = np.concatenate([np.asarray(t) for t in out_tokens], axis=1)
    print(f"arch={cfg.name} batch={args.batch}")
    print(f"prefill: {args.prompt_len} tokens in {prefill_s:.2f}s")
    print(f"decode:  {args.tokens} tokens in {decode_s:.2f}s "
          f"({args.batch * args.tokens / max(decode_s, 1e-9):.1f} tok/s)")
    print("generated ids (first row):", gen[0][:16])
    return gen


if __name__ == "__main__":
    main()
