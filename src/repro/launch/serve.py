"""Serving driver: batched prefill + greedy decode with a KV cache.

Usage::

    python -m repro.launch.serve --arch mistral-nemo-12b --tokens 32

``--telemetry`` enables :mod:`repro.obs`: per-request (= per decode
step) latency histograms labeled warm/cold — the first decode call pays
the jit compile, and lumping it in with steady-state latency hid every
warm-path regression — plus the executor's dispatch counters, rendered
with ``obs.report()`` at exit. ``--trace OUT.json`` additionally writes
the Chrome trace.

``--validate`` turns on :mod:`repro.guard` for the whole run (ring 1
always-on validation plus ring-2 guarded dispatch, DESIGN.md §14).
Guard resolution is per request: after each prefill/decode step the
accumulated trap/fallback counters are checked and recovered
degradations are reported.

Failure handling is the resilience layer's request lifecycle
(DESIGN.md §16), not process abort: every prefill/decode step runs
under :func:`repro.resilience.run_with_policy` — retryable
:class:`~repro.guard.GuardError`\\ s get ``--retries`` bounded retries
with deterministic backoff inside the optional ``--deadline-ms``
budget, and an exhausted/terminal failure becomes a **structured
per-request error result** (printed, counted) while the process keeps
draining. At drain the full summary always prints (decode report +
guard/store/resilience counters) and ``--error-budget`` decides the
exit code: more request errors than the budget exits 1. SIGTERM is
graceful drain — the loop finishes its in-flight decode step, reports
``drained:``, and still prints the complete summary with exit 0.

``--store PATH`` points the process at a durable plan store
(DESIGN.md §15): compiled permutation plans load from disk instead of
re-planning on boot, every loaded plan re-audits through ring 1, and
per-request ``store.hit/miss/quarantined`` deltas print next to the
guard resolution report. ``examples/serve_batch.py`` drives the cold
vs disk-warm first-request comparison end to end.
"""
from __future__ import annotations

import argparse
import signal
import time

import jax
import jax.numpy as jnp
import numpy as np

from .. import guard, obs, resilience, store as _store
from ..configs import get_config, reduce_for_smoke
from ..models import model as M
from ..models.layers import init_params


def _guard_resolve(where: str, base: dict) -> dict:
    """Per-request guard resolution: report counter deltas since
    ``base`` (recovered degradations stay a warning; the raising path
    never reaches here — the typed error aborts in ``main``). Returns
    the new baseline."""
    now = guard.stats()
    trapped = (sum(now["traps"].values())
               - sum(base["traps"].values()))
    recovered = now["recovered"] - base["recovered"]
    if trapped:
        print(f"guard[{where}]: {trapped} trap(s), "
              f"{recovered} recovered via engine fallback")
    return now


def _store_resolve(where: str, base: dict) -> dict:
    """Per-request plan-store resolution, printed next to the guard
    report: hit/miss/quarantined deltas since ``base``. A quarantine
    is never silent — the corrupt entry was refused, replanned past,
    and left in ``quarantine/`` for post-mortem."""
    now = _store.stats()
    hit = now["hit"] - base["hit"]
    miss = now["miss"] - base["miss"]
    quarantined = now["quarantined"] - base["quarantined"]
    if hit or miss or quarantined:
        extra = (f", {quarantined} QUARANTINED (corrupt entry refused, "
                 f"replanned)" if quarantined else "")
        print(f"store[{where}]: {hit} hit / {miss} miss{extra}")
    return now


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="mistral-nemo-12b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--tokens", type=int, default=16)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--telemetry", action="store_true",
                    help="record+print repro.obs latency/dispatch report")
    ap.add_argument("--trace", default=None, metavar="OUT.json",
                    help="write a chrome://tracing span export (implies "
                         "--telemetry)")
    ap.add_argument("--validate", action="store_true",
                    help="guarded execution (repro.guard): validate "
                         "plans, trap faults in-program, degrade "
                         "pallas->ref; exit nonzero on an unrecovered "
                         "trap")
    ap.add_argument("--error-budget", type=int, default=0, metavar="N",
                    help="max per-request structured errors tolerated "
                         "before the drain exit code goes nonzero "
                         "(default 0: any unrecovered request error "
                         "fails the run — but only after draining and "
                         "printing the full summary)")
    ap.add_argument("--deadline-ms", type=float, default=None,
                    metavar="MS",
                    help="per-request deadline budget (attempts + "
                         "retry backoff); an exhausted budget is a "
                         "structured 'deadline' request error")
    ap.add_argument("--retries", type=int, default=1, metavar="N",
                    help="bounded retries of retryable GuardErrors per "
                         "request (deterministic seeded backoff; "
                         "default 1)")
    ap.add_argument("--store", default=None, metavar="PATH",
                    help="durable plan store root (DESIGN.md §15): load "
                         "compiled permutation plans from disk, report "
                         "per-request hit/miss/quarantine deltas")
    ap.add_argument("--head-shuffle", default=None, metavar="ENGINE",
                    choices=("ref", "pallas"),
                    help="enable the BMMC kv-head shuffle through ENGINE "
                         "(needs power-of-two n_kv_heads >= 2); with "
                         "'pallas' the serving path exercises compiled "
                         "permutation plans, so --store traffic is real")
    ap.add_argument("--kv-heads", type=int, default=None, metavar="N",
                    help="override n_kv_heads (power of two; n_heads is "
                         "raised to match if needed) — the smoke configs "
                         "reduce to 2 kv heads, whose 1-bit shuffle is "
                         "identity, so --head-shuffle demos want >= 4")
    args = ap.parse_args(argv)
    if args.telemetry or args.trace:
        obs.enable(sync=True)
    if args.validate:
        guard.enable()
    if args.store:
        _store.configure(args.store)
        _store.reset_stats()

    cfg = reduce_for_smoke(get_config(args.arch))
    if args.kv_heads or args.head_shuffle:
        import dataclasses
        repl = {}
        if args.kv_heads:
            repl["n_kv_heads"] = args.kv_heads
            repl["n_heads"] = max(cfg.n_heads, args.kv_heads)
        if args.head_shuffle:
            repl["head_shuffle"] = args.head_shuffle
        cfg = dataclasses.replace(cfg, **repl)
    key = jax.random.PRNGKey(args.seed)
    params = M.init(cfg, key)
    total = args.prompt_len + args.tokens

    prompts = jax.random.randint(key, (args.batch, args.prompt_len), 0,
                                 cfg.vocab_size)
    batch = {"tokens": prompts}
    if cfg.is_encdec or cfg.family == "vlm":
        batch["src"] = jax.random.normal(key, (args.batch, cfg.src_len,
                                               cfg.d_model), cfg.dtype)

    gbase = guard.stats() if args.validate else None
    sbase = _store.stats() if args.store else None

    policy = resilience.RetryPolicy(max_retries=max(0, args.retries),
                                    seed=args.seed)
    deadline_s = args.deadline_ms / 1e3 if args.deadline_ms else None
    errors = []

    def _request(where, fn, request_id):
        """One policied request: bounded retries + deadline; a failure
        becomes a structured, printed result — never a process abort."""
        res = resilience.run_with_policy(fn, policy=policy,
                                         deadline_s=deadline_s,
                                         request_id=request_id)
        if not res.ok:
            errors.append((where, res))
            print(f"request[{where}]: {res.describe()}")
        elif res.retries:
            print(f"request[{where}]: recovered after "
                  f"{res.retries} retry(ies)")
        return res

    # SIGTERM = graceful drain: finish the in-flight decode step, then
    # fall through to the summary with the tokens served so far
    drain = {"sigterm": False}
    try:
        prev_term = signal.signal(
            signal.SIGTERM, lambda *_: drain.update(sigterm=True))
    except ValueError:          # not the main thread (e.g. under tests)
        prev_term = None

    try:
        t0 = time.time()
        with obs.span("serve.prefill", batch=args.batch,
                      prompt_len=args.prompt_len):
            res = _request("prefill",
                           lambda: M.prefill(cfg, params, batch), 0)
            if res.ok and obs.sync_enabled():
                jax.block_until_ready(res.value[0])
        if args.validate:
            gbase = _guard_resolve("prefill", gbase)
        if args.store:
            sbase = _store_resolve("prefill", sbase)
        prefill_s = time.time() - t0
        if not res.ok:
            _summary(args, cfg, None, prefill_s, 0.0, 0, errors)
            raise SystemExit(1)   # nothing decodable without a prefill
        logits, caches = res.value
        # grow caches to the full decode horizon
        caches = M.grow_caches(caches, args.prompt_len, total)
        if obs.enabled():
            obs.observe("serve.request_us", prefill_s * 1e6,
                        phase="prefill", cache="cold")

        decode = jax.jit(
            lambda p, c, t, pos: M.decode_step(cfg, p, c, t, pos),
            donate_argnums=(1,))

        tok = jnp.argmax(logits[:, -1], axis=-1)[:, None].astype(jnp.int32)
        out_tokens = [tok]
        print(f"serving: decode starting (tokens={args.tokens})",
              flush=True)
        t1 = time.time()
        warm_steps = 0
        for i in range(args.tokens - 1):
            if drain["sigterm"]:
                print(f"drained: SIGTERM after {len(out_tokens)}/"
                      f"{args.tokens} tokens", flush=True)
                break
            with obs.span("serve.decode_step", step=i,
                          cache="cold" if i == 0 else "warm"):
                tr = time.perf_counter_ns()
                res = _request(
                    f"decode step {i}",
                    lambda: decode(params, caches, tok,
                                   jnp.int32(args.prompt_len + i)),
                    i + 1)
                if res.ok and obs.sync_enabled():
                    jax.block_until_ready(res.value[0])
                if res.ok and obs.enabled():
                    # the first decode call carries the jit trace+
                    # compile; label it cold so warm-path latency stays
                    # readable
                    obs.observe("serve.request_us",
                                (time.perf_counter_ns() - tr) / 1e3,
                                phase="decode",
                                cache="cold" if i == 0 else "warm")
            if args.validate:
                gbase = _guard_resolve(f"decode step {i}", gbase)
            if args.store:
                sbase = _store_resolve(f"decode step {i}", sbase)
            if not res.ok:
                # the step's KV cache buffers were donated to the failed
                # attempt — later steps would read freed state, so drain
                # with the tokens served so far; the budget decides the
                # exit code
                break
            logits, caches = res.value
            if i > 0:
                warm_steps += 1
            tok = jnp.argmax(logits[:, -1],
                             axis=-1)[:, None].astype(jnp.int32)
            out_tokens.append(tok)
        decode_s = time.time() - t1
    finally:
        if prev_term is not None:
            signal.signal(signal.SIGTERM, prev_term)

    gen = np.concatenate([np.asarray(t) for t in out_tokens], axis=1)
    _summary(args, cfg, gen, prefill_s, decode_s, warm_steps, errors)
    if len(errors) > args.error_budget:
        raise SystemExit(1)
    return gen


def _summary(args, cfg, gen, prefill_s, decode_s, warm_steps, errors):
    """The drain-time report: always printed in full — on success, on
    drained SIGTERM, and on over-budget failure alike."""
    served = 0 if gen is None else gen.shape[1]
    print(f"arch={cfg.name} batch={args.batch}")
    print(f"prefill: {args.prompt_len} tokens in {prefill_s:.2f}s")
    if warm_steps > 0:
        rate = f"{args.batch * served / max(decode_s, 1e-9):.1f} tok/s"
    else:
        # --tokens 1 (or a first-step failure) times zero warm decode
        # steps; a rate derived from max(decode_s, 1e-9) is nonsense
        rate = "n/a tok/s — no warm decode step timed"
    print(f"decode:  {served}/{args.tokens} tokens in {decode_s:.2f}s "
          f"({rate})")
    if gen is not None:
        print("generated ids (first row):", gen[0][:16])
    if args.validate:
        gs = guard.stats()
        print(f"guard: traps={sum(gs['traps'].values())} "
              f"fallbacks={sum(gs['fallbacks'].values())} "
              f"recovered={gs['recovered']} (all requests validated)")
    if args.store:
        ss = _store.stats()
        st = _store.active()
        print(f"store: hits={ss['hit']} misses={ss['miss']} "
              f"plans_built={ss['plan_built']} "
              f"quarantined={ss['quarantined']} "
              f"({st.entry_count()} entries on disk at {st.root})")
    rs = resilience.stats()
    print(f"resilience: requests={rs['requests']} "
          f"retries={rs['retries']} "
          f"deadline_exceeded={rs['deadline_exceeded']} "
          f"errors={len(errors)} (budget {args.error_budget}) "
          f"breaker={rs['breaker']}")
    if args.trace:
        print(f"trace written to {obs.export_trace(args.trace)}")
    if obs.enabled():
        print(obs.report())


if __name__ == "__main__":
    main()
