"""Target-hardware constants for roofline analysis (TPU v5e per chip)."""

PEAK_FLOPS_BF16 = 197e12     # FLOP/s per chip, bf16
HBM_BW = 819e9               # bytes/s per chip
ICI_BW = 50e9                # bytes/s per link (per-chip injection, ~1 link)
HBM_BYTES = 16 * 1024**3     # 16 GiB per chip

CHIPS_SINGLE_POD = 256
CHIPS_MULTI_POD = 512
