"""Post-partitioning HLO analysis: collective bytes + trip-weighted FLOPs.

``compiled.cost_analysis()`` on XLA counts a ``while`` body **once** and has
no per-collective breakdown, so we parse the partitioned HLO text
(``compiled.as_text()``):

* every ``all-gather / all-reduce / reduce-scatter / all-to-all /
  collective-permute`` contributes its operand bytes (resolved through a
  per-computation symbol table, since operands are name references);
* every ``dot`` contributes ``2 * prod(out_dims) * prod(contracted_dims)``
  FLOPs;
* ops inside ``while`` bodies are multiplied by the loop trip count taken
  from ``backend_config={"known_trip_count":{"n":...}}`` (fallback: largest
  constant in the loop condition), so ``lax.scan`` over layers / KV blocks
  is accounted exactly;
* ``fusion`` (calls=), ``call``/``custom-call`` (to_apply=) and conditional
  branches are walked bottom-up.

All quantities are **per-device** (the HLO is the per-device SPMD program).
Validated against hand-counted programs in tests/test_hlo_analysis.py.
"""
from __future__ import annotations

import collections
import re
from typing import Dict, List, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "f8e4m3fn": 1, "f8e5m2": 1, "s4": 1, "u4": 1, "c128": 16,
}

COLLECTIVE_KINDS = ("all-gather", "all-reduce", "reduce-scatter",
                    "all-to-all", "collective-permute")

_SHAPE_TOK = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_DEF_RE = re.compile(r"^(?:ROOT\s+)?%([\w\.\-]+)\s*=\s*(.*)$")
_TRIP_RE = re.compile(r'"known_trip_count":\{"n":"(\d+)"')


def _parse_shapes(text: str) -> List[Tuple[str, List[int]]]:
    out = []
    for m in _SHAPE_TOK.finditer(text):
        dt, dims = m.group(1), m.group(2)
        if dt in _DTYPE_BYTES:
            out.append((dt, [int(d) for d in dims.split(",")] if dims else []))
    return out


def _shape_bytes_list(shapes) -> int:
    total = 0
    for dt, dims in shapes:
        n = 1
        for d in dims:
            n *= d
        total += n * _DTYPE_BYTES[dt]
    return total


def _result_shapes(rhs: str):
    """Shapes of an op's result: everything before the opcode's '('."""
    i = rhs.find("(")
    head = rhs if i < 0 else rhs[:i]
    return _parse_shapes(head)


def _operand_names(rhs: str, opcode: str = None) -> List[str]:
    """Names inside the op's argument parens.

    With tuple-typed results (e.g. ``(s32[..], ..) all-to-all(%a, %b)``)
    the first ``(`` belongs to the result *type*; anchor on the opcode
    token when given.
    """
    i = -1
    if opcode:
        m = re.search(re.escape(opcode) + r"\(", rhs)
        if m:
            i = m.end() - 1
    if i < 0:
        i = rhs.find("(")
    if i < 0:
        return []
    depth = 0
    for j in range(i, len(rhs)):
        if rhs[j] == "(":
            depth += 1
        elif rhs[j] == ")":
            depth -= 1
            if depth == 0:
                return re.findall(r"%([\w\.\-]+)", rhs[i:j + 1])
    return re.findall(r"%([\w\.\-]+)", rhs[i:])


class _Comp:
    def __init__(self):
        self.lines: List[str] = []
        self.shapes: Dict[str, List] = {}   # symbol -> result shapes
        self.params: Dict[str, List] = {}


def _split_computations(hlo: str) -> Dict[str, _Comp]:
    comps: Dict[str, _Comp] = {}
    cur = None
    for raw in hlo.splitlines():
        line = raw.strip()
        if cur is None:
            if line.endswith("{"):
                m = re.match(r"^(?:ENTRY\s+)?%?([\w\.\-]+)\s*\(", line)
                if m:
                    cur = m.group(1)
                    comps[cur] = _Comp()
                    # parameters declared in the header: %name: shape
                    for pm in re.finditer(r"([\w\.\-]+):\s*((?:\([^)]*\))|(?:[a-z0-9]+\[[0-9,]*\]))",
                                          line):
                        comps[cur].params[pm.group(1)] = _parse_shapes(pm.group(2))
        else:
            if line.startswith("}"):
                cur = None
                continue
            comps[cur].lines.append(line)
            dm = _DEF_RE.match(line)
            if dm:
                comps[cur].shapes[dm.group(1)] = _result_shapes(dm.group(2))
    return comps


def _called(line: str) -> List[Tuple[str, str]]:
    names = []
    for attr in ("to_apply=", "body=", "condition=", "true_computation=",
                 "false_computation=", "calls="):
        for m in re.finditer(re.escape(attr) + r"%?([\w\.\-]+)", line):
            names.append((attr[:-1], m.group(1)))
    i = line.find("branch_computations={")
    if i >= 0:
        inner = line[i + len("branch_computations={"):line.find("}", i)]
        for nm in inner.split(","):
            names.append(("branch", nm.strip().lstrip("%")))
    return names


def _dot_flops(comp: _Comp, rhs: str) -> float:
    out_shapes = _result_shapes(rhs)
    if not out_shapes:
        return 0.0
    out_elems = 1
    for d in out_shapes[0][1]:
        out_elems *= d
    ops = _operand_names(rhs)
    contract = 1
    m = re.search(r"lhs_contracting_dims=\{([0-9,]*)\}", rhs)
    if m and ops:
        lhs = comp.shapes.get(ops[0]) or comp.params.get(ops[0])
        if lhs:
            dims = lhs[0][1]
            for idx in m.group(1).split(","):
                if idx and int(idx) < len(dims):
                    contract *= dims[int(idx)]
    return 2.0 * out_elems * contract


def analyze_hlo(hlo: str) -> Dict[str, float]:
    """Returns per-device, trip-weighted: collective bytes by kind + dot flops."""
    comps = _split_computations(hlo)
    memo: Dict[str, Dict[str, float]] = {}

    def resolve_bytes(comp: _Comp, names: List[str]) -> int:
        total = 0
        for n in names:
            sh = comp.shapes.get(n) or comp.params.get(n)
            if sh:
                total += _shape_bytes_list(sh)
        return total

    def analyze(name: str) -> Dict[str, float]:
        if name in memo:
            return memo[name]
        memo[name] = collections.defaultdict(float)  # cycle guard
        comp = comps.get(name)
        total = collections.defaultdict(float)
        if comp is None:
            memo[name] = {}
            return memo[name]
        for line in comp.lines:
            dm = _DEF_RE.match(line)
            rhs = dm.group(2) if dm else line
            opcode_m = re.match(r"(?:\([^)]*\)|[a-z0-9]+\[[0-9,]*\](?:\{[^}]*\})?|\s|,)*([\w\-]+)\(", rhs)
            opcode = opcode_m.group(1) if opcode_m else ""
            handled_sub = False
            if opcode.endswith("-done"):
                continue
            base = opcode[:-6] if opcode.endswith("-start") else opcode
            if base in COLLECTIVE_KINDS:
                total[base] += resolve_bytes(comp, _operand_names(rhs, opcode))
            elif base == "dot":
                total["dot_flops"] += _dot_flops(comp, rhs)
            elif base == "while":
                calls = dict((a, n) for a, n in _called(rhs))
                trips = 1
                tm = _TRIP_RE.search(rhs)
                if tm:
                    trips = int(tm.group(1))
                elif "condition" in calls:
                    for ln in comps.get(calls["condition"], _Comp()).lines:
                        for cm in re.finditer(r"constant\((\d+)\)", ln):
                            trips = max(trips, int(cm.group(1)))
                if "body" in calls:
                    for k, v in analyze(calls["body"]).items():
                        total[k] += v * trips
                handled_sub = True
            if not handled_sub:
                for attr, sub in _called(rhs):
                    if attr in ("body", "condition"):
                        continue
                    for k, v in analyze(sub).items():
                        total[k] += v
        memo[name] = dict(total)
        return memo[name]

    entry = None
    m = re.search(r"ENTRY\s+%?([\w\.\-]+)", hlo)
    if m:
        entry = m.group(1)
    out = analyze(entry) if entry and entry in comps else {}
    result = {k: float(v) for k, v in out.items()}
    result["collective_total"] = float(
        sum(v for k, v in result.items() if k in COLLECTIVE_KINDS))
    result.setdefault("dot_flops", 0.0)
    return result


def collective_bytes(hlo: str) -> Dict[str, float]:
    r = analyze_hlo(hlo)
    out = {k: v for k, v in r.items() if k in COLLECTIVE_KINDS}
    out["total"] = r["collective_total"]
    return out
