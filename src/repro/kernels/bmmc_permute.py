"""Pallas TPU kernels for tiled BMMC permutations (paper §4-5, TPU-adapted).

Design (see DESIGN.md §2 for the GPU->TPU mapping, §10 for the fused
pipeline):

* The array lives in HBM as a (2^(n-t), 2^t[, d]) row view. The offline
  ``TilePlan`` guarantees both the rows read and the rows written by one
  *tile* (= ``rows_per_tile`` full rows) are whole, contiguous
  ``2^t``-element runs (the TPU analogue of full coalescing).
* Row id tables (``in_rows``/``out_rows``), the per-tile lane XOR and the
  intra-tile gather table ``src0`` are *offline* artifacts (scalar-prefetch /
  VMEM constants), mirroring the paper's offline codegen setting.
* Consecutive row ids are merged into one DMA descriptor (``in_run`` /
  ``out_run`` rows per copy) — the DMA analogue of the paper's §4.3
  iteration amortization.
* The intra-tile permutation is a flat VMEM gather
  ``out.flat[j] = tile.flat[src0[j ^ xor_low[g]]]`` — the per-tile XOR trick
  replaces per-thread index recomputation. The paper's shared-memory shift
  (§4.2, bank conflicts) has no TPU analogue and is intentionally not ported.
* One kernel invocation walks ALL tiles through a **double-buffered DMA
  pipeline**: tile ``g+1``'s input DMAs are launched while tile ``g``
  computes and drains, with ``num_buffers`` VMEM slots per direction
  (``num_buffers`` is part of :func:`plan_geometry`, so pipelined and
  unpipelined executables never share a cache entry).
* A **compute-epilogue hook**: a tuple of fused compute stages
  (min/max compare-exchange, twiddle butterfly, elementwise ``Map``)
  applied to the tile while it sits in VMEM, *before* the intra-tile
  gather — the kernel-side half of the fused-stage megakernel
  (:mod:`repro.combinators.optimize` ``cluster()``; DESIGN.md §10).
  Pair partners, lo/hi selection, and twiddle indices come from the
  offline :class:`repro.core.tiling.ComputeTables`.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from ..core.tiling import TilePlan

# API compat: jax >= 0.5 renamed TPUMemorySpace -> MemorySpace (gaining HBM)
# and TPUCompilerParams -> CompilerParams. Support both spellings.
_MS = getattr(pltpu, "MemorySpace", None) or pltpu.TPUMemorySpace
_HBM = getattr(_MS, "HBM", None) or _MS.ANY
_VMEM = _MS.VMEM
_CompilerParams = (getattr(pltpu, "CompilerParams", None)
                   or pltpu.TPUCompilerParams)


def _epi_counts(epi: tuple) -> tuple:
    """(scalar-prefetch args, VMEM-table args) one epilogue entry consumes.

    Entries: ``("cmp", vr, vc)`` -> hi_base | hi_row, hi_lane;
    ``("bfly", vr, vc, wlen)`` -> hi_base, tw_base | hi_row, hi_lane,
    tw_row, tw_lane, w_planar; ``("map", name)`` -> nothing (the function
    itself is static).
    """
    kind = epi[0]
    if kind == "cmp":
        return 1, 2
    if kind == "bfly":
        return 2, 5
    if kind == "map":
        return 0, 0
    raise ValueError(f"unknown epilogue kind {kind!r}")


def _tile_kernel(*refs, rpt: int, row_len: int, in_run: int, out_run: int,
                 has_tail: bool, batched: bool, n_tiles: int,
                 num_buffers: int, epis: tuple, map_fns: tuple):
    """The fused-stage megakernel: one invocation = all tiles of one pass.

    Ref layout (in pallas order): scalar prefetch ``in_rows, out_rows,
    xor_low`` + per-epilogue per-tile scalars; inputs ``x_hbm, src0`` +
    per-epilogue VMEM tables; output ``o_hbm``; scratch ``tiles, obuf``
    (``num_buffers`` slots each) + input/output DMA semaphore grids.

    Pipeline schedule (``NB = num_buffers``)::

        start_in(0)
        for g in range(n_tiles):          # fori_loop, slot = g % NB
            start_in(g+1)                 # prefetch next tile  (NB > 1)
            wait_in(g)
            tile -> epilogues -> gather   # compute while g+1 is in flight
            wait_out(g - NB)              # slot's previous write drained?
            obuf[slot] = ...; start_out(g)
        wait_out(last NB tiles)           # drain

    ``batched=True`` adds a leading batch axis to the HBM row views and
    runs the whole pipeline once per batch element (grid = (B,)); the
    index tables (and therefore the tile geometry) are shared by every
    batch element.
    """
    nb = num_buffers
    it = iter(refs)
    in_rows, out_rows, xor_low = next(it), next(it), next(it)
    epi_scalar = [tuple(next(it) for _ in range(_epi_counts(e)[0]))
                  for e in epis]
    x_hbm = next(it)
    src0 = next(it)
    epi_vmem = [tuple(next(it) for _ in range(_epi_counts(e)[1]))
                for e in epis]
    o_hbm = next(it)
    tiles, obuf, in_sems, out_sems = next(it), next(it), next(it), next(it)

    b = pl.program_id(0) if batched else None

    def x_rows(r0, run):
        return (x_hbm.at[b, pl.ds(r0, run)] if batched
                else x_hbm.at[pl.ds(r0, run)])

    def o_rows(r0, run):
        return (o_hbm.at[b, pl.ds(r0, run)] if batched
                else o_hbm.at[pl.ds(r0, run)])

    n_in = rpt // in_run
    n_out = rpt // out_run

    # DMA descriptors are reconstructed at wait time (waiting only touches
    # the semaphore), so start/wait can live in different loop iterations.
    def in_copy(g, slot, i):
        return pltpu.make_async_copy(
            x_rows(in_rows[g, i * in_run], in_run),
            tiles.at[slot, pl.ds(i * in_run, in_run)],
            in_sems.at[slot, i])

    def out_copy(g, slot, i):
        return pltpu.make_async_copy(
            obuf.at[slot, pl.ds(i * out_run, out_run)],
            o_rows(out_rows[g, i * out_run], out_run),
            out_sems.at[slot, i])

    def start_in(g):
        slot = jax.lax.rem(g, nb)
        for i in range(n_in):
            in_copy(g, slot, i).start()

    def wait_in(g):
        slot = jax.lax.rem(g, nb)
        for i in range(n_in):
            in_copy(g, slot, i).wait()

    def start_out(g):
        slot = jax.lax.rem(g, nb)
        for i in range(n_out):
            out_copy(g, slot, i).start()

    def wait_out(g):
        slot = jax.lax.rem(g, nb)
        for i in range(n_out):
            out_copy(g, slot, i).wait()

    rowi = jax.lax.broadcasted_iota(jnp.int32, (rpt, row_len), 0)
    lane = jax.lax.broadcasted_iota(jnp.int32, (rpt, row_len), 1)

    def partner_vals(vals, vr, vc):
        """``pv[r, c] = vals[r ^ vr, c ^ vc]`` without a gather: an XOR
        on an index axis is a composition of single-bit axis flips, each
        a reshape + reverse of a length-2 axis (XLA `rev`, far cheaper
        than a tile-sized `take`)."""
        out = vals
        for axis, v in ((0, vr), (1, vc)):
            size = rpt if axis == 0 else row_len
            b = 0
            while (1 << b) < size:
                if (v >> b) & 1:
                    sh = out.shape
                    pre = sh[:axis]
                    post = sh[axis + 1:]
                    out = out.reshape(
                        pre + (size >> (b + 1), 2, 1 << b) + post)
                    out = jnp.flip(out, axis=axis + 1)
                    out = out.reshape(sh)
                b += 1
        return out

    def apply_computes(vals, g):
        """Fused compute stages on the in-VMEM tile (DESIGN.md §10).

        Each compare/butterfly pairs tile position (r, c) with
        (r ^ vr, c ^ vc); which element is the "hi" half (and which
        twiddle a butterfly uses) is affine in the index, split into
        per-row/per-lane parity tables XORed with one per-tile scalar.
        """
        mi = 0
        for k, e in enumerate(epis):
            kind = e[0]
            if kind == "map":
                vals = map_fns[mi](vals)
                mi += 1
                continue
            vr, vc = e[1], e[2]
            pv = partner_vals(vals, vr, vc)
            hi_row, hi_lane = epi_vmem[k][0], epi_vmem[k][1]
            hi = (hi_row[...][:, None] ^ hi_lane[...][None, :]
                  ^ epi_scalar[k][0][g]) == 1
            if kind == "cmp":
                mask = hi[..., None] if has_tail else hi
                vals = jnp.where(mask, jnp.maximum(vals, pv),
                                 jnp.minimum(vals, pv))
            else:  # "bfly": planar (re, im) trailing dim of 2
                tw_row, tw_lane, w = (epi_vmem[k][2], epi_vmem[k][3],
                                      epi_vmem[k][4])
                tw = (tw_row[...][:, None] ^ tw_lane[...][None, :]
                      ^ epi_scalar[k][1][g]).reshape(-1)
                wr = jnp.take(w[...][:, 0], tw, axis=0).reshape(rpt, row_len)
                wi = jnp.take(w[...][:, 1], tw, axis=0).reshape(rpt, row_len)
                lo_re = jnp.where(hi, pv[..., 0], vals[..., 0])
                lo_im = jnp.where(hi, pv[..., 1], vals[..., 1])
                hi_re = jnp.where(hi, vals[..., 0], pv[..., 0])
                hi_im = jnp.where(hi, vals[..., 1], pv[..., 1])
                t_re = wr * hi_re - wi * hi_im
                t_im = wr * hi_im + wi * hi_re
                vals = jnp.stack([jnp.where(hi, lo_re - t_re, lo_re + t_re),
                                  jnp.where(hi, lo_im - t_im, lo_im + t_im)],
                                 axis=-1)
        return vals

    def process(g):
        slot = jax.lax.rem(g, nb)
        wait_in(g)
        vals = tiles[slot]
        if epis:
            vals = apply_computes(vals, g)
        # ---- intra-tile affine permutation (flat gather, per-tile XOR) ----
        if has_tail:
            flat = vals.reshape(rpt * row_len, -1)
        else:
            flat = vals.reshape(rpt * row_len)
        j = (rowi * row_len + (lane ^ xor_low[g])).reshape(-1)
        src = src0[...].reshape(-1)[j]
        permuted = jnp.take(flat, src, axis=0)

        @pl.when(g >= nb)  # slot's previous write must have drained
        def _():
            wait_out(g - nb)

        obuf[slot] = permuted.reshape(tiles.shape[1:])
        start_out(g)

    start_in(0)

    def body(g, carry):
        if nb > 1:
            @pl.when(g + 1 < n_tiles)
            def _():
                start_in(g + 1)  # prefetch overlaps tile g's compute+write
        else:
            @pl.when(g > 0)
            def _():
                start_in(g)      # unpipelined: sequential read-compute-write
        process(g)
        return carry

    jax.lax.fori_loop(0, n_tiles, body, 0)

    for k in range(min(nb, n_tiles)):  # drain the tail writes
        wait_out(n_tiles - 1 - k)


def _tile_bwd_kernel(*refs, rpt: int, row_len: int, in_run: int,
                     out_run: int, has_tail: bool, batched: bool,
                     n_tiles: int, num_buffers: int, epis: tuple,
                     map_fns: tuple):
    """The gradient megakernel: the exact transpose of one fused pass.

    Tile ``g`` reads the saved cluster input ``x`` at the forward's
    ``in_rows`` AND the cotangent at the forward's ``out_rows`` (where
    the forward wrote), then in VMEM (a) un-permutes the cotangent tile
    through the inverse intra-tile gather (``inv_src0``, the offline
    inverse of ``src0``; the per-tile XOR folds into the lookup since
    ``out[j] = pre[src0[j ^ x]] ⇒ ct_pre[k] = ct_out[inv_src0[k] ^ x]``),
    (b) replays the forward epilogue chain on the x tile to recover every
    intermediate, (c) applies the TRANSPOSED epilogues in reverse order —
    masks from the recomputed intermediates, the partner flip being its
    own transpose (involution) — and writes the result to ``in_rows``.
    One kernel invocation is therefore the whole cluster backward:
    ``ctᵢₙ = (B ∘ C̃m ∘ … ∘ C̃1)ᵀ ctₒᵤₜ``, the same DMA round trip count
    as the forward pass it mirrors.
    """
    nb = num_buffers
    it = iter(refs)
    in_rows, out_rows, xor_low = next(it), next(it), next(it)
    epi_scalar = [tuple(next(it) for _ in range(_epi_counts(e)[0]))
                  for e in epis]
    x_hbm = next(it)
    ct_hbm = next(it)
    inv_src0 = next(it)
    epi_vmem = [tuple(next(it) for _ in range(_epi_counts(e)[1]))
                for e in epis]
    o_hbm = next(it)
    (xtiles, ctiles, obuf, xin_sems, cin_sems,
     out_sems) = (next(it), next(it), next(it), next(it), next(it), next(it))

    b = pl.program_id(0) if batched else None

    def hbm_rows(ref, r0, run):
        return (ref.at[b, pl.ds(r0, run)] if batched
                else ref.at[pl.ds(r0, run)])

    n_in = rpt // in_run
    n_out = rpt // out_run

    def x_copy(g, slot, i):
        return pltpu.make_async_copy(
            hbm_rows(x_hbm, in_rows[g, i * in_run], in_run),
            xtiles.at[slot, pl.ds(i * in_run, in_run)],
            xin_sems.at[slot, i])

    def ct_copy(g, slot, i):
        return pltpu.make_async_copy(
            hbm_rows(ct_hbm, out_rows[g, i * out_run], out_run),
            ctiles.at[slot, pl.ds(i * out_run, out_run)],
            cin_sems.at[slot, i])

    def out_copy(g, slot, i):
        # the transpose WRITES where the forward READ: in_rows runs
        return pltpu.make_async_copy(
            obuf.at[slot, pl.ds(i * in_run, in_run)],
            hbm_rows(o_hbm, in_rows[g, i * in_run], in_run),
            out_sems.at[slot, i])

    def start_in(g):
        slot = jax.lax.rem(g, nb)
        for i in range(n_in):
            x_copy(g, slot, i).start()
        for i in range(n_out):
            ct_copy(g, slot, i).start()

    def wait_in(g):
        slot = jax.lax.rem(g, nb)
        for i in range(n_in):
            x_copy(g, slot, i).wait()
        for i in range(n_out):
            ct_copy(g, slot, i).wait()

    def start_out(g):
        slot = jax.lax.rem(g, nb)
        for i in range(n_in):
            out_copy(g, slot, i).start()

    def wait_out(g):
        slot = jax.lax.rem(g, nb)
        for i in range(n_in):
            out_copy(g, slot, i).wait()

    def partner_vals(vals, vr, vc):
        out = vals
        for axis, v in ((0, vr), (1, vc)):
            size = rpt if axis == 0 else row_len
            bb = 0
            while (1 << bb) < size:
                if (v >> bb) & 1:
                    sh = out.shape
                    out = out.reshape(sh[:axis] + (size >> (bb + 1), 2,
                                                   1 << bb) + sh[axis + 1:])
                    out = jnp.flip(out, axis=axis + 1)
                    out = out.reshape(sh)
                bb += 1
        return out

    def forward_chain(vals, g):
        """Replay the epilogues, keeping EVERY intermediate (the masks of
        the transposed compares come from the values each stage saw)."""
        us = [vals]
        mi = 0
        for k, e in enumerate(epis):
            kind = e[0]
            if kind == "map":
                vals = map_fns[mi](vals)
                mi += 1
                us.append(vals)
                continue
            vr, vc = e[1], e[2]
            pv = partner_vals(vals, vr, vc)
            hi_row, hi_lane = epi_vmem[k][0], epi_vmem[k][1]
            hi = (hi_row[...][:, None] ^ hi_lane[...][None, :]
                  ^ epi_scalar[k][0][g]) == 1
            if kind == "cmp":
                mask = hi[..., None] if has_tail else hi
                vals = jnp.where(mask, jnp.maximum(vals, pv),
                                 jnp.minimum(vals, pv))
            else:
                tw_row, tw_lane, w = (epi_vmem[k][2], epi_vmem[k][3],
                                      epi_vmem[k][4])
                tw = (tw_row[...][:, None] ^ tw_lane[...][None, :]
                      ^ epi_scalar[k][1][g]).reshape(-1)
                wr = jnp.take(w[...][:, 0], tw, axis=0).reshape(rpt, row_len)
                wi = jnp.take(w[...][:, 1], tw, axis=0).reshape(rpt, row_len)
                lo_re = jnp.where(hi, pv[..., 0], vals[..., 0])
                lo_im = jnp.where(hi, pv[..., 1], vals[..., 1])
                hi_re = jnp.where(hi, vals[..., 0], pv[..., 0])
                hi_im = jnp.where(hi, vals[..., 1], pv[..., 1])
                t_re = wr * hi_re - wi * hi_im
                t_im = wr * hi_im + wi * hi_re
                vals = jnp.stack(
                    [jnp.where(hi, lo_re - t_re, lo_re + t_re),
                     jnp.where(hi, lo_im - t_im, lo_im + t_im)], axis=-1)
            us.append(vals)
        return us

    def transposed_epilogues(ct, us, g):
        mi = len(map_fns)
        for k in range(len(epis) - 1, -1, -1):
            e = epis[k]
            kind = e[0]
            u = us[k]
            if kind == "map":
                mi -= 1
                _, vjpf = jax.vjp(map_fns[mi], u)
                ct = vjpf(ct)[0]
                continue
            vr, vc = e[1], e[2]
            if kind == "cmp":
                # o = the forward's own output tile (us[k+1]); jax's
                # balanced-eq tie splitting: d = ct · 1{u==o}/(1+1{w==o}),
                # identical on both min/max branches GIVEN o, so the hi
                # mask drops out of the backward entirely
                o = us[k + 1]
                w = partner_vals(u, vr, vc)
                one = jnp.ones((), u.dtype)
                zero = jnp.zeros((), u.dtype)
                two = jnp.full((), 2, u.dtype)
                m1 = (jnp.where(u == o, one, zero)
                      / jnp.where(w == o, two, one))
                m2 = (jnp.where(w == o, one, zero)
                      / jnp.where(u == o, two, one))
                ct = ct * m1 + partner_vals(ct * m2, vr, vc)
            else:
                # linear stage: pair (a₀, a₁) ↦ (a₀ + W a₁, a₀ − W a₁)
                # with W the planar twiddle rotation; the transpose is
                # ct₀ ↦ ct₀ + ct₁ and ct₁ ↦ Wᵀ(ct₀ − ct₁)
                hi_row, hi_lane = epi_vmem[k][0], epi_vmem[k][1]
                hi = (hi_row[...][:, None] ^ hi_lane[...][None, :]
                      ^ epi_scalar[k][0][g]) == 1
                tw_row, tw_lane, w = (epi_vmem[k][2], epi_vmem[k][3],
                                      epi_vmem[k][4])
                tw = (tw_row[...][:, None] ^ tw_lane[...][None, :]
                      ^ epi_scalar[k][1][g]).reshape(-1)
                wr = jnp.take(w[...][:, 0], tw, axis=0).reshape(rpt, row_len)
                wi = jnp.take(w[...][:, 1], tw, axis=0).reshape(rpt, row_len)
                q = partner_vals(ct, vr, vc)
                s_re = q[..., 0] - ct[..., 0]
                s_im = q[..., 1] - ct[..., 1]
                wt_re = wr * s_re + wi * s_im
                wt_im = wr * s_im - wi * s_re
                ct = jnp.stack(
                    [jnp.where(hi, wt_re, ct[..., 0] + q[..., 0]),
                     jnp.where(hi, wt_im, ct[..., 1] + q[..., 1])], axis=-1)
        return ct

    def process(g):
        slot = jax.lax.rem(g, nb)
        wait_in(g)
        xv = xtiles[slot]
        cv = ctiles[slot]
        # ---- inverse intra-tile gather on the cotangent tile ----
        if has_tail:
            flat = cv.reshape(rpt * row_len, -1)
        else:
            flat = cv.reshape(rpt * row_len)
        idx = inv_src0[...].reshape(-1) ^ xor_low[g]
        cv = jnp.take(flat, idx, axis=0).reshape(ctiles.shape[1:])
        if epis:
            us = forward_chain(xv, g)
            cv = transposed_epilogues(cv, us, g)

        @pl.when(g >= nb)
        def _():
            wait_out(g - nb)

        obuf[slot] = cv
        start_out(g)

    start_in(0)

    def body(g, carry):
        if nb > 1:
            @pl.when(g + 1 < n_tiles)
            def _():
                start_in(g + 1)
        else:
            @pl.when(g > 0)
            def _():
                start_in(g)
        process(g)
        return carry

    jax.lax.fori_loop(0, n_tiles, body, 0)

    for k in range(min(nb, n_tiles)):
        wait_out(n_tiles - 1 - k)


def tiled_permute_bwd_tables(x: jax.Array, ct: jax.Array, in_rows, out_rows,
                             xor_low, inv_src0, *, geometry: tuple,
                             epilogue: tuple = (), epi_scalar: tuple = (),
                             epi_vmem: tuple = (), map_fns: tuple = (),
                             interpret: bool = True,
                             batched: bool = False) -> jax.Array:
    """The VJP of one fused tiled pass as ONE kernel invocation.

    ``x`` is the saved cluster input (masks of the transposed compares are
    recomputed from it in VMEM), ``ct`` the output-space cotangent;
    ``inv_src0`` the offline inverse of the pass's ``src0`` gather table.
    Returns the input-space cotangent, same shape as ``x``. Mirrors
    :func:`tiled_permute_tables` exactly: same geometry key, same epilogue
    signature, same DMA pipeline depth — so the backward executable cache
    is as warm as the forward's after one (geometry, signature) trace.
    """
    n, t, rpt, in_run, out_run, n_tiles, num_buffers = geometry
    row_len = 1 << t
    lead = 1 if batched else 0
    has_tail = x.ndim == 2 + lead
    d = x.shape[1 + lead] if has_tail else 1
    row_view = (1 << (n - t), row_len) + ((d,) if has_tail else ())
    if batched:
        row_view = (x.shape[0],) + row_view
    xv = x.reshape(row_view)
    cv = ct.reshape(row_view)
    tile_shape = (rpt, row_len, d) if has_tail else (rpt, row_len)

    kern = functools.partial(
        _tile_bwd_kernel, rpt=rpt, row_len=row_len,
        in_run=in_run, out_run=out_run, has_tail=has_tail, batched=batched,
        n_tiles=n_tiles, num_buffers=num_buffers, epis=tuple(epilogue),
        map_fns=tuple(map_fns),
    )
    grid = (x.shape[0],) if batched else (1,)
    n_scalar = 3 + sum(_epi_counts(e)[0] for e in epilogue)
    n_vtab = sum(_epi_counts(e)[1] for e in epilogue)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=n_scalar,
        grid=grid,
        in_specs=[
            pl.BlockSpec(memory_space=_HBM),   # x rows
            pl.BlockSpec(memory_space=_HBM),   # ct rows
            pl.BlockSpec(memory_space=_VMEM),  # inv_src0
        ] + [pl.BlockSpec(memory_space=_VMEM)] * n_vtab,
        out_specs=pl.BlockSpec(memory_space=_HBM),
        scratch_shapes=[
            pltpu.VMEM((num_buffers,) + tile_shape, x.dtype),   # x slots
            pltpu.VMEM((num_buffers,) + tile_shape, x.dtype),   # ct slots
            pltpu.VMEM((num_buffers,) + tile_shape, x.dtype),   # out slots
            pltpu.SemaphoreType.DMA((num_buffers, rpt // in_run)),
            pltpu.SemaphoreType.DMA((num_buffers, rpt // out_run)),
            pltpu.SemaphoreType.DMA((num_buffers, rpt // in_run)),
        ],
    )
    args = [jnp.asarray(in_rows), jnp.asarray(out_rows), jnp.asarray(xor_low)]
    for grp in epi_scalar:
        args.extend(jnp.asarray(a) for a in grp)
    args.extend([xv, cv, jnp.asarray(inv_src0)])
    for grp in epi_vmem:
        args.extend(jnp.asarray(a) for a in grp)
    out = pl.pallas_call(
        kern,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct(row_view, x.dtype),
        interpret=interpret,
        compiler_params=_CompilerParams(
            dimension_semantics=("arbitrary",) * len(grid),
        ),
    )(*args)
    return out.reshape(x.shape)


def default_num_buffers(n_tiles: int) -> int:
    """2 (double buffering) whenever there is more than one tile."""
    return 1 if n_tiles == 1 else 2


def plan_geometry(plan: TilePlan, num_buffers: int = None) -> tuple:
    """The hashable tile geometry of a plan — everything that shapes the
    kernel *except* the per-stage index tables. Two plans with equal
    geometry can share one compiled kernel executable (tables are runtime
    arguments), which is what :mod:`repro.combinators.execute` exploits to
    amortize trace/compile cost across the stages of a fused program.
    ``num_buffers`` (the DMA pipeline depth) is part of the geometry so
    executables with different buffering never share a cache entry."""
    if num_buffers is None:
        num_buffers = default_num_buffers(plan.n_tiles)
    return (plan.n, plan.t, plan.rows_per_tile, plan.in_run, plan.out_run,
            plan.n_tiles, num_buffers)


def tiled_permute_tables(x: jax.Array, in_rows, out_rows, xor_low, src0, *,
                         geometry: tuple, epilogue: tuple = (),
                         epi_scalar: tuple = (), epi_vmem: tuple = (),
                         map_fns: tuple = (), interpret: bool = True,
                         batched: bool = False) -> jax.Array:
    """One tiled-BMMC pass with the index tables as (traced) arguments.

    ``geometry`` is :func:`plan_geometry` output; tables may be jax arrays,
    so this function traces once per geometry under ``jax.jit``.

    ``epilogue`` is the static fused-compute signature (tuple of
    ``("cmp", vr, vc)`` / ``("bfly", vr, vc, wlen)`` / ``("map", name)``
    entries); ``epi_scalar`` / ``epi_vmem`` carry the matching runtime
    tables, one tuple per entry (see :func:`_epi_counts`), and
    ``map_fns`` the ``Map`` callables in order. The epilogue signature
    must be part of any executable cache key alongside ``geometry``.

    ``batched=True`` accepts a leading batch axis — ``(B, 2^n)`` or
    ``(B, 2^n, d)`` — folded into the HBM row view as ``(B, 2^(n-t), 2^t
    [, d])`` and into the grid as ``(B,)``. Geometry (and hence the
    compiled kernel cache key) is independent of B; only the jit retrace,
    not the plan, depends on the batch size.
    """
    n, t, rpt, in_run, out_run, n_tiles, num_buffers = geometry
    row_len = 1 << t
    lead = 1 if batched else 0
    has_tail = x.ndim == 2 + lead
    d = x.shape[1 + lead] if has_tail else 1
    row_view = (1 << (n - t), row_len) + ((d,) if has_tail else ())
    if batched:
        row_view = (x.shape[0],) + row_view
    xv = x.reshape(row_view)
    tile_shape = (rpt, row_len, d) if has_tail else (rpt, row_len)

    kern = functools.partial(
        _tile_kernel, rpt=rpt, row_len=row_len,
        in_run=in_run, out_run=out_run, has_tail=has_tail, batched=batched,
        n_tiles=n_tiles, num_buffers=num_buffers, epis=tuple(epilogue),
        map_fns=tuple(map_fns),
    )
    grid = (x.shape[0],) if batched else (1,)
    n_scalar = 3 + sum(_epi_counts(e)[0] for e in epilogue)
    n_vtab = sum(_epi_counts(e)[1] for e in epilogue)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=n_scalar,
        grid=grid,
        in_specs=[
            pl.BlockSpec(memory_space=_HBM),   # x rows
            pl.BlockSpec(memory_space=_VMEM),  # src0
        ] + [pl.BlockSpec(memory_space=_VMEM)] * n_vtab,
        out_specs=pl.BlockSpec(memory_space=_HBM),
        scratch_shapes=[
            pltpu.VMEM((num_buffers,) + tile_shape, x.dtype),   # in slots
            pltpu.VMEM((num_buffers,) + tile_shape, x.dtype),   # out slots
            pltpu.SemaphoreType.DMA((num_buffers, rpt // in_run)),
            pltpu.SemaphoreType.DMA((num_buffers, rpt // out_run)),
        ],
    )
    args = [jnp.asarray(in_rows), jnp.asarray(out_rows), jnp.asarray(xor_low)]
    for grp in epi_scalar:
        args.extend(jnp.asarray(a) for a in grp)
    args.extend([xv, jnp.asarray(src0)])
    for grp in epi_vmem:
        args.extend(jnp.asarray(a) for a in grp)
    out = pl.pallas_call(
        kern,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct(row_view, x.dtype),
        interpret=interpret,
        compiler_params=_CompilerParams(
            dimension_semantics=("arbitrary",) * len(grid),
        ),
    )(*args)
    return out.reshape(x.shape)


def _trap_tables(pairs) -> None:
    """Host-side descriptor trap at the kernel-launch boundary: when
    guards are on and the plan tables are still concrete (numpy, not
    traced runtime arguments), refuse to launch a kernel whose gather /
    DMA tables address outside their geometry. This is the last line
    before a poisoned table becomes a baked trace constant; the traced
    twin of the same check lives in :mod:`repro.guard.runtime`
    (DESIGN.md §14, ring 2)."""
    from .. import guard as _g
    if not _g.enabled():
        return
    from ..guard import runtime as _grt
    if not _grt._trace_state_clean():
        # under a trace (incl. ring 2's own guarded executable) the
        # in-program OOB flag owns this check — raising here would
        # preempt the trap → fallback machinery
        return
    from ..guard.errors import DescriptorOOB
    for name, tab, hi in pairs:
        if not isinstance(tab, np.ndarray):
            continue  # traced table: the in-program OOB trap covers it
        if tab.size and (int(tab.min()) < 0 or int(tab.max()) >= hi):
            raise DescriptorOOB(
                f"kernel launch refused: table {name!r} addresses "
                f"[{int(tab.min())}, {int(tab.max())}] outside [0, {hi})")


def tiled_permute(x: jax.Array, plan: TilePlan, *, interpret: bool = True,
                  batched: bool = False) -> jax.Array:
    """Apply one tiled-BMMC pass. ``x``: (2^n,) or (2^n, d); with
    ``batched=True``, (B, 2^n) or (B, 2^n, d)."""
    n_rows = 1 << (plan.n - plan.t)
    _trap_tables([("in_rows", plan.in_rows, n_rows),
                  ("out_rows", plan.out_rows, n_rows),
                  ("xor_low", plan.xor_low, plan.row_len),
                  ("src0", plan.src0, plan.rows_per_tile * plan.row_len)])
    return tiled_permute_tables(
        x, plan.in_rows, plan.out_rows, plan.xor_low, plan.src0,
        geometry=plan_geometry(plan), interpret=interpret, batched=batched,
    )


# ---------------------------------------------------------------------------
# Class fast-path kernels (DESIGN.md §11). The simplest BMMC classes do
# not need the two-buffer gather pipeline at all:
#
# * block-permute: whole 2^b-element blocks move wholesale. The kernel
#   is a copy whose *input grid mapping* is remapped through the offline
#   source-row table (scalar prefetch feeding the BlockSpec index_map) —
#   pallas's own pipeline double-buffers the DMAs, there is no intra-
#   tile gather, and the descriptor count equals `copy_through_vmem`'s.
# * lane-permute: rows never move; each row is permuted in place by the
#   same t-bit map. One pass, in-VMEM `jnp.take` along the lane axis,
#   no transpose pass.
# ---------------------------------------------------------------------------


def _block_kernel(src_ref, x_ref, o_ref):
    del src_ref  # consumed by the index_map; the body is a pure copy
    o_ref[...] = x_ref[...]


def block_permute_tables(x: jax.Array, src_rows, *, geometry: tuple,
                         interpret: bool = True,
                         batched: bool = False) -> jax.Array:
    """Grid-remapped DMA copy: output block ``g`` reads input block
    ``src_rows[g]``. ``geometry`` is :func:`block_geometry` output."""
    n, b, n_rows = geometry
    blk = 1 << b
    lead = 1 if batched else 0
    has_tail = x.ndim == 2 + lead
    d = x.shape[1 + lead] if has_tail else 1
    row_view = (n_rows, blk) + ((d,) if has_tail else ())
    if batched:
        row_view = (x.shape[0],) + row_view
    xv = x.reshape(row_view)
    tail = (d,) if has_tail else ()
    blk_shape = ((1,) if batched else ()) + (1, blk) + tail

    if batched:
        def in_map(bi, i, src_ref):
            return (bi, src_ref[i], 0) + (0,) * len(tail)

        def out_map(bi, i, src_ref):
            return (bi, i, 0) + (0,) * len(tail)
        grid = (x.shape[0], n_rows)
    else:
        def in_map(i, src_ref):
            return (src_ref[i], 0) + (0,) * len(tail)

        def out_map(i, src_ref):
            return (i, 0) + (0,) * len(tail)
        grid = (n_rows,)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=grid,
        in_specs=[pl.BlockSpec(blk_shape, in_map)],
        out_specs=pl.BlockSpec(blk_shape, out_map),
    )
    out = pl.pallas_call(
        _block_kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct(row_view, x.dtype),
        interpret=interpret,
        compiler_params=_CompilerParams(
            dimension_semantics=("arbitrary",) * len(grid),
        ),
    )(jnp.asarray(src_rows), xv)
    return out.reshape(x.shape)


def block_geometry(plan) -> tuple:
    """Hashable kernel geometry of a :class:`repro.core.tiling.BlockPlan`."""
    return (plan.n, plan.b, plan.n_rows)


def block_permute(x: jax.Array, plan, *, interpret: bool = True,
                  batched: bool = False) -> jax.Array:
    _trap_tables([("src_rows", plan.src_rows, plan.n_rows)])
    return block_permute_tables(x, plan.src_rows,
                                geometry=block_geometry(plan),
                                interpret=interpret, batched=batched)


def lane_permute_tables(x: jax.Array, src_lane, *, geometry: tuple,
                        interpret: bool = True,
                        batched: bool = False) -> jax.Array:
    """Single-pass in-VMEM row gather: ``out[.., row, lane] = x[.., row,
    src_lane[lane]]``. ``geometry`` is :func:`lane_geometry` output."""
    n, t, rpb = geometry
    row_len = 1 << t
    n_rows = 1 << (n - t)
    lead = 1 if batched else 0
    has_tail = x.ndim == 2 + lead
    d = x.shape[1 + lead] if has_tail else 1
    tail = (d,) if has_tail else ()
    row_view = (n_rows, row_len) + tail
    if batched:
        row_view = (x.shape[0],) + row_view
    xv = x.reshape(row_view)
    blk_shape = ((1,) if batched else ()) + (rpb, row_len) + tail
    lane_axis = len(blk_shape) - 1 - len(tail)

    def kern(src_ref, x_ref, o_ref):
        o_ref[...] = jnp.take(x_ref[...], src_ref[...], axis=lane_axis)

    if batched:
        def blk_map(bi, i, src_ref):
            return (bi, i, 0) + (0,) * len(tail)
        grid = (x.shape[0], n_rows // rpb)
    else:
        def blk_map(i, src_ref):
            return (i, 0) + (0,) * len(tail)
        grid = (n_rows // rpb,)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=grid,
        in_specs=[pl.BlockSpec(blk_shape, blk_map)],
        out_specs=pl.BlockSpec(blk_shape, blk_map),
    )
    out = pl.pallas_call(
        kern,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct(row_view, x.dtype),
        interpret=interpret,
        compiler_params=_CompilerParams(
            dimension_semantics=("arbitrary",) * len(grid),
        ),
    )(jnp.asarray(src_lane), xv)
    return out.reshape(x.shape)


def lane_geometry(plan) -> tuple:
    """Hashable kernel geometry of a :class:`repro.core.tiling.LanePlan`."""
    return (plan.n, plan.t, plan.rows_per_block)


def lane_permute(x: jax.Array, plan, *, interpret: bool = True,
                 batched: bool = False) -> jax.Array:
    _trap_tables([("src_lane", plan.src_lane, 1 << plan.t)])
    return lane_permute_tables(x, plan.src_lane,
                               geometry=lane_geometry(plan),
                               interpret=interpret, batched=batched)


# ---------------------------------------------------------------------------
# Baseline copy kernel — the "100% effective bandwidth" reference in the
# paper's tables (§2.3, §6). Same DMA structure, identity permutation.
# ---------------------------------------------------------------------------

def _copy_kernel(x_ref, o_ref):
    o_ref[...] = x_ref[...]


def copy_pad_elems(size: int, rows_per_block: int = 8,
                   row_len: int = 256) -> int:
    """Elements of zero padding :func:`copy_through_vmem` appends so the
    array divides into whole blocks (0 = exact fit). Benchmarks label
    padded baselines with this, so a padded copy is never mistaken for a
    pure roofline number."""
    blk = rows_per_block * row_len
    return (-size) % blk


def copy_through_vmem(x: jax.Array, *, rows_per_block: int = 8,
                      row_len: int = 256, interpret: bool = True) -> jax.Array:
    """Block copy staged through VMEM; the bandwidth roofline baseline.

    Sizes that don't divide into whole (rows_per_block, row_len) blocks
    are zero-padded up, copied through the same Pallas kernel, and
    sliced back — the degenerate path always enters pallas, so the
    roofline baseline stays honest (use :func:`copy_pad_elems` to label
    padded measurements).
    """
    total = x.size
    blk = rows_per_block * row_len
    pad = copy_pad_elems(total, rows_per_block, row_len)
    flat = x.reshape(-1)
    if pad:
        flat = jnp.concatenate([flat, jnp.zeros((pad,), x.dtype)])
    nblk = (total + pad) // blk
    xv = flat.reshape(nblk, rows_per_block, row_len)
    out = pl.pallas_call(
        _copy_kernel,
        grid=(nblk,),
        in_specs=[pl.BlockSpec((1, rows_per_block, row_len), lambda i: (i, 0, 0))],
        out_specs=pl.BlockSpec((1, rows_per_block, row_len), lambda i: (i, 0, 0)),
        out_shape=jax.ShapeDtypeStruct(xv.shape, x.dtype),
        interpret=interpret,
    )(xv)
    return out.reshape(-1)[:total].reshape(x.shape)
