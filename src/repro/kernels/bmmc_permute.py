"""Pallas TPU kernels for tiled BMMC permutations (paper §4-5, TPU-adapted).

Design (see DESIGN.md §2 for the GPU->TPU mapping):

* The array lives in HBM as a (2^(n-t), 2^t[, d]) row view. One kernel grid
  step processes one *tile* = ``rows_per_tile`` full rows — the offline
  ``TilePlan`` guarantees both the rows read and the rows written are whole,
  contiguous ``2^t``-element runs (the TPU analogue of full coalescing).
* Row id tables (``in_rows``/``out_rows``), the per-tile lane XOR and the
  intra-tile gather table ``src0`` are *offline* artifacts (scalar-prefetch /
  VMEM constants), mirroring the paper's offline codegen setting.
* Consecutive row ids are merged into one DMA descriptor (``in_run`` /
  ``out_run`` rows per copy) — the DMA analogue of the paper's §4.3
  iteration amortization.
* The intra-tile permutation is a flat VMEM gather
  ``out.flat[j] = tile.flat[src0[j ^ xor_low[g]]]`` — the per-tile XOR trick
  replaces per-thread index recomputation. The paper's shared-memory shift
  (§4.2, bank conflicts) has no TPU analogue and is intentionally not ported.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from ..core.tiling import TilePlan

# API compat: jax >= 0.5 renamed TPUMemorySpace -> MemorySpace (gaining HBM)
# and TPUCompilerParams -> CompilerParams. Support both spellings.
_MS = getattr(pltpu, "MemorySpace", None) or pltpu.TPUMemorySpace
_HBM = getattr(_MS, "HBM", None) or _MS.ANY
_VMEM = _MS.VMEM
_CompilerParams = (getattr(pltpu, "CompilerParams", None)
                   or pltpu.TPUCompilerParams)


def _tile_kernel(in_rows, out_rows, xor_low,   # scalar prefetch (SMEM)
                 x_hbm, src0,                  # inputs (HBM / VMEM)
                 o_hbm,                        # output (HBM)
                 tile, obuf, in_sems, out_sems,  # scratch
                 *, rpt: int, row_len: int, in_run: int, out_run: int,
                 has_tail: bool, batched: bool):
    """One grid step = one tile. See module docstring.

    ``batched=True`` adds a leading batch axis to the HBM row views and a
    leading batch dimension to the grid; the index tables (and therefore
    the tile geometry) are shared by every batch element.
    """
    if batched:
        b = pl.program_id(0)
        g = pl.program_id(1)
    else:
        g = pl.program_id(0)

    def x_rows(r0, run):
        return x_hbm.at[b, pl.ds(r0, run)] if batched else x_hbm.at[pl.ds(r0, run)]

    def o_rows(r0, run):
        return o_hbm.at[b, pl.ds(r0, run)] if batched else o_hbm.at[pl.ds(r0, run)]

    # ---- read the tile: rpt rows as rpt/in_run merged DMAs, all in flight --
    n_in = rpt // in_run
    copies = []
    for i in range(n_in):
        r0 = in_rows[g, i * in_run]
        cp = pltpu.make_async_copy(
            x_rows(r0, in_run),
            tile.at[pl.ds(i * in_run, in_run)],
            in_sems.at[i],
        )
        cp.start()
        copies.append(cp)
    for cp in copies:
        cp.wait()

    # ---- intra-tile affine permutation (flat gather with per-tile XOR) -----
    if has_tail:
        flat = tile[...].reshape(rpt * row_len, -1)
    else:
        flat = tile[...].reshape(rpt * row_len)
    rowi = jax.lax.broadcasted_iota(jnp.int32, (rpt, row_len), 0)
    lane = jax.lax.broadcasted_iota(jnp.int32, (rpt, row_len), 1)
    j = (rowi * row_len + (lane ^ xor_low[g])).reshape(-1)
    src = src0[...].reshape(-1)[j]
    permuted = jnp.take(flat, src, axis=0)
    obuf[...] = permuted.reshape(obuf.shape)

    # ---- write the tile: merged DMAs ---------------------------------------
    n_out = rpt // out_run
    copies = []
    for i in range(n_out):
        r0 = out_rows[g, i * out_run]
        cp = pltpu.make_async_copy(
            obuf.at[pl.ds(i * out_run, out_run)],
            o_rows(r0, out_run),
            out_sems.at[i],
        )
        cp.start()
        copies.append(cp)
    for cp in copies:
        cp.wait()


def plan_geometry(plan: TilePlan) -> tuple:
    """The hashable tile geometry of a plan — everything that shapes the
    kernel *except* the per-stage index tables. Two plans with equal
    geometry can share one compiled kernel executable (tables are runtime
    arguments), which is what :mod:`repro.combinators.execute` exploits to
    amortize trace/compile cost across the stages of a fused program."""
    return (plan.n, plan.t, plan.rows_per_tile, plan.in_run, plan.out_run,
            plan.n_tiles)


def tiled_permute_tables(x: jax.Array, in_rows, out_rows, xor_low, src0, *,
                         geometry: tuple, interpret: bool = True,
                         batched: bool = False) -> jax.Array:
    """One tiled-BMMC pass with the index tables as (traced) arguments.

    ``geometry`` is :func:`plan_geometry` output; tables may be jax arrays,
    so this function traces once per geometry under ``jax.jit``.

    ``batched=True`` accepts a leading batch axis — ``(B, 2^n)`` or
    ``(B, 2^n, d)`` — folded into the HBM row view as ``(B, 2^(n-t), 2^t
    [, d])`` and into the grid as ``(B, n_tiles)``. Geometry (and hence
    the compiled kernel cache key) is independent of B; only the jit
    retrace, not the plan, depends on the batch size.
    """
    n, t, rpt, in_run, out_run, n_tiles = geometry
    row_len = 1 << t
    lead = 1 if batched else 0
    has_tail = x.ndim == 2 + lead
    d = x.shape[1 + lead] if has_tail else 1
    row_view = (1 << (n - t), row_len) + ((d,) if has_tail else ())
    if batched:
        row_view = (x.shape[0],) + row_view
    xv = x.reshape(row_view)
    tile_shape = (rpt, row_len, d) if has_tail else (rpt, row_len)

    kern = functools.partial(
        _tile_kernel, rpt=rpt, row_len=row_len,
        in_run=in_run, out_run=out_run, has_tail=has_tail, batched=batched,
    )
    grid = (x.shape[0], n_tiles) if batched else (n_tiles,)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=3,
        grid=grid,
        in_specs=[
            pl.BlockSpec(memory_space=_HBM),   # x rows
            pl.BlockSpec(memory_space=_VMEM),  # src0
        ],
        out_specs=pl.BlockSpec(memory_space=_HBM),
        scratch_shapes=[
            pltpu.VMEM(tile_shape, x.dtype),                    # in tile
            pltpu.VMEM(tile_shape, x.dtype),                    # out tile
            pltpu.SemaphoreType.DMA((rpt // in_run,)),
            pltpu.SemaphoreType.DMA((rpt // out_run,)),
        ],
    )
    out = pl.pallas_call(
        kern,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct(row_view, x.dtype),
        interpret=interpret,
        compiler_params=_CompilerParams(
            dimension_semantics=("arbitrary",) * len(grid),
        ),
    )(
        jnp.asarray(in_rows), jnp.asarray(out_rows),
        jnp.asarray(xor_low), xv, jnp.asarray(src0),
    )
    return out.reshape(x.shape)


def tiled_permute(x: jax.Array, plan: TilePlan, *, interpret: bool = True,
                  batched: bool = False) -> jax.Array:
    """Apply one tiled-BMMC pass. ``x``: (2^n,) or (2^n, d); with
    ``batched=True``, (B, 2^n) or (B, 2^n, d)."""
    return tiled_permute_tables(
        x, plan.in_rows, plan.out_rows, plan.xor_low, plan.src0,
        geometry=plan_geometry(plan), interpret=interpret, batched=batched,
    )


# ---------------------------------------------------------------------------
# Baseline copy kernel — the "100% effective bandwidth" reference in the
# paper's tables (§2.3, §6). Same DMA structure, identity permutation.
# ---------------------------------------------------------------------------

def _copy_kernel(x_ref, o_ref):
    o_ref[...] = x_ref[...]


def copy_through_vmem(x: jax.Array, *, rows_per_block: int = 8,
                      row_len: int = 256, interpret: bool = True) -> jax.Array:
    """Block copy staged through VMEM; the bandwidth roofline baseline."""
    total = x.size
    blk = rows_per_block * row_len
    nblk = max(total // blk, 1)
    if total % blk:
        return x + 0  # degenerate size: plain copy
    xv = x.reshape(nblk, rows_per_block, row_len)
    out = pl.pallas_call(
        _copy_kernel,
        grid=(nblk,),
        in_specs=[pl.BlockSpec((1, rows_per_block, row_len), lambda i: (i, 0, 0))],
        out_specs=pl.BlockSpec((1, rows_per_block, row_len), lambda i: (i, 0, 0)),
        out_shape=jax.ShapeDtypeStruct(xv.shape, x.dtype),
        interpret=interpret,
    )(xv)
    return out.reshape(x.shape)
