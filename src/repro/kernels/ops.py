"""Public BMMC permutation ops: planning, class dispatch, jit wrappers.

``bmmc_permute`` is the user-facing entry point. Dispatch walks the
class hierarchy most-specialized-first (DESIGN.md §11):

* degenerate / tiny arrays                -> pure-jnp gather (ref oracle);
* identity                                -> no-op;
* tile-index-only (incl. high complement) -> block-permute fast path
                                             (grid-remapped DMA copy);
* lane-local (incl. low complement)       -> lane-permute fast path
                                             (single in-VMEM row gather);
* tiled BMMC (incl. every BPC)            -> one tiled Pallas pass;
* general BMMC                            -> ONE generalized tiled pass
                                             (witness directions), with
                                             the §5.2 two-pass
                                             factorization as fallback.

The BMMC is a *trace-time constant* (offline setting, paper §3/§6): plans
and tables are built once per (matrix, shape) and cached.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..core.bmmc import Bmmc
from ..core.tiling import (class_stats, copy_descriptors, dispatch_kernel,
                           plan_block, plan_bmmc, plan_lane)
from ..obs import metrics as _ometrics
from ..obs import trace as _otrace
from . import ref as _ref
from .bmmc_permute import block_permute, lane_permute, tiled_permute

# VMEM working-set budget for one tile buffer. The double-buffered pipeline
# holds 2 * num_buffers tile-sized slots (in + out, default num_buffers=2);
# v5e has 16 MiB VMEM, leave headroom for the gather table + epilogue tables.
_VMEM_TILE_BYTES = 2 * 1024 * 1024
_MAX_T = 12


def choose_tile(n: int, itemsize: int, d: int = 1, t: Optional[int] = None) -> Optional[int]:
    """Pick n_tile: the LARGEST t whose worst-case (2^t x 2^t) tile fits the
    per-buffer VMEM budget (perf iteration: kernel-hillclimb #1 —
    descriptor-issue, not bandwidth, bounds scattered-bit permutations, and
    descriptors fall 4x per +1 of t; the paper's warp-sized t=5 is far off
    the TPU optimum).

    Returns None if the array is too small to be worth tiling (fallback to
    the reference gather — the whole array fits in VMEM anyway).
    """
    if t is not None:
        return t if 2 * t <= n else None
    t = _MAX_T
    # fit (2^t x 2^t) worst-case tile (n_over = 0) in the VMEM budget
    while t > 1 and (1 << (2 * t)) * itemsize * d > _VMEM_TILE_BYTES:
        t -= 1
    t = min(t, n // 2)
    if t < 1:
        return None
    return t


@functools.lru_cache(maxsize=512)
def _plans_cached(rows: tuple, c: int, t: int) -> tuple:
    return tuple(plan_bmmc(Bmmc(rows, c), t))


def _build_class_plan(rows: tuple, c: int, t: int) -> tuple:
    """Plan from scratch (the store's ``build`` rung): derive the class
    dispatch and construct its payload tables."""
    bmmc = Bmmc(rows, c)
    kernel = dispatch_kernel(bmmc, t)
    if kernel == "none":
        return (kernel, ())
    if kernel == "block":
        return (kernel, plan_block(bmmc, t))
    if kernel == "lane":
        return (kernel, plan_lane(bmmc, t))
    return (kernel, _plans_cached(rows, c, t))


@functools.lru_cache(maxsize=512)
def _class_plan_cached(rows: tuple, c: int, t: int) -> tuple:
    """(kernel name, plan payload) for the class dispatch — the offline
    decision shared by `bmmc_permute` and the combinator executor. The
    payload is the fast-path plan for "block"/"lane", the tiled pass
    tuple otherwise. Backed by the durable plan store when one is
    configured (``REPRO_STORE``): a disk hit is decoded and re-audited
    through guard ring 1 before it is trusted; integrity failures
    quarantine the entry and fall through to fresh planning."""
    from .. import store as _store

    return _store.class_plan_through(
        rows, c, t, lambda: _build_class_plan(rows, c, t))


def bmmc_plans(bmmc: Bmmc, t: int):
    return _plans_cached(bmmc.rows, bmmc.c, t)


def class_plan(bmmc: Bmmc, t: int) -> tuple:
    """Class-dispatch decision: ``(kernel, payload)``; see
    :func:`repro.core.tiling.dispatch_kernel` for the kernel names."""
    return _class_plan_cached(bmmc.rows, bmmc.c, t)


def class_dispatch(x: jax.Array, bmmc: Bmmc, t: Optional[int],
                   batched: bool) -> Optional[tuple]:
    """The full class-dispatch decision for this array: ``(kernel,
    payload)``, or None when the array is too small to tile (callers
    fall back to the reference gather).

    This is the executor stack's single dispatch-decision choke point,
    so telemetry hangs here: one ``kernel.dispatch`` span plus the
    per-kernel / per-class counters and the modeled descriptor /
    round-trip totals — recorded at dispatch/trace time, from offline
    plans, with no device interaction."""
    lead = 1 if batched else 0
    d = x.shape[1 + lead] if x.ndim == 2 + lead else 1
    teff = choose_tile(bmmc.n, x.dtype.itemsize, d, t)
    if teff is None:
        return None
    if not _otrace._state.enabled:
        return class_plan(bmmc, teff)
    with _otrace.span("kernel.dispatch", n=bmmc.n, t=teff) as sargs:
        got = class_plan(bmmc, teff)
        sargs["kernel"] = got[0]
        _ometrics.inc("dispatch.kernel", kernel=got[0])
        _ometrics.inc("dispatch.class", cls=bmmc.bmmc_class(teff))
        tx = modeled_transactions(bmmc, teff, x.dtype.itemsize)
        _ometrics.inc("dma.descriptors", tx["descriptors"])
        _ometrics.inc("model.round_trips", tx["passes"])
    return got


def bmmc_permute(x: jax.Array, bmmc: Bmmc, *, t: Optional[int] = None,
                 engine: str = "pallas", interpret: bool = True,
                 batched: bool = False) -> jax.Array:
    """Permute ``x`` (shape (2^n,) or (2^n, d)) by ``out[A i ^ c] = x[i]``.

    ``engine``: "pallas" (class-dispatched kernels) or "ref" (pure-jnp
    oracle). ``batched=True`` shifts the permuted axis to axis 1 — ``x``
    is ``(B, 2^n)`` or ``(B, 2^n, d)`` and all batch rows share one plan.
    """
    lead = 1 if batched else 0
    assert x.shape[lead] == bmmc.size, (x.shape, bmmc.n)
    from .. import guard as _guard
    if _guard.enabled() and engine in ("pallas", "ref"):
        from ..guard import runtime as _grt
        if _grt._trace_state_clean():
            # ring 2: guarded twin — kernel + probes in one executable,
            # flag readback + pallas → ref fallback at this edge. Under
            # an outer trace the readback is impossible; fall through.
            return _grt.guarded_bmmc_permute(
                x, bmmc, t=t, engine=engine, interpret=interpret,
                batched=batched)
    if engine == "ref":
        return _ref.bmmc_ref(x, bmmc, batched=batched)
    if bmmc.is_identity_perm():
        _ometrics.inc("dispatch.kernel", kernel="none")
        return x
    got = class_dispatch(x, bmmc, t, batched)
    if got is None:
        return _ref.bmmc_ref(x, bmmc, batched=batched)
    kernel, payload = got
    if kernel == "block":
        return block_permute(x, payload, interpret=interpret,
                             batched=batched)
    if kernel == "lane":
        return lane_permute(x, payload, interpret=interpret,
                            batched=batched)
    for plan in payload:
        x = tiled_permute(x, plan, interpret=interpret, batched=batched)
    return x


def num_passes(bmmc: Bmmc, t: int) -> int:
    """1 for every BMMC the one-pass planners take (tiled, generalized);
    2 only for the §5.2 fallback (t > n/2)."""
    return len(bmmc_plans(bmmc, t))


def make_bmmc_permute(bmmc: Bmmc, *, t: Optional[int] = None,
                      engine: str = "pallas", interpret: bool = True):
    """Returns a jit-compiled unary function specialized to ``bmmc``."""
    @jax.jit
    def fn(x):
        return bmmc_permute(x, bmmc, t=t, engine=engine, interpret=interpret)
    return fn


# ---------------------------------------------------------------------------
# Transaction model — the offline counterpart of the paper's effective-
# bandwidth measurements (used by the benchmark harness; no GPU/TPU clock
# exists in this container, see DESIGN.md §7.4).
# ---------------------------------------------------------------------------

def modeled_transactions(bmmc: Bmmc, t: int, itemsize: int = 4) -> dict:
    """DMA descriptor counts + bytes for the class-dispatched kernel vs a
    copy. ``class``/``kernel``/``roofline_ratio`` report the dispatch
    decision and the modeled fraction of copy-kernel descriptor
    throughput (1.0 == the permutation costs exactly an array copy)."""
    n = bmmc.n
    nbytes = (1 << n) * itemsize
    cs = class_stats(bmmc, t)
    passes = max(cs["passes"], 0)
    kernel, payload = class_plan(bmmc, t)
    if kernel in ("none", "block", "lane"):
        total_desc = cs["descriptors"]
        min_run_bytes = nbytes if kernel == "none" else (
            (1 << payload.b) * itemsize if kernel == "block"
            else payload.rows_per_block * (1 << payload.t) * itemsize)
    else:
        plans = payload
        total_desc = sum(p.dma_descriptors() for p in plans)
        min_run = min(min(p.in_run, p.out_run) for p in plans)
        min_run_bytes = min_run * (1 << t) * itemsize
    return {
        "class": cs["class"],
        "kernel": kernel,
        "passes": passes,
        "descriptors": total_desc,
        # copy baseline at the tiled row view (legacy key) and at the
        # copy kernel's own block size (what roofline_ratio uses)
        "copy_descriptors": 2 * (1 << (n - t)),
        "roofline_ratio": (copy_descriptors(n) / max(total_desc, 1)
                           if passes else 1.0),
        "bytes_moved": nbytes * 2 * passes,
        "copy_bytes": nbytes * 2,
        "min_run_bytes": min_run_bytes,
        # modeled fraction of copy throughput, assuming descriptor-issue
        # bound when runs are short and bandwidth bound otherwise:
        "bandwidth_fraction": 1.0 if passes == 0 else 1.0 / passes,
    }
