"""Public BMMC permutation ops: planning, dispatch, jit-friendly wrappers.

``bmmc_permute`` is the user-facing entry point. Dispatch:

* degenerate / tiny arrays                -> pure-jnp gather (ref oracle);
* tiled BMMC (incl. every BPC)            -> one tiled Pallas pass;
* general BMMC                            -> two tiled passes, A = (UR)(RLP)
                                             (paper §5.2).

The BMMC is a *trace-time constant* (offline setting, paper §3/§6): plans
and tables are built once per (matrix, shape) and cached.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..core.bmmc import Bmmc
from ..core.tiling import TilePlan, plan_bmmc, plan_tiled
from . import ref as _ref
from .bmmc_permute import tiled_permute

# VMEM working-set budget for one tile buffer. The double-buffered pipeline
# holds 2 * num_buffers tile-sized slots (in + out, default num_buffers=2);
# v5e has 16 MiB VMEM, leave headroom for the gather table + epilogue tables.
_VMEM_TILE_BYTES = 2 * 1024 * 1024
_MAX_T = 12


def choose_tile(n: int, itemsize: int, d: int = 1, t: Optional[int] = None) -> Optional[int]:
    """Pick n_tile: the LARGEST t whose worst-case (2^t x 2^t) tile fits the
    per-buffer VMEM budget (perf iteration: kernel-hillclimb #1 —
    descriptor-issue, not bandwidth, bounds scattered-bit permutations, and
    descriptors fall 4x per +1 of t; the paper's warp-sized t=5 is far off
    the TPU optimum).

    Returns None if the array is too small to be worth tiling (fallback to
    the reference gather — the whole array fits in VMEM anyway).
    """
    if t is not None:
        return t if 2 * t <= n else None
    t = _MAX_T
    # fit (2^t x 2^t) worst-case tile (n_over = 0) in the VMEM budget
    while t > 1 and (1 << (2 * t)) * itemsize * d > _VMEM_TILE_BYTES:
        t -= 1
    t = min(t, n // 2)
    if t < 1:
        return None
    return t


@functools.lru_cache(maxsize=512)
def _plans_cached(rows: tuple, c: int, t: int) -> tuple:
    return tuple(plan_bmmc(Bmmc(rows, c), t))


def bmmc_plans(bmmc: Bmmc, t: int):
    return _plans_cached(bmmc.rows, bmmc.c, t)


def dispatch_plans(x: jax.Array, bmmc: Bmmc, t: Optional[int],
                   batched: bool) -> Optional[tuple]:
    """The tiled-kernel dispatch decision for this array: the pass plans,
    or None when the array is too small to tile (callers fall back to the
    reference gather). Shared by every pallas execution path."""
    lead = 1 if batched else 0
    d = x.shape[1 + lead] if x.ndim == 2 + lead else 1
    teff = choose_tile(bmmc.n, x.dtype.itemsize, d, t)
    return None if teff is None else bmmc_plans(bmmc, teff)


def bmmc_permute(x: jax.Array, bmmc: Bmmc, *, t: Optional[int] = None,
                 engine: str = "pallas", interpret: bool = True,
                 batched: bool = False) -> jax.Array:
    """Permute ``x`` (shape (2^n,) or (2^n, d)) by ``out[A i ^ c] = x[i]``.

    ``engine``: "pallas" (tiled kernels) or "ref" (pure-jnp oracle).
    ``batched=True`` shifts the permuted axis to axis 1 — ``x`` is
    ``(B, 2^n)`` or ``(B, 2^n, d)`` and all batch rows share one plan.
    """
    lead = 1 if batched else 0
    assert x.shape[lead] == bmmc.size, (x.shape, bmmc.n)
    if engine == "ref":
        return _ref.bmmc_ref(x, bmmc, batched=batched)
    if bmmc.is_identity_perm():
        return x
    plans = dispatch_plans(x, bmmc, t, batched)
    if plans is None:
        return _ref.bmmc_ref(x, bmmc, batched=batched)
    for plan in plans:
        x = tiled_permute(x, plan, interpret=interpret, batched=batched)
    return x


def num_passes(bmmc: Bmmc, t: int) -> int:
    """1 for tiled BMMCs (incl. all BPCs), 2 for general BMMCs (§5.2)."""
    return len(bmmc_plans(bmmc, t))


def make_bmmc_permute(bmmc: Bmmc, *, t: Optional[int] = None,
                      engine: str = "pallas", interpret: bool = True):
    """Returns a jit-compiled unary function specialized to ``bmmc``."""
    @jax.jit
    def fn(x):
        return bmmc_permute(x, bmmc, t=t, engine=engine, interpret=interpret)
    return fn


# ---------------------------------------------------------------------------
# Transaction model — the offline counterpart of the paper's effective-
# bandwidth measurements (used by the benchmark harness; no GPU/TPU clock
# exists in this container, see DESIGN.md §7.4).
# ---------------------------------------------------------------------------

def modeled_transactions(bmmc: Bmmc, t: int, itemsize: int = 4) -> dict:
    """DMA descriptor counts + bytes for the tiled pipeline vs a copy."""
    plans = bmmc_plans(bmmc, t)
    total_desc = sum(p.dma_descriptors() for p in plans)
    n = bmmc.n
    nbytes = (1 << n) * itemsize
    # copy baseline: same row view, one descriptor per in_run-sized run both ways
    copy_desc = 2 * (1 << (n - t))
    min_run = min(min(p.in_run, p.out_run) for p in plans)
    return {
        "passes": len(plans),
        "descriptors": total_desc,
        "copy_descriptors": copy_desc,
        "bytes_moved": nbytes * 2 * len(plans),
        "copy_bytes": nbytes * 2,
        "min_run_bytes": min_run * (1 << t) * itemsize,
        # modeled fraction of copy throughput, assuming descriptor-issue
        # bound when runs are short and bandwidth bound otherwise:
        "bandwidth_fraction": (nbytes * 2) / (nbytes * 2 * len(plans)),
    }
