"""Pure-jnp oracle for BMMC permutations (the kernels' reference).

Semantics: ``out[A x ^ c] = in[x]``, i.e. ``out[y] = in[A^-1 (y ^ c)]`` — a
gather with affine-computed source indices.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from ..core.bmmc import Bmmc
from ..obs import metrics as _ometrics


def bmmc_indices(bmmc: Bmmc) -> np.ndarray:
    """Gather indices realizing the permutation: src[y] = A^-1 (y ^ c)."""
    binv = bmmc.inverse()  # (A^-1, A^-1 c)
    y = np.arange(1 << bmmc.n, dtype=np.uint32)
    src = np.zeros_like(y)
    for i, r in enumerate(binv.rows):
        src |= ((np.bitwise_count(y & np.uint32(r)) & 1).astype(np.uint32)) << np.uint32(i)
    src ^= np.uint32(binv.c)
    return src.astype(np.int32)


@functools.lru_cache(maxsize=256)
def _src_table(rows: tuple, c: int) -> np.ndarray:
    return bmmc_indices(Bmmc(rows, c))


def audit_src_table(bmmc: Bmmc) -> np.ndarray:
    """Guard hook (DESIGN.md §14, ring 1): bounds- and bijection-check
    the CACHED gather table — the array live calls actually bake in,
    which a fault (or in-place mutation) can have diverged from what
    :func:`bmmc_indices` would freshly compute. Raises the typed
    :class:`repro.guard.DescriptorOOB`; returns the table when sound."""
    from ..guard.errors import DescriptorOOB

    tab = _src_table(bmmc.rows, bmmc.c)
    size = bmmc.size
    if tab.shape != (size,):
        raise DescriptorOOB(
            f"ref gather table shape {tab.shape} != ({size},)")
    if int(tab.min()) < 0 or int(tab.max()) >= size:
        raise DescriptorOOB(
            f"ref gather table addresses [{int(tab.min())}, "
            f"{int(tab.max())}] outside [0, {size})")
    if np.unique(tab).size != size:
        raise DescriptorOOB("ref gather table is not a bijection")
    return tab


def bmmc_ref(x: jax.Array, bmmc: Bmmc, *, batched: bool = False) -> jax.Array:
    """Apply the BMMC permutation along the leading axis (pure jnp gather).

    ``batched=True`` shifts the permuted axis to axis 1: ``x`` is
    ``(B, 2^n)`` or ``(B, 2^n, d)`` and every batch row shares the one
    offline gather table.
    """
    axis = 1 if batched else 0
    assert x.shape[axis] == bmmc.size, (x.shape, bmmc.n)
    _ometrics.inc("dispatch.kernel", kernel="ref")
    return jnp.take(x, jnp.asarray(_src_table(bmmc.rows, bmmc.c)), axis=axis)


def bmmc_ref_jnp(x: jax.Array, bmmc: Bmmc) -> jax.Array:
    """Same semantics, indices computed inside the traced program.

    Useful for very large n where an offline int32 table is unwanted, and as
    an independent implementation cross-checking ``bmmc_ref``.
    """
    binv = bmmc.inverse()
    y = jnp.arange(1 << bmmc.n, dtype=jnp.uint32) ^ jnp.uint32(bmmc.c)
    src = jnp.zeros_like(y)
    for i, r in enumerate(binv.rows):
        bit = jax.lax.population_count(y & jnp.uint32(r)) & 1
        src = src | (bit.astype(jnp.uint32) << i)
    # note: Ainv (y ^ c) == (Ainv y) ^ (Ainv c); binv.c == Ainv c already,
    # and we folded c into y above, so no further complement is needed.
    return jnp.take(x, src.astype(jnp.int32), axis=0)
