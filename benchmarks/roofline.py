"""Roofline table generator: reads dry-run JSONs -> EXPERIMENTS.md §Roofline.

Per (arch x shape x mesh):
  compute term    = HLO_dot_FLOPs_per_device / peak_FLOP/s        [s]
  memory term     = HLO_bytes_per_device / HBM_bw                 [s]
  collective term = collective_bytes_per_device / link_bw         [s]
(`hlo_analysis` quantities are per-device and scan-trip-weighted; see
src/repro/launch/hlo_analysis.py. `cost_analysis` bytes are per-device but
count while bodies once — we trip-correct with the dot-flops ratio.)

MODEL_FLOPS = 6*N*D (train) / 2*N*D (inference), N = active params.
"""
from __future__ import annotations

import glob
import json
import os
import sys

import numpy as np

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.launch import hw  # noqa: E402

DRYRUN_DIR = os.path.join(os.path.dirname(__file__), "..", "experiments",
                          "dryrun")


def load_cells(dirpath: str = DRYRUN_DIR):
    cells = []
    for p in sorted(glob.glob(os.path.join(dirpath, "*.json"))):
        with open(p) as f:
            cells.append(json.load(f))
    return cells


def _dp_of_mesh(mesh_name: str) -> int:
    # pod16x16 -> dp 16 ; pod2x16x16 -> dp 32 ; pod64x4 -> 64 ; pod2x64x4 -> 128
    parts = [int(p) for p in mesh_name.replace("pod", "").split("x")]
    return int(np.prod(parts[:-1]))


def analytic_memory_bytes(rec) -> float:
    """Per-device HBM traffic lower bound for one step.

    XLA's ``bytes accessed`` counts unfused op-level traffic (every operand
    to/from memory) — a gross overestimate post-fusion. This model counts
    what *must* move: parameters (fwd read + bwd read + optimizer update
    r/w), remat residuals (layer-boundary activations written+read),
    logits, and KV-cache traffic.
    """
    from repro.configs import ARCHS
    from repro.configs.base import SHAPES
    cfg = ARCHS[rec["arch"]]
    shape = SHAPES[rec["shape"]]
    dp = _dp_of_mesh(rec["mesh"])
    p = rec.get("param_bytes_per_device", 0.0)
    o = rec.get("opt_bytes_per_device", 0.0)
    c = rec.get("cache_bytes_per_device", 0.0)
    tok_dev = shape.global_batch * (shape.seq_len if shape.kind != "decode"
                                    else 1) / dp
    act = cfg.n_layers * tok_dev * cfg.d_model * 2          # residuals, bf16
    logits = tok_dev * cfg.vocab_size * 4
    if shape.kind == "train":
        # params: fwd read + bwd read + recompute read + update write;
        # optimizer: read + write; residuals: write + read; logits: w+r.
        return 4 * p + 2 * o + 2 * act + 2 * logits
    if shape.kind == "prefill":
        return p + c + act + tok_dev * cfg.d_model * 2
    return p + 2 * c + logits  # decode: full cache read + new-slot write


def terms(rec):
    """Roofline terms per device (seconds)."""
    ha = rec.get("hlo_analysis", {})
    ca = rec.get("cost_analysis", {})
    n = rec["n_devices"]
    flops_dev = ha.get("dot_flops", 0.0)
    bytes_dev = analytic_memory_bytes(rec)
    coll_dev = ha.get("collective_total", 0.0)
    t_comp = flops_dev / hw.PEAK_FLOPS_BF16
    t_mem = bytes_dev / hw.HBM_BW
    t_coll = coll_dev / hw.ICI_BW
    dom = max((t_comp, "compute"), (t_mem, "memory"), (t_coll, "collective"))
    useful = rec["model_flops"] / (flops_dev * n) if flops_dev else 0.0
    ideal = rec["model_flops"] / n / hw.PEAK_FLOPS_BF16
    bound = max(t_comp, t_mem, t_coll)
    frac = ideal / bound if bound else 0.0
    return {"compute_s": t_comp, "memory_s": t_mem, "collective_s": t_coll,
            "dominant": dom[1], "useful": useful,
            "xla_unfused_bytes": ca.get("bytes accessed", 0.0),
            "ideal_s": ideal, "roofline_fraction": frac}


def table(cells=None, mesh="pod16x16") -> str:
    cells = cells if cells is not None else load_cells()
    lines = [
        "| arch | shape | compute s | memory s | collective s | dominant | "
        "MODEL/HLO flops | roofline frac |",
        "|---|---|---|---|---|---|---|---|",
    ]
    for r in cells:
        if r["mesh"] != mesh:
            continue
        if "skipped" in r:
            lines.append(f"| {r['arch']} | {r['shape']} | — | — | — | "
                         f"skipped (full attention) | — | — |")
            continue
        if "error" in r:
            lines.append(f"| {r['arch']} | {r['shape']} | ERROR | | | | | |")
            continue
        t = terms(r)
        lines.append(
            f"| {r['arch']} | {r['shape']} | {t['compute_s']:.3f} | "
            f"{t['memory_s']:.3f} | {t['collective_s']:.3f} | "
            f"{t['dominant']} | {t['useful']:.2f} | "
            f"{t['roofline_fraction']:.3f} |")
    return "\n".join(lines)


def bench_roofline():
    """CSV rows for the benchmark harness."""
    rows = []
    for r in load_cells():
        if "skipped" in r or "error" in r:
            continue
        t = terms(r)
        rows.append((f"roofline/{r['arch']}/{r['shape']}/{r['mesh']}",
                     t["ideal_s"] * 1e6,
                     f"dom={t['dominant']};frac={t['roofline_fraction']:.3f}"))
    return rows


if __name__ == "__main__":
    for mesh in ("pod16x16", "pod2x16x16"):
        print(f"\n### mesh {mesh}\n")
        print(table(mesh=mesh))
