"""Chaos-soak SLOs + breaker steady-state overhead (DESIGN.md §16).

Two questions the resilience layer must answer with numbers:

* **Do the serving SLOs hold under scheduled faults?** The per-cell
  ``resilience/soak/*`` rows (model-only: ``us`` is null) replay the
  full injector matrix of :func:`repro.resilience.chaos.run_matrix` —
  memory + disk faults x {ref, pallas} — against a live guarded request
  loop with a bitwise ref oracle. The aggregate gated
  ``resilience/chaos_soak`` row reports
  ``faults_caught``/``faults_injected`` (check_bench requires equal:
  every windowed request either served correct bits or failed loudly —
  zero silent wrong outputs), ``recovery_requests`` vs ``recovery_k``
  (the breaker closed within K requests of the injector clearing), and
  ``traps_while_open`` (must be 0: an open circuit routes at plan level,
  the per-call trap cost is gone).
* **What does open-circuit service cost?** ``breaker_steady_overhead``
  is a paired warm measurement: the condemned pallas program dispatched
  through an OPEN breaker (one route decision + the guarded ref twin)
  vs the same program compiled for ref and dispatched unguarded.
  check_bench gates the ratio at ``BREAKER_OVERHEAD_TOL`` (1.05x) —
  degraded service must cost ref price, not trap-and-fallback price.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro import guard
from repro.combinators import compile_expr
from repro.combinators import vocab as V
from repro.resilience import breaker as _breaker
from repro.resilience import chaos

REPS = 20
STEADY_N = 12


def _steady_overhead():
    """(unguarded ref µs, open-breaker shunted µs, traps during the
    shunted reps) for one 2^STEADY_N bit-reversal."""
    from .autodiff_overhead import _timed  # shared min-stat methodology

    x = jnp.asarray(np.random.default_rng(0).standard_normal(
        1 << STEADY_N).astype(np.float32))
    f_ref = compile_expr(V.bit_reverse(STEADY_N), engine="ref",
                         optimize=False)
    f_pal = compile_expr(V.bit_reverse(STEADY_N), engine="pallas",
                         optimize=False)
    guard.disable()
    jax.block_until_ready(f_ref(x))          # warm the unguarded ref path
    us_plain = _timed(f_ref, x, reps=REPS)
    board = _breaker.board()
    # a cool-down far beyond the rep count keeps the circuit OPEN for
    # the whole timed run (no half-open probe mid-measurement)
    board.configure(threshold=1, cooldown=1_000_000)
    try:
        with guard.guarded():
            r = board.route("pallas")        # condemn pallas: one failure
            board.on_trap(r, ("oob",))       # at threshold=1 opens it
            traps0 = sum(guard.stats()["traps"].values())
            jax.block_until_ready(f_pal(x))  # warm the shunted ref twin
            us_shunted = _timed(f_pal, x, reps=REPS)
            traps = sum(guard.stats()["traps"].values()) - traps0
    finally:
        board.configure(threshold=_breaker.DEFAULT_THRESHOLD,
                        cooldown=_breaker.DEFAULT_COOLDOWN)
    return us_plain, us_shunted, traps


def rows():
    out = []
    reports = chaos.run_matrix()
    for rep in reports:
        out.append((
            f"resilience/soak/{rep.engine}_{rep.fault}", None,
            f"requests={rep.requests};ok={rep.ok};errors={rep.errors};"
            f"faults_caught={rep.faults_caught};"
            f"faults_injected={rep.faults_injected};"
            f"silent_wrong_outputs={rep.silent_wrong};"
            f"recovery_requests={rep.recovery_requests};"
            f"passed={rep.passed}"))

    injected = sum(r.faults_injected for r in reports)
    caught = sum(r.faults_caught for r in reports)
    silent = sum(r.silent_wrong for r in reports)
    traps_open = sum(r.traps_while_open for r in reports)
    # the binding recovery bound: the worst cell, each against its own K
    recovery = max((r.recovery_requests for r in reports
                    if r.recovery_requests is not None), default=None)
    recovery_k = max(r.recovery_k for r in reports)
    unrecovered = sum(1 for r in reports if r.recovery_requests is None)
    opens = sum(r.breaker.get("open", 0) for r in reports)
    probes = sum(r.breaker.get("probe", 0) for r in reports)
    closes = sum(r.breaker.get("close", 0) for r in reports)
    all_pass = all(r.passed for r in reports)

    us_plain, us_shunted, steady_traps = _steady_overhead()
    overhead = us_shunted / max(us_plain, 1e-9)
    out.append((
        f"resilience/steady/2^{STEADY_N}/unguarded_ref", us_plain,
        f"reps={REPS}"))
    out.append((
        f"resilience/steady/2^{STEADY_N}/open_breaker", us_shunted,
        f"reps={REPS};breaker_steady_overhead={overhead:.3f};"
        f"traps_during_reps={steady_traps}"))
    out.append((
        "resilience/chaos_soak", None,
        f"cells={len(reports)};all_pass={all_pass};"
        f"faults_caught={caught};faults_injected={injected};"
        f"silent_wrong_outputs={silent};"
        f"recovery_requests={'unrecovered' if unrecovered else recovery};"
        f"recovery_k={recovery_k};"
        f"traps_while_open={traps_open + steady_traps};"
        f"breaker_opens={opens};breaker_probes={probes};"
        f"breaker_closes={closes};"
        f"breaker_steady_overhead={overhead:.3f}"))
    return out


if __name__ == "__main__":
    for row in rows():
        print(",".join(str(v) for v in row))
