"""Class-dispatch kernel hierarchy: per-class model + dispatch overhead.

Three views (DESIGN.md §11):

* **Per-class transaction model** — for a representative BMMC of each
  class, the dispatched kernel, pass count, DMA descriptors and the
  copy-roofline ratio. The acceptance bar: the block-permute plan's
  descriptor count EQUALS ``copy_through_vmem``'s for the same size
  (ratio 1.0), and a general BMMC runs ONE generalized pass, not the
  §5.2 two.
* **Program model** — per-class kernel counts + model round trips of
  the clustered+folded 2^12 sort / FFT (the stagefusion acceptance
  numbers, now with class dispatch and free folding).
* **Dispatch microbenchmark** — µs/call of the whole-program compiled
  executable vs stage-at-a-time Python dispatch for a many-stage
  program. Both paths execute identical kernels; the gap is pure
  host-side per-call overhead (plan-cache lookups, table conversion,
  one XLA dispatch per stage), which the executable pays only at trace
  time.
* **Telemetry honesty** — the 2^12 sort executed once with
  :mod:`repro.obs` enabled: the per-class dispatch counters the
  executor *actually* recorded must exactly equal the
  ``program_cost(clustered=True)`` kernel-class counts the model
  *claims* (the PR 6 acceptance bar; ``counts_match`` is gated by
  check_bench).
"""
from __future__ import annotations

import random

import jax
import jax.numpy as jnp
import numpy as np

from repro import obs
from repro.combinators import compile_expr
from repro.combinators.sort import sort_expr
from repro.core.bmmc import Bmmc
from repro.core.tiling import class_stats
from repro.kernels.ops import choose_tile

REPS = 20
TELEMETRY_N = 12    # the acceptance size: executed ONCE, counters vs model


def _class_examples(n: int, t: int):
    rng = random.Random(0)
    ident = tuple(1 << i for i in range(n))
    # block: permute + complement only the bits above the copy block
    # (2^11 elements), so whole copy-sized blocks move wholesale and the
    # planned descriptor count EQUALS copy_through_vmem's (ratio 1.0)
    kb = 11
    sub = Bmmc.random(n - kb, rng)
    block = Bmmc(ident[:kb] + tuple(r << kb for r in sub.rows),
                 sub.c << kb)
    # lane: permute the low t bits only
    subl = Bmmc.random(t, rng)
    lane = Bmmc(tuple(subl.rows) + ident[t:], subl.c)
    return (
        ("identity", Bmmc.identity(n)),
        ("complement", Bmmc.reverse_array(n)),
        ("block", block),
        ("lane", lane),
        ("tiled", Bmmc.bit_reverse(n)),
        ("general", Bmmc.random(n, rng)),
    )


def rows():
    out = []
    n = 13
    t = choose_tile(n, 4, 1)
    for name, bmmc in _class_examples(n, t):
        cs = class_stats(bmmc, t)
        out.append((
            f"classdispatch/{name}/2^{n}/model", None,
            f"t={t};kernel={cs['kernel']};passes={cs['passes']};"
            f"desc={cs['descriptors']};copy_desc={cs['copy_descriptors']};"
            f"roofline={cs['roofline_ratio']:.3f}",
        ))

    # -- program-level per-class kernel counts (the acceptance numbers) -----
    for name, d in (("sort", 1), ("fft", 2)):
        from repro.combinators.fft import fft_expr
        mk = sort_expr if name == "sort" else fft_expr
        pn = 12
        pt = choose_tile(pn, 4, d)
        f = compile_expr(mk(pn), engine="pallas")
        cost = f.cost(pn, pt, clustered=True)
        kern = ";".join(f"{k}={v}" for k, v in sorted(cost["kernels"].items()))
        out.append((
            f"classdispatch/{name}/2^{pn}/program", None,
            f"t={pt};round_trips={cost['round_trips']};{kern};"
            f"roofline={cost['roofline_ratio']:.3f}",
        ))

    # -- dispatch-overhead microbenchmark -----------------------------------
    from .autodiff_overhead import _timed  # shared min-stat methodology

    dn = 8
    x = jnp.asarray(np.random.default_rng(0).normal(
        size=(1 << dn,)).astype(np.float32))
    f = compile_expr(sort_expr(dn), engine="pallas")
    jax.block_until_ready(f(x))              # warm the program executable
    jax.block_until_ready(f.call_per_stage(x))   # and the per-stage path
    us_exec = _timed(f, x, reps=REPS)
    us_stage = _timed(f.call_per_stage, x, reps=REPS)
    stages = len(f.clustered_program(dn, choose_tile(dn, 4, 1)))
    out.append((f"classdispatch/sort/2^{dn}/perstage_dispatch", us_stage,
                f"stages={stages}"))
    measured = us_stage / max(us_exec, 1e-9)
    out.append((
        f"classdispatch/sort/2^{dn}/executable_dispatch", us_exec,
        f"stages={stages};speedup={measured:.2f}x",
    ))
    # dispatch model: one XLA dispatch replaces `stages` per-stage
    # dispatches, so modeled speedup == stage count; drift vs the
    # measured speedup is the honesty-gate quantity (per-dispatch cost
    # is not constant across kernels, so drift > 1 is expected — it
    # just must stay stable)
    rel = measured / stages
    out.append((
        f"classdispatch/sort/2^{dn}/model_error", None,
        f"modeled_speedup={stages:.2f};measured_speedup={measured:.2f};"
        f"drift={max(rel, 1 / rel):.2f}",
    ))

    # -- telemetry honesty: measured dispatch counters vs the model ---------
    out.append(_telemetry_row())
    return out


def _telemetry_row():
    """Execute the 2^{TELEMETRY_N} sort ONCE with telemetry recording and
    hold the executor's per-class dispatch counters against the
    clustered transaction model's kernel-class counts."""
    tn = TELEMETRY_N
    tt = choose_tile(tn, 4, 1)
    f = compile_expr(sort_expr(tn), engine="pallas")
    want = f.cost(tn, tt, clustered=True)["kernels"]
    x = jnp.asarray(np.random.default_rng(0).normal(
        size=(1 << tn,)).astype(np.float32))
    was_enabled = obs.enabled()
    obs.enable(sync=True)
    before = obs.kernel_counts()
    try:
        jax.block_until_ready(f(x))
    finally:
        if not was_enabled:
            obs.disable()
    got = {k: v - before.get(k, 0)
           for k, v in obs.kernel_counts().items()
           if v - before.get(k, 0)}
    match = got == {k: v for k, v in want.items() if v}
    counts = ";".join(f"{k}={v}" for k, v in sorted(got.items()))
    return (
        f"classdispatch/sort/2^{tn}/telemetry", None,
        f"counts_match={match};{counts};"
        f"model_round_trips={f.cost(tn, tt, clustered=True)['round_trips']}",
    )


if __name__ == "__main__":
    for r in rows():
        print(",".join(str(v) for v in r))
