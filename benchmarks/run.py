"""Benchmark harness: one module per paper table/figure + roofline.

Prints ``name,us_per_call,derived`` CSV rows. Run with
``PYTHONPATH=src python -m benchmarks.run [--only fig9,...]``.
``--json OUT.json`` additionally writes the rows (plus run metadata) as
machine-readable JSON — the format the ``BENCH_*.json`` perf-trajectory
files at the repo root record. Rows named ``*/model_error`` (modeled vs
measured ratio per workload) are additionally lifted into a structured
``model_error`` section of the payload — the input to check_bench's
model-honesty gate. ``--trace OUT.json`` enables :mod:`repro.obs` for
the whole run and writes the Chrome trace + telemetry snapshot.
"""
from __future__ import annotations

import argparse
import json
import os
import platform
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="comma-separated subset: "
                         "fig9,fig10,transpose,sort,khc,roofline,"
                         "combinators,autodiff,stagefusion,classdispatch,"
                         "guard,store,resilience")
    ap.add_argument("--smoke", action="store_true",
                    help="fast sanity subset (combinators + autodiff + "
                         "stagefusion + classdispatch + guard + store + "
                         "resilience; pairs with `pytest -m tier1` as the "
                         "quick tier-1 smoke entry point)")
    ap.add_argument("--json", default=None, metavar="OUT.json",
                    help="also write rows + metadata as JSON")
    ap.add_argument("--trace", default=None, metavar="TRACE.json",
                    help="enable repro.obs telemetry for the whole run and "
                         "write the Chrome trace (chrome://tracing) here; "
                         "--json payloads gain a telemetry snapshot")
    args = ap.parse_args()
    if args.trace:
        from repro import obs
        obs.enable(sync=True)
    if args.smoke and args.only:
        ap.error("--smoke and --only are mutually exclusive")
    want = set(args.only.split(",")) if args.only else None
    if args.smoke:
        want = {"combinators", "autodiff", "stagefusion", "classdispatch",
                "guard", "store", "resilience"}

    print("name,us_per_call,derived")
    suites = []
    if want is None or "fig9" in want:
        from . import paper_fig9
        suites.append(paper_fig9.rows)
    if want is None or "fig10" in want:
        from . import paper_fig10
        suites.append(paper_fig10.rows)
    if want is None or "transpose" in want:
        from . import transpose_table
        suites.append(transpose_table.rows)
    if want is None or "sort" in want:
        from . import sort_stages
        suites.append(sort_stages.rows)
    if want is None or "khc" in want:
        from . import kernel_hillclimb
        suites.append(kernel_hillclimb.rows)
    if want is None or "roofline" in want:
        from . import roofline
        suites.append(roofline.bench_roofline)
    if want is None or "combinators" in want:
        from . import combinator_fusion
        suites.append(combinator_fusion.rows)
    if want is None or "autodiff" in want:
        from . import autodiff_overhead
        suites.append(autodiff_overhead.rows)
    if want is None or "stagefusion" in want:
        from . import stage_fusion
        suites.append(stage_fusion.rows)
    if want is None or "classdispatch" in want:
        from . import class_dispatch
        suites.append(class_dispatch.rows)
    if want is None or "guard" in want:
        from . import guard_overhead
        suites.append(guard_overhead.rows)
    if want is None or "store" in want:
        from . import store_warmstart
        suites.append(store_warmstart.rows)
    if want is None or "resilience" in want:
        from . import resilience_soak
        suites.append(resilience_soak.rows)
    collected = []
    for rows_fn in suites:
        for name, us, derived in rows_fn():
            # model-only rows (offline transaction counts, telemetry
            # gates) carry no wall-clock measurement: us is None, the
            # CSV cell is empty and the JSON field is null so readers
            # and check_bench can't mistake them for measured 0.00 µs
            us_cell = "" if us is None else f"{us:.2f}"
            print(f"{name},{us_cell},{derived}")
            collected.append(
                {"name": name,
                 "us": None if us is None else round(float(us), 2),
                 "derived": str(derived)})
    if args.trace:
        from repro import obs
        obs.export_trace(args.trace)
        print(f"# wrote {len(obs.events())} trace events to {args.trace}",
              file=sys.stderr)
    if args.json:
        import jax
        import numpy as np
        payload = {
            "metadata": {
                "argv": sys.argv[1:],
                "suites": sorted(want) if want is not None else "all",
                "python": platform.python_version(),
                "jax": jax.__version__,
                "numpy": np.__version__,
                "platform": platform.platform(),
                "backend": jax.default_backend(),
            },
            "rows": collected,
            # modeled-vs-measured accounting per workload: the input to
            # check_bench's model-honesty gate
            "model_error": _model_error_section(collected),
            # durable-store warm-start + fault-coverage accounting: the
            # input to check_bench's store gates
            "store": _store_section(collected),
        }
        if args.trace:
            from repro import obs
            payload["telemetry"] = obs.snapshot()
        with open(args.json, "w") as f:
            json.dump(payload, f, indent=1)
            f.write("\n")
        print(f"# wrote {len(collected)} rows to {args.json}",
              file=sys.stderr)


def _store_section(rows: list) -> list:
    """Lift ``store/*`` gate rows (``/warmstart``, ``/fault_injection``)
    into structured records."""
    out = []
    for row in rows:
        if not row["name"].startswith("store/"):
            continue
        if not row["name"].endswith(("/warmstart", "/fault_injection")):
            continue
        rec = {"row": row["name"]}
        for part in row["derived"].split(";"):
            k, _, v = part.partition("=")
            try:
                rec[k] = float(v)
            except ValueError:
                rec[k] = v
        out.append(rec)
    return out


def _model_error_section(rows: list) -> list:
    """Lift ``*/model_error`` rows into structured records."""
    out = []
    for row in rows:
        if not row["name"].endswith("/model_error"):
            continue
        rec = {"workload": row["name"].rsplit("/", 1)[0]}
        for part in row["derived"].split(";"):
            k, _, v = part.partition("=")
            try:
                rec[k] = float(v)
            except ValueError:
                rec[k] = v
        out.append(rec)
    return out


if __name__ == "__main__":
    main()
