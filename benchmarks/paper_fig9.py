"""Paper Fig. 9: impact of each optimization on permutation running time.

Variants per permutation class (bit-reverse / random BPC / random BMMC),
arrays of 2^30 int32 (the paper's size), via the transaction model:

  naive          — coalesced read, scattered write
  tile           — §4.1 tiling: both sides coalesced (+ second pass if BMMC)
  tile+banks     — §4.2: no TPU analogue (VMEM has no programmer-visible
                   banks); identical transaction counts, kept for table shape
  tile+runmerge  — §4.3 TPU adaptation: merged DMA descriptors (the 'iters'
                   analogue); same bytes, fewer descriptors (reported)

Also reports interpret-mode Pallas wall time at a reduced size (2^16) purely
as a correctness-path sanity check (CPU emulation, not a perf number).
"""
from __future__ import annotations

import random
import time

import jax.numpy as jnp

from repro.core.bmmc import Bmmc
from repro.kernels.ops import bmmc_permute
from .transaction_model import (GPU_RTX4090, TPU_V5E, copy_time,
                                descriptor_counts, naive_time, tiled_time)

N_PAPER = 30      # 2^30 elements, as in the paper
T_GPU = 5         # paper: n_tile = log2(warp) = 5
T_TPU = 7         # 512 B rows of int32


def cases(n: int):
    rng = random.Random(42)
    return [("bit-reverse", Bmmc.bit_reverse(n)),
            ("random-bpc", Bmmc.random_bpc(n, rng)),
            ("random-bmmc", Bmmc.random(n, rng))]


def rows():
    out = []
    for hw, t in ((GPU_RTX4090, T_GPU), (TPU_V5E, T_TPU)):
        c = copy_time(N_PAPER, hw)
        out.append((f"fig9/{hw.name}/copy", c * 1e6, "bw_frac=1.00"))
        for name, b in cases(N_PAPER):
            tn = naive_time(b, hw)
            tt = tiled_time(b, hw, t)
            dc = descriptor_counts(b, t)
            out.append((f"fig9/{hw.name}/{name}/naive", tn * 1e6,
                        f"bw_frac={c / tn:.2f}"))
            out.append((f"fig9/{hw.name}/{name}/tile", tt * 1e6,
                        f"bw_frac={c / tt:.2f};passes={dc['passes']}"))
            out.append((f"fig9/{hw.name}/{name}/tile+runmerge", tt * 1e6,
                        f"bw_frac={c / tt:.2f};desc={dc['descriptors']:.3g}"
                        f"(vs {dc['descriptors_unmerged']:.3g})"))
    # measured interpret-mode sanity (reduced size, CPU emulation)
    n_small = 16
    x = jnp.arange(1 << n_small, dtype=jnp.int32)
    for name, b in cases(n_small):
        fn = lambda: bmmc_permute(x, b, t=4).block_until_ready()
        fn()
        t0 = time.perf_counter()
        fn()
        dt = time.perf_counter() - t0
        out.append((f"fig9/interpret-cpu-2^16/{name}", dt * 1e6,
                    "correctness-path timing, not perf"))
    return out


if __name__ == "__main__":
    for r in rows():
        print(",".join(str(v) for v in r))
