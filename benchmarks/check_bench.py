"""Benchmark regression gate: compare a fresh ``--smoke --json`` run
against a checked-in ``BENCH_*.json`` baseline.

Usage: ``python -m benchmarks.check_bench BASELINE.json CURRENT.json``

Fails (exit 1) when the *model* numbers regress — these are offline
transaction counts, fully deterministic, so any increase is a real code
regression, not noise:

* ``stagefusion/*/model`` and ``classdispatch/*/program`` rows: the
  clustered model ``round_trips`` must not exceed the baseline's;
* ``classdispatch/*/program`` rows: per-class kernel counts must not
  shift toward costlier classes (``sweep`` and ``general2`` counts must
  not grow);
* ``classdispatch/*/model`` rows: the dispatched kernel class and its
  roofline ratio must not regress.

Model-honesty gate (PR 6): ``*/telemetry`` rows must report
``counts_match=True`` — the executor's recorded per-class dispatch
counters equal the transaction model's kernel-class counts, a fully
deterministic comparison; and ``*/model_error`` rows (modeled vs
measured speedup per workload) must not *drift* beyond
``DRIFT_TOL``× the baseline's drift — drift is the symmetric ratio
``max(r, 1/r)`` of measured over modeled speedup, so the gate fires
when the model's relationship to the wall clock changes by a factor,
while ordinary CI machine noise (well under the tolerance) passes.

Backward honesty gate (PR 7): ``*/bwd_telemetry`` rows must report
``bwd_counts_match=True`` — one cold backward call's recorded
``model.vjp_round_trips`` counter delta equals the compiled backward's
modeled cost (``CompiledExpr.vjp_round_trips``) — and their
``bwd_round_trips`` must not exceed the baseline's (the backward is an
offline-compiled program, so extra passes are a code regression, not
noise). Where present, ``bwd_mirrors_fwd`` (permutation-only programs:
the backward kernel-class histogram mirrors the forward's) must stay
True.

Guard gates (PR 8, DESIGN.md §14): ``*/fault_injection`` rows must
report ``faults_caught == faults_injected`` — every corruption class
the ring-3 harness injects is caught (typed error or recovered
fallback), zero silent-wrong-output cases — and ``*/overhead`` rows'
``guard_overhead_ratio`` (guarded / unguarded warm dispatch, a
same-machine paired measurement, so machine noise largely cancels)
must stay <= ``GUARD_OVERHEAD_TOL``.

Store gates (PR 9, DESIGN.md §15): the ``*/warmstart`` row must report
``disk_hit_rate=1.0`` and ``plans_built=0`` (a fresh process booting
from a populated store serves every plan from disk and compiles none)
and a ``warmstart_speedup`` (cold / disk-warm first-call latency, a
paired same-machine measurement) at least ``WARMSTART_MIN_SPEEDUP``;
the ``store/disk/fault_injection`` row rides the generic
fault-injection gate — every injected disk fault (truncation, bit
flip, version skew, torn write, quarantine race) caught.

Resilience gates (PR 10, DESIGN.md §16): the ``resilience/chaos_soak``
row aggregates the chaos soak's full injector matrix (memory + disk
faults x {ref, pallas}) and must report ``faults_caught ==
faults_injected`` with ``silent_wrong_outputs == 0`` (every request
served while an injector was active either returned bitwise-correct
output or failed loudly), ``recovery_requests <= recovery_k`` (the
circuit breaker closed within K requests of the injector clearing),
``traps_while_open == 0`` (an open breaker routes at plan level — the
per-call trap cost is demonstrably gone), and a
``breaker_steady_overhead`` (open-breaker shunted dispatch vs unguarded
ref warm dispatch, a paired same-machine measurement) at most
``BREAKER_OVERHEAD_TOL``.

Other wall-clock rows are reported but never gated (CI machines are
noisy); rows whose ``us`` is null carry no wall-clock measurement at
all (model-only/telemetry rows) and are explicitly exempt from any
timing comparison. Rows missing from the baseline (older recordings)
are skipped with a note, so the gate tightens automatically as
baselines are refreshed.
"""
from __future__ import annotations

import json
import re
import sys

# a workload's measured/modeled drift may grow this much vs the
# baseline before the gate fires: honest-model changes land well under
# it, machine noise too; an order-of-magnitude lie does not
DRIFT_TOL = 5.0

# guarded warm dispatch may cost at most this multiple of unguarded
# (the ISSUE 8 acceptance bar: <= 5% steady-state guard overhead; the
# ratio is a paired same-machine measurement, so noise mostly cancels)
GUARD_OVERHEAD_TOL = 1.05

# a disk-warm boot must be at least this much faster than a cold boot
# (ISSUE 9: cold/disk-warm is a paired same-machine first-call ratio —
# structurally >= 1 since disk-warm skips planning, so the floor sits
# just under 1.0 to absorb shared-CI-machine noise, and the real gates
# are the deterministic disk_hit_rate == 1 / plans_built == 0 pair)
WARMSTART_MIN_SPEEDUP = 0.98

# open-breaker (shunted) warm dispatch may cost at most this multiple
# of unguarded ref warm dispatch (ISSUE 10: degraded service costs ref
# price, not trap-and-fallback price; paired same-machine measurement)
BREAKER_OVERHEAD_TOL = 1.05

_GATED_SUFFIXES = ("/model", "/program", "/model_error", "/telemetry",
                   "/bwd_telemetry", "/overhead", "/fault_injection",
                   "/warmstart", "/chaos_soak")


def _has_timing(row: dict) -> bool:
    """True when the row carries a real wall-clock measurement.

    Model-only and telemetry rows record ``us: null`` (older baselines:
    ``0.0``); neither is a measured time, so timing-based comparisons
    must skip them explicitly rather than treat them as sub-µs calls.
    """
    us = row.get("us")
    return us is not None and float(us) > 0.0


def _derived(row: dict) -> dict:
    out = {}
    for part in row.get("derived", "").split(";"):
        if "=" in part:
            k, _, v = part.partition("=")
            out[k] = v
    return out


def _round_trips(row: dict):
    val = _derived(row).get("round_trips")
    if val is None:
        return None
    m = re.match(r"(?:\d+->)?(\d+)$", val)
    return int(m.group(1)) if m else None


def _rows_by_name(payload: dict) -> dict:
    return {r["name"]: r for r in payload.get("rows", [])}


def _check_drift(name: str, brow: dict, crow: dict) -> list:
    """Model-honesty comparison for one ``*/model_error`` row pair."""
    try:
        b_drift = float(_derived(brow).get("drift"))
        c_drift = float(_derived(crow).get("drift"))
    except (TypeError, ValueError):
        return [f"{name}: model_error row missing a parseable drift value"]
    if c_drift > b_drift * DRIFT_TOL:
        return [
            f"{name}: modeled/measured drift {b_drift:.2f} -> {c_drift:.2f} "
            f"(exceeds {DRIFT_TOL}x tolerance; the transaction model no "
            "longer tracks the wall clock)"]
    return []


def check(baseline: dict, current: dict) -> list:
    base = _rows_by_name(baseline)
    cur = _rows_by_name(current)
    failures = []
    skipped = []
    # a gated row that vanishes from the fresh run is itself a failure —
    # otherwise a renamed/dropped benchmark silently un-gates its numbers
    for name in sorted(base):
        if name.endswith(_GATED_SUFFIXES) and name not in cur:
            failures.append(f"{name}: gated row missing from current run")
    for name, row in sorted(cur.items()):
        if name.endswith("/bwd_telemetry"):
            d = _derived(row)
            # deterministic: one cold backward call's counter delta
            # must equal the compiled backward's modeled pass count
            if d.get("bwd_counts_match") != "True":
                failures.append(
                    f"{name}: cold-backward vjp counter delta diverges "
                    f"from the compiled backward's model "
                    f"(bwd_counts_match={d.get('bwd_counts_match')}, "
                    f"bwd_round_trips={d.get('bwd_round_trips')}, "
                    f"model_bwd_round_trips="
                    f"{d.get('model_bwd_round_trips')})")
            if d.get("bwd_mirrors_fwd") not in (None, "True"):
                failures.append(
                    f"{name}: permutation-only backward kernel histogram "
                    "no longer mirrors the forward's "
                    f"(bwd_mirrors_fwd={d.get('bwd_mirrors_fwd')})")
            if name in base:
                bd = _derived(base[name])
                try:
                    b_rt = int(bd["bwd_round_trips"])
                    c_rt = int(d["bwd_round_trips"])
                except (KeyError, ValueError):
                    b_rt = c_rt = 0
                if c_rt > b_rt:
                    failures.append(
                        f"{name}: backward round_trips {b_rt} -> {c_rt} "
                        "(the compiled backward gained passes)")
            else:
                skipped.append(name)
            continue
        if name.endswith("/fault_injection"):
            d = _derived(row)
            try:
                caught = int(d.get("faults_caught"))
                injected = int(d.get("faults_injected"))
            except (TypeError, ValueError):
                failures.append(
                    f"{name}: fault-injection row missing parseable "
                    f"faults_caught/faults_injected")
                continue
            if caught != injected or injected == 0:
                missed = [p for p in row.get("derived", "").split(";")
                          if p.endswith("=MISSED")]
                failures.append(
                    f"{name}: {caught}/{injected} injected faults caught "
                    f"({'; '.join(missed) or 'no per-kind detail'}) — an "
                    "uncaught fault is a silent-wrong-output path")
            continue
        if name.endswith("/chaos_soak"):
            # the chaos-soak SLO contract (ISSUE 10): all deterministic
            # except the paired steady-overhead ratio
            d = _derived(row)
            try:
                caught = int(d.get("faults_caught"))
                injected = int(d.get("faults_injected"))
                silent = int(d.get("silent_wrong_outputs"))
                recovery = int(d.get("recovery_requests"))
                recovery_k = int(d.get("recovery_k"))
                traps_open = int(d.get("traps_while_open"))
                overhead = float(d.get("breaker_steady_overhead"))
            except (TypeError, ValueError):
                failures.append(
                    f"{name}: chaos_soak row missing parseable "
                    f"faults_caught/faults_injected/silent_wrong_outputs/"
                    f"recovery_requests/recovery_k/traps_while_open/"
                    f"breaker_steady_overhead")
                continue
            if caught != injected or injected == 0:
                failures.append(
                    f"{name}: {caught}/{injected} soak-window faults "
                    "caught — an uncaught fault is a silent-wrong-output "
                    "path under live serving")
            if silent != 0:
                failures.append(
                    f"{name}: {silent} silent wrong output(s) served "
                    "(gate: zero — wrong bits must never leave as ok)")
            if recovery > recovery_k:
                failures.append(
                    f"{name}: breaker recovery took {recovery} requests "
                    f"after the injector cleared (gate: <= {recovery_k})")
            if traps_open != 0:
                failures.append(
                    f"{name}: {traps_open} trap(s) fired while a circuit "
                    "was open (gate: 0 — an open breaker must route at "
                    "plan level, not pay per-call trap cost)")
            if overhead > BREAKER_OVERHEAD_TOL:
                failures.append(
                    f"{name}: open-breaker dispatch costs {overhead:.3f}x "
                    f"unguarded ref warm (gate: <= {BREAKER_OVERHEAD_TOL}x)")
            continue
        if name.endswith("/warmstart"):
            # the durable-store warm-start contract (ISSUE 9): a fresh
            # process booting from a populated store must serve 100%
            # disk hits, compile zero plans, and be no slower than a
            # cold boot — integrity re-audits included
            d = _derived(row)
            try:
                hit_rate = float(d.get("disk_hit_rate"))
                built = int(d.get("plans_built"))
                speedup = float(d.get("warmstart_speedup"))
            except (TypeError, ValueError):
                failures.append(
                    f"{name}: warmstart row missing parseable "
                    f"disk_hit_rate/plans_built/warmstart_speedup")
                continue
            if hit_rate < 1.0:
                failures.append(
                    f"{name}: disk-warm boot hit rate {hit_rate:.3f} < 1.0 "
                    "(a warm process re-planned something the store "
                    "should have served)")
            if built != 0:
                failures.append(
                    f"{name}: disk-warm boot compiled {built} plans "
                    "(gate: zero plans compiled on second boot)")
            if speedup < WARMSTART_MIN_SPEEDUP:
                failures.append(
                    f"{name}: disk-warm vs cold speedup {speedup:.3f} "
                    f"below the {WARMSTART_MIN_SPEEDUP} floor (loading + "
                    "re-auditing plans should not cost more than "
                    "planning them)")
            continue
        if name.endswith("/overhead"):
            d = _derived(row)
            try:
                ratio = float(d.get("guard_overhead_ratio"))
            except (TypeError, ValueError):
                failures.append(
                    f"{name}: overhead row missing a parseable "
                    f"guard_overhead_ratio")
                continue
            if ratio > GUARD_OVERHEAD_TOL:
                failures.append(
                    f"{name}: guarded warm dispatch costs {ratio:.3f}x "
                    f"unguarded (gate: <= {GUARD_OVERHEAD_TOL}x)")
            continue
        if name.endswith("/telemetry"):
            # deterministic counter-vs-model comparison: never True->False
            if _derived(row).get("counts_match") != "True":
                failures.append(
                    f"{name}: recorded dispatch counters diverge from the "
                    f"transaction model (counts_match="
                    f"{_derived(row).get('counts_match')})")
            continue
        if name.endswith("/model_error"):
            if name in base:
                failures.extend(_check_drift(name, base[name], row))
            else:
                skipped.append(name)
            continue
        if not (name.endswith("/model") or name.endswith("/program")):
            continue
        if name not in base:
            skipped.append(name)
            continue
        brow = base[name]
        b_rt, c_rt = _round_trips(brow), _round_trips(row)
        if b_rt is not None and c_rt is not None and c_rt > b_rt:
            failures.append(
                f"{name}: round_trips {b_rt} -> {c_rt} (regression)")
        bd, cd = _derived(brow), _derived(row)
        for key in ("sweep", "general2"):
            bv, cv = int(bd.get(key, 0) or 0), int(cd.get(key, 0) or 0)
            if cv > bv:
                failures.append(
                    f"{name}: kernel class {key!r} count {bv} -> {cv} "
                    "(shifted toward a costlier class)")
        if "kernel" in bd and "kernel" in cd:
            # directional: only a shift toward a COSTLIER kernel class
            # fails (an upgrade, e.g. general2 -> general, is progress)
            rank = {"none": 0, "block": 1, "lane": 1, "tiled": 2,
                    "general": 2, "fused": 2, "general2": 3}
            b_rank = rank.get(bd["kernel"], 3)
            c_rank = rank.get(cd["kernel"], 3)
            if c_rank > b_rank:
                failures.append(f"{name}: dispatched kernel "
                                f"{bd['kernel']} -> {cd['kernel']}")
        if "roofline" in bd and "roofline" in cd:
            if float(cd["roofline"]) < float(bd["roofline"]) - 1e-9:
                failures.append(
                    f"{name}: roofline {bd['roofline']} -> {cd['roofline']}")
    # wall-clock rows: reported only, never gated — and rows with no
    # measurement at all (us null / legacy 0.0) are skipped outright so
    # a model-only row can't masquerade as a sub-µs timing
    for name, row in sorted(cur.items()):
        if name not in base:
            continue
        if not (_has_timing(row) and _has_timing(base[name])):
            continue
        b_us, c_us = float(base[name]["us"]), float(row["us"])
        if c_us > 3.0 * b_us:
            print(f"note: {name} wall clock {b_us:.2f} -> {c_us:.2f} µs "
                  "(reported only; timing rows are never gated)",
                  file=sys.stderr)
    for name in skipped:
        print(f"note: {name} absent from baseline; skipped", file=sys.stderr)
    return failures


def main() -> int:
    if len(sys.argv) != 3:
        print(__doc__, file=sys.stderr)
        return 2
    with open(sys.argv[1]) as f:
        baseline = json.load(f)
    with open(sys.argv[2]) as f:
        current = json.load(f)
    failures = check(baseline, current)
    if failures:
        print("benchmark model regressions vs "
              f"{sys.argv[1]}:", file=sys.stderr)
        for msg in failures:
            print(f"  {msg}", file=sys.stderr)
        return 1
    print(f"benchmark model numbers hold vs {sys.argv[1]}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
