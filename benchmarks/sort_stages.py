"""Paper §7: the parm sorting network, raw vs fused BMMC stage counts.

The compile-time rewrite ``bmmc B . bmmc A -> bmmc (BA)`` collapses the
permutation pipeline; each residual BMMC costs <= 2 coalesced passes
(§5.2), so the table reports the end-to-end pass count of the whole sort.
Also times the compiled sort (pure-jnp engine) on CPU for 2^14 elements.
"""
from __future__ import annotations

import time

import jax.numpy as jnp
import numpy as np

from repro.core.sort import (compile_sort, fuse, num_perm_stages, run_stages)
from repro.kernels.ops import bmmc_plans


def rows():
    out = []
    for n in (4, 8, 12):
        raw = compile_sort(n)
        fz = fuse(raw)
        passes = sum(len(bmmc_plans(s.bmmc, min(3, n // 2)))
                     for s in fz if hasattr(s, "bmmc"))
        out.append((f"sort/2^{n}/stages", 0.0,
                    f"raw={num_perm_stages(raw)};fused={num_perm_stages(fz)};"
                    f"tiled_passes={passes}"))
    n = 14
    xs = jnp.asarray(np.random.default_rng(0).integers(0, 1 << 30, 1 << n,
                                                       dtype=np.int32))
    prog = fuse(compile_sort(n))
    run = lambda: run_stages(prog, xs).block_until_ready()
    got = np.asarray(run())
    assert np.array_equal(got, np.sort(np.asarray(xs)))
    t0 = time.perf_counter()
    run()
    dt = time.perf_counter() - t0
    out.append((f"sort/2^{n}/cpu-jnp", dt * 1e6, "sorted=True"))
    return out


if __name__ == "__main__":
    for r in rows():
        print(",".join(str(v) for v in r))
