"""Paper §7: compiled-sort wall time on CPU (pure-jnp engine).

Stage-count / fusion tables for the sort (and FFT) live in
``benchmarks/combinator_fusion.py`` — this module only times the fused
network end-to-end for 2^14 elements, as a sanity row that the whole
compiled program executes.
"""
from __future__ import annotations

import time

import jax.numpy as jnp
import numpy as np

from repro.core.sort import compile_sort, fuse, run_stages


def rows():
    n = 14
    xs = jnp.asarray(np.random.default_rng(0).integers(0, 1 << 30, 1 << n,
                                                       dtype=np.int32))
    prog = fuse(compile_sort(n))
    run = lambda: run_stages(prog, xs).block_until_ready()
    got = np.asarray(run())
    assert np.array_equal(got, np.sort(np.asarray(xs)))
    t0 = time.perf_counter()
    run()
    dt = time.perf_counter() - t0
    return [(f"sort/2^{n}/cpu-jnp", dt * 1e6, "sorted=True")]


if __name__ == "__main__":
    for r in rows():
        print(",".join(str(v) for v in r))
