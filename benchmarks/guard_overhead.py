"""Guarded-execution overhead + fault-injection coverage (DESIGN.md §14).

Two questions the guard subsystem must answer with numbers:

* **What does ring 2 cost on the warm path?** Guarded dispatch fuses
  the program with its probes into one jitted executable, so the only
  *steady-state* additions are the in-program probe ops (a sampled
  parity gather-compare; the OOB check constant-folds away on clean
  tables) and one int32 host readback per call. The
  ``guard_overhead_ratio`` rows measure guarded vs unguarded warm
  dispatch of the 2^8 and 2^12 compiled sorts — min-of-reps, same
  methodology as the dispatch microbenchmarks — and check_bench gates
  the ratio at ``GUARD_OVERHEAD_TOL`` (the ISSUE's <=5% bar with the
  shared-CI-machine noise floor folded in).
* **Does ring 3 actually catch everything?** The ``fault_injection``
  row (model-only: ``us`` is null) runs the full corruption matrix of
  :func:`repro.guard.inject.run_fault_matrix` against the pallas
  engine and reports ``faults_caught``/``faults_injected`` —
  check_bench fails unless they are equal, i.e. zero
  silent-wrong-output cases.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro import guard
from repro.combinators import compile_expr
from repro.combinators.sort import sort_expr

REPS = 20
SIZES = (8, 12)


def _sorted_input(n: int) -> jax.Array:
    return jnp.asarray(np.random.default_rng(0).standard_normal(
        1 << n).astype(np.float32))


def rows():
    from .autodiff_overhead import _timed  # shared min-stat methodology

    out = []
    for n in SIZES:
        x = _sorted_input(n)
        f = compile_expr(sort_expr(n), engine="pallas")
        guard.disable()
        jax.block_until_ready(f(x))          # warm the unguarded path
        us_plain = _timed(f, x, reps=REPS)
        with guard.guarded():
            jax.block_until_ready(f(x))      # warm the guarded twin
            us_guarded = _timed(f, x, reps=REPS)
        ratio = us_guarded / max(us_plain, 1e-9)
        out.append((
            f"guard/sort/2^{n}/unguarded", us_plain, f"reps={REPS}"))
        out.append((
            f"guard/sort/2^{n}/overhead", us_guarded,
            f"reps={REPS};guard_overhead_ratio={ratio:.3f}"))

    # -- fault-injection coverage (model-only row: no wall clock) -----------
    from repro.guard.inject import run_fault_matrix

    r = run_fault_matrix(engine="pallas")
    kinds = ";".join(
        f"{c['kind']}={'caught' if c['caught'] else 'MISSED'}"
        for c in r["cases"])
    out.append((
        "guard/pallas/fault_injection", None,
        f"faults_caught={r['caught']};faults_injected={r['injected']};"
        f"{kinds}"))
    return out


if __name__ == "__main__":
    for row in rows():
        print(",".join(str(v) for v in row))
