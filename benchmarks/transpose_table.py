"""Paper §2.3 table: copy / naive transpose / tiled transpose.

The paper measures a 2^15 x 2^15 int32 matrix transpose on an RTX4090:
copy 9.3 ms (100%), naive 26.4 ms (35.2%), tiled 12.2 ms (76.2%).
We reproduce the structure via the transaction model (worst-case bound —
the naive bound is harsher than the measured cache-assisted number) and
verify the tiled kernel's correctness on a reduced matrix via Pallas
interpret mode.
"""
from __future__ import annotations

import numpy as np
import jax.numpy as jnp

from repro.core.bmmc import Bmmc
from repro.kernels.ops import bmmc_permute
from repro.kernels.ref import bmmc_ref
from .transaction_model import GPU_RTX4090, copy_time, naive_time, tiled_time

N = 30  # (2^15)^2 elements


def rows():
    b = Bmmc.matrix_transpose(15, 15)
    c = copy_time(N, GPU_RTX4090)
    tn = naive_time(b, GPU_RTX4090)
    tt = tiled_time(b, GPU_RTX4090, 5)
    out = [
        ("transpose/copy", c * 1e6, "bw=100%;paper=100%"),
        ("transpose/naive", tn * 1e6,
         f"bw={100 * c / tn:.1f}%;paper=35.2%(cache-assisted)"),
        ("transpose/tiled", tt * 1e6, f"bw={100 * c / tt:.1f}%;paper=76.2%"),
    ]
    # correctness at reduced size through the actual Pallas kernel
    bs = Bmmc.matrix_transpose(7, 7)
    x = jnp.arange(1 << 14, dtype=jnp.int32)
    got = np.asarray(bmmc_permute(x, bs, t=4))
    want = np.asarray(x).reshape(128, 128).T.reshape(-1)
    assert np.array_equal(got, want), "tiled transpose kernel mismatch"
    assert np.array_equal(got, np.asarray(bmmc_ref(x, bs)))
    out.append(("transpose/pallas-2^14-verified", 0.0, "allclose=True"))
    return out


if __name__ == "__main__":
    for r in rows():
        print(",".join(str(v) for v in r))
