"""Memory-transaction model shared by the paper-table benchmarks.

This container has no GPU/TPU clock, so the paper's *effective bandwidth*
tables are reproduced through exact transaction counting — the quantity the
paper's coalescing argument is about (§2.2): an uncoalesced access touches a
full segment per element, so

    time(variant) = touched_bytes(variant) / BW
    effective_bw  = copy_time / variant_time

``touched_bytes`` counts, per pass, read-side and write-side segment bytes:
fully-coalesced sides touch exactly the useful bytes; the naive kernel's
scattered side touches ``waste`` segments per warp/segment-width run
(measured exactly per matrix by ``naive_write_runs``). This reproduces the
paper's worst-case bound; hardware caches make measured GPU numbers a bit
kinder (paper: 11x for naive bit-reverse vs our 16.5x bound — same regime).

Two constant sets: the paper's GPU segment model (128 B segments, int32
elements) and the TPU-adapted model (512 B minimum efficient DMA run).
"""
from __future__ import annotations

import dataclasses

from repro.core.bmmc import Bmmc
from repro.core.tiling import naive_write_runs, stats_bmmc


@dataclasses.dataclass(frozen=True)
class HwModel:
    name: str
    seg_bytes: int
    bw: float                 # bytes/s
    itemsize: int = 4

    @property
    def seg_elems(self) -> int:
        return self.seg_bytes // self.itemsize


GPU_RTX4090 = HwModel("rtx4090-paper", seg_bytes=128, bw=1008e9)
TPU_V5E = HwModel("tpu-v5e", seg_bytes=512, bw=819e9)


def copy_time(n: int, hw: HwModel) -> float:
    nbytes = (1 << n) * hw.itemsize
    return 2 * nbytes / hw.bw  # read + write


def naive_time(bmmc: Bmmc, hw: HwModel, sample: int = 256) -> float:
    """Naive kernel: coalesced read, scattered write (paper §4 pre-tiling)."""
    nbytes = (1 << bmmc.n) * hw.itemsize
    waste = naive_write_runs(bmmc, hw.seg_elems, sample_tiles=sample)
    return (nbytes + nbytes * waste) / hw.bw


def tiled_time(bmmc: Bmmc, hw: HwModel, t: int) -> float:
    """Tiled kernel(s): both sides fully coalesced; 2 passes if general."""
    plans = stats_bmmc(bmmc, t)
    nbytes = (1 << bmmc.n) * hw.itemsize
    total = 0.0
    for p in plans:
        # rows are whole segments when 2^t * itemsize >= seg_bytes
        row_bytes = p.row_len * hw.itemsize
        waste = max(1.0, hw.seg_bytes / row_bytes)
        total += 2 * nbytes * waste / hw.bw
    return total


def descriptor_counts(bmmc: Bmmc, t: int) -> dict:
    plans = stats_bmmc(bmmc, t)
    return {
        "passes": len(plans),
        "descriptors": sum(p.dma_descriptors() for p in plans),
        "descriptors_unmerged": sum(
            p.n_tiles * 2 * p.rows_per_tile for p in plans),
    }
