"""Paper Fig. 10: optimized-vs-naive speedup across array sizes 2^20..2^30."""
from __future__ import annotations

import random

from repro.core.bmmc import Bmmc
from .transaction_model import GPU_RTX4090, naive_time, tiled_time

T = 5
SIZES = range(20, 31, 2)


def rows():
    out = []
    rng = random.Random(7)
    for n in SIZES:
        for name, b in [("bit-reverse", Bmmc.bit_reverse(n)),
                        ("random-bpc", Bmmc.random_bpc(n, rng)),
                        ("random-bmmc", Bmmc.random(n, rng))]:
            tn = naive_time(b, GPU_RTX4090)
            tt = tiled_time(b, GPU_RTX4090, T)
            out.append((f"fig10/{name}/2^{n}", tt * 1e6,
                        f"speedup={tn / tt:.2f}"))
    return out


if __name__ == "__main__":
    for r in rows():
        print(",".join(str(v) for v in r))
