"""Combinator optimizer: fused vs unfused pass counts for sort and FFT.

The §7.2 rewrite (``bmmc B ∘ bmmc A -> bmmc (BA)``) is the speed lever of
the combinator subsystem: every avoided permutation stage is a full
read+write of the array. This table reports, per workload and size, the
raw-lowered vs fused ``Perm``-stage counts, the resulting tiled kernel
passes (each general BMMC <= 2 passes, §5.2), and the modeled DMA
descriptor totals from the transaction model.
"""
from __future__ import annotations

from repro.combinators.fft import fft_expr
from repro.combinators.optimize import (fuse, lower, num_perm_stages,
                                        program_cost)
from repro.combinators.sort import sort_expr
from repro.kernels.ops import choose_tile


def rows():
    out = []
    for name, mk, sizes in (("sort", sort_expr, (4, 8, 12)),
                            ("fft", fft_expr, (4, 8, 12))):
        for n in sizes:
            raw = lower(mk(n), n)
            fz = fuse(raw)
            t = choose_tile(n, 4, 1) or max(1, n // 2)
            rc = program_cost(raw, t)
            fc = program_cost(fz, t)
            out.append((
                f"combinators/{name}/2^{n}", None,
                f"raw_perms={num_perm_stages(raw)};"
                f"fused_perms={num_perm_stages(fz)};"
                f"raw_passes={rc['tiled_passes']};"
                f"fused_passes={fc['tiled_passes']};"
                f"raw_desc={rc['descriptors']};"
                f"fused_desc={fc['descriptors']}",
            ))
    return out


if __name__ == "__main__":
    for r in rows():
        print(",".join(str(v) for v in r))
