"""Durable-store warm start: cold-boot vs disk-warm vs in-process-warm
(DESIGN.md §15).

Three latencies per workload, min-of-boots:

* ``cold_boot`` — store disabled, every in-process cache dropped: the
  first call pays trace + compile + *plan from scratch*.
* ``disk_warm`` — same fresh-process state but a populated store: the
  first call pays trace + compile + *decode-and-audit from disk*
  (every loaded plan re-proves through guard ring 1 — integrity is
  never traded for the speedup).
* ``warm`` — in-process warm steady state (the lru caches hot), the
  latency every later call sees either way.

The ``/warmstart`` telemetry row is the gated contract: a disk-warm
boot must serve 100% disk hits and compile zero plans
(``disk_hit_rate=1.0;plans_built=0``), and the measured
``warmstart_speedup`` (cold / disk-warm first-call latency) must clear
check_bench's floor. The ``store/disk/fault_injection`` row runs the
disk-fault matrix (truncate / bitflip / skew / torn / quarantine race)
and is gated at caught == injected.

CLI (the CI two-phase job)::

    python -m benchmarks.store_warmstart --phase warm  --store PATH
    python -m benchmarks.store_warmstart --phase serve --store PATH

Phase ``warm`` populates PATH from a cold process; phase ``serve`` (a
fresh process) replays the same workloads and exits nonzero unless the
store served every plan (zero compiled, zero misses).
"""
from __future__ import annotations

import argparse
import sys
import tempfile
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import store
from repro.combinators import vocab as V
from repro.combinators.execute import clear_caches, compile_expr
from repro.combinators.sort import sort_expr

BOOTS = 3
SIZES = (8, 12)


def _workloads(sizes=SIZES):
    """The fixed workload list both phases replay (keys must match)."""
    rng = np.random.default_rng(0)
    out = []
    for n in sizes:
        x = jnp.asarray(rng.standard_normal(1 << n).astype(np.float32))
        out.append((f"sort/2^{n}", sort_expr(n), x))
    xb = jnp.asarray(rng.standard_normal(1 << 10).astype(np.float32))
    out.append(("bit_reverse/2^10", V.bit_reverse(10), xb))
    return out


def _first_call_us(expr, x) -> float:
    t0 = time.perf_counter_ns()
    jax.block_until_ready(compile_expr(expr)(x))
    return (time.perf_counter_ns() - t0) / 1e3


def _boot(expr, x, root) -> float:
    """One fresh-process-equivalent boot: drop every in-process cache,
    point the store at ``root`` (or disable it), first-call latency."""
    clear_caches()
    store.configure(root)
    return _first_call_us(expr, x)


def rows():
    from .autodiff_overhead import _timed  # shared min-stat methodology
    from repro.obs import metrics as _om

    out = []
    tmp = tempfile.mkdtemp(prefix="repro-warmstart-")
    prev = store.active()
    try:
        hit_rates, plans_built = [], []
        for name, expr, x in _workloads():
            # populate once so disk-warm boots start from a full store
            _boot(expr, x, tmp)
            cold = min(_boot(expr, x, None) for _ in range(BOOTS))
            warm_boots = []
            for _ in range(BOOTS):
                us = _boot(expr, x, tmp)
                warm_boots.append(us)
                _om.observe("store.warmstart_us", us, workload=name)
            disk_warm = min(warm_boots)
            s = store.stats()
            hit_rates.append(
                s["hit"] / max(s["hit"] + s["miss"], 1))
            plans_built.append(s["plan_built"])
            f = compile_expr(expr)
            warm = _timed(f, x, reps=10)
            speedup = cold / max(disk_warm, 1e-9)
            out.append((f"store/{name}/cold_boot", cold, f"boots={BOOTS}"))
            out.append((f"store/{name}/disk_warm", disk_warm,
                        f"boots={BOOTS};warmstart_speedup={speedup:.3f};"
                        f"store_hits={s['hit']};store_misses={s['miss']}"))
            out.append((f"store/{name}/warm", warm, "reps=10"))
        # the gated warm-start contract, aggregated over the workloads
        agg_cold = sum(r[1] for r in out if r[0].endswith("/cold_boot"))
        agg_warm = sum(r[1] for r in out if r[0].endswith("/disk_warm"))
        out.append((
            "store/warmstart", None,
            f"disk_hit_rate={min(hit_rates):.3f};"
            f"plans_built={max(plans_built)};"
            f"warmstart_speedup={agg_cold / max(agg_warm, 1e-9):.3f};"
            f"entries={store.active().entry_count()}"))
    finally:
        clear_caches()
        store.configure(prev.root if prev is not None else None)

    # -- disk-fault coverage (model-only row: no wall clock) ----------------
    from repro.guard.inject import run_disk_fault_matrix

    r = run_disk_fault_matrix()
    kinds = ";".join(
        f"{c['kind']}={'caught' if c['caught'] else 'MISSED'}"
        for c in r["cases"])
    out.append((
        "store/disk/fault_injection", None,
        f"faults_caught={r['caught']};faults_injected={r['injected']};"
        f"{kinds}"))
    return out


# ---------------------------------------------------------------------------
# the CI two-phase entry point
# ---------------------------------------------------------------------------

def _phase(which: str, root: str) -> int:
    store.configure(root)
    for name, expr, x in _workloads():
        jax.block_until_ready(compile_expr(expr)(x))
        print(f"# {which}: {name} done; {store.stats()}")
    s = store.stats()
    if which == "serve":
        ok = s["plan_built"] == 0 and s["miss"] == 0 and s["hit"] > 0
        print(f"phase B: hits={s['hit']} misses={s['miss']} "
              f"plans_built={s['plan_built']} -> "
              f"{'100% disk-hit, zero plans compiled' if ok else 'FAIL'}")
        return 0 if ok else 1
    ok = s["plan_built"] > 0 and s["write"] == s["plan_built"]
    print(f"phase A: wrote {s['write']} entries "
          f"({store.active().entry_count()} on disk)")
    return 0 if ok else 1


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--phase", choices=("warm", "serve"), default=None,
                    help="CI two-phase mode: 'warm' populates --store from "
                         "a cold process; 'serve' (fresh process) must "
                         "serve 100%% disk hits with zero plans compiled")
    ap.add_argument("--store", default=None, metavar="PATH",
                    help="store root for --phase")
    args = ap.parse_args()
    if args.phase:
        if not args.store:
            ap.error("--phase requires --store PATH")
        return _phase(args.phase, args.store)
    for row in rows():
        print(",".join("" if v is None else str(v) for v in row))
    return 0


if __name__ == "__main__":
    sys.exit(main())
