"""Autodiff overhead: fwd vs fwd+bwd µs/call for fused combinator programs.

The backward pass of a permutation program is the offline-inverted
program (DESIGN.md §9), so fwd+bwd should cost ~2x fwd in permutation
passes — not the gather-transpose blowup a generic autodiff would pay.
This table reports wall-clock per call on both engines (interpret-mode
pallas; see §7.4 on clocks) plus the modeled pass counts of the forward
and VJP programs, batched and unbatched.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.combinators import compile_expr, inverse_program, vocab as V
from repro.combinators.optimize import num_perm_stages
from repro.combinators.sort import sort_expr
from repro.core.bmmc import Bmmc


def _timed(fn, *args, reps: int = 8):
    """Min µs/call over ``reps`` calls (min, not mean: interpret-mode
    timings on a loaded CPU are noisy in one direction only). Callers
    must warm ``fn`` — and any sibling paths sharing plan/executable
    caches — BEFORE timing: the first call pays trace+compile plus the
    shared offline-table caches, and timing it inflated ``fwd_us`` above
    ``fwdbwd_us`` in BENCH_PR4 (7051.8 vs 2814.1 µs: a warmup artifact,
    not physics)."""
    ts = []
    for _ in range(reps):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        ts.append((time.perf_counter() - t0) * 1e6)
    return min(ts)


def _programs(n):
    import random
    rng = random.Random(0)
    return (
        ("permchain", V.bit_reverse(n) >> V.perm(Bmmc.random(n, rng))
         >> V.riffle(n)),
        ("sort", sort_expr(n)),
    )


def rows():
    out = []
    n = 8
    x = jnp.asarray(np.random.default_rng(0).normal(
        size=(1 << n,)).astype(np.float32))
    xb = jnp.tile(x, (8, 1))
    for name, e in _programs(n):
        for engine in ("ref", "pallas"):
            f = compile_expr(e, engine=engine)
            prog = f.program(n)
            perms = num_perm_stages(prog)
            try:
                vjp_perms = num_perm_stages(inverse_program(prog))
            except TypeError:  # non-perm stages: VJP handled by jax autodiff
                vjp_perms = perms
            fwd = jax.jit(lambda x: jnp.sum(f(x) ** 2))
            bwd = jax.jit(jax.grad(lambda x: jnp.sum(f(x) ** 2)))
            fwd_b = jax.jit(lambda x: jnp.sum(f(x, batched=True) ** 2))
            bwd_b = jax.jit(jax.grad(
                lambda x: jnp.sum(f(x, batched=True) ** 2)))
            # warm EVERY path before timing ANY: trace+compile and the
            # shared plan/executable caches must not land in the first
            # timed row (the PR4 fwd>fwdbwd artifact)
            for wfn, warg in ((fwd, x), (bwd, x), (fwd_b, xb), (bwd_b, xb)):
                jax.block_until_ready(wfn(warg))
            us_f = _timed(fwd, x)
            us_fb = _timed(bwd, x)
            us_bf = _timed(fwd_b, xb)
            us_bfb = _timed(bwd_b, xb)
            out.append((
                f"autodiff/{name}/2^{n}/{engine}", us_fb,
                f"fwd_us={us_f:.1f};fwdbwd_us={us_fb:.1f};"
                f"batched8_fwd_us={us_bf:.1f};batched8_fwdbwd_us={us_bfb:.1f};"
                f"fwd_perm_stages={perms};vjp_perm_stages={vjp_perms}",
            ))
    return out


if __name__ == "__main__":
    for r in rows():
        print(",".join(str(v) for v in r))
