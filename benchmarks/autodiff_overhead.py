"""Autodiff overhead: fwd vs fwd+bwd µs/call for fused combinator programs.

The backward pass of a permutation program is the offline-inverted
program (DESIGN.md §9/§13), and the backward of a compute-bearing
program is the COLLAPSED plan — every transposed pairwise compute
conjugated into forward-output coordinates plus at most ONE composed
inverse BMMC pass — so fwd+bwd should cost ~2x fwd, not the per-stage
replay blowup a generic autodiff would pay. This table reports
wall-clock per call on both engines (interpret-mode pallas; see §7.4 on
clocks) plus the modeled pass counts, batched and unbatched.

``*/bwd_telemetry`` rows additionally hold one COLD backward call's
``model.vjp_round_trips`` counter delta against the compiled backward's
modeled cost (``CompiledExpr.vjp_round_trips``) and record the
backward kernel-class histogram next to the forward's — the backward
honesty gate (DESIGN.md §13), gated by check_bench. These rows carry no
wall-clock measurement, so their ``us`` field is None.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import obs
from repro.combinators import (clear_caches, compile_expr, inverse_program,
                               is_perm_program, vocab as V)
from repro.combinators.optimize import num_perm_stages
from repro.combinators.sort import sort_expr
from repro.core.bmmc import Bmmc
from repro.kernels.ops import choose_tile


def _timed(fn, *args, reps: int = 8):
    """Min µs/call over ``reps`` calls (min, not mean: interpret-mode
    timings on a loaded CPU are noisy in one direction only). The
    callable is re-warmed with one untimed call immediately before the
    timed reps — jit caches were populated earlier, but re-warming PER
    PATH keeps python-side cache-miss tails (weakref probes, dispatch
    memos touched by a sibling path) out of the first timed rep; BENCH_
    PR4 and PR6 both recorded ``fwd_us > fwdbwd_us`` artifacts from
    timing a path straight after warming a *different* one."""
    jax.block_until_ready(fn(*args))
    ts = []
    for _ in range(reps):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        ts.append((time.perf_counter() - t0) * 1e6)
    return min(ts)


def _programs(n):
    import random
    rng = random.Random(0)
    return (
        ("permchain", V.bit_reverse(n) >> V.perm(Bmmc.random(n, rng))
         >> V.riffle(n)),
        ("sort", sort_expr(n)),
    )


def _measure_pair(fwd, bwd, x, reps: int = 8):
    """Time a (fwd, fwd+bwd) pair with the bench's own sanity check:
    a ``jit(value_and_grad(loss))`` call strictly contains the loss's
    forward work, so ``fwdbwd_us < fwd_us`` can only be measurement
    noise. (``jit(grad(loss))`` — what BENCH_PR4..PR6 timed — does NOT:
    XLA dead-code-eliminates the loss reduction the grad never uses,
    which is exactly how permchain/ref recorded fwd_us=8.6 >
    fwdbwd_us=7.4.) Violations re-measure once at 4x the reps (tighter
    mins under a loaded CPU); a persisting violation is a real timing
    bug and raises."""
    us_f = _timed(fwd, x, reps=reps)
    us_fb = _timed(bwd, x, reps=reps)
    if us_fb < us_f:
        us_f = _timed(fwd, x, reps=4 * reps)
        us_fb = _timed(bwd, x, reps=4 * reps)
    assert us_fb >= us_f, (
        f"fwd+bwd measured cheaper than fwd ({us_fb:.1f} < {us_f:.1f} µs) "
        "after re-measure: warmup/timing artifact")
    return us_f, us_fb


def _bwd_telemetry_row(name, n, t, expr, x):
    """One COLD backward call's counter delta vs the compiled backward's
    model, plus forward/backward kernel-class histograms (pallas only —
    the ref engine records no transaction-model counters).

    Counters fire at executable trace time, so "cold" means the
    executor caches are cleared (same semantics as the forward
    telemetry gate in class_dispatch.py). The forward histogram is
    measured from a loss-only call, the backward's is the grad call's
    delta against it; for a permutation-only program the backward
    histogram must MIRROR the forward's class for class (the inverse
    program re-dispatches the same kernel classes), while a collapsed
    compute-bearing backward dispatches at most the one composed final
    pass."""
    f = compile_expr(expr, engine="pallas")
    modeled = f.vjp_round_trips(n, t)
    was_enabled = obs.enabled()
    obs.enable(sync=True)
    try:
        clear_caches()
        obs.reset()
        jax.block_until_ready(jax.jit(lambda v: jnp.sum(f(v) ** 2))(x))
        fwd_kernels = obs.kernel_counts()
        clear_caches()
        obs.reset()
        jax.block_until_ready(
            jax.jit(jax.grad(lambda v: jnp.sum(f(v) ** 2)))(x))
        delta = int(obs.counter_total("model.vjp_round_trips"))
        grad_kernels = obs.kernel_counts()
    finally:
        if not was_enabled:
            obs.disable()
        obs.reset()
    bwd_kernels = {k: v - fwd_kernels.get(k, 0)
                   for k, v in grad_kernels.items()
                   if v - fwd_kernels.get(k, 0)}
    match = modeled is not None and delta == modeled
    parts = [f"bwd_counts_match={match}", f"bwd_round_trips={delta}",
             f"model_bwd_round_trips={modeled}"]
    if is_perm_program(f.clustered_program(n, t)):
        # perm-only: the inverse program re-dispatches the same kernel
        # classes, so the backward histogram must mirror the forward's
        parts.append(f"bwd_mirrors_fwd={bwd_kernels == fwd_kernels}")
    parts += [f"fwd_{k}={v}" for k, v in sorted(fwd_kernels.items())]
    parts += [f"bwd_{k}={v}" for k, v in sorted(bwd_kernels.items())]
    return (f"autodiff/{name}/2^{n}/bwd_telemetry", None, ";".join(parts))


def rows():
    out = []
    n = 8
    x = jnp.asarray(np.random.default_rng(0).normal(
        size=(1 << n,)).astype(np.float32))
    xb = jnp.tile(x, (8, 1))
    progs = _programs(n)
    for name, e in progs:
        for engine in ("ref", "pallas"):
            f = compile_expr(e, engine=engine)
            prog = f.program(n)
            perms = num_perm_stages(prog)
            try:
                vjp_perms = num_perm_stages(inverse_program(prog))
            except TypeError:  # non-perm stages: collapsed/replay backward
                vjp_perms = perms
            fwd = jax.jit(lambda x: jnp.sum(f(x) ** 2))
            bwd = jax.jit(jax.value_and_grad(lambda x: jnp.sum(f(x) ** 2)))
            fwd_b = jax.jit(lambda x: jnp.sum(f(x, batched=True) ** 2))
            bwd_b = jax.jit(jax.value_and_grad(
                lambda x: jnp.sum(f(x, batched=True) ** 2)))
            # warm EVERY path before timing ANY: trace+compile and the
            # shared plan/executable caches must not land in the first
            # timed row (the PR4 fwd>fwdbwd artifact); _timed re-warms
            # each callable again right before its own reps
            for wfn, warg in ((fwd, x), (bwd, x), (fwd_b, xb), (bwd_b, xb)):
                jax.block_until_ready(wfn(warg))
            us_f, us_fb = _measure_pair(fwd, bwd, x)
            us_bf, us_bfb = _measure_pair(fwd_b, bwd_b, xb)
            out.append((
                f"autodiff/{name}/2^{n}/{engine}", us_fb,
                f"fwd_us={us_f:.1f};fwdbwd_us={us_fb:.1f};"
                f"batched8_fwd_us={us_bf:.1f};batched8_fwdbwd_us={us_bfb:.1f};"
                f"fwd_perm_stages={perms};vjp_perm_stages={vjp_perms}",
            ))
    # telemetry rows last: they clear the executor caches, which would
    # otherwise make a later timing row repay tracing inside its warmup
    t = choose_tile(n, 4, 1)
    for name, e in progs:
        out.append(_bwd_telemetry_row(name, n, t, e, x))
    return out


if __name__ == "__main__":
    for r in rows():
        print(",".join("" if v is None else str(v) for v in r))
