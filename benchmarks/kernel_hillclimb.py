"""Kernel-level hillclimb (§Perf #3): tile size + DMA-descriptor modeling.

TPU v5e DMA model (per-chip):
  * bandwidth: 819 GB/s HBM;
  * a DMA descriptor expresses an N-D strided copy (innermost = one
    contiguous 2^t-element row; up to DMA_DIMS-1 additional stride dims).
    Contiguous runs of tile-row *bit positions* collapse into one stride
    dim, so descriptors/tile = prod of sizes of the bit-position groups
    beyond the first DMA_DIMS-1;
  * descriptor issue costs T_DESC on the scalar core (not overlappable
    beyond the issue queue).

  time(t) = max(touched_bytes / BW, descriptors * T_DESC)

Iterates t for the paper's three cases at n=30 int32; asserts correctness
of every candidate against ref.py at reduced size via the Pallas kernel.
"""
from __future__ import annotations

import random

import jax.numpy as jnp
import numpy as np

from repro.core.bmmc import Bmmc
from repro.core import f2
from repro.kernels.ops import bmmc_permute
from repro.kernels.ref import bmmc_ref

BW = 819e9
T_DESC = 100e-9       # descriptor issue interval, scalar core
SEG = 512             # minimum efficient contiguous run, bytes
DMA_DIMS = 4          # innermost row + 3 stride dims
ITEM = 4


def _bit_groups(positions):
    """Contiguous runs of bit positions -> one stride dim each."""
    groups = []
    for p in sorted(positions):
        if groups and p == groups[-1][-1] + 1:
            groups[-1].append(p)
        else:
            groups.append([p])
    return groups


def _pass_model(bmmc: Bmmc, t: int):
    """(touched_bytes, descriptors) for one tiled pass, strided-DMA model."""
    n = bmmc.n
    cols = bmmc.tiled_columns(t)
    if cols is None:
        return None
    low = set(range(t))
    r_set = set(cols)
    n_over = len(r_set & low)
    if n - 2 * t + n_over < 0:
        return None
    n_tiles = 1 << (n - 2 * t + n_over)
    rpt = 1 << (t - n_over)
    row_bytes = (1 << t) * ITEM
    waste = max(1.0, SEG / row_bytes)
    nbytes = (1 << n) * ITEM

    # input side: tile rows vary over R\L bit positions (shifted down by t)
    in_groups = _bit_groups([p - t for p in sorted(r_set - low)])
    extra_in = 1
    for g in in_groups[DMA_DIMS - 1:]:
        extra_in *= 1 << len(g)
    # output side: general tiled BMMCs scatter output rows without a single
    # affine stride structure unless the map is a BPC; approximate with the
    # analytic out_run merging.
    from repro.core.tiling import plan_stats
    st = plan_stats(bmmc, t)
    out_desc_per_tile = rpt // st.out_run
    if bmmc.is_bpc():
        # for BPCs the output rows also form a bit-grid: same group law.
        # Output row bits = images p(j) of the tile-column bits j in L\R,
        # shifted down by t.
        p = f2.to_perm(bmmc.rows)
        outs = [p[j] - t for j in range(t) if p[j] >= t]
        og = _bit_groups(outs)
        out_desc_per_tile = 1
        for g2 in og[DMA_DIMS - 1:]:
            out_desc_per_tile *= 1 << len(g2)
    desc = n_tiles * (extra_in + out_desc_per_tile)
    return 2 * nbytes * waste, desc


def model_time(bmmc: Bmmc, t: int):
    total_b, total_d = 0.0, 0
    for fac in bmmc.factor_tiled(t):
        r = _pass_model(fac, t)
        if r is None:
            return None
        total_b += r[0]
        total_d += r[1]
    return max(total_b / BW, total_d * T_DESC), total_b / BW, total_d


def copy_time(n):
    return 2 * (1 << n) * ITEM / BW


def rows():
    out = []
    n = 30
    rng = random.Random(42)
    cases = [("bit-reverse", Bmmc.bit_reverse(n)),
             ("random-bpc", Bmmc.random_bpc(n, rng)),
             ("random-bmmc", Bmmc.random(n, rng))]
    c = copy_time(n)
    for name, b in cases:
        best = None
        for t in range(5, 11):
            r = model_time(b, t)
            if r is None:
                continue
            tt, bt, d = r
            out.append((f"khc/{name}/t={t}", tt * 1e6,
                        f"bytes_s={bt * 1e6:.0f}us;desc={d:.3g};"
                        f"bw_frac={c / tt:.2f}"))
            if best is None or tt < best[1]:
                best = (t, tt)
        out.append((f"khc/{name}/BEST", best[1] * 1e6,
                    f"t={best[0]};bw_frac={c / best[1]:.2f}"))
        # correctness of the chosen t at reduced size (kernel actually runs)
        ns = 14
        bs = {"bit-reverse": Bmmc.bit_reverse(ns),
              "random-bpc": Bmmc.random_bpc(ns, rng),
              "random-bmmc": Bmmc.random(ns, rng)}[name]
        x = jnp.arange(1 << ns, dtype=jnp.int32)
        got = np.asarray(bmmc_permute(x, bs, t=min(best[0], ns // 2)))
        assert np.array_equal(got, np.asarray(bmmc_ref(x, bs))), name
    return out


if __name__ == "__main__":
    for r in rows():
        print(",".join(str(v) for v in r))
