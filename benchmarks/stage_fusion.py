"""Fused-stage megakernel: per-stage vs fused execution (DESIGN.md §10).

Two views of the same lever:

* **Modeled** (offline, any size): ``program_cost`` on the fused-but-
  unclustered program vs the clustered one — HBM round trips, DMA
  descriptors, bytes moved. The acceptance bar is >= 2x fewer round
  trips for the 2^12 sort and FFT.
* **Measured** (interpret mode): wall-clock of the compiled program
  through the "pallas" engine with clustering on vs off. Interpret mode
  has no DMA overlap, so the win here comes from executing one megakernel
  dispatch instead of `k` kernel passes + jnp sweeps per cluster; the
  modeled bytes say what real hardware would additionally save.

The copy-through-VMEM roofline baseline rides along; rows whose size
does not divide the copy block are labeled ``padded=<elems>`` (the
degenerate path zero-pads instead of silently skipping pallas).
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.combinators import cluster, program_cost, run_program
from repro.combinators.fft import fft_expr, to_planar
from repro.combinators.optimize import optimize
from repro.combinators.sort import sort_expr
from repro.kernels.bmmc_permute import copy_pad_elems, copy_through_vmem
from repro.kernels.ops import choose_tile

MODEL_N = 12        # the acceptance size (modeled only: offline cost)
WALL_N = 9          # interpret-mode wall-clock size (small: CPU interpret)
REPS = 5


def _time(fn, x) -> float:
    """Min wall-clock (us) of REPS calls, after a warmup/compile call.

    Min, not median: interpret-mode timings on a loaded CPU are noisy in
    one direction only (scheduler preemption), and the minimum is the
    standard noise-robust microbenchmark statistic."""
    fn(x).block_until_ready()
    ts = []
    for _ in range(REPS):
        t0 = time.perf_counter()
        fn(x).block_until_ready()
        ts.append((time.perf_counter() - t0) * 1e6)
    return float(np.min(ts))


def _programs(name: str, n: int):
    mk = sort_expr if name == "sort" else fft_expr
    prog = optimize(mk(n), n)
    t = choose_tile(n, 4, 2 if name == "fft" else 1) or max(1, n // 2)
    return prog, cluster(prog, n, t), t


def _payload(name: str, n: int):
    rng = np.random.default_rng(0)
    if name == "fft":
        z = rng.normal(size=1 << n) + 1j * rng.normal(size=1 << n)
        return to_planar(z.astype(np.complex64))
    return jnp.asarray(rng.normal(size=1 << n).astype(np.float32))


def rows():
    out = []
    # -- modeled transaction report at the acceptance size ------------------
    for name in ("sort", "fft"):
        prog, clustered, t = _programs(name, MODEL_N)
        c0 = program_cost(prog, t)
        c1 = program_cost(clustered, t)
        ratio = c0["round_trips"] / max(c1["round_trips"], 1)
        out.append((
            f"stagefusion/{name}/2^{MODEL_N}/model", None,
            f"t={t};round_trips={c0['round_trips']}->{c1['round_trips']};"
            f"ratio={ratio:.2f};bytes={c0['bytes_moved']}->{c1['bytes_moved']};"
            f"desc={c0['descriptors']}->{c1['descriptors']}",
        ))

    # -- interpret-mode wall clock ------------------------------------------
    # The sort is the honest interpret-mode proxy: its per-stage cost is
    # dominated by kernel passes, which is what fusion removes. The fused
    # FFT is reported too but its interpret-mode time is bound by VPU
    # *emulation* of the in-tile twiddle gathers — work that is free
    # relative to DMA on hardware but not under the interpreter — so its
    # wall-clock is labeled, not claimed as the hardware prediction (the
    # model rows above carry that: 24x fewer round trips).
    for name, note in (("sort", ""), ("fft", ";interpret-gather-bound")):
        prog, clustered, wt = _programs(name, WALL_N)
        x = _payload(name, WALL_N)
        us_stage = _time(
            jax.jit(lambda v, p=prog: run_program(p, v, "pallas")), x)
        us_fused = _time(
            jax.jit(lambda v, p=clustered: run_program(p, v, "pallas")), x)
        out.append((f"stagefusion/{name}/2^{WALL_N}/perstage", us_stage, ""))
        measured = us_stage / max(us_fused, 1e-9)
        out.append((
            f"stagefusion/{name}/2^{WALL_N}/fused", us_fused,
            f"speedup={measured:.2f}x{note}",
        ))
        # model-vs-measured accounting at the measured size: the model
        # says fusion wins by the round-trip ratio; the wall clock says
        # what it actually won. ``drift`` (how far the two ratios
        # disagree, symmetric ≥ 1) is what check_bench's honesty gate
        # tracks across baselines — interpret mode won't match hardware
        # physics, but its drift should stay stable run over run.
        cw0 = program_cost(prog, wt)
        cw1 = program_cost(clustered, wt)
        modeled = cw0["round_trips"] / max(cw1["round_trips"], 1)
        rel = measured / modeled
        out.append((
            f"stagefusion/{name}/2^{WALL_N}/model_error", None,
            f"modeled_speedup={modeled:.2f};measured_speedup={measured:.2f};"
            f"drift={max(rel, 1 / rel):.2f}{note}",
        ))

    # -- copy roofline baseline (same array sizes), pad-labeled -------------
    for n in (WALL_N, MODEL_N):
        x = jnp.arange(1 << n, dtype=jnp.float32)
        pad = copy_pad_elems(x.size)
        us = _time(jax.jit(lambda v: copy_through_vmem(v)), x)
        out.append((
            f"stagefusion/copy/2^{n}", us,
            f"padded={pad}" if pad else "exact",
        ))
    return out


if __name__ == "__main__":
    for r in rows():
        print(",".join(str(v) for v in r))
